//! Tuning-as-a-service: an in-process daemon on a loopback socket, two
//! concurrent campaigns submitted through the framed wire client, live
//! event streaming, and an automatic shared-history warm start for the
//! follow-up campaign.
//!
//! ```bash
//! cargo run --release --example service_tuning
//! ```
//!
//! This is the long-lived deployment mode of the paper's tuner: instead
//! of one batch job per campaign, `ytopt-rs serve` keeps a scheduler,
//! worker substrate, and cross-run history store resident, and clients
//! submit campaigns over a length-prefixed framed protocol (`submit`,
//! `watch`, `status`, `cancel`, `shutdown`). Every completed campaign
//! feeds the shared history store, so the *next* compatible campaign
//! warm-starts from its predecessors' elites with no flags at all.

use std::sync::Arc;

use ytopt::runtime::Scorer;
use ytopt::service::{CampaignSpec, Client, Daemon, Event, ServeConfig, ServiceConfig};

fn main() -> anyhow::Result<()> {
    let scorer = Arc::new(Scorer::auto(&ytopt::runtime::default_artifacts_dir()));
    let history = std::env::temp_dir().join("ytopt-service-example-history");
    let _ = std::fs::remove_dir_all(&history); // fresh store each invocation
    std::fs::create_dir_all(&history)?;

    // an ephemeral loopback daemon — in production this is `ytopt-rs
    // serve --addr 127.0.0.1:7459 --history-dir ~/.ytopt/history`
    let daemon = Daemon::start(
        ServeConfig {
            listen: "127.0.0.1:0".into(),
            service: ServiceConfig {
                max_active: 2,
                history_dir: Some(history.clone()),
                checkpoint_dir: None,
                warm_start_elites: 8,
            },
            chaos: None,
        },
        scorer,
    )?;
    let addr = daemon.addr().to_string();
    println!("daemon listening on {addr}\n");

    let mut client = Client::connect(&addr)?;

    // two concurrent energy campaigns over different seeds
    let first = client.submit(CampaignSpec {
        metric: "energy".into(),
        seed: 2023,
        max_evals: 24,
        workers: 4,
        ..CampaignSpec::default()
    })?;
    let second = client.submit(CampaignSpec {
        metric: "energy".into(),
        seed: 2024,
        max_evals: 24,
        workers: 4,
        ..CampaignSpec::default()
    })?;
    println!("submitted campaigns #{first} and #{second} (running concurrently)\n");

    for id in [first, second] {
        let terminal = client.watch(id, 0, &mut |ev| match ev {
            Event::WarmStarted { elites, .. } => {
                println!("campaign #{id}: warm-started from {elites} stored elites")
            }
            Event::Improved { best_objective, config_desc, .. } => {
                println!("campaign #{id}: best -> {best_objective:.3} ({config_desc})")
            }
            _ => {}
        })?;
        if let Event::Done { summary, .. } = terminal {
            println!(
                "campaign #{id}: done — {} evals, best {:.3} ({:.2}% better than baseline)\n",
                summary.evaluations, summary.best_objective, summary.improvement_pct
            );
        }
    }

    // the follow-up campaign warm-starts from the finished campaigns'
    // records automatically: the only "flag" is the daemon's shared
    // history dir, which it already owns
    let third = client.submit(CampaignSpec {
        metric: "energy".into(),
        seed: 2025,
        max_evals: 24,
        workers: 4,
        ..CampaignSpec::default()
    })?;
    println!("submitted follow-up campaign #{third} (auto warm start)\n");
    let terminal = client.watch(third, 0, &mut |ev| {
        if let Event::WarmStarted { elites, .. } = ev {
            println!("campaign #{third}: warm-started from {elites} stored elites");
        }
    })?;
    if let Event::Done { summary, .. } = terminal {
        println!(
            "campaign #{third}: done — best {:.3} ({:.2}% better than baseline)\n",
            summary.best_objective, summary.improvement_pct
        );
    }

    for row in client.status()? {
        println!(
            "  #{:<3} {:<11} {:<16} seed {:<6} evals {:<4} best {:.3}",
            row.id, row.state, row.app, row.seed, row.evaluations, row.best_objective
        );
    }

    client.shutdown()?;
    daemon.shutdown();
    let _ = std::fs::remove_dir_all(&history);
    Ok(())
}
