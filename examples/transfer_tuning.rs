//! Cross-run transfer tuning through the history database: tune at a
//! small node count with `--history-dir` semantics (the run appends a
//! `RunRecord` to a store), then warm-start the large-scale search from
//! that store with `--warm-start-from` semantics.
//!
//! ```bash
//! cargo run --release --example transfer_tuning
//! ```
//!
//! Unlike `transfer_learning.rs` (which hand-carries observations
//! through the deprecated baseline-ratio free function), this is the
//! durable pipeline: the store survives the process, indexes runs by
//! space fingerprint, picks the nearest source scale, and feeds the
//! top-K elites to the optimizer as foreign observations — recorded,
//! marked seen, never re-proposed.

use std::sync::Arc;

use ytopt::apps::AppKind;
use ytopt::coordinator::{autotune_with_scorer, TuneResult, TuneSetup};
use ytopt::history::HistoryStore;
use ytopt::metrics::Metric;
use ytopt::platform::PlatformKind;
use ytopt::runtime::Scorer;

fn main() -> anyhow::Result<()> {
    let scorer = Arc::new(Scorer::auto(&ytopt::runtime::default_artifacts_dir()));
    let store_dir =
        std::env::temp_dir().join(format!("ytopt-transfer-store-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&store_dir);
    let evals = 20usize;

    // 1) small-scale seed run (cheap: 64 nodes), recorded into the store
    let mut small = TuneSetup::new(AppKind::Amg, PlatformKind::Theta, 64, Metric::Runtime);
    small.max_evals = evals;
    small.wallclock_budget_s = 1e9;
    small.seed = 11;
    small.history_dir = Some(store_dir.clone());
    let r_small = autotune_with_scorer(&small, scorer.clone())?;
    println!("--- small scale (64 nodes), recorded to the store ---\n{}", r_small.summary());

    let store = HistoryStore::open(&store_dir)?;
    println!("store now holds {} run record(s) at {}\n", store.load_all()?.len(), store_dir.display());

    // 2) large-scale runs: cold start vs store-driven warm start
    let run_large = |warm: bool| -> anyhow::Result<TuneResult> {
        let mut large = TuneSetup::new(AppKind::Amg, PlatformKind::Theta, 1024, Metric::Runtime);
        large.max_evals = evals;
        large.wallclock_budget_s = 1e9;
        large.seed = 12;
        if warm {
            large.warm_start_from = Some(store_dir.clone());
            large.warm_start_elites = 8;
            large.n_init = 2; // the transferred elites replace most of the random init
        }
        autotune_with_scorer(&large, scorer.clone())
    };
    let cold = run_large(false)?;
    let warm = run_large(true)?;
    println!("--- large scale (1,024 nodes), cold start ---\n{}", cold.summary());
    println!("--- large scale (1,024 nodes), warm start from the store ---\n{}", warm.summary());

    // convergence comparison: best-so-far after k evaluations
    println!("best-so-far by evaluation (cold vs warm):");
    for k in [4usize, 8, 12, 16, evals] {
        let at = |r: &TuneResult| {
            r.db.records
                .iter()
                .take(k)
                .filter(|x| !x.timed_out)
                .map(|x| x.objective)
                .fold(f64::INFINITY, f64::min)
        };
        println!("  after {k:2} evals: cold {:.3} s | warm {:.3} s", at(&cold), at(&warm));
    }

    std::fs::remove_dir_all(&store_dir)?;
    Ok(())
}
