//! Ensemble autotuning of XSBench for energy and EDP on (simulated)
//! Theta, with eight workers evaluating configurations concurrently.
//!
//! ```bash
//! cargo run --release --example ensemble_tuning
//! ```
//!
//! This is the libEnsemble-style extension of the paper's energy study
//! (§VII): the Bayesian optimizer keeps proposing under constant-liar
//! imputation while in-flight configurations run on the worker pool, a
//! straggler policy cancels runs that blow past the batch median, and
//! every completed evaluation is checkpointed so an interrupted campaign
//! resumes without repeating work.

use std::sync::Arc;

use ytopt::apps::AppKind;
use ytopt::coordinator::{autotune_with_scorer, TuneSetup};
use ytopt::ensemble::LiarStrategy;
use ytopt::metrics::Metric;
use ytopt::platform::PlatformKind;
use ytopt::runtime::Scorer;

fn main() -> anyhow::Result<()> {
    let scorer = Arc::new(Scorer::auto(&ytopt::runtime::default_artifacts_dir()));

    for metric in [Metric::Energy, Metric::Edp] {
        let ckpt =
            std::env::temp_dir().join(format!("ytopt-ensemble-example.{}.json", metric.name()));
        let _ = std::fs::remove_file(&ckpt); // fresh campaign each invocation
        let mut setup = TuneSetup::new(AppKind::XSBenchHistory, PlatformKind::Theta, 1, metric);
        setup.max_evals = 32;
        setup.wallclock_budget_s = 1800.0; // the paper's half-hour budget
        setup.seed = 2023;
        setup.ensemble_workers = 8;
        setup.liar = LiarStrategy::ConstantMin;
        setup.straggler_factor = Some(3.0);
        setup.checkpoint_path = Some(ckpt.clone());

        let result = autotune_with_scorer(&setup, scorer.clone())?;
        println!("{}", result.summary());
        if let Some(best) = result.db.best() {
            println!("best launch command:\n  {}\n", best.command);
        }
    }
    println!(
        "note: with the same budget the serial loop would have taken the\n\
         'serial-equivalent' wall-clock printed above — the worker pool is\n\
         what fits a 32-evaluation energy campaign into the 1800 s budget."
    );
    Ok(())
}
