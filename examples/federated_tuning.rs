//! Federated autotuning of XSBench on (simulated) Theta: four manager
//! shards, each owning a deterministic hash partition of the candidate
//! space with its own four-worker pool, exchanging their best
//! configurations every few completions.
//!
//! ```bash
//! cargo run --release --example federated_tuning
//! ```
//!
//! This is the multi-node scaling direction of the paper (spaces of up
//! to 6 million configurations on up to 4,096 nodes): past a point one
//! manager process is the bottleneck, so the candidate space is sharded
//! across managers and their histories merge under global eval ids. The
//! same budget is run through the single continuous manager first, so
//! the printout shows what the federation buys.

use std::sync::Arc;

use ytopt::apps::AppKind;
use ytopt::coordinator::{autotune_with_scorer, TuneSetup};
use ytopt::ensemble::Federation;
use ytopt::metrics::Metric;
use ytopt::platform::PlatformKind;
use ytopt::runtime::Scorer;

fn main() -> anyhow::Result<()> {
    let scorer = Arc::new(Scorer::auto(&ytopt::runtime::default_artifacts_dir()));

    let mut setup = TuneSetup::new(AppKind::XSBenchHistory, PlatformKind::Theta, 1, Metric::Runtime);
    setup.max_evals = 48;
    setup.wallclock_budget_s = 1e9;
    setup.seed = 2024;
    setup.ensemble_workers = 4;

    // reference: one continuous manager, one four-worker pool
    let single = autotune_with_scorer(&setup, scorer.clone())?;
    println!("{}", single.summary());

    // federated: four shards x four workers, elites exchanged every
    // four completions per shard
    let mut fed_setup = setup.clone();
    fed_setup.federation_shards = 4;
    fed_setup.elite_exchange_every = 4;
    fed_setup.federation_elites = 3;
    let fed = Federation::new(fed_setup)?.run(scorer)?;
    println!("{}", fed.summary());

    println!(
        "federation wall-clock: {:.0} s vs {:.0} s single-manager ({:.2}x) at the same \
         {}-evaluation budget",
        fed.wallclock_s,
        single.wallclock_s,
        single.wallclock_s / fed.wallclock_s.max(1e-9),
        fed.evaluations,
    );
    Ok(())
}
