//! END-TO-END DRIVER: reproduce every autotuning experiment in the paper
//! (Figs 5-16, Tables IV/V) through the full three-layer stack — AOT
//! JAX/Pallas artifacts loaded by the Rust PJRT runtime, driving the
//! Bayesian-optimization coordinator over the simulated Theta/Summit
//! substrate.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example full_reproduction            # full run
//! cargo run --release --example full_reproduction -- --evals 12   # quicker
//! ```
//!
//! Writes `reproduction_results.json` next to the repo root; the numbers
//! recorded in EXPERIMENTS.md come from this driver.

use std::sync::Arc;

use ytopt::apps::AppKind;
use ytopt::cliargs::CliSpec;
use ytopt::coordinator::{autotune_with_scorer, TuneSetup};
use ytopt::metrics::Metric;
use ytopt::platform::PlatformKind;
use ytopt::runtime::Scorer;
use ytopt::util::{Json, Table};

struct Case {
    label: &'static str,
    app: AppKind,
    platform: PlatformKind,
    nodes: u64,
    metric: Metric,
    event_transport: bool,
    /// Paper-reported (baseline, best) when stated; None when the figure
    /// gives no absolute numbers.
    paper: Option<(f64, f64)>,
}

const fn case(
    label: &'static str,
    app: AppKind,
    platform: PlatformKind,
    nodes: u64,
    metric: Metric,
    paper: Option<(f64, f64)>,
) -> Case {
    Case { label, app, platform, nodes, metric, event_transport: false, paper }
}

fn main() -> anyhow::Result<()> {
    let spec = CliSpec::new("full_reproduction", "end-to-end reproduction of Figs 5-16")
        .opt("evals", Some("30"), "max evaluations per experiment")
        .opt("seed", Some("2023"), "RNG seed");
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match spec.parse(&argv) {
        Ok(a) => a,
        Err(ytopt::cliargs::CliError::HelpRequested) => {
            println!("{}", spec.usage());
            return Ok(());
        }
        Err(e) => anyhow::bail!("{e}"),
    };
    let evals = args.int("evals").unwrap_or(30) as usize;
    let seed = args.int("seed").unwrap_or(2023) as u64;

    let t_start = std::time::Instant::now();
    let scorer = Arc::new(Scorer::auto(&ytopt::runtime::default_artifacts_dir()));
    anyhow::ensure!(
        scorer.is_accelerated(),
        "full_reproduction requires the AOT artifacts: run `make artifacts` first"
    );
    println!("scorer backend: AOT/XLA artifacts (forest_scorer + energy_reduce)\n");

    use AppKind::*;
    use Metric::*;
    use PlatformKind::*;
    let mut cases = vec![
        Case { event_transport: false, ..case("Fig 5a  XSBench-mixed (history), Theta node", XSBenchMixed, Theta, 1, Runtime, Some((3.31, 3.262))) },
        Case { event_transport: true, ..case("Fig 5b  XSBench-mixed (event), Theta node", XSBenchMixed, Theta, 1, Runtime, Some((3.395, 3.339))) },
        case("Fig 6   XSBench-offload, Summit node (6 GPUs)", XSBenchOffload, Summit, 1, Runtime, Some((2.20, 2.138))),
        case("Fig 7a  XSBench, Theta 1,024", XSBenchEvent, Theta, 1024, Runtime, None),
        case("Fig 7b  XSBench, Theta 4,096", XSBenchEvent, Theta, 4096, Runtime, None),
        case("Fig 8   XSBench-offload, Summit 4,096", XSBenchOffload, Summit, 4096, Runtime, None),
        case("Fig 9   SWFFT, Summit 4,096", Swfft, Summit, 4096, Runtime, Some((8.93, 7.797))),
        case("Fig 10  SWFFT, Theta 4,096", Swfft, Theta, 4096, Runtime, None),
        case("Fig 11  AMG, Summit 4,096", Amg, Summit, 4096, Runtime, Some((8.694, 6.734))),
        case("Fig 12  AMG, Theta 4,096", Amg, Theta, 4096, Runtime, None),
        case("Fig 13  SW4lite, Summit 1,024", Sw4lite, Summit, 1024, Runtime, Some((11.067, 7.661))),
        case("Fig 14  SW4lite, Theta 1,024", Sw4lite, Theta, 1024, Runtime, Some((171.595, 14.427))),
        case("Fig 15a XSBench energy, Theta 4,096", XSBenchEvent, Theta, 4096, Energy, Some((2494.905, 2280.806))),
        case("Fig 15b SWFFT energy, Theta 4,096", Swfft, Theta, 4096, Energy, Some((3185.027, 3118.604))),
        case("Fig 15c AMG energy, Theta 4,096", Amg, Theta, 4096, Energy, Some((5642.568, 4566.747))),
        case("Fig 15d SW4lite energy, Theta 1,024", Sw4lite, Theta, 1024, Energy, Some((8384.034, 6606.233))),
        case("Fig 16a XSBench EDP, Theta 4,096", XSBenchEvent, Theta, 4096, Edp, None),
        case("Fig 16b SWFFT EDP, Theta 4,096", Swfft, Theta, 4096, Edp, None),
        case("Fig 16c AMG EDP, Theta 4,096", Amg, Theta, 4096, Edp, None),
        case("Fig 16d SW4lite EDP, Theta 1,024", Sw4lite, Theta, 1024, Edp, None),
    ];
    // Fig 8 used only ~20 evaluations in the paper's half-hour budget
    for c in &mut cases {
        if c.label.starts_with("Fig 8") {
            // handled below via budget; no per-case field needed
        }
    }

    let mut table = Table::new(
        "Paper vs. reproduction (baselines / best / improvement)",
        &["experiment", "paper base", "ours base", "paper best", "ours best", "paper %", "ours %", "max ovh s"],
    );
    let mut json_records: Vec<Json> = Vec::new();

    for c in &cases {
        let mut setup = TuneSetup::new(c.app, c.platform, c.nodes, c.metric);
        setup.max_evals = evals;
        setup.seed = seed;
        setup.event_transport = c.event_transport;
        setup.wallclock_budget_s = 1800.0;
        let r = autotune_with_scorer(&setup, scorer.clone())?;

        let (pb, pbest, ppct) = match c.paper {
            Some((b, best)) => {
                (format!("{b:.3}"), format!("{best:.3}"), format!("{:.2}", 100.0 * (b - best) / b))
            }
            None => ("-".into(), "-".into(), "-".into()),
        };
        table.row(&[
            c.label.to_string(),
            pb,
            format!("{:.3}", r.baseline_objective),
            pbest,
            format!("{:.3}", r.best_objective),
            ppct,
            format!("{:.2}", r.improvement_pct),
            format!("{:.0}", r.db.max_overhead_s()),
        ]);
        json_records.push(Json::obj(vec![
            ("label", c.label.into()),
            ("app", c.app.name().into()),
            ("platform", c.platform.name().into()),
            ("nodes", (c.nodes as u64).into()),
            ("metric", c.metric.name().into()),
            ("baseline", r.baseline_objective.into()),
            ("best", r.best_objective.into()),
            ("improvement_pct", r.improvement_pct.into()),
            ("evaluations", r.evaluations.into()),
            ("max_overhead_s", r.db.max_overhead_s().into()),
            ("wallclock_s", r.wallclock_s.into()),
            (
                "paper_baseline",
                c.paper.map(|(b, _)| Json::from(b)).unwrap_or(Json::Null),
            ),
            ("paper_best", c.paper.map(|(_, b)| Json::from(b)).unwrap_or(Json::Null)),
            ("best_config", r.best_config_desc.as_str().into()),
        ]));
        println!("done: {} ({} evals, {:.0} s simulated)", c.label, r.evaluations, r.wallclock_s);
    }

    println!("\n{}", table.render());

    let out = Json::obj(vec![
        ("seed", seed.into()),
        ("evals_per_experiment", evals.into()),
        ("experiments", Json::Arr(json_records)),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("reproduction_results.json");
    std::fs::write(&path, out.to_string())?;
    println!("wrote {path:?}");
    println!("total driver wall time: {:.1} s (real)", t_start.elapsed().as_secs_f64());
    Ok(())
}
