//! Quickstart: autotune XSBench on a single (simulated) Theta node.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! This is the paper's Fig. 5 setting in miniature: the Bayesian-
//! optimization loop proposes configurations from the 51,840-point
//! XSBench space, each evaluation walks the five-step pipeline
//! (select -> codegen -> aprun line -> compile -> run), and the best
//! runtime is reported against the 3.31 s baseline.

use ytopt::apps::AppKind;
use ytopt::coordinator::{autotune, TuneSetup};
use ytopt::metrics::Metric;
use ytopt::platform::PlatformKind;

fn main() -> anyhow::Result<()> {
    let mut setup = TuneSetup::new(AppKind::XSBenchHistory, PlatformKind::Theta, 1, Metric::Runtime);
    setup.max_evals = 24;
    setup.wallclock_budget_s = 1800.0; // the paper's half-hour budget
    setup.seed = 2023;

    let result = autotune(&setup)?;
    println!("{}", result.summary());
    println!("--- evaluation trace (Fig. 5a style) ---");
    println!("{}", result.trace());

    // the five-step pipeline artifacts for the best evaluation
    if let Some(best) = result.db.best() {
        println!("launch command of the best configuration:\n  {}", best.command);
    }
    Ok(())
}
