//! Large-scale performance autotuning (paper §VI, Figs 7-14).
//!
//! ```bash
//! cargo run --release --example large_scale_performance -- \
//!     --app sw4lite --platform theta --nodes 1024 --evals 30
//! ```
//!
//! Reproduces any of the at-scale experiments: SW4lite on 1,024 Theta
//! nodes (the 91.59% headline), AMG/SWFFT/XSBench on 4,096 nodes on
//! either system, etc.

use ytopt::apps::AppKind;
use ytopt::cliargs::{Args, CliSpec};
use ytopt::coordinator::{autotune, TuneSetup};
use ytopt::metrics::Metric;
use ytopt::platform::PlatformKind;

fn parse_platform(s: &str) -> Option<PlatformKind> {
    match s.to_ascii_lowercase().as_str() {
        "theta" => Some(PlatformKind::Theta),
        "summit" => Some(PlatformKind::Summit),
        _ => None,
    }
}

fn run(args: &Args) -> anyhow::Result<()> {
    let app = AppKind::parse(args.get_or("app", "sw4lite"))
        .ok_or_else(|| anyhow::anyhow!("unknown app"))?;
    let platform = parse_platform(args.get_or("platform", "theta"))
        .ok_or_else(|| anyhow::anyhow!("unknown platform"))?;
    let nodes = args.int("nodes").unwrap_or(1024) as u64;
    let metric = Metric::parse(args.get_or("metric", "runtime"))
        .ok_or_else(|| anyhow::anyhow!("unknown metric"))?;

    let mut setup = TuneSetup::new(app, platform, nodes, metric);
    setup.max_evals = args.int("evals").unwrap_or(30) as usize;
    setup.wallclock_budget_s = args.float("budget").unwrap_or(1800.0);
    setup.seed = args.int("seed").unwrap_or(2023) as u64;
    if let Some(t) = args.float("timeout") {
        setup.eval_timeout_s = Some(t);
    }
    setup.parallel_evals = args.int("parallel").unwrap_or(1) as usize;

    let result = autotune(&setup)?;
    println!("{}", result.summary());
    println!("{}", result.trace());
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let spec = CliSpec::new("large_scale_performance", "paper §VI at-scale autotuning")
        .opt("app", Some("sw4lite"), "xsbench|xsbench-event|xsbench-mixed|xsbench-offload|swfft|amg|sw4lite")
        .opt("platform", Some("theta"), "theta|summit")
        .opt("nodes", Some("1024"), "node count (paper: 1024/4096)")
        .opt("metric", Some("runtime"), "runtime|energy|edp")
        .opt("evals", Some("30"), "max evaluations")
        .opt("budget", Some("1800"), "wall-clock budget (s)")
        .opt("seed", Some("2023"), "RNG seed")
        .opt("timeout", None, "evaluation timeout (s, §VIII extension)")
        .opt("parallel", Some("1"), "concurrent evaluations (§VIII extension)");
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match spec.parse(&argv) {
        Ok(args) => run(&args),
        Err(ytopt::cliargs::CliError::HelpRequested) => {
            println!("{}", spec.usage());
            Ok(())
        }
        Err(e) => {
            eprintln!("error: {e}\n\n{}", spec.usage());
            std::process::exit(2);
        }
    }
}
