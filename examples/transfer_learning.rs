//! Transfer learning across scales (paper §VIII future work): tune at a
//! small node count, then warm-start the large-scale search with the
//! small-scale observations rescaled by the baseline ratio.
//!
//! ```bash
//! cargo run --release --example transfer_learning
//! ```
//!
//! Prints cold-start vs warm-start convergence on AMG@Summit
//! (64 -> 4,096 nodes): the warm-started run skips most of its random
//! initialization because the surrogate already knows the landscape's
//! ordering structure.

use std::sync::Arc;

use ytopt::apps::AppKind;
use ytopt::coordinator::{autotune_with_scorer, TuneSetup};
use ytopt::metrics::Metric;
use ytopt::platform::PlatformKind;
use ytopt::runtime::Scorer;
use ytopt::history::rescale;
use ytopt::space::Configuration;

fn main() -> anyhow::Result<()> {
    let scorer = Arc::new(Scorer::auto(&ytopt::runtime::default_artifacts_dir()));
    let evals = 20usize;

    // 1) small-scale run (cheap: 64 nodes)
    let mut small = TuneSetup::new(AppKind::Amg, PlatformKind::Summit, 64, Metric::Runtime);
    small.max_evals = evals;
    small.wallclock_budget_s = 1e9;
    small.seed = 11;
    let r_small = autotune_with_scorer(&small, scorer.clone())?;
    println!("--- small scale (64 nodes) ---\n{}", r_small.summary());

    // 2) lift its observations to the large scale
    let prior: Vec<(Configuration, f64)> = r_small
        .db
        .records
        .iter()
        .filter(|r| !r.timed_out)
        .map(|r| {
            let idx: Vec<u32> = r.config_key.split(',').filter_map(|s| s.parse().ok()).collect();
            (Configuration::from_indices(idx), r.objective)
        })
        .collect();

    let run_large = |warm: bool| -> anyhow::Result<_> {
        let mut large = TuneSetup::new(AppKind::Amg, PlatformKind::Summit, 4096, Metric::Runtime);
        large.max_evals = evals;
        large.wallclock_budget_s = 1e9;
        large.seed = 12;
        if warm {
            // estimate the target baseline from one probe run
            let (_, target_baseline) =
                ytopt::coordinator::measure_baseline(&large, &scorer)?;
            large.warm_start =
                Some(rescale(&prior, r_small.baseline_objective, target_baseline));
            large.n_init = 2; // the prior replaces most of the random init
        }
        autotune_with_scorer(&large, scorer.clone())
    };

    let cold = run_large(false)?;
    let warm = run_large(true)?;
    println!("--- large scale (4,096 nodes), cold start ---\n{}", cold.summary());
    println!("--- large scale (4,096 nodes), warm start ---\n{}", warm.summary());

    // convergence comparison: best-so-far after k evaluations
    println!("best-so-far by evaluation (cold vs warm):");
    for k in [4usize, 8, 12, 16, evals] {
        let at = |r: &ytopt::coordinator::TuneResult| {
            r.db.records
                .iter()
                .take(k)
                .filter(|x| !x.timed_out)
                .map(|x| x.objective)
                .fold(f64::INFINITY, f64::min)
        };
        println!("  after {k:2} evals: cold {:.3} s | warm {:.3} s", at(&cold), at(&warm));
    }
    Ok(())
}
