//! Energy / EDP autotuning on Theta through the GEOPM pipeline
//! (paper §VII, Figs 15-16, Table V).
//!
//! ```bash
//! cargo run --release --example energy_edp -- --evals 25
//! ```
//!
//! For each ECP proxy app, runs the Fig.-4 energy framework: geopmlaunch
//! wraps the aprun line, 2 Hz package+DRAM power samples flow through the
//! AOT `energy_reduce` artifact into the gm.report, and the average node
//! energy (or EDP) drives the search.

use ytopt::apps::AppKind;
use ytopt::cliargs::CliSpec;
use ytopt::coordinator::{autotune_with_scorer, TuneSetup};
use ytopt::metrics::Metric;
use ytopt::platform::PlatformKind;
use ytopt::runtime::Scorer;
use ytopt::util::Table;

fn main() -> anyhow::Result<()> {
    let spec = CliSpec::new("energy_edp", "paper §VII energy/EDP autotuning on Theta")
        .opt("evals", Some("25"), "max evaluations per run")
        .opt("seed", Some("2023"), "RNG seed");
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match spec.parse(&argv) {
        Ok(a) => a,
        Err(ytopt::cliargs::CliError::HelpRequested) => {
            println!("{}", spec.usage());
            return Ok(());
        }
        Err(e) => anyhow::bail!("{e}"),
    };
    let evals = args.int("evals").unwrap_or(25) as usize;
    let seed = args.int("seed").unwrap_or(2023) as u64;

    let scorer = std::sync::Arc::new(Scorer::auto(&ytopt::runtime::default_artifacts_dir()));
    println!(
        "energy_reduce backend: {}\n",
        if scorer.is_accelerated() { "AOT/XLA artifact" } else { "pure-Rust fallback" }
    );

    // (app, nodes) as in Figs 15/16: 4,096 nodes; SW4lite at 1,024
    let cases = [
        (AppKind::XSBenchEvent, 4096u64),
        (AppKind::Swfft, 4096),
        (AppKind::Amg, 4096),
        (AppKind::Sw4lite, 1024),
    ];

    let mut table = Table::new(
        "Table V (reproduced): improvement percentage (%) on Theta",
        &["Theta", "XSBench", "SWFFT", "AMG", "SW4lite"],
    );
    for metric in [Metric::Energy, Metric::Edp] {
        let mut row = vec![metric.name().to_string()];
        for (app, nodes) in cases {
            let mut setup = TuneSetup::new(app, PlatformKind::Theta, nodes, metric);
            setup.max_evals = evals;
            setup.seed = seed;
            let r = autotune_with_scorer(&setup, scorer.clone())?;
            println!("{}", r.summary());
            row.push(format!("{:.2}", r.improvement_pct));
        }
        table.row(&row);
    }
    println!("{}", table.render());
    println!("(paper values — Energy: 8.58 / 2.09 / 20.88 / 21.20; EDP: 37.84 / 5.24 / 24.13 / 23.70)");
    Ok(())
}
