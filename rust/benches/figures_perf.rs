//! Regenerates the PERFORMANCE figures of the paper (Figs 5-14): the
//! runtime-vs-wallclock autotuning traces and the per-evaluation ytopt
//! overhead series, for each application/platform/scale.
//!
//! `cargo bench --bench figures_perf`
//! Also dumps the series as JSON to `bench_results/figures_perf.json` so
//! plots can be regenerated offline.

use std::sync::Arc;

use ytopt::apps::AppKind;
use ytopt::bench_support::section;
use ytopt::coordinator::{autotune_with_scorer, TuneResult, TuneSetup};
use ytopt::metrics::Metric;
use ytopt::platform::PlatformKind;
use ytopt::runtime::Scorer;
use ytopt::util::Json;

struct Fig {
    id: &'static str,
    title: &'static str,
    app: AppKind,
    platform: PlatformKind,
    nodes: u64,
    event_transport: bool,
    max_evals: usize,
    /// Paper-reported (baseline, best) runtimes when stated.
    paper: Option<(f64, f64)>,
}

fn run_fig(fig: &Fig, scorer: Arc<Scorer>, seed: u64) -> TuneResult {
    let mut setup = TuneSetup::new(fig.app, fig.platform, fig.nodes, Metric::Runtime);
    setup.max_evals = fig.max_evals;
    setup.seed = seed;
    setup.event_transport = fig.event_transport;
    setup.wallclock_budget_s = 1800.0; // the paper's half-hour budget
    autotune_with_scorer(&setup, scorer).expect("autotune failed")
}

fn print_fig(fig: &Fig, r: &TuneResult) {
    section(&format!("{}: {}", fig.id, fig.title));
    println!(
        "baseline {:.3} s | best {:.3} s | improvement {:.2}% | evals {} | max overhead {:.0} s",
        r.baseline_objective,
        r.best_objective,
        r.improvement_pct,
        r.evaluations,
        r.db.max_overhead_s()
    );
    if let Some((pb, pbest)) = fig.paper {
        println!(
            "paper:    {pb:.3} s -> {pbest:.3} s ({:.2}%)",
            100.0 * (pb - pbest) / pb
        );
    }
    println!("{}", r.trace());
}

fn to_json(fig: &Fig, r: &TuneResult) -> Json {
    Json::obj(vec![
        ("id", fig.id.into()),
        ("title", fig.title.into()),
        ("baseline", r.baseline_objective.into()),
        ("best", r.best_objective.into()),
        ("improvement_pct", r.improvement_pct.into()),
        (
            "wallclock_s",
            Json::Arr(r.db.records.iter().map(|x| Json::from(x.wallclock_s)).collect()),
        ),
        (
            "objective",
            Json::Arr(r.db.records.iter().map(|x| Json::from(x.objective)).collect()),
        ),
        (
            "overhead_s",
            Json::Arr(r.db.records.iter().map(|x| Json::from(x.overhead_s)).collect()),
        ),
    ])
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let evals = |n: usize| if quick { n.min(10) } else { n };
    use AppKind::*;
    use PlatformKind::*;
    let figs = [
        Fig { id: "Fig 5a/5c", title: "XSBench-mixed (history) on a Theta node", app: XSBenchMixed, platform: Theta, nodes: 1, event_transport: false, max_evals: evals(26), paper: Some((3.31, 3.262)) },
        Fig { id: "Fig 5b/5d", title: "XSBench-mixed (event) on a Theta node", app: XSBenchMixed, platform: Theta, nodes: 1, event_transport: true, max_evals: evals(26), paper: Some((3.395, 3.339)) },
        Fig { id: "Fig 6", title: "XSBench-offload (event) on a Summit node", app: XSBenchOffload, platform: Summit, nodes: 1, event_transport: false, max_evals: evals(26), paper: Some((2.20, 2.138)) },
        Fig { id: "Fig 7a", title: "XSBench at 1,024 nodes on Theta", app: XSBenchEvent, platform: Theta, nodes: 1024, event_transport: false, max_evals: evals(24), paper: None },
        Fig { id: "Fig 7b", title: "XSBench at 4,096 nodes on Theta", app: XSBenchEvent, platform: Theta, nodes: 4096, event_transport: false, max_evals: evals(24), paper: None },
        Fig { id: "Fig 8", title: "XSBench-offload at 4,096 nodes on Summit", app: XSBenchOffload, platform: Summit, nodes: 4096, event_transport: false, max_evals: evals(20), paper: None },
        Fig { id: "Fig 9", title: "SWFFT at 4,096 nodes on Summit", app: Swfft, platform: Summit, nodes: 4096, event_transport: false, max_evals: evals(26), paper: Some((8.93, 7.797)) },
        Fig { id: "Fig 10", title: "SWFFT at 4,096 nodes on Theta", app: Swfft, platform: Theta, nodes: 4096, event_transport: false, max_evals: evals(26), paper: None },
        Fig { id: "Fig 11", title: "AMG at 4,096 nodes on Summit", app: Amg, platform: Summit, nodes: 4096, event_transport: false, max_evals: evals(26), paper: Some((8.694, 6.734)) },
        Fig { id: "Fig 12", title: "AMG at 4,096 nodes on Theta", app: Amg, platform: Theta, nodes: 4096, event_transport: false, max_evals: evals(26), paper: None },
        Fig { id: "Fig 13", title: "SW4lite at 1,024 nodes on Summit", app: Sw4lite, platform: Summit, nodes: 1024, event_transport: false, max_evals: evals(26), paper: Some((11.067, 7.661)) },
        Fig { id: "Fig 14", title: "SW4lite at 1,024 nodes on Theta", app: Sw4lite, platform: Theta, nodes: 1024, event_transport: false, max_evals: evals(26), paper: Some((171.595, 14.427)) },
    ];

    let scorer = Arc::new(Scorer::auto(&ytopt::runtime::default_artifacts_dir()));
    println!(
        "scorer backend: {}",
        if scorer.is_accelerated() { "AOT/XLA" } else { "pure-Rust fallback" }
    );

    let mut dumps = Vec::new();
    for fig in &figs {
        let r = run_fig(fig, scorer.clone(), 2023);
        print_fig(fig, &r);
        dumps.push(to_json(fig, &r));
    }

    std::fs::create_dir_all("bench_results").ok();
    let path = "bench_results/figures_perf.json";
    std::fs::write(path, Json::Arr(dumps).to_string()).expect("write json");
    println!("\nseries dumped to {path}");
}
