//! Serial vs. ensemble autotuning: wall-clock and tuning-quality parity.
//!
//! `cargo bench --bench ensemble`
//!
//! For XSBench and AMG, runs the same evaluation budget through the
//! serial coordinator loop and through the ensemble engine at several
//! worker counts, reporting the *simulated* campaign wall-clock (what an
//! operator would wait on the real machine), the best objective found,
//! and the real host-side time the harness itself took. A second
//! section duels the two manager cycles at equal budgets: continuous
//! must never lose wall-clock to generational, must report strictly
//! less barrier idle, and must produce an identical result history
//! across two same-seed runs. A third section duels the K=4 federation
//! against the single continuous manager at the same budget: the
//! sharded campaign must never lose simulated wall-clock (its exchange
//! overhead has to stay cheaper than what sharding saves) and must be
//! deterministic across same-seed runs.

use std::sync::Arc;
use std::time::Instant;

use ytopt::apps::AppKind;
use ytopt::bench_support::section;
use ytopt::coordinator::{autotune_with_scorer, TuneResult, TuneSetup};
use ytopt::ensemble::ManagerCycle;
use ytopt::metrics::Metric;
use ytopt::platform::PlatformKind;
use ytopt::runtime::Scorer;
use ytopt::util::Table;

const EVALS: usize = 32;

fn base(app: AppKind, nodes: u64, metric: Metric) -> TuneSetup {
    let mut s = TuneSetup::new(app, PlatformKind::Theta, nodes, metric);
    s.max_evals = EVALS;
    s.wallclock_budget_s = 1e9;
    s.seed = 13;
    s
}

fn run(setup: &TuneSetup, scorer: &Arc<Scorer>) -> (TuneResult, f64) {
    let t = Instant::now();
    let r = autotune_with_scorer(setup, scorer.clone()).expect("tuning run failed");
    (r, t.elapsed().as_secs_f64())
}

fn campaign(app: AppKind, nodes: u64, metric: Metric, scorer: &Arc<Scorer>) {
    section(&format!(
        "{} on Theta x{nodes} | metric {} | budget {EVALS} evaluations",
        app.name(),
        metric.name()
    ));
    let mut t = Table::new(
        "serial loop vs ensemble engine",
        &["mode", "sim. wallclock (s)", "speedup", "best objective", "vs serial", "host (s)"],
    );
    let (serial, host_s) = run(&base(app, nodes, metric), scorer);
    t.row(&[
        "serial".into(),
        format!("{:.0}", serial.wallclock_s),
        "1.00x".into(),
        format!("{:.3}", serial.best_objective),
        "—".into(),
        format!("{host_s:.2}"),
    ]);
    for workers in [2usize, 4, 8] {
        let mut s = base(app, nodes, metric);
        s.ensemble_workers = workers;
        let (r, host_s) = run(&s, scorer);
        assert_eq!(r.evaluations, serial.evaluations, "budgets must match");
        let gap_pct = 100.0 * (r.best_objective - serial.best_objective) / serial.best_objective;
        t.row(&[
            format!("ensemble x{workers}"),
            format!("{:.0}", r.wallclock_s),
            format!("{:.2}x", serial.wallclock_s / r.wallclock_s),
            format!("{:.3}", r.best_objective),
            format!("{gap_pct:+.1}%"),
            format!("{host_s:.2}"),
        ]);
        if workers == 8 {
            assert!(
                r.wallclock_s < serial.wallclock_s,
                "8-worker ensemble must beat the serial wall-clock"
            );
            assert!(
                r.best_objective <= serial.best_objective * 1.05,
                "8-worker quality {} strayed beyond 5% of serial {}",
                r.best_objective,
                serial.best_objective
            );
        }
    }
    println!("{}", t.render());
}

/// Continuous vs. generational at equal budgets: the acceptance gate
/// for the event-driven manager.
fn cycle_duel(app: AppKind, nodes: u64, metric: Metric, scorer: &Arc<Scorer>) {
    section(&format!(
        "{} on Theta x{nodes} | metric {} | manager-cycle duel at {EVALS} evaluations",
        app.name(),
        metric.name()
    ));
    let mut t = Table::new(
        "generational barrier vs continuous event loop",
        &["cycle x workers", "sim. wallclock (s)", "barrier idle (s)", "best objective", "host (s)"],
    );
    for workers in [4usize, 8] {
        let mut gen_s = base(app, nodes, metric);
        gen_s.ensemble_workers = workers;
        gen_s.manager_cycle = ManagerCycle::Generational;
        let mut cont_s = gen_s.clone();
        cont_s.manager_cycle = ManagerCycle::Continuous;
        let (rg, host_g) = run(&gen_s, scorer);
        let (rc, host_c) = run(&cont_s, scorer);
        // same-seed determinism of the continuous history
        let (rc2, _) = run(&cont_s, scorer);
        let keys = |r: &TuneResult| {
            r.db.records.iter().map(|x| x.config_key.clone()).collect::<Vec<_>>()
        };
        assert_eq!(
            keys(&rc),
            keys(&rc2),
            "continuous result history must be deterministic across same-seed runs"
        );
        assert_eq!(rc.best_objective, rc2.best_objective);

        assert_eq!(rg.evaluations, rc.evaluations, "budgets must match");
        let ig = rg.ensemble.as_ref().unwrap().worker_idle_s;
        let ic = rc.ensemble.as_ref().unwrap().worker_idle_s;
        assert!(
            rc.wallclock_s <= rg.wallclock_s,
            "continuous wall-clock {} exceeded generational {} at {workers} workers",
            rc.wallclock_s,
            rg.wallclock_s
        );
        assert!(
            ic < ig,
            "continuous barrier idle {ic} not strictly below generational {ig}"
        );
        t.row(&[
            format!("generational x{workers}"),
            format!("{:.0}", rg.wallclock_s),
            format!("{ig:.0}"),
            format!("{:.3}", rg.best_objective),
            format!("{host_g:.2}"),
        ]);
        t.row(&[
            format!("continuous x{workers}"),
            format!("{:.0}", rc.wallclock_s),
            format!("{ic:.0}"),
            format!("{:.3}", rc.best_objective),
            format!("{host_c:.2}"),
        ]);
    }
    println!("{}", t.render());
}

/// Single continuous manager (one 4-worker pool) vs. the K=4 federation
/// (four shards, each with its *own* 4-worker pool) at the same
/// evaluation budget. This is the scale-out claim — adding manager
/// shards adds worker pools — so the federation must never lose
/// wall-clock; the coordination-cost claim is gated separately below
/// (exchange seconds must stay a marginal fraction of the campaign),
/// since with 4x the workers the wall-clock comparison alone would not
/// catch an exchange-cost regression. The merged history must also be
/// deterministic across same-seed runs.
fn federation_duel(app: AppKind, nodes: u64, metric: Metric, scorer: &Arc<Scorer>) {
    section(&format!(
        "{} on Theta x{nodes} | metric {} | single manager vs K=4 federation at {EVALS} evaluations",
        app.name(),
        metric.name()
    ));
    let mut t = Table::new(
        "single continuous manager vs sharded federation",
        &["topology", "sim. wallclock (s)", "speedup", "best objective", "host (s)"],
    );
    let mut single = base(app, nodes, metric);
    single.ensemble_workers = 4;
    let mut fed = single.clone();
    fed.federation_shards = 4;
    fed.elite_exchange_every = 4;
    fed.federation_elites = 3;

    let (rs, host_s) = run(&single, scorer);
    let (rf, host_f) = run(&fed, scorer);
    let (rf2, _) = run(&fed, scorer);

    assert_eq!(rs.evaluations, rf.evaluations, "budgets must match");
    let keys =
        |r: &TuneResult| r.db.records.iter().map(|x| x.config_key.clone()).collect::<Vec<_>>();
    assert_eq!(
        keys(&rf),
        keys(&rf2),
        "federated result history must be deterministic across same-seed runs"
    );
    assert_eq!(rf.best_objective, rf2.best_objective);
    assert!(
        rf.wallclock_s <= rs.wallclock_s,
        "K=4 federation wall-clock {} exceeded the single manager's {}",
        rf.wallclock_s,
        rs.wallclock_s
    );
    let fs = rf.federation.as_ref().expect("federation stats present");
    assert!(
        fs.exchange_s < rf.wallclock_s * 0.05,
        "elite-exchange cost {:.1} s is not marginal against the {:.0} s campaign",
        fs.exchange_s,
        rf.wallclock_s
    );
    t.row(&[
        "single manager x4 workers".into(),
        format!("{:.0}", rs.wallclock_s),
        "1.00x".into(),
        format!("{:.3}", rs.best_objective),
        format!("{host_s:.2}"),
    ]);
    t.row(&[
        format!("federation {}x4 workers", fs.shards),
        format!("{:.0}", rf.wallclock_s),
        format!("{:.2}x", rs.wallclock_s / rf.wallclock_s),
        format!("{:.3}", rf.best_objective),
        format!("{host_f:.2}"),
    ]);
    println!("{}", t.render());
    println!(
        "federation: {} exchanges | {} foreign observations | exchange cost {:.1} s | per-shard evals {:?}\n",
        fs.exchanges, fs.elites_absorbed, fs.exchange_s, fs.per_shard_evals
    );
}

/// Cross-run transfer duel: a cold start vs a history-store warm start
/// at the same budget, gated on evaluations-to-target (the seed run's
/// best objective). The warm side must never need *more* evaluations —
/// if transfer cannot at least match a cold start on the synthetic
/// barrier-cliff landscape, the history store is a net loss.
fn warm_start_duel(scorer: &Arc<Scorer>) {
    section(&format!(
        "{} on Theta x1024 | cold start vs history-store warm start at {EVALS} evaluations",
        AppKind::Sw4lite.name()
    ));
    let store =
        std::env::temp_dir().join(format!("ytopt-bench-store-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&store);

    // seed run: small budget, recorded into the store
    let mut seed_s = TuneSetup::new(AppKind::Sw4lite, PlatformKind::Theta, 1024, Metric::Runtime);
    seed_s.max_evals = 12;
    seed_s.wallclock_budget_s = 1e9;
    seed_s.seed = 77;
    seed_s.history_dir = Some(store.clone());
    let (seed_run, _) = run(&seed_s, scorer);
    let target = seed_run.best_objective;

    let to_target = |r: &TuneResult| -> usize {
        let mut best = f64::INFINITY;
        for (i, rec) in r.db.records.iter().enumerate() {
            if !rec.timed_out && rec.objective.is_finite() {
                best = best.min(rec.objective);
            }
            if best <= target {
                return i + 1;
            }
        }
        EVALS + 1
    };
    let fmt_reach = |e: usize| {
        if e > EVALS { "never".to_string() } else { format!("{e}") }
    };
    let mut t = Table::new(
        "cold start vs history-store warm start (target: seed-run best)",
        &["seed", "cold: evals to target", "warm: evals to target", "cold best", "warm best", "host (s)"],
    );
    // summed over three seeds so one lucky cold draw cannot flip the gate
    let mut sum_cold = 0usize;
    let mut sum_warm = 0usize;
    for seed in [78u64, 79, 80] {
        let mut cold_s =
            TuneSetup::new(AppKind::Sw4lite, PlatformKind::Theta, 1024, Metric::Runtime);
        cold_s.max_evals = EVALS;
        cold_s.wallclock_budget_s = 1e9;
        cold_s.seed = seed;
        let mut warm_s = cold_s.clone();
        warm_s.warm_start_from = Some(store.clone());
        warm_s.warm_start_elites = 32; // the full seed history transfers

        let (cold, host_c) = run(&cold_s, scorer);
        let (warm, host_w) = run(&warm_s, scorer);
        let (ec, ew) = (to_target(&cold), to_target(&warm));
        sum_cold += ec;
        sum_warm += ew;
        t.row(&[
            format!("{seed}"),
            fmt_reach(ec),
            fmt_reach(ew),
            format!("{:.3}", cold.best_objective),
            format!("{:.3}", warm.best_objective),
            format!("{:.2}", host_c + host_w),
        ]);
    }
    assert!(
        sum_warm <= sum_cold,
        "warm start needed {sum_warm} evaluations to reach the seed best vs cold's \
         {sum_cold} (summed over 3 seeds) — the history store must not lose to a cold start"
    );
    println!("{}", t.render());
    println!(
        "transfer target: seed-run best {target:.3} after {} evaluations\n",
        seed_run.evaluations
    );
    let _ = std::fs::remove_dir_all(&store);
}

fn main() {
    let scorer = Arc::new(Scorer::auto(&ytopt::runtime::default_artifacts_dir()));
    println!(
        "scorer backend: {}",
        if scorer.is_accelerated() { "AOT/XLA" } else { "pure-Rust fallback" }
    );
    campaign(AppKind::XSBenchHistory, 1, Metric::Runtime, &scorer);
    campaign(AppKind::Amg, 256, Metric::Energy, &scorer);
    cycle_duel(AppKind::XSBenchHistory, 1, Metric::Runtime, &scorer);
    federation_duel(AppKind::XSBenchHistory, 1, Metric::Runtime, &scorer);
    warm_start_duel(&scorer);
}
