//! Regenerates the ENERGY figures of the paper: Fig 15 (energy
//! autotuning on Theta), Fig 16 (EDP autotuning on Theta) and Table V
//! (improvement percentages), through the full GEOPM pipeline and the
//! AOT `energy_reduce` artifact.
//!
//! `cargo bench --bench figures_energy`

use std::sync::Arc;

use ytopt::apps::AppKind;
use ytopt::bench_support::section;
use ytopt::coordinator::{autotune_with_scorer, TuneResult, TuneSetup};
use ytopt::metrics::Metric;
use ytopt::platform::PlatformKind;
use ytopt::runtime::Scorer;
use ytopt::util::{Json, Table};

const CASES: [(&str, AppKind, u64, f64, f64); 4] = [
    // (figure label, app, nodes, paper baseline J, paper best J)
    ("15a XSBench", AppKind::XSBenchEvent, 4096, 2494.905, 2280.806),
    ("15b SWFFT", AppKind::Swfft, 4096, 3185.027, 3118.604),
    ("15c AMG", AppKind::Amg, 4096, 5642.568, 4566.747),
    ("15d SW4lite", AppKind::Sw4lite, 1024, 8384.034, 6606.233),
];

const PAPER_TABLE5: [(&str, f64, f64); 4] = [
    ("XSBench", 8.58, 37.84),
    ("SWFFT", 2.09, 5.24),
    ("AMG", 20.88, 24.13),
    ("SW4lite", 21.20, 23.70),
];

fn run_case(
    app: AppKind,
    nodes: u64,
    metric: Metric,
    scorer: Arc<Scorer>,
    evals: usize,
) -> TuneResult {
    let mut setup = TuneSetup::new(app, PlatformKind::Theta, nodes, metric);
    setup.max_evals = evals;
    setup.seed = 2023;
    setup.wallclock_budget_s = 1800.0;
    autotune_with_scorer(&setup, scorer).expect("autotune failed")
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let evals = if quick { 10 } else { 26 };
    let scorer = Arc::new(Scorer::auto(&ytopt::runtime::default_artifacts_dir()));
    println!(
        "energy_reduce backend: {}",
        if scorer.is_accelerated() { "AOT/XLA" } else { "pure-Rust fallback" }
    );

    let mut energy_pct = Vec::new();
    let mut edp_pct = Vec::new();
    let mut dumps = Vec::new();

    for (label, app, nodes, paper_base, paper_best) in CASES {
        section(&format!("Fig {label}: autotuning ENERGY at {nodes} nodes on Theta"));
        let r = run_case(app, nodes, Metric::Energy, scorer.clone(), evals);
        println!(
            "baseline {:.1} J | best {:.1} J | saving {:.2}%   (paper: {:.1} -> {:.1} J, {:.2}%)",
            r.baseline_objective,
            r.best_objective,
            r.improvement_pct,
            paper_base,
            paper_best,
            100.0 * (paper_base - paper_best) / paper_base,
        );
        println!("{}", r.trace());
        energy_pct.push(r.improvement_pct);
        dumps.push(Json::obj(vec![
            ("figure", format!("Fig {label} energy").into()),
            ("baseline_j", r.baseline_objective.into()),
            ("best_j", r.best_objective.into()),
            ("improvement_pct", r.improvement_pct.into()),
        ]));
    }

    for (label, app, nodes, _, _) in CASES {
        let label = label.replace("15", "16");
        section(&format!("Fig {label}: autotuning EDP at {nodes} nodes on Theta"));
        let r = run_case(app, nodes, Metric::Edp, scorer.clone(), evals);
        println!(
            "baseline {:.1} J*s | best {:.1} J*s | improvement {:.2}%",
            r.baseline_objective, r.best_objective, r.improvement_pct,
        );
        println!("{}", r.trace());
        edp_pct.push(r.improvement_pct);
        dumps.push(Json::obj(vec![
            ("figure", format!("Fig {label} EDP").into()),
            ("baseline_js", r.baseline_objective.into()),
            ("best_js", r.best_objective.into()),
            ("improvement_pct", r.improvement_pct.into()),
        ]));
    }

    section("Table V: improvement percentage (%) for each application on Theta");
    let mut t = Table::new("", &["Theta", "XSBench", "SWFFT", "AMG", "SW4lite"]);
    t.row(&std::iter::once("Energy".to_string())
        .chain(energy_pct.iter().map(|p| format!("{p:.2}")))
        .collect::<Vec<_>>());
    t.row(&std::iter::once("EDP".to_string())
        .chain(edp_pct.iter().map(|p| format!("{p:.2}")))
        .collect::<Vec<_>>());
    println!("{}", t.render());
    let mut p = Table::new("(paper values)", &["Theta", "XSBench", "SWFFT", "AMG", "SW4lite"]);
    p.row(&std::iter::once("Energy".to_string())
        .chain(PAPER_TABLE5.iter().map(|(_, e, _)| format!("{e:.2}")))
        .collect::<Vec<_>>());
    p.row(&std::iter::once("EDP".to_string())
        .chain(PAPER_TABLE5.iter().map(|(_, _, e)| format!("{e:.2}")))
        .collect::<Vec<_>>());
    println!("{}", p.render());

    std::fs::create_dir_all("bench_results").ok();
    let path = "bench_results/figures_energy.json";
    std::fs::write(path, Json::Arr(dumps).to_string()).expect("write json");
    println!("series dumped to {path}");
}
