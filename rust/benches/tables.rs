//! Regenerates every TABLE of the paper: I (system specs), II (compile
//! times), III (parameter spaces), IV (max ytopt overhead).
//!
//! `cargo bench --bench tables`

use std::sync::Arc;

use ytopt::apps::AppKind;
use ytopt::bench_support::section;
use ytopt::coordinator::{autotune_with_scorer, TuneSetup};
use ytopt::metrics::Metric;
use ytopt::platform::{compile_time, PlatformKind};
use ytopt::runtime::Scorer;
use ytopt::space::paper;
use ytopt::util::{Pcg32, Table};

fn table1() {
    section("Table I: System Platform Specifications and Tools");
    let a = PlatformKind::Theta.spec();
    let b = PlatformKind::Summit.spec();
    let mut t = Table::new("", &["field", a.name, b.name]);
    let rows: Vec<(&str, String, String)> = vec![
        ("Location", a.location.into(), b.location.into()),
        ("Architecture", a.architecture.into(), b.architecture.into()),
        ("Number of nodes", a.nodes.to_string(), b.nodes.to_string()),
        ("CPU cores per node", a.cpu_cores_per_node.to_string(), b.cpu_cores_per_node.to_string()),
        ("Sockets per node", a.sockets_per_node.into(), b.sockets_per_node.into()),
        ("CPU type and speed", a.cpu_type.into(), b.cpu_type.into()),
        ("GPUs per node", a.gpus_per_node.to_string(), b.gpus_per_node.to_string()),
        ("L1 cache per core", a.l1_cache.into(), b.l1_cache.into()),
        ("L2 cache per socket", a.l2_cache.into(), b.l2_cache.into()),
        ("L3 cache per socket", a.l3_cache.into(), b.l3_cache.into()),
        ("Threads per core", a.threads_per_core.to_string(), b.threads_per_core.to_string()),
        ("Memory per node", a.memory_per_node.into(), b.memory_per_node.into()),
        ("Network", a.network.into(), b.network.into()),
        ("Power tools", a.power_tools.into(), b.power_tools.into()),
        (
            "TDP per socket",
            format!("{}W", a.tdp_per_socket_w),
            format!("{}W/Power9; {}W/GPU", b.tdp_per_socket_w, b.gpu_tdp_w),
        ),
        ("File system", a.file_system.into(), b.file_system.into()),
    ];
    for (f, x, y) in rows {
        t.row(&[f.to_string(), x, y]);
    }
    println!("{}", t.render());
}

fn table2() {
    section("Table II: Compiling time (s) on Theta and Summit (avg of 5)");
    let mut t = Table::new("", &["System", "XSBench", "SWFFT", "AMG", "SW4lite"]);
    let mut rng = Pcg32::seeded(5);
    for pf in [PlatformKind::Theta, PlatformKind::Summit] {
        let mut row = vec![pf.name().to_string()];
        for app in [AppKind::XSBenchEvent, AppKind::Swfft, AppKind::Amg, AppKind::Sw4lite] {
            // the paper's methodology: compile five times, average
            let avg: f64 = (0..5)
                .map(|_| compile_time::sample_compile_s(app, pf, &mut rng))
                .sum::<f64>()
                / 5.0;
            row.push(format!("{avg:.3}"));
        }
        t.row(&row);
    }
    println!("{}", t.render());
    println!("(paper: Theta 2.021/3.494/2.825/162.066; Summit 4.645/3.781/2.757/58.000)");
}

fn table3() {
    section("Table III: Parameter Space for Each Application");
    let mut t = Table::new(
        "",
        &["ECP Proxy Apps", "System param.", "Application param.", "Space size", "paper size"],
    );
    let cases: [(AppKind, u128); 6] = [
        (AppKind::XSBenchEvent, 51_840),
        (AppKind::XSBenchMixed, 6_272_640),
        (AppKind::XSBenchOffload, 181_440),
        (AppKind::Swfft, 1_080),
        (AppKind::Amg, 552_960),
        (AppKind::Sw4lite, 2_211_840),
    ];
    for (app, paper_size) in cases {
        let platform = if app.uses_gpus() { PlatformKind::Summit } else { PlatformKind::Theta };
        let space = paper::build_space(app, platform);
        let env = space.params().iter().filter(|p| p.name.starts_with("OMP_")).count();
        t.row(&[
            app.name().to_string(),
            format!("{env} env. variables"),
            format!("{}", space.dim() - env),
            space.size().to_string(),
            paper_size.to_string(),
        ]);
        assert_eq!(space.size(), paper_size, "{app:?} space size drifted from Table III");
    }
    println!("{}", t.render());
}

fn table4(scorer: Arc<Scorer>, evals: usize) {
    section("Table IV: maximum ytopt overhead (s) per application and system");
    // run the paper's experiment grid briefly; report observed maxima
    let cases: [(AppKind, PlatformKind, u64); 10] = [
        (AppKind::XSBenchMixed, PlatformKind::Theta, 1),
        (AppKind::XSBenchEvent, PlatformKind::Theta, 4096),
        (AppKind::Swfft, PlatformKind::Theta, 4096),
        (AppKind::Amg, PlatformKind::Theta, 4096),
        (AppKind::Sw4lite, PlatformKind::Theta, 1024),
        (AppKind::XSBenchOffload, PlatformKind::Summit, 1),
        (AppKind::XSBenchOffload, PlatformKind::Summit, 4096),
        (AppKind::Swfft, PlatformKind::Summit, 4096),
        (AppKind::Amg, PlatformKind::Summit, 4096),
        (AppKind::Sw4lite, PlatformKind::Summit, 1024),
    ];
    let mut theta: Vec<String> = vec!["Theta".into()];
    let mut summit: Vec<String> = vec!["Summit".into()];
    for (app, pf, nodes) in cases {
        let mut setup = TuneSetup::new(app, pf, nodes, Metric::Runtime);
        setup.max_evals = evals;
        setup.seed = 7;
        let r = autotune_with_scorer(&setup, scorer.clone()).expect("tune failed");
        let cell = format!("{:.0}", r.db.max_overhead_s());
        if pf == PlatformKind::Theta {
            theta.push(cell);
        } else {
            summit.push(cell);
        }
    }
    let mut t =
        Table::new("", &["System", "XSBench-Mixed", "XSBench", "SWFFT", "AMG", "SW4lite"]);
    t.row(&theta);
    t.row(&summit);
    println!("{}", t.render());
    println!("(paper maxima: Theta 70/69/30/34/46; Summit 24/111/50/45/46)");
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let evals = if quick { 8 } else { 20 };
    table1();
    table2();
    table3();
    let scorer = Arc::new(Scorer::auto(&ytopt::runtime::default_artifacts_dir()));
    println!(
        "\nscorer backend: {}",
        if scorer.is_accelerated() { "AOT/XLA" } else { "pure-Rust fallback" }
    );
    table4(scorer, evals);
}
