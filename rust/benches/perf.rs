//! Performance microbenches + design ablations.
//!
//! `cargo bench --bench perf`
//!
//! Sections:
//!   hot-path   — the per-iteration BO costs: RF fit, tensor export, AOT
//!                scoring vs pure-Rust scoring, energy reduction
//!   substrate  — space sampling/encoding throughput
//!   ablations  — kappa sweep, surrogate family, sequential vs parallel
//!                evaluation, BO vs random vs grid

use std::sync::Arc;

use ytopt::apps::AppKind;
use ytopt::bench_support::{run, section};
use ytopt::coordinator::{autotune_with_scorer, TuneSetup};
use ytopt::metrics::Metric;
use ytopt::platform::PlatformKind;
use ytopt::runtime::Scorer;
use ytopt::search::{StrategyKind, SurrogateKind};
use ytopt::space::paper;
use ytopt::surrogate::{export_forest, ForestConfig, RandomForest};
use ytopt::util::Pcg32;

fn make_training(n: usize, dim: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Pcg32::seeded(seed);
    let mut x = Vec::with_capacity(n * dim);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let row: Vec<f32> = (0..dim).map(|_| rng.f32()).collect();
        y.push(row[0] * 3.0 + (row[1] * 7.0).sin() + 0.2 * row[dim - 1]);
        x.extend(row);
    }
    (x, y)
}

/// L2 profile: XLA cost analysis recorded by aot.py into the manifest.
fn l2_cost_analysis() {
    section("L2: XLA cost analysis of the AOT modules (from manifest.json)");
    let path = ytopt::runtime::default_artifacts_dir().join("manifest.json");
    let Ok(text) = std::fs::read_to_string(&path) else {
        println!("(no artifacts; run `make artifacts`)");
        return;
    };
    let Ok(v) = ytopt::util::Json::parse(&text) else { return };
    for name in ["forest_scorer", "energy_reduce"] {
        if let Some(ca) = v.get(name).and_then(|a| a.get("cost_analysis")) {
            let g = |k: &str| ca.get(k).and_then(|x| x.as_f64()).unwrap_or(0.0);
            println!(
                "{name:<14} flops {:>12.0} | bytes accessed {:>12.0} | arithmetic intensity {:.3} flop/B",
                g("flops"),
                g("bytes_accessed"),
                g("flops") / g("bytes_accessed").max(1.0)
            );
        }
    }
}

fn hot_path(scorer: &Arc<Scorer>, quick: bool) {
    section("hot path: per-BO-iteration costs");
    let m = scorer.manifest().forest.clone();
    let dim = 17; // SW4lite-sized space
    let samples = if quick { 10 } else { 30 };

    for n_obs in [50usize, 200] {
        let (x, y) = make_training(n_obs, dim, 1);
        let mut rng = Pcg32::seeded(2);
        let cfg = ForestConfig { n_trees: m.trees, ..Default::default() };
        run(&format!("RF fit ({n_obs} obs, {} trees)", m.trees), 2, samples, || {
            let f = RandomForest::fit(&x, &y, dim, &cfg, &mut rng);
            std::hint::black_box(&f);
        });
    }

    let (x, y) = make_training(200, dim, 1);
    let mut rng = Pcg32::seeded(3);
    let forest =
        RandomForest::fit(&x, &y, dim, &ForestConfig { n_trees: m.trees, ..Default::default() }, &mut rng);
    run("tensor export (64 trees x 512 nodes)", 2, samples, || {
        let t = export_forest(&forest, m.trees, m.nodes_per_tree, m.features, m.depth).unwrap();
        std::hint::black_box(&t);
    });

    let tensors = export_forest(&forest, m.trees, m.nodes_per_tree, m.features, m.depth).unwrap();
    let mut rows = vec![0.0f32; m.candidates * m.features];
    for v in rows.iter_mut() {
        *v = rng.f32();
    }

    let cpu = Scorer::fallback();
    let rcpu = run(&format!("score {} candidates: pure-Rust", m.candidates), 2, samples, || {
        let o = cpu.score_candidates(&rows, m.candidates, &tensors, 1.96).unwrap();
        std::hint::black_box(&o);
    });
    println!("    -> {:.1}k candidates/s", rcpu.throughput(m.candidates) / 1e3);
    if scorer.is_accelerated() {
        let rxla = run(&format!("score {} candidates: AOT/XLA", m.candidates), 2, samples, || {
            let o = scorer.score_candidates(&rows, m.candidates, &tensors, 1.96).unwrap();
            std::hint::black_box(&o);
        });
        println!(
            "    -> {:.1}k candidates/s ({:.2}x vs pure-Rust)",
            rxla.throughput(m.candidates) / 1e3,
            rcpu.mean_s / rxla.mean_s
        );
    }

    // energy reduction at full Fig-15 shape
    let es = scorer.manifest().energy.clone();
    let nodes = es.max_nodes;
    let s = es.max_samples;
    let mut pkg = vec![0.0f32; nodes * s];
    let mut dram = vec![0.0f32; nodes * s];
    for i in 0..pkg.len() {
        pkg[i] = 100.0 + rng.f32() * 140.0;
        dram[i] = 5.0 + rng.f32() * 25.0;
    }
    let rcpu = run(&format!("energy reduce {nodes}x{s}: pure-Rust"), 1, samples, || {
        let o = cpu.reduce_energy(&pkg, &dram, nodes, s, s as f32, 0.5, 12.0).unwrap();
        std::hint::black_box(&o);
    });
    if scorer.is_accelerated() {
        let rxla = run(&format!("energy reduce {nodes}x{s}: AOT/XLA"), 1, samples, || {
            let o = scorer.reduce_energy(&pkg, &dram, nodes, s, s as f32, 0.5, 12.0).unwrap();
            std::hint::black_box(&o);
        });
        println!("    -> {:.2}x vs pure-Rust", rcpu.mean_s / rxla.mean_s);
    }
}

fn substrate(quick: bool) {
    section("substrate: space sampling / encoding");
    let samples = if quick { 10 } else { 30 };
    let space = paper::build_space(AppKind::Sw4lite, PlatformKind::Theta);
    let mut rng = Pcg32::seeded(4);
    let r = run("sample 1024 valid configs (SW4lite space)", 2, samples, || {
        for _ in 0..1024 {
            std::hint::black_box(space.sample(&mut rng));
        }
    });
    println!("    -> {:.1}k configs/s", r.throughput(1024) / 1e3);
    let cfg = space.sample(&mut rng);
    let mut row = vec![0.0f32; 32];
    let r = run("encode 1024 configs to f32[32]", 2, samples, || {
        for _ in 0..1024 {
            space.encode_into(&cfg, &mut row);
            std::hint::black_box(&row);
        }
    });
    println!("    -> {:.1}k encodes/s", r.throughput(1024) / 1e3);
}

fn ablations(scorer: &Arc<Scorer>, quick: bool) {
    let evals = if quick { 10 } else { 24 };
    let repeats: u64 = if quick { 1 } else { 3 };

    section("ablation: kappa (exploration/exploitation, Eq. 1)");
    for kappa in [0.0, 0.5, 1.96, 4.0] {
        let mut sum = 0.0;
        for seed in 0..repeats {
            let mut s = TuneSetup::new(AppKind::Amg, PlatformKind::Summit, 4096, Metric::Runtime);
            s.max_evals = evals;
            s.kappa = kappa;
            s.seed = 100 + seed;
            sum += autotune_with_scorer(&s, scorer.clone()).unwrap().improvement_pct;
        }
        println!("kappa {kappa:<5} -> mean improvement {:.2}% over {repeats} seeds", sum / repeats as f64);
    }

    section("ablation: surrogate family (paper found RF best)");
    for (name, kind) in [
        ("RandomForest", SurrogateKind::RandomForest),
        ("ExtraTrees", SurrogateKind::ExtraTrees),
        ("GBRT-lite", SurrogateKind::Gbrt),
    ] {
        let mut sum = 0.0;
        for seed in 0..repeats {
            let mut s = TuneSetup::new(AppKind::Amg, PlatformKind::Summit, 4096, Metric::Runtime);
            s.max_evals = evals;
            s.surrogate = kind;
            s.seed = 200 + seed;
            sum += autotune_with_scorer(&s, scorer.clone()).unwrap().improvement_pct;
        }
        println!("{name:<14} -> mean improvement {:.2}%", sum / repeats as f64);
    }

    section("ablation: search strategy");
    for (name, kind) in [
        ("BO (ytopt)", StrategyKind::Bo),
        ("Random", StrategyKind::Random),
        ("Grid", StrategyKind::Grid),
        ("MCTS (mctree)", StrategyKind::Mctree),
    ] {
        let mut sum = 0.0;
        for seed in 0..repeats {
            let mut s = TuneSetup::new(AppKind::Sw4lite, PlatformKind::Summit, 1024, Metric::Runtime);
            s.max_evals = evals;
            s.strategy = kind;
            s.seed = 300 + seed;
            sum += autotune_with_scorer(&s, scorer.clone()).unwrap().improvement_pct;
        }
        println!("{name:<12} -> mean improvement {:.2}%", sum / repeats as f64);
    }

    section("ablation: sequential (Ray-like) vs parallel (libensemble-like)");
    for par in [1usize, 2, 4, 8] {
        let mut s = TuneSetup::new(AppKind::Amg, PlatformKind::Summit, 4096, Metric::Runtime);
        s.max_evals = evals;
        s.parallel_evals = par;
        s.seed = 400;
        s.wallclock_budget_s = 1e9;
        let r = autotune_with_scorer(&s, scorer.clone()).unwrap();
        println!(
            "parallel {par} -> simulated wallclock {:>8.0} s for {} evals, improvement {:.2}%",
            r.wallclock_s, r.evaluations, r.improvement_pct
        );
    }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let scorer = Arc::new(Scorer::auto(&ytopt::runtime::default_artifacts_dir()));
    println!(
        "scorer backend: {}",
        if scorer.is_accelerated() { "AOT/XLA" } else { "pure-Rust fallback" }
    );
    l2_cost_analysis();
    hot_path(&scorer, quick);
    substrate(quick);
    ablations(&scorer, quick);
}
