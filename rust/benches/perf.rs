//! Performance microbenches + design ablations.
//!
//! `cargo bench --bench perf`
//!
//! Sections:
//!   hot-path   — the per-iteration BO costs: RF fit, tensor export, AOT
//!                scoring vs pure-Rust scoring, energy reduction
//!   scorer duel — scalar walker vs blocked lockstep kernel at the
//!                1024x64 artifact shape, plus cold-refit vs
//!                epoch-cached continuous-manager proposal loop; emits
//!                BENCH_scorer.json and (with --gate) enforces the CI
//!                acceptance ratios. `--scorer-only` runs just this.
//!   stats duel — the identical continuous-manager campaign with the
//!                observability sink detached vs attached; emits
//!                BENCH_stats.json and (with --gate) enforces the
//!                near-free overhead bound. `--stats-only` runs just
//!                this.
//!   drift duel — the drifting-substrate campaign with the continuous
//!                controller off vs on; emits BENCH_drift.json and
//!                (with --gate) enforces the near-free controller
//!                overhead bound. `--drift-only` runs just this.
//!   chaos duel — the identical continuous-manager campaign with the
//!                failpoint plan absent vs armed-but-silent (every
//!                rate zero); emits BENCH_chaos.json and (with --gate)
//!                enforces the zero-cost-when-disabled bound.
//!                `--chaos-only` runs just this.
//!   substrate  — space sampling/encoding throughput
//!   ablations  — kappa sweep, surrogate family, sequential vs parallel
//!                evaluation, BO vs random vs grid

use std::sync::Arc;
use std::time::Instant;

use ytopt::apps::AppKind;
use ytopt::bench_support::{run, section};
use ytopt::coordinator::{autotune_with_scorer, TuneSetup};
use ytopt::ensemble::LiarStrategy;
use ytopt::metrics::Metric;
use ytopt::obs::ObsSink;
use ytopt::platform::PlatformKind;
use ytopt::runtime::Scorer;
use ytopt::search::{BayesianOptimizer, BoConfig, SearchStrategy, StrategyKind, SurrogateKind};
use ytopt::space::paper;
use ytopt::surrogate::{export_forest, ForestConfig, RandomForest};
use ytopt::util::{Json, Pcg32};

fn make_training(n: usize, dim: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Pcg32::seeded(seed);
    let mut x = Vec::with_capacity(n * dim);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let row: Vec<f32> = (0..dim).map(|_| rng.f32()).collect();
        y.push(row[0] * 3.0 + (row[1] * 7.0).sin() + 0.2 * row[dim - 1]);
        x.extend(row);
    }
    (x, y)
}

/// L2 profile: XLA cost analysis recorded by aot.py into the manifest.
fn l2_cost_analysis() {
    section("L2: XLA cost analysis of the AOT modules (from manifest.json)");
    let path = ytopt::runtime::default_artifacts_dir().join("manifest.json");
    let Ok(text) = std::fs::read_to_string(&path) else {
        println!("(no artifacts; run `make artifacts`)");
        return;
    };
    let Ok(v) = ytopt::util::Json::parse(&text) else { return };
    for name in ["forest_scorer", "energy_reduce"] {
        if let Some(ca) = v.get(name).and_then(|a| a.get("cost_analysis")) {
            let g = |k: &str| ca.get(k).and_then(|x| x.as_f64()).unwrap_or(0.0);
            println!(
                "{name:<14} flops {:>12.0} | bytes accessed {:>12.0} | arithmetic intensity {:.3} flop/B",
                g("flops"),
                g("bytes_accessed"),
                g("flops") / g("bytes_accessed").max(1.0)
            );
        }
    }
}

fn hot_path(scorer: &Arc<Scorer>, quick: bool) {
    section("hot path: per-BO-iteration costs");
    let m = scorer.manifest().forest.clone();
    let dim = 17; // SW4lite-sized space
    let samples = if quick { 10 } else { 30 };

    for n_obs in [50usize, 200] {
        let (x, y) = make_training(n_obs, dim, 1);
        let mut rng = Pcg32::seeded(2);
        let cfg = ForestConfig { n_trees: m.trees, ..Default::default() };
        run(&format!("RF fit ({n_obs} obs, {} trees)", m.trees), 2, samples, || {
            let f = RandomForest::fit(&x, &y, dim, &cfg, &mut rng);
            std::hint::black_box(&f);
        });
    }

    let (x, y) = make_training(200, dim, 1);
    let mut rng = Pcg32::seeded(3);
    let forest =
        RandomForest::fit(&x, &y, dim, &ForestConfig { n_trees: m.trees, ..Default::default() }, &mut rng);
    run("tensor export (64 trees x 512 nodes)", 2, samples, || {
        let t = export_forest(&forest, m.trees, m.nodes_per_tree, m.features, m.depth).unwrap();
        std::hint::black_box(&t);
    });

    let tensors = export_forest(&forest, m.trees, m.nodes_per_tree, m.features, m.depth).unwrap();
    let mut rows = vec![0.0f32; m.candidates * m.features];
    for v in rows.iter_mut() {
        *v = rng.f32();
    }

    let cpu = Scorer::fallback();
    let rcpu = run(&format!("score {} candidates: pure-Rust", m.candidates), 2, samples, || {
        let o = cpu.score_candidates(&rows, m.candidates, &tensors, 1.96).unwrap();
        std::hint::black_box(&o);
    });
    println!("    -> {:.1}k candidates/s", rcpu.throughput(m.candidates) / 1e3);
    if scorer.is_accelerated() {
        let rxla = run(&format!("score {} candidates: AOT/XLA", m.candidates), 2, samples, || {
            let o = scorer.score_candidates(&rows, m.candidates, &tensors, 1.96).unwrap();
            std::hint::black_box(&o);
        });
        println!(
            "    -> {:.1}k candidates/s ({:.2}x vs pure-Rust)",
            rxla.throughput(m.candidates) / 1e3,
            rcpu.mean_s / rxla.mean_s
        );
    }

    // energy reduction at full Fig-15 shape
    let es = scorer.manifest().energy.clone();
    let nodes = es.max_nodes;
    let s = es.max_samples;
    let mut pkg = vec![0.0f32; nodes * s];
    let mut dram = vec![0.0f32; nodes * s];
    for i in 0..pkg.len() {
        pkg[i] = 100.0 + rng.f32() * 140.0;
        dram[i] = 5.0 + rng.f32() * 25.0;
    }
    let rcpu = run(&format!("energy reduce {nodes}x{s}: pure-Rust"), 1, samples, || {
        let o = cpu.reduce_energy(&pkg, &dram, nodes, s, s as f32, 0.5, 12.0).unwrap();
        std::hint::black_box(&o);
    });
    if scorer.is_accelerated() {
        let rxla = run(&format!("energy reduce {nodes}x{s}: AOT/XLA"), 1, samples, || {
            let o = scorer.reduce_energy(&pkg, &dram, nodes, s, s as f32, 0.5, 12.0).unwrap();
            std::hint::black_box(&o);
        });
        println!("    -> {:.2}x vs pure-Rust", rcpu.mean_s / rxla.mean_s);
    }
}

/// One simulated continuous-manager completion cycle at the BO level:
/// propose a replacement, impute a kriging-believer lie for it, plant
/// the pending observation, then amend an outstanding lie with its
/// "measurement". Cold mode disables the surrogate epoch cache and uses
/// the scalar scorer (the pre-cache pipeline: two full refits + scalar
/// scoring per completion); cached mode is the production path (one
/// refit, believer reuse, blocked scoring). Returns mean seconds per
/// completion.
fn proposal_loop_s(cached: bool, iters: usize) -> f64 {
    let space = Arc::new(paper::build_space(AppKind::Sw4lite, PlatformKind::Theta));
    let scorer = Arc::new(if cached { Scorer::fallback() } else { Scorer::fallback_scalar() });
    let mut bo = BayesianOptimizer::new(
        space.clone(),
        BoConfig { n_candidates: 2048, n_init: 2, ..Default::default() },
        scorer,
    );
    bo.set_surrogate_cache(cached);
    let mut rng = Pcg32::seeded(17);
    let mut reals: Vec<f64> = Vec::new();
    for _ in 0..160 {
        let c = space.sample(&mut rng);
        let y = 50.0 + rng.f64() * 20.0;
        bo.observe(&c, y);
        reals.push(y);
    }
    // warm up (first fit, allocations)
    let c = bo.propose(&mut rng);
    bo.observe(&c, 55.0);
    let t = Instant::now();
    for id in 0..iters {
        let c = bo.propose(&mut rng);
        let lie =
            LiarStrategy::KrigingBeliever.impute(Some(&mut bo), &c, &reals, 60.0, &mut rng);
        bo.observe_pending(id, &c, lie);
        bo.resolve_pending(id, 55.0 + (id % 9) as f64);
    }
    t.elapsed().as_secs_f64() / iters as f64
}

/// Scalar-vs-blocked scorer duel at the full artifact shape, plus the
/// cold-refit vs epoch-cached proposal-loop duel. Emits
/// `BENCH_scorer.json`; with `gate`, enforces the CI acceptance ratios
/// (blocked >= 2x scalar; cached proposal overhead <= 0.5x cold).
fn scorer_duel(quick: bool, gate: bool) {
    section("scorer duel: scalar walker vs blocked lockstep (1024 candidates x 64 trees)");
    let scalar = Scorer::fallback_scalar();
    let blocked = Scorer::fallback();
    let m = blocked.manifest().forest.clone();
    let dim = 17; // SW4lite-sized space
    let (x, y) = make_training(220, dim, 5);
    let mut rng = Pcg32::seeded(6);
    let forest = RandomForest::fit(
        &x,
        &y,
        dim,
        &ForestConfig { n_trees: m.trees, ..Default::default() },
        &mut rng,
    );
    let tensors = export_forest(&forest, m.trees, m.nodes_per_tree, m.features, m.depth).unwrap();
    let mut rows = vec![0.0f32; m.candidates * m.features];
    for i in 0..m.candidates {
        for j in 0..dim {
            rows[i * m.features + j] = rng.f32();
        }
    }
    let samples = if quick { 10 } else { 30 };
    let r_scalar = run(&format!("score {}: scalar walker", m.candidates), 2, samples, || {
        let o = scalar.score_candidates(&rows, m.candidates, &tensors, 1.96).unwrap();
        std::hint::black_box(&o);
    });
    let r_blocked = run(&format!("score {}: blocked lockstep", m.candidates), 2, samples, || {
        let o = blocked.score_candidates(&rows, m.candidates, &tensors, 1.96).unwrap();
        std::hint::black_box(&o);
    });
    let scorer_speedup = r_scalar.mean_s / r_blocked.mean_s;
    println!(
        "    -> {:.1}k vs {:.1}k candidates/s: blocked is {scorer_speedup:.2}x scalar",
        r_blocked.throughput(m.candidates) / 1e3,
        r_scalar.throughput(m.candidates) / 1e3,
    );

    section("proposal duel: cold-refit vs epoch-cached continuous-manager loop");
    let iters = if quick { 12 } else { 40 };
    let cold_s = proposal_loop_s(false, iters);
    let cached_s = proposal_loop_s(true, iters);
    let proposal_speedup = cold_s / cached_s;
    println!(
        "cold-refit {:.2} ms/completion | epoch-cached {:.2} ms/completion | {proposal_speedup:.2}x",
        cold_s * 1e3,
        cached_s * 1e3
    );

    let doc = Json::obj(vec![
        (
            "shape",
            Json::obj(vec![
                ("candidates", (m.candidates as u64).into()),
                ("trees", (m.trees as u64).into()),
                ("features", (m.features as u64).into()),
            ]),
        ),
        ("scalar_s", Json::Num(r_scalar.mean_s)),
        ("blocked_s", Json::Num(r_blocked.mean_s)),
        ("scorer_speedup", Json::Num(scorer_speedup)),
        ("cold_proposal_s", Json::Num(cold_s)),
        ("cached_proposal_s", Json::Num(cached_s)),
        ("proposal_speedup", Json::Num(proposal_speedup)),
    ]);
    // anchor to the package root: cargo runs bench binaries with cwd set
    // to the manifest dir, but direct invocations may not
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_scorer.json");
    std::fs::write(&path, doc.to_string()).expect("writing BENCH_scorer.json");
    println!("wrote {}", path.display());

    if gate {
        assert!(
            scorer_speedup >= 2.0,
            "CI gate: blocked scorer must be >= 2x scalar at the {}x{} shape (got {scorer_speedup:.2}x)",
            m.candidates,
            m.trees
        );
        assert!(
            cached_s <= 0.5 * cold_s,
            "CI gate: epoch-cached proposal overhead must be <= 0.5x cold-refit \
             (got {:.2} ms vs {:.2} ms)",
            cached_s * 1e3,
            cold_s * 1e3
        );
        println!("scorer gates passed: {scorer_speedup:.2}x blocked, {proposal_speedup:.2}x cached proposals");
    }
}

/// One full continuous-manager campaign (the engine `tune --stats`
/// runs), timed end to end, with the observability sink detached or
/// attached. Min-of-`reps` wall time divided by the eval count: seconds
/// per applied completion.
fn stats_campaign_s(with_stats: bool, evals: usize, reps: usize) -> f64 {
    let scorer = Arc::new(Scorer::fallback());
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let mut s = TuneSetup::new(AppKind::XSBenchHistory, PlatformKind::Theta, 1, Metric::Runtime);
        s.max_evals = evals;
        s.wallclock_budget_s = 1e9;
        s.seed = 77;
        s.n_init = 4;
        s.ensemble_workers = 4;
        if with_stats {
            s.obs = Some(Arc::new(ObsSink::default()));
        }
        let t = Instant::now();
        let r = autotune_with_scorer(&s, scorer.clone()).unwrap();
        let dt = t.elapsed().as_secs_f64();
        std::hint::black_box(&r);
        best = best.min(dt);
    }
    best / evals as f64
}

/// Stats duel: the same seed-77 continuous campaign with the sink
/// detached vs attached (every proposal/dispatch/completion recorded
/// into the ring + counters). Emits `BENCH_stats.json`; with `gate`,
/// enforces the ISSUE-8 acceptance bound (stats-on <= 1.05x stats-off
/// per completion).
fn stats_duel(quick: bool, gate: bool) {
    section("stats duel: observability sink detached vs attached (continuous manager)");
    let evals = if quick { 24 } else { 64 };
    let reps = if quick { 2 } else { 5 };
    let off_s = stats_campaign_s(false, evals, reps);
    let on_s = stats_campaign_s(true, evals, reps);
    let overhead = on_s / off_s - 1.0;
    println!(
        "stats-off {:.3} ms/completion | stats-on {:.3} ms/completion | overhead {:+.2}%",
        off_s * 1e3,
        on_s * 1e3,
        overhead * 100.0
    );

    let doc = Json::obj(vec![
        (
            "shape",
            Json::obj(vec![
                ("evals", (evals as u64).into()),
                ("workers", 4u64.into()),
                ("reps", (reps as u64).into()),
            ]),
        ),
        ("stats_off_s", Json::Num(off_s)),
        ("stats_on_s", Json::Num(on_s)),
        ("overhead_frac", Json::Num(overhead)),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_stats.json");
    std::fs::write(&path, doc.to_string()).expect("writing BENCH_stats.json");
    println!("wrote {}", path.display());

    if gate {
        assert!(
            on_s <= 1.05 * off_s,
            "CI gate: stats-on per-completion cost must be <= 1.05x stats-off \
             (got {:.3} ms vs {:.3} ms)",
            on_s * 1e3,
            off_s * 1e3
        );
        println!(
            "stats gate passed: {:+.2}% overhead with the sink attached",
            overhead * 100.0
        );
    }
}

/// One continuous-manager campaign over the drifting substrate (the
/// landscape phase-shifts halfway through the budget), with the
/// continuous controller off (stationary tuner) or on (decayed window +
/// residual CUSUM + authority limits). Min-of-`reps` wall time divided
/// by the eval count: seconds per applied completion.
fn drift_campaign_s(controller: bool, evals: usize, reps: usize) -> f64 {
    let scorer = Arc::new(Scorer::fallback());
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let mut s = TuneSetup::new(AppKind::XSBenchHistory, PlatformKind::Theta, 1, Metric::Runtime);
        s.max_evals = evals;
        s.wallclock_budget_s = 1e9;
        s.seed = 91;
        s.n_init = 4;
        s.ensemble_workers = 4;
        s.drift_at_eval = Some(evals / 2);
        s.drift_magnitude = 0.8;
        s.controller = controller;
        let t = Instant::now();
        let r = autotune_with_scorer(&s, scorer.clone()).unwrap();
        let dt = t.elapsed().as_secs_f64();
        std::hint::black_box(&r);
        best = best.min(dt);
    }
    best / evals as f64
}

/// Drift duel: the same drifting-substrate campaign with the controller
/// off vs on. The controller's extra work per completion — one stale
/// prediction, the CUSUM update, the authority-limit index walk — must
/// stay near-free. Emits `BENCH_drift.json`; with `gate`, enforces the
/// acceptance bound (controller <= 1.05x stationary per completion).
fn drift_duel(quick: bool, gate: bool) {
    section("drift duel: continuous controller vs stationary tuner (drifting substrate)");
    let evals = if quick { 24 } else { 64 };
    let reps = if quick { 2 } else { 5 };
    let off_s = drift_campaign_s(false, evals, reps);
    let on_s = drift_campaign_s(true, evals, reps);
    let overhead = on_s / off_s - 1.0;
    println!(
        "stationary {:.3} ms/completion | controller {:.3} ms/completion | overhead {:+.2}%",
        off_s * 1e3,
        on_s * 1e3,
        overhead * 100.0
    );

    let doc = Json::obj(vec![
        (
            "shape",
            Json::obj(vec![
                ("evals", (evals as u64).into()),
                ("workers", 4u64.into()),
                ("reps", (reps as u64).into()),
                ("drift_at", ((evals / 2) as u64).into()),
            ]),
        ),
        ("stationary_s", Json::Num(off_s)),
        ("controller_s", Json::Num(on_s)),
        ("overhead_frac", Json::Num(overhead)),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_drift.json");
    std::fs::write(&path, doc.to_string()).expect("writing BENCH_drift.json");
    println!("wrote {}", path.display());

    if gate {
        assert!(
            on_s <= 1.05 * off_s,
            "CI gate: controller per-completion cost must be <= 1.05x the stationary \
             tuner's (got {:.3} ms vs {:.3} ms)",
            on_s * 1e3,
            off_s * 1e3
        );
        println!(
            "drift gate passed: {:+.2}% overhead with the controller engaged",
            overhead * 100.0
        );
    }
}

/// One continuous-manager campaign with the chaos failpoint layer
/// absent (`chaos: None`, the production default) or armed but silent
/// (a plan with every site at rate zero — the pointer is threaded
/// through every I/O boundary, but no fault ever fires). Min-of-`reps`
/// wall time divided by the eval count: seconds per applied completion.
fn chaos_campaign_s(armed: bool, evals: usize, reps: usize) -> f64 {
    let scorer = Arc::new(Scorer::fallback());
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let mut s = TuneSetup::new(AppKind::XSBenchHistory, PlatformKind::Theta, 1, Metric::Runtime);
        s.max_evals = evals;
        s.wallclock_budget_s = 1e9;
        s.seed = 83;
        s.n_init = 4;
        s.ensemble_workers = 4;
        if armed {
            s.chaos = Some(Arc::new(ytopt::chaos::FaultPlan::new(123)));
        }
        let t = Instant::now();
        let r = autotune_with_scorer(&s, scorer.clone()).unwrap();
        let dt = t.elapsed().as_secs_f64();
        std::hint::black_box(&r);
        best = best.min(dt);
    }
    best / evals as f64
}

/// Chaos duel: the same seed-83 continuous campaign with the failpoint
/// plan absent vs armed-but-silent. The disabled fast path is one
/// pointer test per site consult, so the armed plan must be free to
/// within measurement noise. Emits `BENCH_chaos.json`; with `gate`,
/// enforces the ISSUE-10 acceptance bound (chaos-armed <= 1.01x
/// chaos-off per completion).
fn chaos_duel(quick: bool, gate: bool) {
    section("chaos duel: failpoint plan absent vs armed-but-silent (continuous manager)");
    let evals = if quick { 24 } else { 64 };
    let reps = if quick { 2 } else { 5 };
    let off_s = chaos_campaign_s(false, evals, reps);
    let on_s = chaos_campaign_s(true, evals, reps);
    let overhead = on_s / off_s - 1.0;
    println!(
        "chaos-off {:.3} ms/completion | chaos-armed {:.3} ms/completion | overhead {:+.2}%",
        off_s * 1e3,
        on_s * 1e3,
        overhead * 100.0
    );

    let doc = Json::obj(vec![
        (
            "shape",
            Json::obj(vec![
                ("evals", (evals as u64).into()),
                ("workers", 4u64.into()),
                ("reps", (reps as u64).into()),
            ]),
        ),
        ("chaos_off_s", Json::Num(off_s)),
        ("chaos_armed_s", Json::Num(on_s)),
        ("overhead_frac", Json::Num(overhead)),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_chaos.json");
    std::fs::write(&path, doc.to_string()).expect("writing BENCH_chaos.json");
    println!("wrote {}", path.display());

    if gate {
        assert!(
            on_s <= 1.01 * off_s,
            "CI gate: an armed-but-silent fault plan must cost <= 1.01x the chaos-off \
             campaign per completion (got {:.3} ms vs {:.3} ms)",
            on_s * 1e3,
            off_s * 1e3
        );
        println!(
            "chaos gate passed: {:+.2}% overhead with the silent plan armed",
            overhead * 100.0
        );
    }
}

fn substrate(quick: bool) {
    section("substrate: space sampling / encoding");
    let samples = if quick { 10 } else { 30 };
    let space = paper::build_space(AppKind::Sw4lite, PlatformKind::Theta);
    let mut rng = Pcg32::seeded(4);
    let r = run("sample 1024 valid configs (SW4lite space)", 2, samples, || {
        for _ in 0..1024 {
            std::hint::black_box(space.sample(&mut rng));
        }
    });
    println!("    -> {:.1}k configs/s", r.throughput(1024) / 1e3);
    let cfg = space.sample(&mut rng);
    let mut row = vec![0.0f32; 32];
    let r = run("encode 1024 configs to f32[32]", 2, samples, || {
        for _ in 0..1024 {
            space.encode_into(&cfg, &mut row);
            std::hint::black_box(&row);
        }
    });
    println!("    -> {:.1}k encodes/s", r.throughput(1024) / 1e3);
}

fn ablations(scorer: &Arc<Scorer>, quick: bool) {
    let evals = if quick { 10 } else { 24 };
    let repeats: u64 = if quick { 1 } else { 3 };

    section("ablation: kappa (exploration/exploitation, Eq. 1)");
    for kappa in [0.0, 0.5, 1.96, 4.0] {
        let mut sum = 0.0;
        for seed in 0..repeats {
            let mut s = TuneSetup::new(AppKind::Amg, PlatformKind::Summit, 4096, Metric::Runtime);
            s.max_evals = evals;
            s.kappa = kappa;
            s.seed = 100 + seed;
            sum += autotune_with_scorer(&s, scorer.clone()).unwrap().improvement_pct;
        }
        println!("kappa {kappa:<5} -> mean improvement {:.2}% over {repeats} seeds", sum / repeats as f64);
    }

    section("ablation: surrogate family (paper found RF best)");
    for (name, kind) in [
        ("RandomForest", SurrogateKind::RandomForest),
        ("ExtraTrees", SurrogateKind::ExtraTrees),
        ("GBRT-lite", SurrogateKind::Gbrt),
    ] {
        let mut sum = 0.0;
        for seed in 0..repeats {
            let mut s = TuneSetup::new(AppKind::Amg, PlatformKind::Summit, 4096, Metric::Runtime);
            s.max_evals = evals;
            s.surrogate = kind;
            s.seed = 200 + seed;
            sum += autotune_with_scorer(&s, scorer.clone()).unwrap().improvement_pct;
        }
        println!("{name:<14} -> mean improvement {:.2}%", sum / repeats as f64);
    }

    section("ablation: search strategy");
    for (name, kind) in [
        ("BO (ytopt)", StrategyKind::Bo),
        ("Random", StrategyKind::Random),
        ("Grid", StrategyKind::Grid),
        ("MCTS (mctree)", StrategyKind::Mctree),
    ] {
        let mut sum = 0.0;
        for seed in 0..repeats {
            let mut s = TuneSetup::new(AppKind::Sw4lite, PlatformKind::Summit, 1024, Metric::Runtime);
            s.max_evals = evals;
            s.strategy = kind;
            s.seed = 300 + seed;
            sum += autotune_with_scorer(&s, scorer.clone()).unwrap().improvement_pct;
        }
        println!("{name:<12} -> mean improvement {:.2}%", sum / repeats as f64);
    }

    section("ablation: sequential (Ray-like) vs parallel (libensemble-like)");
    for par in [1usize, 2, 4, 8] {
        let mut s = TuneSetup::new(AppKind::Amg, PlatformKind::Summit, 4096, Metric::Runtime);
        s.max_evals = evals;
        s.parallel_evals = par;
        s.seed = 400;
        s.wallclock_budget_s = 1e9;
        let r = autotune_with_scorer(&s, scorer.clone()).unwrap();
        println!(
            "parallel {par} -> simulated wallclock {:>8.0} s for {} evals, improvement {:.2}%",
            r.wallclock_s, r.evaluations, r.improvement_pct
        );
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    let gate = args.iter().any(|a| a == "--gate");
    let scorer_only = args.iter().any(|a| a == "--scorer-only");
    let stats_only = args.iter().any(|a| a == "--stats-only");
    let drift_only = args.iter().any(|a| a == "--drift-only");
    let chaos_only = args.iter().any(|a| a == "--chaos-only");
    if scorer_only {
        scorer_duel(quick, gate);
        return;
    }
    if stats_only {
        stats_duel(quick, gate);
        return;
    }
    if drift_only {
        drift_duel(quick, gate);
        return;
    }
    if chaos_only {
        chaos_duel(quick, gate);
        return;
    }
    let scorer = Arc::new(Scorer::auto(&ytopt::runtime::default_artifacts_dir()));
    println!(
        "scorer backend: {}",
        if scorer.is_accelerated() { "AOT/XLA" } else { "pure-Rust fallback" }
    );
    l2_cost_analysis();
    hot_path(&scorer, quick);
    scorer_duel(quick, gate);
    stats_duel(quick, gate);
    drift_duel(quick, gate);
    chaos_duel(quick, gate);
    substrate(quick);
    ablations(&scorer, quick);
}
