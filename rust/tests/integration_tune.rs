//! Full-stack integration: the complete autotuning pipeline with the AOT
//! XLA artifacts (when present), reproducing the paper's headline bands.
//! These are slower tests; each runs a real BO loop end to end.

use std::sync::Arc;

use ytopt::apps::AppKind;
use ytopt::coordinator::{autotune_with_scorer, TuneSetup};
use ytopt::metrics::Metric;
use ytopt::platform::PlatformKind;
use ytopt::runtime::Scorer;

fn scorer() -> Arc<Scorer> {
    Arc::new(Scorer::auto(&ytopt::runtime::default_artifacts_dir()))
}

#[test]
fn sw4lite_theta_full_stack_headline() {
    // paper Fig 14: 171.595 -> 14.427 s (91.59%)
    let mut setup = TuneSetup::new(AppKind::Sw4lite, PlatformKind::Theta, 1024, Metric::Runtime);
    setup.max_evals = 30;
    setup.wallclock_budget_s = 1e9;
    setup.seed = 1;
    let r = autotune_with_scorer(&setup, scorer()).unwrap();
    assert!((r.baseline_objective - 171.595).abs() < 2.0, "baseline {}", r.baseline_objective);
    assert!(r.improvement_pct > 85.0, "improvement {}", r.improvement_pct);
    assert!((11.0..18.0).contains(&r.best_objective), "best {}", r.best_objective);
}

#[test]
fn amg_summit_full_stack_band() {
    // paper Fig 11: 8.694 -> 6.734 s (22.54%)
    let mut setup = TuneSetup::new(AppKind::Amg, PlatformKind::Summit, 4096, Metric::Runtime);
    setup.max_evals = 40;
    setup.wallclock_budget_s = 1e9;
    setup.seed = 2;
    let r = autotune_with_scorer(&setup, scorer()).unwrap();
    assert!((r.baseline_objective - 8.694).abs() < 0.05);
    assert!(r.improvement_pct > 14.0 && r.improvement_pct < 30.0, "{}", r.improvement_pct);
}

#[test]
fn swfft_summit_full_stack_band() {
    // paper Fig 9: 8.93 -> 7.797 s (12.69%)
    let mut setup = TuneSetup::new(AppKind::Swfft, PlatformKind::Summit, 4096, Metric::Runtime);
    setup.max_evals = 40;
    setup.wallclock_budget_s = 1e9;
    setup.seed = 3;
    let r = autotune_with_scorer(&setup, scorer()).unwrap();
    assert!((r.baseline_objective - 8.93).abs() < 0.05);
    assert!(r.improvement_pct > 8.0 && r.improvement_pct < 18.0, "{}", r.improvement_pct);
}

#[test]
fn energy_pipeline_through_aot_artifact() {
    // paper Fig 15c: AMG energy 5642.6 -> 4566.7 J (20.88%)
    let mut setup = TuneSetup::new(AppKind::Amg, PlatformKind::Theta, 4096, Metric::Energy);
    setup.max_evals = 25;
    setup.wallclock_budget_s = 1e9;
    setup.seed = 4;
    let r = autotune_with_scorer(&setup, scorer()).unwrap();
    assert!(
        (r.baseline_objective - 5642.6).abs() < 5642.6 * 0.06,
        "baseline energy {}",
        r.baseline_objective
    );
    assert!(r.improvement_pct > 12.0 && r.improvement_pct < 30.0, "{}", r.improvement_pct);
    // every record went through geopmlaunch
    assert!(r.db.records.iter().all(|x| x.command.contains("geopm")));
}

#[test]
fn overheads_scale_weakly_from_64_to_4096_nodes() {
    // the paper's low-overhead/scalability claim, measured end to end
    let overhead_at = |nodes: u64| {
        let mut setup = TuneSetup::new(AppKind::Swfft, PlatformKind::Theta, nodes, Metric::Runtime);
        setup.max_evals = 10;
        setup.wallclock_budget_s = 1e9;
        setup.seed = 5;
        let r = autotune_with_scorer(&setup, scorer()).unwrap();
        // skip the first-eval setup spike: median-ish via non-first max
        r.db.records.iter().skip(1).map(|x| x.overhead_s).fold(0.0, f64::max)
    };
    let small = overhead_at(64);
    let large = overhead_at(4096);
    assert!(large < small + 10.0, "overhead blew up: {small} -> {large}");
    assert!(large < 30.0, "Table IV band for SWFFT/Theta: {large}");
}

#[test]
fn scorer_auto_falls_back_on_missing_artifacts() {
    let s = Scorer::auto(std::path::Path::new("/nonexistent-artifacts-dir"));
    assert!(!s.is_accelerated());
    // and the fallback still drives a full tune
    let mut setup = TuneSetup::new(AppKind::XSBenchHistory, PlatformKind::Theta, 1, Metric::Runtime);
    setup.max_evals = 10;
    let r = autotune_with_scorer(&setup, Arc::new(s)).unwrap();
    assert_eq!(r.evaluations, 10);
    assert!(!r.scorer_accelerated);
}

#[test]
fn xla_and_fallback_scorers_agree_on_proposals_quality() {
    // not bit-identical paths (fit RNG differs per proposal timing), but
    // both backends must reach the same quality band on the same problem
    let run_with = |s: Arc<Scorer>| {
        let mut setup = TuneSetup::new(AppKind::Amg, PlatformKind::Summit, 4096, Metric::Runtime);
        setup.max_evals = 30;
        setup.wallclock_budget_s = 1e9;
        setup.seed = 6;
        autotune_with_scorer(&setup, s).unwrap().improvement_pct
    };
    let xla = scorer();
    if !xla.is_accelerated() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let a = run_with(xla);
    let b = run_with(Arc::new(Scorer::fallback()));
    assert!((a - b).abs() < 12.0, "XLA {a}% vs fallback {b}%");
}

#[test]
fn grid_baseline_is_no_better_than_bo_on_sw4lite() {
    use ytopt::search::StrategyKind;
    let run_kind = |kind| {
        let mut setup =
            TuneSetup::new(AppKind::Sw4lite, PlatformKind::Theta, 1024, Metric::Runtime);
        setup.max_evals = 24;
        setup.wallclock_budget_s = 1e9;
        setup.strategy = kind;
        setup.seed = 7;
        autotune_with_scorer(&setup, Arc::new(Scorer::fallback())).unwrap().best_objective
    };
    let bo = run_kind(StrategyKind::Bo);
    let grid = run_kind(StrategyKind::Grid);
    assert!(bo <= grid * 1.3, "BO {bo} vs grid {grid}");
}
