//! Observability-layer e2e tests (ISSUE 8 acceptance):
//!
//! * Stats recording is *off the deterministic path*: seed-for-seed
//!   trajectories are bit-identical with the sink attached or absent,
//!   across the continuous, generational, and federated engines — and
//!   the sink's counters/ring agree with the run it watched.
//! * `stats` over the wire: a live daemon campaign serves its counter
//!   snapshot and event-ring tail, with a resumable cursor.
//! * Satellite 1 regression: a `Watch` stream must not park its
//!   connection's request path — submit/status/cancel/stats keep
//!   answering while events flow, and a watcher that never drains its
//!   socket stalls neither other clients nor daemon shutdown.
//! * Satellite 2 regression: the watch replay→live handoff is atomic —
//!   watchers attached before start, mid-run, and after the terminal
//!   event all see the full log exactly once.
//! * Satellite 3: `worker_idle_s` is clamped non-negative and stays
//!   consistent across kill/resume sessions.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use ytopt::apps::AppKind;
use ytopt::coordinator::{autotune_with_scorer, TuneResult, TuneSetup};
use ytopt::ensemble::{LiarStrategy, ManagerCycle};
use ytopt::metrics::Metric;
use ytopt::obs::{ObsEvent, ObsSink};
use ytopt::platform::PlatformKind;
use ytopt::runtime::Scorer;
use ytopt::service::protocol::encode_frame;
use ytopt::service::{
    CampaignSpec, Client, Daemon, Decoder, Event, Message, Request, Response, ServeConfig,
    ServiceConfig,
};

fn run(setup: &TuneSetup) -> TuneResult {
    autotune_with_scorer(setup, Arc::new(Scorer::fallback())).unwrap()
}

fn tmpfile(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("ytopt-obs-{tag}-{}.json", std::process::id()))
}

/// The host-timing-free digest of a run's history (the `ensemble_e2e`
/// convention): everything that must be bit-identical across
/// deterministic replays.
fn history(r: &TuneResult) -> Vec<(usize, String, u64, u64, u64, bool, bool)> {
    r.db.records
        .iter()
        .map(|x| {
            (
                x.id,
                x.config_key.clone(),
                x.objective.to_bits(),
                x.measured.runtime_s.to_bits(),
                x.best_so_far.to_bits(),
                x.timed_out,
                x.cancelled,
            )
        })
        .collect()
}

fn base_setup(seed: u64, max_evals: usize, workers: usize) -> TuneSetup {
    let mut s = TuneSetup::new(AppKind::XSBenchHistory, PlatformKind::Theta, 1, Metric::Runtime);
    s.max_evals = max_evals;
    s.wallclock_budget_s = 1e9;
    s.seed = seed;
    s.n_init = 4;
    s.ensemble_workers = workers;
    s
}

/// Run `setup` twice — sink absent, then attached — and require the two
/// trajectories to be bit-identical. Returns the attached sink for
/// counter checks.
fn assert_stats_transparent(setup: &TuneSetup, what: &str) -> (TuneResult, Arc<ObsSink>) {
    let off = run(setup);
    let sink = Arc::new(ObsSink::default());
    let mut on_setup = setup.clone();
    on_setup.obs = Some(sink.clone());
    let on = run(&on_setup);
    assert_eq!(
        history(&off),
        history(&on),
        "{what}: attaching the stats sink perturbed the trajectory"
    );
    assert_eq!(off.best_objective.to_bits(), on.best_objective.to_bits(), "{what}");
    (on, sink)
}

#[test]
fn stats_recording_is_bit_transparent_across_all_engines() {
    // continuous manager, kriging believer: exercises SurrogateFit
    // (hits and paid fits) alongside the proposal/completion events
    let mut cont = base_setup(101, 16, 4);
    cont.liar = LiarStrategy::KrigingBeliever;
    let (r, sink) = assert_stats_transparent(&cont, "continuous");
    let snap = sink.snapshot();
    assert_eq!(snap.completions, 16);
    assert_eq!(snap.dispatches, snap.proposals);
    assert!(snap.proposals >= 16, "every completion was proposed first");
    assert!(snap.surrogate_fits > 0, "a 16-eval BO run fits surrogates");
    assert!(
        snap.surrogate_cache_hits > 0,
        "the believer must reuse the epoch-cached surrogate"
    );
    assert_eq!(snap.best_objective.to_bits(), r.best_objective.to_bits());
    assert_eq!(snap.shards.len(), 1);
    assert_eq!(snap.shards[0].applied, 16);
    assert_eq!(snap.ring_dropped, 0);
    let (events, next) = sink.tail(0);
    assert_eq!(next, snap.ring_next);
    assert_eq!(
        events.iter().filter(|e| matches!(e.ev, ObsEvent::Completed { .. })).count(),
        16,
        "one Completed ring event per applied evaluation"
    );
    // seqs are the logical clock: strictly consecutive from 0
    for (i, e) in events.iter().enumerate() {
        assert_eq!(e.seq, i as u64);
    }

    // generational cycle records per-batch (shard 0)
    let mut generational = base_setup(202, 16, 4);
    generational.manager_cycle = ManagerCycle::Generational;
    let (_, sink) = assert_stats_transparent(&generational, "generational");
    let snap = sink.snapshot();
    assert_eq!(snap.completions, 16);
    assert_eq!(snap.proposals, 16);
    assert_eq!(snap.shards.len(), 1);
    assert_eq!(snap.shards[0].applied, 16);

    // federated K=2: per-shard gauges plus elite-exchange rounds
    let mut fed = base_setup(303, 16, 2);
    fed.federation_shards = 2;
    fed.elite_exchange_every = 2;
    fed.federation_elites = 2;
    let (_, sink) = assert_stats_transparent(&fed, "federation");
    let snap = sink.snapshot();
    assert_eq!(snap.completions, 16);
    assert!(snap.exchange_rounds > 0, "K=2 at exchange-every-2 must exchange");
    assert_eq!(snap.shards.len(), 2, "one gauge row per shard");
    assert_eq!(snap.shards.iter().map(|g| g.applied).sum::<u64>(), 16);
    let (events, _) = sink.tail(0);
    assert!(
        events.iter().any(|e| matches!(e.ev, ObsEvent::EliteExchange { .. })),
        "exchange rounds must appear in the ring"
    );
}

/// Satellite 3: `worker_idle_s` is clamped non-negative everywhere, and
/// kill/resume leaves the idle accounting consistent — the continuous
/// engine reports exactly zero in the killed session, the resumed
/// session, and the uninterrupted reference alike, while the
/// generational oracle's split sessions each report finite non-negative
/// barrier idle.
#[test]
fn worker_idle_time_is_clamped_and_consistent_across_kill_and_resume() {
    // continuous kill/resume: idle is identically zero on every side
    let ckpt = tmpfile("idle-cont");
    let _ = std::fs::remove_file(&ckpt);
    let mut s = base_setup(41, 18, 4);
    s.app = AppKind::Swfft;
    s.nodes = 64;
    let full = run(&s);
    let full_idle = full.ensemble.as_ref().unwrap().worker_idle_s;
    assert_eq!(full_idle, 0.0);

    let mut killed = s.clone();
    killed.checkpoint_path = Some(ckpt.clone());
    killed.kill_after_evals = Some(6);
    let partial = run(&killed);
    assert_eq!(partial.evaluations, 6);
    let killed_idle = partial.ensemble.as_ref().unwrap().worker_idle_s;

    let mut resumed = s.clone();
    resumed.checkpoint_path = Some(ckpt.clone());
    let r = run(&resumed);
    assert_eq!(r.evaluations, 18);
    let resumed_idle = r.ensemble.as_ref().unwrap().worker_idle_s;

    assert_eq!(killed_idle, 0.0, "a killed continuous session must not invent idle time");
    assert_eq!(resumed_idle, 0.0, "a resumed continuous session must not invent idle time");
    assert_eq!(
        history(&full),
        history(&r),
        "kill/resume must replay the uninterrupted trajectory (stats equality rests on it)"
    );
    std::fs::remove_file(&ckpt).unwrap();

    // generational split sessions: positive at the barriers, never
    // negative (the clamp), finite in both halves
    let ckpt = tmpfile("idle-gen");
    let _ = std::fs::remove_file(&ckpt);
    let mut g = base_setup(43, 20, 4);
    g.manager_cycle = ManagerCycle::Generational;
    g.checkpoint_path = Some(ckpt.clone());
    let mut first = g.clone();
    first.max_evals = 12;
    let ra = run(&first);
    assert_eq!(ra.evaluations, 12);
    let a_idle = ra.ensemble.as_ref().unwrap().worker_idle_s;
    assert!(a_idle.is_finite() && a_idle > 0.0, "generational barriers idle (got {a_idle})");

    let rb = run(&g);
    assert_eq!(rb.evaluations, 20);
    assert_eq!(rb.ensemble.as_ref().unwrap().resumed_evals, 12);
    let b_idle = rb.ensemble.as_ref().unwrap().worker_idle_s;
    assert!(
        b_idle.is_finite() && b_idle >= 0.0,
        "resumed generational session reported negative idle ({b_idle})"
    );
    std::fs::remove_file(&ckpt).unwrap();
}

// ---------------------------------------------------------------------
// daemon-side tests: raw-frame helpers
// ---------------------------------------------------------------------

fn start_daemon() -> Daemon {
    Daemon::start(
        ServeConfig {
            listen: "127.0.0.1:0".into(),
            service: ServiceConfig {
                max_active: 4,
                history_dir: None,
                checkpoint_dir: None,
                warm_start_elites: 0,
            },
            chaos: None,
        },
        Arc::new(Scorer::fallback()),
    )
    .unwrap()
}

fn long_campaign(seed: u64) -> CampaignSpec {
    CampaignSpec {
        seed,
        workers: 2,
        strategy: "random".into(),
        max_evals: 20_000,
        wallclock_budget_s: 1e9,
        warm_start: false,
        ..CampaignSpec::default()
    }
}

fn short_campaign(seed: u64) -> CampaignSpec {
    CampaignSpec {
        seed,
        workers: 2,
        max_evals: 12,
        wallclock_budget_s: 1e9,
        warm_start: false,
        ..CampaignSpec::default()
    }
}

/// A deliberately low-level connection: send any frame at any time, read
/// whatever arrives. The high-level [`Client`] can't interleave requests
/// with a live watch stream — which is exactly what these tests need.
struct RawConn {
    stream: TcpStream,
    dec: Decoder,
    queue: std::collections::VecDeque<Message>,
}

impl RawConn {
    fn connect(addr: &str) -> RawConn {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_millis(100))).unwrap();
        RawConn { stream, dec: Decoder::new(), queue: std::collections::VecDeque::new() }
    }

    fn send(&mut self, req: Request) {
        self.stream.write_all(&encode_frame(&Message::Request(req))).unwrap();
        self.stream.flush().unwrap();
    }

    /// Next frame within `deadline`, pumping the decoder.
    fn next(&mut self, deadline: Instant) -> Option<Message> {
        loop {
            if let Some(m) = self.queue.pop_front() {
                return Some(m);
            }
            if Instant::now() >= deadline {
                return None;
            }
            let mut buf = [0u8; 4096];
            match self.stream.read(&mut buf) {
                Ok(0) => return None,
                Ok(n) => self.queue.extend(self.dec.push(&buf[..n]).unwrap()),
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) => {}
                Err(e) => panic!("raw read failed: {e}"),
            }
        }
    }

    /// Skip event frames until a `Response` arrives (watch streams
    /// interleave events with responses on the shared writer).
    fn next_response(&mut self, deadline: Instant) -> Option<Response> {
        while let Some(m) = self.next(deadline) {
            match m {
                Message::Response(r) => return Some(r),
                Message::Event(_) => continue,
                other => panic!("unexpected frame: {other:?}"),
            }
        }
        None
    }
}

/// Satellite 1: a connection with a live watch stream keeps answering
/// requests. Before the fix the daemon served the watch inline, so
/// status/cancel on the same connection blocked until the campaign went
/// terminal (here: 20k evals away).
#[test]
fn watch_stream_does_not_block_the_connections_request_path() {
    let daemon = start_daemon();
    let addr = daemon.addr().to_string();
    let mut ctl = Client::connect(&addr).unwrap();
    let id = ctl.submit(long_campaign(6001)).unwrap();

    let mut raw = RawConn::connect(&addr);
    raw.send(Request::Watch { campaign: id, from: 0 });
    // the watch is streaming; the same connection must still answer
    raw.send(Request::Status);
    let deadline = Instant::now() + Duration::from_secs(20);
    let resp = raw
        .next_response(deadline)
        .expect("status during a live watch must answer long before the campaign ends");
    match resp {
        Response::Status { campaigns } => {
            let row = campaigns.iter().find(|c| c.id == id).unwrap();
            assert!(
                row.evaluations < 20_000,
                "the answer arrived while the campaign was still running"
            );
        }
        other => panic!("expected status, got {other:?}"),
    }
    // stats interleaves on the same connection too
    raw.send(Request::Stats { campaign: id, from: 0 });
    match raw.next_response(Instant::now() + Duration::from_secs(20)) {
        Some(Response::StatsReply { campaign, .. }) => assert_eq!(campaign, id),
        other => panic!("expected a stats reply, got {other:?}"),
    }
    // and cancel — after which the watch stream itself must conclude
    // with the terminal frame on this very connection
    raw.send(Request::Cancel { campaign: id });
    match raw.next_response(Instant::now() + Duration::from_secs(20)) {
        Some(Response::Cancelling { campaign }) => assert_eq!(campaign, id),
        other => panic!("expected a cancel acknowledgement, got {other:?}"),
    }
    let deadline = Instant::now() + Duration::from_secs(60);
    let mut saw_terminal = false;
    while let Some(m) = raw.next(deadline) {
        if let Message::Event(ev) = m {
            if ev.is_terminal() {
                assert!(matches!(ev, Event::Cancelled { .. }));
                saw_terminal = true;
                break;
            }
        }
    }
    assert!(saw_terminal, "the watch stream must still deliver the terminal event");
    daemon.shutdown();
}

/// Satellite 1, the slow-reader half: a watcher that never drains its
/// socket must not stall other clients' requests, and must not hang
/// daemon shutdown (frame writes to it time out and drop the stream).
#[test]
fn a_watcher_that_never_reads_stalls_nobody() {
    let daemon = start_daemon();
    let addr = daemon.addr().to_string();
    let mut ctl = Client::connect(&addr).unwrap();
    let id = ctl.submit(long_campaign(6002)).unwrap();

    // the deliberately slow reader: sends Watch, then never reads a byte
    let mut slow = RawConn::connect(&addr);
    slow.send(Request::Watch { campaign: id, from: 0 });

    // while its stream backs up, another client's requests answer promptly
    let t0 = Instant::now();
    let mut other = Client::connect(&addr).unwrap();
    other.ping().unwrap();
    let rows = other.status().unwrap();
    assert!(rows.iter().any(|r| r.id == id));
    let (snap, _, _) = other.stats(id, u64::MAX).unwrap();
    assert_eq!(snap.ring_dropped, 0);
    other.cancel(id).unwrap();
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "requests stalled behind a slow watcher ({:?})",
        t0.elapsed()
    );

    // shutdown must complete despite the undrained watcher socket: its
    // writes either fit the kernel buffer or stall out and disconnect
    drop(slow.stream);
    daemon.shutdown();
}

/// Satellite 2: the replay→live handoff is atomic. Watchers attached
/// before the campaign starts, mid-run, and after the terminal event
/// all see the identical full log, exactly once, ending in exactly one
/// terminal frame.
#[test]
fn watchers_attached_at_adversarial_points_see_the_full_log_exactly_once() {
    let daemon = start_daemon();
    let addr = daemon.addr().to_string();
    let mut ctl = Client::connect(&addr).unwrap();
    let id = ctl.submit(short_campaign(6003)).unwrap();

    // attached immediately after submit (usually before the first apply)
    let early_addr = addr.clone();
    let early = std::thread::spawn(move || {
        let mut c = Client::connect(&early_addr).unwrap();
        let mut log = Vec::new();
        c.watch(id, 0, &mut |ev| log.push(ev.clone())).unwrap();
        log
    });

    // attached mid-run (as soon as progress is visible)
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let rows = ctl.status().unwrap();
        if rows.iter().find(|r| r.id == id).unwrap().evaluations >= 2 {
            break;
        }
        assert!(Instant::now() < deadline, "campaign made no progress");
        std::thread::sleep(Duration::from_millis(5));
    }
    let mid_addr = addr.clone();
    let mid = std::thread::spawn(move || {
        let mut c = Client::connect(&mid_addr).unwrap();
        let mut log = Vec::new();
        c.watch(id, 0, &mut |ev| log.push(ev.clone())).unwrap();
        log
    });

    let early_log = early.join().unwrap();
    let mid_log = mid.join().unwrap();

    // attached strictly after the terminal event is in the log
    let mut late = Client::connect(&addr).unwrap();
    let mut late_log = Vec::new();
    late.watch(id, 0, &mut |ev| late_log.push(ev.clone())).unwrap();

    for (what, log) in [("early", &early_log), ("mid", &mid_log), ("late", &late_log)] {
        assert_eq!(
            log.iter().filter(|e| e.is_terminal()).count(),
            1,
            "{what} watcher: exactly one terminal frame"
        );
        assert!(log.last().unwrap().is_terminal(), "{what} watcher: terminal frame last");
        assert!(
            log.iter().any(|e| matches!(e, Event::Started { .. })),
            "{what} watcher: replay must include the Started event"
        );
    }
    let render = |log: &[Event]| format!("{log:?}");
    assert_eq!(render(&early_log), render(&mid_log), "mid-run attach lost or duplicated events");
    assert_eq!(render(&early_log), render(&late_log), "post-terminal attach diverged");

    // a replay cursor pointing mid-log gets exactly the suffix
    let from = (late_log.len() - 3) as u64;
    let mut suffix = Vec::new();
    let mut c = Client::connect(&addr).unwrap();
    c.watch(id, from, &mut |ev| suffix.push(ev.clone())).unwrap();
    assert_eq!(render(&suffix), render(&late_log[from as usize..]));

    daemon.shutdown();
}

/// The stats protocol end-to-end: a finished daemon campaign serves a
/// coherent snapshot and a cursorable ring tail; unknown campaigns are
/// refused with an error, not a dropped connection.
#[test]
fn stats_requests_serve_snapshot_and_ring_tail_with_a_cursor() {
    let daemon = start_daemon();
    let addr = daemon.addr().to_string();
    let mut client = Client::connect(&addr).unwrap();
    let id = client.submit(short_campaign(6004)).unwrap();

    // run to completion first, so counters are exact
    let mut log = Vec::new();
    let terminal = client.watch(id, 0, &mut |ev| log.push(ev.clone())).unwrap();
    assert!(matches!(terminal, Event::Done { .. }));

    let (snap, events, next) = client.stats(id, 0).unwrap();
    assert_eq!(snap.completions, 12);
    assert!(snap.proposals >= 12);
    assert_eq!(snap.dispatches, snap.proposals);
    assert!(snap.best_objective.is_finite());
    assert_eq!(snap.shards.len(), 1);
    assert_eq!(snap.shards[0].applied, 12);
    assert_eq!(snap.shards[0].in_flight, 0, "a finished campaign has nothing in flight");
    assert_eq!(snap.ring_dropped, 0);
    assert_eq!(next, snap.ring_next);
    assert!(!events.is_empty());
    assert_eq!(events.first().unwrap().seq, 0, "from=0 replays the ring from its start");
    assert_eq!(
        events.iter().filter(|e| matches!(e.ev, ObsEvent::Completed { .. })).count(),
        12
    );
    // ring completions agree with the wire-event history
    let wire_completed = log
        .iter()
        .filter(|e| matches!(e, Event::EvalCompleted { .. }))
        .count();
    assert_eq!(wire_completed, 12);

    // the cursor is resumable: polling from `next` drains nothing new
    let (_, more, next2) = client.stats(id, next).unwrap();
    assert!(more.is_empty(), "a drained cursor must stay drained");
    assert_eq!(next2, next);

    // unknown campaigns error without poisoning the connection
    assert!(client.stats(id + 999, 0).is_err());
    client.ping().unwrap();

    daemon.shutdown();
}
