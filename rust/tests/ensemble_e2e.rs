//! End-to-end ensemble-engine tests: tuning-quality parity with the
//! serial loop, wall-clock compression at the same evaluation budget,
//! checkpoint resume with zero re-evaluation, the continuous-vs-
//! generational manager-cycle contracts (seed-for-seed parity at one
//! worker, zero idle-at-barrier gaps at many), and the multi-manager
//! federation contracts (K=1 bit-identity with the single continuous
//! manager, K=3 seed-for-seed determinism, mid-trajectory kill/resume
//! bit-identity via the persisted proposal state, cross-policy resume
//! refusal).

use std::path::PathBuf;
use std::sync::Arc;

use ytopt::apps::AppKind;
use ytopt::coordinator::{autotune_with_scorer, TuneResult, TuneSetup};
use ytopt::ensemble::federation::shard_checkpoint_path;
use ytopt::ensemble::{autotune_ensemble, LiarStrategy, ManagerCycle};
use ytopt::metrics::Metric;
use ytopt::platform::PlatformKind;
use ytopt::runtime::Scorer;

fn run(setup: &TuneSetup) -> TuneResult {
    autotune_with_scorer(setup, Arc::new(Scorer::fallback())).unwrap()
}

fn tmpfile(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("ytopt-e2e-{tag}-{}.json", std::process::id()))
}

/// The host-timing-free view of a run's history: everything that must be
/// bit-identical across deterministic replays. (`processing_s` and
/// `wallclock_s` carry real host search-time jitter and are excluded.)
fn history(r: &TuneResult) -> Vec<(usize, String, u64, u64, u64, bool, bool)> {
    r.db.records
        .iter()
        .map(|x| {
            (
                x.id,
                x.config_key.clone(),
                x.objective.to_bits(),
                x.measured.runtime_s.to_bits(),
                x.best_so_far.to_bits(),
                x.timed_out,
                x.cancelled,
            )
        })
        .collect()
}

#[test]
fn ensemble_matches_serial_quality_in_less_wallclock() {
    // the acceptance setting: 8 workers, same evaluation budget, XSBench
    let mut serial = TuneSetup::new(AppKind::XSBenchHistory, PlatformKind::Theta, 1, Metric::Runtime);
    serial.max_evals = 48;
    serial.wallclock_budget_s = 1e9;
    serial.seed = 7;
    let mut ensemble = serial.clone();
    ensemble.ensemble_workers = 8;

    let rs = run(&serial);
    let re = run(&ensemble);

    assert_eq!(rs.evaluations, 48);
    assert_eq!(re.evaluations, 48, "ensemble must complete the same evaluation budget");
    assert!(rs.ensemble.is_none(), "serial path must not report ensemble stats");
    assert!(re.ensemble.is_some());

    // quality parity: the ensemble's best configuration objective is
    // within 5% of the serial run's
    assert!(
        re.best_objective <= rs.best_objective * 1.05,
        "ensemble best {} vs serial best {}",
        re.best_objective,
        rs.best_objective
    );
    // both actually tune
    assert!(re.best_objective < re.baseline_objective);
    assert!(rs.best_objective < rs.baseline_objective);

    // wall-clock: measurably less than the serial path at 8 workers
    assert!(
        re.wallclock_s < rs.wallclock_s * 0.5,
        "ensemble wallclock {} vs serial {}",
        re.wallclock_s,
        rs.wallclock_s
    );
}

#[test]
fn killed_and_resumed_session_re_evaluates_nothing() {
    let ckpt = tmpfile("resume");
    let _ = std::fs::remove_file(&ckpt);

    let mut base = TuneSetup::new(AppKind::Swfft, PlatformKind::Theta, 64, Metric::Runtime);
    base.wallclock_budget_s = 1e9;
    base.seed = 11;
    base.ensemble_workers = 4;
    base.checkpoint_path = Some(ckpt.clone());

    // "killed" session: completes only 12 of the eventual 20 evaluations
    let mut first = base.clone();
    first.max_evals = 12;
    let ra = run(&first);
    assert_eq!(ra.evaluations, 12);
    assert!(ckpt.exists(), "checkpoint must be written");

    // resumed session: 12 restored + 8 fresh
    let mut second = base.clone();
    second.max_evals = 20;
    let rb = run(&second);
    let es = rb.ensemble.as_ref().unwrap();
    assert_eq!(es.resumed_evals, 12, "all completed evaluations restore from the checkpoint");
    assert_eq!(rb.evaluations, 20);
    for (a, b) in ra.db.records.iter().zip(rb.db.records.iter()) {
        assert_eq!(a.config_key, b.config_key, "restored record drifted");
        assert_eq!(a.objective, b.objective);
        assert_eq!(a.wallclock_s, b.wallclock_s);
    }
    // zero re-evaluation: no fresh record repeats a completed configuration
    for fresh in &rb.db.records[12..] {
        assert!(
            ra.db.records.iter().all(|r| r.config_key != fresh.config_key),
            "configuration {} was re-evaluated after resume",
            fresh.config_key
        );
    }

    // resuming a fully-complete session does no work at all
    let rc = run(&second);
    let es = rc.ensemble.as_ref().unwrap();
    assert_eq!(es.resumed_evals, 20);
    assert_eq!(es.batches, 0, "nothing left to evaluate");
    assert_eq!(rc.evaluations, 20);
    assert_eq!(rc.wallclock_s, rb.wallclock_s);

    std::fs::remove_file(&ckpt).unwrap();
}

/// Seed-for-seed parity: with a single worker there is nothing to
/// overlap, so the continuous cycle must replay the generational
/// trajectory exactly — same configurations, same measurements, same
/// best-so-far curve, bit for bit. (Host-timed fields like
/// `processing_s` are excluded: they carry real search-time jitter in
/// both modes.)
#[test]
fn continuous_single_worker_matches_generational_history() {
    let mut s = TuneSetup::new(AppKind::XSBenchHistory, PlatformKind::Theta, 1, Metric::Runtime);
    s.max_evals = 14;
    s.wallclock_budget_s = 1e9;
    s.seed = 5;
    s.n_init = 4;
    s.ensemble_workers = 1;
    let mut gen_s = s.clone();
    gen_s.manager_cycle = ManagerCycle::Generational;
    let mut cont_s = s.clone();
    cont_s.manager_cycle = ManagerCycle::Continuous;
    let rg = autotune_ensemble(&gen_s, Arc::new(Scorer::fallback())).unwrap();
    let rc = autotune_ensemble(&cont_s, Arc::new(Scorer::fallback())).unwrap();
    assert_eq!(rg.evaluations, 14);
    assert_eq!(rc.evaluations, 14);
    let history = |r: &TuneResult| {
        r.db.records
            .iter()
            .map(|x| {
                (
                    x.id,
                    x.config_key.clone(),
                    x.objective.to_bits(),
                    x.measured.runtime_s.to_bits(),
                    x.best_so_far.to_bits(),
                    x.timed_out,
                    x.cancelled,
                )
            })
            .collect::<Vec<_>>()
    };
    assert_eq!(
        history(&rg),
        history(&rc),
        "single-worker continuous must replay the generational trajectory"
    );
    assert_eq!(rg.best_objective, rc.best_objective);
}

/// The point of the continuous cycle: no worker ever waits at a batch
/// boundary while budget remains. The generational oracle reports
/// strictly positive barrier idle on the same problem; continuous
/// reports exactly zero, and does not pay for that with wall-clock.
#[test]
fn continuous_mode_eliminates_barrier_idle() {
    let mut s = TuneSetup::new(AppKind::XSBenchHistory, PlatformKind::Theta, 1, Metric::Runtime);
    s.max_evals = 24;
    s.wallclock_budget_s = 1e9;
    s.seed = 9;
    s.ensemble_workers = 4;
    let mut gen_s = s.clone();
    gen_s.manager_cycle = ManagerCycle::Generational;
    let rg = run(&gen_s);
    let rc = run(&s); // default cycle is continuous
    let ig = rg.ensemble.as_ref().unwrap().worker_idle_s;
    let ic = rc.ensemble.as_ref().unwrap().worker_idle_s;
    assert_eq!(ic, 0.0, "continuous manager must report zero idle-at-barrier gaps");
    assert!(ig > 0.0, "generational reference must show barrier idle (got {ig})");
    assert!(
        rc.wallclock_s <= rg.wallclock_s,
        "continuous wall-clock {} must not exceed generational {}",
        rc.wallclock_s,
        rg.wallclock_s
    );
}

#[test]
fn checkpoint_from_a_different_run_is_refused() {
    let ckpt = tmpfile("mismatch");
    let _ = std::fs::remove_file(&ckpt);

    let mut a = TuneSetup::new(AppKind::Swfft, PlatformKind::Theta, 64, Metric::Runtime);
    a.wallclock_budget_s = 1e9;
    a.max_evals = 8;
    a.ensemble_workers = 4;
    a.checkpoint_path = Some(ckpt.clone());
    let _ = run(&a);

    let mut b = a.clone();
    b.seed = a.seed + 1; // different run identity
    let err = autotune_with_scorer(&b, Arc::new(Scorer::fallback()));
    assert!(err.is_err(), "mismatched checkpoint must be refused");

    std::fs::remove_file(&ckpt).unwrap();
}

/// Resuming under a different *async policy* must be refused too: the
/// lies planted for in-flight points depend on the liar strategy, the
/// straggler policy, the worker/batch shape, and the manager-cycle
/// mode, so mixing observation streams across policies would silently
/// corrupt the surrogate.
#[test]
fn resume_under_a_different_async_policy_is_refused() {
    let ckpt = tmpfile("policy-mismatch");
    let _ = std::fs::remove_file(&ckpt);

    let mut a = TuneSetup::new(AppKind::Swfft, PlatformKind::Theta, 64, Metric::Runtime);
    a.wallclock_budget_s = 1e9;
    a.max_evals = 6;
    a.ensemble_workers = 4;
    a.checkpoint_path = Some(ckpt.clone());
    let _ = run(&a);

    let mutations: Vec<(&str, TuneSetup)> = vec![
        ("liar strategy", {
            let mut m = a.clone();
            m.liar = LiarStrategy::KrigingBeliever;
            m
        }),
        ("straggler factor", {
            let mut m = a.clone();
            m.straggler_factor = Some(2.0);
            m
        }),
        ("worker count", {
            let mut m = a.clone();
            m.ensemble_workers = 8;
            m
        }),
        ("ensemble batch", {
            let mut m = a.clone();
            m.ensemble_batch = 2;
            m
        }),
        ("manager cycle", {
            let mut m = a.clone();
            m.manager_cycle = ManagerCycle::Generational;
            m
        }),
    ];
    for (what, m) in mutations {
        let err = autotune_with_scorer(&m, Arc::new(Scorer::fallback()));
        assert!(err.is_err(), "resume with a different {what} must be refused");
    }

    std::fs::remove_file(&ckpt).unwrap();
}

/// A K=1 federation runs the very same `ContinuousShard` engine the
/// plain continuous manager delegates to, so its merged history must be
/// bit-identical to the single manager's — configurations, objectives,
/// measurements, best-so-far chain, flags, ids.
#[test]
fn federation_k1_matches_single_continuous_manager_bit_for_bit() {
    let mut s = TuneSetup::new(AppKind::XSBenchHistory, PlatformKind::Theta, 1, Metric::Runtime);
    s.max_evals = 16;
    s.wallclock_budget_s = 1e9;
    s.seed = 21;
    s.n_init = 4;
    s.ensemble_workers = 4;
    let single = run(&s);
    assert!(single.federation.is_none());

    let mut fed_s = s.clone();
    fed_s.federation_shards = 1;
    let fed = run(&fed_s);
    let fs = fed.federation.as_ref().expect("federated run reports federation stats");
    assert_eq!(fs.shards, 1);
    assert_eq!(fs.exchanges, 0, "one shard has nobody to exchange with");
    assert_eq!(fs.elites_absorbed, 0);

    assert_eq!(single.evaluations, 16);
    assert_eq!(fed.evaluations, 16);
    assert_eq!(
        history(&single),
        history(&fed),
        "K=1 federation must replay the single continuous manager exactly"
    );
    assert_eq!(single.best_objective.to_bits(), fed.best_objective.to_bits());
    assert_eq!(single.best_config_desc, fed.best_config_desc);
}

/// A K=3 federated run is seed-for-seed reproducible: shard RNG streams,
/// the hash partition, elite-exchange boundaries (counted in
/// completions, not host time), and the eval-id merge are all
/// deterministic, so two identical runs produce one history.
#[test]
fn federation_k3_is_seed_for_seed_reproducible() {
    let mut s = TuneSetup::new(AppKind::XSBenchHistory, PlatformKind::Theta, 1, Metric::Runtime);
    s.max_evals = 18;
    s.wallclock_budget_s = 1e9;
    s.seed = 33;
    s.n_init = 4;
    s.ensemble_workers = 2;
    s.federation_shards = 3;
    s.elite_exchange_every = 2;
    s.federation_elites = 2;

    let a = run(&s);
    let b = run(&s);
    assert_eq!(a.evaluations, 18);
    assert_eq!(history(&a), history(&b), "K=3 federation must be deterministic");
    assert_eq!(a.best_objective.to_bits(), b.best_objective.to_bits());
    // merged ids are a contiguous 0..max_evals cover (round-robin shards)
    for (i, rec) in a.db.records.iter().enumerate() {
        assert_eq!(rec.id, i);
    }
    let fa = a.federation.as_ref().unwrap();
    let fb = b.federation.as_ref().unwrap();
    assert_eq!(fa.shards, 3);
    assert_eq!(fa.per_shard_evals, vec![6, 6, 6]);
    assert_eq!(fa.exchanges, fb.exchanges);
    assert_eq!(fa.elites_absorbed, fb.elites_absorbed);
    assert!(fa.exchanges > 0, "18 evals at exchange-every-2 must hit exchange boundaries");
}

/// The K=3 mid-trajectory resume contract, upgraded from PR 3's "exact
/// re-queue" equality to full bit-identity: kill the whole federation
/// mid-run (simulated SIGKILL right after a checkpointed apply, under
/// deterministic fault injection), resume, and the merged history —
/// including every *fresh post-resume proposal*, not just the re-queued
/// in-flight work — equals the uninterrupted run's, seed for seed. This
/// is what the persisted proposal state (RNG stream position + strategy
/// event log + absorbed-elite dedup set) buys: each shard replays its
/// log, continues its stream, and re-joins the absolute exchange
/// schedule exactly where the uninterrupted run would be.
///
/// Both kill parities are exercised: a kill at 3 applies persists the
/// round-1 foreign absorptions in the log (replayed at resume, deduped
/// at the next boundary), while a kill at 2 applies loses them to the
/// crash (the exchange fires after the apply-2 checkpoint) and the
/// resumed shard must re-absorb the identical elites at the identical
/// boundary from its peers' history prefixes.
#[test]
fn federated_mid_trajectory_resume_is_bit_identical() {
    let ckpt = tmpfile("fed-midtraj");
    let shard_files: Vec<PathBuf> = (0..3usize).map(|k| shard_checkpoint_path(&ckpt, k)).collect();

    let mut s = TuneSetup::new(AppKind::Swfft, PlatformKind::Theta, 64, Metric::Runtime);
    s.max_evals = 18;
    s.wallclock_budget_s = 1e9;
    s.seed = 47;
    s.n_init = 4;
    s.ensemble_workers = 2;
    s.fault_rate = 0.3;
    s.max_retries = 3;
    s.federation_shards = 3;
    s.elite_exchange_every = 2;
    s.federation_elites = 2;

    // the uninterrupted reference: no checkpointing at all
    let full = run(&s);
    assert_eq!(full.evaluations, 18);
    assert!(
        full.ensemble.as_ref().unwrap().faults > 0,
        "30% fault injection must fire somewhere in 18 evaluations"
    );

    for kill_after in [3usize, 2] {
        let _ = std::fs::remove_file(&ckpt);
        for f in &shard_files {
            let _ = std::fs::remove_file(f);
        }

        // the killed campaign: every shard dies right after its
        // `kill_after`-th checkpointed apply, in-flight work outstanding
        let mut killed = s.clone();
        killed.checkpoint_path = Some(ckpt.clone());
        killed.kill_after_evals = Some(kill_after);
        let partial = run(&killed);
        assert_eq!(
            partial.evaluations,
            3 * kill_after,
            "3 shards x {kill_after} applies before the kill"
        );
        assert!(ckpt.exists(), "federation manifest must be written");
        for f in &shard_files {
            assert!(f.exists(), "every shard must checkpoint ({})", f.display());
        }
        // the killed prefix is the uninterrupted prefix (shard k owns
        // ids k, k+3, …, so the first `kill_after` applies per shard
        // merge into the contiguous ids 0..3*kill_after)
        assert_eq!(
            history(&full)[..3 * kill_after].to_vec(),
            history(&partial),
            "killed campaign must record exactly the uninterrupted prefix"
        );

        // resume without the kill: each shard still owes fresh proposals
        // beyond the re-queued in-flight work, and those must continue
        // the interrupted trajectory exactly
        let mut resumed = s.clone();
        resumed.checkpoint_path = Some(ckpt.clone());
        let r = run(&resumed);
        assert_eq!(r.evaluations, 18);
        let es = r.ensemble.as_ref().unwrap();
        assert_eq!(es.resumed_evals, 3 * kill_after);
        assert_eq!(
            history(&full),
            history(&r),
            "kill at {kill_after}: mid-trajectory resume must be bit-identical \
             (fresh post-resume proposals included)"
        );
        assert_eq!(full.best_objective.to_bits(), r.best_objective.to_bits());
    }

    std::fs::remove_file(&ckpt).unwrap();
    for f in &shard_files {
        std::fs::remove_file(f).unwrap();
    }
}

/// Resuming a federated campaign under a different federation policy —
/// shard count, exchange period, or elite width — must be refused: the
/// shard count decides every manager's partition and global eval ids,
/// and the exchange schedule decides when foreign observations enter
/// each surrogate. The manifest (and every shard fingerprint) pins all
/// three.
#[test]
fn federated_resume_under_a_different_policy_is_refused() {
    let ckpt = tmpfile("fed-policy");
    let shard_files: Vec<PathBuf> = (0..2usize).map(|k| shard_checkpoint_path(&ckpt, k)).collect();
    let _ = std::fs::remove_file(&ckpt);
    for f in &shard_files {
        let _ = std::fs::remove_file(f);
    }

    let mut a = TuneSetup::new(AppKind::Swfft, PlatformKind::Theta, 64, Metric::Runtime);
    a.wallclock_budget_s = 1e9;
    a.max_evals = 8;
    a.ensemble_workers = 2;
    a.federation_shards = 2;
    a.checkpoint_path = Some(ckpt.clone());
    let _ = run(&a);

    let mutations: Vec<(&str, TuneSetup)> = vec![
        ("shard count", {
            let mut m = a.clone();
            m.federation_shards = 3;
            m
        }),
        ("exchange period", {
            let mut m = a.clone();
            m.elite_exchange_every = 5;
            m
        }),
        ("elite width", {
            let mut m = a.clone();
            m.federation_elites = 9;
            m
        }),
    ];
    for (what, m) in mutations {
        let err = autotune_with_scorer(&m, Arc::new(Scorer::fallback()));
        assert!(err.is_err(), "resume with a different {what} must be refused");
    }
    // handing the federation manifest to the single-manager path is
    // refused too (it is not a shard checkpoint)
    let mut plain = a.clone();
    plain.federation_shards = 0;
    assert!(autotune_with_scorer(&plain, Arc::new(Scorer::fallback())).is_err());

    std::fs::remove_file(&ckpt).unwrap();
    for f in &shard_files {
        std::fs::remove_file(f).unwrap();
    }
}

/// The kriging believer now reuses the epoch-cached surrogate (one fit
/// per completion instead of a throwaway forest per in-flight lie):
/// the full continuous-manager engine must stay seed-for-seed
/// deterministic under it, with real worker-pool interleavings, and
/// still tune.
#[test]
fn kriging_believer_continuous_runs_are_deterministic_with_believer_reuse() {
    let mut s = TuneSetup::new(AppKind::XSBenchHistory, PlatformKind::Theta, 1, Metric::Runtime);
    s.max_evals = 24;
    s.wallclock_budget_s = 1e9;
    s.seed = 19;
    s.ensemble_workers = 6;
    s.liar = LiarStrategy::KrigingBeliever;
    let a = run(&s);
    let b = run(&s);
    assert_eq!(a.evaluations, 24);
    assert_eq!(history(&a), history(&b), "believer reuse broke seed-for-seed determinism");
    assert_eq!(a.best_objective.to_bits(), b.best_objective.to_bits());
    assert!(
        a.best_objective < a.baseline_objective * 1.05,
        "believer run went backwards: {} vs baseline {}",
        a.best_objective,
        a.baseline_objective
    );
}

#[test]
fn liar_strategies_all_reach_comparable_quality() {
    let mut setup = TuneSetup::new(AppKind::XSBenchHistory, PlatformKind::Theta, 1, Metric::Runtime);
    setup.max_evals = 32;
    setup.wallclock_budget_s = 1e9;
    setup.seed = 3;
    setup.ensemble_workers = 4;
    let mut bests = Vec::new();
    for liar in [
        LiarStrategy::ConstantMin,
        LiarStrategy::ConstantMean,
        LiarStrategy::ConstantMax,
        LiarStrategy::KrigingBeliever,
    ] {
        let mut s = setup.clone();
        s.liar = liar;
        let r = run(&s);
        assert_eq!(r.evaluations, 32, "{liar:?}");
        assert!(r.best_objective < r.baseline_objective, "{liar:?} failed to tune");
        bests.push(r.best_objective);
    }
    // no strategy collapses: all within 15% of the group's best
    let lo = bests.iter().cloned().fold(f64::INFINITY, f64::min);
    for (i, b) in bests.iter().enumerate() {
        assert!(*b <= lo * 1.15, "liar #{i} best {b} vs group best {lo}");
    }
}
