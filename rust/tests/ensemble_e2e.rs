//! End-to-end ensemble-engine tests: tuning-quality parity with the
//! serial loop, wall-clock compression at the same evaluation budget,
//! and checkpoint resume with zero re-evaluation.

use std::path::PathBuf;
use std::sync::Arc;

use ytopt::apps::AppKind;
use ytopt::coordinator::{autotune_with_scorer, TuneResult, TuneSetup};
use ytopt::metrics::Metric;
use ytopt::platform::PlatformKind;
use ytopt::runtime::Scorer;

fn run(setup: &TuneSetup) -> TuneResult {
    autotune_with_scorer(setup, Arc::new(Scorer::fallback())).unwrap()
}

fn tmpfile(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("ytopt-e2e-{tag}-{}.json", std::process::id()))
}

#[test]
fn ensemble_matches_serial_quality_in_less_wallclock() {
    // the acceptance setting: 8 workers, same evaluation budget, XSBench
    let mut serial = TuneSetup::new(AppKind::XSBenchHistory, PlatformKind::Theta, 1, Metric::Runtime);
    serial.max_evals = 48;
    serial.wallclock_budget_s = 1e9;
    serial.seed = 7;
    let mut ensemble = serial.clone();
    ensemble.ensemble_workers = 8;

    let rs = run(&serial);
    let re = run(&ensemble);

    assert_eq!(rs.evaluations, 48);
    assert_eq!(re.evaluations, 48, "ensemble must complete the same evaluation budget");
    assert!(rs.ensemble.is_none(), "serial path must not report ensemble stats");
    assert!(re.ensemble.is_some());

    // quality parity: the ensemble's best configuration objective is
    // within 5% of the serial run's
    assert!(
        re.best_objective <= rs.best_objective * 1.05,
        "ensemble best {} vs serial best {}",
        re.best_objective,
        rs.best_objective
    );
    // both actually tune
    assert!(re.best_objective < re.baseline_objective);
    assert!(rs.best_objective < rs.baseline_objective);

    // wall-clock: measurably less than the serial path at 8 workers
    assert!(
        re.wallclock_s < rs.wallclock_s * 0.5,
        "ensemble wallclock {} vs serial {}",
        re.wallclock_s,
        rs.wallclock_s
    );
}

#[test]
fn killed_and_resumed_session_re_evaluates_nothing() {
    let ckpt = tmpfile("resume");
    let _ = std::fs::remove_file(&ckpt);

    let mut base = TuneSetup::new(AppKind::Swfft, PlatformKind::Theta, 64, Metric::Runtime);
    base.wallclock_budget_s = 1e9;
    base.seed = 11;
    base.ensemble_workers = 4;
    base.checkpoint_path = Some(ckpt.clone());

    // "killed" session: completes only 12 of the eventual 20 evaluations
    let mut first = base.clone();
    first.max_evals = 12;
    let ra = run(&first);
    assert_eq!(ra.evaluations, 12);
    assert!(ckpt.exists(), "checkpoint must be written");

    // resumed session: 12 restored + 8 fresh
    let mut second = base.clone();
    second.max_evals = 20;
    let rb = run(&second);
    let es = rb.ensemble.as_ref().unwrap();
    assert_eq!(es.resumed_evals, 12, "all completed evaluations restore from the checkpoint");
    assert_eq!(rb.evaluations, 20);
    for (a, b) in ra.db.records.iter().zip(rb.db.records.iter()) {
        assert_eq!(a.config_key, b.config_key, "restored record drifted");
        assert_eq!(a.objective, b.objective);
        assert_eq!(a.wallclock_s, b.wallclock_s);
    }
    // zero re-evaluation: no fresh record repeats a completed configuration
    for fresh in &rb.db.records[12..] {
        assert!(
            ra.db.records.iter().all(|r| r.config_key != fresh.config_key),
            "configuration {} was re-evaluated after resume",
            fresh.config_key
        );
    }

    // resuming a fully-complete session does no work at all
    let rc = run(&second);
    let es = rc.ensemble.as_ref().unwrap();
    assert_eq!(es.resumed_evals, 20);
    assert_eq!(es.batches, 0, "nothing left to evaluate");
    assert_eq!(rc.evaluations, 20);
    assert_eq!(rc.wallclock_s, rb.wallclock_s);

    std::fs::remove_file(&ckpt).unwrap();
}

#[test]
fn checkpoint_from_a_different_run_is_refused() {
    let ckpt = tmpfile("mismatch");
    let _ = std::fs::remove_file(&ckpt);

    let mut a = TuneSetup::new(AppKind::Swfft, PlatformKind::Theta, 64, Metric::Runtime);
    a.wallclock_budget_s = 1e9;
    a.max_evals = 8;
    a.ensemble_workers = 4;
    a.checkpoint_path = Some(ckpt.clone());
    let _ = run(&a);

    let mut b = a.clone();
    b.seed = a.seed + 1; // different run identity
    let err = autotune_with_scorer(&b, Arc::new(Scorer::fallback()));
    assert!(err.is_err(), "mismatched checkpoint must be refused");

    std::fs::remove_file(&ckpt).unwrap();
}

#[test]
fn liar_strategies_all_reach_comparable_quality() {
    use ytopt::ensemble::LiarStrategy;
    let mut setup = TuneSetup::new(AppKind::XSBenchHistory, PlatformKind::Theta, 1, Metric::Runtime);
    setup.max_evals = 32;
    setup.wallclock_budget_s = 1e9;
    setup.seed = 3;
    setup.ensemble_workers = 4;
    let mut bests = Vec::new();
    for liar in [
        LiarStrategy::ConstantMin,
        LiarStrategy::ConstantMean,
        LiarStrategy::ConstantMax,
        LiarStrategy::KrigingBeliever,
    ] {
        let mut s = setup.clone();
        s.liar = liar;
        let r = run(&s);
        assert_eq!(r.evaluations, 32, "{liar:?}");
        assert!(r.best_objective < r.baseline_objective, "{liar:?} failed to tune");
        bests.push(r.best_objective);
    }
    // no strategy collapses: all within 15% of the group's best
    let lo = bests.iter().cloned().fold(f64::INFINITY, f64::min);
    for (i, b) in bests.iter().enumerate() {
        assert!(*b <= lo * 1.15, "liar #{i} best {b} vs group best {lo}");
    }
}
