//! Property tests over the service wire protocol (ISSUE 6 satellite):
//! the codec in `service::protocol` is pure — `encode_frame` /
//! `decode_frame` / `Decoder` work on byte slices with no I/O — so every
//! framing invariant is checkable over generated inputs:
//!
//! * encode → decode is the identity for every message shape;
//! * the [`Decoder`] reassembles frames from arbitrary chunkings of the
//!   byte stream (partial reads are invisible to the caller);
//! * every strict prefix of a valid frame is `Ok(None)`, never an error;
//! * junk — bad magic, foreign versions, unknown kinds, oversized
//!   lengths, arbitrary byte soup — is rejected with a typed error and
//!   never panics or allocates a hostile payload.

use ytopt::proptest_lite::for_all;
use ytopt::service::protocol::{
    decode_frame, encode_frame, CampaignSpec, CampaignStatusInfo, CampaignSummary, Decoder, Event,
    Message, ProtocolError, Request, Response, FRAME_HEADER_BYTES, MAX_FRAME_BYTES,
    PROTOCOL_VERSION,
};
use ytopt::util::Pcg32;

// ---------------------------------------------------------------------------
// generators

/// Strings exercising the JSON escaper: quotes, backslashes, control
/// characters, multi-byte UTF-8.
fn rand_string(rng: &mut Pcg32) -> String {
    const CHARS: &[char] =
        &['a', 'Z', '7', ',', '=', '-', '_', ' ', '"', '\\', '\n', '\t', '/', 'é', '∞'];
    let len = rng.index(14);
    (0..len).map(|_| CHARS[rng.index(CHARS.len())]).collect()
}

/// Ids stay under 2^53: they travel as JSON numbers (f64), so anything
/// wider cannot round-trip — only the `seed` field carries full-width
/// u64s (as hex strings).
fn rand_id(rng: &mut Pcg32) -> u64 {
    rng.gen_range(1 << 53)
}

/// Any finite f64 — including subnormals and huge magnitudes — from raw
/// bit patterns. Finite values round-trip exactly through the writer's
/// shortest-display formatting; non-finite ones intentionally do not
/// (they write as `null`), so they get their own test below.
fn rand_finite(rng: &mut Pcg32) -> f64 {
    loop {
        let v = f64::from_bits(rng.next_u64());
        if v.is_finite() {
            return v;
        }
    }
}

fn rand_spec(rng: &mut Pcg32) -> CampaignSpec {
    CampaignSpec {
        app: rand_string(rng),
        platform: rand_string(rng),
        nodes: rand_id(rng),
        metric: rand_string(rng),
        max_evals: rng.index(1 << 20),
        wallclock_budget_s: rand_finite(rng),
        seed: rng.next_u64(), // full width: travels as a hex string
        strategy: rand_string(rng),
        surrogate: rand_string(rng),
        kappa: rand_finite(rng),
        n_init: rng.index(1 << 16),
        workers: rng.index(64),
        batch: rng.index(64),
        liar: rand_string(rng),
        fault_rate: rand_finite(rng),
        max_retries: rng.index(16),
        straggler_factor: if rng.bool(0.5) { Some(rand_finite(rng)) } else { None },
        eval_timeout_s: if rng.bool(0.5) { Some(rand_finite(rng)) } else { None },
        warm_start: rng.bool(0.5),
    }
}

fn rand_summary(rng: &mut Pcg32) -> CampaignSummary {
    CampaignSummary {
        evaluations: rand_id(rng),
        baseline_objective: rand_finite(rng),
        best_objective: rand_finite(rng),
        best_config_desc: rand_string(rng),
        improvement_pct: rand_finite(rng),
        wallclock_s: rand_finite(rng),
    }
}

fn rand_status(rng: &mut Pcg32) -> CampaignStatusInfo {
    CampaignStatusInfo {
        id: rand_id(rng),
        state: rand_string(rng),
        app: rand_string(rng),
        seed: rng.next_u64(),
        evaluations: rand_id(rng),
        best_objective: rand_finite(rng),
    }
}

/// One message drawn across all three frame families and every variant.
fn rand_message(rng: &mut Pcg32) -> Message {
    match rng.index(18) {
        0 => Message::Request(Request::Ping),
        1 => Message::Request(Request::Submit { spec: rand_spec(rng) }),
        2 => Message::Request(Request::Watch { campaign: rand_id(rng), from: rand_id(rng) }),
        3 => Message::Request(Request::Status),
        4 => Message::Request(Request::Cancel { campaign: rand_id(rng) }),
        5 => Message::Request(Request::Shutdown),
        6 => Message::Response(Response::Pong),
        7 => Message::Response(Response::Accepted { campaign: rand_id(rng) }),
        8 => {
            let n = rng.index(4);
            let campaigns = (0..n).map(|_| rand_status(rng)).collect();
            Message::Response(Response::Status { campaigns })
        }
        9 => Message::Response(Response::Cancelling { campaign: rand_id(rng) }),
        10 => Message::Response(Response::Error { message: rand_string(rng) }),
        11 => Message::Event(Event::Started {
            campaign: rand_id(rng),
            evals_planned: rand_id(rng),
        }),
        12 => Message::Event(Event::WarmStarted { campaign: rand_id(rng), elites: rand_id(rng) }),
        13 => Message::Event(Event::Proposed { campaign: rand_id(rng), eval_id: rand_id(rng) }),
        14 => Message::Event(Event::EvalCompleted {
            campaign: rand_id(rng),
            eval_id: rand_id(rng),
            config_key: rand_string(rng),
            objective: rand_finite(rng),
            runtime_s: rand_finite(rng),
            best_so_far: rand_finite(rng),
            timed_out: rng.bool(0.5),
            cancelled: rng.bool(0.5),
        }),
        15 => Message::Event(Event::Improved {
            campaign: rand_id(rng),
            eval_id: rand_id(rng),
            best_objective: rand_finite(rng),
            config_desc: rand_string(rng),
        }),
        16 => Message::Event(Event::Done { campaign: rand_id(rng), summary: rand_summary(rng) }),
        _ => match rng.index(4) {
            0 => Message::Event(Event::StragglerKilled {
                campaign: rand_id(rng),
                eval_id: rand_id(rng),
            }),
            1 => Message::Event(Event::Cancelled { campaign: rand_id(rng), applied: rand_id(rng) }),
            2 => Message::Event(Event::Interrupted {
                campaign: rand_id(rng),
                applied: rand_id(rng),
                checkpointed: rng.bool(0.5),
            }),
            _ => Message::Event(Event::Failed { campaign: rand_id(rng), message: rand_string(rng) }),
        },
    }
}

// ---------------------------------------------------------------------------
// properties

#[test]
fn prop_encode_decode_is_identity() {
    for_all(
        "decode(encode(msg)) == msg, consuming the whole frame",
        400,
        101,
        rand_message,
        |msg| match decode_frame(&encode_frame(msg)) {
            Ok(Some((back, used))) => back == *msg && used == encode_frame(msg).len(),
            _ => false,
        },
    );
}

#[test]
fn prop_every_frame_prefix_is_a_valid_prefix() {
    for_all(
        "strict prefixes decode to Ok(None), never an error",
        120,
        103,
        |rng| {
            let frame = encode_frame(&rand_message(rng));
            let cut = rng.index(frame.len());
            (frame, cut)
        },
        |(frame, cut)| matches!(decode_frame(&frame[..*cut]), Ok(None)),
    );
}

#[test]
fn prop_decoder_reassembles_any_chunking() {
    for_all(
        "random chunk splits reassemble the exact message sequence",
        150,
        107,
        |rng| {
            let msgs: Vec<Message> = (0..1 + rng.index(5)).map(|_| rand_message(rng)).collect();
            let mut wire = Vec::new();
            for m in &msgs {
                wire.extend_from_slice(&encode_frame(m));
            }
            // cut the stream at random points, including empty chunks
            let mut chunks = Vec::new();
            let mut at = 0usize;
            while at < wire.len() {
                let take = rng.index(40); // 0..39 bytes, empty pushes allowed
                let end = (at + take).min(wire.len());
                chunks.push(wire[at..end].to_vec());
                at = end;
                if take == 0 {
                    chunks.push(Vec::new());
                    at = (at + 1).min(wire.len());
                    chunks.push(wire[end..at].to_vec());
                }
            }
            (msgs, chunks)
        },
        |(msgs, chunks)| {
            let mut dec = Decoder::new();
            let mut got = Vec::new();
            for c in chunks {
                match dec.push(c) {
                    Ok(ms) => got.extend(ms),
                    Err(_) => return false,
                }
            }
            got == *msgs && dec.pending() == 0
        },
    );
}

#[test]
fn prop_non_yt_bytes_are_rejected_at_the_first_byte() {
    for_all(
        "any stream not starting with 'Y' is BadMagic, not a panic",
        200,
        109,
        |rng| {
            let len = 1 + rng.index(32);
            let mut bytes: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            if bytes[0] == b'Y' {
                bytes[0] = b'X';
            }
            bytes
        },
        |bytes| matches!(decode_frame(bytes), Err(ProtocolError::BadMagic(_))),
    );
}

#[test]
fn prop_foreign_versions_kinds_and_lengths_are_rejected() {
    for_all(
        "version/kind/length rejection happens before any payload is trusted",
        200,
        113,
        |rng| {
            let version = loop {
                let v = rng.next_u64() as u8;
                if v != PROTOCOL_VERSION {
                    break v;
                }
            };
            let kind = loop {
                let k = rng.next_u64() as u8;
                if !(1..=3).contains(&k) {
                    break k;
                }
            };
            let oversize = MAX_FRAME_BYTES as u32 + 1 + rng.gen_range(1 << 30) as u32;
            (version, kind, oversize)
        },
        |&(version, kind, oversize)| {
            let bad_version = [b'Y', b'T', version, 1];
            let bad_kind = [b'Y', b'T', PROTOCOL_VERSION, kind];
            let mut oversized = vec![b'Y', b'T', PROTOCOL_VERSION, 1];
            oversized.extend_from_slice(&oversize.to_be_bytes());
            // rejection identifies the offending byte, and header-only
            // rejections consume no payload
            matches!(decode_frame(&bad_version), Err(ProtocolError::BadVersion(v)) if v == version)
                && matches!(decode_frame(&bad_kind), Err(ProtocolError::BadKind(k)) if k == kind)
                && matches!(
                    decode_frame(&oversized),
                    Err(ProtocolError::Oversized(n)) if n == oversize as usize
                )
                && oversized.len() == FRAME_HEADER_BYTES
        },
    );
}

#[test]
fn prop_decoder_survives_byte_soup_and_recovers_after_reset() {
    for_all(
        "arbitrary soup never panics; a poisoned decoder is clean for reuse",
        150,
        127,
        |rng| {
            let len = rng.index(96);
            let soup: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            soup
        },
        |soup| {
            let mut dec = Decoder::new();
            match dec.push(soup) {
                // decoded or still-buffering: pending is bounded by input
                Ok(_) => dec.pending() <= soup.len(),
                // poisoned: the buffer must be dropped so the connection
                // handler can close without dragging junk around…
                Err(_) => {
                    if dec.pending() != 0 {
                        return false;
                    }
                    // …and a fresh valid frame still decodes
                    let ping = encode_frame(&Message::Request(Request::Ping));
                    matches!(
                        dec.push(&ping).as_deref(),
                        Ok([Message::Request(Request::Ping)])
                    )
                }
            }
        },
    );
}

/// Non-finite objectives are the one deliberate non-identity: JSON has
/// no Inf/NaN, so they travel as `null` and read back as `+inf` — the
/// same convention the checkpoint format uses for "no objective yet".
#[test]
fn non_finite_objectives_normalize_to_positive_infinity() {
    for bad in [f64::INFINITY, f64::NEG_INFINITY, f64::NAN] {
        let msg = Message::Event(Event::EvalCompleted {
            campaign: 1,
            eval_id: 2,
            config_key: "0,0".into(),
            objective: bad,
            runtime_s: 1.5,
            best_so_far: bad,
            timed_out: false,
            cancelled: false,
        });
        let (back, _) = decode_frame(&encode_frame(&msg)).unwrap().unwrap();
        match back {
            Message::Event(Event::EvalCompleted { objective, best_so_far, runtime_s, .. }) => {
                assert_eq!(objective, f64::INFINITY);
                assert_eq!(best_so_far, f64::INFINITY);
                assert_eq!(runtime_s, 1.5);
            }
            other => panic!("wrong shape back: {other:?}"),
        }
    }
}

/// A frame followed by trailing garbage: the frame decodes, the garbage
/// poisons the stream only when the decoder reaches it.
#[test]
fn valid_frame_then_junk_decodes_the_frame_first() {
    let msg = Message::Response(Response::Accepted { campaign: 7 });
    let mut wire = encode_frame(&msg);
    wire.extend_from_slice(b"not a frame");
    let mut dec = Decoder::new();
    let err = dec.push(&wire).unwrap_err();
    assert!(matches!(err, ProtocolError::BadMagic(_)));
    // the error reports the junk, but the decoder surfaced nothing of the
    // valid frame — by contract an errored push drops the whole buffer,
    // so feed the frame alone to get it
    let mut dec2 = Decoder::new();
    assert_eq!(dec2.push(&encode_frame(&msg)).unwrap(), vec![msg]);
}
