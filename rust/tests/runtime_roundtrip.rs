//! Integration: the AOT HLO artifacts, executed through the PJRT CPU
//! client, must match the pure-Rust reference semantics and the fitted
//! forest itself. This is the rust half of the interchange contract
//! (python/tests/test_aot.py is the python half).

use ytopt::runtime::{energy_reduce_cpu, forest_score_cpu, Scorer};
use ytopt::surrogate::{export_forest, ForestConfig, RandomForest};
use ytopt::util::Pcg32;

fn load_scorer() -> Option<Scorer> {
    let dir = ytopt::runtime::default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return None;
    }
    let s = Scorer::auto(&dir);
    assert!(s.is_accelerated(), "artifacts exist but XLA runtime failed to load");
    Some(s)
}

fn fitted_forest(seed: u64, dim: usize, n: usize) -> RandomForest {
    let mut rng = Pcg32::seeded(seed);
    let mut x = Vec::with_capacity(n * dim);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let row: Vec<f32> = (0..dim).map(|_| rng.f32()).collect();
        y.push(row[0] * 3.0 + (row[1] * 9.0).sin() - row[dim - 1]);
        x.extend(row);
    }
    RandomForest::fit(&x, &y, dim, &ForestConfig::default(), &mut rng)
}

#[test]
fn forest_scorer_xla_matches_cpu_and_forest() {
    let Some(scorer) = load_scorer() else { return };
    let m = scorer.manifest().forest.clone();
    let dim = 9; // a paper-space-sized dimensionality
    let rf = fitted_forest(42, dim, 180);
    let tensors =
        export_forest(&rf, m.trees, m.nodes_per_tree, m.features, m.depth).unwrap();

    // padded candidate rows
    let mut rng = Pcg32::seeded(7);
    let n = 300; // forces a second (partial) batch on the XLA path
    let mut rows = vec![0.0f32; n * m.features];
    for i in 0..n {
        for j in 0..dim {
            rows[i * m.features + j] = rng.f32();
        }
    }
    let kappa = 1.96f32;
    let xla = scorer.score_candidates(&rows, n, &tensors, kappa).unwrap();
    let cpu = forest_score_cpu(&rows, m.features, &tensors, kappa);
    assert_eq!(xla.mean.len(), n);
    for i in 0..n {
        assert!((xla.mean[i] - cpu.mean[i]).abs() < 1e-4, "mean[{i}]");
        assert!((xla.std[i] - cpu.std[i]).abs() < 1e-4, "std[{i}]");
        assert!((xla.lcb[i] - cpu.lcb[i]).abs() < 3e-4, "lcb[{i}]");
    }
    // ... and the forest itself agrees
    for i in 0..20 {
        let row: Vec<f32> = rows[i * m.features..i * m.features + dim].to_vec();
        let (mean, std) = rf.predict_one(&row);
        assert!((xla.mean[i] - mean).abs() < 1e-4);
        assert!((xla.std[i] - std).abs() < 1e-3);
    }
}

#[test]
fn energy_reduce_xla_matches_cpu() {
    let Some(scorer) = load_scorer() else { return };
    let nodes = 1024usize;
    let samples = 96usize;
    let valid = 61usize;
    let mut rng = Pcg32::seeded(9);
    let mut pkg = vec![0.0f32; nodes * samples];
    let mut dram = vec![0.0f32; nodes * samples];
    for i in 0..nodes {
        for j in 0..valid {
            pkg[i * samples + j] = 80.0 + 160.0 * rng.f32();
            dram[i * samples + j] = 4.0 + 24.0 * rng.f32();
        }
    }
    let (dt, runtime) = (0.5f32, 30.25f32);
    let (node_x, avg_x, edp_x) = scorer
        .reduce_energy(&pkg, &dram, nodes, samples, valid as f32, dt, runtime)
        .unwrap();
    let active = vec![1.0f32; nodes];
    let (node_c, avg_c, edp_c) =
        energy_reduce_cpu(&pkg, &dram, &active, samples, valid as f32, dt, runtime);
    assert_eq!(node_x.len(), nodes);
    for i in 0..nodes {
        assert!(
            (node_x[i] - node_c[i]).abs() < node_c[i].abs() * 1e-4 + 1e-2,
            "node {i}: {} vs {}",
            node_x[i],
            node_c[i]
        );
    }
    assert!((avg_x - avg_c).abs() < avg_c * 1e-4 + 1e-2, "{avg_x} vs {avg_c}");
    assert!((edp_x - edp_c).abs() < edp_c * 1e-4 + 1.0, "{edp_x} vs {edp_c}");
}

#[test]
fn kappa_zero_lcb_equals_mean_through_xla() {
    let Some(scorer) = load_scorer() else { return };
    let m = scorer.manifest().forest.clone();
    let rf = fitted_forest(5, 4, 60);
    let tensors =
        export_forest(&rf, m.trees, m.nodes_per_tree, m.features, m.depth).unwrap();
    let rows = vec![0.25f32; 8 * m.features];
    let out = scorer.score_candidates(&rows, 8, &tensors, 0.0).unwrap();
    for i in 0..8 {
        assert!((out.lcb[i] - out.mean[i]).abs() < 1e-6);
    }
}
