//! End-to-end tuning-as-a-service tests (ISSUE 6 acceptance): a real
//! daemon on a loopback socket, driven through the framed client.
//!
//! * K=3 concurrent daemon campaigns, each bit-identical to the solo
//!   CLI-path run (`autotune_with_scorer`) with the same seed/policy —
//!   co-scheduling must not perturb any campaign's trajectory.
//! * A fourth campaign is cancelled mid-run: terminal `Cancelled` with
//!   a partial applied prefix, and no history record for the partial run.
//! * A compatible follow-up campaign auto-warm-starts from the finished
//!   campaigns' elites in the daemon's shared history store — no flag
//!   beyond the shared directory — and its trajectory equals the solo
//!   run with the same warm-start store pinned explicitly.
//! * Graceful shutdown: a `Shutdown` request interrupts the running
//!   campaign at an apply boundary; its watcher receives a terminal
//!   `Interrupted` frame (not a dropped socket), the v3 checkpoint is on
//!   disk, and new submissions are refused.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use ytopt::coordinator::{autotune_with_scorer, TuneResult};
use ytopt::runtime::Scorer;
use ytopt::service::{CampaignSpec, Client, Daemon, Event, ServeConfig, ServiceConfig};

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("ytopt-svc-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// The host-timing-free digest of a trajectory (the `ensemble_e2e`
/// convention): everything that must be bit-identical across
/// deterministic replays, whether it arrived over the wire or from an
/// in-process run.
type Digest = Vec<(u64, String, u64, u64, u64, bool, bool)>;

fn digest_result(r: &TuneResult) -> Digest {
    r.db.records
        .iter()
        .map(|x| {
            (
                x.id as u64,
                x.config_key.clone(),
                x.objective.to_bits(),
                x.measured.runtime_s.to_bits(),
                x.best_so_far.to_bits(),
                x.timed_out,
                x.cancelled,
            )
        })
        .collect()
}

fn digest_events(events: &[Event]) -> Digest {
    events
        .iter()
        .filter_map(|ev| match ev {
            Event::EvalCompleted {
                eval_id,
                config_key,
                objective,
                runtime_s,
                best_so_far,
                timed_out,
                cancelled,
                ..
            } => Some((
                *eval_id,
                config_key.clone(),
                objective.to_bits(),
                runtime_s.to_bits(),
                best_so_far.to_bits(),
                *timed_out,
                *cancelled,
            )),
            _ => None,
        })
        .collect()
}

/// Watch a campaign from event 0, returning (full event log, terminal).
fn watch_all(client: &mut Client, campaign: u64) -> (Vec<Event>, Event) {
    let mut log = Vec::new();
    let terminal = client
        .watch(campaign, 0, &mut |ev| log.push(ev.clone()))
        .expect("watch stream must end in a terminal event");
    (log, terminal)
}

/// Poll `status` until `campaign` reports at least `want` applied
/// evaluations (bounded wait — campaigns make continuous progress).
fn wait_for_evals(client: &mut Client, campaign: u64, want: u64) {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let rows = client.status().unwrap();
        let row = rows.iter().find(|r| r.id == campaign).expect("campaign listed in status");
        if row.evaluations >= want {
            return;
        }
        assert!(
            Instant::now() < deadline,
            "campaign {campaign} stuck at {} evaluations (wanted {want})",
            row.evaluations
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

fn history_record_count(dir: &PathBuf) -> usize {
    std::fs::read_dir(dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .filter(|e| {
            let name = e.file_name();
            let name = name.to_string_lossy();
            name.starts_with("run-") && name.ends_with(".json")
        })
        .count()
}

#[test]
fn concurrent_daemon_campaigns_match_solo_runs_cancel_and_warm_start() {
    let hist = tmpdir("hist");
    let ckpt = tmpdir("ckpt");
    let daemon = Daemon::start(
        ServeConfig {
            listen: "127.0.0.1:0".into(),
            service: ServiceConfig {
                max_active: 4,
                history_dir: Some(hist.clone()),
                checkpoint_dir: Some(ckpt.clone()),
                warm_start_elites: 8,
            },
            chaos: None,
        },
        Arc::new(Scorer::fallback()),
    )
    .unwrap();
    let addr = daemon.addr().to_string();
    let mut client = Client::connect(&addr).unwrap();
    client.ping().unwrap();

    // three concurrent campaigns with distinct seeds and policies;
    // warm_start off so each solo reference is exactly reproducible
    // regardless of which neighbour finishes (and appends) first
    let parity_specs: Vec<CampaignSpec> = [(1001u64, 2usize, "cl-min"), (2002, 3, "cl-mean"), (3003, 4, "kriging")]
        .iter()
        .map(|&(seed, workers, liar)| CampaignSpec {
            seed,
            workers,
            liar: liar.into(),
            max_evals: 12,
            wallclock_budget_s: 1e9,
            warm_start: false,
            ..CampaignSpec::default()
        })
        .collect();
    let parity_ids: Vec<u64> =
        parity_specs.iter().map(|s| client.submit(s.clone()).unwrap()).collect();

    // a long fourth campaign, to be cancelled mid-run (random strategy:
    // proposal cost stays flat over a long horizon)
    let cancel_spec = CampaignSpec {
        seed: 4004,
        workers: 2,
        strategy: "random".into(),
        max_evals: 20_000,
        wallclock_budget_s: 1e9,
        warm_start: false,
        ..CampaignSpec::default()
    };
    let cancel_id = client.submit(cancel_spec).unwrap();

    // all four are now co-scheduled (max_active = 4); cancel the long
    // one once it has visibly made progress
    wait_for_evals(&mut client, cancel_id, 2);
    client.cancel(cancel_id).unwrap();

    let (cancel_log, cancel_terminal) = watch_all(&mut client, cancel_id);
    match cancel_terminal {
        Event::Cancelled { campaign, applied } => {
            assert_eq!(campaign, cancel_id);
            assert!(applied >= 2, "cancel landed after {applied} applies");
            assert!(applied < 20_000, "the campaign must not have run to completion");
        }
        other => panic!("cancelled campaign ended with {other:?}"),
    }
    assert!(
        !cancel_log.iter().any(|e| matches!(e, Event::Done { .. })),
        "a cancelled campaign must not report Done"
    );

    // each parity campaign: bit-identical to the solo CLI-path run with
    // the same seed/policy, despite three neighbours on the substrate
    for (spec, &id) in parity_specs.iter().zip(&parity_ids) {
        let (log, terminal) = watch_all(&mut client, id);
        assert!(log.iter().all(|e| e.campaign() == id), "event stream leaked across campaigns");
        assert!(
            !log.iter().any(|e| matches!(e, Event::WarmStarted { .. })),
            "warm_start=false campaigns must start cold"
        );
        assert!(
            log.iter().any(|e| matches!(e, Event::Started { .. })),
            "watch from 0 must replay the Started event"
        );

        let solo = autotune_with_scorer(&spec.to_setup().unwrap(), Arc::new(Scorer::fallback()))
            .unwrap();
        assert_eq!(solo.evaluations, 12);
        assert_eq!(
            digest_events(&log),
            digest_result(&solo),
            "campaign {id} (seed {}) diverged from its solo run",
            spec.seed
        );
        match terminal {
            Event::Done { campaign, summary } => {
                assert_eq!(campaign, id);
                assert_eq!(summary.evaluations, 12);
                assert_eq!(
                    summary.best_objective.to_bits(),
                    solo.best_objective.to_bits(),
                    "campaign {id} summary best diverged from solo"
                );
            }
            other => panic!("campaign {id} ended with {other:?}"),
        }
    }

    // the three finished campaigns appended to the shared store; the
    // cancelled one must not have (a partial run is not transferable)
    assert_eq!(history_record_count(&hist), 3, "exactly the finished campaigns in the store");

    // solo warm-start reference FIRST (the store must hold exactly those
    // 3 records when the trajectory is pinned), explicitly pointing at
    // the daemon's store without appending to it
    let warm_spec = CampaignSpec {
        seed: 5005,
        workers: 2,
        max_evals: 12,
        wallclock_budget_s: 1e9,
        warm_start: true,
        ..CampaignSpec::default()
    };
    let mut warm_solo_setup = warm_spec.to_setup().unwrap();
    warm_solo_setup.warm_start_from = Some(hist.clone());
    warm_solo_setup.warm_start_elites = 8;
    let warm_solo = autotune_with_scorer(&warm_solo_setup, Arc::new(Scorer::fallback())).unwrap();

    // the daemon campaign warm-starts automatically: no flag beyond the
    // shared history dir the daemon already owns
    let warm_id = client.submit(warm_spec).unwrap();
    let (warm_log, warm_terminal) = watch_all(&mut client, warm_id);
    let elites = warm_log
        .iter()
        .find_map(|e| match e {
            Event::WarmStarted { elites, .. } => Some(*elites),
            _ => None,
        })
        .expect("compatible follow-up campaign must warm-start");
    assert!(elites > 0, "warm start must absorb at least one elite");
    assert_eq!(
        digest_events(&warm_log),
        digest_result(&warm_solo),
        "daemon auto-warm-start diverged from the explicitly-pinned solo run"
    );
    assert!(matches!(warm_terminal, Event::Done { .. }));

    daemon.shutdown();
    let _ = std::fs::remove_dir_all(&hist);
    let _ = std::fs::remove_dir_all(&ckpt);
}

#[test]
fn graceful_shutdown_interrupts_checkpoints_and_refuses_new_work() {
    let hist = tmpdir("shutdown-hist");
    let ckpt = tmpdir("shutdown-ckpt");
    let daemon = Daemon::start(
        ServeConfig {
            listen: "127.0.0.1:0".into(),
            service: ServiceConfig {
                max_active: 2,
                history_dir: Some(hist.clone()),
                checkpoint_dir: Some(ckpt.clone()),
                warm_start_elites: 8,
            },
            chaos: None,
        },
        Arc::new(Scorer::fallback()),
    )
    .unwrap();
    let addr = daemon.addr().to_string();
    let scheduler = daemon.scheduler();
    let mut client = Client::connect(&addr).unwrap();

    let spec = CampaignSpec {
        seed: 7007,
        workers: 2,
        strategy: "random".into(),
        max_evals: 20_000,
        wallclock_budget_s: 1e9,
        warm_start: false,
        ..CampaignSpec::default()
    };
    let id = client.submit(spec.clone()).unwrap();

    // a watcher attached over the wire BEFORE the shutdown: satellite 2's
    // contract is that it receives a terminal Interrupted frame, not a
    // dropped socket
    let watch_addr = addr.clone();
    let watcher = std::thread::spawn(move || {
        let mut wc = Client::connect(&watch_addr).unwrap();
        watch_all(&mut wc, id)
    });

    wait_for_evals(&mut client, id, 1);
    client.shutdown().unwrap();

    // the scheduler refuses new work the moment shutdown begins
    let refused = scheduler.submit(spec);
    assert!(refused.is_err(), "submissions during shutdown must be refused");
    assert!(format!("{:#}", refused.unwrap_err()).contains("shutting down"));

    let (log, terminal) = watcher.join().expect("watcher thread must not panic");
    match terminal {
        Event::Interrupted { campaign, applied, checkpointed } => {
            assert_eq!(campaign, id);
            assert!(applied >= 1, "the interrupt honored at least one applied completion");
            assert!(applied < 20_000);
            assert!(checkpointed, "a daemon with a checkpoint dir must report the checkpoint");
        }
        other => panic!("interrupted campaign ended with {other:?}"),
    }
    assert!(
        log.iter().any(|e| matches!(e, Event::EvalCompleted { .. })),
        "the watcher saw live progress before the interrupt"
    );
    let ckpt_file = ckpt.join(format!("campaign-{id}.json"));
    assert!(ckpt_file.exists(), "v3 checkpoint must be on disk at {}", ckpt_file.display());

    // an interrupted campaign is not a completed run: nothing appended
    assert_eq!(history_record_count(&hist), 0);
    assert_eq!(
        scheduler.status().iter().find(|r| r.id == id).unwrap().state,
        "interrupted"
    );

    daemon.shutdown();
    let _ = std::fs::remove_dir_all(&hist);
    let _ = std::fs::remove_dir_all(&ckpt);
}
