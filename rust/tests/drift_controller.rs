//! Continuous-controller e2e tests (ISSUE 9 acceptance):
//!
//! * Controller trajectories on the drifting substrate are a pure
//!   function of `(setup, seed)` — bit-identical across repeats.
//! * The CUSUM detector fires at/after the planted phase shift, and the
//!   fire is observable (stats counter + ring event).
//! * The authority limit is never exceeded: across the whole event log,
//!   consecutive dispatched configurations differ by at most
//!   `max_delta` ordinal steps on at most one parameter.
//! * Kill/resume in controller mode replays bit-identically through the
//!   v3 checkpoint (CUSUM accumulators, drift resets, deployed config).
//! * Attaching the stats sink perturbs nothing in controller mode.
//! * The recovery duel: after the drift, the controller's best tracks
//!   an oracle re-tuned from scratch on the post-drift landscape to
//!   within 5%, while the stationary tuner — its incumbents and `fmin`
//!   anchored to a world that no longer exists — does not.

use std::path::PathBuf;
use std::sync::Arc;

use ytopt::apps::AppKind;
use ytopt::coordinator::{autotune_with_scorer, TuneResult, TuneSetup};
use ytopt::drift::AuthorityLimiter;
use ytopt::ensemble::checkpoint::config_from_key;
use ytopt::metrics::Metric;
use ytopt::obs::{ObsEvent, ObsSink};
use ytopt::platform::PlatformKind;
use ytopt::runtime::Scorer;

fn run(setup: &TuneSetup) -> TuneResult {
    autotune_with_scorer(setup, Arc::new(Scorer::fallback())).unwrap()
}

fn tmpfile(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("ytopt-drift-{tag}-{}.json", std::process::id()))
}

/// The host-timing-free digest of a run's history (the `ensemble_e2e`
/// convention): everything that must be bit-identical across
/// deterministic replays.
fn history(r: &TuneResult) -> Vec<(usize, String, u64, u64, u64, bool, bool)> {
    r.db.records
        .iter()
        .map(|x| {
            (
                x.id,
                x.config_key.clone(),
                x.objective.to_bits(),
                x.measured.runtime_s.to_bits(),
                x.best_so_far.to_bits(),
                x.timed_out,
                x.cancelled,
            )
        })
        .collect()
}

/// A controller campaign on the drifting substrate: XSBench on Theta,
/// landscape phase-shifts at `drift_at`.
fn drift_setup(seed: u64, max_evals: usize, workers: usize, drift_at: usize) -> TuneSetup {
    let mut s = TuneSetup::new(AppKind::XSBenchHistory, PlatformKind::Theta, 1, Metric::Runtime);
    s.max_evals = max_evals;
    s.wallclock_budget_s = 1e9;
    s.seed = seed;
    s.n_init = 4;
    s.ensemble_workers = workers;
    s.controller = true;
    s.decay_half_life = 8.0;
    s.drift_threshold = 3.0;
    s.max_delta = 2;
    s.drift_at_eval = Some(drift_at);
    s.drift_magnitude = 3.0;
    s
}

/// Best finite objective among evaluations measured on the drifted
/// landscape (evaluation ids at or past the planted shift).
fn best_from(r: &TuneResult, from_id: usize) -> f64 {
    r.db.records
        .iter()
        .filter(|x| x.id >= from_id && !x.timed_out && !x.cancelled && x.objective.is_finite())
        .map(|x| x.objective)
        .fold(f64::INFINITY, f64::min)
}

#[test]
fn controller_trajectories_are_deterministic_on_the_drifting_substrate() {
    let s = drift_setup(101, 28, 3, 9);
    let a = run(&s);
    let b = run(&s);
    assert_eq!(a.evaluations, 28);
    assert_eq!(
        history(&a),
        history(&b),
        "controller mode must stay a pure function of (setup, seed)"
    );
    assert_eq!(a.best_objective.to_bits(), b.best_objective.to_bits());
}

#[test]
fn drift_fires_after_the_planted_shift_and_is_observable() {
    let mut s = drift_setup(7, 40, 2, 12);
    let sink = Arc::new(ObsSink::default());
    s.obs = Some(sink.clone());
    let r = run(&s);
    assert_eq!(r.evaluations, 40);

    let snap = sink.snapshot();
    assert!(
        snap.drift_detections >= 1,
        "a 3x phase shift at eval 12 must trip the CUSUM (got {} fires)",
        snap.drift_detections
    );
    let (events, _) = sink.tail(0);
    let fires: Vec<u64> = events
        .iter()
        .filter_map(|e| match &e.ev {
            ObsEvent::DriftDetected { eval_id, .. } => Some(*eval_id),
            _ => None,
        })
        .collect();
    assert_eq!(
        fires.len() as u64,
        snap.drift_detections,
        "counter and ring must agree on the number of fires"
    );
    assert!(
        fires.iter().any(|&id| id >= 12),
        "no fire at/after the planted shift (fires at {fires:?})"
    );
}

/// The acceptance invariant: across the whole event log, no apply may
/// exceed the actuation authority. Consecutive dispatched
/// configurations (evaluation-id order is dispatch order) differ by at
/// most `max_delta` ordinal steps summed over axes — which at
/// `max_delta = 1` also pins "at most one parameter moved".
#[test]
fn no_apply_exceeds_the_authority_limit() {
    let mut s = drift_setup(31, 36, 3, 12);
    s.max_delta = 1;
    let r = run(&s);

    let mut trail: Vec<(usize, String)> =
        r.db.records.iter().map(|x| (x.id, x.config_key.clone())).collect();
    trail.sort();
    assert_eq!(trail.len(), 36);
    let configs: Vec<_> = trail.iter().map(|(_, k)| config_from_key(k).unwrap()).collect();
    let mut moved = 0usize;
    for w in configs.windows(2) {
        let d = AuthorityLimiter::step_distance(&w[0], &w[1]);
        assert!(
            d <= 1,
            "an apply moved {d} ordinal steps under max-delta 1: {:?} -> {:?}",
            w[0].indices(),
            w[1].indices()
        );
        moved += d;
    }
    assert!(moved >= 5, "the governed walk never went anywhere ({moved} total steps)");
}

#[test]
fn controller_kill_resume_replays_bit_identically() {
    let ckpt = tmpfile("resume");
    let _ = std::fs::remove_file(&ckpt);
    // kill past the drift point, so the checkpoint carries mid-stream
    // CUSUM accumulators (and, with a 3x shift, a logged drift reset)
    let s = drift_setup(11, 26, 2, 8);
    let full = run(&s);
    assert_eq!(full.evaluations, 26);

    let mut killed = s.clone();
    killed.checkpoint_path = Some(ckpt.clone());
    killed.kill_after_evals = Some(16);
    let partial = run(&killed);
    assert_eq!(partial.evaluations, 16);

    let mut resumed = s.clone();
    resumed.checkpoint_path = Some(ckpt.clone());
    let r = run(&resumed);
    assert_eq!(r.evaluations, 26);
    assert_eq!(
        history(&full),
        history(&r),
        "controller kill/resume must replay the uninterrupted trajectory"
    );
    assert_eq!(full.best_objective.to_bits(), r.best_objective.to_bits());
    std::fs::remove_file(&ckpt).unwrap();
}

#[test]
fn stats_sink_is_bit_transparent_in_controller_mode() {
    let mut s = drift_setup(13, 32, 4, 10);
    let off = run(&s);
    let sink = Arc::new(ObsSink::default());
    s.obs = Some(sink.clone());
    let on = run(&s);
    assert_eq!(
        history(&off),
        history(&on),
        "attaching the stats sink perturbed a controller trajectory"
    );
    assert_eq!(off.best_objective.to_bits(), on.best_objective.to_bits());
    let snap = sink.snapshot();
    assert_eq!(snap.completions, 32);
    assert!(snap.drift_detections >= 1, "the watched run must also have seen the drift");
}

/// The recovery duel. One landscape, one seed, three tuners:
///
/// * `oracle` — a fresh stationary tuner whose entire budget lives on
///   the post-drift landscape (`drift_at = 0`): the re-tuned optimum
///   the acceptance criterion measures against.
/// * `controller` — tunes through the shift; must land within 5% of
///   the oracle on post-drift evaluations.
/// * `stationary` — tunes through the shift with the controller off;
///   its surrogate averages two worlds and its incumbents/`fmin` stay
///   anchored to pre-drift measurements nothing can match any more, so
///   it must NOT get within 5%.
#[test]
fn controller_recovers_from_drift_where_the_stationary_tuner_does_not() {
    const DRIFT_AT: usize = 24;
    const EVALS: usize = 96;

    let mut ctl = drift_setup(4242, EVALS, 2, DRIFT_AT);
    ctl.drift_magnitude = 4.0;
    ctl.drift_threshold = 4.0;
    ctl.decay_half_life = 6.0;
    // authority still moves one parameter per apply, but far enough to
    // correct a whole axis — re-tuning is governed, not hobbled
    ctl.max_delta = 12;
    let sink = Arc::new(ObsSink::default());
    ctl.obs = Some(sink.clone());
    let ctl_run = run(&ctl);

    let mut stationary = ctl.clone();
    stationary.controller = false;
    stationary.obs = None;
    let stat_run = run(&stationary);

    let mut oracle = ctl.clone();
    oracle.controller = false;
    oracle.obs = None;
    oracle.max_evals = EVALS - DRIFT_AT;
    oracle.drift_at_eval = Some(0);
    let oracle_run = run(&oracle);

    assert!(
        sink.snapshot().drift_detections >= 1,
        "the controller never noticed a 4x phase shift"
    );

    let oracle_best = best_from(&oracle_run, 0);
    let ctl_best = best_from(&ctl_run, DRIFT_AT);
    let stat_best = best_from(&stat_run, DRIFT_AT);
    assert!(oracle_best.is_finite() && oracle_best > 0.0, "oracle found nothing");
    assert!(
        ctl_best <= 1.05 * oracle_best,
        "controller failed to re-tune: post-drift best {ctl_best} vs oracle {oracle_best}"
    );
    assert!(
        stat_best > 1.05 * oracle_best,
        "the stationary tuner recovered anyway ({stat_best} vs oracle {oracle_best}) — \
         the duel no longer separates the modes"
    );
    assert!(
        ctl_best < stat_best,
        "controller ({ctl_best}) must beat the stationary tuner ({stat_best}) after the shift"
    );
}
