//! Chaos soak (ISSUE 10 acceptance): the whole stack driven under
//! seeded fault schedules at every I/O boundary.
//!
//! * A sweep of 8 fault schedules — worker crashes, torn/ENOSPC
//!   checkpoint installs, history-append faults, mixed-site combos, a
//!   federated and a controller campaign — each bit-identical to its
//!   fault-free reference: injected faults are retried away (or logged
//!   away, for best-effort history) and never bend a trajectory.
//! * A daemon hosting a chaotic campaign next to a clean one: the clean
//!   campaign stays bit-identical to its solo run, the chaotic one to
//!   its own fault-free reference.
//! * An exhausted retry budget turns exactly one campaign terminal
//!   `Degraded` — the daemon keeps answering, siblings finish `Done`.
//! * Kill/resume under injected checkpoint faults: a checkpoint whose
//!   install needed the retry budget is still a sound resume point.
//! * Socket chaos (torn frames, resets, stalls) against the resilient
//!   client: `watch` reattaches from its absolute cursor and delivers
//!   every event exactly once; `stats` cursors never run backwards.
//!
//! The `#[ignore]`d wide soak sweeps a larger schedule grid plus a
//! mixed daemon run (clean + chaotic + doomed co-resident, under socket
//! chaos) — the release-profile CI job runs it via `--include-ignored`.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use ytopt::apps::AppKind;
use ytopt::chaos::{Backoff, FaultPlan, Site};
use ytopt::coordinator::{autotune_with_scorer, TuneResult, TuneSetup};
use ytopt::metrics::Metric;
use ytopt::platform::PlatformKind;
use ytopt::runtime::Scorer;
use ytopt::service::{
    CampaignHandle, CampaignOutcome, CampaignSpec, Client, Daemon, Event, ResilientClient,
    ServeConfig, ServiceConfig,
};

fn run(setup: &TuneSetup) -> TuneResult {
    autotune_with_scorer(setup, Arc::new(Scorer::fallback())).unwrap()
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("ytopt-chaos-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// The host-timing-free digest of a trajectory (the `service_e2e`
/// convention): everything that must be bit-identical across replays,
/// whether it arrived over the wire or from an in-process run.
type Digest = Vec<(u64, String, u64, u64, u64, bool, bool)>;

fn digest_result(r: &TuneResult) -> Digest {
    r.db.records
        .iter()
        .map(|x| {
            (
                x.id as u64,
                x.config_key.clone(),
                x.objective.to_bits(),
                x.measured.runtime_s.to_bits(),
                x.best_so_far.to_bits(),
                x.timed_out,
                x.cancelled,
            )
        })
        .collect()
}

fn digest_events(events: &[Event]) -> Digest {
    events
        .iter()
        .filter_map(|ev| match ev {
            Event::EvalCompleted {
                eval_id,
                config_key,
                objective,
                runtime_s,
                best_so_far,
                timed_out,
                cancelled,
                ..
            } => Some((
                *eval_id,
                config_key.clone(),
                objective.to_bits(),
                runtime_s.to_bits(),
                best_so_far.to_bits(),
                *timed_out,
                *cancelled,
            )),
            _ => None,
        })
        .collect()
}

fn watch_all(client: &mut Client, campaign: u64) -> (Vec<Event>, Event) {
    let mut log = Vec::new();
    let terminal = client
        .watch(campaign, 0, &mut |ev| log.push(ev.clone()))
        .expect("watch stream must end in a terminal event");
    (log, terminal)
}

fn history_record_count(dir: &PathBuf) -> usize {
    std::fs::read_dir(dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .filter(|e| {
            let name = e.file_name();
            let name = name.to_string_lossy();
            name.starts_with("run-") && name.ends_with(".json")
        })
        .count()
}

/// One entry in the schedule sweep. `fired` lists the exact fire counts
/// expected for rate-1.0 capped sites (anything probabilistic is left
/// unasserted — the schedule is still deterministic, but the expected
/// count is not statically known).
struct Schedule {
    tag: &'static str,
    spec: &'static str,
    shards: usize,
    controller: bool,
    fired: &'static [(Site, u64)],
    /// Expected run-record count in the chaotic run's history store,
    /// for schedules that target the history site.
    history_records: Option<usize>,
}

const SCHEDULES: &[Schedule] = &[
    // the first three executions crash deterministically; the supervised
    // pool respawns the workers and re-queues each job at the same attempt
    Schedule {
        tag: "crash",
        spec: "seed=101;worker-crash=1x3",
        shards: 0,
        controller: false,
        fired: &[(Site::WorkerCrash, 3)],
        history_records: None,
    },
    // the first checkpoint install fails twice (torn or ENOSPC) and
    // succeeds on the third attempt, inside the default retry budget
    Schedule {
        tag: "ckpt",
        spec: "seed=102;ckpt-write=1x2;base-ms=0;cap-ms=1",
        shards: 0,
        controller: false,
        fired: &[(Site::CkptWrite, 2)],
        history_records: None,
    },
    // the history append survives four injected failures and still
    // lands exactly one audited record
    Schedule {
        tag: "history",
        spec: "seed=103;history-write=1x4;base-ms=0;cap-ms=1",
        shards: 0,
        controller: false,
        fired: &[(Site::HistoryWrite, 4)],
        history_records: Some(1),
    },
    // probabilistic mixed-site pressure: crashes and checkpoint faults
    // interleave, every one retried away (fire caps < retry budget)
    Schedule {
        tag: "mixed",
        spec: "seed=104;worker-crash=0.5x4;ckpt-write=0.6x3;base-ms=0;cap-ms=1",
        shards: 0,
        controller: false,
        fired: &[],
        history_records: None,
    },
    // an unclearing history fault exhausts its (tightened) retry budget:
    // the append is best-effort, so the run still completes and simply
    // records nothing
    Schedule {
        tag: "hist-exhaust",
        spec: "seed=105;history-write=1;retries=2;base-ms=0;cap-ms=1",
        shards: 0,
        controller: false,
        fired: &[(Site::HistoryWrite, 3)],
        history_records: Some(0),
    },
    // the brink: five consecutive install failures against a budget of
    // six — the last allowed attempt lands the checkpoint
    Schedule {
        tag: "ckpt-brink",
        spec: "seed=106;ckpt-write=1x5;retries=6;base-ms=0;cap-ms=1",
        shards: 0,
        controller: false,
        fired: &[(Site::CkptWrite, 5)],
        history_records: None,
    },
    // a 3-shard federation: crashes and checkpoint/manifest faults land
    // on whichever shard consults the plan first (racy placement,
    // deterministic recovery)
    Schedule {
        tag: "federated",
        spec: "seed=107;worker-crash=1x4;ckpt-write=1x3;base-ms=0;cap-ms=1",
        shards: 3,
        controller: false,
        fired: &[(Site::WorkerCrash, 4), (Site::CkptWrite, 3)],
        history_records: None,
    },
    // the continuous controller under crash chaos
    Schedule {
        tag: "controller",
        spec: "seed=108;worker-crash=1x2",
        shards: 0,
        controller: true,
        fired: &[(Site::WorkerCrash, 2)],
        history_records: None,
    },
];

fn sweep_setup(sched: &Schedule, seed: u64) -> TuneSetup {
    let mut s = TuneSetup::new(AppKind::Swfft, PlatformKind::Theta, 64, Metric::Runtime);
    s.max_evals = 12;
    s.wallclock_budget_s = 1e9;
    s.seed = seed;
    s.n_init = 4;
    // crash caps in the sweep reach 4: keep every re-queued job below
    // the abandonment threshold (crashes > max_retries + 1)
    s.max_retries = 4;
    if sched.shards > 0 {
        s.ensemble_workers = 2;
        s.federation_shards = sched.shards;
        s.elite_exchange_every = 2;
        s.federation_elites = 2;
    } else {
        s.ensemble_workers = 3;
    }
    s.controller = sched.controller;
    s
}

#[test]
fn swept_fault_schedules_leave_trajectories_bit_identical() {
    for (i, sched) in SCHEDULES.iter().enumerate() {
        let dir = tmpdir(&format!("sweep-{}", sched.tag));
        let needs_ckpt = sched.spec.contains("ckpt-write");
        let needs_hist = sched.spec.contains("history-write");

        // the fault-free reference, with the same storage shape (its own
        // fresh paths) so the only difference is the fault plan
        let mut clean = sweep_setup(sched, 9000 + i as u64);
        if needs_ckpt {
            clean.checkpoint_path = Some(dir.join("clean-ckpt.json"));
        }
        if needs_hist {
            let d = dir.join("clean-hist");
            std::fs::create_dir_all(&d).unwrap();
            clean.history_dir = Some(d);
        }
        let reference = run(&clean);
        assert_eq!(reference.evaluations, 12, "schedule `{}`", sched.tag);

        let mut chaotic = clean.clone();
        if needs_ckpt {
            chaotic.checkpoint_path = Some(dir.join("chaos-ckpt.json"));
        }
        if needs_hist {
            let d = dir.join("chaos-hist");
            std::fs::create_dir_all(&d).unwrap();
            chaotic.history_dir = Some(d);
        }
        let plan = Arc::new(FaultPlan::parse(sched.spec).unwrap());
        chaotic.chaos = Some(plan.clone());
        let r = run(&chaotic);

        assert_eq!(r.evaluations, 12, "schedule `{}`", sched.tag);
        assert_eq!(
            digest_result(&r),
            digest_result(&reference),
            "schedule `{}` ({}) bent the trajectory",
            sched.tag,
            sched.spec
        );
        for &(site, want) in sched.fired {
            assert_eq!(
                plan.fired(site),
                want,
                "schedule `{}`: site `{}` fire count",
                sched.tag,
                site.name()
            );
        }
        if sched.tag == "crash" {
            assert_eq!(r.ensemble.as_ref().unwrap().worker_crashes, 3);
        }
        if let Some(want) = sched.history_records {
            assert_eq!(
                history_record_count(chaotic.history_dir.as_ref().unwrap()),
                want,
                "schedule `{}`: history record count",
                sched.tag
            );
        }
        if let Some(ckpt) = &chaotic.checkpoint_path {
            assert!(
                ckpt.exists(),
                "schedule `{}`: the retried checkpoint install must land ({})",
                sched.tag,
                ckpt.display()
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn chaotic_and_clean_campaigns_coexist_on_one_daemon() {
    let hist = tmpdir("co-hist");
    let ckpt = tmpdir("co-ckpt");
    let daemon = Daemon::start(
        ServeConfig {
            listen: "127.0.0.1:0".into(),
            service: ServiceConfig {
                max_active: 4,
                history_dir: Some(hist.clone()),
                checkpoint_dir: Some(ckpt.clone()),
                warm_start_elites: 8,
            },
            chaos: None,
        },
        Arc::new(Scorer::fallback()),
    )
    .unwrap();
    let addr = daemon.addr().to_string();
    let mut client = Client::connect(&addr).unwrap();

    let clean_spec = CampaignSpec {
        seed: 1111,
        workers: 2,
        max_evals: 12,
        wallclock_budget_s: 1e9,
        warm_start: false,
        ..CampaignSpec::default()
    };
    let chaotic_spec = CampaignSpec {
        seed: 2222,
        workers: 3,
        max_evals: 12,
        wallclock_budget_s: 1e9,
        warm_start: false,
        max_retries: 4,
        chaos: Some("seed=21;worker-crash=1x3;ckpt-write=1x2;base-ms=0;cap-ms=1".into()),
        ..CampaignSpec::default()
    };
    let clean_id = client.submit(clean_spec.clone()).unwrap();
    let chaotic_id = client.submit(chaotic_spec.clone()).unwrap();

    let (clean_log, clean_terminal) = watch_all(&mut client, clean_id);
    let (chaotic_log, chaotic_terminal) = watch_all(&mut client, chaotic_id);
    assert!(matches!(clean_terminal, Event::Done { .. }), "clean: {clean_terminal:?}");
    assert!(matches!(chaotic_terminal, Event::Done { .. }), "chaotic: {chaotic_terminal:?}");

    // the clean campaign is bit-identical to its solo run — a chaotic
    // neighbour on the same substrate perturbs nothing
    let clean_solo = autotune_with_scorer(
        &clean_spec.to_setup().unwrap(),
        Arc::new(Scorer::fallback()),
    )
    .unwrap();
    assert_eq!(
        digest_events(&clean_log),
        digest_result(&clean_solo),
        "clean campaign diverged from its solo run"
    );

    // and the chaotic campaign is bit-identical to its own FAULT-FREE
    // reference: the injected crashes and checkpoint faults were
    // absorbed by supervision, not by the trajectory
    let fault_free = CampaignSpec { chaos: None, ..chaotic_spec };
    let chaotic_ref = autotune_with_scorer(
        &fault_free.to_setup().unwrap(),
        Arc::new(Scorer::fallback()),
    )
    .unwrap();
    assert_eq!(
        digest_events(&chaotic_log),
        digest_result(&chaotic_ref),
        "the chaotic campaign's trajectory must match its fault-free reference"
    );

    // both completed campaigns appended to the shared store
    assert_eq!(history_record_count(&hist), 2);

    daemon.shutdown();
    let _ = std::fs::remove_dir_all(&hist);
    let _ = std::fs::remove_dir_all(&ckpt);
}

#[test]
fn an_exhausted_retry_budget_degrades_one_campaign_and_spares_the_daemon() {
    let hist = tmpdir("deg-hist");
    let ckpt = tmpdir("deg-ckpt");
    let daemon = Daemon::start(
        ServeConfig {
            listen: "127.0.0.1:0".into(),
            service: ServiceConfig {
                max_active: 2,
                history_dir: Some(hist.clone()),
                checkpoint_dir: Some(ckpt.clone()),
                warm_start_elites: 8,
            },
            chaos: None,
        },
        Arc::new(Scorer::fallback()),
    )
    .unwrap();
    let addr = daemon.addr().to_string();
    let scheduler = daemon.scheduler();
    let mut client = Client::connect(&addr).unwrap();

    // an unclearing checkpoint fault against a budget of one retry:
    // the first save exhausts it and the campaign turns Degraded
    let doomed = CampaignSpec {
        seed: 3001,
        workers: 2,
        max_evals: 200,
        wallclock_budget_s: 1e9,
        warm_start: false,
        chaos: Some("seed=31;ckpt-write=1;retries=1;base-ms=0;cap-ms=1".into()),
        ..CampaignSpec::default()
    };
    let doomed_id = client.submit(doomed).unwrap();
    let (doomed_log, doomed_terminal) = watch_all(&mut client, doomed_id);
    match doomed_terminal {
        Event::Degraded { campaign, applied, message } => {
            assert_eq!(campaign, doomed_id);
            assert!(applied < 200, "the campaign must not have run its budget out");
            assert!(
                message.contains("ckpt-write"),
                "the degradation message names the failing site: {message}"
            );
            assert!(
                message.contains("retry budget exhausted"),
                "the degradation message carries the typed marker: {message}"
            );
        }
        other => panic!("doomed campaign ended with {other:?}"),
    }
    assert!(
        !doomed_log.iter().any(|e| matches!(e, Event::Done { .. })),
        "a degraded campaign must not report Done"
    );

    // the daemon is unharmed: it answers, accepts new work, and runs
    // the sibling campaign to a clean finish
    client.ping().unwrap();
    let ok_spec = CampaignSpec {
        seed: 3002,
        workers: 2,
        max_evals: 10,
        wallclock_budget_s: 1e9,
        warm_start: false,
        ..CampaignSpec::default()
    };
    let ok_id = client.submit(ok_spec).unwrap();
    let (_, ok_terminal) = watch_all(&mut client, ok_id);
    assert!(matches!(ok_terminal, Event::Done { .. }), "sibling: {ok_terminal:?}");

    assert_eq!(
        scheduler.status().iter().find(|r| r.id == doomed_id).unwrap().state,
        "degraded"
    );
    // a degraded campaign is not a completed run: only the sibling
    // appended to the store
    assert_eq!(history_record_count(&hist), 1);

    daemon.shutdown();
    let _ = std::fs::remove_dir_all(&hist);
    let _ = std::fs::remove_dir_all(&ckpt);
}

#[test]
fn kill_resume_stays_bit_identical_when_checkpoint_installs_fault() {
    let dir = tmpdir("killres");
    let ckpt = dir.join("manifest.json");

    let mut base = TuneSetup::new(AppKind::Swfft, PlatformKind::Theta, 64, Metric::Runtime);
    base.max_evals = 18;
    base.wallclock_budget_s = 1e9;
    base.seed = 53;
    base.n_init = 4;
    base.ensemble_workers = 2;
    base.max_retries = 4;
    base.federation_shards = 3;
    base.elite_exchange_every = 2;
    base.federation_elites = 2;

    // the uninterrupted fault-free reference: no checkpointing at all
    let full = run(&base);
    assert_eq!(full.evaluations, 18);

    // the killed campaign: every shard dies after its 3rd checkpointed
    // apply, and the first two checkpoint installs fail (torn/ENOSPC)
    // before the retry budget lands them
    let mut killed = base.clone();
    killed.checkpoint_path = Some(ckpt.clone());
    killed.kill_after_evals = Some(3);
    let killed_plan = Arc::new(FaultPlan::parse("seed=41;ckpt-write=1x2;base-ms=0;cap-ms=1").unwrap());
    killed.chaos = Some(killed_plan.clone());
    let partial = run(&killed);
    assert_eq!(partial.evaluations, 9, "3 shards x 3 applies before the kill");
    assert_eq!(
        killed_plan.fired(Site::CkptWrite),
        2,
        "both injected install faults must actually fire before the kill"
    );
    assert!(ckpt.exists(), "the federation manifest survived the faulted installs");

    // resume under fresh checkpoint faults: a checkpoint whose install
    // needed the retry budget is still a sound resume point, and the
    // resumed trajectory is the uninterrupted one, bit for bit
    let mut resumed = base.clone();
    resumed.checkpoint_path = Some(ckpt.clone());
    resumed.chaos =
        Some(Arc::new(FaultPlan::parse("seed=42;ckpt-write=1x2;base-ms=0;cap-ms=1").unwrap()));
    let r = run(&resumed);
    assert_eq!(r.evaluations, 18);
    assert_eq!(r.ensemble.as_ref().unwrap().resumed_evals, 9);
    assert_eq!(
        digest_result(&full),
        digest_result(&r),
        "kill/resume under checkpoint faults must be bit-identical"
    );
    assert_eq!(full.best_objective.to_bits(), r.best_objective.to_bits());

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn the_stepped_engine_reports_degraded_not_error() {
    let dir = tmpdir("deg-solo");
    let mut setup = CampaignSpec {
        seed: 61,
        workers: 2,
        max_evals: 50,
        wallclock_budget_s: 1e9,
        warm_start: false,
        ..CampaignSpec::default()
    }
    .to_setup()
    .unwrap();
    setup.checkpoint_path = Some(dir.join("ckpt.json"));
    setup.chaos =
        Some(Arc::new(FaultPlan::parse("seed=61;ckpt-write=1;retries=1;base-ms=0;cap-ms=1").unwrap()));

    let mut handle = CampaignHandle::start(setup, Arc::new(Scorer::fallback()));
    match handle.join().expect("degradation is Ok(...), not Err — the driver survives") {
        CampaignOutcome::Degraded { message, .. } => {
            assert!(message.contains("ckpt-write"), "site named: {message}");
            assert!(message.contains("retry budget exhausted"), "typed marker: {message}");
        }
        other => panic!("expected Degraded, got {other:?}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Submit under socket chaos. Submission is not idempotent and either
/// leg can die: the request may be dropped before the daemon decodes it
/// (nothing queued) or the acceptance frame may be torn after the
/// campaign was queued. Status — which IS idempotent — disambiguates.
fn submit_chaotic(rc: &mut ResilientClient, spec: &CampaignSpec, known: &[u64]) -> u64 {
    for _ in 0..20 {
        match rc.submit(spec.clone()) {
            Ok(id) => return id,
            Err(_) => {
                std::thread::sleep(Duration::from_millis(100));
                let rows = rc.status().expect("status must survive socket chaos");
                // the newest id we did not place earlier is this spec's
                // campaign (ids are monotonically assigned)
                if let Some(id) =
                    rows.iter().map(|r| r.id).filter(|id| !known.contains(id)).max()
                {
                    return id;
                }
            }
        }
    }
    panic!("could not place a campaign through the socket chaos");
}

#[test]
fn resilient_watch_survives_socket_chaos_exactly_once() {
    let daemon = Daemon::start(
        ServeConfig {
            listen: "127.0.0.1:0".into(),
            service: ServiceConfig {
                max_active: 4,
                history_dir: None,
                checkpoint_dir: None,
                warm_start_elites: 8,
            },
            // daemon-wide socket chaos: torn frames, resets, and stalls
            // on writes, plus read-side drops — shared occurrence
            // counters across every connection thread
            chaos: Some(Arc::new(
                FaultPlan::parse("seed=99;sock-write=0.7x6;sock-read=0.4x3").unwrap(),
            )),
        },
        Arc::new(Scorer::fallback()),
    )
    .unwrap();
    let addr = daemon.addr().to_string();
    let mut rc = ResilientClient::new(&addr).with_policy(30, Backoff::new(1, 20, 0));

    let spec = CampaignSpec {
        seed: 7171,
        workers: 2,
        max_evals: 12,
        wallclock_budget_s: 1e9,
        warm_start: false,
        ..CampaignSpec::default()
    };
    let id = submit_chaotic(&mut rc, &spec, &[]);

    // the resilient watch: absolute event-log cursors make every redial
    // resume exactly where the dead connection stopped
    let mut log: Vec<Event> = Vec::new();
    let terminal = rc
        .watch(id, 0, &mut |ev| log.push(ev.clone()))
        .expect("the watch must outlive the fault schedule");
    assert!(matches!(terminal, Event::Done { .. }), "terminal: {terminal:?}");
    assert_eq!(
        log.iter().filter(|e| matches!(e, Event::Started { .. })).count(),
        1,
        "reattaching from the cursor must not replay the stream head"
    );

    let solo =
        autotune_with_scorer(&spec.to_setup().unwrap(), Arc::new(Scorer::fallback())).unwrap();
    assert_eq!(
        digest_events(&log),
        digest_result(&solo),
        "socket chaos lost or duplicated an event"
    );

    // `stats --follow` semantics: the ring's logical clock is the
    // cursor, so reconnects never re-print and never skip
    let mut cur = 0u64;
    for _ in 0..5 {
        let (_snapshot, _events, next) =
            rc.stats(id, cur).expect("stats must survive socket chaos");
        assert!(next >= cur, "ring cursor ran backwards: {next} < {cur}");
        cur = next;
    }

    daemon.shutdown();
}

/// The release-profile wide soak (CI runs this with `--include-ignored`
/// in the `chaos-soak-release` job): a larger solo schedule grid, then
/// a daemon hosting clean, chaotic, and doomed campaigns at once under
/// daemon-wide socket chaos — no panic, every campaign terminates, and
/// the clean campaign stays bit-identical to its solo run.
#[test]
#[ignore = "release-profile soak; run via --include-ignored"]
fn wide_soak_terminates_every_campaign_across_swept_schedules() {
    // part 1: a 12-point solo grid cycling site mixes over seeds, every
    // run compared against its fault-free reference
    for round in 0u64..12 {
        let spec = match round % 4 {
            0 => format!("seed={};worker-crash=1x3", 500 + round),
            1 => format!("seed={};ckpt-write=1x2;base-ms=0;cap-ms=1", 500 + round),
            2 => format!("seed={};history-write=1x3;base-ms=0;cap-ms=1", 500 + round),
            _ => format!(
                "seed={};worker-crash=0.5x4;ckpt-write=0.5x2;base-ms=0;cap-ms=1",
                500 + round
            ),
        };
        let sched = Schedule {
            tag: "wide",
            spec: "",
            shards: if round % 6 == 0 { 3 } else { 0 },
            controller: false,
            fired: &[],
            history_records: None,
        };
        let dir = tmpdir(&format!("wide-{round}"));
        let mut clean = sweep_setup(&sched, 600 + round);
        clean.max_evals = 10;
        if spec.contains("ckpt-write") {
            clean.checkpoint_path = Some(dir.join("clean-ckpt.json"));
        }
        if spec.contains("history-write") {
            let d = dir.join("clean-hist");
            std::fs::create_dir_all(&d).unwrap();
            clean.history_dir = Some(d);
        }
        let reference = run(&clean);

        let mut chaotic = clean.clone();
        if spec.contains("ckpt-write") {
            chaotic.checkpoint_path = Some(dir.join("chaos-ckpt.json"));
        }
        if spec.contains("history-write") {
            let d = dir.join("chaos-hist");
            std::fs::create_dir_all(&d).unwrap();
            chaotic.history_dir = Some(d);
        }
        chaotic.chaos = Some(Arc::new(FaultPlan::parse(&spec).unwrap()));
        let r = run(&chaotic);
        assert_eq!(
            digest_result(&r),
            digest_result(&reference),
            "wide round {round} ({spec}) bent the trajectory"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    // part 2: a mixed daemon soak — clean + chaotic + doomed campaigns
    // co-resident, the wire itself under fault pressure
    let hist = tmpdir("wide-hist");
    let ckpt = tmpdir("wide-ckpt");
    let daemon = Daemon::start(
        ServeConfig {
            listen: "127.0.0.1:0".into(),
            service: ServiceConfig {
                max_active: 4,
                history_dir: Some(hist.clone()),
                checkpoint_dir: Some(ckpt.clone()),
                warm_start_elites: 8,
            },
            chaos: Some(Arc::new(
                FaultPlan::parse("seed=700;sock-write=0.5x8;sock-read=0.3x4").unwrap(),
            )),
        },
        Arc::new(Scorer::fallback()),
    )
    .unwrap();
    let addr = daemon.addr().to_string();
    let mut rc = ResilientClient::new(&addr).with_policy(40, Backoff::new(1, 20, 0));

    let clean_spec = CampaignSpec {
        seed: 8001,
        workers: 2,
        max_evals: 12,
        wallclock_budget_s: 1e9,
        warm_start: false,
        ..CampaignSpec::default()
    };
    let chaotic_spec = CampaignSpec {
        seed: 8002,
        workers: 2,
        max_evals: 12,
        wallclock_budget_s: 1e9,
        warm_start: false,
        max_retries: 4,
        chaos: Some("seed=71;worker-crash=1x3;ckpt-write=1x2;base-ms=0;cap-ms=1".into()),
        ..CampaignSpec::default()
    };
    let doomed_spec = CampaignSpec {
        seed: 8003,
        workers: 2,
        max_evals: 200,
        wallclock_budget_s: 1e9,
        warm_start: false,
        chaos: Some("seed=72;ckpt-write=1;retries=1;base-ms=0;cap-ms=1".into()),
        ..CampaignSpec::default()
    };
    let clean_id = submit_chaotic(&mut rc, &clean_spec, &[]);
    let chaotic_id = submit_chaotic(&mut rc, &chaotic_spec, &[clean_id]);
    let doomed_id = submit_chaotic(&mut rc, &doomed_spec, &[clean_id, chaotic_id]);

    let mut clean_log: Vec<Event> = Vec::new();
    let clean_terminal = rc.watch(clean_id, 0, &mut |ev| clean_log.push(ev.clone())).unwrap();
    assert!(matches!(clean_terminal, Event::Done { .. }));
    let chaotic_terminal = rc.watch(chaotic_id, 0, &mut |_| {}).unwrap();
    assert!(matches!(chaotic_terminal, Event::Done { .. }), "{chaotic_terminal:?}");
    let doomed_terminal = rc.watch(doomed_id, 0, &mut |_| {}).unwrap();
    assert!(matches!(doomed_terminal, Event::Degraded { .. }), "{doomed_terminal:?}");

    let clean_solo = autotune_with_scorer(
        &clean_spec.to_setup().unwrap(),
        Arc::new(Scorer::fallback()),
    )
    .unwrap();
    assert_eq!(
        digest_events(&clean_log),
        digest_result(&clean_solo),
        "the clean campaign must shrug off both neighbours and the wire chaos"
    );

    // the daemon survived the whole soak
    let mut probe = Client::connect(&addr).unwrap();
    while probe.ping().is_err() {
        probe = Client::connect(&addr).unwrap();
    }

    daemon.shutdown();
    let _ = std::fs::remove_dir_all(&hist);
    let _ = std::fs::remove_dir_all(&ckpt);
}
