//! Property tests over the coordinator's core invariants (proptest_lite):
//! space indexing/encoding, neighbourhood validity, forest export
//! equivalence, JSON/report round-trips.

use ytopt::apps::AppKind;
use ytopt::platform::PlatformKind;
use ytopt::power::GeopmReport;
use ytopt::proptest_lite::for_all;
use ytopt::runtime::{forest_score_blocked, forest_score_blocked_par, forest_score_cpu};
use ytopt::space::{paper, Configuration};
use ytopt::surrogate::{export_forest, ForestConfig, RandomForest};
use ytopt::util::{Json, Pcg32};

const APPS: [AppKind; 7] = [
    AppKind::XSBenchHistory,
    AppKind::XSBenchEvent,
    AppKind::XSBenchMixed,
    AppKind::XSBenchOffload,
    AppKind::Swfft,
    AppKind::Amg,
    AppKind::Sw4lite,
];

fn random_space(rng: &mut Pcg32) -> ytopt::space::ConfigSpace {
    let app = APPS[rng.index(APPS.len())];
    let pf = if rng.bool(0.5) { PlatformKind::Theta } else { PlatformKind::Summit };
    paper::build_space(app, pf)
}

#[test]
fn prop_index_roundtrip_on_paper_spaces() {
    for_all(
        "config_at . index_of == id",
        300,
        11,
        |rng| {
            let space = random_space(rng);
            let i = rng.gen_range(u64::MAX) as u128 % space.size();
            (space, i)
        },
        |(space, i)| {
            let c = space.config_at(*i);
            space.is_valid(&c) && space.index_of(&c) == *i
        },
    );
}

/// Federation sharding: for random paper spaces and K in 1..=8, the
/// seeded hash partition is a disjoint cover of the flat index space —
/// every sampled index lands in a valid shard and is claimed by exactly
/// one `ShardSpec` — and re-sharding under the same seed is
/// byte-identical.
#[test]
fn prop_shard_partition_is_a_stable_disjoint_cover() {
    use ytopt::ensemble::{shard_of_index, ShardSpec};
    for_all(
        "seeded hash-sharding: disjoint cover, byte-stable",
        80,
        41,
        |rng| {
            let space = random_space(rng);
            let k = 1 + rng.index(8) as u32; // K in 1..=8
            let seed = rng.next_u64();
            let idxs: Vec<u128> =
                (0..48).map(|_| rng.gen_range(u64::MAX) as u128 % space.size()).collect();
            (space, k, seed, idxs)
        },
        |(space, k, seed, idxs)| {
            idxs.iter().all(|&i| {
                let s = shard_of_index(*seed, i, *k);
                let cfg = space.config_at(i);
                let claims = (0..*k)
                    .filter(|&sh| {
                        ShardSpec { seed: *seed, shards: *k, shard: sh }.contains(space, &cfg)
                    })
                    .count();
                // in range, claimed exactly once, stable under re-shard
                s < *k && claims == 1 && shard_of_index(*seed, i, *k) == s
            })
        },
    );
}

#[test]
fn prop_encoding_is_unit_interval_and_zero_padded() {
    for_all(
        "encode in [0,1], padded with 0",
        200,
        13,
        |rng| {
            let space = random_space(rng);
            let c = space.sample(rng);
            (space, c)
        },
        |(space, c)| {
            let e = space.encode(c, 32);
            e.len() == 32
                && e[..space.dim()].iter().all(|&x| (0.0..=1.0).contains(&x))
                && e[space.dim()..].iter().all(|&x| x == 0.0)
        },
    );
}

#[test]
fn prop_neighbors_stay_valid_and_close() {
    for_all(
        "neighbor valid, hamming <= 1",
        200,
        17,
        |rng| {
            let space = random_space(rng);
            let c = space.sample(rng);
            let mut r = rng.split(9);
            let n = space.neighbor(&c, &mut r);
            (space, c, n)
        },
        |(space, c, n)| {
            let diff =
                c.indices().iter().zip(n.indices()).filter(|(a, b)| a != b).count();
            space.is_valid(n) && diff <= 1
        },
    );
}

#[test]
fn prop_forest_export_preserves_predictions() {
    for_all(
        "tensor lockstep == tree walk",
        25,
        19,
        |rng| {
            let dim = 1 + rng.index(16);
            let n = 20 + rng.index(150);
            let mut x = Vec::with_capacity(n * dim);
            let mut y = Vec::with_capacity(n);
            for _ in 0..n {
                let row: Vec<f32> = (0..dim).map(|_| rng.f32()).collect();
                y.push(row.iter().sum::<f32>() + rng.f32() * 0.1);
                x.extend(row);
            }
            let cfg = ForestConfig { n_trees: 8, ..Default::default() };
            let mut frng = rng.split(3);
            let forest = RandomForest::fit(&x, &y, dim, &cfg, &mut frng);
            let probe: Vec<f32> = (0..8 * dim).map(|_| rng.f32() * 1.5 - 0.25).collect();
            (forest, probe, dim)
        },
        |(forest, probe, dim)| {
            let tensors = export_forest(forest, 8, 512, 32, 16).unwrap();
            // pad probe rows to the 32-feature layout
            let n = probe.len() / dim;
            let mut rows = vec![0.0f32; n * 32];
            for i in 0..n {
                rows[i * 32..i * 32 + dim].copy_from_slice(&probe[i * dim..(i + 1) * dim]);
            }
            let out = forest_score_cpu(&rows, 32, &tensors, 1.96);
            (0..n).all(|i| {
                let (m, s) = forest.predict_one(&probe[i * dim..(i + 1) * dim]);
                (out.mean[i] - m).abs() < 1e-4 && (out.std[i] - s).abs() < 1e-3
            })
        },
    );
}

/// The blocked lockstep scorer (and its scoped-thread parallel variant)
/// is bit-identical to the scalar reference walker — across random
/// forests, feature dimensionalities, kappa values, thread counts, and
/// batch sizes including n = 0, n = 1, and n not a multiple of the
/// 128-candidate block. This is the invariant that lets the production
/// fallback path swap kernels without perturbing a single trajectory.
#[test]
fn prop_blocked_scorer_bit_identical_to_scalar() {
    for_all(
        "blocked lockstep == scalar walker, bit for bit",
        20,
        47,
        |rng| {
            let dim = 1 + rng.index(16);
            let n_obs = 25 + rng.index(120);
            let mut x = Vec::with_capacity(n_obs * dim);
            let mut y = Vec::with_capacity(n_obs);
            for _ in 0..n_obs {
                let row: Vec<f32> = (0..dim).map(|_| rng.f32()).collect();
                y.push(row.iter().sum::<f32>() * 2.0 + rng.f32() * 0.3);
                x.extend(row);
            }
            let trees = *rng.choose(&[1usize, 8, 64]);
            let cfg = ForestConfig { n_trees: trees, ..Default::default() };
            let mut frng = rng.split(13);
            let forest = RandomForest::fit(&x, &y, dim, &cfg, &mut frng);
            let tensors = export_forest(&forest, trees, 512, 32, 16).unwrap();
            let n = *rng.choose(&[0usize, 1, 2, 127, 128, 129, 200, 300]);
            let mut rows = vec![0.0f32; n * 32];
            for i in 0..n {
                for j in 0..dim {
                    rows[i * 32 + j] = rng.f32() * 1.6 - 0.3;
                }
            }
            let kappa = *rng.choose(&[0.0f32, 0.5, 1.96, 4.0]);
            let threads = 1 + rng.index(6);
            (tensors, rows, kappa, threads)
        },
        |(tensors, rows, kappa, threads)| {
            let scalar = forest_score_cpu(rows, 32, tensors, *kappa);
            let blocked = forest_score_blocked(rows, 32, tensors, *kappa);
            let par = forest_score_blocked_par(rows, 32, tensors, *kappa, *threads);
            let n = rows.len() / 32;
            scalar.mean.len() == n
                && (0..n).all(|i| {
                    scalar.mean[i].to_bits() == blocked.mean[i].to_bits()
                        && scalar.std[i].to_bits() == blocked.std[i].to_bits()
                        && scalar.lcb[i].to_bits() == blocked.lcb[i].to_bits()
                        && scalar.mean[i].to_bits() == par.mean[i].to_bits()
                        && scalar.std[i].to_bits() == par.std[i].to_bits()
                        && scalar.lcb[i].to_bits() == par.lcb[i].to_bits()
                })
        },
    );
}

/// Cross-run history: an arbitrary `RunRecord` — including non-finite
/// objectives/runtimes (JSON `null`), empty histories, and
/// awkward-but-valid strings — survives serialize → parse losslessly,
/// and its content-derived id is stable.
#[test]
fn prop_run_record_json_roundtrip() {
    use ytopt::history::{HistoryEval, RunRecord};
    fn random_record(rng: &mut Pcg32) -> RunRecord {
        let n = rng.index(12);
        let evals: Vec<HistoryEval> = (0..n)
            .map(|i| {
                let timed_out = rng.bool(0.15);
                HistoryEval {
                    config_key: format!("{},{},{}", rng.index(8), rng.index(8), i),
                    objective: if timed_out { f64::INFINITY } else { rng.f64() * 2e3 - 1e2 },
                    runtime_s: if rng.bool(0.1) { f64::INFINITY } else { rng.f64() * 500.0 },
                    energy_j: rng.bool(0.5).then(|| rng.f64() * 9e3),
                    timed_out,
                }
            })
            .collect();
        RunRecord {
            space_fingerprint: format!("s|{}d|{}|a:{}", rng.index(9), rng.index(7), rng.index(5)),
            app: (*rng.choose(&["xsbench", "amg", "sw\"4\\lite"])).to_string(),
            platform: "Theta".to_string(),
            nodes: rng.gen_range(8192) + 1,
            metric: "runtime".to_string(),
            seed: rng.next_u64(), // full u64 range: seeds are hex-encoded
            baseline_objective: rng.f64() * 100.0 + 0.1,
            best_objective: rng.f64() * 100.0,
            best_config_key: format!("{},{}", rng.index(9), rng.index(9)),
            wallclock_s: rng.f64() * 1e5,
            evals,
        }
    }
    for_all(
        "RunRecord parse(render(r)) == r",
        200,
        37,
        random_record,
        |r| {
            RunRecord::parse(&r.to_json().to_string())
                .map(|back| back == *r && back.run_id() == r.run_id())
                .unwrap_or(false)
        },
    );
}

/// Cross-run history: top-K elite extraction is a pure function of the
/// record *set* — any permutation of the insertion order yields the
/// same elites in the same order, and the result is deduped and
/// ascending in objective.
#[test]
fn prop_history_elites_stable_under_insertion_order() {
    use ytopt::history::{HistoryEval, RunRecord};
    fn record(rng: &mut Pcg32, seed: u64) -> RunRecord {
        let n = 1 + rng.index(10);
        let evals: Vec<HistoryEval> = (0..n)
            .map(|_| HistoryEval {
                // small key space on purpose: cross-record duplicates
                config_key: format!("{},{}", rng.index(4), rng.index(4)),
                objective: (rng.f64() * 40.0).round() / 2.0,
                runtime_s: rng.f64() * 10.0,
                energy_j: None,
                timed_out: rng.bool(0.1),
            })
            .collect();
        RunRecord {
            space_fingerprint: "toy".into(),
            app: "xsbench".into(),
            platform: "Theta".into(),
            nodes: 64,
            metric: "runtime".into(),
            seed,
            baseline_objective: 10.0,
            best_objective: 1.0,
            best_config_key: String::new(),
            wallclock_s: 1.0,
            evals,
        }
    }
    for_all(
        "top-K elites independent of record order",
        120,
        43,
        |rng| {
            let records: Vec<RunRecord> =
                (0..2 + rng.index(5)).map(|i| record(rng, i as u64)).collect();
            let k = 1 + rng.index(8);
            let mut order: Vec<usize> = (0..records.len()).collect();
            let mut r = rng.split(5);
            r.shuffle(&mut order);
            (records, order, k)
        },
        |(records, order, k)| {
            let forward: Vec<&RunRecord> = records.iter().collect();
            let shuffled: Vec<&RunRecord> = order.iter().map(|&i| &records[i]).collect();
            let a = ytopt::history::top_k_elites(&forward, *k);
            let b = ytopt::history::top_k_elites(&shuffled, *k);
            let key = |v: &[(ytopt::space::Configuration, f64)]| {
                v.iter().map(|(c, y)| (c.key(), y.to_bits())).collect::<Vec<_>>()
            };
            // identical under permutation, capped at k, deduped, ascending
            let keys: Vec<String> = a.iter().map(|(c, _)| c.key()).collect();
            let mut sorted_keys = keys.clone();
            sorted_keys.sort();
            sorted_keys.dedup();
            let deduped = sorted_keys.len() == keys.len();
            let ascending = a.windows(2).all(|w| w[0].1 <= w[1].1);
            key(&a) == key(&b) && a.len() <= *k && deduped && ascending
        },
    );
}

#[test]
fn prop_json_roundtrip() {
    fn random_json(rng: &mut Pcg32, depth: usize) -> Json {
        match if depth == 0 { rng.index(4) } else { rng.index(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bool(0.5)),
            2 => Json::Num((rng.f64() * 2e6).round() / 8.0 - 1e5),
            3 => {
                let len = rng.index(12);
                Json::Str((0..len).map(|_| *rng.choose(&['a', 'Z', '"', '\\', 'é', '\n', ' '])).collect())
            }
            4 => Json::Arr((0..rng.index(5)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.index(5))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for_all(
        "parse(render(v)) == v",
        300,
        23,
        |rng| random_json(rng, 3),
        |v| Json::parse(&v.to_string()).map(|b| b == *v).unwrap_or(false),
    );
}

#[test]
fn prop_geopm_report_roundtrip() {
    for_all(
        "GEOPM report render/parse",
        100,
        29,
        |rng| {
            let n = 1 + rng.index(64);
            let energies: Vec<f32> =
                (0..n).map(|_| (rng.f64() * 9000.0) as f32).collect();
            (energies, 0.5 + rng.f64() * 0.5, rng.f64() * 200.0)
        },
        |(energies, pkg_frac, runtime)| {
            let rep = GeopmReport::from_node_energy(energies, *pkg_frac, *runtime);
            let back = GeopmReport::parse(&rep.render()).unwrap();
            back.nodes.len() == energies.len()
                && (back.average_node_energy() - rep.average_node_energy()).abs()
                    < rep.average_node_energy().abs() * 1e-3 + 1e-2
        },
    );
}

#[test]
fn prop_codegen_always_verifies_on_matching_spaces() {
    for_all(
        "instantiate verifies",
        150,
        31,
        |rng| {
            let app = APPS[rng.index(APPS.len())];
            let pf = if app.uses_gpus() { PlatformKind::Summit } else { PlatformKind::Theta };
            let space = paper::build_space(app, pf);
            let cfg = space.sample(rng);
            (app, space, cfg)
        },
        |(app, space, cfg)| {
            ytopt::codegen::instantiate(*app, space, cfg)
                .map(|src| ytopt::codegen::verify(&src))
                .unwrap_or(false)
        },
    );
}

#[test]
fn prop_launch_lines_accept_every_space_thread_choice() {
    // every OMP_NUM_THREADS value in every paper space must produce a
    // valid launch line on its platform (the spaces honour §VI rules)
    for app in APPS {
        for pf in [PlatformKind::Theta, PlatformKind::Summit] {
            let space = paper::build_space(app, pf);
            for &n in paper::thread_choices(pf) {
                let r = match (pf, app.uses_gpus()) {
                    (PlatformKind::Theta, _) => {
                        ytopt::platform::launch::aprun(64, n as u64, "x")
                    }
                    (PlatformKind::Summit, true) => {
                        ytopt::platform::launch::jsrun_gpu(64, n as u64, "x")
                    }
                    (PlatformKind::Summit, false) => {
                        ytopt::platform::launch::jsrun_cpu(64, n as u64, "x")
                    }
                };
                assert!(r.is_ok(), "{app:?}@{pf:?} threads {n}: {r:?}");
            }
            let _ = space;
        }
    }
}

#[test]
fn prop_run_noise_is_bounded_and_centered() {
    let mut sum = 0.0f64;
    let n = 2000;
    for i in 0..n {
        let cfg = Configuration::from_indices(vec![i as u32, (i * 7) as u32]);
        let f = ytopt::apps::common::run_noise(&cfg, i as u64, 0.008);
        assert!((0.9..1.1).contains(&f), "noise {f}");
        sum += f;
    }
    let mean = sum / n as f64;
    assert!((mean - 1.0).abs() < 0.005, "noise mean {mean}");
}
