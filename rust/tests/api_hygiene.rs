//! Source-level hygiene gates: the deprecated `amend_last`-era API
//! surface (`BayesianOptimizer::amend_last`, the `search::transfer`
//! warm-start shim) stays available — with its pinned tests — but no
//! runtime caller may creep back onto the hot path. Enforced by the
//! detlint engine's `deprecated-api` rule (`ytopt::lint`), so a
//! reintroduction fails CI with a pointer to this contract instead of
//! silently resurrecting the positional-amendment bug class.
//!
//! This file is a thin wrapper: the hand-rolled grep/comment-stripping
//! code it used to carry now lives (comment- and string-aware) in
//! `rust/src/lint/`, shared with `ytopt-rs lint` and `tests/detlint.rs`.

use std::path::{Path, PathBuf};

use ytopt::lint::{check_files, check_tree, Diagnostic, Rule, SourceFile};

fn src_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("src")
}

/// The tree's `deprecated-api` diagnostics whose message names `needle`.
fn deprecated_mentioning(needle: &str) -> Vec<Diagnostic> {
    check_tree(&src_root())
        .expect("lintable source tree")
        .into_iter()
        .filter(|d| d.rule == Rule::DeprecatedApi && d.message.contains(needle))
        .collect()
}

fn render(diags: &[Diagnostic]) -> String {
    diags.iter().map(Diagnostic::render).collect::<Vec<_>>().join("\n")
}

#[test]
fn amend_last_has_no_caller_outside_its_definition_and_pinned_tests() {
    let diags = deprecated_mentioning("amend_last");
    assert!(
        diags.is_empty(),
        "`amend_last` referenced outside its #[deprecated] home — \
         use the index-keyed observe_pending/resolve_pending instead:\n{}",
        render(&diags)
    );
    // and the engine would catch a regression: a planted caller fires
    let planted = check_files(&[SourceFile {
        path: "ensemble/planted.rs".into(),
        text: "fn f(bo: &mut B) {\n    bo.amend_last(0.0);\n}\n".into(),
    }]);
    assert!(
        planted.iter().any(|d| d.rule == Rule::DeprecatedApi && d.line == 2),
        "deprecated-api rule lost its teeth:\n{}",
        render(&planted)
    );
}

#[test]
fn transfer_warm_start_shim_has_no_runtime_caller() {
    let diags = deprecated_mentioning("warm_start");
    assert!(
        diags.is_empty(),
        "deprecated transfer warm-start referenced outside its shim — \
         use history::rescale / history::apply_warm_start:\n{}",
        render(&diags)
    );
    let planted = check_files(&[SourceFile {
        path: "coordinator/planted.rs".into(),
        text: "fn f() {\n    let _ = ytopt::search::transfer::warm_start(&[]);\n}\n".into(),
    }]);
    assert!(
        planted.iter().any(|d| d.rule == Rule::DeprecatedApi && d.line == 2),
        "deprecated-api rule lost its teeth:\n{}",
        render(&planted)
    );
}
