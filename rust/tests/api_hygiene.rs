//! Source-level hygiene gates: the deprecated `amend_last`-era API
//! surface (`BayesianOptimizer::amend_last`, the `search::transfer`
//! warm-start shim) stays available — with its pinned tests — but no
//! runtime caller may creep back onto the hot path. Enforced by
//! grepping the crate sources, so a reintroduction fails CI with a
//! pointer to this contract instead of silently resurrecting the
//! positional-amendment bug class.

use std::path::{Path, PathBuf};

/// Every `.rs` file under `rust/src`, recursively.
fn source_files(dir: &Path, out: &mut Vec<PathBuf>) {
    for entry in std::fs::read_dir(dir).expect("readable source tree") {
        let path = entry.expect("readable dir entry").path();
        if path.is_dir() {
            source_files(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

/// Strip line comments (`//`, `///`, `//!`) so documentation may keep
/// referring to the deprecated names; only code counts.
fn strip_comments(source: &str) -> String {
    source
        .lines()
        .map(|l| l.split("//").next().unwrap_or(""))
        .collect::<Vec<_>>()
        .join("\n")
}

/// Occurrences of `needle` in the comment-stripped source of `path`,
/// counting only matches that start at an identifier boundary (so
/// `apply_warm_start(` does not count as `warm_start(`).
fn code_occurrences(path: &Path, needle: &str) -> usize {
    let text = std::fs::read_to_string(path).expect("readable source file");
    let code = strip_comments(&text);
    code.match_indices(needle)
        .filter(|(i, _)| {
            *i == 0
                || !code[..*i]
                    .chars()
                    .next_back()
                    .is_some_and(|c| c.is_ascii_alphanumeric() || c == '_')
        })
        .count()
}

fn src_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("src")
}

#[test]
fn amend_last_has_no_caller_outside_its_definition_and_pinned_tests() {
    let mut files = Vec::new();
    source_files(&src_root(), &mut files);
    assert!(files.len() > 20, "source walk looks broken: {} files", files.len());
    for f in &files {
        let hits = code_occurrences(f, "amend_last");
        let allowed = f.ends_with("search/bo.rs");
        assert!(
            hits == 0 || allowed,
            "{}: `amend_last` referenced {hits}x outside its #[deprecated] home — \
             use the index-keyed observe_pending/resolve_pending instead",
            f.display()
        );
    }
    // the definition and its pinned tests still exist (the API surface
    // contract: deprecated, not deleted)
    let bo = files.iter().find(|f| f.ends_with("search/bo.rs")).expect("bo.rs present");
    assert!(code_occurrences(bo, "pub fn amend_last") == 1, "deprecated API surface removed");
}

#[test]
fn transfer_warm_start_shim_has_no_runtime_caller() {
    let mut files = Vec::new();
    source_files(&src_root(), &mut files);
    for f in &files {
        let hits = code_occurrences(f, "transfer::warm_start")
            + code_occurrences(f, "warm_start(");
        // the shim's own file (definition + pinned delegation tests) and
        // the search/mod.rs re-export are the whole allowed surface
        let allowed = f.ends_with("search/transfer.rs") || f.ends_with("search/mod.rs");
        assert!(
            hits == 0 || allowed,
            "{}: deprecated transfer warm-start referenced {hits}x — \
             use history::rescale / history::apply_warm_start",
            f.display()
        );
    }
    let shim =
        files.iter().find(|f| f.ends_with("search/transfer.rs")).expect("shim present");
    assert!(code_occurrences(shim, "pub fn warm_start") == 1, "deprecated shim removed");
}
