//! detlint, tier-1: the determinism contract holds over the whole tree
//! on every `cargo test`, and the engine itself is proven against
//! planted-violation fixtures — each rule fires at the right line, the
//! `detlint: allow` escape works only with a reason, and a malformed or
//! unknown directive is itself an error. The contract text lives in
//! DESIGN.md ("Determinism contract").

use std::path::{Path, PathBuf};

use ytopt::lint::{check_files, check_tree, Diagnostic, Rule, SourceFile};

fn src_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("src")
}

fn fx(path: &str, text: &str) -> SourceFile {
    SourceFile { path: path.into(), text: text.into() }
}

/// The (line, rule) pairs of every diagnostic, for exact-position
/// assertions.
fn hits(diags: &[Diagnostic]) -> Vec<(usize, Rule)> {
    diags.iter().map(|d| (d.line, d.rule)).collect()
}

fn render(diags: &[Diagnostic]) -> String {
    diags.iter().map(Diagnostic::render).collect::<Vec<_>>().join("\n")
}

// ---------------------------------------------------------------------------
// the gate: the real tree is clean

#[test]
fn the_tree_upholds_the_determinism_contract() {
    let diags = check_tree(&src_root()).expect("lintable source tree");
    assert!(diags.is_empty(), "determinism contract violations:\n{}", render(&diags));
}

#[test]
fn the_tree_walk_sees_the_whole_crate() {
    // guard against a silently-empty walk making the gate vacuous
    fn count(dir: &Path, n: &mut usize) {
        for entry in std::fs::read_dir(dir).expect("readable source tree") {
            let path = entry.expect("readable dir entry").path();
            if path.is_dir() {
                count(&path, n);
            } else if path.extension().is_some_and(|e| e == "rs") {
                *n += 1;
            }
        }
    }
    let mut n = 0;
    count(&src_root(), &mut n);
    assert!(n > 20, "source walk looks broken: {n} files");
}

// ---------------------------------------------------------------------------
// hash-order

#[test]
fn hash_order_fires_in_the_core_at_the_right_lines() {
    let diags = check_files(&[fx(
        "search/fixture.rs",
        "use std::collections::HashMap;\nfn f() {\n    let m: HashMap<u32, u32> = HashMap::new();\n}\n",
    )]);
    assert_eq!(hits(&diags), vec![(1, Rule::HashOrder), (3, Rule::HashOrder)], "{}", render(&diags));
}

#[test]
fn hash_order_does_not_fire_outside_the_core() {
    let diags = check_files(&[fx("power/fixture.rs", "use std::collections::HashMap;\n")]);
    assert!(diags.is_empty(), "{}", render(&diags));
}

#[test]
fn needles_in_comments_and_strings_are_ignored() {
    let diags = check_files(&[fx(
        "search/fixture.rs",
        "// HashMap in prose is fine\nfn f() -> &'static str {\n    \"HashMap Instant::now thread_rng\"\n}\n",
    )]);
    assert!(diags.is_empty(), "{}", render(&diags));
}

#[test]
fn identifier_boundaries_prevent_substring_hits() {
    let diags = check_files(&[fx("search/fixture.rs", "struct HashMapLike;\nfn f(x: &HashMapLike) {}\n")]);
    assert!(diags.is_empty(), "{}", render(&diags));
}

// ---------------------------------------------------------------------------
// wall-clock

#[test]
fn wall_clock_fires_on_instant_and_thread_identity() {
    let diags = check_files(&[fx(
        "ensemble/fixture.rs",
        "fn f() {\n    let t = std::time::Instant::now();\n    let id = std::thread::current().id();\n}\n",
    )]);
    assert_eq!(hits(&diags), vec![(2, Rule::WallClock), (3, Rule::WallClock)], "{}", render(&diags));
}

// ---------------------------------------------------------------------------
// rng-source

#[test]
fn rng_source_fires_on_ambient_randomness() {
    let diags = check_files(&[fx("search/fixture.rs", "fn f() {\n    let mut r = rand::thread_rng();\n}\n")]);
    assert!(!diags.is_empty());
    assert!(diags.iter().all(|d| d.rule == Rule::RngSource && d.line == 2), "{}", render(&diags));
}

// ---------------------------------------------------------------------------
// par-float-accum

#[test]
fn par_float_accum_fires_in_the_core_but_not_in_the_blessed_scorer() {
    let body = "fn f(xs: &[f64]) {\n    std::thread::scope(|s| {\n        s.spawn(|| xs.iter().sum::<f64>());\n    });\n}\n";
    let in_core = check_files(&[fx("search/fixture.rs", body)]);
    assert_eq!(hits(&in_core), vec![(2, Rule::ParFloatAccum)], "{}", render(&in_core));
    let blessed = check_files(&[fx("runtime/batch.rs", body)]);
    assert!(blessed.is_empty(), "{}", render(&blessed));
}

// ---------------------------------------------------------------------------
// nan-order

#[test]
fn nan_order_fires_on_partial_cmp_in_the_core() {
    let diags = check_files(&[fx(
        "ensemble/fixture.rs",
        "fn f(xs: &mut [f64]) {\n    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());\n}\n",
    )]);
    assert_eq!(hits(&diags), vec![(2, Rule::NanOrder)], "{}", render(&diags));
    assert!(diags[0].message.contains("total_cmp"), "{}", render(&diags));
}

#[test]
fn nan_order_spares_non_core_files_and_honors_allows() {
    let body = "fn f(xs: &mut [f64]) {\n    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());\n}\n";
    let outside = check_files(&[fx("util/fixture.rs", body)]);
    assert!(outside.is_empty(), "{}", render(&outside));
    let allowed = check_files(&[fx(
        "search/fixture.rs",
        "fn f(xs: &mut [f64]) {\n    // detlint: allow(nan-order) -- inputs pre-filtered to finite\n    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());\n}\n",
    )]);
    assert!(allowed.is_empty(), "{}", render(&allowed));
}

// ---------------------------------------------------------------------------
// daemon-unwrap

#[test]
fn daemon_unwrap_fires_only_in_the_daemon() {
    let body = "fn f(m: std::sync::Mutex<u32>) {\n    let g = m.lock().unwrap();\n    drop(g);\n}\n";
    let daemon = check_files(&[fx("service/daemon.rs", body)]);
    assert_eq!(hits(&daemon), vec![(2, Rule::DaemonUnwrap)], "{}", render(&daemon));
    let client = check_files(&[fx("service/client.rs", body)]);
    assert!(client.is_empty(), "{}", render(&client));
}

// ---------------------------------------------------------------------------
// io-atomic

#[test]
fn io_atomic_fires_on_bare_installs_in_the_core() {
    let diags = check_files(&[fx(
        "history/fixture.rs",
        "fn f(path: &std::path::Path, bytes: &[u8]) {\n    std::fs::write(path, bytes).unwrap();\n    let _ = std::fs::File::create(path);\n    std::fs::rename(path, path).unwrap();\n}\n",
    )]);
    assert_eq!(
        hits(&diags),
        vec![(2, Rule::IoAtomic), (3, Rule::IoAtomic), (4, Rule::IoAtomic)],
        "{}",
        render(&diags)
    );
    assert!(diags[0].message.contains("install_atomic"), "{}", render(&diags));
}

#[test]
fn io_atomic_spares_the_blessed_writer_and_the_edges() {
    let body = "fn f(path: &std::path::Path) {\n    std::fs::write(path, b\"x\").unwrap();\n}\n";
    // chaos/fsx.rs IS the atomic installer — the rule exempts it
    let blessed = check_files(&[fx("chaos/fsx.rs", body)]);
    assert!(blessed.is_empty(), "{}", render(&blessed));
    // outside the core the rule does not apply at all
    let outside = check_files(&[fx("power/fixture.rs", body)]);
    assert!(outside.is_empty(), "{}", render(&outside));
    // planted test fixtures escape with a reasoned allow
    let allowed = check_files(&[fx(
        "ensemble/fixture.rs",
        "fn f(path: &std::path::Path) {\n    // detlint: allow(io-atomic) -- planted fixture for a torn-file test\n    std::fs::write(path, b\"x\").unwrap();\n}\n",
    )]);
    assert!(allowed.is_empty(), "{}", render(&allowed));
}

#[test]
fn io_atomic_does_not_flag_the_blessed_helper_calls() {
    let diags = check_files(&[fx(
        "ensemble/fixture.rs",
        "fn f(path: &std::path::Path, b: &[u8]) -> anyhow::Result<()> {\n    crate::chaos::fsx::write_file(path, b, None, crate::chaos::Site::CkptWrite)?;\n    crate::chaos::fsx::install_atomic(path, b, None, crate::chaos::Site::CkptWrite)\n}\n",
    )]);
    assert!(diags.is_empty(), "{}", render(&diags));
}

// ---------------------------------------------------------------------------
// deprecated-api

#[test]
fn deprecated_api_fires_on_callers_outside_the_home_files() {
    let diags = check_files(&[fx(
        "ensemble/fixture.rs",
        "fn g(bo: &mut ytopt::search::BayesianOptimizer) {\n    bo.amend_last(1.0);\n}\n",
    )]);
    assert_eq!(hits(&diags), vec![(2, Rule::DeprecatedApi)], "{}", render(&diags));
}

#[test]
fn deprecated_api_allows_the_pinned_home_definition() {
    let diags = check_files(&[fx("search/bo.rs", "pub fn amend_last(y: f64) {\n    let _ = y;\n}\n")]);
    assert!(diags.is_empty(), "{}", render(&diags));
}

#[test]
fn deprecated_api_fires_when_the_pinned_surface_is_removed() {
    // deprecated-not-deleted: bo.rs without `pub fn amend_last` breaks
    // the surface contract
    let diags = check_files(&[fx("search/bo.rs", "fn something_else() {}\n")]);
    assert_eq!(hits(&diags), vec![(1, Rule::DeprecatedApi)], "{}", render(&diags));
    assert!(diags[0].message.contains("amend_last"), "{}", render(&diags));
}

// ---------------------------------------------------------------------------
// fingerprint-coverage

const MINI_SETUP_COVERED: &str =
    "pub struct TuneSetup {\n    pub app: u32,\n    pub seed: u64,\n}\n";
const MINI_FP: &str = "pub fn fingerprint(setup: &TuneSetup) -> String {\n    let _ = (setup.app, setup.seed);\n    String::new()\n}\n";

#[test]
fn fingerprint_coverage_is_clean_when_every_field_is_a_component() {
    let diags = check_files(&[
        fx("coordinator/mod.rs", MINI_SETUP_COVERED),
        fx("ensemble/checkpoint.rs", MINI_FP),
    ]);
    assert!(diags.is_empty(), "{}", render(&diags));
}

#[test]
fn a_new_tune_setup_field_without_a_fingerprint_component_fails() {
    // the acceptance fixture: add a knob, forget the fingerprint, and
    // the lint points at the new field's line
    let setup = "pub struct TuneSetup {\n    pub app: u32,\n    pub seed: u64,\n    pub shiny_new_knob: bool,\n}\n";
    let diags = check_files(&[
        fx("coordinator/mod.rs", setup),
        fx("ensemble/checkpoint.rs", MINI_FP),
    ]);
    assert_eq!(hits(&diags), vec![(4, Rule::FingerprintCoverage)], "{}", render(&diags));
    assert!(diags[0].message.contains("shiny_new_knob"), "{}", render(&diags));
    assert_eq!(diags[0].path, "coordinator/mod.rs");
}

#[test]
fn an_annotated_exclusion_with_a_reason_is_accepted() {
    let setup = "pub struct TuneSetup {\n    pub app: u32,\n    pub seed: u64,\n    // detlint: allow(fingerprint-coverage) -- capacity knob, not identity\n    pub max_widgets: usize,\n}\n";
    let diags = check_files(&[
        fx("coordinator/mod.rs", setup),
        fx("ensemble/checkpoint.rs", MINI_FP),
    ]);
    assert!(diags.is_empty(), "{}", render(&diags));
}

#[test]
fn a_missing_fingerprint_function_is_itself_a_violation() {
    let diags = check_files(&[fx("coordinator/mod.rs", MINI_SETUP_COVERED)]);
    assert_eq!(hits(&diags), vec![(1, Rule::FingerprintCoverage)], "{}", render(&diags));
}

#[test]
fn campaign_spec_fields_are_checked_through_the_alias_map() {
    // `workers` maps onto the fingerprinted `ensemble_workers`; an
    // unmapped, unreferenced spec field fails at its line
    let spec = "pub struct CampaignSpec {\n    pub workers: usize,\n    pub sneaky_knob: bool,\n}\n";
    let fp = "pub fn fingerprint(setup: &TuneSetup) -> String {\n    let _ = (setup.app, setup.seed, setup.ensemble_workers);\n    String::new()\n}\n";
    let diags = check_files(&[
        fx("coordinator/mod.rs", MINI_SETUP_COVERED),
        fx("ensemble/checkpoint.rs", fp),
        fx("service/protocol.rs", spec),
    ]);
    assert_eq!(hits(&diags), vec![(3, Rule::FingerprintCoverage)], "{}", render(&diags));
    assert!(diags[0].message.contains("sneaky_knob"), "{}", render(&diags));
    assert_eq!(diags[0].path, "service/protocol.rs");
}

// ---------------------------------------------------------------------------
// the allow escape hatch

#[test]
fn a_trailing_allow_with_a_reason_suppresses_its_line() {
    let diags = check_files(&[fx(
        "search/fixture.rs",
        "use std::collections::HashSet; // detlint: allow(hash-order) -- membership only; never iterated\n",
    )]);
    assert!(diags.is_empty(), "{}", render(&diags));
}

#[test]
fn a_standalone_allow_with_a_reason_shields_the_next_code_line() {
    let diags = check_files(&[fx(
        "search/fixture.rs",
        "// detlint: allow(hash-order) -- membership only; never iterated\nuse std::collections::HashSet;\n",
    )]);
    assert!(diags.is_empty(), "{}", render(&diags));
}

#[test]
fn an_allow_without_a_reason_is_rejected_and_suppresses_nothing() {
    let diags = check_files(&[fx(
        "search/fixture.rs",
        "use std::collections::HashSet; // detlint: allow(hash-order)\n",
    )]);
    assert_eq!(
        hits(&diags),
        vec![(1, Rule::HashOrder), (1, Rule::AllowSyntax)],
        "{}",
        render(&diags)
    );
}

#[test]
fn an_unknown_rule_name_in_an_allow_is_an_error() {
    let diags = check_files(&[fx(
        "search/fixture.rs",
        "use std::collections::HashSet; // detlint: allow(hash-disorder) -- sounds right\n",
    )]);
    assert_eq!(
        hits(&diags),
        vec![(1, Rule::HashOrder), (1, Rule::AllowSyntax)],
        "{}",
        render(&diags)
    );
    assert!(diags.iter().any(|d| d.message.contains("hash-disorder")), "{}", render(&diags));
}

#[test]
fn an_allow_does_not_leak_to_other_lines_or_rules() {
    // shielded line 1, unshielded line 2; and a hash-order allow must
    // not hide a wall-clock hit on its own line
    let diags = check_files(&[fx(
        "search/fixture.rs",
        "use std::collections::HashSet; // detlint: allow(hash-order) -- pinned\nlet s: HashSet<u32> = HashSet::new();\nlet t = std::time::Instant::now(); // detlint: allow(hash-order) -- wrong rule\n",
    )]);
    assert_eq!(
        hits(&diags),
        vec![(2, Rule::HashOrder), (3, Rule::WallClock)],
        "{}",
        render(&diags)
    );
}
