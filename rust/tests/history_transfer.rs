//! End-to-end tests for the cross-run tuning-history database and its
//! transfer-learning warm starts, plus the mid-trajectory resume
//! contract the persisted proposal state provides:
//!
//! * a warm-started run is seed-for-seed deterministic *given the same
//!   store contents*, and actually differs from a cold start (the
//!   transfer is wired, not decorative);
//! * warm-starting from a store with no space-compatible run is refused
//!   with a clear error naming the fingerprints;
//! * a warm-started search reaches the seed run's best-so-far in fewer
//!   evaluations than a cold start on the synthetic app;
//! * kill-mid-run → resume produces *bit-identical* post-resume
//!   proposals (the mid-trajectory resume gap PR 3 documented);
//! * a federation warm-starts every shard from one store without
//!   double-absorbing elites, and never re-proposes a transferred
//!   configuration;
//! * resuming a warm-started run against a store whose contents changed
//!   is refused (the resolved prior is part of the run fingerprint).

use std::path::PathBuf;
use std::sync::Arc;

use ytopt::apps::AppKind;
use ytopt::coordinator::{autotune_with_scorer, TuneResult, TuneSetup};
use ytopt::history::{space_fingerprint, top_k_elites, HistoryStore, RunRecord};
use ytopt::metrics::Metric;
use ytopt::platform::PlatformKind;
use ytopt::runtime::Scorer;
use ytopt::space::paper;

fn run(setup: &TuneSetup) -> TuneResult {
    autotune_with_scorer(setup, Arc::new(Scorer::fallback())).unwrap()
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("ytopt-ht-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn tmpfile(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("ytopt-ht-{tag}-{}.json", std::process::id()))
}

/// The host-timing-free view of a run's history (same projection the
/// ensemble e2e suite pins): everything that must be bit-identical
/// across deterministic replays.
fn history(r: &TuneResult) -> Vec<(usize, String, u64, u64, u64, bool, bool)> {
    r.db.records
        .iter()
        .map(|x| {
            (
                x.id,
                x.config_key.clone(),
                x.objective.to_bits(),
                x.measured.runtime_s.to_bits(),
                x.best_so_far.to_bits(),
                x.timed_out,
                x.cancelled,
            )
        })
        .collect()
}

/// Evaluations until the run's finite best first reaches `target`
/// (1-based), or `budget + 1` when it never does.
fn evals_to_target(r: &TuneResult, target: f64, budget: usize) -> usize {
    let mut best = f64::INFINITY;
    for (i, rec) in r.db.records.iter().enumerate() {
        if !rec.timed_out && rec.objective.is_finite() {
            best = best.min(rec.objective);
        }
        if best <= target {
            return i + 1;
        }
    }
    budget + 1
}

fn seed_setup(store: &std::path::Path) -> TuneSetup {
    let mut s = TuneSetup::new(AppKind::XSBenchHistory, PlatformKind::Theta, 1, Metric::Runtime);
    s.max_evals = 14;
    s.wallclock_budget_s = 1e9;
    s.seed = 5;
    s.history_dir = Some(store.to_path_buf());
    s
}

/// (a) Same store contents + same seed => one history, bit for bit; and
/// the warm start demonstrably steers the search (it differs from cold).
#[test]
fn warm_start_is_deterministic_given_the_same_store() {
    let store = tmpdir("determinism");
    let seed_run = run(&seed_setup(&store));
    assert!(seed_run.evaluations > 0);
    assert_eq!(HistoryStore::open(&store).unwrap().load_all().unwrap().len(), 1);

    let mut warm = TuneSetup::new(AppKind::XSBenchHistory, PlatformKind::Theta, 1, Metric::Runtime);
    warm.max_evals = 16;
    warm.wallclock_budget_s = 1e9;
    warm.seed = 9;
    warm.ensemble_workers = 4;
    warm.warm_start_from = Some(store.clone());
    warm.warm_start_elites = 8;

    let a = run(&warm);
    let b = run(&warm);
    assert_eq!(a.evaluations, 16);
    assert_eq!(
        history(&a),
        history(&b),
        "warm-started run must be seed-for-seed deterministic given the same store"
    );
    assert_eq!(a.best_objective.to_bits(), b.best_objective.to_bits());

    // the transfer is wired: a cold run at the same seed walks a
    // different trajectory
    let mut cold = warm.clone();
    cold.warm_start_from = None;
    let c = run(&cold);
    assert_ne!(history(&a), history(&c), "warm start changed nothing — transfer unwired?");

    std::fs::remove_dir_all(&store).unwrap();
}

/// (b) A store with no space-compatible run is refused with an error
/// naming the fingerprints — silently cold-starting would misreport a
/// transfer experiment.
#[test]
fn warm_start_refuses_mismatched_space_fingerprint() {
    let store = tmpdir("mismatch");
    let _ = run(&seed_setup(&store)); // XSBench-history records only

    // AMG's space has a different fingerprint: refuse, don't cold-start
    let mut other = TuneSetup::new(AppKind::Amg, PlatformKind::Theta, 64, Metric::Runtime);
    other.max_evals = 4;
    other.wallclock_budget_s = 1e9;
    other.warm_start_from = Some(store.clone());
    let err = match autotune_with_scorer(&other, Arc::new(Scorer::fallback())) {
        Err(e) => e,
        Ok(_) => panic!("mismatched space fingerprint must be refused"),
    };
    let msg = format!("{err:#}");
    assert!(
        msg.contains("compatible space fingerprint"),
        "refusal must explain the fingerprint mismatch, got: {msg}"
    );
    let amg_fp = space_fingerprint(&paper::build_space(AppKind::Amg, PlatformKind::Theta));
    assert!(msg.contains(&amg_fp), "refusal must name the wanted fingerprint, got: {msg}");

    // an empty-but-existing store is refused too (nothing to transfer
    // is an error, not a silent cold start) ...
    let empty = tmpdir("mismatch-empty");
    std::fs::create_dir_all(&empty).unwrap();
    let mut e = other.clone();
    e.warm_start_from = Some(empty.clone());
    assert!(autotune_with_scorer(&e, Arc::new(Scorer::fallback())).is_err());
    // ... and a missing store path errors without being mkdir'd as a
    // side effect of what should be a pure read
    let missing = tmpdir("mismatch-missing"); // removed, never created
    let mut m = other.clone();
    m.warm_start_from = Some(missing.clone());
    assert!(autotune_with_scorer(&m, Arc::new(Scorer::fallback())).is_err());
    assert!(!missing.exists(), "warm-start resolution must not create the store");

    // the metric is compatibility too: an Energy-metric history must
    // not seed a Runtime search on the identical space (joules are not
    // seconds)
    let estore = tmpdir("mismatch-metric");
    let mut eseed = TuneSetup::new(AppKind::Amg, PlatformKind::Theta, 64, Metric::Energy);
    eseed.max_evals = 8;
    eseed.wallclock_budget_s = 1e9;
    eseed.history_dir = Some(estore.clone());
    let _ = run(&eseed);
    let mut rt = TuneSetup::new(AppKind::Amg, PlatformKind::Theta, 64, Metric::Runtime);
    rt.max_evals = 4;
    rt.wallclock_budget_s = 1e9;
    rt.warm_start_from = Some(estore.clone());
    assert!(
        autotune_with_scorer(&rt, Arc::new(Scorer::fallback())).is_err(),
        "an energy-metric history must not warm-start a runtime search"
    );
    std::fs::remove_dir_all(&estore).unwrap();

    // the elite-count range check lives at the library level, so a
    // config file (which bypasses the CLI validator) gets the same rule
    let mut z = TuneSetup::new(AppKind::XSBenchHistory, PlatformKind::Theta, 1, Metric::Runtime);
    z.max_evals = 4;
    z.wallclock_budget_s = 1e9;
    z.warm_start_from = Some(store.clone());
    for bad in [0usize, 65] {
        z.warm_start_elites = bad;
        assert!(
            autotune_with_scorer(&z, Arc::new(Scorer::fallback())).is_err(),
            "warm_start_elites = {bad} must be refused"
        );
    }

    std::fs::remove_dir_all(&store).unwrap();
    std::fs::remove_dir_all(&empty).unwrap();
}

/// (c) Transfer pays: on SW4lite (the barrier-cliff landscape), a
/// warm-started search reaches the seed run's best-so-far in fewer
/// evaluations than a cold start. Summed over three seed pairs so one
/// lucky cold draw cannot flip the verdict; the per-pair gate lives in
/// `benches/ensemble.rs`.
#[test]
fn warm_start_reaches_the_seed_best_in_fewer_evaluations() {
    let store = tmpdir("converge");
    let mut seed_run = TuneSetup::new(AppKind::Sw4lite, PlatformKind::Theta, 1024, Metric::Runtime);
    seed_run.max_evals = 12;
    seed_run.wallclock_budget_s = 1e9;
    seed_run.seed = 101;
    seed_run.history_dir = Some(store.clone());
    let r_seed = run(&seed_run);
    let target = r_seed.best_objective;
    assert!(target.is_finite());

    let budget = 30usize;
    let mut warm_total = 0usize;
    let mut cold_total = 0usize;
    for seed in [211u64, 212, 213] {
        let mut cold = TuneSetup::new(AppKind::Sw4lite, PlatformKind::Theta, 1024, Metric::Runtime);
        cold.max_evals = budget;
        cold.wallclock_budget_s = 1e9;
        cold.seed = seed;
        let mut warm = cold.clone();
        warm.warm_start_from = Some(store.clone());
        // transfer the full seed history (12 evals < 32): the warm
        // surrogate starts where the seed run's ended
        warm.warm_start_elites = 32;
        let rc = run(&cold);
        let rw = run(&warm);
        let ec = evals_to_target(&rc, target, budget);
        let ew = evals_to_target(&rw, target, budget);
        warm_total += ew;
        cold_total += ec;
        println!("seed {seed}: warm reached target in {ew}, cold in {ec} (of {budget})");
    }
    assert!(
        warm_total < cold_total,
        "warm start must reach the seed best in strictly fewer evaluations \
         (warm {warm_total} vs cold {cold_total} summed over 3 seeds)"
    );

    std::fs::remove_dir_all(&store).unwrap();
}

/// (d) The single-manager mid-trajectory resume gap PR 3 documented is
/// closed: kill the continuous manager mid-run (simulated SIGKILL after
/// the apply-6 checkpoint), resume, and the history — including every
/// fresh post-resume proposal beyond the re-queued in-flight work — is
/// bit-identical to the uninterrupted run's.
#[test]
fn continuous_kill_mid_run_resume_is_bit_identical() {
    let ckpt = tmpfile("kill-resume");
    let _ = std::fs::remove_file(&ckpt);

    let mut s = TuneSetup::new(AppKind::XSBenchHistory, PlatformKind::Theta, 1, Metric::Runtime);
    s.max_evals = 16;
    s.wallclock_budget_s = 1e9;
    s.seed = 23;
    s.n_init = 4;
    s.ensemble_workers = 4;

    let full = run(&s);
    assert_eq!(full.evaluations, 16);

    let mut killed = s.clone();
    killed.checkpoint_path = Some(ckpt.clone());
    killed.kill_after_evals = Some(6);
    let partial = run(&killed);
    assert_eq!(partial.evaluations, 6, "the kill must land right after the 6th apply");
    assert_eq!(
        history(&full)[..6].to_vec(),
        history(&partial),
        "killed session must record exactly the uninterrupted prefix"
    );

    let mut resumed = s.clone();
    resumed.checkpoint_path = Some(ckpt.clone());
    let r = run(&resumed);
    assert_eq!(r.evaluations, 16);
    let es = r.ensemble.as_ref().unwrap();
    assert_eq!(es.resumed_evals, 6);
    // with 4 workers at most 4 evaluations were in flight at the kill:
    // at least 6 of the 10 post-resume records are *fresh* proposals
    assert_eq!(
        history(&full),
        history(&r),
        "post-resume proposals must be bit-identical to the uninterrupted run"
    );
    assert_eq!(full.best_objective.to_bits(), r.best_objective.to_bits());

    std::fs::remove_file(&ckpt).unwrap();
}

/// Federation + warm start: every shard absorbs the same store prior
/// once (the absorbed-elite dedup set is seeded with it, so elite
/// exchange cannot double-absorb), no transferred configuration is ever
/// re-proposed, and the whole campaign stays deterministic.
#[test]
fn federated_warm_start_shares_the_store_without_double_absorbing() {
    let store = tmpdir("fed-warm");
    let seed_run = run(&seed_setup(&store));
    assert!(seed_run.evaluations > 0);

    let elites = {
        let all = HistoryStore::open(&store).unwrap().load_all().unwrap();
        let views: Vec<&RunRecord> = all.iter().collect();
        top_k_elites(&views, 6)
    };
    assert!(!elites.is_empty());

    let mut fed = TuneSetup::new(AppKind::XSBenchHistory, PlatformKind::Theta, 1, Metric::Runtime);
    fed.max_evals = 12;
    fed.wallclock_budget_s = 1e9;
    fed.seed = 31;
    fed.n_init = 4;
    fed.ensemble_workers = 2;
    fed.federation_shards = 2;
    fed.elite_exchange_every = 2;
    fed.federation_elites = 2;
    fed.warm_start_from = Some(store.clone());
    fed.warm_start_elites = 6;

    let a = run(&fed);
    let b = run(&fed);
    assert_eq!(a.evaluations, 12);
    assert_eq!(history(&a), history(&b), "warm-started federation must be deterministic");
    // transferred elites are marked seen in every shard: none may be
    // re-evaluated by either partition
    for rec in &a.db.records {
        for (cfg, _) in &elites {
            assert_ne!(
                rec.config_key,
                cfg.key(),
                "transferred elite was re-proposed by a federation shard"
            );
        }
    }

    std::fs::remove_dir_all(&store).unwrap();
}

/// The resolved warm-start prior is run identity: resuming a
/// warm-started campaign after the store contents changed underneath it
/// is refused (the checkpoint fingerprint pins the resolved elites).
#[test]
fn resume_is_refused_when_the_warm_store_contents_change() {
    let store = tmpdir("store-drift");
    let ckpt = tmpfile("store-drift");
    let _ = std::fs::remove_file(&ckpt);
    let _ = run(&seed_setup(&store));

    let mut warm = TuneSetup::new(AppKind::XSBenchHistory, PlatformKind::Theta, 1, Metric::Runtime);
    warm.max_evals = 8;
    warm.wallclock_budget_s = 1e9;
    warm.seed = 13;
    warm.ensemble_workers = 2;
    warm.warm_start_from = Some(store.clone());
    warm.checkpoint_path = Some(ckpt.clone());
    let first = run(&warm);
    assert_eq!(first.evaluations, 8);

    // same store: resuming with a larger budget is the normal use
    let mut more = warm.clone();
    more.max_evals = 10;
    let resumed = run(&more);
    assert_eq!(resumed.ensemble.as_ref().unwrap().resumed_evals, 8);

    // drift the store: a strictly better record displaces the old elites
    let hs = HistoryStore::open(&store).unwrap();
    let mut better = hs.load_all().unwrap().into_iter().next().unwrap();
    better.seed += 1;
    for e in &mut better.evals {
        if e.objective.is_finite() {
            e.objective *= 0.5;
        }
    }
    better.best_objective *= 0.5;
    hs.append(&better).unwrap();

    let mut drifted = warm.clone();
    drifted.max_evals = 12;
    let err = autotune_with_scorer(&drifted, Arc::new(Scorer::fallback()));
    assert!(
        err.is_err(),
        "resume against a drifted warm-start store must be refused, not absorbed"
    );

    std::fs::remove_dir_all(&store).unwrap();
    std::fs::remove_file(&ckpt).unwrap();
}
