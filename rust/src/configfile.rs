//! TOML-subset experiment-configuration parser (no serde/toml offline).
//!
//! Supports the subset the launcher needs: `[section]` headers, `key =
//! value` with string/int/float/bool/array-of-scalar values, `#` comments.
//! Used by `ytopt-rs tune --config <file>` and the examples.

use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// A parsed config document: `section.key -> value`; keys before any
/// section header live in the "" (root) section.
#[derive(Debug, Default, Clone)]
pub struct ConfigDoc {
    sections: BTreeMap<String, BTreeMap<String, Value>>,
}

#[derive(Debug)]
pub struct ConfigError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ConfigError {}

impl ConfigDoc {
    pub fn parse(text: &str) -> Result<ConfigDoc, ConfigError> {
        let mut doc = ConfigDoc::default();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            let err = |msg: &str| ConfigError { line: ln + 1, msg: msg.to_string() };
            if let Some(name) = line.strip_prefix('[') {
                let name = name.strip_suffix(']').ok_or_else(|| err("unterminated section"))?;
                section = name.trim().to_string();
                if section.is_empty() {
                    return Err(err("empty section name"));
                }
                doc.sections.entry(section.clone()).or_default();
            } else if let Some(eq) = line.find('=') {
                let key = line[..eq].trim().to_string();
                if key.is_empty() {
                    return Err(err("empty key"));
                }
                let value = parse_value(line[eq + 1..].trim()).map_err(|m| err(&m))?;
                doc.sections.entry(section.clone()).or_default().insert(key, value);
            } else {
                return Err(err("expected `key = value` or `[section]`"));
            }
        }
        Ok(doc)
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<ConfigDoc> {
        let text = std::fs::read_to_string(path)?;
        Ok(Self::parse(&text)?)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    pub fn str_or<'a>(&'a self, section: &str, key: &str, default: &'a str) -> &'a str {
        self.get(section, key).and_then(Value::as_str).unwrap_or(default)
    }

    pub fn int_or(&self, section: &str, key: &str, default: i64) -> i64 {
        self.get(section, key).and_then(Value::as_int).unwrap_or(default)
    }

    pub fn float_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(Value::as_float).unwrap_or(default)
    }

    /// Non-negative count (worker/batch sizes); negative values fall back
    /// to the default rather than wrapping.
    pub fn usize_or(&self, section: &str, key: &str, default: usize) -> usize {
        match self.get(section, key).and_then(Value::as_int) {
            Some(i) if i >= 0 => i as usize,
            _ => default,
        }
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(Value::as_bool).unwrap_or(default)
    }

    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(String::as_str)
    }
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a double-quoted string
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    let s = s.trim();
    if s.is_empty() {
        return Err("missing value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Value::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?;
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in split_top_level(inner) {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(Value::Array(items));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value `{s}`"))
}

/// Split a flat array body on commas (strings may contain commas).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"
# experiment config
title = "xsbench large scale"

[tune]
app = "xsbench"          # which proxy app
platform = "Theta"
nodes = 4096
max_evals = 128
wallclock_s = 1800.0
parallel = false
seeds = [1, 2, 3]
"#;

    #[test]
    fn parses_typed_values() {
        let doc = ConfigDoc::parse(DOC).unwrap();
        assert_eq!(doc.str_or("", "title", ""), "xsbench large scale");
        assert_eq!(doc.str_or("tune", "app", ""), "xsbench");
        assert_eq!(doc.int_or("tune", "nodes", 0), 4096);
        assert!((doc.float_or("tune", "wallclock_s", 0.0) - 1800.0).abs() < 1e-12);
        assert!(!doc.bool_or("tune", "parallel", true));
        match doc.get("tune", "seeds") {
            Some(Value::Array(a)) => assert_eq!(a.len(), 3),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn defaults_apply_for_missing_keys() {
        let doc = ConfigDoc::parse(DOC).unwrap();
        assert_eq!(doc.int_or("tune", "missing", 7), 7);
        assert_eq!(doc.str_or("nope", "x", "d"), "d");
    }

    #[test]
    fn usize_or_clamps_semantics() {
        let doc = ConfigDoc::parse("[ensemble]\nworkers = 8\nbatch = -2").unwrap();
        assert_eq!(doc.usize_or("ensemble", "workers", 0), 8);
        // negative counts fall back to the default instead of wrapping
        assert_eq!(doc.usize_or("ensemble", "batch", 4), 4);
        assert_eq!(doc.usize_or("ensemble", "missing", 3), 3);
    }

    #[test]
    fn comments_and_strings_interact() {
        let doc = ConfigDoc::parse(r##"k = "a # not comment" # real comment"##).unwrap();
        assert_eq!(doc.str_or("", "k", ""), "a # not comment");
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(ConfigDoc::parse("[unterminated").is_err());
        assert!(ConfigDoc::parse("novalue").is_err());
        assert!(ConfigDoc::parse("k = ").is_err());
        assert!(ConfigDoc::parse("k = \"open").is_err());
        assert!(ConfigDoc::parse("= v").is_err());
    }

    #[test]
    fn float_and_int_distinction() {
        let doc = ConfigDoc::parse("a = 2\nb = 2.5").unwrap();
        assert_eq!(doc.get("", "a"), Some(&Value::Int(2)));
        assert_eq!(doc.get("", "b"), Some(&Value::Float(2.5)));
        // ints coerce to float on request
        assert_eq!(doc.float_or("", "a", 0.0), 2.0);
    }
}
