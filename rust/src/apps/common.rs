//! Shared landscape ingredients for the application models.
//!
//! Each helper encodes one effect the paper's tuning knobs exercise;
//! individual app models combine them with app-specific sensitivities.

use crate::platform::PlatformKind;
use crate::space::{ConfigSpace, Configuration};
use crate::util::Pcg32;

/// The four OpenMP runtime env vars every Table III space carries.
#[derive(Debug, Clone)]
pub struct OmpEnv {
    pub threads: i64,
    pub places: String,
    pub bind: String,
    pub schedule: String,
}

pub fn omp_env(space: &ConfigSpace, cfg: &Configuration) -> OmpEnv {
    OmpEnv {
        threads: space.int_value(cfg, "OMP_NUM_THREADS"),
        places: space.str_value(cfg, "OMP_PLACES"),
        bind: space.str_value(cfg, "OMP_PROC_BIND"),
        schedule: space.str_value(cfg, "OMP_SCHEDULE"),
    }
}

/// Parallel speedup of `n` threads on `cores` physical cores.
///
/// Amdahl with serial fraction `serial`; hyperthreads past the physical
/// core count contribute with `smt_yield` effectiveness that saturates as
/// oversubscription grows (KNL/Power9 4-way SMT gives small, diminishing
/// returns on these memory-bound kernels).
pub fn thread_speedup(n: f64, cores: f64, serial: f64, smt_yield: f64) -> f64 {
    assert!(n >= 1.0);
    let phys = n.min(cores);
    let extra = (n - cores).max(0.0);
    let eff = phys + smt_yield * extra / (1.0 + extra / cores);
    1.0 / (serial + (1.0 - serial) / eff)
}

/// Affinity (OMP_PLACES x OMP_PROC_BIND) runtime multiplier, >= ~1.
///
/// `sensitivity` in [0, 1] scales how strongly the app reacts.
/// The pathological corner the paper hits on AMG (Fig. 12): with
/// `places=threads` + `bind=master` every thread is bound into the master
/// place partition; past a handful of threads they serialize on a few
/// cores sharing L2 — the observed ~40x blowup at 48 threads.
pub fn affinity_factor(env: &OmpEnv, cores: f64, sensitivity: f64) -> f64 {
    let n = env.threads as f64;
    let raw = match (env.places.as_str(), env.bind.as_str()) {
        ("threads", "master") => {
            if n <= 8.0 {
                1.0 + 0.05 * n
            } else {
                // threads pile onto the master place: progressive
                // serialization, saturating around ~44x
                1.0 + 44.0 * (1.0 - (-(n - 8.0) / 24.0).exp())
            }
        }
        ("cores", "master") => 1.12,
        ("sockets", "master") => 1.06,
        ("threads", "close") => 1.02, // packs SMT siblings first
        ("threads", "spread") => 1.0,
        ("cores", "close") => 1.0, // the sane default
        ("cores", "spread") => 0.995,
        ("sockets", "close") => 1.01,
        ("sockets", "spread") => 0.99, // best for bandwidth-bound kernels
        _ => 1.0,
    };
    // interpolate between "insensitive" (1.0) and the raw factor
    1.0 + sensitivity * (raw - 1.0) * (n / cores).clamp(0.25, 1.5)
}

/// OMP_SCHEDULE multiplier for a loop with `trips` iterations per thread,
/// intrinsic load `imbalance` (fractional runtime cost under static), and
/// per-dispatch `dispatch_cost` (fractional cost of one dynamic dispatch).
pub fn schedule_factor(
    schedule: &str,
    chunk: f64,
    trips: f64,
    imbalance: f64,
    dispatch_cost: f64,
) -> f64 {
    match schedule {
        "static" => 1.0 + imbalance,
        "dynamic" => {
            let dispatches = (trips / chunk.max(1.0)).max(1.0);
            // residual imbalance grows again once chunks get too coarse
            let residual = imbalance * (chunk / trips).clamp(0.0, 1.0);
            1.0 + dispatch_cost * dispatches + residual
        }
        "auto" => 1.0 + 0.35 * imbalance,
        _ => 1.0,
    }
}

/// Count how many of the `base_<i>` toggle sites are enabled.
pub fn toggles_on(space: &ConfigSpace, cfg: &Configuration, base: &str, sites: usize) -> usize {
    (0..sites)
        .filter(|i| space.int_value(cfg, &format!("{base}_{i}")) == 1)
        .count()
}

/// Deterministic multiplicative run-to-run noise (~lognormal, sigma).
///
/// Keyed by the configuration identity and the evaluation seed so a
/// repeated evaluation of the same point jitters like a real re-run.
pub fn run_noise(cfg: &Configuration, seed: u64, sigma: f64) -> f64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &i in cfg.indices() {
        h ^= i as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h ^= seed.wrapping_mul(0x9e3779b97f4a7c15);
    let mut rng = Pcg32::seeded(h);
    (sigma * rng.normal()).exp()
}

/// Package+DRAM power for a CPU phase.
///
/// `active_frac` = busy logical share of the node, `intensity` in [0,1]
/// (compute vs stall mix), `mem_frac` in [0,1] DRAM traffic share.
/// KNL idles near ~68 W package; Power9 nodes (2 sockets) near ~120 W.
pub fn cpu_power(
    platform: PlatformKind,
    active_frac: f64,
    intensity: f64,
    mem_frac: f64,
) -> (f64, f64) {
    let (idle, dynamic_max, dram_idle, dram_max) = match platform {
        PlatformKind::Theta => (68.0, 150.0, 6.0, 24.0),
        PlatformKind::Summit => (120.0, 265.0, 10.0, 34.0),
    };
    let a = active_frac.clamp(0.0, 1.0);
    let pkg = idle + dynamic_max * a.powf(0.85) * intensity.clamp(0.1, 1.0);
    let dram = dram_idle + dram_max * a * mem_frac.clamp(0.0, 1.0);
    (pkg, dram)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::{Param, ParamDomain};

    #[test]
    fn speedup_monotone_up_to_cores() {
        let mut prev = 0.0;
        for n in [1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0] {
            let s = thread_speedup(n, 64.0, 0.002, 0.05);
            assert!(s > prev);
            prev = s;
        }
        // SMT yields a little more, but far less than linear
        let s64 = thread_speedup(64.0, 64.0, 0.002, 0.05);
        let s256 = thread_speedup(256.0, 64.0, 0.002, 0.05);
        assert!(s256 > s64);
        assert!(s256 < s64 * 1.12);
    }

    #[test]
    fn master_threads_corner_is_pathological() {
        let env = OmpEnv {
            threads: 48,
            places: "threads".into(),
            bind: "master".into(),
            schedule: "dynamic".into(),
        };
        let f = affinity_factor(&env, 64.0, 1.0);
        assert!(f > 20.0, "expected pathological blowup, got {f}");
        let sane = OmpEnv { places: "cores".into(), bind: "close".into(), ..env };
        assert!(affinity_factor(&sane, 64.0, 1.0) < 1.05);
    }

    #[test]
    fn dynamic_schedule_has_chunk_sweet_spot() {
        // tiny chunks pay dispatch, huge chunks pay imbalance
        let f10 = schedule_factor("dynamic", 10.0, 10_000.0, 0.04, 3e-5);
        let f150 = schedule_factor("dynamic", 150.0, 10_000.0, 0.04, 3e-5);
        let f5000 = schedule_factor("dynamic", 5_000.0, 10_000.0, 0.04, 3e-5);
        assert!(f150 < f10, "{f150} !< {f10}");
        assert!(f150 < f5000, "{f150} !< {f5000}");
        // static pays the full imbalance
        assert!(schedule_factor("static", 0.0, 10_000.0, 0.04, 3e-5) > f150);
    }

    #[test]
    fn noise_is_deterministic_and_small() {
        let cfg = Configuration::from_indices(vec![1, 2, 3]);
        let a = run_noise(&cfg, 7, 0.008);
        let b = run_noise(&cfg, 7, 0.008);
        assert_eq!(a, b);
        assert!((a - 1.0).abs() < 0.05);
        let c = run_noise(&cfg, 8, 0.008);
        assert_ne!(a, c);
    }

    #[test]
    fn cpu_power_within_tdp_envelope() {
        let (pkg, dram) = cpu_power(PlatformKind::Theta, 1.0, 1.0, 1.0);
        assert!(pkg <= 218.0 + 1e-9, "KNL package {pkg} exceeds TDP");
        assert!(dram <= 30.0);
        let (idle_pkg, _) = cpu_power(PlatformKind::Theta, 0.0, 1.0, 0.0);
        assert!((55.0..80.0).contains(&idle_pkg));
    }

    #[test]
    fn toggles_counted() {
        let mut s = ConfigSpace::new("t");
        s.add(Param::new("u_0", ParamDomain::Toggle));
        s.add(Param::new("u_1", ParamDomain::Toggle));
        s.add(Param::new("u_2", ParamDomain::Toggle));
        let cfg = Configuration::from_indices(vec![1, 0, 1]);
        assert_eq!(toggles_on(&s, &cfg, "u", 3), 2);
    }
}
