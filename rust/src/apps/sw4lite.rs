//! SW4lite performance/power model.
//!
//! SW4lite runs the LOH.1-h50 seismic-wave problem (grid 30000 x 30000 x
//! 17000 m at h=50) with 4th-order finite differences: the paper's
//! *strong-scaling* application (§III-A2). Runtime = stencil compute
//! (shrinks with node count) + per-timestep halo exchange (grows with
//! node count).
//!
//! Calibration (pinned by tests):
//!   Theta 1024 nodes:  baseline 171.595 s — compute ~3.4 s + ~168.2 s of
//!     desynchronized communication; inserting
//!     `MPI_Barrier(MPI_COMM_WORLD)` per timestep collapses the comm term,
//!     best ~14.427 s (-91.59%, Fig 14). Baseline node energy ~= 8384 J
//!     (the comm phase idles near ~45 W — the paper's own explanation of
//!     why the energy saving (21.2%) trails the runtime saving).
//!   Summit 1024 nodes: baseline 11.067 s -> best ~7.661 s (-30.78%,
//!     Fig 13): no desync catastrophe on EDR InfiniBand; gains come from
//!     `#pragma omp for nowait` comm/compute overlap, unrolls and SMT.
//!
//! The Theta blowup reproduces the paper's diagnosis: the improved
//! SW4lite [64] parameter space exists precisely because the original
//! code's unsynchronized progression lets ranks drift a full timestep
//! apart on the dragonfly, and every halo exchange then waits on the
//! slowest rank's previous step.

use super::common::{self};
use super::{AppKind, AppModel, AppRun, EvalContext, PowerPhase};
use crate::platform::network::Network;
use crate::platform::PlatformKind;
use crate::space::{ConfigSpace, Configuration};

pub struct Sw4lite;

struct PlatCal {
    compute_s: f64,     // stencil compute at baseline threads, 1024 nodes
    comm_base_s: f64,   // synchronized comm at 1024 nodes
    desync_comm_s: f64, // extra desynchronized comm without barrier
    pkg_compute: f64,
    dram_compute: f64,
    pkg_comm: f64,
    dram_comm: f64,
}

const UNROLL6_GAIN: f64 = 0.985; // 3 sites: rhs4 stencil rows
const PF_GAINS: [f64; 5] = [0.96, 0.97, 0.98, 0.99, 0.995];

impl Sw4lite {
    pub fn new() -> Self {
        Sw4lite
    }

    fn cal(platform: PlatformKind) -> PlatCal {
        match platform {
            PlatformKind::Theta => PlatCal {
                compute_s: 3.43,
                comm_base_s: 11.2,
                desync_comm_s: 157.0, // applied iff the fabric collapses
                pkg_compute: 200.0,
                dram_compute: 24.0,
                pkg_comm: 40.0,
                dram_comm: 5.3,
            },
            PlatformKind::Summit => PlatCal {
                compute_s: 6.6,
                comm_base_s: 4.467,
                desync_comm_s: 157.0, // gated off: EDR has no catastrophe
                pkg_compute: 340.0,
                dram_compute: 30.0,
                pkg_comm: 150.0,
                dram_comm: 10.0,
            },
        }
    }

    fn baseline_threads(platform: PlatformKind) -> f64 {
        match platform {
            PlatformKind::Theta => 64.0,
            PlatformKind::Summit => 168.0,
        }
    }

    /// Strong scaling: compute shrinks with nodes, comm grows slowly.
    fn compute_scale(nodes: u64) -> f64 {
        1024.0 / nodes.max(1) as f64
    }

    /// Desynchronized halo term: only fabrics that collapse pay it.
    fn desync_comm(cal: &PlatCal, net: Network, nodes: u64) -> f64 {
        if net.halo_desync_catastrophe() {
            cal.desync_comm_s * net.desync_scale(nodes, 1024)
        } else {
            0.0
        }
    }

    fn thread_factor(threads: f64, platform: PlatformKind) -> f64 {
        let cores = platform.spec().cpu_cores_per_node as f64;
        let s = |n: f64| common::thread_speedup(n, cores, 0.01, 0.08);
        s(Self::baseline_threads(platform)) / s(threads)
    }

    fn build(&self, compute: f64, comm: f64, cal: &PlatCal) -> AppRun {
        AppRun::from_phases(vec![
            PowerPhase {
                label: "stencil",
                duration_s: compute,
                pkg_w: cal.pkg_compute,
                dram_w: cal.dram_compute,
            },
            PowerPhase {
                label: "halo",
                duration_s: comm,
                pkg_w: cal.pkg_comm,
                dram_w: cal.dram_comm,
            },
        ])
    }
}

impl AppModel for Sw4lite {
    fn kind(&self) -> AppKind {
        AppKind::Sw4lite
    }

    fn baseline(&self, ctx: &EvalContext) -> AppRun {
        let cal = Self::cal(ctx.platform);
        let net = Network::of(ctx.platform);
        let compute = cal.compute_s * Self::compute_scale(ctx.nodes);
        // original code: no barrier -> full desync where the fabric collapses
        let comm = cal.comm_base_s * net.halo_scale(ctx.nodes, 1024)
            + Self::desync_comm(&cal, net, ctx.nodes);
        self.build(compute, comm, &cal)
    }

    fn run(&self, space: &ConfigSpace, cfg: &Configuration, ctx: &EvalContext) -> AppRun {
        let cal = Self::cal(ctx.platform);
        let env = common::omp_env(space, cfg);
        let cores = ctx.platform.spec().cpu_cores_per_node as f64;

        let mut compute = cal.compute_s
            * Self::compute_scale(ctx.nodes)
            * Self::thread_factor(env.threads as f64, ctx.platform);
        for i in 0..3 {
            if space.int_value(cfg, &format!("unroll6_{i}")) == 1 {
                compute *= UNROLL6_GAIN;
            }
        }
        for (i, g) in PF_GAINS.iter().enumerate() {
            if space.int_value(cfg, &format!("parallel_for_{i}")) == 1 {
                compute *= g;
            }
        }
        compute *= common::affinity_factor(&env, cores, 0.55);
        compute *= match env.schedule.as_str() {
            "static" => 1.0,
            "dynamic" => 1.02,
            _ => 1.006,
        };

        let net = Network::of(ctx.platform);
        let barrier = space.int_value(cfg, "mpi_barrier_0") == 1;
        let mut comm = cal.comm_base_s * net.halo_scale(ctx.nodes, 1024);
        if barrier {
            comm *= net.barrier_cost();
        } else {
            comm += Self::desync_comm(&cal, net, ctx.nodes);
        }
        let nowaits = common::toggles_on(space, cfg, "for_nowait", 4);
        comm *= net.overlap_gain().powi(nowaits as i32);

        let noise = common::run_noise(cfg, ctx.noise_seed, 0.008);
        self.build(compute * noise, comm * noise, &cal)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::paper::build_space;
    use crate::util::Pcg32;

    #[test]
    fn theta_baseline_matches_fig14() {
        let model = Sw4lite::new();
        let run = model.baseline(&EvalContext::new(PlatformKind::Theta, 1024));
        assert!((run.runtime_s - 171.595).abs() < 1.5, "baseline {}", run.runtime_s);
        // Fig 15d: node energy ~8384 J; the comm phase must be low-power
        let e = run.node_energy_j();
        assert!((e - 8384.0).abs() < 8384.0 * 0.05, "energy {e}");
    }

    #[test]
    fn theta_best_matches_fig14() {
        // paper: best 14.427 s (-91.59%) with the barrier enabled
        let model = Sw4lite::new();
        let space = build_space(AppKind::Sw4lite, PlatformKind::Theta);
        let ctx = EvalContext::new(PlatformKind::Theta, 1024);
        let mut rng = Pcg32::seeded(41);
        let mut best = f64::INFINITY;
        for _ in 0..4000 {
            let cfg = space.sample(&mut rng);
            best = best.min(model.run(&space, &cfg, &ctx).runtime_s);
        }
        let baseline = model.baseline(&ctx).runtime_s;
        let gain = 1.0 - best / baseline;
        assert!(gain > 0.88 && gain < 0.95, "gain {gain} best {best}");
        assert!((12.0..16.5).contains(&best), "best {best}");
    }

    #[test]
    fn summit_baseline_and_best_match_fig13() {
        let model = Sw4lite::new();
        let ctx = EvalContext::new(PlatformKind::Summit, 1024);
        let baseline = model.baseline(&ctx).runtime_s;
        assert!((baseline - 11.067).abs() < 0.08, "baseline {baseline}");
        let space = build_space(AppKind::Sw4lite, PlatformKind::Summit);
        let mut rng = Pcg32::seeded(42);
        let mut best = f64::INFINITY;
        for _ in 0..4000 {
            let cfg = space.sample(&mut rng);
            best = best.min(model.run(&space, &cfg, &ctx).runtime_s);
        }
        let gain = 1.0 - best / baseline;
        // paper: 30.78% improvement (7.661 s)
        assert!(gain > 0.24 && gain < 0.38, "gain {gain} best {best}");
    }

    #[test]
    fn barrier_is_the_dominant_theta_knob() {
        let model = Sw4lite::new();
        let space = build_space(AppKind::Sw4lite, PlatformKind::Theta);
        let ctx = EvalContext::new(PlatformKind::Theta, 1024);
        let mut with_barrier = vec![0u32; space.dim()];
        with_barrier[space.param_index("OMP_NUM_THREADS").unwrap()] = 4; // 64
        let mut without = with_barrier.clone();
        with_barrier[space.param_index("mpi_barrier_0").unwrap()] = 1;
        without[space.param_index("mpi_barrier_0").unwrap()] = 0;
        let on = model
            .run(&space, &crate::space::Configuration::from_indices(with_barrier), &ctx)
            .runtime_s;
        let off = model
            .run(&space, &crate::space::Configuration::from_indices(without), &ctx)
            .runtime_s;
        assert!(off / on > 8.0, "barrier should dominate: on {on} off {off}");
    }

    #[test]
    fn strong_scaling_compute_shrinks_with_nodes() {
        let model = Sw4lite::new();
        let a = model.baseline(&EvalContext::new(PlatformKind::Summit, 256));
        let b = model.baseline(&EvalContext::new(PlatformKind::Summit, 1024));
        let st = |r: &AppRun| r.phases.iter().find(|p| p.label == "stencil").unwrap().duration_s;
        assert!((st(&a) / st(&b) - 4.0).abs() < 1e-6);
    }
}
