//! Deterministic drifting substrate: phase-shifted variants of the
//! existing application models, keyed on the evaluation index.
//!
//! The continuous controller (ISSUE: online re-tuning under drift)
//! needs a world that *moves* under the tuner — an input-phase change,
//! a thermal derate, a co-scheduled neighbour — without giving up the
//! determinism contract. [`DriftingModel`] wraps any [`AppModel`]: up
//! to the planted drift evaluation it is a bit-exact pass-through;
//! from that evaluation on, every run pays a configuration-dependent
//! penalty proportional to its distance from a *seed-derived* new
//! sweet spot. The optimum therefore relocates at the drift point —
//! re-tuning has something real to find — while the whole trajectory
//! remains a pure function of `(setup, seed)`.
//!
//! The drift is keyed on the **evaluation index**, which the model
//! recovers from the per-eval noise seed the engines already thread
//! through [`EvalContext`]: every engine computes
//! `noise_seed = seed ^ eval_id * NOISE_MUL` (see
//! `ensemble::evaluate_one`), and `NOISE_MUL` is odd, hence invertible
//! mod 2^64 — so the wrapper inverts the mix instead of widening every
//! engine's evaluation plumbing.

use super::{AppKind, AppModel, AppRun, EvalContext};
use crate::space::{ConfigSpace, Configuration};

/// The per-eval noise-seed mixing constant every engine uses
/// (`ensemble::evaluate_one` and the serial loop alike).
pub const NOISE_MUL: u64 = 0x9e37_79b9_7f4a_7c15;

/// Multiplicative inverse of [`NOISE_MUL`] mod 2^64, computed at
/// compile time by Newton–Raphson (each step doubles the number of
/// correct low bits; an odd seed value is correct to 3 bits, so six
/// steps reach 64+).
pub const NOISE_MUL_INV: u64 = mul_inverse(NOISE_MUL);

const fn mul_inverse(m: u64) -> u64 {
    let mut x = m;
    let mut i = 0;
    while i < 6 {
        x = x.wrapping_mul(2u64.wrapping_sub(m.wrapping_mul(x)));
        i += 1;
    }
    x
}

/// Recover the evaluation index from a per-eval noise seed.
pub fn eval_id_of_noise_seed(run_seed: u64, noise_seed: u64) -> u64 {
    (noise_seed ^ run_seed).wrapping_mul(NOISE_MUL_INV)
}

/// splitmix64 finalizer → a unit-interval coordinate for axis `j`.
fn target_coord(seed: u64, j: usize) -> f64 {
    let mut h = seed ^ (j as u64 + 1).wrapping_mul(0xd6e8_feb8_6659_fd93);
    h ^= h >> 30;
    h = h.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    h ^= h >> 27;
    h = h.wrapping_mul(0x94d0_49bb_1331_11eb);
    h ^= h >> 31;
    (h >> 11) as f64 / (1u64 << 53) as f64
}

/// A base application model whose landscape phase-shifts at a planted
/// evaluation index. See the module docs for the contract.
pub struct DriftingModel {
    base: Box<dyn AppModel>,
    run_seed: u64,
    drift_at: usize,
    magnitude: f64,
}

impl DriftingModel {
    pub fn new(
        base: Box<dyn AppModel>,
        run_seed: u64,
        drift_at: usize,
        magnitude: f64,
    ) -> DriftingModel {
        DriftingModel { base, run_seed, drift_at, magnitude: magnitude.max(0.0) }
    }

    /// Post-drift runtime multiplier for `cfg`: `1 + magnitude * d`,
    /// where `d` is the mean squared distance (per encoded axis, in
    /// [0, 1]) from the seed-derived post-drift sweet spot. The old
    /// optimum sits at a generic position relative to the new target,
    /// so it pays a real penalty; re-tuning toward the target earns it
    /// back.
    pub fn drift_factor(&self, space: &ConfigSpace, cfg: &Configuration) -> f64 {
        let mut dist = 0.0f64;
        let mut dims = 0.0f64;
        for (j, (p, &i)) in space.params().iter().zip(cfg.indices().iter()).enumerate() {
            let card = p.domain.cardinality();
            if card <= 1 {
                continue;
            }
            let x = i as f64 / (card - 1) as f64;
            let t = target_coord(self.run_seed, j);
            dist += (x - t) * (x - t);
            dims += 1.0;
        }
        let d = if dims > 0.0 { dist / dims } else { 0.0 };
        1.0 + self.magnitude * d
    }

    /// Does the evaluation carrying `noise_seed` run on the drifted
    /// substrate?
    pub fn drifted(&self, noise_seed: u64) -> bool {
        eval_id_of_noise_seed(self.run_seed, noise_seed) >= self.drift_at as u64
    }
}

impl AppModel for DriftingModel {
    fn kind(&self) -> AppKind {
        self.base.kind()
    }

    /// The baseline is measured before the campaign starts — always the
    /// pre-drift world (its noise seeds come from the baseline stream,
    /// not the per-eval mix, so they must not be decoded).
    fn baseline(&self, ctx: &EvalContext) -> AppRun {
        self.base.baseline(ctx)
    }

    fn run(&self, space: &ConfigSpace, cfg: &Configuration, ctx: &EvalContext) -> AppRun {
        let mut run = self.base.run(space, cfg, ctx);
        if self.drifted(ctx.noise_seed) {
            let f = self.drift_factor(space, cfg);
            for phase in &mut run.phases {
                phase.duration_s *= f;
            }
            run.runtime_s *= f;
        }
        run
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::model_for;
    use crate::platform::PlatformKind;
    use crate::space::paper;

    #[test]
    fn noise_mix_inverts_exactly() {
        assert_eq!(NOISE_MUL.wrapping_mul(NOISE_MUL_INV), 1, "inverse mod 2^64");
        for seed in [0u64, 7, 0xdead_beef, u64::MAX] {
            for id in [0u64, 1, 2, 41, 1_000_000, u64::from(u32::MAX) + 3] {
                let noise = seed ^ id.wrapping_mul(NOISE_MUL);
                assert_eq!(eval_id_of_noise_seed(seed, noise), id, "seed {seed} id {id}");
            }
        }
    }

    fn ctx_for_eval(seed: u64, id: u64) -> EvalContext {
        let mut ctx = EvalContext::new(PlatformKind::Theta, 1);
        ctx.noise_seed = seed ^ id.wrapping_mul(NOISE_MUL);
        ctx
    }

    #[test]
    fn pass_through_before_the_drift_point_is_bit_exact() {
        let seed = 33u64;
        let space = paper::build_space(AppKind::XSBenchHistory, PlatformKind::Theta);
        let plain = model_for(AppKind::XSBenchHistory);
        let drifting =
            DriftingModel::new(model_for(AppKind::XSBenchHistory), seed, 10, 0.8);
        let cfg = space.config_at(123);
        for id in 0..10u64 {
            let ctx = ctx_for_eval(seed, id);
            let a = plain.run(&space, &cfg, &ctx);
            let b = drifting.run(&space, &cfg, &ctx);
            assert_eq!(a.runtime_s.to_bits(), b.runtime_s.to_bits(), "eval {id} diverged");
            assert_eq!(a.phases, b.phases);
        }
        // the baseline stays the pre-drift world
        let bctx = EvalContext::new(PlatformKind::Theta, 1);
        assert_eq!(
            plain.baseline(&bctx).runtime_s.to_bits(),
            drifting.baseline(&bctx).runtime_s.to_bits()
        );
    }

    #[test]
    fn post_drift_penalty_is_deterministic_and_moves_the_landscape() {
        let seed = 33u64;
        let space = paper::build_space(AppKind::XSBenchHistory, PlatformKind::Theta);
        let drifting =
            DriftingModel::new(model_for(AppKind::XSBenchHistory), seed, 10, 0.8);
        let cfg = space.config_at(123);
        let ctx = ctx_for_eval(seed, 10);
        let a = drifting.run(&space, &cfg, &ctx);
        let b = drifting.run(&space, &cfg, &ctx);
        assert_eq!(a.runtime_s.to_bits(), b.runtime_s.to_bits(), "drifted run not deterministic");
        let plain = model_for(AppKind::XSBenchHistory).run(&space, &cfg, &ctx);
        let f = drifting.drift_factor(&space, &cfg);
        assert!(f >= 1.0 && f <= 1.8 + 1e-12, "factor {f} out of band");
        assert!(
            (a.runtime_s - plain.runtime_s * f).abs() < 1e-9,
            "penalty must scale the whole run"
        );
        // the penalty is configuration-dependent (the optimum can move):
        // scan a few points and require at least two distinct factors
        let mut factors: Vec<u64> = (0..8u128)
            .map(|i| drifting.drift_factor(&space, &space.config_at(i * 97)).to_bits())
            .collect();
        factors.dedup();
        assert!(factors.len() > 1, "drift penalty is flat — the optimum cannot move");
        // energy scales with the stretched phases
        assert!(a.node_energy_j() > plain.node_energy_j());
    }

    #[test]
    fn zero_magnitude_never_perturbs() {
        let seed = 5u64;
        let space = paper::build_space(AppKind::Amg, PlatformKind::Theta);
        let plain = model_for(AppKind::Amg);
        let drifting = DriftingModel::new(model_for(AppKind::Amg), seed, 0, 0.0);
        let cfg = space.config_at(7);
        let ctx = ctx_for_eval(seed, 99);
        assert_eq!(
            plain.run(&space, &cfg, &ctx).runtime_s.to_bits(),
            drifting.run(&space, &cfg, &ctx).runtime_s.to_bits()
        );
    }
}
