//! AMG performance/power model.
//!
//! AMG is a parallel algebraic-multigrid solver on a 3D Laplace problem
//! (`-laplace -n 100 100 100 -P X Y Z`): 10^6 grid points per MPI rank,
//! weak scaling by data decomposition. Runtime = V-cycle compute
//! (smoothers / residuals / interpolation — the loops the unroll and
//! parallel-for pragmas target) + halo/coarse-grid communication.
//!
//! Calibration (pinned by tests):
//!   Summit 4096 nodes: baseline 8.694 s -> best 6.734 s (-22.54%, Fig 11)
//!   Theta 4096 nodes:  baseline ~26.5 s; the `48 threads +
//!     OMP_PLACES=threads + OMP_PROC_BIND=master + dynamic` corner blows
//!     up to ~1,039 s (Fig 12a's second evaluation);
//!     baseline node energy ~= 5643 J (Fig 15c)
//!
//! AMG is the most pragma-sensitive model: several solver loops in the
//! reference code are unparallelized or unrolled suboptimally, so the
//! `#pragma unroll(3)`, `#pragma unroll(6)` and added `#pragma omp
//! parallel for` sites carry the bulk of the 22.5% headroom the paper
//! finds.

use super::common::{self};
use super::{AppKind, AppModel, AppRun, EvalContext, PowerPhase};
use crate::platform::PlatformKind;
use crate::space::{ConfigSpace, Configuration};

pub struct Amg;

struct PlatCal {
    compute_s: f64, // V-cycle compute at baseline threads, 4096 nodes
    comm_s: f64,    // halo + coarse-grid comm at 4096 nodes
    pkg_compute: f64,
    dram_compute: f64,
    pkg_comm: f64,
    dram_comm: f64,
}

/// Per-site compute multipliers when a pragma site is enabled.
const UNROLL3_GAIN: f64 = 0.975; // 3 sites: the relax/axpy inner loops
const UNROLL6_GAIN: f64 = 0.988; // 3 sites: matvec rows
const PF_GAINS: [f64; 5] = [0.94, 0.955, 0.97, 0.99, 0.995];

impl Amg {
    pub fn new() -> Self {
        Amg
    }

    fn cal(platform: PlatformKind) -> PlatCal {
        match platform {
            PlatformKind::Theta => PlatCal {
                compute_s: 21.5,
                comm_s: 5.0,
                pkg_compute: 212.0,
                dram_compute: 25.0,
                pkg_comm: 100.0,
                dram_comm: 10.0,
            },
            PlatformKind::Summit => PlatCal {
                compute_s: 7.5,
                comm_s: 1.194,
                pkg_compute: 345.0,
                dram_compute: 32.0,
                pkg_comm: 170.0,
                dram_comm: 12.0,
            },
        }
    }

    fn baseline_threads(platform: PlatformKind) -> f64 {
        match platform {
            PlatformKind::Theta => 64.0,
            PlatformKind::Summit => 168.0,
        }
    }

    /// Coarse-grid levels serialize on more ranks: comm grows with log(p)
    /// (the network's collective scaling).
    fn comm_scale(platform: PlatformKind, nodes: u64) -> f64 {
        crate::platform::network::Network::of(platform).collective_scale(nodes, 4096)
    }

    fn thread_factor(threads: f64, platform: PlatformKind) -> f64 {
        let cores = platform.spec().cpu_cores_per_node as f64;
        let s = |n: f64| common::thread_speedup(n, cores, 0.015, 0.06);
        s(Self::baseline_threads(platform)) / s(threads)
    }

    fn build(&self, compute: f64, comm: f64, cal: &PlatCal) -> AppRun {
        AppRun::from_phases(vec![
            PowerPhase {
                label: "vcycle",
                duration_s: compute,
                pkg_w: cal.pkg_compute,
                dram_w: cal.dram_compute,
            },
            PowerPhase {
                label: "halo",
                duration_s: comm,
                pkg_w: cal.pkg_comm,
                dram_w: cal.dram_comm,
            },
        ])
    }
}

impl AppModel for Amg {
    fn kind(&self) -> AppKind {
        AppKind::Amg
    }

    fn baseline(&self, ctx: &EvalContext) -> AppRun {
        let cal = Self::cal(ctx.platform);
        let comm = cal.comm_s * Self::comm_scale(ctx.platform, ctx.nodes);
        self.build(cal.compute_s, comm, &cal)
    }

    fn run(&self, space: &ConfigSpace, cfg: &Configuration, ctx: &EvalContext) -> AppRun {
        let cal = Self::cal(ctx.platform);
        let env = common::omp_env(space, cfg);
        let cores = ctx.platform.spec().cpu_cores_per_node as f64;

        let mut compute = cal.compute_s * Self::thread_factor(env.threads as f64, ctx.platform);

        // pragma sites
        for i in 0..3 {
            if space.int_value(cfg, &format!("unroll3_{i}")) == 1 {
                compute *= UNROLL3_GAIN;
            }
            if space.int_value(cfg, &format!("unroll6_{i}")) == 1 {
                compute *= UNROLL6_GAIN;
            }
        }
        for (i, g) in PF_GAINS.iter().enumerate() {
            if space.int_value(cfg, &format!("parallel_for_{i}")) == 1 {
                compute *= g;
            }
        }

        // schedule: V-cycle loops are regular; dynamic only adds dispatch
        compute *= match env.schedule.as_str() {
            "static" => 1.0,
            "dynamic" => 1.025,
            _ => 1.008,
        };

        // affinity — AMG is the paper's pathological case (sensitivity 1)
        let mut aff = common::affinity_factor(&env, cores, 1.0);
        if env.places == "threads" && env.bind == "master" && env.schedule == "dynamic" {
            aff *= 1.18; // dynamic dispatch contends on the piled-up cores
        }
        compute *= aff;

        let comm = cal.comm_s * Self::comm_scale(ctx.platform, ctx.nodes);
        let noise = common::run_noise(cfg, ctx.noise_seed, 0.008);
        self.build(compute * noise, comm * noise, &cal)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::paper::build_space;
    use crate::util::Pcg32;

    #[test]
    fn summit_baseline_and_best_match_fig11() {
        let ctx = EvalContext::new(PlatformKind::Summit, 4096);
        let model = Amg::new();
        let baseline = model.baseline(&ctx).runtime_s;
        assert!((baseline - 8.694).abs() < 0.05, "baseline {baseline}");

        let space = build_space(AppKind::Amg, PlatformKind::Summit);
        let mut rng = Pcg32::seeded(31);
        let mut best = f64::INFINITY;
        for _ in 0..4000 {
            let cfg = space.sample(&mut rng);
            best = best.min(model.run(&space, &cfg, &ctx).runtime_s);
        }
        let gain = 1.0 - best / baseline;
        // paper: 22.54% improvement (6.734 s)
        assert!(gain > 0.17 && gain < 0.28, "gain {gain} best {best}");
    }

    #[test]
    fn theta_pathological_corner_matches_fig12() {
        // 48 threads, places=threads, bind=master, schedule=dynamic
        // took 1,039.06 s vs ~26 s typical
        let model = Amg::new();
        let space = build_space(AppKind::Amg, PlatformKind::Theta);
        let mut idx = vec![0u32; space.dim()];
        idx[space.param_index("OMP_NUM_THREADS").unwrap()] = 4; // not 48: closest grid pt below
        // thread_choices Theta: [4,8,16,32,64,...] — 48 isn't a grid point;
        // build the exact paper configuration off-grid via a custom check
        // on the affinity factor instead:
        idx[space.param_index("OMP_PLACES").unwrap()] = 1; // threads
        idx[space.param_index("OMP_PROC_BIND").unwrap()] = 2; // master
        idx[space.param_index("OMP_SCHEDULE").unwrap()] = 1; // dynamic
        idx[space.param_index("OMP_NUM_THREADS").unwrap()] = 4; // 64 threads
        let cfg = crate::space::Configuration::from_indices(idx);
        let ctx = EvalContext::new(PlatformKind::Theta, 4096);
        let bad = model.run(&space, &cfg, &ctx).runtime_s;
        let baseline = model.baseline(&ctx).runtime_s;
        assert!(
            bad > 25.0 * baseline && bad < 60.0 * baseline,
            "pathological {bad} vs baseline {baseline}"
        );
        // the paper's observed blowup was ~1039 s; ours must be same order
        assert!((500.0..2000.0).contains(&bad), "blowup {bad}");
    }

    #[test]
    fn theta_energy_baseline_matches_fig15c() {
        let model = Amg::new();
        let e = model.baseline(&EvalContext::new(PlatformKind::Theta, 4096)).node_energy_j();
        assert!((e - 5642.6).abs() < 5642.6 * 0.05, "energy {e}");
    }

    #[test]
    fn theta_energy_saving_in_fig15c_band() {
        // paper: 20.88% saving
        let model = Amg::new();
        let space = build_space(AppKind::Amg, PlatformKind::Theta);
        let ctx = EvalContext::new(PlatformKind::Theta, 4096);
        let baseline = model.baseline(&ctx).node_energy_j();
        let mut rng = Pcg32::seeded(32);
        let mut best = f64::INFINITY;
        for _ in 0..4000 {
            let cfg = space.sample(&mut rng);
            best = best.min(model.run(&space, &cfg, &ctx).node_energy_j());
        }
        let saving = 1.0 - best / baseline;
        assert!(saving > 0.15 && saving < 0.30, "saving {saving}");
    }

    #[test]
    fn weak_scaling_compute_flat() {
        let model = Amg::new();
        let a = model.baseline(&EvalContext::new(PlatformKind::Summit, 64));
        let b = model.baseline(&EvalContext::new(PlatformKind::Summit, 4096));
        let vc = |r: &AppRun| r.phases.iter().find(|p| p.label == "vcycle").unwrap().duration_s;
        assert!((vc(&a) - vc(&b)).abs() < 1e-9);
        assert!(b.runtime_s > a.runtime_s); // comm grows
    }
}
