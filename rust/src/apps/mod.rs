//! ECP proxy-application models (the evaluation substrate).
//!
//! The paper evaluates real XSBench / SWFFT / AMG / SW4lite binaries on
//! Theta and Summit; we substitute calibrated analytic models that map a
//! parameter configuration + execution context to (runtime, per-node power
//! phases). The search-relevant object is the configuration→metric
//! landscape; each model encodes the paper's observed structure — thread
//! scaling with SMT, affinity pathologies (AMG's 1,039 s evaluation),
//! schedule/chunk interactions, communication desynchronization (SW4lite's
//! 168 s on Theta), weak vs strong scaling — and is pinned to the paper's
//! baseline and best-found numbers by unit tests.

pub mod amg;
pub mod common;
pub mod drifting;
pub mod sw4lite;
pub mod swfft;
pub mod xsbench;

use crate::platform::PlatformKind;
use crate::space::{ConfigSpace, Configuration};

/// The application variants of Table III.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AppKind {
    /// XSBench, history-based transport (default).
    XSBenchHistory,
    /// XSBench, event-based transport.
    XSBenchEvent,
    /// XSBench with mixed Clang loop pragmas + OpenMP pragmas (§V-A).
    XSBenchMixed,
    /// XSBench OpenMP offload (event-based only; Summit GPUs, §V-B).
    XSBenchOffload,
    Swfft,
    Amg,
    Sw4lite,
}

impl AppKind {
    pub fn name(&self) -> &'static str {
        match self {
            AppKind::XSBenchHistory => "XSBench-history",
            AppKind::XSBenchEvent => "XSBench-event",
            AppKind::XSBenchMixed => "XSBench-mixed",
            AppKind::XSBenchOffload => "XSBench-offload",
            AppKind::Swfft => "SWFFT",
            AppKind::Amg => "AMG",
            AppKind::Sw4lite => "SW4lite",
        }
    }

    pub fn parse(s: &str) -> Option<AppKind> {
        match s.to_ascii_lowercase().as_str() {
            "xsbench" | "xsbench-history" => Some(AppKind::XSBenchHistory),
            "xsbench-event" => Some(AppKind::XSBenchEvent),
            "xsbench-mixed" => Some(AppKind::XSBenchMixed),
            "xsbench-offload" => Some(AppKind::XSBenchOffload),
            "swfft" => Some(AppKind::Swfft),
            "amg" => Some(AppKind::Amg),
            "sw4lite" => Some(AppKind::Sw4lite),
            _ => None,
        }
    }

    /// Weak-scaling apps keep per-rank work constant (§III-A1); SW4lite is
    /// the strong-scaling case (§III-A2).
    pub fn is_weak_scaling(&self) -> bool {
        !matches!(self, AppKind::Sw4lite)
    }

    pub fn uses_gpus(&self) -> bool {
        matches!(self, AppKind::XSBenchOffload)
    }

    /// Compile-time row of Table II shared across XSBench variants.
    pub fn compile_family(&self) -> &'static str {
        match self {
            AppKind::XSBenchHistory
            | AppKind::XSBenchEvent
            | AppKind::XSBenchMixed
            | AppKind::XSBenchOffload => "XSBench",
            AppKind::Swfft => "SWFFT",
            AppKind::Amg => "AMG",
            AppKind::Sw4lite => "SW4lite",
        }
    }
}

/// Execution context for one evaluation (derived from the launch plan).
#[derive(Debug, Clone)]
pub struct EvalContext {
    pub platform: PlatformKind,
    pub nodes: u64,
    pub ranks_per_node: u64,
    pub uses_gpus: bool,
    /// Seed for the deterministic run-to-run noise of this evaluation.
    pub noise_seed: u64,
}

impl EvalContext {
    pub fn new(platform: PlatformKind, nodes: u64) -> Self {
        EvalContext { platform, nodes, ranks_per_node: 1, uses_gpus: false, noise_seed: 0 }
    }
}

/// One region of roughly constant per-node power draw.
#[derive(Debug, Clone, PartialEq)]
pub struct PowerPhase {
    pub label: &'static str,
    pub duration_s: f64,
    /// Package power per node (W). For the offload variant this includes
    /// GPU board power (GEOPM is Theta-only; Summit power is not tuned).
    pub pkg_w: f64,
    /// DRAM power per node (W).
    pub dram_w: f64,
}

/// The result of one simulated application run.
#[derive(Debug, Clone)]
pub struct AppRun {
    pub runtime_s: f64,
    pub phases: Vec<PowerPhase>,
}

impl AppRun {
    pub fn from_phases(phases: Vec<PowerPhase>) -> Self {
        let runtime_s = phases.iter().map(|p| p.duration_s).sum();
        AppRun { runtime_s, phases }
    }

    /// Analytic node energy in joules (the GEOPM sampler approximates
    /// this by 2 Hz trapezoid integration).
    pub fn node_energy_j(&self) -> f64 {
        self.phases.iter().map(|p| p.duration_s * (p.pkg_w + p.dram_w)).sum()
    }
}

/// An application performance+power model.
pub trait AppModel: Send + Sync {
    fn kind(&self) -> AppKind;

    /// Run the original (untuned) binary under the default system
    /// configuration with the paper's baseline thread count (64 on Theta,
    /// 168 on Summit).
    fn baseline(&self, ctx: &EvalContext) -> AppRun;

    /// Run the code-mold binary instantiated with `cfg`.
    fn run(&self, space: &ConfigSpace, cfg: &Configuration, ctx: &EvalContext) -> AppRun;
}

/// Model registry.
pub fn model_for(kind: AppKind) -> Box<dyn AppModel> {
    match kind {
        AppKind::XSBenchHistory | AppKind::XSBenchEvent | AppKind::XSBenchMixed => {
            Box::new(xsbench::XsBenchCpu::new(kind))
        }
        AppKind::XSBenchOffload => Box::new(xsbench::XsBenchOffload::new()),
        AppKind::Swfft => Box::new(swfft::Swfft::new()),
        AppKind::Amg => Box::new(amg::Amg::new()),
        AppKind::Sw4lite => Box::new(sw4lite::Sw4lite::new()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for k in [
            AppKind::XSBenchHistory,
            AppKind::XSBenchEvent,
            AppKind::XSBenchMixed,
            AppKind::XSBenchOffload,
            AppKind::Swfft,
            AppKind::Amg,
            AppKind::Sw4lite,
        ] {
            assert_eq!(AppKind::parse(k.name()), Some(k), "{k:?}");
        }
        assert_eq!(AppKind::parse("nope"), None);
    }

    #[test]
    fn scaling_classes() {
        assert!(AppKind::XSBenchHistory.is_weak_scaling());
        assert!(AppKind::Swfft.is_weak_scaling());
        assert!(AppKind::Amg.is_weak_scaling());
        assert!(!AppKind::Sw4lite.is_weak_scaling());
    }

    #[test]
    fn app_run_energy_integrates_phases() {
        let run = AppRun::from_phases(vec![
            PowerPhase { label: "compute", duration_s: 2.0, pkg_w: 200.0, dram_w: 25.0 },
            PowerPhase { label: "comm", duration_s: 1.0, pkg_w: 50.0, dram_w: 10.0 },
        ]);
        assert!((run.runtime_s - 3.0).abs() < 1e-12);
        assert!((run.node_energy_j() - (2.0 * 225.0 + 60.0)).abs() < 1e-9);
    }
}
