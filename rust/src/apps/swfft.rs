//! SWFFT performance/power model.
//!
//! SWFFT runs the HACC 3D distributed FFT: the 3D Cartesian grid is
//! re-distributed into three 2D pencil layouts in turn, computing 1D FFTs
//! along each axis (one forward + one backward transform, two test runs).
//! Weak scaling with a 4096^3 grid on 4096 ranks (§III-A1). Runtime =
//! local FFT compute (threads, FFTW) + alltoall redistribution (network).
//!
//! Calibration (pinned by tests):
//!   Summit 4096 nodes: baseline 8.93 s -> best ~7.797 s (-12.69%, Fig 9)
//!   Theta 4096 nodes:  baseline ~15.8 s, best ~= baseline (Fig 10);
//!                      baseline node energy ~= 3185 J (Fig 15b)
//!
//! The single tunable application parameter is `MPI_Barrier(CartComm)`
//! before the alltoall (2 insertion sites): on Summit's dual-rail EDR
//! fabric, pre-synchronizing the exchange avoids stragglers injecting
//! into a busy switch (a well-known alltoall effect) and cuts comm time
//! markedly; the Cray Aries adaptive-routed dragonfly already handles the
//! desynchronized case well, so on Theta the barrier barely matters —
//! exactly the asymmetry Figs 9/10 show.

use super::common::{self};
use super::{AppKind, AppModel, AppRun, EvalContext, PowerPhase};
use crate::platform::network::Network;
use crate::platform::PlatformKind;
use crate::space::{ConfigSpace, Configuration};

pub struct Swfft;

struct PlatCal {
    compute_s: f64, // local FFT time at baseline threads, 4096 nodes
    comm_s: f64,    // alltoall time at 4096 nodes, no barrier
    bw_knee: f64,   // FFT thread-scaling saturation knee (cores)
    pkg_compute: f64,
    dram_compute: f64,
    pkg_comm: f64,
    dram_comm: f64,
}

impl Swfft {
    pub fn new() -> Self {
        Swfft
    }

    fn cal(platform: PlatformKind) -> PlatCal {
        match platform {
            PlatformKind::Theta => PlatCal {
                compute_s: 11.5,
                comm_s: 4.3,
                bw_knee: 90.0,
                pkg_compute: 208.0,
                dram_compute: 27.0,
                pkg_comm: 96.0,
                dram_comm: 10.0,
            },
            PlatformKind::Summit => PlatCal {
                compute_s: 5.2,
                comm_s: 3.73,
                bw_knee: 60.0,
                pkg_compute: 330.0,
                dram_compute: 30.0,
                pkg_comm: 165.0,
                dram_comm: 12.0,
            },
        }
    }

    fn baseline_threads(platform: PlatformKind) -> f64 {
        match platform {
            PlatformKind::Theta => 64.0,
            PlatformKind::Summit => 168.0,
        }
    }

    fn compute_time(&self, cal: &PlatCal, threads: f64, platform: PlatformKind) -> f64 {
        // bandwidth-saturating FFT scaling: effective cores follow a
        // hyperbolic knee, SMT adds only latency hiding
        let cores = platform.spec().cpu_cores_per_node as f64;
        let eff = |n: f64| {
            let phys = n.min(cores);
            let smt = 1.0 + 0.008 * ((n / cores).ceil().clamp(1.0, 4.0) - 1.0);
            (phys / (1.0 + phys / cal.bw_knee)) * smt
        };
        cal.compute_s * eff(Self::baseline_threads(platform)) / eff(threads)
    }

    fn build(&self, compute: f64, comm: f64, cal: &PlatCal) -> AppRun {
        AppRun::from_phases(vec![
            PowerPhase {
                label: "fft",
                duration_s: compute,
                pkg_w: cal.pkg_compute,
                dram_w: cal.dram_compute,
            },
            PowerPhase {
                label: "alltoall",
                duration_s: comm,
                pkg_w: cal.pkg_comm,
                dram_w: cal.dram_comm,
            },
        ])
    }
}

impl AppModel for Swfft {
    fn kind(&self) -> AppKind {
        AppKind::Swfft
    }

    fn baseline(&self, ctx: &EvalContext) -> AppRun {
        let cal = Self::cal(ctx.platform);
        let net = Network::of(ctx.platform);
        let comm = cal.comm_s * net.collective_scale(ctx.nodes, 4096);
        self.build(cal.compute_s, comm, &cal)
    }

    fn run(&self, space: &ConfigSpace, cfg: &Configuration, ctx: &EvalContext) -> AppRun {
        let cal = Self::cal(ctx.platform);
        let env = common::omp_env(space, cfg);
        let cores = ctx.platform.spec().cpu_cores_per_node as f64;

        let mut compute = self.compute_time(&cal, env.threads as f64, ctx.platform);
        compute *= common::affinity_factor(&env, cores, 0.35);
        // FFT butterflies are uniform: static is right, dynamic pays
        compute *= match env.schedule.as_str() {
            "static" => 1.0,
            "dynamic" => 1.018,
            _ => 1.006,
        };

        let net = Network::of(ctx.platform);
        let mut comm = cal.comm_s * net.collective_scale(ctx.nodes, 4096);
        let barriers = common::toggles_on(space, cfg, "mpi_barrier", 2);
        comm *= net.alltoall_barrier_gain().powi(barriers as i32);

        let noise = common::run_noise(cfg, ctx.noise_seed, 0.008);
        let mut run = self.build(compute * noise, comm * noise, &cal);
        run.runtime_s = compute * noise + comm * noise;
        run
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::paper::build_space;
    use crate::util::Pcg32;

    #[test]
    fn summit_baseline_and_best_match_fig9() {
        let ctx = EvalContext::new(PlatformKind::Summit, 4096);
        let model = Swfft::new();
        let baseline = model.baseline(&ctx).runtime_s;
        assert!((baseline - 8.93).abs() < 0.05, "baseline {baseline}");

        let space = build_space(AppKind::Swfft, PlatformKind::Summit);
        let mut rng = Pcg32::seeded(21);
        let mut best = f64::INFINITY;
        for _ in 0..1000 {
            let cfg = space.sample(&mut rng);
            best = best.min(model.run(&space, &cfg, &ctx).runtime_s);
        }
        let gain = 1.0 - best / baseline;
        // paper: 12.69% improvement (7.797 s)
        assert!(gain > 0.08 && gain < 0.18, "gain {gain} best {best}");
    }

    #[test]
    fn theta_is_flat_like_fig10() {
        let ctx = EvalContext::new(PlatformKind::Theta, 4096);
        let model = Swfft::new();
        let baseline = model.baseline(&ctx).runtime_s;
        let space = build_space(AppKind::Swfft, PlatformKind::Theta);
        let mut rng = Pcg32::seeded(22);
        let mut best = f64::INFINITY;
        for _ in 0..1000 {
            let cfg = space.sample(&mut rng);
            best = best.min(model.run(&space, &cfg, &ctx).runtime_s);
        }
        let gain = 1.0 - best / baseline;
        assert!(gain < 0.05, "Theta SWFFT should be near-flat, gain {gain}");
    }

    #[test]
    fn theta_energy_baseline_matches_fig15b() {
        let model = Swfft::new();
        let e = model.baseline(&EvalContext::new(PlatformKind::Theta, 4096)).node_energy_j();
        assert!((e - 3185.0).abs() < 3185.0 * 0.05, "energy {e}");
    }

    #[test]
    fn comm_grows_with_scale_compute_does_not() {
        let model = Swfft::new();
        let small = model.baseline(&EvalContext::new(PlatformKind::Summit, 64));
        let large = model.baseline(&EvalContext::new(PlatformKind::Summit, 4096));
        let comm = |r: &AppRun| {
            r.phases.iter().find(|p| p.label == "alltoall").unwrap().duration_s
        };
        let fft = |r: &AppRun| r.phases.iter().find(|p| p.label == "fft").unwrap().duration_s;
        assert!(comm(&large) > comm(&small));
        assert!((fft(&large) - fft(&small)).abs() < 1e-9);
    }

    #[test]
    fn barrier_helps_summit_more_than_theta() {
        let model = Swfft::new();
        let run_with = |platform, barrier: u32| {
            let space = build_space(AppKind::Swfft, platform);
            let mut idx = vec![0u32; space.dim()];
            // threads=64-ish defaults; set both barrier toggles
            idx[space.param_index("OMP_NUM_THREADS").unwrap()] = 4; // 64 / 32
            idx[space.param_index("mpi_barrier_0").unwrap()] = barrier;
            idx[space.param_index("mpi_barrier_1").unwrap()] = barrier;
            let cfg = crate::space::Configuration::from_indices(idx);
            model.run(&space, &cfg, &EvalContext::new(platform, 4096)).runtime_s
        };
        let summit_gain = run_with(PlatformKind::Summit, 0) - run_with(PlatformKind::Summit, 1);
        let theta_gain = run_with(PlatformKind::Theta, 0) - run_with(PlatformKind::Theta, 1);
        assert!(summit_gain > 5.0 * theta_gain.max(0.0), "summit {summit_gain} theta {theta_gain}");
    }
}
