//! XSBench performance/power models (history, event, mixed, offload).
//!
//! XSBench is the Monte-Carlo macroscopic-cross-section lookup mini-app:
//! embarrassingly parallel across MPI ranks (identical work per rank, no
//! decomposition — §III-A1), memory-latency-bound inside a rank. Weak
//! scaling: per-rank runtime is flat in node count; at >= 64 nodes the
//! runs use the full "large" problem (3.6x the single-node tuning-demo
//! work), which is what makes the Theta at-scale energy figures land in
//! the paper's Joule range.
//!
//! Landscape calibration (pinned by tests):
//!   Theta 1 node, history: baseline 3.31 s, best reachable ~= 3.26 s
//!   Theta 1 node, event:   baseline 3.395 s, best ~= 3.34 s
//!   Summit 1 node, offload (6 GPUs): baseline 2.20 s, best ~= 2.14 s
//!   Theta 4096 nodes: baseline energy ~= 2495 J/node, tuned ~ -5..-9 %
//!
//! Mechanisms: main lookup loop ships as `schedule(dynamic, 100)` in the
//! original code (the `block_size` default); tuning trades dispatch
//! overhead vs residual imbalance (sweet spot near chunk ~350). At scale,
//! OS-noise desynchronization inflates the embarrassingly-parallel
//! ensemble (all ranks wait for the slowest); dynamic scheduling with
//! moderate chunks plus spread binding damps it. The offload space adds
//! the coalescing chunk (best = 1), host-fallback (DISABLED ~ 4.2x) and
//! the device-clause trap (pinning every rank to one GPU serializes six
//! ranks onto it).

use super::common::{self, OmpEnv};
use super::{AppKind, AppModel, AppRun, EvalContext, PowerPhase};
use crate::platform::PlatformKind;
use crate::space::{ConfigSpace, Configuration};

/// Work multiplier for at-scale runs (the "large" default problem).
fn work_factor(nodes: u64) -> f64 {
    if nodes >= 64 {
        3.6
    } else {
        1.0
    }
}

/// Desynchronization amplitude at `nodes` (fraction of runtime lost to
/// waiting on straggler ranks under fully static scheduling).
fn desync_amp(nodes: u64) -> f64 {
    if nodes < 64 {
        0.0
    } else {
        0.12 * ((nodes as f64).log2() / 12.0).powf(1.5)
    }
}

/// How much of the desync amplitude a schedule choice retains.
fn desync_retention(env: &OmpEnv, chunk: f64) -> f64 {
    let sched = match env.schedule.as_str() {
        "static" => 1.0,
        "auto" => 0.7,
        "dynamic" => 0.3 + 0.4 * (chunk / 400.0).clamp(0.0, 1.0),
        _ => 1.0,
    };
    let bind = if env.bind == "spread" { 0.55 } else { 1.0 };
    let places = if env.places == "sockets" { 0.85 } else { 1.0 };
    sched * bind * places
}

const TRIPS: f64 = 10_000.0; // lookups per thread in the main loop
const IMBALANCE: f64 = 0.018; // stochastic lookup-cost imbalance
const DISPATCH: f64 = 6.0e-5; // fractional cost of one dynamic dispatch

/// CPU XSBench (history / event / mixed-pragma variants).
pub struct XsBenchCpu {
    kind: AppKind,
    event: bool,
    mixed: bool,
}

impl XsBenchCpu {
    pub fn new(kind: AppKind) -> Self {
        let (event, mixed) = match kind {
            AppKind::XSBenchHistory => (false, false),
            AppKind::XSBenchEvent => (true, false),
            AppKind::XSBenchMixed => (false, true),
            other => panic!("XsBenchCpu cannot model {other:?}"),
        };
        XsBenchCpu { kind, event, mixed }
    }

    /// The mixed-pragma space driven by the event-based transport
    /// (paper Fig. 5b/5d).
    pub fn mixed_event() -> Self {
        XsBenchCpu { kind: AppKind::XSBenchMixed, event: true, mixed: true }
    }

    fn single_node_base(&self, platform: PlatformKind) -> f64 {
        let theta = if self.event { 3.395 } else { 3.31 };
        match platform {
            PlatformKind::Theta => theta,
            // Power9 node is ~18% faster on this latency-bound kernel
            PlatformKind::Summit => theta * 0.82,
        }
    }

    /// Relative runtime factor of a full parameterization (baseline-
    /// normalized elsewhere).
    fn rel_runtime(&self, env: &OmpEnv, chunk: f64, app_factor: f64, ctx: &EvalContext) -> f64 {
        let cores = ctx.platform.spec().cpu_cores_per_node as f64;
        let speed = common::thread_speedup(env.threads as f64, cores, 0.002, 0.01);
        let aff = common::affinity_factor(env, cores, 0.5);
        let sched = common::schedule_factor(&env.schedule, chunk, TRIPS, IMBALANCE, DISPATCH);
        let desync = 1.0 + desync_amp(ctx.nodes) * desync_retention(env, chunk);
        (1.0 / speed) * aff * sched * desync * app_factor
    }

    fn baseline_env(&self, platform: PlatformKind) -> OmpEnv {
        OmpEnv {
            threads: match platform {
                PlatformKind::Theta => 64,
                PlatformKind::Summit => 168,
            },
            places: "cores".into(),
            bind: "close".into(),
            // original code hard-codes schedule(dynamic, 100) on the
            // lookup loop; the env default does not override it
            schedule: "dynamic".into(),
        }
    }

    fn phases(&self, runtime: f64, env: &OmpEnv, ctx: &EvalContext) -> Vec<PowerPhase> {
        let cores = ctx.platform.spec().cpu_cores_per_node as f64;
        let active = (env.threads as f64 / cores).min(1.0);
        let smt_level = ((env.threads as f64 / cores).ceil()).clamp(1.0, 4.0);
        let (mut pkg, dram) = common::cpu_power(ctx.platform, active, 0.88, 0.95);
        pkg *= 1.0 + 0.04 * (smt_level - 1.0); // SMT keeps more pipes busy
        if env.bind == "spread" {
            pkg *= 0.985;
        }
        if env.places == "sockets" {
            pkg *= 0.975;
        }
        let init = 0.13 * runtime;
        vec![
            PowerPhase {
                label: "init",
                duration_s: init,
                pkg_w: 0.55 * pkg,
                dram_w: 0.6 * dram,
            },
            PowerPhase { label: "lookup", duration_s: runtime - init, pkg_w: pkg, dram_w: dram },
        ]
    }
}

impl AppModel for XsBenchCpu {
    fn kind(&self) -> AppKind {
        self.kind
    }

    fn baseline(&self, ctx: &EvalContext) -> AppRun {
        let env = self.baseline_env(ctx.platform);
        let rel = self.rel_runtime(&env, 100.0, 1.0, ctx);
        let rel0 = {
            let mut c1 = ctx.clone();
            c1.nodes = 1;
            self.rel_runtime(&env, 100.0, 1.0, &c1)
        };
        let runtime =
            self.single_node_base(ctx.platform) * work_factor(ctx.nodes) * rel / rel0;
        AppRun { runtime_s: runtime, phases: self.phases(runtime, &env, ctx) }
    }

    fn run(&self, space: &ConfigSpace, cfg: &Configuration, ctx: &EvalContext) -> AppRun {
        let env = common::omp_env(space, cfg);
        let chunk = space.int_value(cfg, "block_size") as f64;

        // application-pragma factor
        let mut app = 1.0;
        let pf_sites = if self.mixed { 3 } else { 4 };
        let gains = [0.006, 0.003, 0.002, 0.0015];
        for i in 0..pf_sites {
            if space.int_value(cfg, &format!("parallel_for_{i}")) == 1 {
                app *= 1.0 - gains[i];
            }
        }
        if self.mixed {
            if space.int_value(cfg, "unroll_full") == 1 {
                app *= 0.996;
            }
            let tx = space.int_value(cfg, "tile_x") as f64;
            let ty = space.int_value(cfg, "tile_y") as f64;
            let d = (tx.log2() - 6.0).powi(2) + (ty.log2() - 6.0).powi(2);
            // tiling the energy-grid walk: ~64x64 fits L2 slices; extreme
            // tiles thrash (2x2 dispatch overhead, 1024x1024 spills)
            app *= 0.995 + 0.0018 * d;
        }
        if self.event {
            app *= 1.004; // event-based needs an extra sort/scan pass
        }

        let rel = self.rel_runtime(&env, chunk, app, ctx);
        let rel0 = {
            let base_env = self.baseline_env(ctx.platform);
            let mut c1 = ctx.clone();
            c1.nodes = 1;
            let mut r = self.rel_runtime(&base_env, 100.0, 1.0, &c1);
            if self.event {
                r *= 1.004; // baseline of the event build pays it too
            }
            r
        };
        let noise = common::run_noise(cfg, ctx.noise_seed, 0.008);
        let runtime =
            self.single_node_base(ctx.platform) * work_factor(ctx.nodes) * rel / rel0 * noise;
        AppRun { runtime_s: runtime, phases: self.phases(runtime, &env, ctx) }
    }
}

/// XSBench OpenMP-offload (event-based, Summit; 6 GPUs, 1 rank/GPU).
pub struct XsBenchOffload;

impl XsBenchOffload {
    pub fn new() -> Self {
        XsBenchOffload
    }

    const BASE_S: f64 = 2.20; // paper §V-B baseline (168 threads, 6 GPUs)

    fn factors(&self, space: &ConfigSpace, cfg: &Configuration, ctx: &EvalContext) -> f64 {
        let env = common::omp_env(space, cfg);
        let mut f = 1.0;
        match space.str_value(cfg, "OMP_TARGET_OFFLOAD").as_str() {
            // host fallback: the event kernel on 2x Power9 instead of V100s
            "DISABLED" => f *= 4.2,
            _ => {}
        }
        // schedule(static, chunk) on the target teams loop: chunk 1 is
        // perfectly coalesced; growing chunks stride the accesses; 0
        // means "clause absent" (compiler default, mildly uncoalesced)
        let chunk = space.int_value(cfg, "sched_chunk");
        f *= match chunk {
            0 => 1.0,
            1 => 0.975,
            2 => 0.980,
            4 => 0.985,
            8 => 0.990,
            16 => 0.995,
            _ => 0.999,
        };
        if space.int_value(cfg, "simd") == 1 {
            f *= 0.995;
        }
        // device clause: -1 leaves each rank on its own GPU; a concrete
        // id funnels all six ranks onto one device
        if space.int_value(cfg, "device") >= 0 {
            f *= 4.5;
        }
        for i in 0..2 {
            if space.int_value(cfg, &format!("parallel_for_{i}")) == 1 {
                f *= 0.997;
            }
        }
        // host-side env still shapes the (small) CPU portions
        f *= 1.0 + 0.01 * (1.0 - (env.threads as f64 / 168.0).min(1.0));
        if env.schedule == "static" {
            f *= 1.004;
        }
        // weak-scaling desync is mild: GPU kernels are uniform
        f *= 1.0 + 0.25 * desync_amp(ctx.nodes);
        f
    }

    fn phases(&self, runtime: f64, gpu_active: bool) -> Vec<PowerPhase> {
        // GEOPM does not run on Summit; these phases exist for
        // completeness (nvidia-smi-style board power folded into pkg_w).
        let gpu = if gpu_active { 6.0 * 165.0 } else { 6.0 * 52.0 };
        let cpu = if gpu_active { 150.0 } else { 320.0 };
        vec![PowerPhase { label: "sim", duration_s: runtime, pkg_w: cpu + gpu, dram_w: 22.0 }]
    }
}

impl AppModel for XsBenchOffload {
    fn kind(&self) -> AppKind {
        AppKind::XSBenchOffload
    }

    fn baseline(&self, ctx: &EvalContext) -> AppRun {
        let runtime = Self::BASE_S * (1.0 + 0.25 * desync_amp(ctx.nodes));
        AppRun { runtime_s: runtime, phases: self.phases(runtime, true) }
    }

    fn run(&self, space: &ConfigSpace, cfg: &Configuration, ctx: &EvalContext) -> AppRun {
        let noise = common::run_noise(cfg, ctx.noise_seed, 0.008);
        let runtime = Self::BASE_S * self.factors(space, cfg, ctx) * noise;
        let on_gpu = space.str_value(cfg, "OMP_TARGET_OFFLOAD") != "DISABLED";
        AppRun { runtime_s: runtime, phases: self.phases(runtime, on_gpu) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::paper::build_space;
    use crate::util::Pcg32;

    fn best_of_random(
        model: &dyn AppModel,
        space: &ConfigSpace,
        ctx: &EvalContext,
        n: usize,
    ) -> f64 {
        let mut rng = Pcg32::seeded(12345);
        let mut best = f64::INFINITY;
        for _ in 0..n {
            let cfg = space.sample(&mut rng);
            best = best.min(model.run(space, &cfg, ctx).runtime_s);
        }
        best
    }

    #[test]
    fn theta_single_node_baselines_match_paper() {
        let ctx = EvalContext::new(PlatformKind::Theta, 1);
        let hist = XsBenchCpu::new(AppKind::XSBenchHistory).baseline(&ctx);
        assert!((hist.runtime_s - 3.31).abs() < 0.01, "history {}", hist.runtime_s);
        let event = XsBenchCpu::new(AppKind::XSBenchEvent).baseline(&ctx);
        assert!((event.runtime_s - 3.395).abs() < 0.015, "event {}", event.runtime_s);
    }

    #[test]
    fn theta_mixed_best_in_paper_band() {
        // Fig 5a: best 3.262 vs baseline 3.31 (-1.45%)
        let ctx = EvalContext::new(PlatformKind::Theta, 1);
        let model = XsBenchCpu::new(AppKind::XSBenchMixed);
        let space = build_space(AppKind::XSBenchMixed, PlatformKind::Theta);
        let best = best_of_random(&model, &space, &ctx, 4000);
        let baseline = model.baseline(&ctx).runtime_s;
        let gain = 1.0 - best / baseline;
        assert!(gain > 0.008 && gain < 0.05, "gain {gain} best {best} baseline {baseline}");
    }

    #[test]
    fn offload_baseline_and_best_match_paper() {
        // Fig 6: baseline 2.20 s, best 2.138 s on one Summit node
        let ctx = EvalContext::new(PlatformKind::Summit, 1);
        let model = XsBenchOffload::new();
        assert!((model.baseline(&ctx).runtime_s - 2.20).abs() < 0.01);
        let space = build_space(AppKind::XSBenchOffload, PlatformKind::Summit);
        let best = best_of_random(&model, &space, &ctx, 3000);
        let gain = 1.0 - best / 2.20;
        assert!(gain > 0.015 && gain < 0.06, "gain {gain} best {best}");
    }

    #[test]
    fn offload_traps_are_penalized() {
        let ctx = EvalContext::new(PlatformKind::Summit, 1);
        let model = XsBenchOffload::new();
        let space = build_space(AppKind::XSBenchOffload, PlatformKind::Summit);
        let mut rng = Pcg32::seeded(3);
        let mut disabled_worse = 0;
        let mut device_worse = 0;
        for _ in 0..200 {
            let cfg = space.sample(&mut rng);
            let rt = model.run(&space, &cfg, &ctx).runtime_s;
            if space.str_value(&cfg, "OMP_TARGET_OFFLOAD") == "DISABLED" && rt > 6.0 {
                disabled_worse += 1;
            }
            if space.int_value(&cfg, "device") >= 0
                && space.str_value(&cfg, "OMP_TARGET_OFFLOAD") != "DISABLED"
                && rt > 6.0
            {
                device_worse += 1;
            }
        }
        assert!(disabled_worse > 20);
        assert!(device_worse > 20);
    }

    #[test]
    fn weak_scaling_is_flat_in_nodes() {
        let model = XsBenchCpu::new(AppKind::XSBenchHistory);
        let big = model.baseline(&EvalContext::new(PlatformKind::Theta, 1024)).runtime_s;
        let bigger = model.baseline(&EvalContext::new(PlatformKind::Theta, 4096)).runtime_s;
        // same large problem; only desync grows slightly
        assert!((bigger / big - 1.0).abs() < 0.04, "{big} vs {bigger}");
    }

    #[test]
    fn at_scale_energy_baseline_in_paper_range() {
        // Fig 15a: XSBench baseline node energy 2494.905 J on 4096 nodes
        let model = XsBenchCpu::new(AppKind::XSBenchEvent);
        let run = model.baseline(&EvalContext::new(PlatformKind::Theta, 4096));
        let e = run.node_energy_j();
        assert!((2100.0..2900.0).contains(&e), "node energy {e} J (runtime {} s)", run.runtime_s);
    }

    #[test]
    fn at_scale_energy_tunable_by_several_percent() {
        let model = XsBenchCpu::new(AppKind::XSBenchEvent);
        let space = build_space(AppKind::XSBenchEvent, PlatformKind::Theta);
        let ctx = EvalContext::new(PlatformKind::Theta, 4096);
        let baseline_e = model.baseline(&ctx).node_energy_j();
        let mut rng = Pcg32::seeded(777);
        let mut best_e = f64::INFINITY;
        for _ in 0..3000 {
            let cfg = space.sample(&mut rng);
            best_e = best_e.min(model.run(&space, &cfg, &ctx).node_energy_j());
        }
        let saving = 1.0 - best_e / baseline_e;
        assert!(saving > 0.04 && saving < 0.20, "energy saving {saving}");
    }

    #[test]
    fn power_stays_within_node_envelope_on_theta() {
        let model = XsBenchCpu::new(AppKind::XSBenchHistory);
        let space = build_space(AppKind::XSBenchHistory, PlatformKind::Theta);
        let ctx = EvalContext::new(PlatformKind::Theta, 4096);
        let mut rng = Pcg32::seeded(9);
        for _ in 0..300 {
            let cfg = space.sample(&mut rng);
            for ph in model.run(&space, &cfg, &ctx).phases {
                assert!(ph.pkg_w <= 240.0, "pkg {} W", ph.pkg_w);
                assert!(ph.dram_w <= 32.0);
            }
        }
    }
}
