//! `ytopt-rs top`: a no-dependency terminal monitor over
//! [`StatsSnapshot`]s, scxtop-style — ANSI cursor-home redraw, per-shard
//! worker utilization bars, in-flight gauges, a best-so-far trajectory
//! sparkline, and the per-completion overhead number the paper's §IV
//! argument rests on.
//!
//! The rendering itself is pure (`render_frame` maps a snapshot history
//! to lines — unit-tested without a terminal); only the driving loop
//! touches the wall clock, under reasoned detlint allows: a monitor
//! repaints in viewer time by definition and feeds nothing back into
//! any trajectory.

use super::StatsSnapshot;

/// Eight-level block sparkline (the scxtop/spark idiom).
const SPARK: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];

/// Map a series onto the block ramp. Non-finite values render as `·`.
/// Lower objectives are better, so the caller typically inverts — this
/// function just scales min..max onto the ramp.
pub fn sparkline(series: &[f64]) -> String {
    let finite: Vec<f64> = series.iter().copied().filter(|v| v.is_finite()).collect();
    if finite.is_empty() {
        return series.iter().map(|_| '·').collect();
    }
    let lo = finite.iter().copied().fold(f64::INFINITY, f64::min);
    let hi = finite.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let span = (hi - lo).max(f64::EPSILON);
    series
        .iter()
        .map(|v| {
            if !v.is_finite() {
                return '·';
            }
            let t = ((v - lo) / span).clamp(0.0, 1.0);
            SPARK[((t * (SPARK.len() - 1) as f64).round()) as usize]
        })
        .collect()
}

/// A `[####....]`-style utilization bar for a fraction in `[0, 1]`.
pub fn bar(frac: f64, width: usize) -> String {
    let width = width.max(1);
    let filled = ((frac.clamp(0.0, 1.0) * width as f64).round()) as usize;
    let mut s = String::with_capacity(width + 2);
    s.push('[');
    for i in 0..width {
        s.push(if i < filled { '#' } else { '.' });
    }
    s.push(']');
    s
}

fn fmt_obj(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.4}")
    } else {
        "-".to_string()
    }
}

/// Render one frame: a header, the campaign counters, per-shard rows,
/// and the best-so-far sparkline over `best_history` (the monitor
/// appends one entry per poll). Pure — no terminal, no clock.
pub fn render_frame(title: &str, snap: &StatsSnapshot, best_history: &[f64]) -> Vec<String> {
    let mut out = Vec::new();
    out.push(format!("ytop — {title}"));
    out.push(format!(
        "evals: {} applied / {} proposed ({} in flight)   best: {}   stragglers killed: {}",
        snap.completions,
        snap.proposals,
        snap.in_flight(),
        fmt_obj(snap.best_objective),
        snap.straggler_kills,
    ));
    out.push(format!(
        "overhead: {:.0} us/completion   surrogate cache: {:.0}% hit ({} fits, {} hits)   \
         exchanges: {}",
        snap.overhead_us_per_completion(),
        snap.cache_hit_rate() * 100.0,
        snap.surrogate_fits,
        snap.surrogate_cache_hits,
        snap.exchange_rounds,
    ));
    out.push(format!(
        "ring: {} events ({} dropped)",
        snap.ring_next, snap.ring_dropped
    ));
    for sh in &snap.shards {
        let util = sh.utilization();
        out.push(format!(
            "shard {:>2}  {} {:>5.1}%  workers {:>2}  in-flight {:>3}  applied {:>5}  \
             best {}  t={:.1}s",
            sh.shard,
            bar(util, 20),
            util * 100.0,
            sh.workers,
            sh.in_flight,
            sh.applied,
            fmt_obj(sh.best_objective),
            sh.sim_wallclock_s,
        ));
    }
    if !best_history.is_empty() {
        out.push(format!("best-so-far  {}", sparkline(best_history)));
    }
    out
}

/// Clear-and-home ANSI prefix, then the frame. Kept separate from
/// [`render_frame`] so tests never have to strip escapes.
pub fn paint(frame: &[String]) -> String {
    let mut s = String::from("\x1b[H\x1b[2J");
    for line in frame {
        s.push_str(line);
        s.push_str("\x1b[K\r\n");
    }
    s
}

/// Drive the monitor: poll `fetch` every `interval_ms`, repaint, stop
/// after `frames` paints (0 = until `fetch` returns `None`). Returns
/// the number of frames painted. `fetch` returning `None` ends the loop
/// (daemon gone, campaign done, snapshot file removed).
pub fn run<F>(title: &str, mut fetch: F, interval_ms: u64, frames: u64) -> u64
where
    F: FnMut() -> Option<StatsSnapshot>,
{
    let mut best_history: Vec<f64> = Vec::new();
    let mut painted = 0u64;
    while frames == 0 || painted < frames {
        let Some(snap) = fetch() else { break };
        if snap.best_objective.is_finite() {
            best_history.push(snap.best_objective);
            let overflow = best_history.len().saturating_sub(60);
            if overflow > 0 {
                best_history.drain(..overflow);
            }
        }
        let frame = render_frame(title, &snap, &best_history);
        print!("{}", paint(&frame));
        use std::io::Write;
        let _ = std::io::stdout().flush();
        painted += 1;
        if frames != 0 && painted >= frames {
            break;
        }
        // detlint: allow(wall-clock) -- viewer-time repaint cadence; renders state, never feeds a trajectory
        std::thread::sleep(std::time::Duration::from_millis(interval_ms.max(50)));
    }
    painted
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::ShardGauges;

    #[test]
    fn sparkline_scales_and_marks_non_finite() {
        assert_eq!(sparkline(&[]), "");
        assert_eq!(sparkline(&[1.0]), "▁");
        let s = sparkline(&[0.0, 0.5, 1.0]);
        assert_eq!(s.chars().count(), 3);
        assert_eq!(s.chars().next(), Some('▁'));
        assert_eq!(s.chars().last(), Some('█'));
        assert_eq!(sparkline(&[f64::INFINITY, 2.0]), "·▁");
        // all-equal series stays on the floor instead of dividing by zero
        assert_eq!(sparkline(&[3.0, 3.0, 3.0]), "▁▁▁");
    }

    #[test]
    fn bars_round_to_width() {
        assert_eq!(bar(0.0, 4), "[....]");
        assert_eq!(bar(1.0, 4), "[####]");
        assert_eq!(bar(0.5, 4), "[##..]");
        assert_eq!(bar(2.0, 4), "[####]"); // clamped
    }

    #[test]
    fn frames_render_counters_and_shards() {
        let mut snap = StatsSnapshot {
            proposals: 10,
            completions: 8,
            best_objective: 11.5,
            ..StatsSnapshot::default()
        };
        snap.shards.push(ShardGauges {
            shard: 0,
            workers: 4,
            in_flight: 2,
            applied: 8,
            best_objective: 11.5,
            sim_wallclock_s: 10.0,
            busy_s: 30.0,
        });
        let frame = render_frame("campaign 1", &snap, &[14.0, 12.0, 11.5]);
        let text = frame.join("\n");
        assert!(text.contains("campaign 1"));
        assert!(text.contains("8 applied / 10 proposed"));
        assert!(text.contains("shard  0"));
        assert!(text.contains("75.0%"));
        assert!(text.contains("best-so-far"));
        // the paint wrapper is the only place ANSI escapes appear
        assert!(!text.contains('\x1b'));
        assert!(paint(&frame).starts_with("\x1b[H\x1b[2J"));
    }
}
