//! Always-cheap live observability (ISSUE 8): the event ring and the
//! stats counters every front-end reads.
//!
//! The paper's §IV argument is that autotuning at scale works because
//! the framework's own overhead is low and *measured*. This module is
//! where the fleet measures itself while running: the continuous
//! manager, every federation shard, and the surrogate cache record
//! [`ObsEvent`]s into a fixed-capacity [`EventRing`] and bump the
//! monotonic counters behind [`StatsSnapshot`] — which `ytopt-rs stats`
//! and `ytopt-rs top` read over the service protocol (daemon) or from a
//! snapshot file (solo `tune --stats`).
//!
//! # Off the deterministic path
//!
//! Recording is strictly write-only from the engine's point of view:
//! events carry eval ids, simulated timestamps, and a ring sequence
//! number (the logical clock) — never decisions — and nothing in the
//! core ever reads a sink. The sink is optional (`TuneSetup::obs`), and
//! seed-for-seed trajectories are pinned bit-identical with stats on
//! vs. off. All wall-clock durations recorded here are measured *by the
//! core's existing overhead stats* (`search_s`, `last_fit_s`, under
//! their own detlint allows) and passed in; `obs/` itself only touches
//! the wall clock in the [`monitor`] renderer, under reasoned allows.
//!
//! # Ring semantics
//!
//! The writer never blocks and never allocates per event: [`EventRing::
//! record`] takes the ring lock with `try_lock`, and a contended record
//! increments the `dropped` counter instead of waiting (manager progress
//! is worth more than a perfect event tail). Sequence numbers are
//! assigned under the lock, so delivered events are totally ordered;
//! when the ring wraps, readers see a gap between their cursor and the
//! oldest retained sequence — visible, never silent.

pub mod monitor;

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::Json;

/// Default ring capacity: enough to tail a busy campaign for a while,
/// small enough to be memory-irrelevant.
pub const DEFAULT_RING_CAPACITY: usize = 4096;

/// One manager event, as recorded by the engines. Durations are carried
/// in integer microseconds (atomically summable); simulated timestamps
/// stay in seconds like the rest of the engine.
#[derive(Debug, Clone, PartialEq)]
pub enum ObsEvent {
    /// A fresh configuration was proposed; `search_us` is the measured
    /// proposal-loop overhead (surrogate fit + acquisition scoring).
    Proposed { eval_id: u64, shard: u32, search_us: u64 },
    /// The proposal was handed to the worker pool.
    Dispatched { eval_id: u64, shard: u32 },
    /// An evaluation completed and was applied in eval-id order.
    Completed { eval_id: u64, shard: u32, objective: f64, best_so_far: f64, sim_wallclock_s: f64 },
    /// The straggler policy cancelled this in-flight evaluation.
    StragglerKilled { eval_id: u64, shard: u32 },
    /// The continuous controller's residual CUSUM fired while applying
    /// this evaluation: the observed objectives have shifted away from
    /// the surrogate's predictions and the search window was reset.
    DriftDetected { eval_id: u64, shard: u32 },
    /// One federation elite-exchange absorption at a round boundary.
    EliteExchange { round: u64, shard: u32, absorbed: u64 },
    /// The surrogate epoch cache answered a model use: a hit reuses the
    /// epoch's fitted forest (`fit_us == 0`), a miss pays a fit.
    SurrogateFit { shard: u32, cache_hit: bool, fit_us: u64 },
}

impl ObsEvent {
    /// Short tag for rendering and the wire encoding.
    pub fn kind(&self) -> &'static str {
        match self {
            ObsEvent::Proposed { .. } => "proposed",
            ObsEvent::Dispatched { .. } => "dispatched",
            ObsEvent::Completed { .. } => "completed",
            ObsEvent::StragglerKilled { .. } => "straggler_killed",
            ObsEvent::DriftDetected { .. } => "drift_detected",
            ObsEvent::EliteExchange { .. } => "elite_exchange",
            ObsEvent::SurrogateFit { .. } => "surrogate_fit",
        }
    }

    pub fn to_json(&self) -> Json {
        let t = |t: &'static str| ("type", Json::Str(t.to_string()));
        match self {
            ObsEvent::Proposed { eval_id, shard, search_us } => Json::obj(vec![
                t("proposed"),
                ("eval_id", (*eval_id).into()),
                ("shard", (*shard as u64).into()),
                ("search_us", (*search_us).into()),
            ]),
            ObsEvent::Dispatched { eval_id, shard } => Json::obj(vec![
                t("dispatched"),
                ("eval_id", (*eval_id).into()),
                ("shard", (*shard as u64).into()),
            ]),
            ObsEvent::Completed { eval_id, shard, objective, best_so_far, sim_wallclock_s } => {
                Json::obj(vec![
                    t("completed"),
                    ("eval_id", (*eval_id).into()),
                    ("shard", (*shard as u64).into()),
                    ("objective", num_or_null(*objective)),
                    ("best_so_far", num_or_null(*best_so_far)),
                    ("sim_wallclock_s", num_or_null(*sim_wallclock_s)),
                ])
            }
            ObsEvent::StragglerKilled { eval_id, shard } => Json::obj(vec![
                t("straggler_killed"),
                ("eval_id", (*eval_id).into()),
                ("shard", (*shard as u64).into()),
            ]),
            ObsEvent::DriftDetected { eval_id, shard } => Json::obj(vec![
                t("drift_detected"),
                ("eval_id", (*eval_id).into()),
                ("shard", (*shard as u64).into()),
            ]),
            ObsEvent::EliteExchange { round, shard, absorbed } => Json::obj(vec![
                t("elite_exchange"),
                ("round", (*round).into()),
                ("shard", (*shard as u64).into()),
                ("absorbed", (*absorbed).into()),
            ]),
            ObsEvent::SurrogateFit { shard, cache_hit, fit_us } => Json::obj(vec![
                t("surrogate_fit"),
                ("shard", (*shard as u64).into()),
                ("cache_hit", (*cache_hit).into()),
                ("fit_us", (*fit_us).into()),
            ]),
        }
    }

    /// Lenient parse (absent fields default), `None` on unknown type.
    pub fn from_json(v: &Json) -> Option<ObsEvent> {
        let eval_id = get_u(v, "eval_id");
        let shard = get_u(v, "shard") as u32;
        match v.get("type").and_then(Json::as_str).unwrap_or("") {
            "proposed" => {
                Some(ObsEvent::Proposed { eval_id, shard, search_us: get_u(v, "search_us") })
            }
            "dispatched" => Some(ObsEvent::Dispatched { eval_id, shard }),
            "completed" => Some(ObsEvent::Completed {
                eval_id,
                shard,
                objective: get_obj(v, "objective"),
                best_so_far: get_obj(v, "best_so_far"),
                sim_wallclock_s: get_f(v, "sim_wallclock_s"),
            }),
            "straggler_killed" => Some(ObsEvent::StragglerKilled { eval_id, shard }),
            "drift_detected" => Some(ObsEvent::DriftDetected { eval_id, shard }),
            "elite_exchange" => Some(ObsEvent::EliteExchange {
                round: get_u(v, "round"),
                shard,
                absorbed: get_u(v, "absorbed"),
            }),
            "surrogate_fit" => Some(ObsEvent::SurrogateFit {
                shard,
                cache_hit: v.get("cache_hit").and_then(Json::as_bool).unwrap_or(false),
                fit_us: get_u(v, "fit_us"),
            }),
            _ => None,
        }
    }
}

/// An [`ObsEvent`] with its ring sequence number — the logical clock
/// readers cursor by.
#[derive(Debug, Clone, PartialEq)]
pub struct RingEvent {
    pub seq: u64,
    pub ev: ObsEvent,
}

impl RingEvent {
    pub fn to_json(&self) -> Json {
        match self.ev.to_json() {
            Json::Obj(mut fields) => {
                fields.insert("seq".to_string(), self.seq.into());
                Json::Obj(fields)
            }
            other => other,
        }
    }

    pub fn from_json(v: &Json) -> Option<RingEvent> {
        Some(RingEvent { seq: get_u(v, "seq"), ev: ObsEvent::from_json(v)? })
    }
}

struct RingInner {
    next_seq: u64,
    buf: VecDeque<RingEvent>,
}

/// Fixed-capacity event ring. The writer side never blocks (`try_lock`;
/// a contended record is counted, not waited for) and readers copy the
/// tail under a short lock.
pub struct EventRing {
    capacity: usize,
    inner: Mutex<RingInner>,
    dropped: AtomicU64,
}

impl std::fmt::Debug for EventRing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("EventRing")
            .field("capacity", &self.capacity)
            .field("next_seq", &self.next_seq())
            .field("dropped", &self.dropped())
            .finish()
    }
}

impl EventRing {
    pub fn new(capacity: usize) -> EventRing {
        EventRing {
            capacity: capacity.max(1),
            inner: Mutex::new(RingInner { next_seq: 0, buf: VecDeque::new() }),
            dropped: AtomicU64::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Record one event. Never blocks: if a reader holds the lock this
    /// instant, the event is dropped and counted instead.
    pub fn record(&self, ev: ObsEvent) {
        match self.inner.try_lock() {
            Ok(mut inner) => {
                let seq = inner.next_seq;
                inner.next_seq += 1;
                inner.buf.push_back(RingEvent { seq, ev });
                if inner.buf.len() > self.capacity {
                    inner.buf.pop_front();
                }
            }
            Err(_) => {
                self.dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Copy every retained event with `seq >= from`, plus the cursor to
    /// pass next time. A `from` older than the oldest retained sequence
    /// means the reader fell behind the wraparound; the gap is visible
    /// in the returned sequence numbers.
    pub fn tail(&self, from: u64) -> (Vec<RingEvent>, u64) {
        let inner = self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let evs = inner.buf.iter().filter(|e| e.seq >= from).cloned().collect();
        (evs, inner.next_seq)
    }

    /// The next sequence number to be assigned (== events recorded so
    /// far, drops excluded).
    pub fn next_seq(&self) -> u64 {
        self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner).next_seq
    }

    /// Events lost to writer-side lock contention.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }
}

/// Per-shard gauges, refreshed on every applied completion.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ShardGauges {
    pub shard: u32,
    pub workers: u64,
    pub in_flight: u64,
    pub applied: u64,
    pub best_objective: f64,
    pub sim_wallclock_s: f64,
    /// Sum of simulated spans charged to workers (serial-equivalent
    /// time); utilization = busy / (workers * wallclock).
    pub busy_s: f64,
}

impl ShardGauges {
    /// Worker utilization in `[0, 1]` under the simulated schedule.
    pub fn utilization(&self) -> f64 {
        let denom = self.workers as f64 * self.sim_wallclock_s;
        if denom > 0.0 {
            (self.busy_s / denom).clamp(0.0, 1.0)
        } else {
            0.0
        }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("shard", (self.shard as u64).into()),
            ("workers", self.workers.into()),
            ("in_flight", self.in_flight.into()),
            ("applied", self.applied.into()),
            ("best_objective", num_or_null(self.best_objective)),
            ("sim_wallclock_s", num_or_null(self.sim_wallclock_s)),
            ("busy_s", num_or_null(self.busy_s)),
        ])
    }

    fn from_json(v: &Json) -> ShardGauges {
        ShardGauges {
            shard: get_u(v, "shard") as u32,
            workers: get_u(v, "workers"),
            in_flight: get_u(v, "in_flight"),
            applied: get_u(v, "applied"),
            best_objective: get_obj(v, "best_objective"),
            sim_wallclock_s: get_f(v, "sim_wallclock_s"),
            busy_s: get_f(v, "busy_s"),
        }
    }
}

/// A point-in-time copy of every counter and gauge, serializable for
/// the `StatsReply` frame and the solo snapshot file.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct StatsSnapshot {
    pub proposals: u64,
    pub dispatches: u64,
    pub completions: u64,
    pub straggler_kills: u64,
    /// Continuous-controller drift detections (CUSUM fires).
    pub drift_detections: u64,
    pub exchange_rounds: u64,
    /// Surrogate fits actually paid (epoch-cache misses).
    pub surrogate_fits: u64,
    pub surrogate_cache_hits: u64,
    /// Total measured proposal-loop overhead, microseconds.
    pub search_us_total: u64,
    /// Total measured surrogate-fit time, microseconds.
    pub fit_us_total: u64,
    /// Ring logical clock (events recorded so far).
    pub ring_next: u64,
    pub ring_dropped: u64,
    pub best_objective: f64,
    pub shards: Vec<ShardGauges>,
}

impl StatsSnapshot {
    /// Mean framework overhead per applied completion, microseconds
    /// (proposal loop + surrogate fits) — the paper-§IV-style number the
    /// bench gate holds near-free.
    pub fn overhead_us_per_completion(&self) -> f64 {
        if self.completions == 0 {
            return 0.0;
        }
        (self.search_us_total + self.fit_us_total) as f64 / self.completions as f64
    }

    /// Epoch-cache hit rate over all surrogate model uses.
    pub fn cache_hit_rate(&self) -> f64 {
        let uses = self.surrogate_fits + self.surrogate_cache_hits;
        if uses == 0 {
            0.0
        } else {
            self.surrogate_cache_hits as f64 / uses as f64
        }
    }

    pub fn in_flight(&self) -> u64 {
        self.shards.iter().map(|s| s.in_flight).sum()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("proposals", self.proposals.into()),
            ("dispatches", self.dispatches.into()),
            ("completions", self.completions.into()),
            ("straggler_kills", self.straggler_kills.into()),
            ("drift_detections", self.drift_detections.into()),
            ("exchange_rounds", self.exchange_rounds.into()),
            ("surrogate_fits", self.surrogate_fits.into()),
            ("surrogate_cache_hits", self.surrogate_cache_hits.into()),
            ("search_us_total", self.search_us_total.into()),
            ("fit_us_total", self.fit_us_total.into()),
            ("ring_next", self.ring_next.into()),
            ("ring_dropped", self.ring_dropped.into()),
            ("best_objective", num_or_null(self.best_objective)),
            ("shards", Json::Arr(self.shards.iter().map(ShardGauges::to_json).collect())),
        ])
    }

    pub fn from_json(v: &Json) -> StatsSnapshot {
        StatsSnapshot {
            proposals: get_u(v, "proposals"),
            dispatches: get_u(v, "dispatches"),
            completions: get_u(v, "completions"),
            straggler_kills: get_u(v, "straggler_kills"),
            drift_detections: get_u(v, "drift_detections"),
            exchange_rounds: get_u(v, "exchange_rounds"),
            surrogate_fits: get_u(v, "surrogate_fits"),
            surrogate_cache_hits: get_u(v, "surrogate_cache_hits"),
            search_us_total: get_u(v, "search_us_total"),
            fit_us_total: get_u(v, "fit_us_total"),
            ring_next: get_u(v, "ring_next"),
            ring_dropped: get_u(v, "ring_dropped"),
            best_objective: get_obj(v, "best_objective"),
            shards: v
                .get("shards")
                .and_then(Json::as_arr)
                .map(|a| a.iter().map(ShardGauges::from_json).collect())
                .unwrap_or_default(),
        }
    }
}

/// The shared recording handle: one per campaign, cloned (via `Arc`)
/// into every shard, the generational manager, and the Bayesian
/// optimizer. Counters are atomics; the per-shard gauge table and the
/// ring take `try_lock` on the write side so the engine never waits on
/// a reader.
pub struct ObsSink {
    ring: EventRing,
    proposals: AtomicU64,
    dispatches: AtomicU64,
    completions: AtomicU64,
    straggler_kills: AtomicU64,
    drift_detections: AtomicU64,
    exchange_rounds: AtomicU64,
    surrogate_fits: AtomicU64,
    surrogate_cache_hits: AtomicU64,
    search_us_total: AtomicU64,
    fit_us_total: AtomicU64,
    /// f64 bits of the best finite objective seen (init +inf).
    best_bits: AtomicU64,
    shards: Mutex<BTreeMap<u32, ShardGauges>>,
}

impl std::fmt::Debug for ObsSink {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ObsSink").field("snapshot", &self.snapshot()).finish()
    }
}

impl Default for ObsSink {
    fn default() -> ObsSink {
        ObsSink::new(DEFAULT_RING_CAPACITY)
    }
}

impl ObsSink {
    pub fn new(ring_capacity: usize) -> ObsSink {
        ObsSink {
            ring: EventRing::new(ring_capacity),
            proposals: AtomicU64::new(0),
            dispatches: AtomicU64::new(0),
            completions: AtomicU64::new(0),
            straggler_kills: AtomicU64::new(0),
            drift_detections: AtomicU64::new(0),
            exchange_rounds: AtomicU64::new(0),
            surrogate_fits: AtomicU64::new(0),
            surrogate_cache_hits: AtomicU64::new(0),
            search_us_total: AtomicU64::new(0),
            fit_us_total: AtomicU64::new(0),
            best_bits: AtomicU64::new(f64::INFINITY.to_bits()),
            shards: Mutex::new(BTreeMap::new()),
        }
    }

    /// Record one event: bump the matching counters, then push it onto
    /// the ring. Write-only — nothing here is ever read back by the
    /// engine, so recording cannot perturb a trajectory.
    pub fn record(&self, ev: ObsEvent) {
        match &ev {
            ObsEvent::Proposed { search_us, .. } => {
                self.proposals.fetch_add(1, Ordering::Relaxed);
                self.search_us_total.fetch_add(*search_us, Ordering::Relaxed);
            }
            ObsEvent::Dispatched { .. } => {
                self.dispatches.fetch_add(1, Ordering::Relaxed);
            }
            ObsEvent::Completed { best_so_far, .. } => {
                self.completions.fetch_add(1, Ordering::Relaxed);
                if best_so_far.is_finite() {
                    let bits = best_so_far.to_bits();
                    // monotonic min over positive finite f64s: their bit
                    // patterns order like the values
                    let _ = self.best_bits.fetch_update(
                        Ordering::Relaxed,
                        Ordering::Relaxed,
                        |cur| (f64::from_bits(cur) > *best_so_far).then_some(bits),
                    );
                }
            }
            ObsEvent::StragglerKilled { .. } => {
                self.straggler_kills.fetch_add(1, Ordering::Relaxed);
            }
            ObsEvent::DriftDetected { .. } => {
                self.drift_detections.fetch_add(1, Ordering::Relaxed);
            }
            ObsEvent::EliteExchange { .. } => {
                self.exchange_rounds.fetch_add(1, Ordering::Relaxed);
            }
            ObsEvent::SurrogateFit { cache_hit, fit_us, .. } => {
                if *cache_hit {
                    self.surrogate_cache_hits.fetch_add(1, Ordering::Relaxed);
                } else {
                    self.surrogate_fits.fetch_add(1, Ordering::Relaxed);
                    self.fit_us_total.fetch_add(*fit_us, Ordering::Relaxed);
                }
            }
        }
        self.ring.record(ev);
    }

    /// Refresh one shard's gauges. Skipped (not waited for) if a reader
    /// holds the table this instant — gauges are refreshed every apply,
    /// so one stale tick is invisible.
    pub fn set_shard_gauges(&self, g: ShardGauges) {
        if let Ok(mut shards) = self.shards.try_lock() {
            shards.insert(g.shard, g);
        }
    }

    /// Copy the tail of the event ring from sequence `from`.
    pub fn tail(&self, from: u64) -> (Vec<RingEvent>, u64) {
        self.ring.tail(from)
    }

    pub fn ring(&self) -> &EventRing {
        &self.ring
    }

    pub fn snapshot(&self) -> StatsSnapshot {
        let shards: Vec<ShardGauges> = self
            .shards
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .values()
            .cloned()
            .collect();
        StatsSnapshot {
            proposals: self.proposals.load(Ordering::Relaxed),
            dispatches: self.dispatches.load(Ordering::Relaxed),
            completions: self.completions.load(Ordering::Relaxed),
            straggler_kills: self.straggler_kills.load(Ordering::Relaxed),
            drift_detections: self.drift_detections.load(Ordering::Relaxed),
            exchange_rounds: self.exchange_rounds.load(Ordering::Relaxed),
            surrogate_fits: self.surrogate_fits.load(Ordering::Relaxed),
            surrogate_cache_hits: self.surrogate_cache_hits.load(Ordering::Relaxed),
            search_us_total: self.search_us_total.load(Ordering::Relaxed),
            fit_us_total: self.fit_us_total.load(Ordering::Relaxed),
            ring_next: self.ring.next_seq(),
            ring_dropped: self.ring.dropped(),
            best_objective: f64::from_bits(self.best_bits.load(Ordering::Relaxed)),
            shards,
        }
    }
}

/// Seconds → whole microseconds, saturating (stat durations only).
pub fn secs_to_us(s: f64) -> u64 {
    if s.is_finite() && s > 0.0 {
        (s * 1e6) as u64
    } else {
        0
    }
}

fn num_or_null(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

fn get_u(v: &Json, key: &str) -> u64 {
    v.get(key).and_then(Json::as_u64).unwrap_or(0)
}

fn get_f(v: &Json, key: &str) -> f64 {
    v.get(key).and_then(Json::as_f64).unwrap_or(0.0)
}

/// Objective off the wire: `null` (non-finite on encode) reads as +inf.
fn get_obj(v: &Json, key: &str) -> f64 {
    v.get(key).and_then(Json::as_f64).unwrap_or(f64::INFINITY)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest_lite::for_all;
    use crate::util::Pcg32;

    fn ev(eval_id: u64) -> ObsEvent {
        ObsEvent::Dispatched { eval_id, shard: 0 }
    }

    #[test]
    fn ring_retains_the_newest_capacity_events() {
        let ring = EventRing::new(4);
        for i in 0..10 {
            ring.record(ev(i));
        }
        let (evs, next) = ring.tail(0);
        assert_eq!(next, 10);
        assert_eq!(evs.len(), 4);
        let seqs: Vec<u64> = evs.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![6, 7, 8, 9]);
        assert_eq!(ring.dropped(), 0);
    }

    #[test]
    fn tail_cursors_resume_without_gaps_or_duplicates() {
        let ring = EventRing::new(64);
        for i in 0..5 {
            ring.record(ev(i));
        }
        let (first, cursor) = ring.tail(0);
        for i in 5..9 {
            ring.record(ev(i));
        }
        let (second, cursor2) = ring.tail(cursor);
        let mut seqs: Vec<u64> = first.iter().chain(second.iter()).map(|e| e.seq).collect();
        assert_eq!(seqs, (0..9).collect::<Vec<u64>>());
        seqs.dedup();
        assert_eq!(seqs.len() as u64, cursor2);
    }

    #[test]
    fn prop_ring_wraparound_keeps_a_contiguous_newest_suffix() {
        // proptest_lite sweep of (capacity, pushes): whatever the
        // wraparound point, the retained events are exactly the newest
        // min(pushes, capacity) with contiguous ascending sequences
        for_all(
            "ring_wraparound",
            200,
            0x0b5e5eed,
            |rng: &mut Pcg32| {
                let capacity = 1 + (rng.next_u64() % 16) as usize;
                let pushes = (rng.next_u64() % 64) as usize;
                (capacity, pushes)
            },
            |&(capacity, pushes)| {
                let ring = EventRing::new(capacity);
                for i in 0..pushes {
                    ring.record(ev(i as u64));
                }
                let (evs, next) = ring.tail(0);
                let expect_len = pushes.min(capacity);
                let first = pushes - expect_len;
                next == pushes as u64
                    && evs.len() == expect_len
                    && evs.iter().enumerate().all(|(i, e)| e.seq == (first + i) as u64)
            },
        );
    }

    #[test]
    fn sink_counters_and_best_track_events() {
        let sink = ObsSink::new(16);
        sink.record(ObsEvent::Proposed { eval_id: 0, shard: 0, search_us: 120 });
        sink.record(ObsEvent::Dispatched { eval_id: 0, shard: 0 });
        sink.record(ObsEvent::SurrogateFit { shard: 0, cache_hit: false, fit_us: 900 });
        sink.record(ObsEvent::SurrogateFit { shard: 0, cache_hit: true, fit_us: 0 });
        sink.record(ObsEvent::Completed {
            eval_id: 0,
            shard: 0,
            objective: 12.5,
            best_so_far: 12.5,
            sim_wallclock_s: 3.0,
        });
        sink.record(ObsEvent::Completed {
            eval_id: 1,
            shard: 0,
            objective: 15.0,
            best_so_far: 12.5,
            sim_wallclock_s: 6.0,
        });
        sink.record(ObsEvent::StragglerKilled { eval_id: 1, shard: 0 });
        sink.record(ObsEvent::DriftDetected { eval_id: 1, shard: 0 });
        sink.record(ObsEvent::EliteExchange { round: 1, shard: 0, absorbed: 2 });
        let snap = sink.snapshot();
        assert_eq!(snap.proposals, 1);
        assert_eq!(snap.dispatches, 1);
        assert_eq!(snap.completions, 2);
        assert_eq!(snap.straggler_kills, 1);
        assert_eq!(snap.drift_detections, 1);
        assert_eq!(snap.exchange_rounds, 1);
        assert_eq!(snap.surrogate_fits, 1);
        assert_eq!(snap.surrogate_cache_hits, 1);
        assert_eq!(snap.search_us_total, 120);
        assert_eq!(snap.fit_us_total, 900);
        assert_eq!(snap.best_objective, 12.5);
        assert_eq!(snap.ring_next, 9);
        assert_eq!(snap.cache_hit_rate(), 0.5);
        assert_eq!(snap.overhead_us_per_completion(), 510.0);
    }

    #[test]
    fn snapshot_and_events_roundtrip_through_json() {
        let sink = ObsSink::new(8);
        sink.record(ObsEvent::Proposed { eval_id: 3, shard: 1, search_us: 42 });
        sink.record(ObsEvent::Completed {
            eval_id: 3,
            shard: 1,
            objective: f64::INFINITY, // travels as null, reads as +inf
            best_so_far: 9.25,
            sim_wallclock_s: 1.5,
        });
        sink.set_shard_gauges(ShardGauges {
            shard: 1,
            workers: 4,
            in_flight: 3,
            applied: 7,
            best_objective: 9.25,
            sim_wallclock_s: 20.0,
            busy_s: 60.0,
        });
        let snap = sink.snapshot();
        let back =
            StatsSnapshot::from_json(&Json::parse(&snap.to_json().to_string()).unwrap());
        assert_eq!(back, snap);
        assert_eq!(back.shards[0].utilization(), 0.75);
        let (evs, _) = sink.tail(0);
        for e in evs {
            let rt = RingEvent::from_json(&Json::parse(&e.to_json().to_string()).unwrap());
            assert_eq!(rt, Some(e));
        }
    }
}
