//! Multi-manager federation: shard the candidate space across K
//! continuous-cycle managers (the paper tunes spaces of up to 6 million
//! configurations on up to 4,096 nodes — past a point, one manager
//! process is the bottleneck; ROADMAP names this federation as the step
//! after PR 2's continuous cycle, following the ytopt+libEnsemble
//! manager/worker scaling direction).
//!
//! Topology and guarantees:
//!
//! * **Sharding** — every configuration has a flat cartesian index
//!   (`ConfigSpace::index_of`); [`shard_of_index`] hashes `(seed, index)`
//!   into `0..K`. Because it is a total function of the index, the K
//!   partitions are a *disjoint cover* of the space by construction, and
//!   re-sharding under the same seed is byte-identical (both pinned by
//!   `tests/property_invariants.rs`). A [`ShardSpec`] carries the
//!   `(seed, shards, shard)` triple and answers membership queries.
//! * **Shard managers** — each shard runs a [`ContinuousShard`]: its own
//!   worker pool, its own RNG stream, its own surrogate, and the PR-2
//!   continuous manager cycle, restricted to proposals inside its
//!   partition. Global eval ids interleave round-robin (shard `k` owns
//!   ids `k, k+K, k+2K, …`), so the final merge is a plain id sort.
//! * **Elite exchange** — every `elite_exchange_every` completions per
//!   shard, each shard broadcasts its top-N `(configuration, objective)`
//!   history entries; receivers absorb them through
//!   `BayesianOptimizer::observe_foreign` (recorded *and* marked seen,
//!   so a shard never proposes a duplicate of a foreign elite), deduped
//!   by configuration key across rounds. The exchange cost is modeled by
//!   [`crate::coordinator::overhead::federation_exchange_s`].
//! * **Determinism** — shard trajectories depend only on seeds, eval
//!   ids, and the (deterministic) exchange schedule, never on host
//!   thread timing; a K-shard run is seed-for-seed reproducible, and a
//!   K=1 federation runs the *same* engine the plain continuous manager
//!   uses, so its history is bit-identical to it.
//! * **Checkpointing** — each shard writes its own checkpoint (under its
//!   original global eval ids) next to a federation *manifest* that pins
//!   the policy fingerprint; resume restores every shard exactly and
//!   refuses manifests from a different federation policy.

use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use super::{
    checkpoint, evaluate_one, handle_outcome, save_checkpoint, settle_result, Checkpoint,
    EnsembleStats, EvalDone, EvalJob, EvalOutcome, ManagerCycle, OutcomeKind, Resolved,
    STRAGGLER_MIN_SAMPLES,
};
use crate::coordinator::{self, overhead, EvalRecord, PerfDatabase, TuneResult, TuneSetup};
use crate::metrics::improvement_pct;
use crate::runtime::Scorer;
use crate::space::{paper, ConfigSpace, Configuration};
use crate::util::stats::RunningQuantile;
use crate::util::{Json, Pcg32};
use anyhow::{Context, Result};

/// Upper bound on the shard count — far above anything a simulated
/// campaign needs, low enough to catch a mistyped flag.
pub const MAX_SHARDS: usize = 64;

/// Deterministic shard assignment for one flat configuration index:
/// a seeded 128-bit mix (splitmix-style finalizer) reduced mod `shards`.
/// Total function of `(seed, flat, shards)` — the K partitions cover the
/// index space with no overlap by construction — and byte-identical
/// across calls, which is what makes re-sharding stable across resumes.
pub fn shard_of_index(seed: u64, flat: u128, shards: u32) -> u32 {
    if shards <= 1 {
        return 0;
    }
    let mut h = seed ^ 0x51ed_2701_a1b2_c3d4;
    for v in [flat as u64, (flat >> 64) as u64] {
        h ^= v.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        h = h.rotate_left(27).wrapping_mul(0x2545_f491_4f6c_dd1d);
    }
    h ^= h >> 33;
    h = h.wrapping_mul(0xff51_afd7_ed55_8ccd);
    h ^= h >> 33;
    h = h.wrapping_mul(0xc4ce_b9fe_1a85_ec53);
    h ^= h >> 33;
    (h % shards as u64) as u32
}

/// One shard's view of the partitioned space: `(seed, shards, shard)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    /// Sharding seed (the run seed: same seed, same partition).
    pub seed: u64,
    /// Total shard count K.
    pub shards: u32,
    /// This shard's index in `0..K`.
    pub shard: u32,
}

impl ShardSpec {
    /// Does `cfg` belong to this shard's partition? With one shard the
    /// answer is always yes (the unsharded special case) — short-circuit
    /// before paying the `index_of` walk.
    pub fn contains(&self, space: &ConfigSpace, cfg: &Configuration) -> bool {
        self.shards <= 1 || self.contains_index(space.index_of(cfg))
    }

    /// Membership by flat configuration index — for callers that already
    /// hold the index (the BO candidate path dedups by it), sparing the
    /// second `index_of` walk.
    pub fn contains_index(&self, flat: u128) -> bool {
        self.shards <= 1 || shard_of_index(self.seed, flat, self.shards) == self.shard
    }

    fn stride(&self) -> usize {
        self.shards.max(1) as usize
    }
}

/// Federation telemetry surfaced in [`TuneResult::federation`].
#[derive(Debug, Clone)]
pub struct FederationStats {
    /// Manager shard count K.
    pub shards: usize,
    /// Completions per shard between elite exchanges.
    pub exchange_every: usize,
    /// Top-N history entries broadcast per shard per exchange.
    pub elite_n: usize,
    /// Exchange rounds performed.
    pub exchanges: usize,
    /// Foreign elite observations absorbed across all shards (deduped).
    pub elites_absorbed: usize,
    /// Simulated seconds charged per shard for exchange synchronization.
    pub exchange_s: f64,
    /// Completed evaluations per shard, in shard order.
    pub per_shard_evals: Vec<usize>,
}

/// Checkpoint fingerprint of one shard: the run fingerprint (which
/// covers the federation policy) plus the shard's identity, so shard
/// files can never be swapped between shards undetected.
pub fn shard_fingerprint(setup: &TuneSetup, shard: usize) -> String {
    format!("{}|shard{}", checkpoint::fingerprint(setup), shard)
}

/// Where shard `shard` of a federation checkpointing to `base` keeps its
/// per-shard checkpoint: `campaign.json` → `campaign.json.shard3.json`.
/// The suffix is *appended* to the full file name (never spliced in with
/// `with_extension`, which would replace an existing extension): bases
/// like `run.v2` and `run.v3` must derive distinct shard files.
pub fn shard_checkpoint_path(base: &Path, shard: usize) -> PathBuf {
    let mut name = base.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(format!(".shard{shard}.json"));
    base.with_file_name(name)
}

/// The federation manifest written at `checkpoint_path` itself: pins the
/// policy fingerprint and shard count so a resume under a different
/// federation policy is refused before any shard file is touched.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FederationManifest {
    pub fingerprint: String,
    pub shards: usize,
}

impl FederationManifest {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("version", 1u64.into()),
            ("kind", "federation-manifest".into()),
            ("fingerprint", self.fingerprint.as_str().into()),
            ("shards", (self.shards as u64).into()),
        ])
    }

    pub fn parse(text: &str) -> Result<FederationManifest> {
        let v = Json::parse(text).context("parsing federation manifest")?;
        anyhow::ensure!(
            v.get("kind").and_then(Json::as_str) == Some("federation-manifest"),
            "not a federation manifest (missing `kind`)"
        );
        let fingerprint = v
            .get("fingerprint")
            .and_then(Json::as_str)
            .context("federation manifest missing `fingerprint`")?
            .to_string();
        let shards = v
            .get("shards")
            .and_then(Json::as_u64)
            .context("federation manifest missing `shards`")? as usize;
        Ok(FederationManifest { fingerprint, shards })
    }

    /// Load from `path`; `Ok(None)` when no manifest exists yet. Sweeps
    /// any orphaned temp sibling first: a crash between temp write and
    /// rename must not leave litter behind.
    pub fn load(path: &Path) -> Result<Option<FederationManifest>> {
        crate::chaos::fsx::clean_orphan_tmp(path);
        if !path.exists() {
            return Ok(None);
        }
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading federation manifest {}", path.display()))?;
        Ok(Some(Self::parse(&text)?))
    }

    /// Atomic save through the blessed writer: sibling temp, read-back
    /// audit, rename. The temp name appends to the full file name so
    /// manifests at `run.v2` and `run.v3` never race on one temp file.
    pub fn save(&self, path: &Path) -> Result<()> {
        crate::chaos::fsx::install_atomic(
            path,
            self.to_json().to_string().as_bytes(),
            None,
            crate::chaos::Site::CkptWrite,
        )
        .with_context(|| format!("installing federation manifest {}", path.display()))
    }
}

/// RNG stream seed for one shard. A K=1 federation *is* the single
/// continuous manager, so it keeps the plain run seed; K>1 shards get
/// distinct mixed streams.
fn shard_rng_seed(seed: u64, shard: usize, shards: usize) -> u64 {
    if shards <= 1 {
        seed
    } else {
        seed ^ (shard as u64 + 1).wrapping_mul(0xa24b_aed4_963e_e407)
    }
}

/// Out-of-shard strategy proposals tolerated *per shard of stride* —
/// the budget scales with K (uniform hash partitions accept ~1/K of
/// shard-unaware proposals, so a fixed budget would silently degrade
/// high-K grid/mctree runs to rejection sampling) — before the shard
/// falls back to sampling its partition directly.
const PROPOSE_RETRIES_PER_STRIDE: usize = 32;

/// What one finished shard hands back to the driver (`pub(crate)`: the
/// service engine in [`crate::service`] drives shards too).
pub(crate) struct ShardRun {
    pub(crate) db: PerfDatabase,
    pub(crate) stats: EnsembleStats,
    pub(crate) wallclock: f64,
    pub(crate) best: f64,
    pub(crate) best_desc: String,
}

/// Live state of the continuous controller (`TuneSetup::controller`):
/// the drift detector over predicted-vs-observed residuals, the
/// actuation authority limiter, and the configuration currently
/// deployed on the substrate (the last dispatched proposal).
struct ControllerState {
    cusum: crate::drift::CusumDetector,
    limiter: crate::drift::AuthorityLimiter,
    deployed: Option<Configuration>,
}

/// One manager shard running the PR-2 continuous cycle over its
/// partition of the candidate space. The unsharded continuous manager is
/// exactly this struct with `ShardSpec { shards: 1, .. }` — which is
/// what makes the K=1 federation bit-identical to it.
pub(crate) struct ContinuousShard {
    setup: TuneSetup,
    lens: ShardSpec,
    space: Arc<ConfigSpace>,
    strat: coordinator::Strat,
    rng: Pcg32,
    pool: super::WorkerPool<EvalJob, EvalOutcome>,
    workers: usize,
    inflight_target: usize,
    completion_s: f64,
    db: PerfDatabase,
    stats: EnsembleStats,
    baseline_objective: f64,
    real_objectives: Vec<f64>,
    best: f64,
    best_desc: String,
    /// Next global eval id this shard will propose (stride = K).
    next_id: usize,
    /// Next global eval id to apply (results buffer until in order).
    next_apply: usize,
    inflight: BTreeMap<usize, Configuration>,
    arrived: BTreeMap<usize, Resolved>,
    runtime_dist: RunningQuantile,
    worker_free: Vec<f64>,
    wallclock: f64,
    charged_wallclock: f64,
    allocation: Option<crate::platform::scheduler::Allocation>,
    alloc_stop: bool,
    /// Configuration keys of foreign elites already absorbed (dedup
    /// across exchange rounds; seeded with warm-start elites and, on
    /// resume, with the checkpoint log's `Foreign` events).
    received_foreign: BTreeSet<String>,
    /// Strategy event log (proposals with their planted lies, applies,
    /// foreign absorptions) persisted with every checkpoint so a
    /// resumed shard's *fresh* proposals are bit-identical to an
    /// uninterrupted run's.
    slog: Vec<checkpoint::StrategyEvent>,
    /// False when this session resumed a pre-proposal-state checkpoint:
    /// a log started mid-run would not cover the restored records, so
    /// the session keeps writing the legacy format instead.
    log_valid: bool,
    fingerprint: String,
    checkpoint_path: Option<PathBuf>,
    done: bool,
    /// Simulated SIGKILL fired (`TuneSetup::kill_after_evals`): the
    /// shard stopped right after a checkpointed apply, leaving its
    /// dispatched-but-unfinished evaluations behind.
    killed: bool,
    /// Observability sink (`--stats`). Strictly write-only: every
    /// recording site below emits already-computed values; nothing in
    /// this shard ever reads the sink, so trajectories stay
    /// bit-identical with it present or absent (pinned by e2e).
    obs: Option<Arc<crate::obs::ObsSink>>,
    /// Continuous-controller state (`--controller`): drift detection,
    /// authority limits, quarantine. `None` runs the classic
    /// tune-to-budget campaign unchanged, bit for bit.
    ctl: Option<ControllerState>,
}

impl ContinuousShard {
    /// Build one shard manager: construct the strategy, resume from the
    /// shard checkpoint (completed records restore, in-flight re-queue
    /// under their original global eval ids), and spin up the pool.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn new(
        setup: &TuneSetup,
        lens: ShardSpec,
        space: Arc<ConfigSpace>,
        scorer: Arc<Scorer>,
        baseline_objective: f64,
        fingerprint: String,
        checkpoint_path: Option<PathBuf>,
    ) -> Result<ContinuousShard> {
        let workers = setup.ensemble_workers;
        anyhow::ensure!(workers >= 1, "shard needs >= 1 worker (got {workers})");
        let batch_target = if setup.ensemble_batch == 0 { workers } else { setup.ensemble_batch };
        let stride = lens.stride();

        let mut rng = Pcg32::seeded(shard_rng_seed(setup.seed, lens.shard as usize, stride));
        let mut strat = coordinator::build_strategy(setup, space.clone(), scorer.clone());
        // sharded BO filters its candidate pool by partition membership
        // before acquisition scoring: one fit per accepted proposal,
        // instead of ~K discarded propose pipelines. Unsharded (K=1),
        // the optimizer is left untouched so the RNG stream is identical
        // to the plain continuous manager's.
        if lens.shards > 1 {
            if let Some(bo) = strat.as_bo_mut() {
                bo.restrict_to_shard(lens);
            }
        }
        let obs = setup.obs.clone();
        if let (Some(sink), Some(bo)) = (&obs, strat.as_bo_mut()) {
            bo.set_obs(sink.clone(), lens.shard);
        }

        // ---- continuous controller (`--controller`) ---------------------
        // one governed tuner over the whole space: drift detection needs a
        // single residual stream and authority limits a single deployed
        // configuration, so the controller refuses sharded federations
        let mut ctl = if setup.controller {
            anyhow::ensure!(
                lens.shards <= 1,
                "the continuous controller drives a single manager (got {} federation shards)",
                lens.shards
            );
            anyhow::ensure!(
                setup.decay_half_life.is_finite() && setup.decay_half_life > 0.0,
                "decay-half-life must be a positive number of observations (got {})",
                setup.decay_half_life
            );
            anyhow::ensure!(
                setup.drift_threshold.is_finite() && setup.drift_threshold > 0.0,
                "drift-threshold must be a positive CUSUM threshold (got {})",
                setup.drift_threshold
            );
            anyhow::ensure!(
                setup.max_delta >= 1,
                "max-delta must allow at least one ordinal step (got {})",
                setup.max_delta
            );
            if let Some(bo) = strat.as_bo_mut() {
                bo.set_decay(setup.decay_half_life);
            }
            Some(ControllerState {
                cusum: crate::drift::CusumDetector::new(setup.drift_threshold),
                limiter: crate::drift::AuthorityLimiter::new(setup.max_delta),
                deployed: None,
            })
        } else {
            None
        };

        let mut db = PerfDatabase::new();
        let mut wallclock = 0.0f64;
        let mut best = f64::INFINITY;
        let mut best_desc = String::new();
        let mut real_objectives: Vec<f64> = Vec::new();
        let mut stats =
            EnsembleStats::new(workers, batch_target, setup.liar, ManagerCycle::Continuous);

        // warm-start elites were absorbed at strategy construction (in
        // `coordinator::build_strategy`); the shard seeds its liar pool
        // and its exchange dedup set with them here, so a federation
        // round can never re-absorb an elite the warm start already
        // planted — and so fresh and resumed sessions agree on the
        // real-objective pool's contents and order.
        let mut received_foreign: BTreeSet<String> = BTreeSet::new();
        if let Some(prior) = &setup.foreign_warm {
            for (c, y) in prior {
                received_foreign.insert(c.key());
                if y.is_finite() {
                    real_objectives.push(*y);
                }
            }
        }

        // ---- resume: feed checkpointed evaluations straight to the search
        let mut resume_inflight: Vec<(usize, Configuration)> = Vec::new();
        let mut slog: Vec<checkpoint::StrategyEvent> = Vec::new();
        let mut log_valid = true;
        let mut restored_rng: Option<Pcg32> = None;
        if let Some(path) = &checkpoint_path {
            if let Some(cp) = Checkpoint::load(path)? {
                anyhow::ensure!(
                    cp.fingerprint == fingerprint,
                    "checkpoint {} belongs to a different run: `{}` != `{fingerprint}`",
                    path.display(),
                    cp.fingerprint
                );
                match cp.proposal {
                    Some(ps) => {
                        // version-3 resume: replay the strategy event log.
                        // Pending lies land at their original observation
                        // indices, completions amend in their original
                        // order, and foreign elites re-enter (re-seeding
                        // the dedup set) between the right completions;
                        // then the persisted RNG stream continues — so
                        // fresh post-resume proposals are bit-identical
                        // to an uninterrupted run's.
                        let by_id: BTreeMap<usize, &EvalRecord> =
                            cp.records.iter().map(|r| (r.id, r)).collect();
                        let mut applied = 0usize;
                        for ev in &ps.log {
                            match ev {
                                checkpoint::StrategyEvent::Propose {
                                    eval_id,
                                    config_key,
                                    lie,
                                } => {
                                    // the logged configuration is the one
                                    // actually dispatched (post authority
                                    // limit), so replaying it restores the
                                    // controller's deployed state exactly
                                    if let Some(c) = &mut ctl {
                                        c.deployed =
                                            Some(checkpoint::config_from_key(config_key)?);
                                    }
                                    if let Some(lie) = lie {
                                        let cfg = checkpoint::config_from_key(config_key)?;
                                        if let Some(bo) = strat.as_bo_mut() {
                                            bo.observe_pending(*eval_id, &cfg, *lie);
                                        }
                                    }
                                }
                                checkpoint::StrategyEvent::Apply { eval_id } => {
                                    let rec = by_id.get(eval_id).with_context(|| {
                                        format!(
                                            "checkpoint {} log applies eval {eval_id} with no \
                                             record for it",
                                            path.display()
                                        )
                                    })?;
                                    let cfg = checkpoint::config_from_key(&rec.config_key)?;
                                    // the quarantine decision is a pure
                                    // function of (objective, baseline) —
                                    // recomputing it here replays the live
                                    // path's surrogate feed bit for bit
                                    let quarantined = ctl.is_some()
                                        && crate::drift::quarantine(
                                            rec.objective,
                                            baseline_objective,
                                        );
                                    let surrogate_y = if quarantined {
                                        baseline_objective
                                    } else {
                                        rec.objective
                                    };
                                    let amended = match strat.as_bo_mut() {
                                        Some(bo) => bo.resolve_pending(*eval_id, surrogate_y),
                                        None => false,
                                    };
                                    if !amended {
                                        strat.observe(&cfg, surrogate_y);
                                    }
                                    if !quarantined
                                        && !rec.timed_out
                                        && rec.objective.is_finite()
                                    {
                                        real_objectives.push(rec.objective);
                                        if rec.objective < best {
                                            best = rec.objective;
                                            best_desc = rec.config_desc.clone();
                                        }
                                    }
                                    applied += 1;
                                }
                                checkpoint::StrategyEvent::Drift { .. } => {
                                    // a checkpointed drift fire: re-reset
                                    // the surrogate window at the same
                                    // point in the observation stream (the
                                    // CUSUM accumulators themselves resume
                                    // from the checkpointed state below)
                                    if let Some(bo) = strat.as_bo_mut() {
                                        bo.reset_window();
                                    }
                                }
                                checkpoint::StrategyEvent::Foreign { config_key, y } => {
                                    let cfg = checkpoint::config_from_key(config_key)?;
                                    received_foreign.insert(config_key.clone());
                                    strat.observe_foreign(&cfg, *y);
                                    if y.is_finite() {
                                        real_objectives.push(*y);
                                    }
                                }
                            }
                        }
                        anyhow::ensure!(
                            applied == cp.records.len(),
                            "checkpoint {} strategy log covers {applied} applied completions \
                             but {} records are checkpointed",
                            path.display(),
                            cp.records.len()
                        );
                        if let (Some(c), Some((pos, neg))) = (&mut ctl, ps.cusum) {
                            c.cusum.restore(pos, neg);
                        }
                        restored_rng = Some(Pcg32::from_state(ps.rng_state, ps.rng_inc));
                        slog = ps.log;
                    }
                    None => {
                        // pre-proposal-state checkpoint: restore the
                        // applied history only. Resume stays exact for the
                        // re-queued in-flight work (outcomes depend only
                        // on seed/config/id/attempt); fresh proposals draw
                        // a fresh stream, as before this state existed.
                        // The session must then keep the legacy format: a
                        // log started mid-run would cover neither the
                        // restored records nor the re-imputed lies.
                        log_valid = cp.records.is_empty() && cp.in_flight.is_empty();
                        // the controller cannot resume without it: the
                        // CUSUM accumulators and the deployed
                        // configuration live in the proposal state
                        anyhow::ensure!(
                            ctl.is_none() || log_valid,
                            "checkpoint {} predates the proposal state the continuous \
                             controller needs to resume",
                            path.display()
                        );
                        for rec in &cp.records {
                            let cfg = checkpoint::config_from_key(&rec.config_key)?;
                            strat.observe(&cfg, rec.objective);
                            if !rec.timed_out && rec.objective.is_finite() {
                                if rec.objective < best {
                                    best = rec.objective;
                                    best_desc = rec.config_desc.clone();
                                }
                                real_objectives.push(rec.objective);
                            }
                        }
                    }
                }
                for rec in cp.records {
                    db.push(rec);
                }
                wallclock = cp.wallclock_s;
                stats.resumed_evals = db.len();
                for f in cp.in_flight {
                    let cfg = checkpoint::config_from_key(&f.config_key)?;
                    resume_inflight.push((f.eval_id, cfg));
                }
                // applications happen in eval-id order, so the in-flight
                // set must be exactly this shard's ids right after its
                // completed records
                let first_free = lens.shard as usize + db.len() * stride;
                for (i, (id, _)) in resume_inflight.iter().enumerate() {
                    anyhow::ensure!(
                        *id == first_free + i * stride,
                        "checkpoint {} in-flight ids are not contiguous with its \
                         completed records (found {id}, expected {})",
                        path.display(),
                        first_free + i * stride
                    );
                }
                log::info!(
                    "shard {}: resumed {} completed evaluations ({} in flight re-queued, \
                     proposal state {}) from {}",
                    lens.shard,
                    db.len(),
                    resume_inflight.len(),
                    if restored_rng.is_some() { "replayed" } else { "absent" },
                    path.display()
                );
            }
        }
        let mut next_id = lens.shard as usize + db.len() * stride;
        let next_apply = next_id;

        // ---- the worker pool --------------------------------------------
        let eval_fn = {
            let setup = Arc::new(setup.clone());
            let space = space.clone();
            let scorer = scorer.clone();
            let model: Arc<dyn crate::apps::AppModel> =
                Arc::from(coordinator::model_for_setup(&setup));
            move |worker: usize, job: EvalJob| -> EvalOutcome {
                if job.excluded.contains(&worker) {
                    return EvalOutcome { job, worker, kind: OutcomeKind::Bounced };
                }
                if let Some(plan) = &setup.chaos {
                    if plan.fire(crate::chaos::Site::WorkerCrash).is_some() {
                        panic!("chaos: injected worker crash on ensemble-worker-{worker}");
                    }
                }
                evaluate_one(&setup, &space, &scorer, model.as_ref(), worker, job)
            }
        };
        let pool: super::WorkerPool<EvalJob, EvalOutcome> = super::WorkerPool::new_supervised(
            workers,
            workers.max(batch_target) * 2,
            eval_fn,
            |worker, job| EvalOutcome { job, worker, kind: OutcomeKind::Crashed },
        );

        // node-hour budgets split evenly across the federation's shards
        let allocation = setup.node_hours_budget.map(|nh| {
            crate::platform::scheduler::Allocation::new(
                setup.platform,
                "ytopt-repro",
                nh / stride as f64,
            )
        });

        let inflight_target = batch_target.max(1);
        let completion_s = overhead::continuous_completion_s(workers);
        let mut inflight: BTreeMap<usize, Configuration> = BTreeMap::new();
        // online runtime distribution for the straggler cutoff, seeded
        // from resumed history
        let mut runtime_dist = RunningQuantile::new();
        for rec in &db.records {
            if !rec.timed_out && !rec.cancelled {
                runtime_dist.push(rec.measured.runtime_s);
            }
        }
        let worker_free = vec![wallclock; workers];
        let charged_wallclock = wallclock;

        // re-queue checkpointed in-flight evaluations under their
        // original global eval ids before proposing anything new
        let replayed = restored_rng.is_some();
        for (id, cfg) in &resume_inflight {
            // a replayed session already planted these lies through the
            // log (at their original observation indices, with their
            // original values); the legacy path re-imputes them, gated
            // as on the fresh proposal path — lies only matter when more
            // than one proposal can be outstanding
            if !replayed && inflight_target > 1 {
                if let Some(bo) = strat.as_bo_mut() {
                    let lie = setup.liar.impute(
                        Some(&mut *bo),
                        cfg,
                        &real_objectives,
                        baseline_objective,
                        &mut rng,
                    );
                    bo.observe_pending(*id, cfg, lie);
                }
            }
            inflight.insert(*id, cfg.clone());
            anyhow::ensure!(
                pool.submit(EvalJob {
                    eval_id: *id,
                    attempt: 0,
                    bounces: 0,
                    crashes: 0,
                    excluded: Vec::new(),
                    cfg: cfg.clone(),
                    search_s: 0.0,
                }),
                "ensemble worker pool rejected a re-queued job"
            );
            next_id += stride;
        }
        // continue the persisted stream (replay) instead of re-seeding:
        // the next fresh proposal draws exactly the numbers the
        // uninterrupted run would have drawn
        if let Some(r) = restored_rng {
            rng = r;
        }

        Ok(ContinuousShard {
            setup: setup.clone(),
            lens,
            space,
            strat,
            rng,
            pool,
            workers,
            inflight_target,
            completion_s,
            db,
            stats,
            baseline_objective,
            real_objectives,
            best,
            best_desc,
            next_id,
            next_apply,
            inflight,
            arrived: BTreeMap::new(),
            runtime_dist,
            worker_free,
            wallclock,
            charged_wallclock,
            allocation,
            alloc_stop: false,
            received_foreign,
            slog,
            log_valid,
            fingerprint,
            checkpoint_path,
            done: false,
            killed: false,
            obs,
            ctl,
        })
    }

    /// Out of work (budget drained) *or* simulated-killed: either way
    /// this shard applies nothing more this session.
    pub(crate) fn is_finished(&self) -> bool {
        self.done || self.killed
    }

    /// Completions applied so far, resumed history included — the
    /// absolute count the federation's exchange schedule is keyed on.
    pub(crate) fn applied(&self) -> usize {
        self.db.len()
    }

    /// The applied history so far, in eval-id order (read-only view for
    /// drivers that stream per-completion progress events).
    pub(crate) fn records(&self) -> &[EvalRecord] {
        &self.db.records
    }

    /// Global eval ids proposed so far (the next id this shard will
    /// assign). The delta across a [`ContinuousShard::run_for`] call is
    /// how many fresh proposals that step made.
    pub(crate) fn proposed(&self) -> usize {
        self.next_id
    }

    /// Propose the next configuration inside this shard's partition.
    /// Unsharded (K=1), this is a plain `strat.propose` — identical RNG
    /// stream to the single continuous manager. Sharded, BO already
    /// filters its candidates to the partition (`restrict_to_shard` in
    /// the constructor: one fit per proposal), so the bounded discard
    /// loop below is a safety net for the non-BO strategies (random /
    /// grid / mctree propose shard-unaware) and for BO's rare
    /// exhausted-space fallbacks, before direct rejection sampling.
    fn propose_in_shard(&mut self) -> Configuration {
        if self.lens.shards <= 1 {
            return self.strat.propose(&mut self.rng);
        }
        for _ in 0..PROPOSE_RETRIES_PER_STRIDE * self.lens.stride() {
            let c = self.strat.propose(&mut self.rng);
            if self.lens.contains(&self.space, &c) {
                return c;
            }
        }
        log::warn!(
            "shard {}: strategy proposals kept leaving the partition; \
             falling back to rejection sampling",
            self.lens.shard
        );
        for _ in 0..10_000 {
            let c = self.space.sample(&mut self.rng);
            if self.lens.contains(&self.space, &c) {
                return c;
            }
        }
        // pathological partition (tiny space): accept an out-of-shard
        // point rather than spin forever
        self.strat.propose(&mut self.rng)
    }

    /// Keep every worker fed while budget remains. Runs at manager
    /// events only, so the propose/apply interleaving — and with it the
    /// surrogate state behind every proposal — is a pure function of the
    /// applied prefix plus the deterministic exchange schedule.
    fn top_up(&mut self) -> Result<()> {
        while self.inflight.len() < self.inflight_target
            && self.next_id < self.setup.max_evals
            && self.wallclock < self.setup.wallclock_budget_s
            && !self.alloc_stop
        {
            if let Some(alloc) = &self.allocation {
                let done_n = self.db.len();
                let est = if done_n > 0 { self.wallclock / done_n as f64 } else { 60.0 };
                if !alloc.can_afford(self.setup.nodes, est) {
                    log::info!(
                        "shard {}: allocation exhausted after {done_n} evaluations",
                        self.lens.shard
                    );
                    self.alloc_stop = true;
                    break;
                }
            }
            // detlint: allow(wall-clock) -- search-overhead stat only; simulated time drives the trajectory
            let t_search = std::time::Instant::now();
            let cfg = self.propose_in_shard();
            // authority limit: the dispatched configuration moves at most
            // one parameter at most `max_delta` steps from the deployed
            // one. The limited configuration — not the raw proposal — is
            // what gets the lie, the log entry, and the dispatch, so a
            // resumed run replays the governed trajectory verbatim.
            let cfg = match &mut self.ctl {
                Some(c) => {
                    let limited = match &c.deployed {
                        Some(dep) => c.limiter.limit(&self.space, dep, &cfg),
                        None => cfg,
                    };
                    c.deployed = Some(limited.clone());
                    limited
                }
                None => cfg,
            };
            let mut planted_lie = None;
            if self.inflight_target > 1 {
                if let Some(bo) = self.strat.as_bo_mut() {
                    let lie = self.setup.liar.impute(
                        Some(&mut *bo),
                        &cfg,
                        &self.real_objectives,
                        self.baseline_objective,
                        &mut self.rng,
                    );
                    bo.observe_pending(self.next_id, &cfg, lie);
                    planted_lie = Some(lie);
                }
            }
            if self.log_valid {
                self.slog.push(checkpoint::StrategyEvent::Propose {
                    eval_id: self.next_id,
                    config_key: cfg.key(),
                    lie: planted_lie,
                });
            }
            let search_s = t_search.elapsed().as_secs_f64();
            self.inflight.insert(self.next_id, cfg.clone());
            anyhow::ensure!(
                self.pool.submit(EvalJob {
                    eval_id: self.next_id,
                    attempt: 0,
                    bounces: 0,
                    crashes: 0,
                    excluded: Vec::new(),
                    cfg,
                    search_s,
                }),
                "ensemble worker pool rejected a job"
            );
            if let Some(obs) = &self.obs {
                obs.record(crate::obs::ObsEvent::Proposed {
                    eval_id: self.next_id as u64,
                    shard: self.lens.shard,
                    search_us: crate::obs::secs_to_us(search_s),
                });
                obs.record(crate::obs::ObsEvent::Dispatched {
                    eval_id: self.next_id as u64,
                    shard: self.lens.shard,
                });
            }
            self.next_id += self.lens.stride();
        }
        Ok(())
    }

    /// Apply exactly one in-order completion: amend the pending lie by
    /// index, record, advance the simulated schedule, checkpoint.
    fn apply_next(&mut self) -> Result<()> {
        let res = self.arrived.remove(&self.next_apply).expect("caller checked arrival");
        let (job, done): (&EvalJob, Option<&EvalDone>) = match &res {
            Resolved::Done(j, d) => (j, Some(&**d)),
            Resolved::Failed(j) => (j, None),
        };
        // running-quantile straggler cutoff over all completed runtimes
        let cancel_cutoff = match (self.setup.straggler_factor, done) {
            (Some(factor), Some(d))
                if !d.timed_out && self.runtime_dist.len() >= STRAGGLER_MIN_SAMPLES =>
            {
                let cutoff =
                    self.runtime_dist.median().unwrap_or(f64::INFINITY) * factor.max(1.0);
                (d.charged_runtime_s > cutoff).then_some(cutoff)
            }
            _ => None,
        };
        let cancelled = cancel_cutoff.is_some();
        // every shard manager pays environment setup on its own first
        // evaluation (global id == shard index)
        let first_extra = if job.eval_id == self.lens.shard as usize {
            overhead::first_eval_setup_s(self.setup.app, self.setup.platform, self.setup.nodes)
        } else {
            0.0
        };
        let s = settle_result(
            &self.setup,
            self.baseline_objective,
            job,
            done,
            cancel_cutoff,
            job.search_s + self.completion_s,
            first_extra,
        );
        if done.is_none() {
            self.stats.failed_evals += 1;
        }
        if let Some(d) = done {
            if d.timed_out {
                self.stats.timeouts += 1;
            }
            if !d.timed_out && !cancelled {
                self.runtime_dist.push(d.charged_runtime_s);
            }
        }
        if cancelled {
            self.stats.stragglers_cancelled += 1;
        }

        // continuous controller: score the observation against the
        // surrogate's *stale* forecast (the model as it stood before this
        // result) and accumulate the standardized residual in the CUSUM.
        // Quarantined measurements never reach the detector — the
        // quarantine gate owns garbage; the CUSUM owns sustained shift.
        let mut drift_fired = false;
        let quarantined = self.ctl.is_some()
            && crate::drift::quarantine(s.objective, self.baseline_objective);
        if let Some(c) = &mut self.ctl {
            if !quarantined {
                if let Some(bo) = self.strat.as_bo_mut() {
                    if let (Some(pred), Some(scale)) =
                        (bo.predict_mean_stale(&job.cfg), bo.stale_scale())
                    {
                        if scale > 0.0 {
                            drift_fired = c.cusum.observe((s.objective - pred) / scale);
                        }
                    }
                }
            }
        }

        // (a) amend this result's pending lie by index. A quarantined
        // measurement is recorded in the history database below but
        // never trusted as model evidence: the surrogate sees a neutral
        // baseline-valued stand-in in its place (the replay path
        // recomputes the same decision from the checkpointed record).
        if self.log_valid {
            self.slog.push(checkpoint::StrategyEvent::Apply { eval_id: job.eval_id });
        }
        let surrogate_y = if quarantined { self.baseline_objective } else { s.objective };
        let amended = match self.strat.as_bo_mut() {
            Some(bo) => bo.resolve_pending(job.eval_id, surrogate_y),
            None => false,
        };
        if !amended {
            self.strat.observe(&job.cfg, surrogate_y);
        }
        if !quarantined && !s.timed_out && s.objective.is_finite() {
            self.real_objectives.push(s.objective);
            if s.objective < self.best {
                self.best = s.objective;
                self.best_desc = self.space.describe(&job.cfg);
            }
        }
        if drift_fired {
            // the world moved: discard the stale window so the next fit
            // sees only post-drift observations, log the fire so a
            // resumed run resets at the same point, and surface it
            if let Some(bo) = self.strat.as_bo_mut() {
                bo.reset_window();
            }
            if self.log_valid {
                self.slog.push(checkpoint::StrategyEvent::Drift { eval_id: job.eval_id });
            }
            log::info!(
                "shard {}: drift detected at eval {} — surrogate window reset",
                self.lens.shard,
                job.eval_id
            );
            if let Some(obs) = &self.obs {
                obs.record(crate::obs::ObsEvent::DriftDetected {
                    eval_id: job.eval_id as u64,
                    shard: self.lens.shard,
                });
            }
        }

        // advance the simulated schedule: the freed worker takes the
        // span, no barrier in sight
        let span = s.processing_s + s.charged;
        self.stats.serial_equivalent_s += span;
        let w = (0..self.workers)
            .min_by(|&a, &b| self.worker_free[a].total_cmp(&self.worker_free[b]))
            .unwrap();
        self.worker_free[w] += span;
        let completion = self.worker_free[w];
        self.wallclock = self.wallclock.max(completion);

        self.db.push(EvalRecord {
            id: job.eval_id,
            config_key: job.cfg.key(),
            config_desc: self.space.describe(&job.cfg),
            command: done.map(|d| d.command.clone()).unwrap_or_default(),
            measured: s.measured,
            objective: s.objective,
            compile_s: s.compile_s,
            processing_s: s.processing_s,
            overhead_s: s.processing_s - s.compile_s,
            wallclock_s: completion,
            best_so_far: if self.best.is_finite() { self.best } else { s.objective },
            timed_out: s.timed_out,
            cancelled,
        });

        self.inflight.remove(&self.next_apply);
        self.next_apply += self.lens.stride();
        self.stats.batches += 1;

        if let Some(obs) = &self.obs {
            obs.record(crate::obs::ObsEvent::Completed {
                eval_id: job.eval_id as u64,
                shard: self.lens.shard,
                objective: s.objective,
                best_so_far: if self.best.is_finite() { self.best } else { s.objective },
                sim_wallclock_s: completion,
            });
            if cancelled {
                obs.record(crate::obs::ObsEvent::StragglerKilled {
                    eval_id: job.eval_id as u64,
                    shard: self.lens.shard,
                });
            }
            obs.set_shard_gauges(crate::obs::ShardGauges {
                shard: self.lens.shard,
                workers: self.workers as u64,
                in_flight: self.inflight.len() as u64,
                applied: self.db.len() as u64,
                best_objective: self.best,
                sim_wallclock_s: self.wallclock,
                busy_s: self.stats.serial_equivalent_s,
            });
        }

        if let Some(alloc) = &mut self.allocation {
            let advance = self.wallclock - self.charged_wallclock;
            if advance > 0.0 {
                if alloc.charge(self.setup.nodes, advance).is_err() {
                    // allocation exhausted: stop proposing, drain what is
                    // already in flight
                    self.alloc_stop = true;
                }
                self.charged_wallclock = self.wallclock;
            }
        }
        // the checkpoint records the applied prefix, the still-in-flight
        // suffix, AND the proposal state (RNG stream position + strategy
        // event log) so a kill here resumes clean *and* keeps proposing
        // mid-trajectory exactly as the uninterrupted run would
        if let Some(path) = &self.checkpoint_path {
            let (rng_state, rng_inc) = self.rng.state();
            let proposal = self.log_valid.then(|| checkpoint::ProposalParts {
                rng_state,
                rng_inc,
                log: self.slog.as_slice(),
                cusum: self.ctl.as_ref().map(|c| c.cusum.state()),
            });
            save_checkpoint(
                path,
                &self.fingerprint,
                self.wallclock,
                &self.db,
                &self.inflight,
                proposal,
                self.setup.chaos.as_deref(),
            )?;
        }
        Ok(())
    }

    /// Run the continuous cycle for up to `max_apply` more completions
    /// (or until this shard's budget is exhausted and its in-flight work
    /// drained). Returns how many completions were applied.
    pub(crate) fn run_for(&mut self, max_apply: usize) -> Result<usize> {
        if self.is_finished() {
            return Ok(0);
        }
        let mut applied = 0usize;
        while applied < max_apply {
            // simulated SIGKILL (crash-recovery tests): stop right after
            // the checkpoint for the latest apply was written — before
            // proposing anything further — leaving the dispatched-but-
            // unfinished work exactly as a real kill would
            if self.setup.kill_after_evals.is_some_and(|n| self.db.len() >= n) {
                self.killed = true;
                log::info!(
                    "shard {}: simulated kill after {} applied completions",
                    self.lens.shard,
                    self.db.len()
                );
                break;
            }
            self.top_up()?;
            if self.inflight.is_empty() {
                self.done = true;
                break;
            }
            // wait for the next *in-order* completion; later results
            // buffer in `arrived` until their predecessors land
            while !self.arrived.contains_key(&self.next_apply) {
                let out = self
                    .pool
                    .recv_timeout(Duration::from_secs(120))
                    .context("ensemble worker stalled (no result within 120 s)")?;
                if let Some(r) = handle_outcome(
                    &self.pool,
                    out,
                    self.workers,
                    self.setup.max_retries,
                    &mut self.stats,
                )? {
                    self.arrived.insert(r.eval_id(), r);
                }
            }
            self.apply_next()?;
            applied += 1;
        }
        Ok(applied)
    }

    /// Run until this shard has applied `target` completions *in total*
    /// (resumed history included). The federation's exchange schedule is
    /// expressed in absolute per-shard completion counts, so a resumed
    /// shard re-joins exactly the boundaries an uninterrupted run hits —
    /// a relative "run N more" would shift every boundary by the resume
    /// point and desynchronize the elite exchange.
    fn run_until(&mut self, target: usize) -> Result<usize> {
        self.run_for(target.saturating_sub(self.db.len()))
    }

    /// This shard's top-`n` finite history entries among its first
    /// `upto` completions (ascending objective, ties by eval id), for
    /// the elite exchange. The prefix — not the whole history — is what
    /// keeps a resumed campaign's exchanges bit-identical: a shard that
    /// restored *beyond* a boundary must broadcast what it knew *at*
    /// that boundary, exactly as the uninterrupted run did.
    fn elites_at(&self, n: usize, upto: usize) -> Vec<(Configuration, f64)> {
        let upto = upto.min(self.db.records.len());
        let mut fin: Vec<&EvalRecord> = self.db.records[..upto]
            .iter()
            .filter(|r| !r.timed_out && r.objective.is_finite())
            .collect();
        fin.sort_by(|a, b| a.objective.total_cmp(&b.objective).then(a.id.cmp(&b.id)));
        fin.into_iter()
            .take(n)
            .filter_map(|r| {
                checkpoint::config_from_key(&r.config_key).ok().map(|c| (c, r.objective))
            })
            .collect()
    }

    /// Absorb another shard's elites: each new `(configuration,
    /// objective)` pair enters the surrogate as a real foreign
    /// observation (marked seen — never re-proposed), deduped across
    /// rounds by configuration key. Own-partition entries are skipped:
    /// this shard owns (or will own) their measurements already.
    fn absorb_foreign(&mut self, elites: &[(Configuration, f64)]) -> usize {
        let mut absorbed = 0usize;
        for (cfg, y) in elites {
            let key = cfg.key();
            if self.received_foreign.contains(&key) || self.lens.contains(&self.space, cfg) {
                continue;
            }
            self.received_foreign.insert(key.clone());
            self.strat.observe_foreign(cfg, *y);
            if y.is_finite() {
                self.real_objectives.push(*y);
            }
            if self.log_valid {
                self.slog.push(checkpoint::StrategyEvent::Foreign { config_key: key, y: *y });
            }
            absorbed += 1;
        }
        absorbed
    }

    /// Record one elite-exchange round on the observability sink
    /// (write-only; the exchange itself is unaffected).
    fn record_exchange(&self, round: u64, absorbed: u64) {
        if let Some(obs) = &self.obs {
            obs.record(crate::obs::ObsEvent::EliteExchange {
                round,
                shard: self.lens.shard,
                absorbed,
            });
        }
    }

    /// Charge one exchange round's synchronization cost to this shard's
    /// simulated clock (workers cannot pick up new spans before it).
    fn charge_exchange(&mut self, s: f64) {
        if s <= 0.0 || self.is_finished() {
            return;
        }
        self.wallclock += s;
        for w in &mut self.worker_free {
            *w = w.max(self.wallclock);
        }
    }

    /// Shut the pool down and hand back this shard's history.
    pub(crate) fn finish(mut self) -> ShardRun {
        self.pool.shutdown();
        ShardRun {
            db: self.db,
            stats: self.stats,
            wallclock: self.wallclock,
            best: self.best,
            best_desc: self.best_desc,
        }
    }
}

/// Validate a federation policy; returns the shard count K.
pub(crate) fn validate_federation(setup: &TuneSetup) -> Result<usize> {
    let k = setup.federation_shards;
    anyhow::ensure!(
        (1..=MAX_SHARDS).contains(&k),
        "federation needs 1..={MAX_SHARDS} shards (got {k})"
    );
    anyhow::ensure!(
        setup.ensemble_workers >= 1,
        "federation needs >= 1 ensemble worker per shard (got {})",
        setup.ensemble_workers
    );
    anyhow::ensure!(
        setup.manager_cycle == ManagerCycle::Continuous,
        "federation shards run the continuous manager cycle (got `{}`)",
        setup.manager_cycle.name()
    );
    // range checks live here — not only in the CLI — so config-file and
    // library callers get the same acceptance rules, and no silently
    // clamped value can diverge from what the fingerprint recorded
    anyhow::ensure!(
        setup.elite_exchange_every >= 1,
        "elite-exchange-every must be >= 1 (got {})",
        setup.elite_exchange_every
    );
    anyhow::ensure!(
        setup.federation_elites <= 64,
        "federation-elites must be <= 64 (got {})",
        setup.federation_elites
    );
    Ok(k)
}

/// The unsharded continuous manager: one [`ContinuousShard`] with
/// `shards = 1`, run to completion. `ensemble::autotune_ensemble`
/// delegates its continuous branch here. The stepped engine itself lives
/// in [`crate::service::engine::drive_continuous`] — the CLI one-shot
/// path (this function) and the tuning daemon are two front-ends over
/// that one engine, which is what pins a daemon campaign's trajectory to
/// the solo run's: both step the identical state machine.
pub(crate) fn autotune_continuous(setup: &TuneSetup, scorer: Arc<Scorer>) -> Result<TuneResult> {
    use crate::service::engine::{drive_continuous, CampaignOutcome};
    let never = std::sync::atomic::AtomicBool::new(false);
    match drive_continuous(setup, scorer, &never, &mut |_| {})? {
        CampaignOutcome::Finished(result) => Ok(*result),
        // unreachable: the cancel flag above is never raised
        CampaignOutcome::Interrupted { .. } => {
            anyhow::bail!("continuous manager interrupted without a cancel request")
        }
        // the classic blocking dispatch has no degraded mode: exhausting
        // an I/O retry budget is a hard error for the solo CLI path
        CampaignOutcome::Degraded { applied, message } => {
            anyhow::bail!("campaign degraded after {applied} applied completions: {message}")
        }
    }
}

/// Run a federated campaign: K continuous manager shards over a
/// seeded-hash partition of the candidate space, with periodic elite
/// exchange and a final eval-id-ordered merge into one [`TuneResult`].
pub fn autotune_federation(setup: &TuneSetup, scorer: Arc<Scorer>) -> Result<TuneResult> {
    let k = validate_federation(setup)?;
    // resolve the history-database warm start (idempotent; every shard
    // then absorbs the same resolved prior — once — at strategy
    // construction, deduped against later elite exchanges through each
    // shard's `received_foreign` set)
    let mut setup = setup.clone();
    crate::history::apply_warm_start(&mut setup, scorer.as_ref())?;
    let setup = &setup;
    let space = Arc::new(paper::build_space(setup.app, setup.platform));
    let (baseline, baseline_objective) = coordinator::measure_baseline(setup, &scorer)?;
    let fp = checkpoint::fingerprint(setup);

    // manifest: pin the policy before touching any shard file
    if let Some(path) = &setup.checkpoint_path {
        match FederationManifest::load(path)? {
            Some(m) => {
                anyhow::ensure!(
                    m.fingerprint == fp,
                    "federation manifest {} belongs to a different run: `{}` != `{fp}`",
                    path.display(),
                    m.fingerprint
                );
                anyhow::ensure!(
                    m.shards == k,
                    "federation manifest {} was written by a {}-shard run (resuming with {k})",
                    path.display(),
                    m.shards
                );
            }
            None => FederationManifest { fingerprint: fp.clone(), shards: k }.save(path)?,
        }
    }

    let mut shards: Vec<ContinuousShard> = (0..k)
        .map(|s| {
            ContinuousShard::new(
                setup,
                ShardSpec { seed: setup.seed, shards: k as u32, shard: s as u32 },
                space.clone(),
                scorer.clone(),
                baseline_objective,
                shard_fingerprint(setup, s),
                setup.checkpoint_path.as_ref().map(|p| shard_checkpoint_path(p, s)),
            )
        })
        .collect::<Result<_>>()?;

    let every = setup.elite_exchange_every; // validated >= 1 above
    let elite_n = setup.federation_elites;
    let exch_s = overhead::federation_exchange_s(k, elite_n);
    let mut fstats = FederationStats {
        shards: k,
        exchange_every: every,
        elite_n,
        exchanges: 0,
        elites_absorbed: 0,
        exchange_s: 0.0,
        per_shard_evals: Vec::new(),
    };

    // round loop: every shard advances to the next *absolute* exchange
    // boundary (boundaries are counted in per-shard completions — never
    // in host time — so the schedule is deterministic, and a resumed
    // shard re-joins exactly the boundaries an uninterrupted run hits),
    // then elites broadcast all-to-all from each shard's history prefix
    // at that boundary.
    let mut round = 0usize;
    loop {
        round += 1;
        let boundary = round.saturating_mul(every);
        for sh in shards.iter_mut() {
            sh.run_until(boundary)?;
        }
        if shards.iter().all(ContinuousShard::is_finished) {
            break;
        }
        if k > 1 {
            // finished shards propose nothing more; a shard resumed
            // *past* this boundary absorbed and paid for this exchange
            // in its previous life (its checkpoint log replays those
            // absorptions). A live shard sits exactly at the boundary.
            let at_boundary =
                |sh: &ContinuousShard| !sh.is_finished() && sh.applied() <= boundary;
            if shards.iter().any(|s| at_boundary(s)) {
                let all_elites: Vec<Vec<(Configuration, f64)>> =
                    shards.iter().map(|s| s.elites_at(elite_n, boundary)).collect();
                for (i, sh) in shards.iter_mut().enumerate() {
                    if !at_boundary(sh) {
                        continue;
                    }
                    let mut absorbed = 0usize;
                    for (j, es) in all_elites.iter().enumerate() {
                        if i != j {
                            absorbed += sh.absorb_foreign(es);
                        }
                    }
                    fstats.elites_absorbed += absorbed;
                    sh.charge_exchange(exch_s);
                    sh.record_exchange(round as u64, absorbed as u64);
                }
                fstats.exchanges += 1;
                fstats.exchange_s += exch_s;
            }
        }
    }

    // ---- merge: concatenate shard histories, sort by global eval id ----
    let runs: Vec<ShardRun> = shards.into_iter().map(ContinuousShard::finish).collect();
    let mut agg = EnsembleStats::new(0, 0, setup.liar, ManagerCycle::Continuous);
    let mut records: Vec<EvalRecord> = Vec::new();
    let mut wallclock = 0.0f64;
    for run in runs {
        fstats.per_shard_evals.push(run.db.len());
        agg.workers += run.stats.workers;
        agg.batch += run.stats.batch;
        agg.batches += run.stats.batches;
        agg.faults += run.stats.faults;
        agg.retries += run.stats.retries;
        agg.worker_crashes += run.stats.worker_crashes;
        agg.failed_evals += run.stats.failed_evals;
        agg.timeouts += run.stats.timeouts;
        agg.stragglers_cancelled += run.stats.stragglers_cancelled;
        agg.resumed_evals += run.stats.resumed_evals;
        agg.serial_equivalent_s += run.stats.serial_equivalent_s;
        agg.worker_idle_s += run.stats.worker_idle_s;
        wallclock = wallclock.max(run.wallclock);
        records.extend(run.db.records);
    }
    records.sort_by_key(|r| r.id);
    // recompute the best-so-far chain over the merged order with exactly
    // the per-shard rule, so a K=1 merge reproduces the shard's own
    // values bit for bit
    let mut best = f64::INFINITY;
    let mut best_desc = String::new();
    for r in &mut records {
        if !r.timed_out && r.objective.is_finite() && r.objective < best {
            best = r.objective;
            best_desc = r.config_desc.clone();
        }
        r.best_so_far = if best.is_finite() { best } else { r.objective };
    }
    let mut db = PerfDatabase::new();
    for r in records {
        db.push(r);
    }

    let param_importance = coordinator::importance_from_db(&space, &db, setup.seed);
    Ok(TuneResult {
        setup: setup.clone(),
        space_size: space.size(),
        baseline,
        baseline_objective,
        best_objective: best,
        best_config_desc: best_desc,
        improvement_pct: improvement_pct(baseline_objective, best),
        wallclock_s: wallclock,
        evaluations: db.len(),
        scorer_accelerated: scorer.is_accelerated(),
        param_importance,
        db,
        ensemble: Some(agg),
        federation: Some(fstats),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::AppKind;
    use crate::metrics::Metric;
    use crate::platform::PlatformKind;
    use crate::search::StrategyKind;

    fn setup(shards: usize) -> TuneSetup {
        let mut s =
            TuneSetup::new(AppKind::XSBenchHistory, PlatformKind::Theta, 1, Metric::Runtime);
        s.max_evals = 12;
        s.wallclock_budget_s = 1e9;
        s.n_init = 4;
        s.ensemble_workers = 2;
        s.federation_shards = shards;
        s.elite_exchange_every = 2;
        s.federation_elites = 2;
        s
    }

    fn run(s: &TuneSetup) -> TuneResult {
        autotune_federation(s, Arc::new(Scorer::fallback())).unwrap()
    }

    /// Exhaustive disjoint-cover check on a small space: every flat
    /// index lands in exactly one shard, the assignment is stable under
    /// re-sharding with the same seed, and a different seed re-deals.
    #[test]
    fn sharding_is_an_exhaustive_disjoint_cover_on_small_spaces() {
        use crate::space::{Param, ParamDomain};
        let mut sp = ConfigSpace::new("toy");
        sp.add(Param::new("a", ParamDomain::ordinal(&[0, 1, 2, 3])));
        sp.add(Param::new("b", ParamDomain::ordinal(&[0, 1, 2])));
        sp.add(Param::new("c", ParamDomain::Toggle));
        let size = sp.size();
        assert_eq!(size, 24);
        for k in 1..=8u32 {
            let assign: Vec<u32> =
                (0..size).map(|i| shard_of_index(99, i, k)).collect();
            let again: Vec<u32> =
                (0..size).map(|i| shard_of_index(99, i, k)).collect();
            assert_eq!(assign, again, "k={k}: re-sharding must be byte-identical");
            let mut counts = vec![0usize; k as usize];
            for (i, &s) in assign.iter().enumerate() {
                assert!(s < k, "k={k} index {i}: shard {s} out of range");
                counts[s as usize] += 1;
                // exactly one ShardSpec claims each configuration
                let cfg = sp.config_at(i as u128);
                let claims = (0..k)
                    .filter(|&sh| {
                        ShardSpec { seed: 99, shards: k, shard: sh }.contains(&sp, &cfg)
                    })
                    .count();
                assert_eq!(claims, 1, "k={k} index {i}");
            }
            assert_eq!(counts.iter().sum::<usize>(), size as usize, "cover, k={k}");
        }
        // a different seed deals a different partition (k >= 2)
        let a: Vec<u32> = (0..size).map(|i| shard_of_index(1, i, 4)).collect();
        let b: Vec<u32> = (0..size).map(|i| shard_of_index(2, i, 4)).collect();
        assert_ne!(a, b, "different seeds must re-deal the partition");
    }

    #[test]
    fn round_robin_ids_cover_the_budget_exactly() {
        let r = run(&setup(3));
        assert_eq!(r.evaluations, 12);
        for (i, rec) in r.db.records.iter().enumerate() {
            assert_eq!(rec.id, i, "merged ids must be a contiguous 0..max_evals");
        }
        let fs = r.federation.as_ref().expect("federation stats present");
        assert_eq!(fs.shards, 3);
        assert_eq!(fs.per_shard_evals, vec![4, 4, 4]);
        // every evaluated configuration sits in its owner's partition
        for rec in &r.db.records {
            let cfg = checkpoint::config_from_key(&rec.config_key).unwrap();
            let space = paper::build_space(r.setup.app, r.setup.platform);
            let owner = shard_of_index(r.setup.seed, space.index_of(&cfg), 3);
            assert_eq!(owner as usize, rec.id % 3, "id {} strayed out of its shard", rec.id);
        }
    }

    #[test]
    fn federation_rejects_bad_policies() {
        let mut s = setup(0);
        assert!(autotune_federation(&s, Arc::new(Scorer::fallback())).is_err());
        s.federation_shards = MAX_SHARDS + 1;
        assert!(autotune_federation(&s, Arc::new(Scorer::fallback())).is_err());
        s.federation_shards = 2;
        s.ensemble_workers = 0;
        assert!(autotune_federation(&s, Arc::new(Scorer::fallback())).is_err());
        s.ensemble_workers = 2;
        s.manager_cycle = ManagerCycle::Generational;
        assert!(autotune_federation(&s, Arc::new(Scorer::fallback())).is_err());
        // range checks apply to config-file/library callers, not just CLI
        s.manager_cycle = ManagerCycle::Continuous;
        s.elite_exchange_every = 0;
        assert!(autotune_federation(&s, Arc::new(Scorer::fallback())).is_err());
        s.elite_exchange_every = 2;
        s.federation_elites = 65;
        assert!(autotune_federation(&s, Arc::new(Scorer::fallback())).is_err());
    }

    #[test]
    fn manifest_roundtrips_and_rejects_foreign_files() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("ytopt-fed-manifest-{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        assert!(FederationManifest::load(&path).unwrap().is_none());
        let m = FederationManifest { fingerprint: "fp".into(), shards: 4 };
        m.save(&path).unwrap();
        assert_eq!(FederationManifest::load(&path).unwrap().unwrap(), m);
        // a plain shard checkpoint is not a manifest
        // detlint: allow(io-atomic) -- planted imposter file, not a real install
        std::fs::write(&path, "{\"fingerprint\":\"fp\",\"records\":[]}").unwrap();
        assert!(FederationManifest::load(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn shard_checkpoint_paths_and_fingerprints_are_distinct() {
        let base = PathBuf::from("/tmp/campaign.json");
        assert_eq!(
            shard_checkpoint_path(&base, 3),
            PathBuf::from("/tmp/campaign.json.shard3.json")
        );
        // bases with a non-json suffix keep their distinguishing name
        assert_ne!(
            shard_checkpoint_path(&PathBuf::from("/tmp/run.v2"), 0),
            shard_checkpoint_path(&PathBuf::from("/tmp/run.v3"), 0)
        );
        let s = setup(2);
        assert_ne!(shard_fingerprint(&s, 0), shard_fingerprint(&s, 1));
        assert!(shard_fingerprint(&s, 0).starts_with(&checkpoint::fingerprint(&s)));
    }

    #[test]
    fn exchange_absorbs_foreign_elites() {
        let mut s = setup(2);
        s.max_evals = 16;
        let r = run(&s);
        let fs = r.federation.as_ref().unwrap();
        assert!(fs.exchanges > 0, "a 16-eval K=2 run must hit exchange boundaries");
        assert!(fs.elites_absorbed > 0, "exchanges must move elites across shards");
        assert!(fs.exchange_s > 0.0);
        assert_eq!(r.evaluations, 16);
        // same tolerance the serial XSBench test allows at this budget
        assert!(
            r.best_objective < r.baseline_objective * 1.05,
            "federated run went backwards: best {} vs baseline {}",
            r.best_objective,
            r.baseline_objective
        );
    }

    #[test]
    fn non_bo_strategies_run_federated() {
        for kind in [StrategyKind::Random, StrategyKind::Mctree] {
            let mut s = setup(2);
            s.strategy = kind;
            s.max_evals = 8;
            let r = run(&s);
            assert_eq!(r.evaluations, 8, "{kind:?}");
        }
    }
}
