//! A bounded-queue manager/worker thread pool (the libEnsemble-style
//! evaluation engine's substrate).
//!
//! Design constraints, in order:
//!   * **std-only** — the offline crate set has no crossbeam/rayon, so
//!     the queue is a `Mutex<VecDeque>` + three condvars (job ready,
//!     slot free, result ready).
//!   * **bounded** — `submit` blocks while the queue holds `capacity`
//!     jobs, so a fast manager cannot run unbounded ahead of slow
//!     workers (libEnsemble's alloc_f gives the same back-pressure).
//!   * **graceful shutdown** — `shutdown` (and `Drop`) stops intake,
//!     lets workers drain the queue, then joins every thread. No job
//!     that was accepted is abandoned mid-run.
//!
//! The pool is generic over job and result types; the ensemble manager
//! instantiates it with the five-step evaluation closure.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

struct State<J, R> {
    jobs: VecDeque<J>,
    results: VecDeque<R>,
    shutdown: bool,
    /// Workers currently executing a job (not counting queued jobs).
    busy: usize,
}

struct Shared<J, R> {
    state: Mutex<State<J, R>>,
    job_ready: Condvar,
    slot_free: Condvar,
    result_ready: Condvar,
    capacity: usize,
}

/// A fixed-size pool of `std::thread` workers running one closure.
pub struct WorkerPool<J: Send + 'static, R: Send + 'static> {
    shared: Arc<Shared<J, R>>,
    handles: Vec<JoinHandle<()>>,
    n_workers: usize,
}

impl<J: Send + 'static, R: Send + 'static> WorkerPool<J, R> {
    /// Spawn `n_workers` threads running `f(worker_id, job) -> result`
    /// over a bounded queue of `capacity` waiting jobs.
    pub fn new<F>(n_workers: usize, capacity: usize, f: F) -> Self
    where
        F: Fn(usize, J) -> R + Send + Sync + 'static,
    {
        assert!(n_workers >= 1, "pool needs at least one worker");
        assert!(capacity >= 1, "queue capacity must be at least 1");
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                jobs: VecDeque::new(),
                results: VecDeque::new(),
                shutdown: false,
                busy: 0,
            }),
            job_ready: Condvar::new(),
            slot_free: Condvar::new(),
            result_ready: Condvar::new(),
            capacity,
        });
        let f = Arc::new(f);
        let handles = (0..n_workers)
            .map(|wid| {
                let shared = shared.clone();
                let f = f.clone();
                std::thread::Builder::new()
                    .name(format!("ensemble-worker-{wid}"))
                    .spawn(move || worker_loop(wid, &shared, &*f))
                    .expect("failed to spawn ensemble worker thread")
            })
            .collect();
        WorkerPool { shared, handles, n_workers }
    }

    pub fn workers(&self) -> usize {
        self.n_workers
    }

    /// Enqueue a job, blocking while the bounded queue is full. Returns
    /// false (job dropped) if the pool has been shut down.
    pub fn submit(&self, job: J) -> bool {
        let mut st = self.shared.state.lock().unwrap();
        while st.jobs.len() >= self.shared.capacity && !st.shutdown {
            st = self.shared.slot_free.wait(st).unwrap();
        }
        if st.shutdown {
            return false;
        }
        st.jobs.push_back(job);
        drop(st);
        self.shared.job_ready.notify_one();
        true
    }

    /// Next completed result, blocking up to `timeout`. `None` on timeout.
    pub fn recv_timeout(&self, timeout: Duration) -> Option<R> {
        // real-time blocking wait only: arrival order never reaches the
        // trajectory (the manager re-sorts results by eval id)
        let deadline = Instant::now() + timeout; // detlint: allow(wall-clock) -- condvar deadline, not trajectory state
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if let Some(r) = st.results.pop_front() {
                return Some(r);
            }
            let now = Instant::now(); // detlint: allow(wall-clock) -- condvar deadline, not trajectory state
            if now >= deadline {
                return None;
            }
            let (guard, _timed_out) =
                self.shared.result_ready.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }

    /// Jobs accepted but whose results have not been received yet.
    pub fn outstanding(&self) -> usize {
        let st = self.shared.state.lock().unwrap();
        st.jobs.len() + st.busy + st.results.len()
    }

    /// Graceful shutdown: stop intake, let workers drain the queue, join
    /// every thread. Idempotent; also invoked by `Drop`.
    pub fn shutdown(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.job_ready.notify_all();
        self.shared.slot_free.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl<J: Send + 'static, R: Send + 'static> Drop for WorkerPool<J, R> {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop<J, R>(wid: usize, shared: &Shared<J, R>, f: &(dyn Fn(usize, J) -> R)) {
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if let Some(j) = st.jobs.pop_front() {
                    st.busy += 1;
                    break j;
                }
                if st.shutdown {
                    return;
                }
                st = shared.job_ready.wait(st).unwrap();
            }
        };
        shared.slot_free.notify_one();
        let r = f(wid, job);
        {
            let mut st = shared.state.lock().unwrap();
            st.busy -= 1;
            st.results.push_back(r);
        }
        shared.result_ready.notify_one();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const TICK: Duration = Duration::from_secs(10);

    #[test]
    fn results_collected_independent_of_completion_order() {
        let pool: WorkerPool<u64, u64> = WorkerPool::new(4, 8, |_wid, j| {
            // stagger completion so arrival order scrambles
            if j % 3 == 0 {
                std::thread::sleep(Duration::from_millis(2));
            }
            j * j
        });
        for j in 0..50u64 {
            assert!(pool.submit(j));
        }
        let mut got: Vec<u64> = (0..50).map(|_| pool.recv_timeout(TICK).expect("result")).collect();
        got.sort_unstable();
        let want: Vec<u64> = (0..50u64).map(|j| j * j).collect();
        assert_eq!(got, want);
        assert_eq!(pool.outstanding(), 0);
    }

    #[test]
    fn bounded_queue_applies_backpressure_without_loss() {
        // capacity 1 with slow workers: submits must block, not drop
        let pool: WorkerPool<u64, u64> = WorkerPool::new(2, 1, |_wid, j| {
            std::thread::sleep(Duration::from_millis(1));
            j + 100
        });
        for j in 0..20u64 {
            assert!(pool.submit(j));
        }
        let mut got: Vec<u64> = (0..20).map(|_| pool.recv_timeout(TICK).expect("result")).collect();
        got.sort_unstable();
        assert_eq!(got, (100..120u64).collect::<Vec<_>>());
    }

    #[test]
    fn shutdown_drains_queued_jobs_and_joins() {
        let counter = Arc::new(Mutex::new(0usize));
        let c = counter.clone();
        let mut pool: WorkerPool<usize, usize> = WorkerPool::new(2, 16, move |_wid, j| {
            *c.lock().unwrap() += 1;
            j
        });
        for j in 0..10 {
            assert!(pool.submit(j));
        }
        pool.shutdown(); // must not hang; queued jobs drain first
        assert_eq!(*counter.lock().unwrap(), 10, "queued jobs were abandoned");
        assert!(!pool.submit(99), "submit after shutdown must be rejected");
        pool.shutdown(); // idempotent
    }

    #[test]
    fn drop_joins_without_deadlock() {
        let pool: WorkerPool<u8, u8> = WorkerPool::new(3, 4, |_wid, j| j);
        for j in 0..4 {
            pool.submit(j);
        }
        drop(pool); // Drop path must terminate
    }

    #[test]
    fn recv_timeout_expires_when_idle() {
        let pool: WorkerPool<u8, u8> = WorkerPool::new(1, 1, |_wid, j| j);
        let t0 = Instant::now(); // detlint: allow(wall-clock) -- test measures the real timeout itself
        assert!(pool.recv_timeout(Duration::from_millis(20)).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(15));
    }
}
