//! A bounded-queue manager/worker thread pool (the libEnsemble-style
//! evaluation engine's substrate).
//!
//! Design constraints, in order:
//!   * **std-only** — the offline crate set has no crossbeam/rayon, so
//!     the queue is a `Mutex<VecDeque>` + three condvars (job ready,
//!     slot free, result ready).
//!   * **bounded** — `submit` blocks while the queue holds `capacity`
//!     jobs, so a fast manager cannot run unbounded ahead of slow
//!     workers (libEnsemble's alloc_f gives the same back-pressure).
//!   * **graceful shutdown** — `shutdown` (and `Drop`) stops intake,
//!     lets workers drain the queue, then joins every thread. No job
//!     that was accepted is abandoned mid-run.
//!
//! The pool is generic over job and result types; the ensemble manager
//! instantiates it with the five-step evaluation closure.
//!
//! **Self-healing** (chaos-harness requirement): a pool built with
//! [`WorkerPool::new_supervised`] survives a *hard worker crash* — a
//! panic inside the job closure, not just a failed evaluation. The
//! dying worker converts its in-flight job into a crash result (so the
//! manager's receive loop learns of the loss immediately and can
//! re-queue the evaluation through the retry-with-exclusion path),
//! flags its own worker id for respawn, and exits; the pool respawns a
//! replacement thread under the same worker id on the next
//! `submit`/`recv_timeout`. Plain [`WorkerPool::new`] pools keep the
//! original fail-fast behaviour.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

struct State<J, R> {
    jobs: VecDeque<J>,
    results: VecDeque<R>,
    shutdown: bool,
    /// Workers currently executing a job (not counting queued jobs).
    busy: usize,
    /// Worker ids whose threads died to a crash, awaiting respawn.
    dead: Vec<usize>,
    /// Total hard crashes survived so far.
    crashes: usize,
}

struct Shared<J, R> {
    state: Mutex<State<J, R>>,
    job_ready: Condvar,
    slot_free: Condvar,
    result_ready: Condvar,
    capacity: usize,
}

/// Respawn material for a supervised pool: the job closure and the
/// crash adapter, kept so replacement threads run the same work.
struct Supervisor<J, R> {
    f: Arc<dyn Fn(usize, J) -> R + Send + Sync>,
    on_crash: Arc<dyn Fn(usize, J) -> R + Send + Sync>,
}

/// A fixed-size pool of `std::thread` workers running one closure.
pub struct WorkerPool<J: Send + 'static, R: Send + 'static> {
    shared: Arc<Shared<J, R>>,
    handles: Mutex<Vec<JoinHandle<()>>>,
    n_workers: usize,
    supervisor: Option<Supervisor<J, R>>,
}

impl<J: Send + 'static, R: Send + 'static> WorkerPool<J, R> {
    fn new_shared(capacity: usize) -> Arc<Shared<J, R>> {
        Arc::new(Shared {
            state: Mutex::new(State {
                jobs: VecDeque::new(),
                results: VecDeque::new(),
                shutdown: false,
                busy: 0,
                dead: Vec::new(),
                crashes: 0,
            }),
            job_ready: Condvar::new(),
            slot_free: Condvar::new(),
            result_ready: Condvar::new(),
            capacity,
        })
    }

    /// Spawn `n_workers` threads running `f(worker_id, job) -> result`
    /// over a bounded queue of `capacity` waiting jobs.
    pub fn new<F>(n_workers: usize, capacity: usize, f: F) -> Self
    where
        F: Fn(usize, J) -> R + Send + Sync + 'static,
    {
        assert!(n_workers >= 1, "pool needs at least one worker");
        assert!(capacity >= 1, "queue capacity must be at least 1");
        let shared = Self::new_shared(capacity);
        let f = Arc::new(f);
        let handles = (0..n_workers)
            .map(|wid| {
                let shared = shared.clone();
                let f = f.clone();
                std::thread::Builder::new()
                    .name(format!("ensemble-worker-{wid}"))
                    .spawn(move || worker_loop(wid, &shared, &*f))
                    .expect("failed to spawn ensemble worker thread")
            })
            .collect();
        WorkerPool { shared, handles: Mutex::new(handles), n_workers, supervisor: None }
    }

    /// Supervised variant: a panic inside `f` kills only its worker
    /// thread. The in-flight job (pre-cloned) is converted through
    /// `on_crash(worker_id, job)` into an ordinary result the manager's
    /// receive loop sees immediately, and the dead worker id is
    /// respawned on the next pool interaction.
    pub fn new_supervised<F, C>(n_workers: usize, capacity: usize, f: F, on_crash: C) -> Self
    where
        J: Clone,
        F: Fn(usize, J) -> R + Send + Sync + 'static,
        C: Fn(usize, J) -> R + Send + Sync + 'static,
    {
        assert!(n_workers >= 1, "pool needs at least one worker");
        assert!(capacity >= 1, "queue capacity must be at least 1");
        let shared = Self::new_shared(capacity);
        let sup = Supervisor {
            f: Arc::new(f) as Arc<dyn Fn(usize, J) -> R + Send + Sync>,
            on_crash: Arc::new(on_crash) as Arc<dyn Fn(usize, J) -> R + Send + Sync>,
        };
        let handles = (0..n_workers)
            .map(|wid| {
                let shared = shared.clone();
                let f = sup.f.clone();
                let oc = sup.on_crash.clone();
                std::thread::Builder::new()
                    .name(format!("ensemble-worker-{wid}"))
                    .spawn(move || supervised_loop(wid, &shared, &*f, &*oc))
                    .expect("failed to spawn ensemble worker thread")
            })
            .collect();
        WorkerPool { shared, handles: Mutex::new(handles), n_workers, supervisor: Some(sup) }
    }

    pub fn workers(&self) -> usize {
        self.n_workers
    }

    /// Total hard worker crashes survived so far.
    pub fn crashes(&self) -> usize {
        self.shared.state.lock().unwrap().crashes
    }

    /// Respawn any workers that died to a crash (supervised pools only;
    /// a no-op otherwise). Called from every pool interaction so a dead
    /// worker is replaced the moment the manager touches the pool again.
    fn respawn_dead(&self) {
        let dead: Vec<usize> = {
            let mut st = self.shared.state.lock().unwrap();
            if st.dead.is_empty() || st.shutdown {
                return;
            }
            std::mem::take(&mut st.dead)
        };
        let Some(sup) = &self.supervisor else { return };
        let mut handles = self.handles.lock().unwrap();
        for wid in dead {
            let shared = self.shared.clone();
            let f = sup.f.clone();
            let oc = sup.on_crash.clone();
            let h = std::thread::Builder::new()
                .name(format!("ensemble-worker-{wid}"))
                .spawn(move || supervised_loop(wid, &shared, &*f, &*oc))
                .expect("failed to respawn ensemble worker thread");
            handles.push(h);
            log::info!("respawned crashed ensemble-worker-{wid}");
        }
    }

    /// Enqueue a job, blocking while the bounded queue is full. Returns
    /// false (job dropped) if the pool has been shut down.
    pub fn submit(&self, job: J) -> bool {
        self.respawn_dead();
        let mut st = self.shared.state.lock().unwrap();
        while st.jobs.len() >= self.shared.capacity && !st.shutdown {
            st = self.shared.slot_free.wait(st).unwrap();
        }
        if st.shutdown {
            return false;
        }
        st.jobs.push_back(job);
        drop(st);
        self.shared.job_ready.notify_one();
        true
    }

    /// Next completed result, blocking up to `timeout`. `None` on timeout.
    pub fn recv_timeout(&self, timeout: Duration) -> Option<R> {
        self.respawn_dead();
        // real-time blocking wait only: arrival order never reaches the
        // trajectory (the manager re-sorts results by eval id)
        let deadline = Instant::now() + timeout; // detlint: allow(wall-clock) -- condvar deadline, not trajectory state
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if let Some(r) = st.results.pop_front() {
                return Some(r);
            }
            let now = Instant::now(); // detlint: allow(wall-clock) -- condvar deadline, not trajectory state
            if now >= deadline {
                return None;
            }
            let (guard, _timed_out) =
                self.shared.result_ready.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }

    /// Jobs accepted but whose results have not been received yet.
    pub fn outstanding(&self) -> usize {
        let st = self.shared.state.lock().unwrap();
        st.jobs.len() + st.busy + st.results.len()
    }

    /// Graceful shutdown: stop intake, let workers drain the queue, join
    /// every thread. Idempotent; also invoked by `Drop`.
    pub fn shutdown(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.job_ready.notify_all();
        self.shared.slot_free.notify_all();
        let handles: Vec<JoinHandle<()>> = {
            let mut guard = self.handles.lock().unwrap();
            guard.drain(..).collect()
        };
        for h in handles {
            let _ = h.join();
        }
    }
}

impl<J: Send + 'static, R: Send + 'static> Drop for WorkerPool<J, R> {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop<J, R>(wid: usize, shared: &Shared<J, R>, f: &(dyn Fn(usize, J) -> R)) {
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if let Some(j) = st.jobs.pop_front() {
                    st.busy += 1;
                    break j;
                }
                if st.shutdown {
                    return;
                }
                st = shared.job_ready.wait(st).unwrap();
            }
        };
        shared.slot_free.notify_one();
        let r = f(wid, job);
        {
            let mut st = shared.state.lock().unwrap();
            st.busy -= 1;
            st.results.push_back(r);
        }
        shared.result_ready.notify_one();
    }
}

/// Supervised worker loop: a panic inside `f` is caught *outside* any
/// lock (the state mutex is never poisoned by it), converted through
/// `on_crash` into a result the manager sees immediately, and the
/// thread exits after flagging its worker id for respawn — a hard
/// crash, survived.
fn supervised_loop<J: Clone, R>(
    wid: usize,
    shared: &Shared<J, R>,
    f: &(dyn Fn(usize, J) -> R),
    on_crash: &(dyn Fn(usize, J) -> R),
) {
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if let Some(j) = st.jobs.pop_front() {
                    st.busy += 1;
                    break j;
                }
                if st.shutdown {
                    return;
                }
                st = shared.job_ready.wait(st).unwrap();
            }
        };
        shared.slot_free.notify_one();
        let saved = job.clone();
        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(wid, job))) {
            Ok(r) => {
                let mut st = shared.state.lock().unwrap();
                st.busy -= 1;
                st.results.push_back(r);
                drop(st);
                shared.result_ready.notify_one();
            }
            Err(_) => {
                log::warn!("ensemble-worker-{wid} crashed; converting in-flight job and exiting");
                let mut st = shared.state.lock().unwrap();
                st.busy -= 1;
                st.crashes += 1;
                st.dead.push(wid);
                st.results.push_back(on_crash(wid, saved));
                drop(st);
                shared.result_ready.notify_one();
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const TICK: Duration = Duration::from_secs(10);

    #[test]
    fn results_collected_independent_of_completion_order() {
        let pool: WorkerPool<u64, u64> = WorkerPool::new(4, 8, |_wid, j| {
            // stagger completion so arrival order scrambles
            if j % 3 == 0 {
                std::thread::sleep(Duration::from_millis(2));
            }
            j * j
        });
        for j in 0..50u64 {
            assert!(pool.submit(j));
        }
        let mut got: Vec<u64> = (0..50).map(|_| pool.recv_timeout(TICK).expect("result")).collect();
        got.sort_unstable();
        let want: Vec<u64> = (0..50u64).map(|j| j * j).collect();
        assert_eq!(got, want);
        assert_eq!(pool.outstanding(), 0);
    }

    #[test]
    fn bounded_queue_applies_backpressure_without_loss() {
        // capacity 1 with slow workers: submits must block, not drop
        let pool: WorkerPool<u64, u64> = WorkerPool::new(2, 1, |_wid, j| {
            std::thread::sleep(Duration::from_millis(1));
            j + 100
        });
        for j in 0..20u64 {
            assert!(pool.submit(j));
        }
        let mut got: Vec<u64> = (0..20).map(|_| pool.recv_timeout(TICK).expect("result")).collect();
        got.sort_unstable();
        assert_eq!(got, (100..120u64).collect::<Vec<_>>());
    }

    #[test]
    fn shutdown_drains_queued_jobs_and_joins() {
        let counter = Arc::new(Mutex::new(0usize));
        let c = counter.clone();
        let mut pool: WorkerPool<usize, usize> = WorkerPool::new(2, 16, move |_wid, j| {
            *c.lock().unwrap() += 1;
            j
        });
        for j in 0..10 {
            assert!(pool.submit(j));
        }
        pool.shutdown(); // must not hang; queued jobs drain first
        assert_eq!(*counter.lock().unwrap(), 10, "queued jobs were abandoned");
        assert!(!pool.submit(99), "submit after shutdown must be rejected");
        pool.shutdown(); // idempotent
    }

    #[test]
    fn drop_joins_without_deadlock() {
        let pool: WorkerPool<u8, u8> = WorkerPool::new(3, 4, |_wid, j| j);
        for j in 0..4 {
            pool.submit(j);
        }
        drop(pool); // Drop path must terminate
    }

    /// Chaos contract: a panic inside the job closure kills only its
    /// worker. The in-flight job comes back through the crash adapter,
    /// the pool respawns the dead worker, and every other job still
    /// completes — across more crashes than the pool has workers.
    #[test]
    fn supervised_pool_survives_hard_worker_crashes() {
        let pool: WorkerPool<u64, Result<u64, u64>> = WorkerPool::new_supervised(
            2,
            4,
            |_wid, j| {
                if j % 5 == 0 {
                    panic!("chaos: injected worker crash");
                }
                Ok(j)
            },
            |_wid, j| Err(j),
        );
        for j in 1..=20u64 {
            assert!(pool.submit(j));
        }
        let mut ok = Vec::new();
        let mut crashed = Vec::new();
        for _ in 0..20 {
            match pool.recv_timeout(TICK).expect("result or crash report") {
                Ok(j) => ok.push(j),
                Err(j) => crashed.push(j),
            }
        }
        ok.sort_unstable();
        crashed.sort_unstable();
        assert_eq!(crashed, vec![5, 10, 15, 20], "every crashed job must be reported");
        assert_eq!(ok, (1..=20).filter(|j| j % 5 != 0).collect::<Vec<_>>());
        assert_eq!(pool.crashes(), 4);
        assert_eq!(pool.outstanding(), 0);
    }

    #[test]
    fn recv_timeout_expires_when_idle() {
        let pool: WorkerPool<u8, u8> = WorkerPool::new(1, 1, |_wid, j| j);
        let t0 = Instant::now(); // detlint: allow(wall-clock) -- test measures the real timeout itself
        assert!(pool.recv_timeout(Duration::from_millis(20)).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(15));
    }
}
