//! Asynchronous ensemble evaluation: a libEnsemble-style manager/worker
//! engine for parallel, fault-tolerant autotuning (the paper's follow-on
//! "Integrating ytopt and libEnsemble" direction).
//!
//! The serial coordinator walks Fig. 1's five steps one configuration at
//! a time; this subsystem decouples *selection* from *evaluation*:
//!
//! * [`worker`] — a bounded-queue [`WorkerPool`] of `std::thread`
//!   workers, each running the five-step evaluation pipeline (codegen →
//!   launch line → compile model → app model → measurement) against the
//!   simulated substrate.
//! * [`liar`] — the async-BO bridge: in-flight configurations are
//!   observed under a [`LiarStrategy`] imputation (constant-liar min /
//!   mean / max, kriging believer) so the surrogate keeps proposing
//!   while evaluations are outstanding, then amended in place
//!   (`BayesianOptimizer::amend_at`) when real measurements land.
//! * fault handling — deterministic transient-fault injection with
//!   retry-with-exclusion, per-evaluation timeouts (as in the serial
//!   path), and straggler cancellation (runs exceeding a multiple of the
//!   batch-median runtime are cut off and penalized), all surfaced in
//!   [`EnsembleStats`]. Exclusion is a *placement* policy (the retry is
//!   kept off the worker that just failed it, as an operator would drain
//!   a suspect node); whether the retry itself faults is rolled from
//!   `(seed, configuration, attempt)` only, which is what keeps the
//!   tuning trajectory independent of thread scheduling.
//! * [`checkpoint`] — completed evaluations persist through an atomic
//!   JSON checkpoint; a killed session resumes with zero re-evaluation
//!   of completed configurations.
//!
//! Determinism: evaluation outcomes depend only on `(seed, eval_id,
//! attempt)` — never on which OS thread ran them or in which order
//! results arrived — and the manager applies results in eval-id order
//! with an analytic greedy-scheduler wall-clock model, so a tuning run
//! is reproducible from its seed despite real concurrency.

pub mod checkpoint;
pub mod liar;
pub mod worker;

pub use checkpoint::Checkpoint;
pub use liar::LiarStrategy;
pub use worker::WorkerPool;

use std::collections::HashSet;
use std::sync::Arc;
use std::time::Duration;

use crate::apps::{AppModel, EvalContext};
use crate::codegen;
use crate::coordinator::{self, overhead, EvalRecord, PerfDatabase, TuneResult, TuneSetup};
use crate::metrics::{improvement_pct, Measured};
use crate::platform::{compile_time, launch};
use crate::runtime::Scorer;
use crate::search::SearchStrategy;
use crate::space::{paper, ConfigSpace, Configuration};
use crate::util::Pcg32;
use anyhow::{Context, Result};

/// Ensemble telemetry surfaced in [`TuneResult`].
#[derive(Debug, Clone)]
pub struct EnsembleStats {
    pub workers: usize,
    /// Proposals in flight per manager cycle.
    pub batch: usize,
    pub liar: LiarStrategy,
    /// Manager cycles executed (excluding resumed history).
    pub batches: usize,
    /// Transient faults observed (including ones later retried away).
    pub faults: usize,
    /// Retry submissions issued (always with the failing worker excluded).
    pub retries: usize,
    /// Evaluations abandoned after exhausting retries (or failing launch).
    pub failed_evals: usize,
    /// Evaluations cut off by the per-evaluation timeout.
    pub timeouts: usize,
    /// In-flight runs cancelled by the straggler policy.
    pub stragglers_cancelled: usize,
    /// Completed evaluations restored from the checkpoint (not re-run).
    pub resumed_evals: usize,
    /// What the recorded evaluations would have cost back-to-back — the
    /// serial-equivalent wall-clock the worker pool compressed.
    pub serial_equivalent_s: f64,
}

/// One unit of work handed to the pool.
struct EvalJob {
    eval_id: usize,
    /// Observation index of this point's pending lie in the optimizer.
    bo_index: Option<usize>,
    attempt: usize,
    bounces: usize,
    /// Workers excluded by retry-with-exclusion.
    excluded: Vec<usize>,
    cfg: Configuration,
}

/// A completed five-step evaluation (simulated timings included).
struct EvalDone {
    command: String,
    measured: Measured,
    timed_out: bool,
    charged_runtime_s: f64,
    compile_s: f64,
    orch_s: f64,
    launch_s: f64,
}

enum OutcomeKind {
    Done(Box<EvalDone>),
    /// Deterministic transient fault (simulated node/launch failure).
    Fault,
    /// The polling worker was excluded for this job; resubmit.
    Bounced,
    /// Launch-line generation failed (invalid placement).
    LaunchFailed(String),
    /// Measurement pipeline error — fatal, mirrors the serial `?`.
    MeasureError(String),
}

struct EvalOutcome {
    job: EvalJob,
    worker: usize,
    kind: OutcomeKind,
}

/// A job's final disposition after retries/bounces settle.
enum Resolved {
    Done(EvalJob, Box<EvalDone>),
    Failed(EvalJob),
}

impl Resolved {
    fn eval_id(&self) -> usize {
        match self {
            Resolved::Done(j, _) => j.eval_id,
            Resolved::Failed(j) => j.eval_id,
        }
    }
}

/// Deterministic fault roll for `(seed, configuration, attempt)` —
/// independent of the worker and of thread scheduling.
fn fault_roll(seed: u64, cfg: &Configuration, attempt: usize) -> f64 {
    let mut h = seed ^ 0xfa01_77ab_c0de_5eed;
    for &i in cfg.indices() {
        h = h.rotate_left(9) ^ (i as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
    }
    h ^= (attempt as u64 + 1).wrapping_mul(0x2545_f491_4f6c_dd1d);
    let mut r = Pcg32::new(h, 0xfa417);
    r.f64()
}

/// Run the five-step pipeline for one job on one worker.
fn evaluate_one(
    setup: &TuneSetup,
    space: &ConfigSpace,
    scorer: &Scorer,
    model: &dyn AppModel,
    worker: usize,
    job: EvalJob,
) -> EvalOutcome {
    // per-(eval, attempt) stream: deterministic wherever this job runs
    let mut rng = Pcg32::new(
        setup.seed ^ (job.eval_id as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15),
        0x5851_f42d ^ job.attempt as u64,
    );

    if setup.fault_rate > 0.0 && fault_roll(setup.seed, &job.cfg, job.attempt) < setup.fault_rate {
        return EvalOutcome { job, worker, kind: OutcomeKind::Fault };
    }

    // ---- Step 2: instantiate + verify the code mold -------------------
    let source = match codegen::instantiate(setup.app, space, &job.cfg) {
        Ok(s) => s,
        Err(e) => {
            let kind = OutcomeKind::MeasureError(format!("code-mold instantiation: {e}"));
            return EvalOutcome { job, worker, kind };
        }
    };
    if !codegen::verify(&source) {
        let kind = OutcomeKind::MeasureError("generated code failed verification".to_string());
        return EvalOutcome { job, worker, kind };
    }

    // ---- Step 3: generate the launch command --------------------------
    let (command, ctx) = match coordinator::launch_plan(setup, space, &job.cfg) {
        Ok(plan) => {
            let mut ctx = EvalContext::new(setup.platform, setup.nodes);
            ctx.ranks_per_node = plan.ranks_per_node;
            ctx.uses_gpus = plan.uses_gpus;
            let cmd = if setup.metric.needs_power() {
                format!(
                    "{} {}",
                    codegen::env_prefix(space, &job.cfg),
                    launch::geopmlaunch(&plan, "gm.report")
                )
            } else {
                format!("{} {}", codegen::env_prefix(space, &job.cfg), plan.command)
            };
            (cmd, ctx)
        }
        Err(e) => {
            return EvalOutcome { job, worker, kind: OutcomeKind::LaunchFailed(e.to_string()) }
        }
    };

    // ---- Step 4: compile ----------------------------------------------
    let compile_s = compile_time::sample_compile_s(setup.app, setup.platform, &mut rng);

    // ---- Step 5: run + measure ----------------------------------------
    let mut ctx = ctx;
    ctx.noise_seed = setup.seed ^ (job.eval_id as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
    let mut run = model.run(space, &job.cfg, &ctx);
    if let Some(cap) = setup.power_cap_w {
        run = crate::power::apply_cap(&run, cap);
    }
    let (measured, timed_out, charged_runtime_s) = match setup.eval_timeout_s {
        Some(t) if run.runtime_s > t => (Measured::runtime_only(f64::INFINITY), true, t),
        _ => match coordinator::measure(setup, &run, scorer, ctx.noise_seed) {
            Ok(m) => (m, false, m.runtime_s),
            Err(e) => {
                let kind = OutcomeKind::MeasureError(format!("{e:#}"));
                return EvalOutcome { job, worker, kind };
            }
        },
    };
    let orch_s = overhead::sample_orchestration_s(setup.app, setup.platform, setup.nodes, &mut rng);
    let launch_s = launch::launch_overhead_s(setup.platform, setup.nodes);
    EvalOutcome {
        job,
        worker,
        kind: OutcomeKind::Done(Box::new(EvalDone {
            command,
            measured,
            timed_out,
            charged_runtime_s,
            compile_s,
            orch_s,
            launch_s,
        })),
    }
}

/// Run the full autotuning loop on the ensemble engine. Invoked by
/// [`coordinator::autotune_with_scorer`] when `ensemble_workers >= 2`.
pub fn autotune_ensemble(setup: &TuneSetup, scorer: Arc<Scorer>) -> Result<TuneResult> {
    anyhow::ensure!(
        setup.ensemble_workers >= 2,
        "ensemble path needs >= 2 workers (got {})",
        setup.ensemble_workers
    );
    let workers = setup.ensemble_workers;
    let batch_target = if setup.ensemble_batch == 0 { workers } else { setup.ensemble_batch };

    let space = Arc::new(paper::build_space(setup.app, setup.platform));
    let mut rng = Pcg32::seeded(setup.seed);
    let (baseline, baseline_objective) = coordinator::measure_baseline(setup, &scorer)?;

    let mut strat = coordinator::build_strategy(setup, space.clone(), scorer.clone());

    let mut db = PerfDatabase::new();
    let mut wallclock = 0.0f64;
    let mut best = f64::INFINITY;
    let mut best_desc = String::new();
    let mut eval_id = 0usize;
    // finite real measurements (the liar pool)
    let mut real_objectives: Vec<f64> = Vec::new();
    let mut stats = EnsembleStats {
        workers,
        batch: batch_target,
        liar: setup.liar,
        batches: 0,
        faults: 0,
        retries: 0,
        failed_evals: 0,
        timeouts: 0,
        stragglers_cancelled: 0,
        resumed_evals: 0,
        serial_equivalent_s: 0.0,
    };

    // ---- resume: feed checkpointed evaluations straight to the search --
    let fp = checkpoint::fingerprint(setup);
    if let Some(path) = &setup.checkpoint_path {
        if let Some(cp) = Checkpoint::load(path)? {
            anyhow::ensure!(
                cp.fingerprint == fp,
                "checkpoint {} belongs to a different run: `{}` != `{fp}`",
                path.display(),
                cp.fingerprint
            );
            for rec in cp.records {
                let cfg = checkpoint::config_from_key(&rec.config_key)?;
                strat.observe(&cfg, rec.objective);
                if !rec.timed_out && rec.objective.is_finite() {
                    if rec.objective < best {
                        best = rec.objective;
                        best_desc = rec.config_desc.clone();
                    }
                    real_objectives.push(rec.objective);
                }
                db.push(rec);
            }
            eval_id = db.len();
            wallclock = cp.wallclock_s;
            stats.resumed_evals = eval_id;
            log::info!("resumed {eval_id} completed evaluations from {}", path.display());
        }
    }

    // ---- the worker pool ------------------------------------------------
    let eval_fn = {
        let setup = Arc::new(setup.clone());
        let space = space.clone();
        let scorer = scorer.clone();
        let model: Arc<dyn AppModel> = Arc::from(coordinator::model_for_setup(&setup));
        move |worker: usize, job: EvalJob| -> EvalOutcome {
            if job.excluded.contains(&worker) {
                return EvalOutcome { job, worker, kind: OutcomeKind::Bounced };
            }
            evaluate_one(&setup, &space, &scorer, model.as_ref(), worker, job)
        }
    };
    let mut pool: WorkerPool<EvalJob, EvalOutcome> =
        WorkerPool::new(workers, workers.max(batch_target) * 2, eval_fn);

    let mut allocation = setup.node_hours_budget.map(|nh| {
        crate::platform::scheduler::Allocation::new(setup.platform, "ytopt-repro", nh)
    });

    'outer: while eval_id < setup.max_evals && wallclock < setup.wallclock_budget_s {
        if let Some(alloc) = &allocation {
            let est = if eval_id > 0 { wallclock / eval_id as f64 } else { 60.0 };
            if !alloc.can_afford(setup.nodes, est) {
                log::info!("allocation exhausted after {eval_id} evaluations");
                break 'outer;
            }
        }
        let batch = batch_target.min(setup.max_evals - eval_id);

        // ---- Step 1: propose a batch, lying about in-flight points -----
        let t_search = std::time::Instant::now();
        let mut jobs: Vec<EvalJob> = Vec::with_capacity(batch);
        for b in 0..batch {
            let cfg = strat.propose(&mut rng);
            let bo_index = match strat.as_bo_mut() {
                Some(bo) if batch > 1 => {
                    let lie = setup.liar.impute(
                        Some(&*bo),
                        &cfg,
                        &real_objectives,
                        baseline_objective,
                        &mut rng,
                    );
                    let idx = bo.next_index();
                    bo.observe(&cfg, lie);
                    Some(idx)
                }
                _ => None,
            };
            jobs.push(EvalJob {
                eval_id: eval_id + b,
                bo_index,
                attempt: 0,
                bounces: 0,
                excluded: Vec::new(),
                cfg,
            });
        }
        let search_s = t_search.elapsed().as_secs_f64();

        // ---- dispatch + collect (retries and bounces settle here) ------
        for job in jobs {
            anyhow::ensure!(pool.submit(job), "ensemble worker pool rejected a job");
        }
        let mut resolved: Vec<Resolved> = Vec::with_capacity(batch);
        while resolved.len() < batch {
            let out = pool
                .recv_timeout(Duration::from_secs(120))
                .context("ensemble worker stalled (no result within 120 s)")?;
            match out.kind {
                OutcomeKind::Done(d) => resolved.push(Resolved::Done(out.job, d)),
                OutcomeKind::Bounced => {
                    let mut job = out.job;
                    job.bounces += 1;
                    if job.bounces > 8 * workers {
                        // pathological exclusion set: clear it rather than
                        // ping-pong forever
                        job.excluded.clear();
                    }
                    // back off briefly so an excluded-but-idle worker does
                    // not turn resubmission into a hot spin while the
                    // non-excluded workers stay busy
                    std::thread::sleep(Duration::from_millis(1));
                    anyhow::ensure!(pool.submit(job), "ensemble worker pool rejected a retry");
                }
                OutcomeKind::Fault => {
                    stats.faults += 1;
                    let mut job = out.job;
                    if job.attempt < setup.max_retries {
                        stats.retries += 1;
                        job.attempt += 1;
                        if !job.excluded.contains(&out.worker) {
                            job.excluded.push(out.worker);
                        }
                        if job.excluded.len() >= workers {
                            job.excluded.clear();
                        }
                        anyhow::ensure!(pool.submit(job), "ensemble worker pool rejected a retry");
                    } else {
                        resolved.push(Resolved::Failed(job));
                    }
                }
                OutcomeKind::LaunchFailed(e) => {
                    log::warn!("launch generation failed: {e}");
                    resolved.push(Resolved::Failed(out.job));
                }
                OutcomeKind::MeasureError(e) => {
                    anyhow::bail!("evaluation {} failed: {e}", out.job.eval_id);
                }
            }
        }
        // apply results in eval-id order: the tuning trajectory must not
        // depend on thread completion order
        resolved.sort_by_key(Resolved::eval_id);

        // ---- straggler cancellation ------------------------------------
        let mut straggler_cutoff = f64::INFINITY;
        let mut cancelled_ids: HashSet<usize> = HashSet::new();
        if let Some(factor) = setup.straggler_factor {
            let mut runtimes: Vec<f64> = resolved
                .iter()
                .filter_map(|r| match r {
                    Resolved::Done(_, d) if !d.timed_out => Some(d.charged_runtime_s),
                    _ => None,
                })
                .collect();
            if runtimes.len() >= 3 {
                runtimes.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let median = runtimes[runtimes.len() / 2];
                straggler_cutoff = median * factor.max(1.0);
                for r in &resolved {
                    if let Resolved::Done(j, d) = r {
                        if !d.timed_out && d.charged_runtime_s > straggler_cutoff {
                            cancelled_ids.insert(j.eval_id);
                        }
                    }
                }
            }
        }

        // ---- record, amend the surrogate, advance simulated time -------
        let batch_n = resolved.len().max(1);
        let dispatch_s = overhead::ensemble_dispatch_s(workers);
        // greedy schedule over the real worker count: completion offsets
        let mut worker_free = vec![0.0f64; workers];
        for r in &resolved {
            let (job, done) = match r {
                Resolved::Done(j, d) => (j, Some(d)),
                Resolved::Failed(j) => (j, None),
            };
            let first_extra = if job.eval_id == 0 {
                overhead::first_eval_setup_s(setup.app, setup.platform, setup.nodes)
            } else {
                0.0
            };
            let record_s = 0.2;
            let (measured, objective, timed_out, cancelled, compile_s, processing_s, charged) =
                match done {
                    Some(d) => {
                        let cancelled = cancelled_ids.contains(&job.eval_id);
                        let timed_out = d.timed_out || cancelled;
                        let measured = if cancelled {
                            Measured::runtime_only(f64::INFINITY)
                        } else {
                            d.measured
                        };
                        // penalties stay strictly worse than anything real
                        // in objective units (timeouts are seconds, which
                        // for energy/EDP could undercut real joules)
                        let objective = if d.timed_out {
                            (setup.eval_timeout_s.unwrap_or(baseline_objective) * 3.0)
                                .max(baseline_objective * 3.0)
                        } else if cancelled {
                            baseline_objective * 3.0
                        } else {
                            d.measured.objective(setup.metric)
                        };
                        let charged =
                            if cancelled { straggler_cutoff } else { d.charged_runtime_s };
                        let processing_s = search_s / batch_n as f64
                            + d.orch_s
                            + first_extra
                            + d.launch_s
                            + d.compile_s
                            + dispatch_s
                            + record_s;
                        (measured, objective, timed_out, cancelled, d.compile_s, processing_s, charged)
                    }
                    None => {
                        // abandoned after retries: every attempt burned
                        // orchestration + launch time but produced nothing
                        let attempts = job.attempt as f64 + 1.0;
                        let burn = attempts
                            * (overhead::orchestration_s(setup.app, setup.platform, setup.nodes)
                                + launch::launch_overhead_s(setup.platform, setup.nodes));
                        let processing_s =
                            search_s / batch_n as f64 + burn + first_extra + dispatch_s + record_s;
                        (
                            Measured::runtime_only(f64::INFINITY),
                            baseline_objective * 3.0,
                            true,
                            false,
                            0.0,
                            processing_s,
                            0.0,
                        )
                    }
                };
            if done.is_none() {
                stats.failed_evals += 1;
            }
            if let Some(d) = done {
                if d.timed_out {
                    stats.timeouts += 1;
                }
            }
            if cancelled {
                stats.stragglers_cancelled += 1;
            }

            // amend the pending lie (or observe, when no lie was planted)
            match job.bo_index {
                Some(idx) => {
                    if let Some(bo) = strat.as_bo_mut() {
                        bo.amend_at(idx, objective);
                    }
                }
                None => strat.observe(&job.cfg, objective),
            }
            if !timed_out && objective.is_finite() {
                real_objectives.push(objective);
                if objective < best {
                    best = objective;
                    best_desc = space.describe(&job.cfg);
                }
            }

            let span = processing_s + charged;
            stats.serial_equivalent_s += span;
            // earliest-free worker takes the next job (submission order)
            let w = (0..workers)
                .min_by(|&a, &b| worker_free[a].partial_cmp(&worker_free[b]).unwrap())
                .unwrap();
            worker_free[w] += span;
            let completion = wallclock + worker_free[w];

            db.push(EvalRecord {
                id: job.eval_id,
                config_key: job.cfg.key(),
                config_desc: space.describe(&job.cfg),
                command: done.map(|d| d.command.clone()).unwrap_or_default(),
                measured,
                objective,
                compile_s,
                processing_s,
                overhead_s: processing_s - compile_s,
                wallclock_s: completion,
                best_so_far: if best.is_finite() { best } else { objective },
                timed_out,
                cancelled,
            });
        }
        let makespan = worker_free.iter().cloned().fold(0.0, f64::max);
        wallclock += makespan;
        eval_id += batch;
        stats.batches += 1;

        if let Some(alloc) = &mut allocation {
            if alloc.charge(setup.nodes, makespan).is_err() {
                // the job simply hits its allocation limit
                if let Some(path) = &setup.checkpoint_path {
                    save_checkpoint(path, &fp, wallclock, &db)?;
                }
                break 'outer;
            }
        }
        if let Some(path) = &setup.checkpoint_path {
            save_checkpoint(path, &fp, wallclock, &db)?;
        }
    }

    pool.shutdown();

    let param_importance = coordinator::importance_from_db(&space, &db, setup.seed);
    Ok(TuneResult {
        setup: setup.clone(),
        space_size: space.size(),
        baseline,
        baseline_objective,
        best_objective: best,
        best_config_desc: best_desc,
        improvement_pct: improvement_pct(baseline_objective, best),
        wallclock_s: wallclock,
        evaluations: db.len(),
        scorer_accelerated: scorer.is_accelerated(),
        param_importance,
        db,
        ensemble: Some(stats),
    })
}

fn save_checkpoint(
    path: &std::path::Path,
    fingerprint: &str,
    wallclock_s: f64,
    db: &PerfDatabase,
) -> Result<()> {
    Checkpoint { fingerprint: fingerprint.to_string(), wallclock_s, records: db.records.clone() }
        .save(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::AppKind;
    use crate::metrics::Metric;
    use crate::platform::PlatformKind;

    fn setup(app: AppKind, platform: PlatformKind, nodes: u64, metric: Metric) -> TuneSetup {
        let mut s = TuneSetup::new(app, platform, nodes, metric);
        s.max_evals = 16;
        s.wallclock_budget_s = 1e9;
        s.n_init = 6;
        s.ensemble_workers = 4;
        s
    }

    fn run(s: &TuneSetup) -> TuneResult {
        autotune_ensemble(s, Arc::new(Scorer::fallback())).unwrap()
    }

    #[test]
    fn ensemble_is_deterministic_despite_threads() {
        let s = setup(AppKind::XSBenchHistory, PlatformKind::Theta, 1, Metric::Runtime);
        let a = run(&s);
        let b = run(&s);
        assert_eq!(a.best_objective, b.best_objective);
        // spans include the real (host) search time, which jitters by
        // milliseconds against tens-of-seconds simulated spans
        assert!(
            (a.wallclock_s - b.wallclock_s).abs() < a.wallclock_s * 0.01 + 1.0,
            "{} vs {}",
            a.wallclock_s,
            b.wallclock_s
        );
        let keys = |r: &TuneResult| {
            r.db.records.iter().map(|x| x.config_key.clone()).collect::<Vec<_>>()
        };
        assert_eq!(keys(&a), keys(&b));
    }

    #[test]
    fn ensemble_compresses_wallclock_vs_serial_equivalent() {
        let s = setup(AppKind::Swfft, PlatformKind::Theta, 64, Metric::Runtime);
        let r = run(&s);
        assert_eq!(r.evaluations, 16);
        let es = r.ensemble.as_ref().expect("ensemble stats present");
        assert_eq!(es.workers, 4);
        assert!(es.batches >= 4);
        // the pool must beat back-to-back execution by a wide margin
        assert!(
            r.wallclock_s < es.serial_equivalent_s * 0.6,
            "wallclock {} vs serial-equivalent {}",
            r.wallclock_s,
            es.serial_equivalent_s
        );
        // records exist for every id, in order
        for (i, rec) in r.db.records.iter().enumerate() {
            assert_eq!(rec.id, i);
        }
    }

    #[test]
    fn faults_retry_with_exclusion_and_the_run_completes() {
        let mut s = setup(AppKind::Swfft, PlatformKind::Summit, 64, Metric::Runtime);
        s.fault_rate = 0.4;
        s.max_retries = 3;
        let r = run(&s);
        let es = r.ensemble.as_ref().unwrap();
        assert_eq!(r.evaluations, 16, "every evaluation id must resolve");
        assert!(es.faults > 0, "fault injection at 40% produced no faults in 16 evals");
        assert!(es.retries > 0);
        // permanently failed evaluations (if any) are penalty records
        for rec in &r.db.records {
            if rec.command.is_empty() {
                assert!(rec.timed_out);
                assert!(!rec.measured.runtime_s.is_finite());
            }
        }
        // a clean best still emerged
        assert!(r.best_objective.is_finite());
    }

    #[test]
    fn timeout_extension_applies_on_the_ensemble_path() {
        let mut s = setup(AppKind::Amg, PlatformKind::Theta, 4096, Metric::Runtime);
        s.eval_timeout_s = Some(60.0); // AMG pathological corner ~1000 s
        s.max_evals = 24;
        let r = run(&s);
        let es = r.ensemble.as_ref().unwrap();
        for rec in &r.db.records {
            if rec.timed_out && !rec.cancelled {
                assert!(!rec.measured.runtime_s.is_finite());
            } else if !rec.timed_out {
                assert!(rec.measured.runtime_s <= 60.0);
            }
        }
        assert_eq!(
            es.timeouts,
            r.db.records.iter().filter(|x| x.timed_out && !x.cancelled).count()
        );
    }

    #[test]
    fn stragglers_are_cancelled_under_an_aggressive_policy() {
        let mut s = setup(AppKind::XSBenchHistory, PlatformKind::Theta, 1, Metric::Runtime);
        s.straggler_factor = Some(1.02);
        s.max_evals = 24;
        s.ensemble_workers = 8;
        let r = run(&s);
        let es = r.ensemble.as_ref().unwrap();
        assert!(
            es.stragglers_cancelled > 0,
            "a 1.02x-median cutoff over random early batches must cancel something"
        );
        for rec in r.db.records.iter().filter(|x| x.cancelled) {
            assert!(rec.timed_out);
            assert!(!rec.measured.runtime_s.is_finite());
            assert!(rec.objective > r.baseline_objective, "cancellation must be penalized");
        }
    }

    #[test]
    fn energy_metric_flows_through_workers() {
        let mut s = setup(AppKind::Amg, PlatformKind::Theta, 256, Metric::Energy);
        s.max_evals = 12;
        let r = run(&s);
        assert!(r.baseline.avg_node_energy_j.is_some());
        let ok = r.db.records.iter().find(|x| !x.timed_out).expect("a finished eval");
        assert!(ok.command.contains("geopmlaunch"), "{}", ok.command);
        assert!(ok.measured.avg_node_energy_j.unwrap_or(0.0) > 0.0);
    }

    #[test]
    fn rejects_single_worker_setups() {
        let mut s = setup(AppKind::Amg, PlatformKind::Theta, 64, Metric::Runtime);
        s.ensemble_workers = 1;
        assert!(autotune_ensemble(&s, Arc::new(Scorer::fallback())).is_err());
    }

    #[test]
    fn non_bo_strategies_run_on_the_ensemble_path() {
        use crate::search::StrategyKind;
        for kind in [StrategyKind::Random, StrategyKind::Grid, StrategyKind::Mctree] {
            let mut s = setup(AppKind::Swfft, PlatformKind::Summit, 64, Metric::Runtime);
            s.strategy = kind;
            s.max_evals = 10;
            let r = run(&s);
            assert_eq!(r.evaluations, 10, "{kind:?}");
        }
    }
}
