//! Asynchronous ensemble evaluation: a libEnsemble-style manager/worker
//! engine for parallel, fault-tolerant autotuning (the paper's follow-on
//! "Integrating ytopt and libEnsemble" direction).
//!
//! The serial coordinator walks Fig. 1's five steps one configuration at
//! a time; this subsystem decouples *selection* from *evaluation*:
//!
//! * [`worker`] — a bounded-queue [`WorkerPool`] of `std::thread`
//!   workers, each running the five-step evaluation pipeline (codegen →
//!   launch line → compile model → app model → measurement) against the
//!   simulated substrate.
//! * [`liar`] — the async-BO bridge: in-flight configurations are
//!   observed under a [`LiarStrategy`] imputation (constant-liar min /
//!   mean / max, kriging believer) so the surrogate keeps proposing
//!   while evaluations are outstanding; real measurements amend exactly
//!   the observation they belong to through the index-keyed
//!   `BayesianOptimizer::observe_pending` / `resolve_pending` pair —
//!   never positionally, which would corrupt the surrogate the moment a
//!   completion lands out of proposal order. The believer reads the
//!   epoch-cached surrogate (the same fit the proposal scored with), so
//!   a per-completion imputation costs a tree descent, not a refit.
//! * manager cycle ([`ManagerCycle`]) — **continuous** (the default):
//!   an event-driven loop that blocks on the result channel and, on
//!   every single completion, amends that result's pending lie by
//!   index, proposes one replacement candidate under the liar strategy,
//!   and dispatches it immediately — no worker ever idles at a batch
//!   boundary while budget remains. The **generational** cycle (propose
//!   a batch, barrier on the whole batch, repeat) is retained as the
//!   reference oracle for parity tests.
//! * fault handling — deterministic transient-fault injection with
//!   retry-with-exclusion, per-evaluation timeouts (as in the serial
//!   path), and straggler cancellation, all surfaced in
//!   [`EnsembleStats`]. The continuous cycle draws its straggler cutoff
//!   from a running quantile over *all* completed runtimes (never from
//!   fewer than four samples — a median of one or two runtimes plus a
//!   factor near 1.0 would cancel the only other in-flight run);
//!   exclusion is a *placement* policy (the retry is kept off the
//!   worker that just failed it, as an operator would drain a suspect
//!   node); whether the retry itself faults is rolled from `(seed,
//!   configuration, attempt)` only, which is what keeps the tuning
//!   trajectory independent of thread scheduling.
//! * [`checkpoint`] — completed evaluations persist through an atomic
//!   JSON checkpoint, and the continuous cycle additionally records its
//!   dispatched-but-unfinished evaluations *and its proposal state*
//!   (RNG stream position plus the strategy event log: planted lies,
//!   applies, absorbed foreign elites, in manager-event order); a
//!   killed session resumes with zero re-evaluation of completed
//!   configurations, re-queues the in-flight ones under their original
//!   eval ids, and — replaying the log, then continuing the persisted
//!   stream — keeps *proposing* mid-trajectory: fresh post-resume
//!   proposals are bit-identical to an uninterrupted run's.
//! * [`federation`] — the multi-manager layer: K continuous shards, each
//!   owning a seeded-hash partition of the candidate space (a disjoint
//!   cover of the flat index space), exchanging top-N elites
//!   periodically, and merging into one eval-id-ordered history. The
//!   plain continuous manager is the K=1 special case of the same
//!   engine; the [`Federation`] front-end validates and runs a policy.
//!
//! Determinism: evaluation outcomes depend only on `(seed, eval_id,
//! attempt)` — never on which OS thread ran them or in which order
//! results arrived — and the manager applies results (surrogate
//! amendments, records, replacement proposals) in eval-id order even
//! when completions interleave freely, with an analytic greedy-scheduler
//! wall-clock model, so a tuning run is reproducible from its seed
//! despite real concurrency.

pub mod checkpoint;
pub mod federation;
pub mod liar;
pub mod worker;

pub use checkpoint::{Checkpoint, InFlightEval, ProposalState, StrategyEvent};
pub use federation::{
    autotune_federation, shard_of_index, FederationManifest, FederationStats, ShardSpec,
};
pub use liar::LiarStrategy;
pub use worker::WorkerPool;

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;
use std::time::Duration;

use crate::apps::{AppModel, EvalContext};
use crate::codegen;
use crate::coordinator::{self, overhead, EvalRecord, PerfDatabase, TuneResult, TuneSetup};
use crate::metrics::{improvement_pct, Measured};
use crate::platform::{compile_time, launch};
use crate::runtime::Scorer;
use crate::space::{paper, ConfigSpace, Configuration};
use crate::util::Pcg32;
use anyhow::{Context, Result};

/// How the manager feeds the worker pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ManagerCycle {
    /// Propose a batch, barrier on the whole batch, repeat. Kept as the
    /// reference oracle: workers idle at every batch boundary.
    Generational,
    /// Event-driven: every single completion amends its pending lie by
    /// index, proposes one replacement, and dispatches it immediately.
    #[default]
    Continuous,
}

impl ManagerCycle {
    /// Every accepted spelling, paired with its cycle. The CLI's choice
    /// validation and [`Self::parse`] both read this table, so the two
    /// can never drift apart.
    pub const ALIASES: [(&'static str, ManagerCycle); 6] = [
        ("continuous", ManagerCycle::Continuous),
        ("cont", ManagerCycle::Continuous),
        ("async", ManagerCycle::Continuous),
        ("generational", ManagerCycle::Generational),
        ("gen", ManagerCycle::Generational),
        ("batch", ManagerCycle::Generational),
    ];

    pub fn parse(s: &str) -> Option<ManagerCycle> {
        let s = s.to_ascii_lowercase();
        Self::ALIASES.iter().find(|(a, _)| *a == s).map(|(_, c)| *c)
    }

    pub fn name(&self) -> &'static str {
        match self {
            ManagerCycle::Generational => "generational",
            ManagerCycle::Continuous => "continuous",
        }
    }
}

/// Ensemble telemetry surfaced in [`TuneResult`].
#[derive(Debug, Clone)]
pub struct EnsembleStats {
    pub workers: usize,
    /// In-flight proposal target (generational: batch size; continuous:
    /// maximum concurrent proposals).
    pub batch: usize,
    pub liar: LiarStrategy,
    pub cycle: ManagerCycle,
    /// Manager cycles executed, excluding resumed history (generational:
    /// batches; continuous: completions processed).
    pub batches: usize,
    /// Transient faults observed (including ones later retried away).
    pub faults: usize,
    /// Retry submissions issued (always with the failing worker excluded).
    pub retries: usize,
    /// Evaluations abandoned after exhausting retries (or failing launch).
    pub failed_evals: usize,
    /// Evaluations cut off by the per-evaluation timeout.
    pub timeouts: usize,
    /// In-flight runs cancelled by the straggler policy.
    pub stragglers_cancelled: usize,
    /// Hard worker crashes survived (thread respawned, in-flight eval
    /// re-queued at the same attempt through the exclusion path).
    pub worker_crashes: usize,
    /// Completed evaluations restored from the checkpoint (not re-run).
    pub resumed_evals: usize,
    /// What the recorded evaluations would have cost back-to-back — the
    /// serial-equivalent wall-clock the worker pool compressed.
    pub serial_equivalent_s: f64,
    /// Simulated worker-seconds spent idle at manager synchronization
    /// barriers. The generational cycle pays this at every batch
    /// boundary (each worker waits for the batch makespan); the
    /// continuous cycle has no barriers and reports exactly 0.
    pub worker_idle_s: f64,
}

impl EnsembleStats {
    /// Fresh zeroed counters — every manager (both cycles, each
    /// federation shard, and the federation merge accumulator) starts
    /// here, so adding a stat field touches exactly one literal.
    pub fn new(workers: usize, batch: usize, liar: LiarStrategy, cycle: ManagerCycle) -> Self {
        EnsembleStats {
            workers,
            batch,
            liar,
            cycle,
            batches: 0,
            faults: 0,
            retries: 0,
            failed_evals: 0,
            timeouts: 0,
            stragglers_cancelled: 0,
            worker_crashes: 0,
            resumed_evals: 0,
            serial_equivalent_s: 0.0,
            worker_idle_s: 0.0,
        }
    }
}

/// One unit of work handed to the pool. `Clone` so the supervised pool
/// can save a copy before the job enters the (possibly crashing)
/// evaluation closure.
#[derive(Clone)]
struct EvalJob {
    eval_id: usize,
    attempt: usize,
    bounces: usize,
    /// Hard worker crashes this job has already survived (counted
    /// separately from `attempt`: a crash re-queues at the *same*
    /// attempt, so the eventual outcome stays a pure function of
    /// `(seed, configuration, attempt)` — trajectory-neutral).
    crashes: usize,
    /// Workers excluded by retry-with-exclusion.
    excluded: Vec<usize>,
    cfg: Configuration,
    /// Host-side search time spent proposing this configuration
    /// (continuous cycle charges it per completion; the generational
    /// cycle amortizes the batch's search time instead).
    search_s: f64,
}

/// A completed five-step evaluation (simulated timings included).
struct EvalDone {
    command: String,
    measured: Measured,
    timed_out: bool,
    charged_runtime_s: f64,
    compile_s: f64,
    orch_s: f64,
    launch_s: f64,
}

enum OutcomeKind {
    Done(Box<EvalDone>),
    /// Deterministic transient fault (simulated node/launch failure).
    Fault,
    /// The polling worker was excluded for this job; resubmit.
    Bounced,
    /// The worker thread died to a hard crash mid-evaluation (chaos
    /// injection or a real panic); the supervised pool converted the
    /// in-flight job into this report and respawned the worker.
    Crashed,
    /// Launch-line generation failed (invalid placement).
    LaunchFailed(String),
    /// Measurement pipeline error — fatal, mirrors the serial `?`.
    MeasureError(String),
}

struct EvalOutcome {
    job: EvalJob,
    worker: usize,
    kind: OutcomeKind,
}

/// A job's final disposition after retries/bounces settle.
enum Resolved {
    Done(EvalJob, Box<EvalDone>),
    Failed(EvalJob),
}

impl Resolved {
    fn eval_id(&self) -> usize {
        match self {
            Resolved::Done(j, _) => j.eval_id,
            Resolved::Failed(j) => j.eval_id,
        }
    }
}

/// Minimum completed runtimes before the straggler policy may cancel
/// anything, shared by both manager cycles: a "median" of 1-2 samples
/// with a factor near 1.0 would cancel the only other in-flight run.
const STRAGGLER_MIN_SAMPLES: usize = 4;

/// Generational straggler cutoff from one batch's completed runtimes.
/// Non-finite runtimes (a faulted evaluation can surface NaN) are
/// excluded before the median — one poisoned sample must cost one
/// evaluation, never panic the whole run — and the policy stays
/// disarmed (`INFINITY`) below [`STRAGGLER_MIN_SAMPLES`] clean samples.
fn batch_straggler_cutoff(runtimes: &[f64], factor: f64) -> f64 {
    let mut clean: Vec<f64> = runtimes.iter().copied().filter(|r| r.is_finite()).collect();
    if clean.len() < STRAGGLER_MIN_SAMPLES {
        return f64::INFINITY;
    }
    clean.sort_by(f64::total_cmp);
    clean[clean.len() / 2] * factor.max(1.0)
}

/// Deterministic fault roll for `(seed, configuration, attempt)` —
/// independent of the worker and of thread scheduling.
fn fault_roll(seed: u64, cfg: &Configuration, attempt: usize) -> f64 {
    let mut h = seed ^ 0xfa01_77ab_c0de_5eed;
    for &i in cfg.indices() {
        h = h.rotate_left(9) ^ (i as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
    }
    h ^= (attempt as u64 + 1).wrapping_mul(0x2545_f491_4f6c_dd1d);
    let mut r = Pcg32::new(h, 0xfa417);
    r.f64()
}

/// Run the five-step pipeline for one job on one worker.
fn evaluate_one(
    setup: &TuneSetup,
    space: &ConfigSpace,
    scorer: &Scorer,
    model: &dyn AppModel,
    worker: usize,
    job: EvalJob,
) -> EvalOutcome {
    // per-(eval, attempt) stream: deterministic wherever this job runs
    let mut rng = Pcg32::new(
        setup.seed ^ (job.eval_id as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15),
        0x5851_f42d ^ job.attempt as u64,
    );

    if setup.fault_rate > 0.0 && fault_roll(setup.seed, &job.cfg, job.attempt) < setup.fault_rate {
        return EvalOutcome { job, worker, kind: OutcomeKind::Fault };
    }

    // ---- Step 2: instantiate + verify the code mold -------------------
    let source = match codegen::instantiate(setup.app, space, &job.cfg) {
        Ok(s) => s,
        Err(e) => {
            let kind = OutcomeKind::MeasureError(format!("code-mold instantiation: {e}"));
            return EvalOutcome { job, worker, kind };
        }
    };
    if !codegen::verify(&source) {
        let kind = OutcomeKind::MeasureError("generated code failed verification".to_string());
        return EvalOutcome { job, worker, kind };
    }

    // ---- Step 3: generate the launch command --------------------------
    let (command, ctx) = match coordinator::launch_plan(setup, space, &job.cfg) {
        Ok(plan) => {
            let mut ctx = EvalContext::new(setup.platform, setup.nodes);
            ctx.ranks_per_node = plan.ranks_per_node;
            ctx.uses_gpus = plan.uses_gpus;
            let cmd = if setup.metric.needs_power() {
                format!(
                    "{} {}",
                    codegen::env_prefix(space, &job.cfg),
                    launch::geopmlaunch(&plan, "gm.report")
                )
            } else {
                format!("{} {}", codegen::env_prefix(space, &job.cfg), plan.command)
            };
            (cmd, ctx)
        }
        Err(e) => {
            return EvalOutcome { job, worker, kind: OutcomeKind::LaunchFailed(e.to_string()) }
        }
    };

    // ---- Step 4: compile ----------------------------------------------
    let compile_s = compile_time::sample_compile_s(setup.app, setup.platform, &mut rng);

    // ---- Step 5: run + measure ----------------------------------------
    let mut ctx = ctx;
    ctx.noise_seed = setup.seed ^ (job.eval_id as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
    let mut run = model.run(space, &job.cfg, &ctx);
    if let Some(cap) = setup.power_cap_w {
        run = crate::power::apply_cap(&run, cap);
    }
    let (measured, timed_out, charged_runtime_s) = match setup.eval_timeout_s {
        Some(t) if run.runtime_s > t => (Measured::runtime_only(f64::INFINITY), true, t),
        _ => match coordinator::measure(setup, &run, scorer, ctx.noise_seed) {
            Ok(m) => (m, false, m.runtime_s),
            Err(e) => {
                let kind = OutcomeKind::MeasureError(format!("{e:#}"));
                return EvalOutcome { job, worker, kind };
            }
        },
    };
    let orch_s = overhead::sample_orchestration_s(setup.app, setup.platform, setup.nodes, &mut rng);
    let launch_s = launch::launch_overhead_s(setup.platform, setup.nodes);
    EvalOutcome {
        job,
        worker,
        kind: OutcomeKind::Done(Box::new(EvalDone {
            command,
            measured,
            timed_out,
            charged_runtime_s,
            compile_s,
            orch_s,
            launch_s,
        })),
    }
}

/// Drain one pool event, shared by both manager cycles so the retry /
/// exclusion / bounce policy can never diverge between them: bounces
/// and retryable faults are resubmitted (returning `None`); terminal
/// outcomes come back as `Some(Resolved)` for the caller's collection
/// (the generational batch vec or the continuous reorder buffer).
fn handle_outcome(
    pool: &WorkerPool<EvalJob, EvalOutcome>,
    out: EvalOutcome,
    workers: usize,
    max_retries: usize,
    stats: &mut EnsembleStats,
) -> Result<Option<Resolved>> {
    match out.kind {
        OutcomeKind::Done(d) => Ok(Some(Resolved::Done(out.job, d))),
        OutcomeKind::Bounced => {
            let mut job = out.job;
            job.bounces += 1;
            if job.bounces > 8 * workers {
                // pathological exclusion set: clear it rather than
                // ping-pong forever
                job.excluded.clear();
            }
            // back off briefly so an excluded-but-idle worker does not
            // turn resubmission into a hot spin while the non-excluded
            // workers stay busy
            std::thread::sleep(Duration::from_millis(1));
            anyhow::ensure!(pool.submit(job), "ensemble worker pool rejected a retry");
            Ok(None)
        }
        OutcomeKind::Crashed => {
            stats.worker_crashes += 1;
            let mut job = out.job;
            job.crashes += 1;
            if job.crashes > max_retries + 1 {
                // a job that keeps killing workers is abandoned like an
                // exhausted-fault job rather than crash-looping the pool
                log::warn!(
                    "evaluation {} abandoned after {} worker crashes",
                    job.eval_id,
                    job.crashes
                );
                return Ok(Some(Resolved::Failed(job)));
            }
            // placement policy only: re-queue at the SAME attempt (the
            // outcome stays a pure function of (seed, configuration,
            // attempt) — a crash must not bend the trajectory), kept off
            // the worker that just died under it
            if !job.excluded.contains(&out.worker) {
                job.excluded.push(out.worker);
            }
            if job.excluded.len() >= workers {
                job.excluded.clear();
            }
            anyhow::ensure!(pool.submit(job), "ensemble worker pool rejected a crash re-queue");
            Ok(None)
        }
        OutcomeKind::Fault => {
            stats.faults += 1;
            let mut job = out.job;
            if job.attempt < max_retries {
                stats.retries += 1;
                job.attempt += 1;
                if !job.excluded.contains(&out.worker) {
                    job.excluded.push(out.worker);
                }
                if job.excluded.len() >= workers {
                    job.excluded.clear();
                }
                anyhow::ensure!(pool.submit(job), "ensemble worker pool rejected a retry");
                Ok(None)
            } else {
                Ok(Some(Resolved::Failed(job)))
            }
        }
        OutcomeKind::LaunchFailed(e) => {
            log::warn!("launch generation failed: {e}");
            Ok(Some(Resolved::Failed(out.job)))
        }
        OutcomeKind::MeasureError(e) => {
            anyhow::bail!("evaluation {} failed: {e}", out.job.eval_id)
        }
    }
}

/// Everything one resolved evaluation contributes to the database.
struct Settled {
    measured: Measured,
    objective: f64,
    timed_out: bool,
    compile_s: f64,
    processing_s: f64,
    /// Application runtime charged to the simulated schedule.
    charged: f64,
}

/// Shared Step-5 bookkeeping for one resolved evaluation: penalty
/// objectives, charged runtime, and processing seconds. `cancel_cutoff`
/// is `Some(cutoff)` when the straggler policy cancelled this run at
/// that runtime; `manager_s` is the mode-specific manager cost charged
/// to this evaluation (amortized batch search + dispatch for the
/// generational cycle, per-completion cost for the continuous cycle).
fn settle_result(
    setup: &TuneSetup,
    baseline_objective: f64,
    job: &EvalJob,
    done: Option<&EvalDone>,
    cancel_cutoff: Option<f64>,
    manager_s: f64,
    first_extra: f64,
) -> Settled {
    let record_s = 0.2;
    let cancelled = cancel_cutoff.is_some();
    match done {
        Some(d) => {
            let timed_out = d.timed_out || cancelled;
            let measured =
                if cancelled { Measured::runtime_only(f64::INFINITY) } else { d.measured };
            // penalties stay strictly worse than anything real in
            // objective units (timeouts are seconds, which for
            // energy/EDP could undercut real joules)
            let objective = if d.timed_out {
                (setup.eval_timeout_s.unwrap_or(baseline_objective) * 3.0)
                    .max(baseline_objective * 3.0)
            } else if cancelled {
                baseline_objective * 3.0
            } else {
                d.measured.objective(setup.metric)
            };
            let charged = cancel_cutoff.unwrap_or(d.charged_runtime_s);
            let processing_s =
                manager_s + d.orch_s + first_extra + d.launch_s + d.compile_s + record_s;
            Settled {
                measured,
                objective,
                timed_out,
                compile_s: d.compile_s,
                processing_s,
                charged,
            }
        }
        None => {
            // abandoned after retries: every attempt burned orchestration
            // + launch time but produced nothing
            let attempts = job.attempt as f64 + 1.0;
            let burn = attempts
                * (overhead::orchestration_s(setup.app, setup.platform, setup.nodes)
                    + launch::launch_overhead_s(setup.platform, setup.nodes));
            let processing_s = manager_s + burn + first_extra + record_s;
            Settled {
                measured: Measured::runtime_only(f64::INFINITY),
                objective: baseline_objective * 3.0,
                timed_out: true,
                compile_s: 0.0,
                processing_s,
                charged: 0.0,
            }
        }
    }
}

/// Run the full autotuning loop on the ensemble engine. Invoked by
/// [`coordinator::autotune_with_scorer`] when `ensemble_workers >= 2`;
/// callable directly with a single worker (used by the continuous-vs-
/// generational parity tests, where one worker makes the two cycles
/// provably identical).
pub fn autotune_ensemble(setup: &TuneSetup, scorer: Arc<Scorer>) -> Result<TuneResult> {
    anyhow::ensure!(
        setup.ensemble_workers >= 1,
        "ensemble path needs >= 1 worker (got {})",
        setup.ensemble_workers
    );
    // resolve the history-database warm start (idempotent: a no-op when
    // the coordinator front-end already did, or none is configured)
    let mut setup = setup.clone();
    crate::history::apply_warm_start(&mut setup, scorer.as_ref())?;
    let setup = &setup;
    // The continuous cycle (the default) is the single-shard special
    // case of the federation's shard manager; both run the same engine,
    // which is what makes a K=1 federation bit-identical to the plain
    // continuous manager.
    if setup.manager_cycle == ManagerCycle::Continuous {
        return federation::autotune_continuous(setup, scorer);
    }
    let workers = setup.ensemble_workers;
    let batch_target = if setup.ensemble_batch == 0 { workers } else { setup.ensemble_batch };

    let space = Arc::new(paper::build_space(setup.app, setup.platform));
    let mut rng = Pcg32::seeded(setup.seed);
    let (baseline, baseline_objective) = coordinator::measure_baseline(setup, &scorer)?;

    let mut strat = coordinator::build_strategy(setup, space.clone(), scorer.clone());

    let mut db = PerfDatabase::new();
    let mut wallclock = 0.0f64;
    let mut best = f64::INFINITY;
    let mut best_desc = String::new();
    let mut eval_id = 0usize;
    // finite real measurements (the liar pool)
    let mut real_objectives: Vec<f64> = Vec::new();
    let mut stats = EnsembleStats::new(workers, batch_target, setup.liar, setup.manager_cycle);

    // ---- resume: feed checkpointed evaluations straight to the search --
    let fp = checkpoint::fingerprint(setup);
    let mut resume_inflight: Vec<(usize, Configuration)> = Vec::new();
    if let Some(path) = &setup.checkpoint_path {
        if let Some(cp) = Checkpoint::load(path)? {
            anyhow::ensure!(
                cp.fingerprint == fp,
                "checkpoint {} belongs to a different run: `{}` != `{fp}`",
                path.display(),
                cp.fingerprint
            );
            for rec in cp.records {
                let cfg = checkpoint::config_from_key(&rec.config_key)?;
                strat.observe(&cfg, rec.objective);
                if !rec.timed_out && rec.objective.is_finite() {
                    if rec.objective < best {
                        best = rec.objective;
                        best_desc = rec.config_desc.clone();
                    }
                    real_objectives.push(rec.objective);
                }
                db.push(rec);
            }
            eval_id = db.len();
            wallclock = cp.wallclock_s;
            stats.resumed_evals = eval_id;
            for f in cp.in_flight {
                let cfg = checkpoint::config_from_key(&f.config_key)?;
                resume_inflight.push((f.eval_id, cfg));
            }
            // applications happen in eval-id order, so the in-flight set
            // must be exactly the ids right after the completed records
            for (i, (id, _)) in resume_inflight.iter().enumerate() {
                anyhow::ensure!(
                    *id == eval_id + i,
                    "checkpoint {} in-flight ids are not contiguous with its \
                     completed records (found {id}, expected {})",
                    path.display(),
                    eval_id + i
                );
            }
            log::info!(
                "resumed {eval_id} completed evaluations ({} in flight re-queued) from {}",
                resume_inflight.len(),
                path.display()
            );
        }
    }

    // ---- the worker pool ------------------------------------------------
    let eval_fn = {
        let setup = Arc::new(setup.clone());
        let space = space.clone();
        let scorer = scorer.clone();
        let model: Arc<dyn AppModel> = Arc::from(coordinator::model_for_setup(&setup));
        move |worker: usize, job: EvalJob| -> EvalOutcome {
            if job.excluded.contains(&worker) {
                return EvalOutcome { job, worker, kind: OutcomeKind::Bounced };
            }
            // chaos failpoint: a hard worker crash, not a failed eval —
            // the supervised pool catches the panic, reports the job as
            // Crashed, and respawns the thread
            if let Some(plan) = &setup.chaos {
                if plan.fire(crate::chaos::Site::WorkerCrash).is_some() {
                    panic!("chaos: injected worker crash on ensemble-worker-{worker}");
                }
            }
            evaluate_one(&setup, &space, &scorer, model.as_ref(), worker, job)
        }
    };
    let mut pool: WorkerPool<EvalJob, EvalOutcome> = WorkerPool::new_supervised(
        workers,
        workers.max(batch_target) * 2,
        eval_fn,
        |worker, job| EvalOutcome { job, worker, kind: OutcomeKind::Crashed },
    );

    let mut allocation = setup.node_hours_budget.map(|nh| {
        crate::platform::scheduler::Allocation::new(setup.platform, "ytopt-repro", nh)
    });

    match setup.manager_cycle {
        // ================================================================
        // Generational reference cycle: propose a batch, barrier on the
        // whole batch, repeat. Workers idle at every batch boundary.
        // ================================================================
        ManagerCycle::Generational => {
            anyhow::ensure!(
                resume_inflight.is_empty(),
                "generational cycle cannot re-queue in-flight evaluations \
                 (checkpoint was written by a continuous run)"
            );
            let no_inflight: BTreeMap<usize, Configuration> = BTreeMap::new();
            'outer: while eval_id < setup.max_evals && wallclock < setup.wallclock_budget_s {
                if let Some(alloc) = &allocation {
                    let est = if eval_id > 0 { wallclock / eval_id as f64 } else { 60.0 };
                    if !alloc.can_afford(setup.nodes, est) {
                        log::info!("allocation exhausted after {eval_id} evaluations");
                        break 'outer;
                    }
                }
                let batch = batch_target.min(setup.max_evals - eval_id);

                // ---- Step 1: propose a batch, lying about in-flight points
                // detlint: allow(wall-clock) -- search-overhead stat only; simulated time drives the trajectory
                let t_search = std::time::Instant::now();
                let mut jobs: Vec<EvalJob> = Vec::with_capacity(batch);
                for b in 0..batch {
                    let cfg = strat.propose(&mut rng);
                    if let Some(bo) = strat.as_bo_mut() {
                        if batch > 1 {
                            let lie = setup.liar.impute(
                                Some(&mut *bo),
                                &cfg,
                                &real_objectives,
                                baseline_objective,
                                &mut rng,
                            );
                            bo.observe_pending(eval_id + b, &cfg, lie);
                        }
                    }
                    jobs.push(EvalJob {
                        eval_id: eval_id + b,
                        attempt: 0,
                        bounces: 0,
                        crashes: 0,
                        excluded: Vec::new(),
                        cfg,
                        search_s: 0.0,
                    });
                }
                let search_s = t_search.elapsed().as_secs_f64();

                // ---- dispatch + collect (retries and bounces settle here)
                for job in jobs {
                    anyhow::ensure!(pool.submit(job), "ensemble worker pool rejected a job");
                }
                let mut resolved: Vec<Resolved> = Vec::with_capacity(batch);
                while resolved.len() < batch {
                    let out = pool
                        .recv_timeout(Duration::from_secs(120))
                        .context("ensemble worker stalled (no result within 120 s)")?;
                    if let Some(r) =
                        handle_outcome(&pool, out, workers, setup.max_retries, &mut stats)?
                    {
                        resolved.push(r);
                    }
                }
                // apply results in eval-id order: the tuning trajectory must
                // not depend on thread completion order
                resolved.sort_by_key(Resolved::eval_id);

                // ---- straggler cancellation (batch median, min 4 samples)
                let mut straggler_cutoff = f64::INFINITY;
                let mut cancelled_ids: BTreeSet<usize> = BTreeSet::new();
                if let Some(factor) = setup.straggler_factor {
                    let runtimes: Vec<f64> = resolved
                        .iter()
                        .filter_map(|r| match r {
                            Resolved::Done(_, d) if !d.timed_out => Some(d.charged_runtime_s),
                            _ => None,
                        })
                        .collect();
                    straggler_cutoff = batch_straggler_cutoff(&runtimes, factor);
                    for r in &resolved {
                        if let Resolved::Done(j, d) = r {
                            if !d.timed_out && d.charged_runtime_s > straggler_cutoff {
                                cancelled_ids.insert(j.eval_id);
                            }
                        }
                    }
                }

                // ---- record, amend the surrogate, advance simulated time --
                let batch_n = resolved.len().max(1);
                let dispatch_s = overhead::ensemble_dispatch_s(workers);
                // greedy schedule over the real worker count
                let mut worker_free = vec![0.0f64; workers];
                for r in &resolved {
                    let (job, done): (&EvalJob, Option<&EvalDone>) = match r {
                        Resolved::Done(j, d) => (j, Some(&**d)),
                        Resolved::Failed(j) => (j, None),
                    };
                    let first_extra = if job.eval_id == 0 {
                        overhead::first_eval_setup_s(setup.app, setup.platform, setup.nodes)
                    } else {
                        0.0
                    };
                    let cancel_cutoff = if cancelled_ids.contains(&job.eval_id) {
                        Some(straggler_cutoff)
                    } else {
                        None
                    };
                    let cancelled = cancel_cutoff.is_some();
                    let s = settle_result(
                        setup,
                        baseline_objective,
                        job,
                        done,
                        cancel_cutoff,
                        search_s / batch_n as f64 + dispatch_s,
                        first_extra,
                    );
                    if done.is_none() {
                        stats.failed_evals += 1;
                    }
                    if let Some(d) = done {
                        if d.timed_out {
                            stats.timeouts += 1;
                        }
                    }
                    if cancelled {
                        stats.stragglers_cancelled += 1;
                    }

                    // amend the pending lie (or observe, when none was planted)
                    let amended = match strat.as_bo_mut() {
                        Some(bo) => bo.resolve_pending(job.eval_id, s.objective),
                        None => false,
                    };
                    if !amended {
                        strat.observe(&job.cfg, s.objective);
                    }
                    if !s.timed_out && s.objective.is_finite() {
                        real_objectives.push(s.objective);
                        if s.objective < best {
                            best = s.objective;
                            best_desc = space.describe(&job.cfg);
                        }
                    }

                    let span = s.processing_s + s.charged;
                    stats.serial_equivalent_s += span;
                    // earliest-free worker takes the next job
                    let w = (0..workers)
                        .min_by(|&a, &b| worker_free[a].total_cmp(&worker_free[b]))
                        .unwrap();
                    worker_free[w] += span;
                    let completion = wallclock + worker_free[w];

                    db.push(EvalRecord {
                        id: job.eval_id,
                        config_key: job.cfg.key(),
                        config_desc: space.describe(&job.cfg),
                        command: done.map(|d| d.command.clone()).unwrap_or_default(),
                        measured: s.measured,
                        objective: s.objective,
                        compile_s: s.compile_s,
                        processing_s: s.processing_s,
                        overhead_s: s.processing_s - s.compile_s,
                        wallclock_s: completion,
                        best_so_far: if best.is_finite() { best } else { s.objective },
                        timed_out: s.timed_out,
                        cancelled,
                    });
                }
                let makespan = worker_free.iter().cloned().fold(0.0, f64::max);
                // the barrier: every worker waits out the batch makespan.
                // Clamped at zero: `worker_free` restarts from 0.0 each
                // batch, so a resumed run (or any future schedule change
                // that seeds workers past the makespan fold's 0.0 floor)
                // can never report negative — and thereby double-counted —
                // idle time (ISSUE 8 audit; pinned by kill/resume
                // stats-equality test).
                for w in &worker_free {
                    stats.worker_idle_s += (makespan - *w).max(0.0);
                }
                wallclock += makespan;
                eval_id += batch;
                stats.batches += 1;

                if let Some(obs) = &setup.obs {
                    let search_us =
                        crate::obs::secs_to_us(search_s / batch_n as f64);
                    for r in &db.records[db.len() - resolved.len()..] {
                        obs.record(crate::obs::ObsEvent::Proposed {
                            eval_id: r.id as u64,
                            shard: 0,
                            search_us,
                        });
                        obs.record(crate::obs::ObsEvent::Dispatched {
                            eval_id: r.id as u64,
                            shard: 0,
                        });
                        obs.record(crate::obs::ObsEvent::Completed {
                            eval_id: r.id as u64,
                            shard: 0,
                            objective: r.objective,
                            best_so_far: r.best_so_far,
                            sim_wallclock_s: r.wallclock_s,
                        });
                        if r.cancelled {
                            obs.record(crate::obs::ObsEvent::StragglerKilled {
                                eval_id: r.id as u64,
                                shard: 0,
                            });
                        }
                    }
                    obs.set_shard_gauges(crate::obs::ShardGauges {
                        shard: 0,
                        workers: workers as u64,
                        in_flight: 0,
                        applied: db.len() as u64,
                        best_objective: best,
                        sim_wallclock_s: wallclock,
                        busy_s: stats.serial_equivalent_s,
                    });
                }

                if let Some(alloc) = &mut allocation {
                    if alloc.charge(setup.nodes, makespan).is_err() {
                        // the job simply hits its allocation limit
                        if let Some(path) = &setup.checkpoint_path {
                            // the generational oracle does not persist
                            // proposal state (no mid-batch resume exists)
                            save_checkpoint(
                                path,
                                &fp,
                                wallclock,
                                &db,
                                &no_inflight,
                                None,
                                setup.chaos.as_deref(),
                            )?;
                        }
                        break 'outer;
                    }
                }
                if let Some(path) = &setup.checkpoint_path {
                    save_checkpoint(
                        path,
                        &fp,
                        wallclock,
                        &db,
                        &no_inflight,
                        None,
                        setup.chaos.as_deref(),
                    )?;
                }
            }
        }

        // The continuous cycle lives in `federation::ContinuousShard`
        // (the single manager is its one-shard special case) and
        // delegates at the top of this function; only the
        // generational oracle reaches this match.
        ManagerCycle::Continuous => unreachable!("continuous runs delegate above"),
    }

    pool.shutdown();

    let param_importance = coordinator::importance_from_db(&space, &db, setup.seed);
    Ok(TuneResult {
        setup: setup.clone(),
        space_size: space.size(),
        baseline,
        baseline_objective,
        best_objective: best,
        best_config_desc: best_desc,
        improvement_pct: improvement_pct(baseline_objective, best),
        wallclock_s: wallclock,
        evaluations: db.len(),
        scorer_accelerated: scorer.is_accelerated(),
        param_importance,
        db,
        ensemble: Some(stats),
        federation: None,
    })
}

/// Front-end for the multi-manager federation in [`federation`]: holds a
/// validated policy (shard count, exchange period, elite width all live
/// on [`TuneSetup`]) and runs K continuous shards over a seeded-hash
/// partition of the candidate space, merging their histories into one
/// eval-id-ordered [`TuneResult`].
pub struct Federation {
    setup: TuneSetup,
}

impl Federation {
    /// Validate the federation policy carried by `setup` (shard count in
    /// range, at least one worker per shard, continuous manager cycle).
    pub fn new(setup: TuneSetup) -> Result<Federation> {
        federation::validate_federation(&setup)?;
        Ok(Federation { setup })
    }

    /// Shard count K.
    pub fn shards(&self) -> usize {
        self.setup.federation_shards
    }

    /// Run the federated campaign.
    pub fn run(&self, scorer: Arc<Scorer>) -> Result<TuneResult> {
        federation::autotune_federation(&self.setup, scorer)
    }
}

fn save_checkpoint(
    path: &std::path::Path,
    fingerprint: &str,
    wallclock_s: f64,
    db: &PerfDatabase,
    in_flight: &BTreeMap<usize, Configuration>,
    proposal: Option<checkpoint::ProposalParts<'_>>,
    plan: Option<&crate::chaos::FaultPlan>,
) -> Result<()> {
    // serialize by reference: the continuous cycle saves per completion,
    // so this path must not clone the full record vec each time (only
    // the handful of in-flight entries are materialized)
    let in_flight: Vec<InFlightEval> = in_flight
        .iter()
        .map(|(id, cfg)| InFlightEval { eval_id: *id, config_key: cfg.key() })
        .collect();
    checkpoint::save_parts(path, fingerprint, wallclock_s, &db.records, &in_flight, proposal, plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::AppKind;
    use crate::metrics::Metric;
    use crate::platform::PlatformKind;

    fn setup(app: AppKind, platform: PlatformKind, nodes: u64, metric: Metric) -> TuneSetup {
        let mut s = TuneSetup::new(app, platform, nodes, metric);
        s.max_evals = 16;
        s.wallclock_budget_s = 1e9;
        s.n_init = 6;
        s.ensemble_workers = 4;
        s
    }

    fn run(s: &TuneSetup) -> TuneResult {
        autotune_ensemble(s, Arc::new(Scorer::fallback())).unwrap()
    }

    /// Regression: a faulted evaluation's NaN runtime used to panic the
    /// batch-median sort inside the straggler policy, killing the whole
    /// run instead of costing one evaluation. The cutoff now excludes
    /// non-finite samples and orders totally.
    #[test]
    fn straggler_cutoff_survives_planted_nan_runtime() {
        // NaN planted mid-batch: filtered out, median over the rest
        let runtimes = [40.0, f64::NAN, 42.0, 44.0, 46.0];
        let cutoff = batch_straggler_cutoff(&runtimes, 1.5);
        assert!((cutoff - 44.0 * 1.5).abs() < 1e-12, "cutoff {cutoff}");
        // infinities (timeout-charged) are excluded the same way
        let cutoff = batch_straggler_cutoff(&[40.0, f64::INFINITY, 42.0, 44.0, 46.0], 2.0);
        assert!(cutoff.is_finite());
        // dropping below the minimum clean-sample floor disarms the policy
        assert_eq!(
            batch_straggler_cutoff(&[40.0, f64::NAN, 42.0, 44.0], 1.0),
            f64::INFINITY
        );
        // factors below 1.0 clamp (a sub-median cutoff would cancel half
        // of every batch)
        let cutoff = batch_straggler_cutoff(&[1.0, 2.0, 3.0, 4.0], 0.5);
        assert!((cutoff - 3.0).abs() < 1e-12);
    }

    /// Fault-injected runs exercise the straggler policy end-to-end on
    /// the generational cycle: faulted evaluations resolve as penalty
    /// records with non-finite runtimes, and the cutoff must digest that
    /// batch without panicking while still cancelling honest stragglers.
    #[test]
    fn generational_straggler_policy_survives_faulted_batches() {
        let mut s = setup(AppKind::XSBenchHistory, PlatformKind::Theta, 1, Metric::Runtime);
        s.manager_cycle = ManagerCycle::Generational;
        s.straggler_factor = Some(1.05);
        s.fault_rate = 0.35;
        s.max_retries = 0; // faults become abandoned (non-finite) records
        s.max_evals = 24;
        s.ensemble_workers = 8;
        let r = run(&s);
        assert_eq!(r.evaluations, 24);
        let es = r.ensemble.as_ref().unwrap();
        assert!(es.faults > 0, "no faults at 35% over 24 evals");
        assert!(es.failed_evals > 0, "retries=0 must abandon at least one eval");
        // abandoned evals carry non-finite runtimes through the batch
        assert!(r.db.records.iter().any(|rec| !rec.measured.runtime_s.is_finite()));
    }

    #[test]
    fn manager_cycle_parses_and_defaults_to_continuous() {
        assert_eq!(ManagerCycle::default(), ManagerCycle::Continuous);
        for cycle in [ManagerCycle::Generational, ManagerCycle::Continuous] {
            assert_eq!(ManagerCycle::parse(cycle.name()), Some(cycle));
        }
        assert_eq!(ManagerCycle::parse("ASYNC"), Some(ManagerCycle::Continuous));
        assert_eq!(ManagerCycle::parse("batch"), Some(ManagerCycle::Generational));
        assert_eq!(ManagerCycle::parse("nope"), None);
        // the CLI allowlist and parse() read the same table
        for (alias, cycle) in ManagerCycle::ALIASES {
            assert_eq!(ManagerCycle::parse(alias), Some(cycle), "{alias}");
        }
    }

    #[test]
    fn ensemble_is_deterministic_despite_threads() {
        let s = setup(AppKind::XSBenchHistory, PlatformKind::Theta, 1, Metric::Runtime);
        let a = run(&s);
        let b = run(&s);
        assert_eq!(a.best_objective, b.best_objective);
        // spans include the real (host) search time, which jitters by
        // milliseconds against tens-of-seconds simulated spans
        assert!(
            (a.wallclock_s - b.wallclock_s).abs() < a.wallclock_s * 0.01 + 1.0,
            "{} vs {}",
            a.wallclock_s,
            b.wallclock_s
        );
        let keys = |r: &TuneResult| {
            r.db.records.iter().map(|x| x.config_key.clone()).collect::<Vec<_>>()
        };
        assert_eq!(keys(&a), keys(&b));
    }

    #[test]
    fn generational_cycle_is_also_deterministic() {
        let mut s = setup(AppKind::XSBenchHistory, PlatformKind::Theta, 1, Metric::Runtime);
        s.manager_cycle = ManagerCycle::Generational;
        let a = run(&s);
        let b = run(&s);
        assert_eq!(a.evaluations, 16);
        assert_eq!(a.best_objective, b.best_objective);
        let keys = |r: &TuneResult| {
            r.db.records.iter().map(|x| x.config_key.clone()).collect::<Vec<_>>()
        };
        assert_eq!(keys(&a), keys(&b));
        // the oracle still reports barrier idle; continuous reports none
        assert!(a.ensemble.as_ref().unwrap().worker_idle_s > 0.0);
    }

    #[test]
    fn ensemble_compresses_wallclock_vs_serial_equivalent() {
        let s = setup(AppKind::Swfft, PlatformKind::Theta, 64, Metric::Runtime);
        let r = run(&s);
        assert_eq!(r.evaluations, 16);
        let es = r.ensemble.as_ref().expect("ensemble stats present");
        assert_eq!(es.workers, 4);
        assert_eq!(es.cycle, ManagerCycle::Continuous);
        assert!(es.batches >= 4);
        assert_eq!(es.worker_idle_s, 0.0, "continuous cycle must not idle at barriers");
        // the pool must beat back-to-back execution by a wide margin
        assert!(
            r.wallclock_s < es.serial_equivalent_s * 0.6,
            "wallclock {} vs serial-equivalent {}",
            r.wallclock_s,
            es.serial_equivalent_s
        );
        // records exist for every id, in order
        for (i, rec) in r.db.records.iter().enumerate() {
            assert_eq!(rec.id, i);
        }
    }

    #[test]
    fn faults_retry_with_exclusion_and_the_run_completes() {
        let mut s = setup(AppKind::Swfft, PlatformKind::Summit, 64, Metric::Runtime);
        s.fault_rate = 0.4;
        s.max_retries = 3;
        let r = run(&s);
        let es = r.ensemble.as_ref().unwrap();
        assert_eq!(r.evaluations, 16, "every evaluation id must resolve");
        assert!(es.faults > 0, "fault injection at 40% produced no faults in 16 evals");
        assert!(es.retries > 0);
        // permanently failed evaluations (if any) are penalty records
        for rec in &r.db.records {
            if rec.command.is_empty() {
                assert!(rec.timed_out);
                assert!(!rec.measured.runtime_s.is_finite());
            }
        }
        // a clean best still emerged
        assert!(r.best_objective.is_finite());
    }

    #[test]
    fn timeout_extension_applies_on_the_ensemble_path() {
        let mut s = setup(AppKind::Amg, PlatformKind::Theta, 4096, Metric::Runtime);
        s.eval_timeout_s = Some(60.0); // AMG pathological corner ~1000 s
        s.max_evals = 24;
        let r = run(&s);
        let es = r.ensemble.as_ref().unwrap();
        for rec in &r.db.records {
            if rec.timed_out && !rec.cancelled {
                assert!(!rec.measured.runtime_s.is_finite());
            } else if !rec.timed_out {
                assert!(rec.measured.runtime_s <= 60.0);
            }
        }
        assert_eq!(
            es.timeouts,
            r.db.records.iter().filter(|x| x.timed_out && !x.cancelled).count()
        );
    }

    #[test]
    fn stragglers_are_cancelled_under_an_aggressive_policy() {
        let mut s = setup(AppKind::XSBenchHistory, PlatformKind::Theta, 1, Metric::Runtime);
        s.straggler_factor = Some(1.02);
        s.max_evals = 24;
        s.ensemble_workers = 8;
        let r = run(&s);
        let es = r.ensemble.as_ref().unwrap();
        assert!(
            es.stragglers_cancelled > 0,
            "a 1.02x-median cutoff over noisy runtimes must cancel something"
        );
        for rec in r.db.records.iter().filter(|x| x.cancelled) {
            assert!(rec.timed_out);
            assert!(!rec.measured.runtime_s.is_finite());
            assert!(rec.objective > r.baseline_objective, "cancellation must be penalized");
        }
    }

    /// The straggler policy must never fire off fewer than 4 completed
    /// runtimes: a "median" of 1-2 samples with a factor near 1.0 would
    /// cancel the only other in-flight run.
    #[test]
    fn no_straggler_cancellation_below_four_samples() {
        for cycle in [ManagerCycle::Generational, ManagerCycle::Continuous] {
            let mut s = setup(AppKind::XSBenchHistory, PlatformKind::Theta, 1, Metric::Runtime);
            s.manager_cycle = cycle;
            s.straggler_factor = Some(1.0); // maximally aggressive
            s.max_evals = 3;
            s.ensemble_workers = 3;
            let r = run(&s);
            let es = r.ensemble.as_ref().unwrap();
            assert_eq!(r.evaluations, 3, "{cycle:?}");
            assert_eq!(
                es.stragglers_cancelled, 0,
                "{cycle:?}: cancelled off a sub-4-sample runtime distribution"
            );
            assert!(r.db.records.iter().all(|rec| !rec.cancelled), "{cycle:?}");
        }
    }

    /// A continuous checkpoint with in-flight evaluations re-queues them
    /// under their original eval ids, reproducing the exact outcomes the
    /// uninterrupted run recorded (determinism is per `(seed, eval id,
    /// configuration, attempt)`).
    #[test]
    fn continuous_resume_requeues_in_flight_evaluations() {
        let mut s = setup(AppKind::Swfft, PlatformKind::Theta, 64, Metric::Runtime);
        s.max_evals = 8;
        s.seed = 17;
        let full = run(&s);
        assert_eq!(full.evaluations, 8);

        let path = std::env::temp_dir()
            .join(format!("ytopt-requeue-test-{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        // hand-craft a mid-run checkpoint: 4 applied, 2 in flight
        let cp = Checkpoint {
            fingerprint: checkpoint::fingerprint(&s),
            wallclock_s: full.db.records[3].wallclock_s,
            records: full.db.records[..4].to_vec(),
            in_flight: vec![
                InFlightEval { eval_id: 4, config_key: full.db.records[4].config_key.clone() },
                InFlightEval { eval_id: 5, config_key: full.db.records[5].config_key.clone() },
            ],
            proposal: None, // legacy checkpoint: exact re-queue, fresh stream
        };
        cp.save(&path).unwrap();

        let mut resumed = s.clone();
        resumed.checkpoint_path = Some(path.clone());
        let r = run(&resumed);
        let es = r.ensemble.as_ref().unwrap();
        assert_eq!(es.resumed_evals, 4);
        assert_eq!(r.evaluations, 8);
        // the re-queued evaluations ran the checkpointed configurations
        // under their original ids and reproduced their measurements
        for id in [4usize, 5] {
            assert_eq!(r.db.records[id].config_key, full.db.records[id].config_key);
            assert_eq!(r.db.records[id].objective, full.db.records[id].objective);
        }
        std::fs::remove_file(&path).unwrap();
    }

    /// Chaos contract: hard worker crashes are a *placement* event, not
    /// a trajectory event — the supervised pool respawns the thread and
    /// the job re-runs at the same attempt, so a crash-riddled campaign
    /// stays bit-identical to a clean one (both manager cycles).
    #[test]
    fn injected_worker_crashes_do_not_bend_the_trajectory() {
        for cycle in [ManagerCycle::Continuous, ManagerCycle::Generational] {
            let mut s = setup(AppKind::XSBenchHistory, PlatformKind::Theta, 1, Metric::Runtime);
            s.manager_cycle = cycle;
            let clean = run(&s);
            let mut chaotic = s.clone();
            // the first three executions crash deterministically, then
            // the fault clears; every crashed job re-queues and completes
            chaotic.chaos = Some(Arc::new(
                crate::chaos::FaultPlan::parse("seed=5;worker-crash=1x3").unwrap(),
            ));
            let r = run(&chaotic);
            let es = r.ensemble.as_ref().unwrap();
            assert_eq!(es.worker_crashes, 3, "{cycle:?}");
            assert_eq!(r.evaluations, clean.evaluations, "{cycle:?}");
            assert_eq!(r.best_objective, clean.best_objective, "{cycle:?}");
            let keys = |r: &TuneResult| {
                r.db.records.iter().map(|x| x.config_key.clone()).collect::<Vec<_>>()
            };
            assert_eq!(keys(&r), keys(&clean), "{cycle:?}");
            let objs = |r: &TuneResult| {
                r.db.records.iter().map(|x| x.objective.to_bits()).collect::<Vec<_>>()
            };
            assert_eq!(objs(&r), objs(&clean), "{cycle:?}");
        }
    }

    #[test]
    fn energy_metric_flows_through_workers() {
        let mut s = setup(AppKind::Amg, PlatformKind::Theta, 256, Metric::Energy);
        s.max_evals = 12;
        let r = run(&s);
        assert!(r.baseline.avg_node_energy_j.is_some());
        let ok = r.db.records.iter().find(|x| !x.timed_out).expect("a finished eval");
        assert!(ok.command.contains("geopmlaunch"), "{}", ok.command);
        assert!(ok.measured.avg_node_energy_j.unwrap_or(0.0) > 0.0);
    }

    #[test]
    fn rejects_zero_worker_setups_but_allows_one() {
        let mut s = setup(AppKind::Amg, PlatformKind::Theta, 64, Metric::Runtime);
        s.ensemble_workers = 0;
        assert!(autotune_ensemble(&s, Arc::new(Scorer::fallback())).is_err());
        // a single worker is valid (the parity-oracle configuration)
        s.ensemble_workers = 1;
        s.max_evals = 4;
        let r = autotune_ensemble(&s, Arc::new(Scorer::fallback())).unwrap();
        assert_eq!(r.evaluations, 4);
    }

    /// The `Federation` front-end validates policies up front and runs
    /// the same campaign `autotune_federation` would.
    #[test]
    fn federation_front_end_validates_and_runs() {
        let mut s = setup(AppKind::XSBenchHistory, PlatformKind::Theta, 1, Metric::Runtime);
        s.max_evals = 8;
        s.ensemble_workers = 2;
        s.federation_shards = 2;
        let fed = Federation::new(s.clone()).expect("valid policy");
        assert_eq!(fed.shards(), 2);
        let r = fed.run(Arc::new(Scorer::fallback())).unwrap();
        assert_eq!(r.evaluations, 8);
        assert_eq!(r.federation.as_ref().unwrap().shards, 2);
        // invalid policies are refused before any work happens
        let mut bad = s.clone();
        bad.ensemble_workers = 0;
        assert!(Federation::new(bad).is_err());
        let mut bad = s.clone();
        bad.manager_cycle = ManagerCycle::Generational;
        assert!(Federation::new(bad).is_err());
        let mut bad = s;
        bad.federation_shards = federation::MAX_SHARDS + 1;
        assert!(Federation::new(bad).is_err());
    }

    #[test]
    fn non_bo_strategies_run_on_the_ensemble_path() {
        use crate::search::StrategyKind;
        for kind in [StrategyKind::Random, StrategyKind::Grid, StrategyKind::Mctree] {
            let mut s = setup(AppKind::Swfft, PlatformKind::Summit, 64, Metric::Runtime);
            s.strategy = kind;
            s.max_evals = 10;
            let r = run(&s);
            assert_eq!(r.evaluations, 10, "{kind:?}");
        }
    }
}
