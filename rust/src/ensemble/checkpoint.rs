//! Ensemble session persistence: every completed evaluation is appended
//! to a JSON checkpoint (atomically: write-temp + rename), so a killed
//! session resumes without re-evaluating any completed configuration.
//!
//! The checkpoint carries a setup fingerprint; resuming against a
//! different app/platform/metric/seed is refused rather than silently
//! polluting the surrogate with foreign observations.

use std::path::Path;

use crate::coordinator::{EvalRecord, TuneSetup};
use crate::space::Configuration;
use crate::util::Json;
use anyhow::{Context, Result};

/// Persisted state of one (possibly interrupted) ensemble session.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub fingerprint: String,
    /// Simulated wall-clock at the last applied completion.
    pub wallclock_s: f64,
    /// Completed evaluations, in id order.
    pub records: Vec<EvalRecord>,
    /// Evaluations dispatched but not yet completed when the checkpoint
    /// was written (continuous manager cycle); a resumed session
    /// re-queues them with their original eval ids, so the deterministic
    /// outcome — which depends only on `(seed, configuration, eval id,
    /// attempt)` — is unchanged by the interruption.
    pub in_flight: Vec<InFlightEval>,
    /// The manager's persisted proposal state (version-3 checkpoints):
    /// RNG stream position plus the strategy event log. With it, a
    /// resumed shard's *fresh* proposals are bit-identical to an
    /// uninterrupted run's — without it (older checkpoints), resume is
    /// exact only for the re-queued in-flight work.
    pub proposal: Option<ProposalState>,
}

/// One strategy-shaping event in a continuous manager's life, recorded
/// in manager-event order. Replaying the log at resume rebuilds the
/// search strategy's internal state exactly as the live run built it:
/// pending lies land at their original observation indices, completions
/// amend in the original order, and foreign elites re-enter (and re-seed
/// the absorbed-elite dedup set) at their original positions between
/// completions — none of which is recoverable from the completed
/// records alone.
#[derive(Debug, Clone, PartialEq)]
pub enum StrategyEvent {
    /// A proposal was dispatched; `lie` is the pending-point imputation
    /// planted at propose time (`None` when no lie was planted — single
    /// in-flight slot, or a non-BO strategy).
    Propose { eval_id: usize, config_key: String, lie: Option<f64> },
    /// The completion for `eval_id` was applied (its objective lives in
    /// the checkpoint's record with that id).
    Apply { eval_id: usize },
    /// A foreign elite was absorbed from a peer shard.
    Foreign { config_key: String, y: f64 },
    /// The continuous controller's drift detector fired right after the
    /// completion for `eval_id` was applied: the surrogate's trust
    /// window was reset there. Replay re-applies the reset at the same
    /// position, so a resumed controller's window (and every proposal
    /// after it) matches the uninterrupted run's.
    Drift { eval_id: usize },
}

impl StrategyEvent {
    fn to_json(&self) -> Json {
        let num = |v: f64| if v.is_finite() { Json::Num(v) } else { Json::Null };
        match self {
            StrategyEvent::Propose { eval_id, config_key, lie } => Json::obj(vec![
                ("t", "propose".into()),
                ("id", (*eval_id).into()),
                ("config", config_key.as_str().into()),
                ("lie", lie.map(num).unwrap_or(Json::Null)),
            ]),
            StrategyEvent::Apply { eval_id } => {
                Json::obj(vec![("t", "apply".into()), ("id", (*eval_id).into())])
            }
            StrategyEvent::Foreign { config_key, y } => Json::obj(vec![
                ("t", "foreign".into()),
                ("config", config_key.as_str().into()),
                ("y", num(*y)),
            ]),
            StrategyEvent::Drift { eval_id } => {
                Json::obj(vec![("t", "drift".into()), ("id", (*eval_id).into())])
            }
        }
    }

    fn from_json(v: &Json) -> Result<StrategyEvent> {
        let id = || -> Result<usize> {
            Ok(v.get("id").and_then(Json::as_u64).context("strategy event missing `id`")? as usize)
        };
        let config = || -> Result<String> {
            Ok(v.get("config")
                .and_then(Json::as_str)
                .context("strategy event missing `config`")?
                .to_string())
        };
        match v.get("t").and_then(Json::as_str) {
            Some("propose") => Ok(StrategyEvent::Propose {
                eval_id: id()?,
                config_key: config()?,
                lie: v.get("lie").and_then(Json::as_f64),
            }),
            Some("apply") => Ok(StrategyEvent::Apply { eval_id: id()? }),
            Some("foreign") => Ok(StrategyEvent::Foreign {
                config_key: config()?,
                // an absorbed elite is always finite when broadcast;
                // null reads back as +inf defensively
                y: v.get("y").and_then(Json::as_f64).unwrap_or(f64::INFINITY),
            }),
            Some("drift") => Ok(StrategyEvent::Drift { eval_id: id()? }),
            other => anyhow::bail!("unknown strategy event kind {other:?}"),
        }
    }
}

/// The persisted proposal state of one continuous manager shard: the
/// PCG32 stream position (full 64-bit words, hex-encoded — JSON numbers
/// are f64 and cannot carry them losslessly) plus the strategy event
/// log. The absorbed-elite dedup set and the exchange-receiver history
/// the ROADMAP calls for are both carried by the log's `Foreign` events.
#[derive(Debug, Clone, PartialEq)]
pub struct ProposalState {
    pub rng_state: u64,
    pub rng_inc: u64,
    pub log: Vec<StrategyEvent>,
    /// The continuous controller's CUSUM accumulators `(pos, neg)` at
    /// save time (hex-encoded f64 bit patterns on disk — lossless).
    /// `None` for non-controller runs and for checkpoints written
    /// before the controller existed.
    pub cusum: Option<(f64, f64)>,
}

impl ProposalState {
    // serialization lives in `parts_to_json`, which writes from borrowed
    // parts so the per-completion save path never clones the event log

    fn from_json(v: &Json) -> Result<ProposalState> {
        let hex = |key: &str| -> Result<u64> {
            let s = v
                .get(key)
                .and_then(Json::as_str)
                .with_context(|| format!("proposal state missing `{key}`"))?;
            u64::from_str_radix(s, 16)
                .with_context(|| format!("proposal state `{key}` is not a hex word: `{s}`"))
        };
        let log = v
            .get("log")
            .and_then(Json::as_arr)
            .context("proposal state missing `log`")?
            .iter()
            .map(StrategyEvent::from_json)
            .collect::<Result<_>>()?;
        // absent in pre-controller checkpoints: lenient
        let cusum = match v.get("cusum").and_then(Json::as_str) {
            Some(s) => match s.split_once(':') {
                Some((p, n)) => Some((
                    f64::from_bits(u64::from_str_radix(p, 16).with_context(|| {
                        format!("proposal state `cusum` pos is not a hex word: `{s}`")
                    })?),
                    f64::from_bits(u64::from_str_radix(n, 16).with_context(|| {
                        format!("proposal state `cusum` neg is not a hex word: `{s}`")
                    })?),
                )),
                None => anyhow::bail!("proposal state `cusum` is not `pos:neg`: `{s}`"),
            },
            None => None,
        };
        Ok(ProposalState { rng_state: hex("rng_state")?, rng_inc: hex("rng_inc")?, log, cusum })
    }
}

/// One dispatched-but-unfinished evaluation in a [`Checkpoint`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InFlightEval {
    pub eval_id: usize,
    pub config_key: String,
}

/// Content hash of a warm-start prior: same length with different
/// observations must not fingerprint-match.
fn prior_hash(prior: Option<&Vec<(Configuration, f64)>>, salt: u64) -> u64 {
    prior
        .map(|prior| {
            prior.iter().fold(0xcbf2_9ce4_8422_2325u64 ^ salt, |mut h, (c, y)| {
                for &i in c.indices() {
                    h = (h ^ i as u64).wrapping_mul(0x100_0000_01b3);
                }
                (h ^ y.to_bits()).wrapping_mul(0x100_0000_01b3)
            })
        })
        .unwrap_or(0)
}

/// Identity of a tuning run for resume-compatibility checks.
///
/// Everything that shapes what the recorded observations *mean* is
/// included: the problem (app/platform/nodes/metric, power cap, event
/// transport), the search (seed/strategy/surrogate/n_init/kappa and the
/// warm-start prior's contents), the outcome semantics (timeout
/// penalty, fault injection, straggler policy, liar imputation), the
/// async evaluation policy (worker count, in-flight batch size, and
/// the manager-cycle mode) — the lies planted for in-flight points
/// depend on how many proposals are outstanding, so resuming under a
/// different async policy would silently mix two different observation
/// streams into one surrogate — and the federation policy (shard count,
/// elite-exchange period, elite width): the shard count decides which
/// partition each manager proposes from and which global eval ids it
/// owns, and the exchange schedule decides when foreign observations
/// enter each surrogate, so resuming any shard under a different
/// federation policy would replay its history into the wrong partition.
/// Deliberately excluded are pure capacity knobs — max_evals, the
/// wall-clock budget, and node-hours — because resuming with a larger
/// budget is the normal way to continue an interrupted session — and
/// the *resolved* history warm start (`foreign_warm`): the foreign
/// observations it plants shape every proposal, so resuming against a
/// store whose contents changed must be refused.
///
/// The continuous-controller policy (controller mode, decay half-life,
/// drift threshold, authority limit) and the drifting-substrate
/// identity (drift point and magnitude) are identity too: the first
/// four shape every post-detection proposal and apply, and the last two
/// change what the recorded objectives *measured*.
pub fn fingerprint(setup: &TuneSetup) -> String {
    let warm_hash = prior_hash(setup.warm_start.as_ref(), 0);
    let fwarm_hash = prior_hash(setup.foreign_warm.as_ref(), 0x5ee3_9c1d);
    // hash the *resolved* in-flight target (0 means "worker count"), so
    // spelling the identical policy differently still resumes
    let batch_target =
        if setup.ensemble_batch == 0 { setup.ensemble_workers } else { setup.ensemble_batch };
    format!(
        "{}|{}|n{}|{}|seed{}|{:?}|{:?}|init{}|k{}|t{:?}|liar:{}|fault{}|r{}|straggle{:?}|cap{:?}|evt{}|w{}|b{}|cycle:{}|warm{:x}|fed{}:ex{}:el{}|fwarm{:x}|ctl{}:hl{}:dt{}:md{}|drift{:?}:{}",
        setup.app.name(),
        setup.platform.name(),
        setup.nodes,
        setup.metric.name(),
        setup.seed,
        setup.strategy,
        setup.surrogate,
        setup.n_init,
        setup.kappa,
        setup.eval_timeout_s,
        setup.liar.name(),
        setup.fault_rate,
        setup.max_retries,
        setup.straggler_factor,
        setup.power_cap_w,
        setup.event_transport,
        setup.ensemble_workers,
        batch_target,
        setup.manager_cycle.name(),
        warm_hash,
        setup.federation_shards,
        setup.elite_exchange_every,
        setup.federation_elites,
        fwarm_hash,
        setup.controller,
        setup.decay_half_life,
        setup.drift_threshold,
        setup.max_delta,
        setup.drift_at_eval,
        setup.drift_magnitude,
    )
}

/// Parse a `Configuration` back from an [`EvalRecord::config_key`].
pub fn config_from_key(key: &str) -> Result<Configuration> {
    let idx: std::result::Result<Vec<u32>, _> =
        key.split(',').map(|s| s.trim().parse::<u32>()).collect();
    match idx {
        Ok(v) if !v.is_empty() => Ok(Configuration::from_indices(v)),
        _ => anyhow::bail!("malformed config key `{key}`"),
    }
}

impl InFlightEval {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", self.eval_id.into()),
            ("config", self.config_key.as_str().into()),
        ])
    }
}

/// Borrowed view of a [`ProposalState`] for the hot save path: the
/// continuous manager saves after every completion and must not clone
/// its whole event log per event.
pub struct ProposalParts<'a> {
    pub rng_state: u64,
    pub rng_inc: u64,
    pub log: &'a [StrategyEvent],
    /// Controller CUSUM accumulators (`None` for non-controller runs —
    /// the key is then omitted, keeping pre-controller checkpoint bytes
    /// unchanged).
    pub cusum: Option<(f64, f64)>,
}

/// Serialize checkpoint parts without owning them — the continuous
/// manager saves after every completion, so the hot path must not clone
/// the full record vec per event.
fn parts_to_json(
    fingerprint: &str,
    wallclock_s: f64,
    records: &[EvalRecord],
    in_flight: &[InFlightEval],
    proposal: Option<ProposalParts<'_>>,
) -> Json {
    let mut pairs = vec![
        ("version", if proposal.is_some() { 3u64.into() } else { 2u64.into() }),
        ("fingerprint", fingerprint.into()),
        ("wallclock_s", wallclock_s.into()),
        ("records", Json::Arr(records.iter().map(EvalRecord::to_json_full).collect())),
        ("in_flight", Json::Arr(in_flight.iter().map(InFlightEval::to_json).collect())),
    ];
    if let Some(p) = proposal {
        let mut fields = vec![
            ("rng_state", format!("{:016x}", p.rng_state).into()),
            ("rng_inc", format!("{:016x}", p.rng_inc).into()),
            ("log", Json::Arr(p.log.iter().map(StrategyEvent::to_json).collect())),
        ];
        if let Some((pos, neg)) = p.cusum {
            // f64 bit patterns, hex: JSON numbers are f64-parsed and
            // could denormalize; the accumulators must resume exactly
            fields.push(("cusum", format!("{:016x}:{:016x}", pos.to_bits(), neg.to_bits()).into()));
        }
        pairs.push(("proposal", Json::obj(fields)));
    }
    Json::obj(pairs)
}

/// Atomic save from borrowed parts, through the blessed helper: write a
/// sibling temp file, audit it back, then rename over `path` — retried
/// under the chaos plan's budget (`plan` is also the `ckpt-write`
/// failpoint; `None` injects nothing and retries real I/O errors under
/// the default budget).
pub fn save_parts(
    path: &Path,
    fingerprint: &str,
    wallclock_s: f64,
    records: &[EvalRecord],
    in_flight: &[InFlightEval],
    proposal: Option<ProposalParts<'_>>,
    plan: Option<&crate::chaos::FaultPlan>,
) -> Result<()> {
    let text = parts_to_json(fingerprint, wallclock_s, records, in_flight, proposal).to_string();
    crate::chaos::fsx::install_atomic(path, text.as_bytes(), plan, crate::chaos::Site::CkptWrite)
        .with_context(|| format!("saving checkpoint {}", path.display()))
}

impl Checkpoint {
    pub fn to_json(&self) -> Json {
        parts_to_json(
            &self.fingerprint,
            self.wallclock_s,
            &self.records,
            &self.in_flight,
            self.proposal.as_ref().map(|p| ProposalParts {
                rng_state: p.rng_state,
                rng_inc: p.rng_inc,
                log: p.log.as_slice(),
                cusum: p.cusum,
            }),
        )
    }

    pub fn parse(text: &str) -> Result<Checkpoint> {
        let v = Json::parse(text).context("parsing ensemble checkpoint")?;
        let fingerprint = v
            .get("fingerprint")
            .and_then(Json::as_str)
            .context("checkpoint missing `fingerprint`")?
            .to_string();
        let wallclock_s = v
            .get("wallclock_s")
            .and_then(Json::as_f64)
            .context("checkpoint missing `wallclock_s`")?;
        let mut records: Vec<EvalRecord> = v
            .get("records")
            .and_then(Json::as_arr)
            .context("checkpoint missing `records`")?
            .iter()
            .map(EvalRecord::from_json_full)
            .collect::<Result<_>>()?;
        records.sort_by_key(|r| r.id);
        // absent in version-1 (generational-only) checkpoints
        let mut in_flight: Vec<InFlightEval> = match v.get("in_flight").and_then(Json::as_arr) {
            Some(arr) => arr
                .iter()
                .map(|e| {
                    let eval_id = e
                        .get("id")
                        .and_then(Json::as_u64)
                        .context("in_flight entry missing `id`")?
                        as usize;
                    let config_key = e
                        .get("config")
                        .and_then(Json::as_str)
                        .context("in_flight entry missing `config`")?
                        .to_string();
                    Ok(InFlightEval { eval_id, config_key })
                })
                .collect::<Result<_>>()?,
            None => Vec::new(),
        };
        in_flight.sort_by_key(|f| f.eval_id);
        // absent before version 3 (no persisted proposal state)
        let proposal = match v.get("proposal") {
            Some(p) => Some(ProposalState::from_json(p)?),
            None => None,
        };
        Ok(Checkpoint { fingerprint, wallclock_s, records, in_flight, proposal })
    }

    /// Load from `path`; `Ok(None)` when no checkpoint exists yet. A
    /// crash mid-install leaves an orphaned temp sibling behind — it is
    /// swept (with a warning) before the authoritative file is read, so
    /// it can neither leak forever nor be mistaken for corruption.
    pub fn load(path: &Path) -> Result<Option<Checkpoint>> {
        crate::chaos::fsx::clean_orphan_tmp(path);
        if !path.exists() {
            return Ok(None);
        }
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        Ok(Some(Self::parse(&text)?))
    }

    /// Atomic save: write a sibling temp file, audit, rename over `path`.
    pub fn save(&self, path: &Path) -> Result<()> {
        save_parts(
            path,
            &self.fingerprint,
            self.wallclock_s,
            &self.records,
            &self.in_flight,
            self.proposal.as_ref().map(|p| ProposalParts {
                rng_state: p.rng_state,
                rng_inc: p.rng_inc,
                log: p.log.as_slice(),
                cusum: p.cusum,
            }),
            None,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Measured;

    fn rec(id: usize) -> EvalRecord {
        EvalRecord {
            id,
            config_key: format!("{},{}", id, id + 1),
            config_desc: format!("threads={id}"),
            command: "aprun -n 1".into(),
            measured: Measured::runtime_only(3.0 + id as f64),
            objective: 3.0 + id as f64,
            compile_s: 2.0,
            processing_s: 40.0,
            overhead_s: 38.0,
            wallclock_s: 60.0 * (id + 1) as f64,
            best_so_far: 3.0,
            timed_out: false,
            cancelled: false,
        }
    }

    /// A crash between temp-write and rename leaves `<name>.json.tmp`
    /// behind; the next load must sweep it and read the authoritative
    /// checkpoint (or report a clean "none yet") instead of leaking the
    /// orphan or tripping over it.
    #[test]
    fn load_sweeps_orphaned_temp_siblings() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("ytopt-ckpt-orphan-{}.json", std::process::id()));
        let tmp = crate::chaos::fsx::tmp_sibling(&path);
        let _ = std::fs::remove_file(&path);
        // orphan with NO installed checkpoint: load reports none, sweeps
        // detlint: allow(io-atomic) -- planted orphan temp, not a real install
        std::fs::write(&tmp, b"{ torn half-writ").unwrap();
        assert!(Checkpoint::load(&path).unwrap().is_none());
        assert!(!tmp.exists(), "orphan survived a none-yet load");
        // orphan next to a good checkpoint: the installed file wins
        let cp = Checkpoint {
            fingerprint: "fp".into(),
            wallclock_s: 1.0,
            records: vec![rec(0)],
            in_flight: vec![],
            proposal: None,
        };
        cp.save(&path).unwrap();
        // detlint: allow(io-atomic) -- planted orphan temp, not a real install
        std::fs::write(&tmp, b"{ torn half-writ").unwrap();
        let back = Checkpoint::load(&path).unwrap().expect("checkpoint exists");
        assert_eq!(back.fingerprint, "fp");
        assert!(!tmp.exists(), "orphan survived a load");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("ytopt-ckpt-test-{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        assert!(Checkpoint::load(&path).unwrap().is_none());
        let cp = Checkpoint {
            fingerprint: "fp".into(),
            wallclock_s: 123.5,
            records: vec![rec(1), rec(0)],
            in_flight: vec![
                InFlightEval { eval_id: 3, config_key: "5,6".into() },
                InFlightEval { eval_id: 2, config_key: "4,5".into() },
            ],
            proposal: None,
        };
        cp.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap().expect("checkpoint exists");
        assert_eq!(back.fingerprint, "fp");
        assert_eq!(back.wallclock_s, 123.5);
        assert!(back.proposal.is_none());
        // records come back sorted by id
        assert_eq!(back.records.len(), 2);
        assert_eq!(back.records[0].id, 0);
        assert_eq!(back.records[1].id, 1);
        assert_eq!(back.records[1].config_key, "1,2");
        // in-flight evaluations round-trip too, sorted by id
        assert_eq!(
            back.in_flight,
            vec![
                InFlightEval { eval_id: 2, config_key: "4,5".into() },
                InFlightEval { eval_id: 3, config_key: "5,6".into() },
            ]
        );
        std::fs::remove_file(&path).unwrap();
    }

    /// The persisted proposal state round-trips losslessly: full 64-bit
    /// RNG words (beyond f64's integer range) and the event log with
    /// planted lies, applies, and foreign absorptions in order.
    #[test]
    fn proposal_state_roundtrips_bit_exactly() {
        let ps = ProposalState {
            rng_state: 0xdead_beef_cafe_f00d, // > 2^53: must survive JSON
            rng_inc: u64::MAX,
            log: vec![
                StrategyEvent::Propose {
                    eval_id: 0,
                    config_key: "1,2".into(),
                    lie: Some(3.0000000000000004),
                },
                StrategyEvent::Propose { eval_id: 3, config_key: "0,0".into(), lie: None },
                StrategyEvent::Apply { eval_id: 0 },
                StrategyEvent::Foreign { config_key: "7,7".into(), y: 0.1 + 0.2 },
                StrategyEvent::Apply { eval_id: 3 },
                StrategyEvent::Drift { eval_id: 3 },
            ],
            // bit patterns JSON number round-tripping could mangle
            cusum: Some((0.1 + 0.2, 5e-324)),
        };
        let cp = Checkpoint {
            fingerprint: "fp".into(),
            wallclock_s: 1.0,
            records: vec![rec(0)],
            in_flight: Vec::new(),
            proposal: Some(ps.clone()),
        };
        let back = Checkpoint::parse(&cp.to_json().to_string()).unwrap();
        assert_eq!(back.proposal, Some(ps));
    }

    #[test]
    fn version1_checkpoints_without_in_flight_still_parse() {
        let cp = Checkpoint {
            fingerprint: "fp".into(),
            wallclock_s: 9.0,
            records: vec![rec(0)],
            in_flight: Vec::new(),
            proposal: None,
        };
        // strip the in_flight key to simulate a pre-continuous checkpoint
        let full = cp.to_json().to_string();
        let text = full.replace("\"in_flight\":[],", "").replace(",\"in_flight\":[]", "");
        assert_ne!(text, full, "the in_flight key must actually be stripped");
        assert!(!text.contains("in_flight"));
        let back = Checkpoint::parse(&text).unwrap();
        assert_eq!(back.records.len(), 1);
        assert!(back.in_flight.is_empty());
    }

    #[test]
    fn config_key_parses_and_rejects() {
        let c = config_from_key("3,0,7").unwrap();
        assert_eq!(c.indices(), &[3, 0, 7]);
        assert!(config_from_key("").is_err());
        assert!(config_from_key("1,x").is_err());
    }

    #[test]
    fn fingerprint_distinguishes_setups() {
        use crate::apps::AppKind;
        use crate::metrics::Metric;
        use crate::platform::PlatformKind;
        let a = TuneSetup::new(AppKind::Amg, PlatformKind::Theta, 64, Metric::Runtime);
        let mut b = a.clone();
        b.seed = a.seed + 1;
        assert_ne!(fingerprint(&a), fingerprint(&b));
        // search-identity knobs all change the fingerprint
        let mut k = a.clone();
        k.kappa = 4.0;
        assert_ne!(fingerprint(&a), fingerprint(&k));
        let mut t = a.clone();
        t.eval_timeout_s = Some(60.0);
        assert_ne!(fingerprint(&a), fingerprint(&t));
        let mut l = a.clone();
        l.liar = crate::ensemble::LiarStrategy::KrigingBeliever;
        assert_ne!(fingerprint(&a), fingerprint(&l));
        let mut p = a.clone();
        p.power_cap_w = Some(200.0); // different physics
        assert_ne!(fingerprint(&a), fingerprint(&p));
        // warm-start content (not just length) is part of the identity
        let cfg = Configuration::from_indices(vec![1, 2]);
        let mut w1 = a.clone();
        w1.warm_start = Some(vec![(cfg.clone(), 5.0)]);
        let mut w2 = a.clone();
        w2.warm_start = Some(vec![(cfg, 6.0)]);
        assert_ne!(fingerprint(&w1), fingerprint(&w2));
        assert_ne!(fingerprint(&a), fingerprint(&w1));
        // the resolved history warm start is identity too (and is not
        // confusable with the preload-style warm_start prior)
        let cfg2 = Configuration::from_indices(vec![1, 2]);
        let mut h1 = a.clone();
        h1.foreign_warm = Some(vec![(cfg2.clone(), 5.0)]);
        let mut h2 = a.clone();
        h2.foreign_warm = Some(vec![(cfg2.clone(), 6.0)]);
        assert_ne!(fingerprint(&h1), fingerprint(&h2));
        assert_ne!(fingerprint(&a), fingerprint(&h1));
        let mut cross = a.clone();
        cross.warm_start = Some(vec![(cfg2, 5.0)]);
        assert_ne!(fingerprint(&h1), fingerprint(&cross), "prior kinds must not alias");
        // capacity knobs must NOT change identity
        let mut c = a.clone();
        c.max_evals += 10;
        c.wallclock_budget_s *= 2.0;
        c.node_hours_budget = Some(500.0);
        c.kill_after_evals = Some(3); // simulated-kill point is capacity too
        assert_eq!(fingerprint(&a), fingerprint(&c));
    }

    #[test]
    fn fingerprint_covers_the_async_evaluation_policy() {
        use crate::apps::AppKind;
        use crate::ensemble::{LiarStrategy, ManagerCycle};
        use crate::metrics::Metric;
        use crate::platform::PlatformKind;
        let a = TuneSetup::new(AppKind::Amg, PlatformKind::Theta, 64, Metric::Runtime);
        // worker count and in-flight batch shape the pending-lie stream
        let mut w = a.clone();
        w.ensemble_workers = 16;
        assert_ne!(fingerprint(&a), fingerprint(&w));
        let mut b = a.clone();
        b.ensemble_batch = 32;
        assert_ne!(fingerprint(&a), fingerprint(&b));
        // ...but the identical policy spelled differently (batch 0 means
        // "worker count") resolves to the same identity
        let mut e1 = a.clone();
        e1.ensemble_workers = 4;
        let mut e2 = e1.clone();
        e2.ensemble_batch = 4;
        assert_eq!(fingerprint(&e1), fingerprint(&e2));
        // manager-cycle mode changes when lies are amended
        let mut m = a.clone();
        m.manager_cycle = ManagerCycle::Generational;
        assert_ne!(fingerprint(&a), fingerprint(&m));
        // liar strategy and straggler policy were already identity
        let mut l = a.clone();
        l.liar = LiarStrategy::ConstantMax;
        assert_ne!(fingerprint(&a), fingerprint(&l));
        let mut s = a.clone();
        s.straggler_factor = Some(2.5);
        assert_ne!(fingerprint(&a), fingerprint(&s));
    }

    /// The federation policy is run identity too: the shard count picks
    /// each manager's partition and global eval ids, and the exchange
    /// schedule decides when foreign observations enter each surrogate —
    /// so cross-policy resumes must be refused.
    #[test]
    fn fingerprint_covers_the_federation_policy() {
        use crate::apps::AppKind;
        use crate::metrics::Metric;
        use crate::platform::PlatformKind;
        let a = TuneSetup::new(AppKind::Amg, PlatformKind::Theta, 64, Metric::Runtime);
        let mut k = a.clone();
        k.federation_shards = 4;
        assert_ne!(fingerprint(&a), fingerprint(&k));
        let mut k1 = a.clone();
        k1.federation_shards = 1;
        assert_ne!(fingerprint(&a), fingerprint(&k1), "K=1 federation is its own identity");
        assert_ne!(fingerprint(&k1), fingerprint(&k));
        let mut e = a.clone();
        e.elite_exchange_every = 16;
        assert_ne!(fingerprint(&a), fingerprint(&e));
        let mut n = a.clone();
        n.federation_elites = 7;
        assert_ne!(fingerprint(&a), fingerprint(&n));
        // the three knobs must not alias each other through formatting
        let mut x = a.clone();
        x.federation_shards = 2;
        x.elite_exchange_every = 3;
        x.federation_elites = 4;
        let mut y = a.clone();
        y.federation_shards = 23;
        y.elite_exchange_every = 4;
        y.federation_elites = 4;
        assert_ne!(fingerprint(&x), fingerprint(&y));
    }

    /// The continuous-controller policy and the drifting-substrate
    /// identity are both part of the fingerprint: resuming a controller
    /// campaign under different authority/detection knobs — or against
    /// a substrate that drifts differently — must be refused.
    #[test]
    fn fingerprint_covers_the_controller_policy_and_the_drifting_substrate() {
        use crate::apps::AppKind;
        use crate::metrics::Metric;
        use crate::platform::PlatformKind;
        let a = TuneSetup::new(AppKind::Amg, PlatformKind::Theta, 64, Metric::Runtime);
        let mut c = a.clone();
        c.controller = true;
        assert_ne!(fingerprint(&a), fingerprint(&c));
        let mut h = a.clone();
        h.decay_half_life = 32.0;
        assert_ne!(fingerprint(&a), fingerprint(&h));
        let mut t = a.clone();
        t.drift_threshold = 4.0;
        assert_ne!(fingerprint(&a), fingerprint(&t));
        let mut m = a.clone();
        m.max_delta = 2;
        assert_ne!(fingerprint(&a), fingerprint(&m));
        let mut d = a.clone();
        d.drift_at_eval = Some(20);
        assert_ne!(fingerprint(&a), fingerprint(&d));
        let mut g = d.clone();
        g.drift_magnitude = 0.5;
        assert_ne!(fingerprint(&d), fingerprint(&g));
    }
}
