//! Pending-point imputation for the async-BO bridge.
//!
//! While evaluations are in flight, the Bayesian optimizer must keep
//! proposing — without imputation it would re-propose the same argmin of
//! the unchanged acquisition surface (or stall waiting on stragglers).
//! Each in-flight configuration is therefore observed with a *lie* that
//! is amended to the real measurement when the worker reports back
//! (the index-keyed `BayesianOptimizer::observe_pending` /
//! `resolve_pending` pair, keyed by eval id so completions may land in
//! any order). The lie family is the classic batch
//! BO menu (Ginsbourger's constant liar and kriging believer, the same
//! options libEnsemble's persistent-gp generator exposes):
//!
//! * `cl-min`  — lie with the best (minimum) real objective so far:
//!   optimistic; spreads the batch away from the incumbent.
//! * `cl-mean` — lie with the mean real objective: neutral.
//! * `cl-max`  — lie with the worst real objective: pessimistic; allows
//!   the batch to densify near promising regions.
//! * `kriging` — believe the surrogate: lie with its posterior mean at
//!   the pending point.

use crate::search::BayesianOptimizer;
use crate::space::Configuration;
use crate::util::Pcg32;

/// How in-flight (pending) evaluations are imputed for the surrogate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LiarStrategy {
    ConstantMin,
    ConstantMean,
    ConstantMax,
    KrigingBeliever,
}

impl LiarStrategy {
    pub fn parse(s: &str) -> Option<LiarStrategy> {
        match s.to_ascii_lowercase().as_str() {
            "cl-min" | "clmin" | "min" | "constant-liar" => Some(LiarStrategy::ConstantMin),
            "cl-mean" | "clmean" | "mean" => Some(LiarStrategy::ConstantMean),
            "cl-max" | "clmax" | "max" => Some(LiarStrategy::ConstantMax),
            "kriging" | "kriging-believer" | "believer" | "kb" => {
                Some(LiarStrategy::KrigingBeliever)
            }
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            LiarStrategy::ConstantMin => "cl-min",
            LiarStrategy::ConstantMean => "cl-mean",
            LiarStrategy::ConstantMax => "cl-max",
            LiarStrategy::KrigingBeliever => "kriging",
        }
    }

    /// The imputed objective for a pending configuration.
    ///
    /// `real_ys` are the finite real measurements so far; `fallback` (the
    /// baseline objective) is used before any exist. The kriging believer
    /// consults the optimizer's surrogate and degrades to `cl-mean` when
    /// the posterior is unavailable (fewer than two observations). The
    /// optimizer is `&mut` because the believer reuses — or, on the
    /// first model use of an epoch, fits — the epoch-cached surrogate
    /// (`BayesianOptimizer::predict_mean`): on the continuous manager's
    /// per-completion path this removes the throwaway per-lie forest fit
    /// entirely.
    pub fn impute(
        &self,
        bo: Option<&mut BayesianOptimizer>,
        cfg: &Configuration,
        real_ys: &[f64],
        fallback: f64,
        rng: &mut Pcg32,
    ) -> f64 {
        let finite: Vec<f64> = real_ys.iter().copied().filter(|y| y.is_finite()).collect();
        if finite.is_empty() {
            return fallback;
        }
        let mean = finite.iter().sum::<f64>() / finite.len() as f64;
        match self {
            LiarStrategy::ConstantMin => finite.iter().copied().fold(f64::INFINITY, f64::min),
            LiarStrategy::ConstantMean => mean,
            LiarStrategy::ConstantMax => finite.iter().copied().fold(f64::NEG_INFINITY, f64::max),
            LiarStrategy::KrigingBeliever => bo
                .and_then(|b| b.predict_mean(cfg, rng))
                .filter(|m| m.is_finite())
                .unwrap_or(mean),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip_and_aliases() {
        for s in [
            LiarStrategy::ConstantMin,
            LiarStrategy::ConstantMean,
            LiarStrategy::ConstantMax,
            LiarStrategy::KrigingBeliever,
        ] {
            assert_eq!(LiarStrategy::parse(s.name()), Some(s), "{s:?}");
        }
        assert_eq!(LiarStrategy::parse("KB"), Some(LiarStrategy::KrigingBeliever));
        assert_eq!(LiarStrategy::parse("nope"), None);
    }

    #[test]
    fn constant_liars_pick_the_right_statistic() {
        let cfg = Configuration::from_indices(vec![0]);
        let mut rng = Pcg32::seeded(1);
        let ys = [3.0, 1.0, 5.0, f64::INFINITY]; // non-finite ignored
        let args = |s: LiarStrategy, rng: &mut Pcg32| s.impute(None, &cfg, &ys, 9.0, rng);
        assert_eq!(args(LiarStrategy::ConstantMin, &mut rng), 1.0);
        assert_eq!(args(LiarStrategy::ConstantMean, &mut rng), 3.0);
        assert_eq!(args(LiarStrategy::ConstantMax, &mut rng), 5.0);
        // no data at all: fall back to the baseline
        assert_eq!(LiarStrategy::ConstantMin.impute(None, &cfg, &[], 9.0, &mut rng), 9.0);
        // believer without an optimizer degrades to the mean
        assert_eq!(LiarStrategy::KrigingBeliever.impute(None, &cfg, &ys, 9.0, &mut rng), 3.0);
    }
}
