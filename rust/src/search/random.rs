//! Random-search baseline (uniform valid sampling without repetition).

use std::collections::BTreeSet;
use std::sync::Arc;

use super::SearchStrategy;
use crate::space::{ConfigSpace, Configuration};
use crate::util::Pcg32;

pub struct RandomSearch {
    space: Arc<ConfigSpace>,
    seen: BTreeSet<Configuration>,
}

impl RandomSearch {
    pub fn new(space: Arc<ConfigSpace>) -> Self {
        RandomSearch { space, seen: BTreeSet::new() }
    }
}

impl SearchStrategy for RandomSearch {
    fn propose(&mut self, rng: &mut Pcg32) -> Configuration {
        for _ in 0..2000 {
            let c = self.space.sample(rng);
            if !self.seen.contains(&c) {
                return c;
            }
        }
        self.space.sample(rng)
    }

    fn observe(&mut self, cfg: &Configuration, _objective: f64) {
        self.seen.insert(cfg.clone());
    }

    fn name(&self) -> &'static str {
        "random"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::{Param, ParamDomain};

    #[test]
    fn avoids_repeats_until_exhaustion() {
        let mut s = ConfigSpace::new("t");
        s.add(Param::new("a", ParamDomain::ordinal(&[0, 1, 2])));
        s.add(Param::new("b", ParamDomain::Toggle));
        let mut rs = RandomSearch::new(Arc::new(s));
        let mut rng = Pcg32::seeded(1);
        let mut seen = BTreeSet::new();
        for _ in 0..6 {
            let c = rs.propose(&mut rng);
            assert!(seen.insert(c.clone()));
            rs.observe(&c, 0.0);
        }
        // space exhausted: repeats now allowed rather than an infinite loop
        let _ = rs.propose(&mut rng);
    }
}
