//! Bayesian optimization with a Random-Forest surrogate and LCB
//! acquisition — the ytopt search method (paper §IV-A).
//!
//! Each iteration: fit the RF on all observations (Rust), export the
//! ensemble to the AOT tensor encoding, score a candidate batch through
//! the PJRT forest-scorer artifact (or the pure-Rust blocked lockstep
//! kernel), and propose the LCB argmin among unevaluated candidates.
//! The candidate batch mixes uniform samples (exploration) with
//! neighbourhood moves around the incumbents (exploitation
//! densification) — mirroring how skopt optimizes the acquisition over
//! discrete spaces.
//!
//! # The surrogate epoch cache
//!
//! The continuous ensemble manager proposes on *every worker
//! completion*, and the kriging believer additionally consults the
//! posterior for every in-flight lie — so the proposal path must cost
//! `O(what changed)`, not `O(everything, every time)`:
//!
//! * an **epoch counter** bumps on every observation mutation
//!   ([`BayesianOptimizer::observe`], `amend_at`, `observe_foreign`,
//!   `preload`); the fitted surrogate, its exported [`ForestTensors`],
//!   and the standardization constants are memoized per epoch, so
//!   [`BayesianOptimizer::predict_mean`] (the believer) reuses the
//!   *real* surrogate fitted by the same epoch's proposal instead of
//!   fitting a throwaway forest per completion;
//! * **fit seeds are drawn once per epoch** (one `u64` per tree, the
//!   exact stream consumption `RandomForest::fit` performs itself) on
//!   the first model use of that epoch. Cache hits and misses — and
//!   runs with the cache disabled — therefore consume the RNG stream
//!   identically, and the fit is a pure function of `(observations,
//!   epoch seeds)`: an epoch-cached run is seed-for-seed bit-identical
//!   to an uncached one (pinned by test);
//! * **running sum / sum-of-squares accumulators** maintained by the
//!   observation mutators replace the per-proposal full folds behind
//!   the objective standardization, the encoded design matrix grows
//!   incrementally (`xs_enc`), and the candidate/encode buffers are
//!   reused across proposals — no per-proposal re-encode of history and
//!   no per-proposal allocations proportional to it;
//! * the candidate pool dedups by **flat configuration index**
//!   (`u128`), not by cloning `Configuration`s into hash sets.

use std::collections::HashSet; // detlint: allow(hash-order) -- u128 membership sets below; never iterated
use std::sync::Arc;

use super::SearchStrategy;
use crate::acquisition::Acquisition;
use crate::runtime::Scorer;
use crate::space::{ConfigSpace, Configuration};
use crate::surrogate::{export_forest, ForestConfig, ForestTensors, GbrtLite, RandomForest};
use crate::util::Pcg32;

/// Surrogate family (the paper's prior work compared these; RF won).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SurrogateKind {
    RandomForest,
    ExtraTrees,
    Gbrt,
}

impl SurrogateKind {
    pub fn parse(s: &str) -> Option<SurrogateKind> {
        match s.to_ascii_lowercase().as_str() {
            "rf" | "randomforest" | "random-forest" => Some(SurrogateKind::RandomForest),
            "et" | "extratrees" | "extra-trees" => Some(SurrogateKind::ExtraTrees),
            "gbrt" => Some(SurrogateKind::Gbrt),
            _ => None,
        }
    }
}

/// Boosting stages of the GBRT-lite ablation surrogate.
const GBRT_STAGES: usize = 48;

#[derive(Clone)]
pub struct BoConfig {
    /// Random evaluations before the surrogate takes over.
    pub n_init: usize,
    /// Candidate batch size per iteration. Every scorer path — the AOT
    /// artifact and both pure-Rust kernels — consumes at most the
    /// manifest's batch width (1024) per call; larger batches loop
    /// (chunked inside `Scorer::score_candidates`).
    pub n_candidates: usize,
    /// Fraction of candidates drawn uniformly (rest are neighbours of the
    /// best observed configurations).
    pub explore_fraction: f64,
    pub acquisition: Acquisition,
    pub surrogate: SurrogateKind,
}

impl Default for BoConfig {
    fn default() -> Self {
        BoConfig {
            n_init: 8,
            n_candidates: 1024,
            explore_fraction: 0.6,
            acquisition: Acquisition::lcb_default(),
            surrogate: SurrogateKind::RandomForest,
        }
    }
}

/// Index-keyed bookkeeping for in-flight observations: maps an
/// evaluation id to the observation index holding its imputed lie, so a
/// real measurement amends exactly the observation it belongs to no
/// matter in which order completions arrive. This is what retires the
/// positional `amend_last` from the async hot path — pairing results
/// with "the most recent observations" corrupts the surrogate the
/// moment a mid-batch result lands late.
#[derive(Debug, Clone, Default)]
pub struct PendingSet {
    map: std::collections::BTreeMap<usize, usize>,
}

impl PendingSet {
    pub fn new() -> Self {
        PendingSet::default()
    }

    pub fn insert(&mut self, eval_id: usize, obs_index: usize) {
        self.map.insert(eval_id, obs_index);
    }

    /// Remove and return the observation index for `eval_id`.
    pub fn take(&mut self, eval_id: usize) -> Option<usize> {
        self.map.remove(&eval_id)
    }

    pub fn get(&self, eval_id: usize) -> Option<usize> {
        self.map.get(&eval_id).copied()
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Pending evaluation ids, ascending.
    pub fn ids(&self) -> impl Iterator<Item = usize> + '_ {
        self.map.keys().copied()
    }
}

/// The fitted surrogate of one observation epoch.
enum SurrogateModel {
    Forest(RandomForest),
    Gbrt(GbrtLite),
}

/// Everything the proposal path derives from the observation set,
/// memoized per epoch: the fitted model, its AOT tensor export, and the
/// standardization constants. Valid exactly while no observation is
/// added or amended; the epoch's fit seeds (drawn from the caller's RNG
/// stream on first model use) complete the cache identity, so a cached
/// reuse is bit-identical to an uncached refit.
struct SurrogateCache {
    epoch: u64,
    model: SurrogateModel,
    /// AOT tensor export (forest surrogates only).
    tensors: Option<ForestTensors>,
    /// Objective standardization at fit time.
    mean: f64,
    scale: f64,
}

pub struct BayesianOptimizer {
    space: Arc<ConfigSpace>,
    cfg: BoConfig,
    scorer: Arc<Scorer>,
    xs: Vec<Configuration>,
    ys: Vec<f64>,
    /// Flat configuration indices observed (own or foreign) — excluded
    /// from future proposals. Keyed by `ConfigSpace::index_of`, which is
    /// a bijection onto the flat index space, so membership is identical
    /// to configuration equality without cloning `Configuration`s.
    /// Membership-only on the hot path (PR 5); never iterated.
    // detlint: allow(hash-order) -- membership-only set; never iterated
    seen: HashSet<u128>,
    /// In-flight lies awaiting their real measurement, keyed by eval id.
    pending: PendingSet,
    /// Foreign observations absorbed (federation elite exchange).
    foreign: usize,
    /// Proposal restriction to one federation shard's partition
    /// (None = the whole space).
    shard: Option<crate::ensemble::ShardSpec>,
    /// Observation epoch: bumps on every mutation of the observation
    /// set. The surrogate cache is valid exactly for its fit epoch.
    epoch: u64,
    /// The per-tree fit seeds assigned to `epoch` on its first model
    /// use (drawn from the caller's stream exactly as the fit itself
    /// would), so every model use within one epoch — and every cached
    /// or uncached refit — sees the same seeds.
    epoch_seeds: Option<(u64, Vec<u64>)>,
    cache: Option<SurrogateCache>,
    /// When false, the fitted surrogate is never reused across calls
    /// (every model use refits from scratch with the same epoch seeds):
    /// the bit-identical "cold" pipeline the epoch cache is pinned and
    /// benchmarked against.
    cache_enabled: bool,
    /// Recency half-life (observations) for the continuous controller's
    /// decayed standardization; `None` (the default) keeps the
    /// stationary all-history pipeline bit-identical to before the
    /// controller existed.
    decay: Option<f64>,
    /// First observation index the surrogate trusts. 0 until a drift
    /// reset slides the window forward; observations before it stay
    /// recorded (indices never shift under pending amendments) but no
    /// longer enter the fit, the standardization, or the incumbents.
    window_start: usize,
    /// Running Σy / Σy² / count over the finite observations
    /// (standardization accumulators; non-finite entries are skipped so
    /// a penalty path can never poison them).
    sum_y: f64,
    sum_sq_y: f64,
    finite_ys: usize,
    /// Incrementally encoded design matrix, row-major `[n, space.dim()]`
    /// — appended once per observation instead of re-encoding the whole
    /// history on every fit.
    xs_enc: Vec<f32>,
    /// Reusable candidate-matrix / encode-row / standardized-objective
    /// buffers (no per-proposal allocations proportional to history or
    /// candidate count).
    cand_rows: Vec<f32>,
    row_buf: Vec<f32>,
    y_std: Vec<f32>,
    /// Per-fit timing (seconds) for the overhead accounting + perf bench
    /// (0.0 when the epoch cache made the fit free).
    pub last_fit_s: f64,
    pub last_score_s: f64,
    /// Observability sink (`--stats`): surrogate cache hits/misses are
    /// recorded here. Write-only — never read back into proposals.
    obs: Option<std::sync::Arc<crate::obs::ObsSink>>,
    /// Shard tag stamped on recorded events (0 unsharded).
    obs_shard: u32,
}

impl BayesianOptimizer {
    pub fn new(space: Arc<ConfigSpace>, cfg: BoConfig, scorer: Arc<Scorer>) -> Self {
        BayesianOptimizer {
            space,
            cfg,
            scorer,
            xs: Vec::new(),
            ys: Vec::new(),
            seen: HashSet::new(), // detlint: allow(hash-order) -- membership-only set; never iterated
            pending: PendingSet::new(),
            foreign: 0,
            shard: None,
            epoch: 0,
            epoch_seeds: None,
            cache: None,
            cache_enabled: true,
            decay: None,
            window_start: 0,
            sum_y: 0.0,
            sum_sq_y: 0.0,
            finite_ys: 0,
            xs_enc: Vec::new(),
            cand_rows: Vec::new(),
            row_buf: Vec::new(),
            y_std: Vec::new(),
            last_fit_s: 0.0,
            last_score_s: 0.0,
            obs: None,
            obs_shard: 0,
        }
    }

    /// Attach the observability sink (`--stats`): every surrogate model
    /// use records an epoch-cache hit or a paid fit, tagged `shard`.
    pub fn set_obs(&mut self, sink: std::sync::Arc<crate::obs::ObsSink>, shard: u32) {
        self.obs = Some(sink);
        self.obs_shard = shard;
    }

    pub fn observations(&self) -> usize {
        self.ys.len()
    }

    pub fn space(&self) -> &ConfigSpace {
        &self.space
    }

    pub fn scorer(&self) -> &Scorer {
        &self.scorer
    }

    /// The observation epoch (bumped by every observe/amend). Exposed
    /// for the cache-invariant tests and the perf bench.
    pub fn surrogate_epoch(&self) -> u64 {
        self.epoch
    }

    /// Enable/disable surrogate memoization. Disabled, every model use
    /// refits from scratch — with the same per-epoch fit seeds, so the
    /// trajectory stays bit-identical to the cached pipeline (pinned by
    /// test; the perf bench duels the two).
    pub fn set_surrogate_cache(&mut self, enabled: bool) {
        self.cache_enabled = enabled;
        if !enabled {
            self.cache = None;
        }
    }

    pub fn surrogate_cache_enabled(&self) -> bool {
        self.cache_enabled
    }

    /// Enable the continuous controller's recency decay: the objective
    /// standardization weights each windowed observation by
    /// `0.5^(age / half_life)` (age in observations, newest = 0). The
    /// weights are a pure function of the window, so cached and
    /// uncached fits stay bit-identical; with decay unset the
    /// stationary pipeline is untouched.
    pub fn set_decay(&mut self, half_life: f64) {
        if half_life.is_finite() && half_life > 0.0 {
            self.decay = Some(half_life);
            self.epoch += 1;
            self.cache = None;
        }
    }

    pub fn decay_half_life(&self) -> Option<f64> {
        self.decay
    }

    /// Slide the trust window past everything observed so far (drift
    /// detected: the old landscape is no longer evidence). Recorded
    /// observations keep their indices — pending amendments still land
    /// in their own slots — but the surrogate refits, restandardizes,
    /// and picks incumbents from post-reset observations only.
    pub fn reset_window(&mut self) {
        self.window_start = self.ys.len();
        self.epoch += 1;
        self.cache = None;
    }

    /// First observation index inside the trust window.
    pub fn window_start(&self) -> usize {
        self.window_start
    }

    /// Observations currently inside the trust window.
    pub fn windowed_len(&self) -> usize {
        self.ys.len() - self.window_start
    }

    /// Record one observation: history, accumulators, incremental design
    /// matrix, epoch bump. (Shared by `observe` and `preload`; only
    /// `observe` marks the configuration seen.)
    fn record_observation(&mut self, cfg: &Configuration, y: f64) {
        self.xs.push(cfg.clone());
        self.ys.push(y);
        if y.is_finite() {
            self.sum_y += y;
            self.sum_sq_y += y * y;
            self.finite_ys += 1;
        }
        let dim = self.space.dim();
        let start = self.xs_enc.len();
        self.xs_enc.resize(start + dim, 0.0);
        self.space.encode_into(cfg, &mut self.xs_enc[start..]);
        self.epoch += 1;
    }

    /// Rebuild the standardization accumulators from scratch (after a
    /// bulk amendment or a non-finite edit).
    fn rebuild_accumulators(&mut self) {
        self.sum_y = 0.0;
        self.sum_sq_y = 0.0;
        self.finite_ys = 0;
        for &y in &self.ys {
            if y.is_finite() {
                self.sum_y += y;
                self.sum_sq_y += y * y;
                self.finite_ys += 1;
            }
        }
    }

    /// Standardization constants from the running accumulators:
    /// mean/scale over the *finite* recorded objectives (LCB ordering is
    /// affine invariant, so these only serve numeric stability; with an
    /// all-finite history — the normal case — the finite count equals
    /// the observation count).
    fn standardization(&self) -> (f64, f64) {
        let n = self.finite_ys.max(1) as f64;
        let mean = self.sum_y / n;
        let var = (self.sum_sq_y / n - mean * mean).max(0.0);
        (mean, var.sqrt().max(1e-12))
    }

    /// Controller-mode standardization: recency-weighted mean/scale over
    /// the *windowed* finite objectives, weight `0.5^(age / half_life)`
    /// (uniform weights when only the window — not decay — is active).
    /// A deterministic O(window) fold per fit; part of the cache
    /// identity through the epoch, so cached reuse stays exact.
    fn windowed_standardization(&self) -> (f64, f64) {
        let ys = &self.ys[self.window_start..];
        let n = ys.len();
        let mut sw = 0.0f64;
        let mut swy = 0.0f64;
        let mut swyy = 0.0f64;
        for (i, &y) in ys.iter().enumerate() {
            if !y.is_finite() {
                continue;
            }
            let w = match self.decay {
                Some(hl) => 0.5f64.powf((n - 1 - i) as f64 / hl),
                None => 1.0,
            };
            sw += w;
            swy += w * y;
            swyy += w * y * y;
        }
        if sw <= 0.0 {
            return (0.0, 1e-12);
        }
        let mean = swy / sw;
        let var = (swyy / sw - mean * mean).max(0.0);
        (mean, var.sqrt().max(1e-12))
    }

    /// Replace the objectives of the last `n` observations (constant-liar
    /// batch proposals are amended with real measurements afterwards).
    ///
    /// Bounds-safe: if `n` exceeds either `ys.len()` or the number of
    /// recorded observations, the request is clamped — the *most recent*
    /// `min(n, ys.len(), observations)` entries of `ys` are applied to
    /// the most recent observations. Returns how many were amended.
    #[deprecated(
        note = "positional amendment pairs results with the most recent \
                observations and corrupts the surrogate when completions \
                arrive out of proposal order; use the index-keyed \
                `amend_at` / `observe_pending` + `resolve_pending` instead"
    )]
    pub fn amend_last(&mut self, n: usize, ys: &[f64]) -> usize {
        let n = n.min(ys.len()).min(self.ys.len());
        if n == 0 {
            return 0;
        }
        let start = self.ys.len() - n;
        self.ys[start..].copy_from_slice(&ys[ys.len() - n..]);
        self.rebuild_accumulators();
        self.epoch += 1;
        n
    }

    /// Replace one observation's objective (async-ensemble amendment of a
    /// pending-point lie with the real measurement). Returns false when
    /// `idx` is out of range instead of panicking.
    pub fn amend_at(&mut self, idx: usize, y: f64) -> bool {
        match self.ys.get_mut(idx) {
            Some(slot) => {
                let old = *slot;
                *slot = y;
                if old.is_finite() && y.is_finite() {
                    self.sum_y += y - old;
                    self.sum_sq_y += y * y - old * old;
                } else {
                    // a non-finite entry enters or leaves: recount
                    self.rebuild_accumulators();
                }
                self.epoch += 1;
                true
            }
            None => false,
        }
    }

    /// Index the next `observe` call will occupy (pending-point
    /// bookkeeping for the ensemble's async-BO bridge).
    pub fn next_index(&self) -> usize {
        self.ys.len()
    }

    /// Observe `cfg` under an imputed objective (`lie`) for the
    /// in-flight evaluation `eval_id`; the observation index is tracked
    /// in the [`PendingSet`] so [`Self::resolve_pending`] amends exactly
    /// this observation when the real measurement lands — regardless of
    /// completion order.
    pub fn observe_pending(&mut self, eval_id: usize, cfg: &Configuration, lie: f64) {
        let idx = self.next_index();
        self.observe(cfg, lie);
        self.pending.insert(eval_id, idx);
    }

    /// Amend the pending lie for `eval_id` with the real measurement.
    /// Returns false (and changes nothing) when `eval_id` has no pending
    /// observation — callers fall back to a plain `observe`.
    pub fn resolve_pending(&mut self, eval_id: usize, y: f64) -> bool {
        match self.pending.take(eval_id) {
            Some(idx) => self.amend_at(idx, y),
            None => false,
        }
    }

    /// The in-flight lies still awaiting their real measurement.
    pub fn pending(&self) -> &PendingSet {
        &self.pending
    }

    /// Record a *foreign* observation — a real measurement imported from
    /// another federation shard's history. The measurement is final (no
    /// pending entry is involved) and the configuration is marked seen,
    /// so this optimizer never proposes a duplicate of an imported
    /// point: its shard neither owns it nor needs to re-measure it.
    pub fn observe_foreign(&mut self, cfg: &Configuration, y: f64) {
        self.foreign += 1;
        self.observe(cfg, y);
    }

    /// How many foreign observations have been absorbed.
    pub fn foreign_observations(&self) -> usize {
        self.foreign
    }

    /// Whether `cfg` has been observed (own or foreign) and is therefore
    /// excluded from future proposals.
    pub fn has_seen(&self, cfg: &Configuration) -> bool {
        self.seen.contains(&self.space.index_of(cfg))
    }

    /// Restrict every future proposal to `spec`'s partition of the flat
    /// config-index space (multi-manager federation). The candidate pool
    /// is filtered by membership *before* acquisition scoring, so one
    /// surrogate fit always yields an in-shard proposal — without this,
    /// a K-shard manager would pay ~K discarded full propose pipelines
    /// (fit + score) per accepted proposal, and at large K would degrade
    /// to uniform random search once every model proposal missed.
    pub fn restrict_to_shard(&mut self, spec: crate::ensemble::ShardSpec) {
        self.shard = Some(spec);
    }

    /// Shard membership by flat index (the candidate and random paths
    /// already hold the index for the seen-set check — no second
    /// `index_of` walk).
    fn in_shard_flat(&self, flat: u128) -> bool {
        match self.shard {
            Some(s) => s.contains_index(flat),
            None => true,
        }
    }

    /// The recorded objectives (real measurements and any still-pending
    /// imputed lies), in observation order.
    pub fn objectives(&self) -> &[f64] {
        &self.ys
    }

    /// How many fit seeds one surrogate fit of the configured family
    /// draws (one per tree / boosting stage).
    fn seed_count(&self) -> usize {
        match self.cfg.surrogate {
            SurrogateKind::Gbrt => GBRT_STAGES,
            _ => self.scorer.manifest().forest.trees,
        }
    }

    /// Assign fit seeds to the current epoch on its first model use —
    /// drawing exactly what an unconditional fit would draw, so stream
    /// consumption is invariant to cache hits and to the cache being
    /// disabled (the seeds are part of the cache identity).
    fn refresh_epoch_seeds(&mut self, rng: &mut Pcg32) {
        let n = self.seed_count();
        let fresh =
            matches!(&self.epoch_seeds, Some((e, s)) if *e == self.epoch && s.len() == n);
        if !fresh {
            let seeds: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            self.epoch_seeds = Some((self.epoch, seeds));
        }
    }

    /// Make `self.cache` hold the current epoch's fitted surrogate:
    /// a no-op on a cache hit, a full fit + tensor export otherwise.
    /// Requires at least one observation.
    fn ensure_surrogate(&mut self, rng: &mut Pcg32) {
        self.refresh_epoch_seeds(rng);
        if self.cache_enabled && self.cache.as_ref().is_some_and(|c| c.epoch == self.epoch) {
            self.last_fit_s = 0.0;
            if let Some(obs) = &self.obs {
                obs.record(crate::obs::ObsEvent::SurrogateFit {
                    shard: self.obs_shard,
                    cache_hit: true,
                    fit_us: 0,
                });
            }
            return;
        }
        // detlint: allow(wall-clock) -- fit-overhead stat (last_fit_s) only; simulated time drives the trajectory
        let t0 = std::time::Instant::now();
        // controller mode (a live window reset or a decay half-life)
        // standardizes over the trust window with recency weights; the
        // stationary default keeps the accumulator-backed constants, so
        // pre-controller trajectories are bit-identical
        let (mean, scale) = if self.window_start > 0 || self.decay.is_some() {
            self.windowed_standardization()
        } else {
            self.standardization()
        };
        let dim = self.space.dim();
        let mut y_std = std::mem::take(&mut self.y_std);
        y_std.clear();
        y_std.extend(self.ys[self.window_start..].iter().map(|v| ((v - mean) / scale) as f32));
        // the trees fit on the windowed slice of the incremental design
        // matrix (the whole matrix while the window sits at 0)
        let xs_fit = &self.xs_enc[self.window_start * dim..];
        let fshape = self.scorer.manifest().forest.clone();
        let seeds = &self.epoch_seeds.as_ref().expect("seeds assigned above").1;
        let model = match self.cfg.surrogate {
            SurrogateKind::RandomForest => {
                let fc = ForestConfig { n_trees: fshape.trees, ..Default::default() };
                SurrogateModel::Forest(RandomForest::fit_with_seeds(
                    xs_fit,
                    &y_std,
                    dim,
                    &fc,
                    seeds,
                ))
            }
            SurrogateKind::ExtraTrees => {
                let fc = ForestConfig { n_trees: fshape.trees, ..ForestConfig::extra_trees() };
                SurrogateModel::Forest(RandomForest::fit_with_seeds(
                    xs_fit,
                    &y_std,
                    dim,
                    &fc,
                    seeds,
                ))
            }
            SurrogateKind::Gbrt => SurrogateModel::Gbrt(GbrtLite::fit_with_seeds(
                xs_fit,
                &y_std,
                dim,
                GBRT_STAGES,
                seeds,
            )),
        };
        let tensors = match &model {
            SurrogateModel::Forest(rf) => Some(
                export_forest(rf, fshape.trees, fshape.nodes_per_tree, fshape.features, fshape.depth)
                    .expect("forest violates AOT contract"),
            ),
            SurrogateModel::Gbrt(_) => None,
        };
        self.y_std = y_std;
        self.cache = Some(SurrogateCache { epoch: self.epoch, model, tensors, mean, scale });
        self.last_fit_s = t0.elapsed().as_secs_f64();
        if let Some(obs) = &self.obs {
            obs.record(crate::obs::ObsEvent::SurrogateFit {
                shard: self.obs_shard,
                cache_hit: false,
                fit_us: crate::obs::secs_to_us(self.last_fit_s),
            });
        }
    }

    /// Surrogate posterior mean at `cfg` in objective units — the
    /// kriging-believer imputation for in-flight points. `None` until two
    /// observations exist.
    ///
    /// Reuses the current epoch's *real* fitted surrogate (the one the
    /// same epoch's proposal scored candidates with); only when no model
    /// use has happened this epoch does it fit one — which the next
    /// proposal then reuses in turn. On the continuous manager's
    /// per-completion path this makes the believer O(tree depth) instead
    /// of O(refit the forest).
    pub fn predict_mean(&mut self, cfg: &Configuration, rng: &mut Pcg32) -> Option<f64> {
        if self.windowed_len() < 2 {
            return None;
        }
        self.ensure_surrogate(rng);
        let dim = self.space.dim();
        let mut row = std::mem::take(&mut self.row_buf);
        row.resize(dim, 0.0);
        self.space.encode_into(cfg, &mut row);
        let cache = self.cache.as_ref().expect("ensure_surrogate ran");
        let m = match &cache.model {
            SurrogateModel::Forest(rf) => rf.predict_one(&row).0,
            SurrogateModel::Gbrt(g) => g.predict_one(&row).0,
        };
        let out = m as f64 * cache.scale + cache.mean;
        self.row_buf = row;
        Some(out)
    }

    /// Posterior mean at `cfg` from the *last fitted* surrogate —
    /// whatever epoch it belongs to — in objective units. The drift
    /// detector's residual source: predicted-before-observed must come
    /// from the model that proposed the point, not from a model that
    /// has already absorbed its measurement. Consumes nothing from any
    /// RNG stream and never fits; `None` until a model use has fitted
    /// at least once.
    pub fn predict_mean_stale(&mut self, cfg: &Configuration) -> Option<f64> {
        if self.cache.is_none() {
            return None;
        }
        let dim = self.space.dim();
        let mut row = std::mem::take(&mut self.row_buf);
        row.resize(dim, 0.0);
        self.space.encode_into(cfg, &mut row);
        let cache = self.cache.as_ref().expect("checked above");
        let m = match &cache.model {
            SurrogateModel::Forest(rf) => rf.predict_one(&row).0,
            SurrogateModel::Gbrt(g) => g.predict_one(&row).0,
        };
        let out = m as f64 * cache.scale + cache.mean;
        self.row_buf = row;
        Some(out)
    }

    /// The last fitted surrogate's standardization scale (objective
    /// units) — the drift detector's residual normalizer. `None` until
    /// a fit exists.
    pub fn stale_scale(&self) -> Option<f64> {
        self.cache.as_ref().map(|c| c.scale)
    }

    /// Pre-load observations (transfer-learning warm start, §VIII).
    pub fn preload(&mut self, prior: &[(Configuration, f64)]) {
        for (c, y) in prior {
            // prior points are NOT marked seen: the target-scale run may
            // legitimately re-evaluate them
            self.record_observation(c, *y);
        }
    }

    /// Warm-start from the cross-run history database: every prior
    /// `(configuration, objective)` pair — already rescaled to this
    /// run's objective range by `history::warm_prior` — enters the
    /// surrogate through [`Self::observe_foreign`], so it is recorded
    /// *and marked seen*, exactly like a federation elite: the search
    /// starts from the transferred landscape without ever re-proposing
    /// a transferred point. Returns how many observations were absorbed.
    pub fn warm_start_from_history(&mut self, prior: &[(Configuration, f64)]) -> usize {
        for (c, y) in prior {
            self.observe_foreign(c, *y);
        }
        prior.len()
    }

    fn random_unseen(&self, rng: &mut Pcg32) -> Configuration {
        for _ in 0..2000 {
            let c = self.space.sample(rng);
            // one index_of walk serves both the seen check and the
            // shard membership test
            let flat = self.space.index_of(&c);
            if !self.seen.contains(&flat) && self.in_shard_flat(flat) {
                return c;
            }
        }
        self.space.sample(rng) // exhausted small space/shard: allow repeats
    }

    /// Candidate batch: uniform + neighbourhood moves around incumbents.
    /// Dedup is by flat configuration index (`u128`) — no
    /// `Configuration` clones enter hash sets on this path.
    fn candidates(&self, rng: &mut Pcg32) -> Vec<Configuration> {
        let n = self.cfg.n_candidates;
        let n_random = ((n as f64) * self.cfg.explore_fraction) as usize;
        let mut out: Vec<Configuration> = Vec::with_capacity(n);
        let mut dedup: HashSet<u128> = HashSet::with_capacity(n); // detlint: allow(hash-order) -- membership-only set; never iterated
        while out.len() < n_random {
            let c = self.space.sample(rng);
            let flat = self.space.index_of(&c);
            // out-of-shard draws still enter `dedup` so the exhaustion
            // bound below keeps terminating on small spaces
            if !self.seen.contains(&flat) && dedup.insert(flat) && self.in_shard_flat(flat) {
                out.push(c);
            }
            if dedup.len() + self.seen.len() >= self.space.size().min(u128::from(u64::MAX)) as usize
            {
                break;
            }
        }
        // incumbents: indices of the best observations inside the trust
        // window (the whole history while the window sits at 0).
        // `total_cmp` orders NaN objectives last instead of panicking —
        // a failed evaluation's penalty path must never poison the
        // ordering.
        let mut order: Vec<usize> = (self.window_start..self.ys.len()).collect();
        order.sort_by(|&a, &b| self.ys[a].total_cmp(&self.ys[b]));
        let top: Vec<&Configuration> = order.iter().take(5).map(|&i| &self.xs[i]).collect();
        if !top.is_empty() {
            let mut attempts = 0;
            while out.len() < n && attempts < 20 * n {
                attempts += 1;
                let base = top[rng.index(top.len())];
                // 1-3 neighbourhood steps
                let mut c = (*base).clone();
                for _ in 0..1 + rng.index(3) {
                    c = self.space.neighbor(&c, rng);
                }
                let flat = self.space.index_of(&c);
                if !self.seen.contains(&flat) && dedup.insert(flat) && self.in_shard_flat(flat) {
                    out.push(c);
                }
            }
        }
        if out.is_empty() {
            out.push(self.random_unseen(rng));
        }
        out
    }

    fn propose_by_model(&mut self, rng: &mut Pcg32) -> Configuration {
        // fit (or reuse) the epoch's surrogate; standardization comes
        // from the running accumulators
        self.ensure_surrogate(rng);
        let cands = self.candidates(rng);
        // detlint: allow(wall-clock) -- score-overhead stat (last_score_s) only; simulated time drives the trajectory
        let t1 = std::time::Instant::now();
        let fshape = self.scorer.manifest().forest.clone();
        let kappa = match self.cfg.acquisition {
            Acquisition::Lcb { kappa } => kappa as f32,
            Acquisition::Ei => 0.0, // EI computed host-side from mean/std
        };
        let f = fshape.features;
        let mut rows = std::mem::take(&mut self.cand_rows);
        rows.resize(cands.len() * f, 0.0);
        for (i, c) in cands.iter().enumerate() {
            // encode_into zero-pads the tail, so buffer reuse never
            // leaks a previous proposal's rows
            self.space.encode_into(c, &mut rows[i * f..(i + 1) * f]);
        }
        let cache = self.cache.as_ref().expect("ensure_surrogate ran");
        let (mu, sc) = (cache.mean, cache.scale);
        let (mean_v, std_v): (Vec<f32>, Vec<f32>) = match (&cache.model, &cache.tensors) {
            (SurrogateModel::Forest(_), Some(tensors)) => {
                let out = self
                    .scorer
                    .score_candidates(&rows, cands.len(), tensors, kappa)
                    .expect("scorer failed");
                (out.mean, out.std)
            }
            (SurrogateModel::Gbrt(g), _) => {
                let gd = g.dim;
                let mut m = Vec::with_capacity(cands.len());
                let mut s = Vec::with_capacity(cands.len());
                for i in 0..cands.len() {
                    let (mm, ss) = g.predict_one(&rows[i * f..i * f + gd]);
                    m.push(mm);
                    s.push(ss);
                }
                (m, s)
            }
            (SurrogateModel::Forest(_), None) => {
                unreachable!("forest surrogates always cache exported tensors")
            }
        };
        self.last_score_s = t1.elapsed().as_secs_f64();
        self.cand_rows = rows;

        let fmin = self.ys[self.window_start..].iter().cloned().fold(f64::INFINITY, f64::min);
        let fmin_norm = (fmin - mu) / sc;
        let scores = self.cfg.acquisition.score(&mean_v, &std_v, fmin_norm);
        let best = crate::util::stats::argmin(&scores).unwrap_or(0);
        cands[best].clone()
    }
}

impl SearchStrategy for BayesianOptimizer {
    fn propose(&mut self, rng: &mut Pcg32) -> Configuration {
        // the init gate counts windowed observations: after a drift
        // reset the search re-seeds the fresh landscape with random
        // draws exactly as it bootstrapped the original one
        let n = self.windowed_len();
        let c = if n < self.cfg.n_init || n < 2 {
            self.random_unseen(rng)
        } else {
            self.propose_by_model(rng)
        };
        c
    }

    fn observe(&mut self, cfg: &Configuration, objective: f64) {
        self.record_observation(cfg, objective);
        self.seen.insert(self.space.index_of(cfg));
    }

    fn name(&self) -> &'static str {
        match self.cfg.surrogate {
            SurrogateKind::RandomForest => "bo-rf",
            SurrogateKind::ExtraTrees => "bo-et",
            SurrogateKind::Gbrt => "bo-gbrt",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ensemble::LiarStrategy;
    use crate::space::{Param, ParamDomain};

    /// Synthetic objective with a unique optimum the BO should find much
    /// faster than random search.
    fn toy_space() -> Arc<ConfigSpace> {
        let mut s = ConfigSpace::new("toy");
        for name in ["a", "b", "c", "d"] {
            s.add(Param::new(name, ParamDomain::ordinal(&[0, 1, 2, 3, 4, 5, 6, 7])));
        }
        Arc::new(s)
    }

    fn objective(space: &ConfigSpace, c: &Configuration) -> f64 {
        // bowl centred at (5,2,7,1)
        let t = [5.0, 2.0, 7.0, 1.0];
        ["a", "b", "c", "d"]
            .iter()
            .zip(t.iter())
            .map(|(n, t)| {
                let v = space.int_value(c, n) as f64;
                (v - t) * (v - t)
            })
            .sum()
    }

    fn run_strategy(mut s: impl SearchStrategy, space: &ConfigSpace, evals: usize, seed: u64) -> f64 {
        let mut rng = Pcg32::seeded(seed);
        let mut best = f64::INFINITY;
        for _ in 0..evals {
            let c = s.propose(&mut rng);
            let y = objective(space, &c);
            best = best.min(y);
            s.observe(&c, y);
        }
        best
    }

    #[test]
    fn bo_beats_random_on_average() {
        let space = toy_space();
        let mut bo_wins = 0;
        for seed in 0..5 {
            let bo = BayesianOptimizer::new(
                space.clone(),
                BoConfig { n_candidates: 256, ..Default::default() },
                Arc::new(Scorer::fallback()),
            );
            let bo_best = run_strategy(bo, &space, 40, seed);
            let rs = crate::search::RandomSearch::new(space.clone());
            let rs_best = run_strategy(rs, &space, 40, seed);
            if bo_best <= rs_best {
                bo_wins += 1;
            }
        }
        assert!(bo_wins >= 3, "BO won only {bo_wins}/5 against random");
    }

    #[test]
    fn bo_finds_near_optimum_quickly() {
        let space = toy_space();
        let bo = BayesianOptimizer::new(
            space.clone(),
            BoConfig { n_candidates: 512, ..Default::default() },
            Arc::new(Scorer::fallback()),
        );
        let best = run_strategy(bo, &space, 60, 7);
        assert!(best <= 3.0, "BO best {best} after 60/4096 evals");
    }

    #[test]
    fn bo_does_not_repeat_evaluations() {
        let space = toy_space();
        let mut bo = BayesianOptimizer::new(space.clone(), BoConfig::default(), Arc::new(Scorer::fallback()));
        let mut rng = Pcg32::seeded(3);
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..50 {
            let c = bo.propose(&mut rng);
            assert!(seen.insert(c.clone()), "repeated proposal {c:?}");
            bo.observe(&c, objective(&space, &c));
        }
    }

    #[test]
    fn ei_acquisition_also_works() {
        let space = toy_space();
        let bo = BayesianOptimizer::new(
            space.clone(),
            BoConfig {
                acquisition: Acquisition::Ei,
                n_candidates: 256,
                ..Default::default()
            },
            Arc::new(Scorer::fallback()),
        );
        let best = run_strategy(bo, &space, 50, 11);
        assert!(best <= 6.0, "EI best {best}");
    }

    #[test]
    #[allow(deprecated)] // pinning the legacy helper's clamping contract
    fn amend_last_clamps_out_of_range() {
        let space = toy_space();
        let mut bo =
            BayesianOptimizer::new(space.clone(), BoConfig::default(), Arc::new(Scorer::fallback()));
        // empty optimizer: nothing to amend, and no panic
        assert_eq!(bo.amend_last(3, &[1.0, 2.0, 3.0]), 0);
        let mut rng = Pcg32::seeded(21);
        for y in [1.0, 2.0, 3.0] {
            let c = bo.propose(&mut rng);
            bo.observe(&c, y);
        }
        // n exceeds the recorded observations: clamped to 3, applying the
        // most recent entries of ys
        assert_eq!(bo.amend_last(5, &[9.0, 8.0, 7.0, 6.0, 5.0]), 3);
        assert_eq!(bo.objectives(), &[7.0, 6.0, 5.0]);
        // n exceeds ys.len(): clamped to the provided values
        assert_eq!(bo.amend_last(3, &[4.0]), 1);
        assert_eq!(bo.objectives(), &[7.0, 6.0, 4.0]);
        // the normal in-bounds path still amends exactly the tail
        assert_eq!(bo.amend_last(2, &[1.5, 2.5]), 2);
        assert_eq!(bo.objectives(), &[7.0, 1.5, 2.5]);
    }

    /// Regression for the out-of-order amendment corruption: a batch of
    /// pending lies completed in *reverse* order must still land each
    /// measurement in its own observation slot. (The retired positional
    /// `amend_last` would have overwritten the wrong entries here.)
    #[test]
    fn out_of_order_completions_amend_their_own_observations() {
        let space = toy_space();
        let mut bo =
            BayesianOptimizer::new(space.clone(), BoConfig::default(), Arc::new(Scorer::fallback()));
        let mut rng = Pcg32::seeded(31);
        for id in 0..3usize {
            let c = bo.propose(&mut rng);
            bo.observe_pending(id, &c, 100.0);
        }
        assert_eq!(bo.pending().len(), 3);
        assert_eq!(bo.pending().ids().collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(bo.pending().get(1), Some(1));
        // completions land in reverse order; ys[i] must hold its own value
        for (id, y) in [(2usize, 12.0), (1, 11.0), (0, 10.0)] {
            assert!(bo.resolve_pending(id, y));
        }
        assert_eq!(bo.objectives(), &[10.0, 11.0, 12.0]);
        assert!(bo.pending().is_empty());
        // double-resolve and unknown ids are inert
        assert!(!bo.resolve_pending(0, 9.0));
        assert!(!bo.resolve_pending(7, 9.0));
        assert_eq!(bo.objectives(), &[10.0, 11.0, 12.0]);
    }

    /// A shard-restricted optimizer (federation) proposes only inside
    /// its partition — through both the random warm-up path and the
    /// model-driven candidate path — with a single fit per proposal.
    #[test]
    fn shard_restricted_proposals_stay_in_the_partition() {
        use crate::ensemble::ShardSpec;
        let space = toy_space();
        let mut bo = BayesianOptimizer::new(
            space.clone(),
            BoConfig { n_candidates: 128, ..Default::default() },
            Arc::new(Scorer::fallback()),
        );
        let spec = ShardSpec { seed: 9, shards: 4, shard: 2 };
        bo.restrict_to_shard(spec);
        let mut rng = Pcg32::seeded(77);
        for i in 0..40 {
            let c = bo.propose(&mut rng);
            assert!(spec.contains(&space, &c), "proposal {i} left shard 2's partition");
            bo.observe(&c, objective(&space, &c));
        }
    }

    /// Foreign observations (federation elite exchange) enter the
    /// surrogate as real measurements and are never proposed again —
    /// even while pending lies are outstanding.
    #[test]
    fn foreign_observations_are_recorded_and_never_proposed() {
        let space = toy_space();
        let mut bo = BayesianOptimizer::new(
            space.clone(),
            BoConfig { n_candidates: 256, ..Default::default() },
            Arc::new(Scorer::fallback()),
        );
        let mut rng = Pcg32::seeded(41);
        // plant a pending lie first: a foreign observe must not disturb
        // the index-keyed amendment
        let inflight = bo.propose(&mut rng);
        bo.observe_pending(0, &inflight, 100.0);
        let foreign = space.config_at(17);
        assert!(!bo.has_seen(&foreign));
        bo.observe_foreign(&foreign, 2.5);
        assert_eq!(bo.foreign_observations(), 1);
        assert!(bo.has_seen(&foreign));
        assert_eq!(bo.objectives(), &[100.0, 2.5]);
        // the pending lie still amends its own slot
        assert!(bo.resolve_pending(0, 7.0));
        assert_eq!(bo.objectives(), &[7.0, 2.5]);
        // the foreign point is excluded from every future proposal
        for _ in 0..60 {
            let c = bo.propose(&mut rng);
            assert_ne!(c, foreign, "foreign elite was re-proposed");
            bo.observe(&c, objective(&space, &c));
        }
    }

    /// History warm starts enter through the foreign-observation path:
    /// recorded, marked seen, never re-proposed — and the surrogate
    /// actually uses the transferred landscape (it proposes near the
    /// transferred optimum's neighbourhood once the model activates).
    #[test]
    fn history_warm_start_is_recorded_and_never_reproposed() {
        let space = toy_space();
        let mut bo = BayesianOptimizer::new(
            space.clone(),
            BoConfig { n_candidates: 256, ..Default::default() },
            Arc::new(Scorer::fallback()),
        );
        let prior: Vec<(Configuration, f64)> = (0..6u128)
            .map(|i| {
                let c = space.config_at(i * 7);
                let y = objective(&space, &c);
                (c, y)
            })
            .collect();
        assert_eq!(bo.warm_start_from_history(&prior), 6);
        assert_eq!(bo.observations(), 6);
        assert_eq!(bo.foreign_observations(), 6);
        let mut rng = Pcg32::seeded(51);
        for _ in 0..40 {
            let c = bo.propose(&mut rng);
            for (p, _) in &prior {
                assert_ne!(&c, p, "warm-started observation was re-proposed");
            }
            bo.observe(&c, objective(&space, &c));
        }
    }

    #[test]
    fn amend_at_is_bounds_safe() {
        let space = toy_space();
        let mut bo =
            BayesianOptimizer::new(space.clone(), BoConfig::default(), Arc::new(Scorer::fallback()));
        let mut rng = Pcg32::seeded(22);
        assert_eq!(bo.next_index(), 0);
        let c = bo.propose(&mut rng);
        bo.observe(&c, 10.0);
        assert_eq!(bo.next_index(), 1);
        assert!(bo.amend_at(0, 4.0));
        assert!(!bo.amend_at(1, 4.0));
        assert_eq!(bo.objectives(), &[4.0]);
    }

    #[test]
    fn predict_mean_tracks_the_landscape() {
        let space = toy_space();
        let mut bo =
            BayesianOptimizer::new(space.clone(), BoConfig::default(), Arc::new(Scorer::fallback()));
        let mut rng = Pcg32::seeded(23);
        let probe = space.sample(&mut rng.clone());
        assert!(bo.predict_mean(&probe, &mut rng).is_none(), "no data yet");
        for _ in 0..40 {
            let c = bo.propose(&mut rng);
            let y = objective(&space, &c);
            bo.observe(&c, y);
        }
        // the believer's mean should land inside the observed range
        let lo = bo.objectives().iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = bo.objectives().iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let m = bo.predict_mean(&probe, &mut rng).unwrap();
        assert!(m >= lo - 10.0 && m <= hi + 10.0, "believer mean {m} outside [{lo}, {hi}]");
    }

    #[test]
    fn alternative_surrogates_work() {
        let space = toy_space();
        for kind in [SurrogateKind::ExtraTrees, SurrogateKind::Gbrt] {
            let bo = BayesianOptimizer::new(
                space.clone(),
                BoConfig { surrogate: kind, n_candidates: 256, ..Default::default() },
                Arc::new(Scorer::fallback()),
            );
            let best = run_strategy(bo, &space, 50, 13);
            assert!(best <= 8.0, "{kind:?} best {best}");
        }
    }

    /// Satellite regression: a NaN objective (a failed-eval penalty path
    /// can produce one) must never panic the proposal pipeline — the
    /// incumbent ordering in `candidates()` used `partial_cmp().unwrap()`
    /// and blew up here before the `total_cmp` fix.
    #[test]
    fn nan_objectives_never_panic_the_proposal_path() {
        let space = toy_space();
        let mut bo = BayesianOptimizer::new(
            space.clone(),
            BoConfig { n_candidates: 128, ..Default::default() },
            Arc::new(Scorer::fallback()),
        );
        let mut rng = Pcg32::seeded(44);
        for i in 0..10 {
            let c = bo.propose(&mut rng);
            let y = if i == 3 { f64::NAN } else { objective(&space, &c) };
            bo.observe(&c, y);
        }
        // model-driven proposals over the NaN-poisoned history
        for _ in 0..5 {
            let c = bo.propose(&mut rng);
            bo.observe(&c, objective(&space, &c));
        }
        // amending the NaN away (and to NaN again) keeps the
        // accumulators coherent and the pipeline alive
        assert!(bo.amend_at(3, 2.0));
        assert!(bo.amend_at(5, f64::NAN));
        let c = bo.propose(&mut rng);
        assert!(space.is_valid(&c));
        let (mean, scale) = bo.standardization();
        assert!(mean.is_finite() && scale.is_finite(), "accumulators poisoned: {mean}/{scale}");
    }

    /// The tentpole's determinism pin: the epoch-cached + blocked(-par)
    /// pipeline must equal the uncached + scalar pipeline float for
    /// float — proposals, believer imputations, amended objectives, and
    /// the RNG stream position — across a full async-style drive with
    /// out-of-order completions.
    #[test]
    fn epoch_cached_blocked_pipeline_matches_uncached_scalar_bit_for_bit() {
        let space = toy_space();
        let build = |cached: bool| {
            let scorer =
                if cached { Scorer::fallback() } else { Scorer::fallback_scalar() };
            let mut bo = BayesianOptimizer::new(
                space.clone(),
                BoConfig { n_candidates: 192, n_init: 4, ..Default::default() },
                Arc::new(scorer),
            );
            bo.set_surrogate_cache(cached);
            bo
        };
        let mut a = build(true);
        let mut b = build(false);
        assert!(a.surrogate_cache_enabled() && !b.surrogate_cache_enabled());
        let mut ra = Pcg32::seeded(91);
        let mut rb = Pcg32::seeded(91);
        let mut reals: Vec<f64> = Vec::new();
        let mut inflight: std::collections::VecDeque<(usize, Configuration)> =
            std::collections::VecDeque::new();
        for id in 0..24usize {
            let ca = a.propose(&mut ra);
            let cb = b.propose(&mut rb);
            assert_eq!(ca, cb, "proposal {id} diverged");
            // the believer consults the surrogate: cached reuse vs
            // uncached refit must impute the identical lie
            let lie_a =
                LiarStrategy::KrigingBeliever.impute(Some(&mut a), &ca, &reals, 100.0, &mut ra);
            let lie_b =
                LiarStrategy::KrigingBeliever.impute(Some(&mut b), &cb, &reals, 100.0, &mut rb);
            assert_eq!(lie_a.to_bits(), lie_b.to_bits(), "believer lie {id} diverged");
            a.observe_pending(id, &ca, lie_a);
            b.observe_pending(id, &cb, lie_b);
            inflight.push_back((id, ca));
            // resolve completions out of proposal order (newest first
            // every other step) to exercise the amend path
            if inflight.len() >= 3 {
                let (rid, cfg) = if id % 2 == 0 {
                    inflight.pop_back().unwrap()
                } else {
                    inflight.pop_front().unwrap()
                };
                let y = objective(&space, &cfg);
                assert!(a.resolve_pending(rid, y));
                assert!(b.resolve_pending(rid, y));
                reals.push(y);
            }
        }
        assert_eq!(a.objectives(), b.objectives());
        assert_eq!(ra.state(), rb.state(), "RNG streams desynced");
        // and the believer itself agrees bit for bit at the end
        let probe = space.config_at(99);
        let ma = a.predict_mean(&probe, &mut ra).unwrap();
        let mb = b.predict_mean(&probe, &mut rb).unwrap();
        assert_eq!(ma.to_bits(), mb.to_bits());
    }

    /// Believer reuse is O(tree depth): after a model proposal, the same
    /// epoch's `predict_mean` consumes nothing from the stream and
    /// returns a stable value; an epoch bump invalidates the cache and
    /// draws fresh fit seeds.
    #[test]
    fn believer_reuses_the_epoch_surrogate_without_stream_draws() {
        let space = toy_space();
        let mut bo = BayesianOptimizer::new(
            space.clone(),
            BoConfig { n_candidates: 128, ..Default::default() },
            Arc::new(Scorer::fallback()),
        );
        let mut rng = Pcg32::seeded(5);
        for _ in 0..10 {
            let c = bo.propose(&mut rng);
            bo.observe(&c, objective(&space, &c));
        }
        let epoch = bo.surrogate_epoch();
        let c = bo.propose(&mut rng); // model path: fits this epoch's surrogate
        assert_eq!(bo.surrogate_epoch(), epoch, "propose must not bump the epoch");
        let s0 = rng.state();
        let m1 = bo.predict_mean(&c, &mut rng).unwrap();
        assert_eq!(rng.state(), s0, "fresh-epoch believer drew from the stream");
        let m2 = bo.predict_mean(&c, &mut rng).unwrap();
        assert_eq!(m1.to_bits(), m2.to_bits(), "believer must be stable within an epoch");
        assert_eq!(bo.last_fit_s, 0.0, "cache hit must record a zero fit time");
        bo.observe(&c, objective(&space, &c)); // epoch bump
        assert_eq!(bo.surrogate_epoch(), epoch + 1);
        let _ = bo.predict_mean(&c, &mut rng);
        assert_ne!(rng.state(), s0, "stale epoch must draw fresh fit seeds");
    }

    /// Controller-mode determinism pin: with a decay half-life set, the
    /// epoch-cached pipeline must still equal the uncached one float for
    /// float — the recency weights are part of the fit's pure identity,
    /// never a cache side-channel.
    #[test]
    fn decay_mode_cached_pipeline_matches_uncached_bit_for_bit() {
        let space = toy_space();
        let build = |cached: bool| {
            let scorer = if cached { Scorer::fallback() } else { Scorer::fallback_scalar() };
            let mut bo = BayesianOptimizer::new(
                space.clone(),
                BoConfig { n_candidates: 128, n_init: 4, ..Default::default() },
                Arc::new(scorer),
            );
            bo.set_surrogate_cache(cached);
            bo.set_decay(6.0);
            bo
        };
        let mut a = build(true);
        let mut b = build(false);
        assert_eq!(a.decay_half_life(), Some(6.0));
        let mut ra = Pcg32::seeded(135);
        let mut rb = Pcg32::seeded(135);
        for i in 0..20usize {
            let ca = a.propose(&mut ra);
            let cb = b.propose(&mut rb);
            assert_eq!(ca, cb, "decay-mode proposal {i} diverged");
            let y = objective(&space, &ca);
            a.observe(&ca, y);
            b.observe(&cb, y);
            // a mid-run window reset must stay in lockstep too
            if i == 12 {
                a.reset_window();
                b.reset_window();
            }
        }
        assert_eq!(ra.state(), rb.state(), "RNG streams desynced under decay");
        let probe = space.config_at(33);
        let (ma, mb) = (a.predict_mean(&probe, &mut ra), b.predict_mean(&probe, &mut rb));
        assert_eq!(ma.unwrap().to_bits(), mb.unwrap().to_bits());
    }

    /// A window reset forgets the stale landscape: the init gate
    /// re-opens (random re-seeding), incumbents come from post-reset
    /// observations only, and pending lies planted before the reset
    /// still amend their own (now untrusted) slots.
    #[test]
    fn window_reset_restarts_the_search_on_fresh_observations() {
        let space = toy_space();
        let mut bo = BayesianOptimizer::new(
            space.clone(),
            BoConfig { n_candidates: 128, n_init: 4, ..Default::default() },
            Arc::new(Scorer::fallback()),
        );
        let mut rng = Pcg32::seeded(61);
        for _ in 0..10 {
            let c = bo.propose(&mut rng);
            bo.observe(&c, objective(&space, &c));
        }
        let pre = bo.propose(&mut rng);
        bo.observe_pending(99, &pre, 50.0);
        assert_eq!(bo.windowed_len(), 11);
        bo.reset_window();
        assert_eq!(bo.window_start(), 11);
        assert_eq!(bo.windowed_len(), 0);
        assert_eq!(bo.observations(), 11, "reset must not discard recorded history");
        // the pre-reset pending lie still lands in its own slot
        assert!(bo.resolve_pending(99, 42.0));
        assert_eq!(bo.objectives()[10], 42.0);
        // post-reset proposals random-seed the fresh window, then the
        // model path takes over once n_init windowed observations exist
        for _ in 0..6 {
            let c = bo.propose(&mut rng);
            bo.observe(&c, objective(&space, &c) + 1000.0); // shifted world
        }
        assert!(bo.windowed_len() >= 4);
        let probe = space.config_at(7);
        let m = bo.predict_mean(&probe, &mut rng).unwrap();
        assert!(m > 500.0, "post-reset surrogate still averages the old world: {m}");
    }

    /// The drift detector's residual source: `predict_mean_stale` reuses
    /// the last fitted surrogate without fitting, without touching any
    /// RNG stream, and without seeing observations recorded after that
    /// fit.
    #[test]
    fn predict_mean_stale_reuses_the_last_fit_without_stream_draws() {
        let space = toy_space();
        let mut bo = BayesianOptimizer::new(
            space.clone(),
            BoConfig { n_candidates: 128, ..Default::default() },
            Arc::new(Scorer::fallback()),
        );
        assert!(bo.predict_mean_stale(&space.config_at(3)).is_none(), "no fit yet");
        assert!(bo.stale_scale().is_none());
        let mut rng = Pcg32::seeded(71);
        for _ in 0..10 {
            let c = bo.propose(&mut rng);
            bo.observe(&c, objective(&space, &c));
        }
        let c = bo.propose(&mut rng); // fits this epoch's surrogate
        let probe = space.config_at(17);
        let fresh = bo.predict_mean(&probe, &mut rng).unwrap();
        let s0 = rng.state();
        let stale = bo.predict_mean_stale(&probe).unwrap();
        assert_eq!(rng.state(), s0, "stale predictor has no RNG stream to draw from");
        assert_eq!(stale.to_bits(), fresh.to_bits(), "same epoch: stale == fresh");
        assert!(bo.stale_scale().unwrap() > 0.0);
        // new observations do NOT move the stale prediction (that is the
        // point: predicted-before-observed)
        bo.observe(&c, objective(&space, &c) + 500.0);
        let still = bo.predict_mean_stale(&probe).unwrap();
        assert_eq!(still.to_bits(), stale.to_bits(), "stale predictor refit behind our back");
    }
}
