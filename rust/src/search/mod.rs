//! Search strategies over a [`ConfigSpace`]: the Bayesian-optimization
//! loop (the paper's method) plus random and grid baselines. The
//! transfer-learning warm start (paper §VIII future work) lives in
//! [`crate::history`] now; [`transfer`] keeps a deprecated shim.

pub mod bo;
pub mod grid;
pub mod mctree;
pub mod random;
pub mod transfer;

pub use bo::{BoConfig, BayesianOptimizer, PendingSet, SurrogateKind};
pub use grid::GridSearch;
pub use mctree::McTreeSearch;
pub use random::RandomSearch;
#[allow(deprecated)]
pub use transfer::warm_start;

use crate::space::Configuration;
use crate::util::Pcg32;

/// A sequential search strategy: propose, evaluate (externally), observe.
pub trait SearchStrategy {
    /// Next configuration to evaluate. Strategies avoid re-proposing
    /// already-observed points while the space allows it.
    fn propose(&mut self, rng: &mut Pcg32) -> Configuration;

    /// Feed back the measured objective (lower is better).
    fn observe(&mut self, cfg: &Configuration, objective: f64);

    /// Strategy name (database/bench labels).
    fn name(&self) -> &'static str;
}

/// Which strategy to construct (CLI / config selection).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StrategyKind {
    Bo,
    Random,
    Grid,
    /// Monte-Carlo tree search (the mctree/ProTuner family, §II).
    Mctree,
}

impl StrategyKind {
    pub fn parse(s: &str) -> Option<StrategyKind> {
        match s.to_ascii_lowercase().as_str() {
            "bo" | "bayesian" | "ytopt" => Some(StrategyKind::Bo),
            "random" => Some(StrategyKind::Random),
            "grid" => Some(StrategyKind::Grid),
            "mctree" | "mcts" => Some(StrategyKind::Mctree),
            _ => None,
        }
    }
}
