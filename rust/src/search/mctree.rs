//! Monte-Carlo tree search over the parameter space.
//!
//! The paper's background (§II) contrasts vector-space search (ytopt)
//! with tree-space search (mctree [47][48], ProTuner [45], Telamon
//! [51]): every level of the tree fixes one parameter, leaves are
//! complete configurations, and UCT balances exploration/exploitation
//! down the tree. Implemented here as an alternative strategy so the
//! paper's framing can be tested empirically (benches/perf.rs ablation).
//!
//! Minimization: rewards are negated objectives normalized online.

use std::collections::BTreeMap;
use std::sync::Arc;

use super::SearchStrategy;
use crate::space::{ConfigSpace, Configuration};
use crate::util::Pcg32;

#[derive(Debug, Default, Clone)]
struct NodeStats {
    visits: u64,
    total_reward: f64,
}

pub struct McTreeSearch {
    space: Arc<ConfigSpace>,
    /// UCT exploration constant.
    c: f64,
    /// Stats per (depth, partial-assignment-key, value-index). Ordered
    /// map: the table is keyed, never iterated today, but a BTreeMap
    /// keeps any future iteration (debug dumps, serialization)
    /// deterministic by construction.
    stats: BTreeMap<(usize, String, u32), NodeStats>,
    /// Online objective normalization.
    obs_min: f64,
    obs_max: f64,
    /// Pending proposal path (filled by propose, consumed by observe).
    last_path: Option<Configuration>,
}

impl McTreeSearch {
    pub fn new(space: Arc<ConfigSpace>) -> Self {
        McTreeSearch {
            space,
            c: std::f64::consts::SQRT_2,
            stats: BTreeMap::new(),
            obs_min: f64::INFINITY,
            obs_max: f64::NEG_INFINITY,
            last_path: None,
        }
    }

    fn key(prefix: &[u32]) -> String {
        prefix.iter().map(|i| i.to_string()).collect::<Vec<_>>().join(",")
    }

    /// UCT selection down the parameter levels; unvisited values win ties
    /// (forced exploration), the rest of the path is a random rollout.
    fn select_path(&self, rng: &mut Pcg32) -> Configuration {
        let mut prefix: Vec<u32> = Vec::with_capacity(self.space.dim());
        for (depth, p) in self.space.params().iter().enumerate() {
            let key = Self::key(&prefix);
            let card = p.domain.cardinality();
            let parent_visits: u64 = (0..card)
                .map(|v| {
                    self.stats
                        .get(&(depth, key.clone(), v as u32))
                        .map(|s| s.visits)
                        .unwrap_or(0)
                })
                .sum();
            if parent_visits == 0 {
                // untouched subtree: random rollout from here
                prefix.push(rng.index(card) as u32);
                continue;
            }
            let mut best_v = 0u32;
            let mut best_score = f64::NEG_INFINITY;
            for v in 0..card {
                let s = self.stats.get(&(depth, key.clone(), v as u32));
                let score = match s {
                    None | Some(NodeStats { visits: 0, .. }) => {
                        // unvisited arm: infinite UCT, randomized tiebreak
                        f64::INFINITY - rng.f64()
                    }
                    Some(s) => {
                        s.total_reward / s.visits as f64
                            + self.c
                                * ((parent_visits as f64).ln() / s.visits as f64).sqrt()
                    }
                };
                if score > best_score {
                    best_score = score;
                    best_v = v as u32;
                }
            }
            prefix.push(best_v);
        }
        Configuration::from_indices(prefix)
    }

    fn backprop(&mut self, cfg: &Configuration, reward: f64) {
        let idx = cfg.indices();
        for depth in 0..idx.len() {
            let key = Self::key(&idx[..depth]);
            let e = self.stats.entry((depth, key, idx[depth])).or_default();
            e.visits += 1;
            e.total_reward += reward;
        }
    }
}

impl SearchStrategy for McTreeSearch {
    fn propose(&mut self, rng: &mut Pcg32) -> Configuration {
        // re-sample until valid (constraints are rare in the paper spaces)
        for _ in 0..100 {
            let c = self.select_path(rng);
            if self.space.is_valid(&c) {
                self.last_path = Some(c.clone());
                return c;
            }
        }
        self.space.sample(rng)
    }

    fn observe(&mut self, cfg: &Configuration, objective: f64) {
        self.obs_min = self.obs_min.min(objective);
        self.obs_max = self.obs_max.max(objective);
        let span = (self.obs_max - self.obs_min).max(1e-12);
        // reward in [0, 1], higher = better (lower objective)
        let reward = (self.obs_max - objective) / span;
        self.backprop(cfg, reward);
        self.last_path = None;
    }

    fn name(&self) -> &'static str {
        "mctree"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::{Param, ParamDomain};

    fn toy_space() -> Arc<ConfigSpace> {
        let mut s = ConfigSpace::new("toy");
        for name in ["a", "b", "c"] {
            s.add(Param::new(name, ParamDomain::ordinal(&[0, 1, 2, 3, 4, 5])));
        }
        Arc::new(s)
    }

    fn objective(space: &ConfigSpace, c: &Configuration) -> f64 {
        let t = [4.0, 1.0, 3.0];
        ["a", "b", "c"]
            .iter()
            .zip(t.iter())
            .map(|(n, t)| {
                let v = space.int_value(c, n) as f64;
                (v - t) * (v - t)
            })
            .sum()
    }

    #[test]
    fn converges_on_toy_bowl() {
        let space = toy_space();
        let mut mcts = McTreeSearch::new(space.clone());
        let mut rng = Pcg32::seeded(1);
        let mut best = f64::INFINITY;
        for _ in 0..120 {
            let c = mcts.propose(&mut rng);
            let y = objective(&space, &c);
            best = best.min(y);
            mcts.observe(&c, y);
        }
        assert!(best <= 1.0, "MCTS best {best} after 120/216 evals");
    }

    #[test]
    fn beats_pure_random_on_average() {
        let space = toy_space();
        let mut wins = 0;
        for seed in 0..5 {
            let run = |mut s: Box<dyn SearchStrategy>| {
                let mut rng = Pcg32::seeded(seed);
                let mut best = f64::INFINITY;
                for _ in 0..60 {
                    let c = s.propose(&mut rng);
                    let y = objective(&space, &c);
                    best = best.min(y);
                    s.observe(&c, y);
                }
                best
            };
            let m = run(Box::new(McTreeSearch::new(space.clone())));
            let r = run(Box::new(crate::search::RandomSearch::new(space.clone())));
            if m <= r {
                wins += 1;
            }
        }
        assert!(wins >= 3, "MCTS won {wins}/5");
    }

    #[test]
    fn stats_accumulate_along_paths() {
        let space = toy_space();
        let mut mcts = McTreeSearch::new(space.clone());
        let cfg = Configuration::from_indices(vec![1, 2, 3]);
        mcts.observe(&cfg, 5.0);
        mcts.observe(&cfg, 3.0);
        let root = mcts.stats.get(&(0, String::new(), 1)).unwrap();
        assert_eq!(root.visits, 2);
    }
}
