//! Coarse grid-search baseline: strided enumeration of the cartesian
//! space (Category 1/2-style exhaustive approaches, §II — included to
//! demonstrate why enumeration is untenable at 10^6-configuration scale).

use std::sync::Arc;

use super::SearchStrategy;
use crate::space::{ConfigSpace, Configuration};
use crate::util::Pcg32;

pub struct GridSearch {
    space: Arc<ConfigSpace>,
    stride: u128,
    next: u128,
}

impl GridSearch {
    /// Visit ~`target_points` configurations spread over the whole space.
    pub fn new(space: Arc<ConfigSpace>, target_points: u128) -> Self {
        let size = space.size();
        let stride = (size / target_points.max(1)).max(1);
        // odd strides co-prime with most radix factors cover better
        let stride = if stride % 2 == 0 { stride + 1 } else { stride };
        GridSearch { space, stride, next: 0 }
    }
}

impl SearchStrategy for GridSearch {
    fn propose(&mut self, _rng: &mut Pcg32) -> Configuration {
        let size = self.space.size();
        let c = self.space.config_at(self.next % size);
        self.next = (self.next + self.stride) % size;
        c
    }

    fn observe(&mut self, _cfg: &Configuration, _objective: f64) {}

    fn name(&self) -> &'static str {
        "grid"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::{Param, ParamDomain};

    #[test]
    fn strided_coverage_is_spread_and_valid() {
        let mut s = ConfigSpace::new("t");
        s.add(Param::new("a", ParamDomain::ordinal(&[0, 1, 2, 3, 4, 5, 6, 7])));
        s.add(Param::new("b", ParamDomain::ordinal(&[0, 1, 2, 3, 4, 5, 6, 7])));
        let space = Arc::new(s);
        let mut g = GridSearch::new(space.clone(), 16);
        let mut rng = Pcg32::seeded(1);
        let mut firsts = std::collections::BTreeSet::new();
        for _ in 0..16 {
            let c = g.propose(&mut rng);
            assert!(space.is_valid(&c));
            firsts.insert(space.int_value(&c, "a"));
        }
        assert!(firsts.len() >= 4, "grid stuck in one region");
    }
}
