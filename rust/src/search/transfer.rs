//! Transfer-learning warm start (paper §VIII future work): seed the
//! target-scale search with observations from a small-scale run.
//!
//! Objectives measured at the source scale are rescaled by the ratio of
//! target/source baselines so the surrogate sees values in the target's
//! range; the *ordering structure* of the landscape is what transfers.

use crate::space::Configuration;

/// Rescale source-scale observations into the target scale's range.
///
/// `source_baseline` / `target_baseline` are the default-configuration
/// objectives at each scale.
pub fn warm_start(
    source_obs: &[(Configuration, f64)],
    source_baseline: f64,
    target_baseline: f64,
) -> Vec<(Configuration, f64)> {
    assert!(source_baseline > 0.0 && target_baseline > 0.0);
    let ratio = target_baseline / source_baseline;
    source_obs.iter().map(|(c, y)| (c.clone(), y * ratio)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rescales_by_baseline_ratio() {
        let obs = vec![
            (Configuration::from_indices(vec![0]), 2.0),
            (Configuration::from_indices(vec![1]), 4.0),
        ];
        let out = warm_start(&obs, 2.0, 20.0);
        assert_eq!(out[0].1, 20.0);
        assert_eq!(out[1].1, 40.0);
        // ordering preserved
        assert!(out[0].1 < out[1].1);
    }

    #[test]
    #[should_panic]
    fn rejects_nonpositive_baselines() {
        warm_start(&[], 0.0, 1.0);
    }
}
