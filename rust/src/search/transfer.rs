//! Transfer-learning warm start (paper §VIII future work) — subsumed by
//! the cross-run history database in [`crate::history`].
//!
//! The baseline-ratio rescaling that used to live here is now
//! [`crate::history::rescale`], feeding the index-keyed
//! `BayesianOptimizer::warm_start_from_history` path (warmed
//! observations are recorded but never re-proposed, like federation
//! elites). This module keeps a thin deprecated shim for source
//! compatibility, mirroring the `amend_last` precedent.

use crate::space::Configuration;

/// Rescale source-scale observations into the target scale's range.
///
/// `source_baseline` / `target_baseline` are the default-configuration
/// objectives at each scale.
#[deprecated(
    note = "use `crate::history::rescale` (and the history store's \
            `warm_prior` / `apply_warm_start` pipeline, which also marks \
            transferred points seen so they are never re-proposed); this \
            free function rescales only and predates the store"
)]
pub fn warm_start(
    source_obs: &[(Configuration, f64)],
    source_baseline: f64,
    target_baseline: f64,
) -> Vec<(Configuration, f64)> {
    crate::history::rescale(source_obs, source_baseline, target_baseline)
}

#[cfg(test)]
mod tests {
    #![allow(deprecated)] // pinning the legacy shim's delegation contract
    use super::*;

    #[test]
    fn rescales_by_baseline_ratio() {
        let obs = vec![
            (Configuration::from_indices(vec![0]), 2.0),
            (Configuration::from_indices(vec![1]), 4.0),
        ];
        let out = warm_start(&obs, 2.0, 20.0);
        assert_eq!(out[0].1, 20.0);
        assert_eq!(out[1].1, 40.0);
        // ordering preserved
        assert!(out[0].1 < out[1].1);
        // the shim and its replacement are the same function
        let direct = crate::history::rescale(&obs, 2.0, 20.0);
        assert_eq!(out, direct);
    }

    #[test]
    #[should_panic]
    fn rejects_nonpositive_baselines() {
        warm_start(&[], 0.0, 1.0);
    }
}
