//! The six parameter spaces of Table III, reconstructed exactly.
//!
//! The paper gives, per application: the system parameters (OpenMP runtime
//! environment variables), the count of *unique* application parameters
//! ("some of them are used repeatedly in the application code"), and the
//! total space size. We reconstruct factorizations that (a) match the
//! stated unique-parameter counts, (b) respect the described ranges, and
//! (c) hit the exact Table III sizes:
//!
//! | space            | factorization                                          | size      |
//! |------------------|---------------------------------------------------------|-----------|
//! | XSBench          | 270 env x block(12) x parallel-for at 4 sites (2^4)      | 51,840    |
//! | XSBench-mixed    | 270 env x block(12) x unroll(2) x tile_x(11) x tile_y(11)|           |
//! |                  |   x parallel-for at 3 sites (2^3)                        | 6,272,640 |
//! | XSBench-offload  | 810 env x sched-chunk(7) x simd(2) x device(4)           |           |
//! |                  |   x parallel-for at 2 sites (2^2)                        | 181,440   |
//! | SWFFT            | 270 env x MPI_Barrier at 2 sites (2^2)                   | 1,080     |
//! | AMG              | 270 env x unroll3 at 3 + unroll6 at 3 + pf at 5 (2^11)   | 552,960   |
//! | SW4lite          | 270 env x unroll6 at 3 + pf at 5 + nowait at 4           |           |
//! |                  |   + MPI_Barrier(1) (2^13)                                | 2,211,840 |
//!
//! 270 env = 10 thread choices x 3 OMP_PLACES x 3 OMP_PROC_BIND x
//! 3 OMP_SCHEDULE; the offload space adds OMP_TARGET_OFFLOAD (x3 = 810).
//! Thread choices honour the paper's launch-algorithm divisibility rules
//! (§VI): on Theta n/2, n/3 or n/4 integer past 64; on Summit n/4 integer.

use super::param::{Param, ParamDomain};
use super::space::ConfigSpace;
use crate::apps::AppKind;
use crate::platform::PlatformKind;

/// Thread-count choices (10 per system, paper §V-A / §V-B).
pub fn thread_choices(platform: PlatformKind) -> &'static [i64] {
    match platform {
        // 64 cores x 4 SMT = up to 256; >64 must divide evenly per -j level
        PlatformKind::Theta => &[4, 8, 16, 32, 64, 96, 128, 144, 192, 256],
        // 42 cores x 4 SMT = up to 168; jsrun -bpacked:n/4 needs n % 4 == 0
        PlatformKind::Summit => &[4, 8, 16, 24, 32, 48, 64, 84, 128, 168],
    }
}

/// XSBench block-size choices (12, range 10..400, default 100; §V-A).
pub const BLOCK_SIZES: [i64; 12] = [10, 20, 40, 60, 80, 100, 130, 160, 200, 250, 300, 400];

/// 2D tile sizes for the mixed-pragma loop tiling (11, range 2..1024).
pub const TILE_SIZES: [i64; 11] = [2, 4, 8, 16, 32, 64, 128, 256, 512, 768, 1024];

/// OpenMP target schedule chunk sizes (7 = six chunks in 1..32 or absent).
pub const OFFLOAD_CHUNKS: [i64; 7] = [0, 1, 2, 4, 8, 16, 32];

/// Device clause choices for the offload version (4 incl. "unset" = -1).
pub const OFFLOAD_DEVICES: [i64; 4] = [-1, 0, 2, 4];

fn add_omp_env(s: &mut ConfigSpace, platform: PlatformKind) {
    s.add(Param::new("OMP_NUM_THREADS", ParamDomain::ordinal(thread_choices(platform))));
    s.add(Param::new("OMP_PLACES", ParamDomain::categorical(&["cores", "threads", "sockets"])));
    s.add(Param::new("OMP_PROC_BIND", ParamDomain::categorical(&["close", "spread", "master"])));
    s.add(Param::new("OMP_SCHEDULE", ParamDomain::categorical(&["static", "dynamic", "auto"])));
}

fn add_toggles(s: &mut ConfigSpace, base: &str, sites: usize) {
    for i in 0..sites {
        s.add(Param::new(&format!("{base}_{i}"), ParamDomain::Toggle));
    }
}

/// Build the Table III space for an application on a platform.
pub fn build_space(app: AppKind, platform: PlatformKind) -> ConfigSpace {
    let mut s = ConfigSpace::new(&format!("{}@{}", app.name(), platform.name()));
    match app {
        AppKind::XSBenchHistory | AppKind::XSBenchEvent => {
            add_omp_env(&mut s, platform);
            s.add(Param::new("block_size", ParamDomain::ordinal(&BLOCK_SIZES)));
            add_toggles(&mut s, "parallel_for", 4);
        }
        AppKind::XSBenchMixed => {
            add_omp_env(&mut s, platform);
            s.add(Param::new("block_size", ParamDomain::ordinal(&BLOCK_SIZES)));
            s.add(Param::new("unroll_full", ParamDomain::Toggle));
            s.add(Param::new("tile_x", ParamDomain::ordinal(&TILE_SIZES)));
            s.add(Param::new("tile_y", ParamDomain::ordinal(&TILE_SIZES)));
            add_toggles(&mut s, "parallel_for", 3);
        }
        AppKind::XSBenchOffload => {
            add_omp_env(&mut s, platform);
            s.add(Param::new(
                "OMP_TARGET_OFFLOAD",
                ParamDomain::categorical(&["DEFAULT", "DISABLED", "MANDATORY"]),
            ));
            s.add(Param::new("sched_chunk", ParamDomain::ordinal(&OFFLOAD_CHUNKS)));
            s.add(Param::new("simd", ParamDomain::Toggle));
            s.add(Param::new("device", ParamDomain::ordinal(&OFFLOAD_DEVICES)));
            add_toggles(&mut s, "parallel_for", 2);
        }
        AppKind::Swfft => {
            add_omp_env(&mut s, platform);
            add_toggles(&mut s, "mpi_barrier", 2);
        }
        AppKind::Amg => {
            add_omp_env(&mut s, platform);
            add_toggles(&mut s, "unroll3", 3);
            add_toggles(&mut s, "unroll6", 3);
            add_toggles(&mut s, "parallel_for", 5);
        }
        AppKind::Sw4lite => {
            add_omp_env(&mut s, platform);
            add_toggles(&mut s, "unroll6", 3);
            add_toggles(&mut s, "parallel_for", 5);
            add_toggles(&mut s, "for_nowait", 4);
            add_toggles(&mut s, "mpi_barrier", 1);
        }
    }
    s
}

/// Expected Table III size for an app space (platform-independent).
pub fn table3_size(app: AppKind) -> u128 {
    match app {
        AppKind::XSBenchHistory | AppKind::XSBenchEvent => 51_840,
        AppKind::XSBenchMixed => 6_272_640,
        AppKind::XSBenchOffload => 181_440,
        AppKind::Swfft => 1_080,
        AppKind::Amg => 552_960,
        AppKind::Sw4lite => 2_211_840,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    const ALL: [AppKind; 7] = [
        AppKind::XSBenchHistory,
        AppKind::XSBenchEvent,
        AppKind::XSBenchMixed,
        AppKind::XSBenchOffload,
        AppKind::Swfft,
        AppKind::Amg,
        AppKind::Sw4lite,
    ];

    #[test]
    fn sizes_match_table3_exactly() {
        for app in ALL {
            for platform in [PlatformKind::Theta, PlatformKind::Summit] {
                let s = build_space(app, platform);
                assert_eq!(s.size(), table3_size(app), "{app:?} on {platform:?}");
            }
        }
    }

    #[test]
    fn system_param_counts_match_table3() {
        // 4 env vars for all spaces; 5 for the offload space.
        for app in ALL {
            let s = build_space(app, PlatformKind::Theta);
            let env = s
                .params()
                .iter()
                .filter(|p| p.name.starts_with("OMP_"))
                .count();
            let want = if matches!(app, AppKind::XSBenchOffload) { 5 } else { 4 };
            assert_eq!(env, want, "{app:?}");
        }
    }

    #[test]
    fn thread_choices_satisfy_launch_divisibility() {
        for &n in thread_choices(PlatformKind::Theta) {
            if n > 64 && n <= 128 {
                assert_eq!(n % 2, 0);
            } else if n > 128 && n <= 192 {
                assert_eq!(n % 3, 0);
            } else if n > 192 {
                assert_eq!(n % 4, 0);
            }
            assert!(n <= 256);
        }
        for &n in thread_choices(PlatformKind::Summit) {
            assert_eq!(n % 4, 0, "Summit thread count {n} must divide by SMT 4");
            assert!(n <= 168);
        }
    }

    #[test]
    fn sampling_each_space_is_valid() {
        let mut rng = Pcg32::seeded(1);
        for app in ALL {
            let s = build_space(app, PlatformKind::Summit);
            for _ in 0..50 {
                let c = s.sample(&mut rng);
                assert!(s.is_valid(&c));
            }
        }
    }

    #[test]
    fn encode_fits_aot_feature_budget() {
        // The AOT forest scorer has FEATURES=32 axes; every paper space
        // must fit.
        for app in ALL {
            let s = build_space(app, PlatformKind::Theta);
            assert!(s.dim() <= 32, "{app:?} has {} params", s.dim());
        }
    }
}
