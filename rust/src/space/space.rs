//! `ConfigSpace`: the search-space expression + valid-only sampling
//! (Category 4 in the paper's taxonomy, §II).

use super::param::{Param, ParamValue};
use crate::util::Pcg32;

/// A point in the space: one value index per parameter.
///
/// Storing *indices* (not values) makes hashing, encoding, and neighbour
/// moves O(1) per axis; values are materialized through the space.
/// `Ord` (lexicographic over indices) lets deduplication live in ordered
/// sets, keeping any iteration over seen-configurations deterministic.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Configuration {
    idx: Vec<u32>,
}

impl Configuration {
    pub fn from_indices(idx: Vec<u32>) -> Self {
        Configuration { idx }
    }

    pub fn indices(&self) -> &[u32] {
        &self.idx
    }

    pub fn key(&self) -> String {
        let parts: Vec<String> = self.idx.iter().map(|i| i.to_string()).collect();
        parts.join(",")
    }
}

/// Validity predicate: Category-4 frameworks sample only valid points.
pub type Constraint = fn(&ConfigSpace, &Configuration) -> bool;

/// A fixed vector space of tunable parameters (paper §IV-A, Table III).
///
/// Debug shows name/dim/size (constraints are fn pointers).
pub struct ConfigSpace {
    name: String,
    params: Vec<Param>,
    constraints: Vec<(String, Constraint)>,
}

impl std::fmt::Debug for ConfigSpace {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ConfigSpace")
            .field("name", &self.name)
            .field("dim", &self.params.len())
            .field("size", &self.size())
            .field("constraints", &self.constraints.len())
            .finish()
    }
}

impl ConfigSpace {
    pub fn new(name: &str) -> Self {
        ConfigSpace { name: name.to_string(), params: Vec::new(), constraints: Vec::new() }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn add(&mut self, param: Param) -> &mut Self {
        assert!(
            self.params.iter().all(|p| p.name != param.name),
            "duplicate parameter {}",
            param.name
        );
        self.params.push(param);
        self
    }

    /// Declare a validity constraint (named, for diagnostics).
    pub fn constrain(&mut self, name: &str, c: Constraint) -> &mut Self {
        self.constraints.push((name.to_string(), c));
        self
    }

    pub fn params(&self) -> &[Param] {
        &self.params
    }

    pub fn dim(&self) -> usize {
        self.params.len()
    }

    pub fn param_index(&self, name: &str) -> Option<usize> {
        self.params.iter().position(|p| p.name == name)
    }

    /// Cartesian size of the space (Table III "space size"); constraints
    /// are not discounted (the paper reports raw cartesian sizes too).
    pub fn size(&self) -> u128 {
        self.params.iter().map(|p| p.domain.cardinality() as u128).product()
    }

    /// Value of `config` for the named parameter.
    pub fn value(&self, config: &Configuration, name: &str) -> Option<ParamValue> {
        let i = self.param_index(name)?;
        Some(self.params[i].domain.value_at(config.idx[i] as usize))
    }

    /// Integer value accessor (panics on type mismatch — programmer error).
    pub fn int_value(&self, config: &Configuration, name: &str) -> i64 {
        self.value(config, name)
            .and_then(|v| v.as_int())
            .unwrap_or_else(|| panic!("no int param {name}"))
    }

    /// String value accessor.
    pub fn str_value(&self, config: &Configuration, name: &str) -> String {
        match self.value(config, name) {
            Some(ParamValue::Str(s)) => s,
            other => panic!("no str param {name}: {other:?}"),
        }
    }

    /// Render `config` as `name=value` pairs (database / log lines).
    pub fn describe(&self, config: &Configuration) -> String {
        let parts: Vec<String> = self
            .params
            .iter()
            .zip(config.idx.iter())
            .map(|(p, &i)| format!("{}={}", p.name, p.domain.value_at(i as usize)))
            .collect();
        parts.join(" ")
    }

    pub fn is_valid(&self, config: &Configuration) -> bool {
        config.idx.len() == self.dim()
            && config
                .idx
                .iter()
                .zip(self.params.iter())
                .all(|(&i, p)| (i as usize) < p.domain.cardinality())
            && self.constraints.iter().all(|(_, c)| c(self, config))
    }

    /// Sample a *valid* configuration (Category 4: constraints are applied
    /// during generation via bounded resampling of the violating axes).
    pub fn sample(&self, rng: &mut Pcg32) -> Configuration {
        for _ in 0..10_000 {
            let idx = self
                .params
                .iter()
                .map(|p| rng.index(p.domain.cardinality()) as u32)
                .collect();
            let c = Configuration::from_indices(idx);
            if self.constraints.iter().all(|(_, f)| f(self, &c)) {
                return c;
            }
        }
        panic!("space '{}': constraints too tight — no valid sample in 10k draws", self.name);
    }

    /// Sample `n` distinct valid configurations (best effort on small
    /// spaces: gives up on distinctness after enough collisions).
    pub fn sample_distinct(&self, n: usize, rng: &mut Pcg32) -> Vec<Configuration> {
        let mut out: Vec<Configuration> = Vec::with_capacity(n);
        let mut misses = 0usize;
        while out.len() < n && misses < 100 * n + 1000 {
            let c = self.sample(rng);
            if out.contains(&c) {
                misses += 1;
            } else {
                out.push(c);
            }
        }
        out
    }

    /// Enumerate the `i`-th point of the cartesian product (mixed radix,
    /// first parameter fastest). Used by the grid baseline and tests.
    pub fn config_at(&self, mut i: u128) -> Configuration {
        assert!(i < self.size());
        let mut idx = Vec::with_capacity(self.dim());
        for p in &self.params {
            let card = p.domain.cardinality() as u128;
            idx.push((i % card) as u32);
            i /= card;
        }
        Configuration::from_indices(idx)
    }

    /// Inverse of `config_at`.
    pub fn index_of(&self, config: &Configuration) -> u128 {
        let mut mult = 1u128;
        let mut acc = 0u128;
        for (p, &i) in self.params.iter().zip(config.idx.iter()) {
            acc += i as u128 * mult;
            mult *= p.domain.cardinality() as u128;
        }
        acc
    }

    /// Encode for the surrogate: each axis → normalized index in [0, 1].
    ///
    /// Ordinal axes preserve order (RF split semantics match the numeric
    /// ordering); categorical axes still get index positions — fine for
    /// tree models, which only ever threshold, and identical to how the
    /// skopt/ConfigSpace stack feeds RF surrogates.
    pub fn encode_into(&self, config: &Configuration, out: &mut [f32]) {
        assert!(out.len() >= self.dim());
        for (j, (p, &i)) in self.params.iter().zip(config.idx.iter()).enumerate() {
            let card = p.domain.cardinality();
            out[j] = if card <= 1 { 0.0 } else { i as f32 / (card - 1) as f32 };
        }
        for slot in out.iter_mut().skip(self.dim()) {
            *slot = 0.0;
        }
    }

    pub fn encode(&self, config: &Configuration, feature_dim: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; feature_dim.max(self.dim())];
        self.encode_into(config, &mut v);
        v.truncate(feature_dim.max(self.dim()));
        v
    }

    /// One-axis neighbour move (used to densify candidates near incumbents).
    /// Ordinal axes step ±1; categorical axes resample the axis. Returns a
    /// valid configuration.
    pub fn neighbor(&self, config: &Configuration, rng: &mut Pcg32) -> Configuration {
        for _ in 0..1000 {
            let mut idx = config.idx.clone();
            let j = rng.index(self.dim());
            let card = self.params[j].domain.cardinality();
            if card > 1 {
                if self.params[j].domain.is_ordered() {
                    let step: i64 = if rng.bool(0.5) { 1 } else { -1 };
                    let ni = (idx[j] as i64 + step).clamp(0, card as i64 - 1);
                    if ni as u32 == idx[j] {
                        continue;
                    }
                    idx[j] = ni as u32;
                } else {
                    let mut ni = rng.index(card) as u32;
                    if ni == idx[j] {
                        ni = (ni + 1) % card as u32;
                    }
                    idx[j] = ni;
                }
            }
            let c = Configuration::from_indices(idx);
            if self.is_valid(&c) {
                return c;
            }
        }
        config.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::param::ParamDomain;

    fn toy_space() -> ConfigSpace {
        let mut s = ConfigSpace::new("toy");
        s.add(Param::new("threads", ParamDomain::ordinal(&[4, 8, 16])));
        s.add(Param::new("places", ParamDomain::categorical(&["cores", "threads"])));
        s.add(Param::new("unroll", ParamDomain::Toggle));
        s
    }

    #[test]
    fn size_is_cartesian_product() {
        assert_eq!(toy_space().size(), 3 * 2 * 2);
    }

    #[test]
    fn config_at_roundtrip_full_enumeration() {
        let s = toy_space();
        for i in 0..s.size() {
            let c = s.config_at(i);
            assert!(s.is_valid(&c));
            assert_eq!(s.index_of(&c), i);
        }
    }

    #[test]
    fn sample_valid_and_deterministic() {
        let s = toy_space();
        let mut r1 = Pcg32::seeded(3);
        let mut r2 = Pcg32::seeded(3);
        for _ in 0..50 {
            let a = s.sample(&mut r1);
            let b = s.sample(&mut r2);
            assert_eq!(a, b);
            assert!(s.is_valid(&a));
        }
    }

    #[test]
    fn constraint_respected_by_sampling() {
        let mut s = toy_space();
        // forbid threads=16 with places=threads
        s.constrain("no-16-threads-place", |sp, c| {
            !(sp.int_value(c, "threads") == 16 && sp.str_value(c, "places") == "threads")
        });
        let mut rng = Pcg32::seeded(5);
        for _ in 0..200 {
            let c = s.sample(&mut rng);
            assert!(!(s.int_value(&c, "threads") == 16 && s.str_value(&c, "places") == "threads"));
        }
    }

    #[test]
    fn encode_normalizes_indices() {
        let s = toy_space();
        let c = s.config_at(0);
        let e = s.encode(&c, 8);
        assert_eq!(e.len(), 8);
        assert!(e.iter().all(|&x| (0.0..=1.0).contains(&x)));
        let last = s.config_at(s.size() - 1);
        let e2 = s.encode(&last, 8);
        assert_eq!(&e2[..3], &[1.0, 1.0, 1.0]);
        assert_eq!(&e2[3..], &[0.0; 5]);
    }

    #[test]
    fn neighbor_changes_at_most_one_axis_and_stays_valid() {
        let s = toy_space();
        let mut rng = Pcg32::seeded(8);
        let c = s.sample(&mut rng);
        for _ in 0..100 {
            let n = s.neighbor(&c, &mut rng);
            assert!(s.is_valid(&n));
            let diff = c.indices().iter().zip(n.indices()).filter(|(a, b)| a != b).count();
            assert!(diff <= 1);
        }
    }

    #[test]
    fn sample_distinct_unique() {
        let s = toy_space();
        let mut rng = Pcg32::seeded(9);
        let v = s.sample_distinct(10, &mut rng);
        assert_eq!(v.len(), 10);
        for i in 0..v.len() {
            for j in i + 1..v.len() {
                assert_ne!(v[i], v[j]);
            }
        }
    }

    #[test]
    fn describe_lists_values() {
        let s = toy_space();
        let c = s.config_at(0);
        let d = s.describe(&c);
        assert!(d.contains("threads=4"));
        assert!(d.contains("places=cores"));
    }
}
