//! Tunable-parameter domains and values (the ConfigSpace substrate).
//!
//! The paper (§IV-A) expresses a search space as a fixed vector of
//! parameter "knobs" — OpenMP runtime environment variables plus
//! application parameters (pragmas, clauses, block/tile sizes). Every knob
//! here is a finite domain so the cartesian size (Table III) is exact.

use std::fmt;

/// A concrete value taken by one parameter.
#[derive(Debug, Clone, PartialEq)]
pub enum ParamValue {
    Str(String),
    Int(i64),
}

impl ParamValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            ParamValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            ParamValue::Int(i) => Some(*i),
            _ => None,
        }
    }
}

impl fmt::Display for ParamValue {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ParamValue::Str(s) => write!(f, "{s}"),
            ParamValue::Int(i) => write!(f, "{i}"),
        }
    }
}

/// The finite domain of one parameter.
#[derive(Debug, Clone, PartialEq)]
pub enum ParamDomain {
    /// Unordered string choices (e.g. OMP_PLACES = cores|threads|sockets).
    Categorical(Vec<String>),
    /// Ordered numeric choices (e.g. thread counts, block/tile sizes).
    Ordinal(Vec<i64>),
    /// On/off pragma toggle — categorical {off, on} but encoded ordinally.
    Toggle,
}

impl ParamDomain {
    pub fn categorical(choices: &[&str]) -> Self {
        ParamDomain::Categorical(choices.iter().map(|s| s.to_string()).collect())
    }

    pub fn ordinal(choices: &[i64]) -> Self {
        assert!(choices.windows(2).all(|w| w[0] < w[1]), "ordinal choices must be sorted");
        ParamDomain::Ordinal(choices.to_vec())
    }

    /// Number of distinct values.
    pub fn cardinality(&self) -> usize {
        match self {
            ParamDomain::Categorical(c) => c.len(),
            ParamDomain::Ordinal(c) => c.len(),
            ParamDomain::Toggle => 2,
        }
    }

    /// The `i`-th value of the domain (i < cardinality).
    pub fn value_at(&self, i: usize) -> ParamValue {
        match self {
            ParamDomain::Categorical(c) => ParamValue::Str(c[i].clone()),
            ParamDomain::Ordinal(c) => ParamValue::Int(c[i]),
            ParamDomain::Toggle => ParamValue::Int(i as i64),
        }
    }

    /// Inverse of `value_at`.
    pub fn index_of(&self, v: &ParamValue) -> Option<usize> {
        match (self, v) {
            (ParamDomain::Categorical(c), ParamValue::Str(s)) => c.iter().position(|x| x == s),
            (ParamDomain::Ordinal(c), ParamValue::Int(i)) => c.iter().position(|x| x == i),
            (ParamDomain::Toggle, ParamValue::Int(i)) if *i == 0 || *i == 1 => Some(*i as usize),
            _ => None,
        }
    }

    /// True if the surrogate should treat the encoded axis as ordered.
    pub fn is_ordered(&self) -> bool {
        !matches!(self, ParamDomain::Categorical(_))
    }
}

/// A named tunable parameter.
#[derive(Debug, Clone, PartialEq)]
pub struct Param {
    pub name: String,
    pub domain: ParamDomain,
}

impl Param {
    pub fn new(name: &str, domain: ParamDomain) -> Self {
        Param { name: name.to_string(), domain }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cardinality_and_values() {
        let d = ParamDomain::categorical(&["static", "dynamic", "auto"]);
        assert_eq!(d.cardinality(), 3);
        assert_eq!(d.value_at(1), ParamValue::Str("dynamic".into()));
        assert_eq!(d.index_of(&ParamValue::Str("auto".into())), Some(2));
        assert_eq!(d.index_of(&ParamValue::Str("guided".into())), None);
    }

    #[test]
    fn ordinal_roundtrip() {
        let d = ParamDomain::ordinal(&[4, 8, 16, 32]);
        for i in 0..d.cardinality() {
            let v = d.value_at(i);
            assert_eq!(d.index_of(&v), Some(i));
        }
    }

    #[test]
    #[should_panic]
    fn ordinal_must_be_sorted() {
        ParamDomain::ordinal(&[8, 4]);
    }

    #[test]
    fn toggle() {
        let d = ParamDomain::Toggle;
        assert_eq!(d.cardinality(), 2);
        assert_eq!(d.value_at(1), ParamValue::Int(1));
        assert!(d.is_ordered());
    }
}
