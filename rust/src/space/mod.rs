//! Search-space expression and sampling: the ConfigSpace substrate
//! (paper §II requirement 1, §IV-A) plus the exact Table III spaces.

pub mod paper;
mod param;
#[allow(clippy::module_inception)]
mod space;

pub use param::{Param, ParamDomain, ParamValue};
pub use space::{ConfigSpace, Configuration, Constraint};
