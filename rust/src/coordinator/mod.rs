//! The autotuning coordinator: the paper's five-step iterative framework
//! (Fig. 1 for performance, Fig. 4 for energy/EDP) over the simulated
//! substrate, with ytopt-style overhead accounting, a wall-clock budget,
//! the evaluation-timeout and parallel-evaluation extensions (§VIII), and
//! the performance database.
//!
//! Step 1  Bayesian optimization selects a configuration.
//! Step 2  The code mold is instantiated and verified (codegen).
//! Step 3  The aprun/jsrun (or geopmlaunch) command line is generated.
//! Step 4  The new code is "compiled" (platform::compile_time model).
//! Step 5  The application is evaluated (apps models; GEOPM pipeline for
//!         energy/EDP through the AOT energy_reduce artifact) and the
//!         result lands in the performance database.

pub mod database;
pub mod overhead;

pub use database::{EvalRecord, PerfDatabase};

use std::sync::Arc;

use crate::apps::{self, AppKind, AppModel, EvalContext};
use crate::codegen;
use crate::metrics::{improvement_pct, Measured, Metric};
use crate::platform::{compile_time, launch, PlatformKind};
use crate::power::{sample_traces, GeopmReport};
use crate::runtime::Scorer;
use crate::search::{
    BayesianOptimizer, BoConfig, GridSearch, RandomSearch, SearchStrategy, StrategyKind,
    SurrogateKind,
};
use crate::space::{paper, ConfigSpace, Configuration};
use crate::util::Pcg32;
use anyhow::{Context, Result};

/// Everything one autotuning run needs.
#[derive(Clone)]
pub struct TuneSetup {
    pub app: AppKind,
    pub platform: PlatformKind,
    pub nodes: u64,
    pub metric: Metric,
    /// Maximum number of code evaluations.
    // detlint: allow(fingerprint-coverage) -- capacity knob: resuming with a larger budget continues the same campaign
    pub max_evals: usize,
    /// Wall-clock budget for the whole run (the paper used 1800 s).
    // detlint: allow(fingerprint-coverage) -- capacity knob: resuming with a larger budget continues the same campaign
    pub wallclock_budget_s: f64,
    pub seed: u64,
    pub strategy: StrategyKind,
    pub surrogate: SurrogateKind,
    /// LCB exploration parameter (Eq. 1; default 1.96).
    pub kappa: f64,
    /// Evaluation timeout (paper §VIII future work). Runs longer than
    /// this are cut off and recorded as timed out.
    pub eval_timeout_s: Option<f64>,
    /// Concurrent evaluations (1 = the paper's Ray executor; >1 = the
    /// libensemble-style extension).
    // detlint: allow(fingerprint-coverage) -- serial-path concurrency; the checkpointable engines key on ensemble_workers/ensemble_batch, which are fingerprinted
    pub parallel_evals: usize,
    /// Random evaluations before the surrogate activates.
    pub n_init: usize,
    /// Transfer-learning warm start: prior (config, objective) pairs.
    pub warm_start: Option<Vec<(Configuration, f64)>>,
    /// Drive the mixed-pragma space with the event-based transport
    /// (paper Fig. 5b/5d). Only meaningful for XSBench-mixed.
    pub event_transport: bool,
    /// PowerStack node package-power cap (W): every run — baseline
    /// included — executes throttled under it (§IV-B context).
    pub power_cap_w: Option<f64>,
    /// Project node-hour budget (the paper's real constraint that forced
    /// the 1800 s wall-clock limits); the run stops when exhausted.
    // detlint: allow(fingerprint-coverage) -- capacity knob: resuming with a larger budget continues the same campaign
    pub node_hours_budget: Option<f64>,
    /// Ensemble evaluation engine: 0 or 1 keeps the serial in-loop path;
    /// >= 2 routes the run through `crate::ensemble`'s manager/worker
    /// subsystem (opt-in).
    pub ensemble_workers: usize,
    /// Proposals in flight per ensemble manager cycle (0 = worker count).
    pub ensemble_batch: usize,
    /// Pending-point imputation for the ensemble's async-BO bridge.
    pub liar: crate::ensemble::LiarStrategy,
    /// Simulated transient evaluation-failure probability (ensemble fault
    /// injection; 0.0 disables).
    pub fault_rate: f64,
    /// Retries (with worker exclusion) before an evaluation is abandoned.
    pub max_retries: usize,
    /// Cancel in-flight runs whose runtime exceeds this multiple of the
    /// median runtime (ensemble straggler policy; None disables). The
    /// continuous manager cycle uses a running quantile over all
    /// completed runtimes; the generational cycle uses the batch median.
    /// Neither cancels off fewer than 4 completed samples.
    pub straggler_factor: Option<f64>,
    /// How the ensemble manager feeds its workers: `Continuous` (the
    /// default) tops up a freed worker the moment each completion is
    /// applied; `Generational` barriers on whole proposal batches (kept
    /// as the reference oracle for parity tests).
    pub manager_cycle: crate::ensemble::ManagerCycle,
    /// Ensemble checkpoint file: completed evaluations persist here and a
    /// resumed session re-evaluates none of them.
    // detlint: allow(fingerprint-coverage) -- where the checkpoint lives, not what the run is; the file carries the fingerprint inside
    pub checkpoint_path: Option<std::path::PathBuf>,
    /// Manager federation: 0 keeps the single-manager paths; K >= 1 runs
    /// K continuous manager shards, each owning a deterministic hash
    /// partition of the candidate space (K = 1 is the single manager
    /// spelled through the federation front-end — bit-identical history).
    pub federation_shards: usize,
    /// Completions per shard between federation elite exchanges.
    pub elite_exchange_every: usize,
    /// Top-N history entries each shard broadcasts per exchange.
    pub federation_elites: usize,
    /// Cross-run tuning-history database directory: every completed run
    /// appends one `history::RunRecord` here (atomic, space-fingerprint
    /// indexed), so later runs at any scale can warm-start from it.
    // detlint: allow(fingerprint-coverage) -- output sink only; appending records never feeds back into this run's trajectory
    pub history_dir: Option<std::path::PathBuf>,
    /// Transfer-learning warm-start source: a history-store directory.
    /// At run start the store's space-compatible, nearest-scale,
    /// top-`warm_start_elites` observations are rescaled by the
    /// target/source baseline ratio and absorbed as foreign
    /// observations (recorded, marked seen, never re-proposed — like
    /// federation elites). A store with no compatible run is refused.
    // detlint: allow(fingerprint-coverage) -- source path only; the *resolved* prior it produces (foreign_warm) is fingerprinted
    pub warm_start_from: Option<std::path::PathBuf>,
    /// How many elites the warm start pulls from the store.
    // detlint: allow(fingerprint-coverage) -- resolution knob only; the *resolved* prior it produces (foreign_warm) is fingerprinted
    pub warm_start_elites: usize,
    /// The *resolved* warm-start prior (`history::apply_warm_start`
    /// fills this from `warm_start_from`; tests may set it directly).
    /// Part of the run's checkpoint fingerprint: resuming against a
    /// store whose contents changed is refused.
    pub foreign_warm: Option<Vec<(Configuration, f64)>>,
    /// Memoized baseline measurement: `history::apply_warm_start` pays
    /// for the baseline once (the rescale anchor) and the tuning
    /// engines reuse it through [`measure_baseline`] instead of
    /// re-measuring — in the deployment this simulates, a baseline is a
    /// full application run at scale. Derived state (a pure function of
    /// the setup), so it is not part of the checkpoint fingerprint.
    // detlint: allow(fingerprint-coverage) -- derived state, a pure function of the fingerprinted fields
    pub baseline_memo: Option<(Measured, f64)>,
    /// Simulated mid-run kill for crash-recovery tests: the continuous
    /// manager (and every federation shard) abandons the campaign right
    /// after this many completions have been applied and checkpointed,
    /// leaving its dispatched-but-unfinished evaluations behind —
    /// exactly the on-disk state a SIGKILL at that moment leaves.
    /// Excluded from the checkpoint fingerprint (a capacity knob, like
    /// `max_evals`: resuming past the kill point is the normal use).
    // detlint: allow(fingerprint-coverage) -- capacity knob: resuming past the kill point is the normal use
    pub kill_after_evals: Option<usize>,
    /// Observability sink (`--stats`): the engines record manager events
    /// and counters here when present. Strictly write-only from the
    /// engine's side — recording never feeds back into the trajectory,
    /// and seed-for-seed runs are pinned bit-identical with it on or
    /// off, so it must stay outside the checkpoint fingerprint.
    // detlint: allow(fingerprint-coverage) -- write-only telemetry sink; trajectories are pinned bit-identical with stats on vs. off
    pub obs: Option<std::sync::Arc<crate::obs::ObsSink>>,
    /// Chaos failpoint plan (`--chaos`): seeded fault injection at the
    /// I/O boundaries (checkpoint/history/stats installs, worker
    /// threads; the daemon carries its own plan for sockets). The
    /// recovery machinery it exercises — audited atomic installs,
    /// deterministic backoff, worker respawn with same-attempt re-queue
    /// — keeps trajectories bit-identical with the plan on or off, and
    /// the soak tests pin that, so the plan stays outside the
    /// checkpoint fingerprint exactly like `obs`.
    // detlint: allow(fingerprint-coverage) -- fault schedule, not run identity; recovery is pinned trajectory-neutral by chaos_soak
    pub chaos: Option<std::sync::Arc<crate::chaos::FaultPlan>>,
    /// Continuous-controller mode (`--controller`): the tuner never
    /// stops — it watches predicted-vs-observed residuals through a
    /// CUSUM detector, resets the surrogate's trust window when the
    /// substrate drifts, and applies configuration changes under a
    /// bounded per-update authority limit. Requires the unsharded
    /// continuous manager cycle.
    pub controller: bool,
    /// Recency half-life, in observations, of the controller's decayed
    /// objective standardization (`--decay-half-life`).
    pub decay_half_life: f64,
    /// CUSUM threshold (standard deviations of accumulated residual)
    /// that declares drift (`--drift-threshold`).
    pub drift_threshold: f64,
    /// Authority limit: at most one parameter moves at most this many
    /// ordinal steps per applied update (`--max-delta`).
    pub max_delta: usize,
    /// Drifting-substrate simulator: phase-shift the application model
    /// starting at this evaluation index (`--drift-at`). Substrate
    /// identity — what the recorded objectives measured — so it is in
    /// the checkpoint fingerprint.
    pub drift_at_eval: Option<usize>,
    /// Magnitude of the simulated substrate drift (`--drift-magnitude`,
    /// fraction of the model's baseline scale; 0 disables even with a
    /// drift point set).
    pub drift_magnitude: f64,
}

impl TuneSetup {
    pub fn new(app: AppKind, platform: PlatformKind, nodes: u64, metric: Metric) -> Self {
        TuneSetup {
            app,
            platform,
            nodes,
            metric,
            max_evals: 128,
            wallclock_budget_s: 1800.0,
            seed: 42,
            strategy: StrategyKind::Bo,
            surrogate: SurrogateKind::RandomForest,
            kappa: crate::acquisition::DEFAULT_KAPPA,
            eval_timeout_s: None,
            parallel_evals: 1,
            n_init: 8,
            warm_start: None,
            event_transport: false,
            power_cap_w: None,
            node_hours_budget: None,
            ensemble_workers: 0,
            ensemble_batch: 0,
            liar: crate::ensemble::LiarStrategy::ConstantMin,
            fault_rate: 0.0,
            max_retries: 2,
            straggler_factor: None,
            manager_cycle: crate::ensemble::ManagerCycle::Continuous,
            checkpoint_path: None,
            federation_shards: 0,
            elite_exchange_every: 8,
            federation_elites: 3,
            history_dir: None,
            warm_start_from: None,
            warm_start_elites: 8,
            foreign_warm: None,
            baseline_memo: None,
            kill_after_evals: None,
            obs: None,
            chaos: None,
            controller: false,
            decay_half_life: 16.0,
            drift_threshold: 8.0,
            max_delta: 1,
            drift_at_eval: None,
            drift_magnitude: 0.0,
        }
    }
}

/// Result of one autotuning run.
pub struct TuneResult {
    pub setup: TuneSetup,
    pub space_size: u128,
    /// Baseline: original code, default configuration, best of 5 runs.
    pub baseline: Measured,
    pub baseline_objective: f64,
    pub db: PerfDatabase,
    pub best_objective: f64,
    pub best_config_desc: String,
    pub improvement_pct: f64,
    /// Total simulated wall-clock of the autotuning run.
    pub wallclock_s: f64,
    pub evaluations: usize,
    pub scorer_accelerated: bool,
    /// Split-gain parameter importance from a forest refit on the run's
    /// database (which knobs mattered), normalized, descending.
    pub param_importance: Vec<(String, f64)>,
    /// Ensemble-engine telemetry (None on the serial path).
    pub ensemble: Option<crate::ensemble::EnsembleStats>,
    /// Multi-manager federation telemetry (None off the federated path).
    pub federation: Option<crate::ensemble::FederationStats>,
}

pub(crate) enum Strat {
    Bo(BayesianOptimizer),
    Other(Box<dyn SearchStrategy>),
}

impl Strat {
    pub(crate) fn propose(&mut self, rng: &mut Pcg32) -> Configuration {
        match self {
            Strat::Bo(b) => b.propose(rng),
            Strat::Other(s) => s.propose(rng),
        }
    }

    pub(crate) fn observe(&mut self, cfg: &Configuration, y: f64) {
        match self {
            Strat::Bo(b) => b.observe(cfg, y),
            Strat::Other(s) => s.observe(cfg, y),
        }
    }

    /// Record a real measurement imported from another federation shard.
    /// BO marks it seen (never re-proposed); other strategies take it as
    /// a plain observation.
    pub(crate) fn observe_foreign(&mut self, cfg: &Configuration, y: f64) {
        match self {
            Strat::Bo(b) => b.observe_foreign(cfg, y),
            Strat::Other(s) => s.observe(cfg, y),
        }
    }

    /// The Bayesian optimizer, when that is the active strategy (the
    /// ensemble's pending-point bridge only applies to BO).
    pub(crate) fn as_bo_mut(&mut self) -> Option<&mut BayesianOptimizer> {
        match self {
            Strat::Bo(b) => Some(b),
            Strat::Other(_) => None,
        }
    }
}

/// Construct the configured search strategy (shared by the serial loop
/// and the ensemble manager).
pub(crate) fn build_strategy(
    setup: &TuneSetup,
    space: Arc<crate::space::ConfigSpace>,
    scorer: Arc<Scorer>,
) -> Strat {
    let mut strat = match setup.strategy {
        StrategyKind::Bo => {
            let mut bo = BayesianOptimizer::new(
                space,
                BoConfig {
                    n_init: setup.n_init,
                    acquisition: crate::acquisition::Acquisition::Lcb { kappa: setup.kappa },
                    surrogate: setup.surrogate,
                    ..Default::default()
                },
                scorer,
            );
            if let Some(prior) = &setup.warm_start {
                bo.preload(prior);
            }
            Strat::Bo(bo)
        }
        StrategyKind::Random => Strat::Other(Box::new(RandomSearch::new(space))),
        StrategyKind::Grid => {
            Strat::Other(Box::new(GridSearch::new(space, setup.max_evals as u128 * 2)))
        }
        StrategyKind::Mctree => Strat::Other(Box::new(crate::search::McTreeSearch::new(space))),
    };
    // history-database warm start: transferred observations enter as
    // foreign measurements (BO records them and marks them seen, so the
    // elites are never re-proposed; other strategies take them as plain
    // observations). Absorbed at construction — before any proposal and
    // before any checkpoint replay — so fresh and resumed sessions see
    // an identical strategy state.
    if let Some(prior) = &setup.foreign_warm {
        match &mut strat {
            Strat::Bo(bo) => {
                bo.warm_start_from_history(prior);
            }
            Strat::Other(s) => {
                for (c, y) in prior {
                    s.observe(c, *y);
                }
            }
        }
    }
    strat
}

pub(crate) fn model_for_setup(setup: &TuneSetup) -> Box<dyn AppModel> {
    let base = if setup.app == AppKind::XSBenchMixed && setup.event_transport {
        Box::new(apps::xsbench::XsBenchCpu::mixed_event())
    } else {
        apps::model_for(setup.app)
    };
    // drifting-substrate simulator: phase-shift the model at the planted
    // evaluation index (deterministic — keyed on the per-eval noise
    // seed, so every engine sees the identical drifted world)
    match setup.drift_at_eval {
        Some(at) if setup.drift_magnitude != 0.0 => Box::new(
            apps::drifting::DriftingModel::new(base, setup.seed, at, setup.drift_magnitude),
        ),
        _ => base,
    }
}

/// Generate the Step-3 launch plan for a configuration.
pub(crate) fn launch_plan(
    setup: &TuneSetup,
    space: &ConfigSpace,
    cfg: &Configuration,
) -> Result<launch::LaunchPlan, launch::LaunchError> {
    let threads = space.int_value(cfg, "OMP_NUM_THREADS") as u64;
    let binary = setup.app.name();
    match (setup.platform, setup.app.uses_gpus()) {
        (PlatformKind::Theta, _) => launch::aprun(setup.nodes, threads, binary),
        (PlatformKind::Summit, true) => launch::jsrun_gpu(setup.nodes, threads, binary),
        (PlatformKind::Summit, false) => launch::jsrun_cpu(setup.nodes, threads, binary),
    }
}

/// Measure one run with the selected metric (Step 5's measurement half).
pub(crate) fn measure(
    setup: &TuneSetup,
    run: &crate::apps::AppRun,
    scorer: &Scorer,
    eval_seed: u64,
) -> Result<Measured> {
    if !setup.metric.needs_power() {
        return Ok(Measured::runtime_only(run.runtime_s));
    }
    anyhow::ensure!(
        setup.platform == PlatformKind::Theta,
        "GEOPM energy measurement is only available on Theta (paper §III)"
    );
    let es = scorer.manifest().energy.clone();
    let spec = setup.platform.spec();
    // GEOPM controller occupies one core as an extra pthread: ~0.5%
    // runtime dilation on the remaining cores
    let runtime = run.runtime_s * 1.005;
    let nodes = (setup.nodes as usize).min(es.max_nodes);
    let traces = sample_traces(run, nodes, spec.power_sample_period_s, es.max_samples, eval_seed);
    let (node_energy, avg, _edp) = scorer.reduce_energy(
        &traces.pkg,
        &traces.dram,
        nodes,
        traces.samples,
        traces.n_valid as f32,
        traces.period_s as f32,
        runtime as f32,
    )?;
    // exercise the report round-trip the real framework performs
    let report = GeopmReport::from_node_energy(&node_energy, 0.92, runtime);
    let parsed = GeopmReport::parse(&report.render()).context("gm.report parse")?;
    let avg_energy = parsed.average_node_energy();
    debug_assert!((avg_energy - avg as f64).abs() < avg as f64 * 0.01 + 1.0);
    Ok(Measured::with_energy(runtime, avg_energy))
}

/// Baseline: original code under the default system configuration, run
/// five times; the paper keeps the smallest value. Deterministic in the
/// setup, so a memoized measurement (warm-start resolution already paid
/// for one) is returned as-is.
pub fn measure_baseline(setup: &TuneSetup, scorer: &Scorer) -> Result<(Measured, f64)> {
    if let Some(memo) = setup.baseline_memo {
        return Ok(memo);
    }
    let model = model_for_setup(setup);
    let mut ctx = EvalContext::new(setup.platform, setup.nodes);
    let mut best: Option<(Measured, f64)> = None;
    for rep in 0..5 {
        ctx.noise_seed = setup.seed.wrapping_mul(97).wrapping_add(rep);
        let mut run = model.baseline(&ctx);
        if let Some(cap) = setup.power_cap_w {
            run = crate::power::apply_cap(&run, cap);
        }
        let m = measure(setup, &run, scorer, ctx.noise_seed)?;
        let obj = m.objective(setup.metric);
        if best.as_ref().map(|(_, b)| obj < *b).unwrap_or(true) {
            best = Some((m, obj));
        }
    }
    Ok(best.unwrap())
}

/// Run the full autotuning loop.
pub fn autotune(setup: &TuneSetup) -> Result<TuneResult> {
    let scorer = Arc::new(Scorer::auto(&crate::runtime::default_artifacts_dir()));
    autotune_with_scorer(setup, scorer)
}

/// Run with a pre-loaded scorer (examples/benches share one runtime).
///
/// Defaults to the paper's serial loop; setups with `ensemble_workers >=
/// 2` opt in to the asynchronous manager/worker engine in
/// [`crate::ensemble`]. This wrapper also resolves the history-database
/// warm start (once, up front, so the resolved prior lands in every
/// path's checkpoint fingerprint) and appends the finished run to the
/// cross-run history store when `history_dir` is configured.
pub fn autotune_with_scorer(setup: &TuneSetup, scorer: Arc<Scorer>) -> Result<TuneResult> {
    anyhow::ensure!(setup.parallel_evals >= 1, "parallel_evals must be >= 1");
    let mut setup = setup.clone();
    crate::history::apply_warm_start(&mut setup, scorer.as_ref())?;
    let result = if setup.federation_shards >= 1 {
        crate::ensemble::autotune_federation(&setup, scorer)?
    } else if setup.ensemble_workers >= 2 {
        crate::ensemble::autotune_ensemble(&setup, scorer)?
    } else {
        autotune_serial(&setup, scorer)?
    };
    // a campaign cut short by the simulated SIGKILL is not a completed
    // run: a real kill would never reach this append, so neither may
    // the simulated one (a truncated RunRecord would pollute every
    // future nearest-scale/elite selection)
    if let (Some(dir), None) = (&setup.history_dir, setup.kill_after_evals) {
        // best-effort bookkeeping: a completed campaign must never be
        // discarded over an unwritable store (full disk, vanished mount)
        let appended = crate::history::HistoryStore::open(dir)
            .map(|store| match &setup.chaos {
                Some(plan) => store.with_chaos(plan.clone()),
                None => store,
            })
            .and_then(|store| store.append(&crate::history::RunRecord::from_result(&result)));
        match appended {
            Ok(path) => log::info!("tuning history appended to {}", path.display()),
            Err(e) => log::warn!(
                "tuning history NOT recorded to {}: {e:#} (the run result is unaffected)",
                dir.display()
            ),
        }
    }
    Ok(result)
}

/// The paper's serial five-step loop (one evaluation in flight unless
/// `parallel_evals > 1` batches them).
fn autotune_serial(setup: &TuneSetup, scorer: Arc<Scorer>) -> Result<TuneResult> {
    let space = Arc::new(paper::build_space(setup.app, setup.platform));
    let model = model_for_setup(setup);
    let mut rng = Pcg32::seeded(setup.seed);

    let (baseline, baseline_objective) = measure_baseline(setup, &scorer)?;

    let mut strat = build_strategy(setup, space.clone(), scorer.clone());

    let mut db = PerfDatabase::new();
    let mut wallclock = 0.0f64;
    let mut best = f64::INFINITY;
    let mut best_desc = String::new();
    let mut eval_id = 0usize;

    // node-hour accounting (platform::scheduler): the allocation economy
    // that forced the paper's half-hour budgets
    let mut allocation = setup.node_hours_budget.map(|nh| {
        crate::platform::scheduler::Allocation::new(setup.platform, "ytopt-repro", nh)
    });

    'outer: while eval_id < setup.max_evals && wallclock < setup.wallclock_budget_s {
        if let Some(alloc) = &allocation {
            // stop when the next evaluation can no longer be afforded
            // (estimate: the mean span so far, or 60 s before any data)
            let est = if eval_id > 0 { wallclock / eval_id as f64 } else { 60.0 };
            if !alloc.can_afford(setup.nodes, est) {
                log::info!("allocation exhausted after {eval_id} evaluations");
                break 'outer;
            }
        }
        let batch = setup.parallel_evals.min(setup.max_evals - eval_id);
        // ---- Step 1: select configurations --------------------------------
        // detlint: allow(wall-clock) -- search-overhead stat only; simulated time drives the trajectory
        let t_search = std::time::Instant::now();
        let mut cfgs = Vec::with_capacity(batch);
        // pending key of each planted lie, so the real measurement amends
        // exactly the observation it belongs to (index-keyed through the
        // optimizer's PendingSet) even when a mid-batch evaluation is
        // skipped (failed launch)
        let mut lie_keys: Vec<Option<usize>> = Vec::with_capacity(batch);
        for b in 0..batch {
            let c = strat.propose(&mut rng);
            // constant-liar so a BO batch spreads out; amended below.
            // Non-BO strategies have no amendment hook and get their real
            // observations after the batch completes instead.
            let lie = match strat.as_bo_mut() {
                Some(bo) if batch > 1 => {
                    let liar = if best.is_finite() { best } else { baseline_objective };
                    bo.observe_pending(eval_id + b, &c, liar);
                    Some(eval_id + b)
                }
                _ => None,
            };
            lie_keys.push(lie);
            cfgs.push(c);
        }
        let search_s = t_search.elapsed().as_secs_f64();

        let mut batch_spans: Vec<f64> = Vec::with_capacity(batch);
        let mut real_ys: Vec<(Configuration, f64)> = Vec::with_capacity(batch);
        let mut amendments: Vec<(usize, f64)> = Vec::with_capacity(batch);
        for (cfg, lie) in cfgs.into_iter().zip(lie_keys) {
            // ---- Step 2: instantiate + verify the code mold ---------------
            let source = codegen::instantiate(setup.app, &space, &cfg)
                .context("code-mold instantiation")?;
            anyhow::ensure!(codegen::verify(&source), "generated code failed verification");

            // ---- Step 3: generate the launch command ----------------------
            let (command, ctx) = match launch_plan(setup, &space, &cfg) {
                Ok(plan) => {
                    let mut ctx = EvalContext::new(setup.platform, setup.nodes);
                    ctx.ranks_per_node = plan.ranks_per_node;
                    ctx.uses_gpus = plan.uses_gpus;
                    let cmd = if setup.metric.needs_power() {
                        format!("{} {}", codegen::env_prefix(&space, &cfg),
                            launch::geopmlaunch(&plan, "gm.report"))
                    } else {
                        format!("{} {}", codegen::env_prefix(&space, &cfg), plan.command)
                    };
                    (cmd, ctx)
                }
                Err(e) => {
                    // invalid launch (should not happen with paper spaces):
                    // skip, but settle this configuration's pending lie so
                    // later amendments stay aligned with their observations
                    log::warn!("launch generation failed: {e}");
                    if let (Some(key), Some(bo)) = (lie, strat.as_bo_mut()) {
                        bo.resolve_pending(key, baseline_objective * 3.0);
                    }
                    continue;
                }
            };

            // ---- Step 4: compile ------------------------------------------
            let compile_s = compile_time::sample_compile_s(setup.app, setup.platform, &mut rng);

            // ---- Step 5: run + measure ------------------------------------
            let mut ctx = ctx;
            ctx.noise_seed = setup.seed ^ (eval_id as u64).wrapping_mul(0x9e3779b97f4a7c15);
            let mut run = model.run(&space, &cfg, &ctx);
            if let Some(cap) = setup.power_cap_w {
                run = crate::power::apply_cap(&run, cap);
            }
            let (measured, timed_out, charged_runtime) = match setup.eval_timeout_s {
                Some(t) if run.runtime_s > t => {
                    // cut off: no valid measurement; charge the timeout
                    (Measured::runtime_only(f64::INFINITY), true, t)
                }
                _ => {
                    let m = measure(setup, &run, &scorer, ctx.noise_seed)?;
                    (m, false, m.runtime_s)
                }
            };
            let objective = if timed_out {
                // penalty for the surrogate: strictly worse than anything
                // real in *objective units* (the timeout is seconds, which
                // for energy/EDP metrics could otherwise undercut real
                // measurements in joules)
                (setup.eval_timeout_s.unwrap() * 3.0).max(baseline_objective * 3.0)
            } else {
                measured.objective(setup.metric)
            };

            // processing time (everything except the application run)
            let orch = overhead::sample_orchestration_s(
                setup.app,
                setup.platform,
                setup.nodes,
                &mut rng,
            );
            let first_extra = if eval_id == 0 {
                overhead::first_eval_setup_s(setup.app, setup.platform, setup.nodes)
            } else {
                0.0
            };
            let launch_s = launch::launch_overhead_s(setup.platform, setup.nodes);
            let record_s = 0.2;
            let processing_s =
                search_s / batch as f64 + orch + first_extra + launch_s + compile_s + record_s;
            let overhead_s = processing_s - compile_s;

            if !timed_out && objective < best {
                best = objective;
                best_desc = space.describe(&cfg);
            }
            db.push(EvalRecord {
                id: eval_id,
                config_key: cfg.key(),
                config_desc: space.describe(&cfg),
                command,
                measured,
                objective,
                compile_s,
                processing_s,
                overhead_s,
                wallclock_s: wallclock + processing_s + charged_runtime,
                best_so_far: if best.is_finite() { best } else { objective },
                timed_out,
                cancelled: false,
            });
            batch_spans.push(processing_s + charged_runtime);
            if let Some(key) = lie {
                amendments.push((key, objective));
            }
            real_ys.push((cfg, objective));
            eval_id += 1;

            if eval_id >= setup.max_evals {
                break;
            }
        }

        // feed back real observations: BO batches amend their pending
        // lies in place (index-keyed, so completion order is irrelevant);
        // everything else observes the real objectives
        if amendments.is_empty() {
            for (cfg, y) in &real_ys {
                strat.observe(cfg, *y);
            }
        } else if let Some(bo) = strat.as_bo_mut() {
            for (key, y) in &amendments {
                bo.resolve_pending(*key, *y);
            }
        }

        // wall clock: sequential = sum; parallel = max of the batch
        let span: f64 = if setup.parallel_evals > 1 {
            batch_spans.iter().cloned().fold(0.0, f64::max)
        } else {
            batch_spans.iter().sum()
        };
        wallclock += span;
        if let Some(alloc) = &mut allocation {
            // charge what was actually consumed; an over-budget batch ends
            // the run rather than erroring (the job simply hits its limit)
            if alloc.charge(setup.nodes, span).is_err() {
                break 'outer;
            }
        }
        if real_ys.is_empty() {
            break 'outer; // all launches failed: avoid spinning
        }
    }

    let param_importance = importance_from_db(&space, &db, setup.seed);

    Ok(TuneResult {
        setup: setup.clone(),
        space_size: space.size(),
        baseline,
        baseline_objective,
        best_objective: best,
        best_config_desc: best_desc,
        improvement_pct: improvement_pct(baseline_objective, best),
        wallclock_s: wallclock,
        evaluations: db.len(),
        scorer_accelerated: scorer.is_accelerated(),
        param_importance,
        db,
        ensemble: None,
        federation: None,
    })
}

/// Which knobs mattered: refit a forest on the evaluated points and pull
/// split-gain importances (surrogate::importance), ranked descending.
pub(crate) fn importance_from_db(
    space: &ConfigSpace,
    db: &PerfDatabase,
    seed: u64,
) -> Vec<(String, f64)> {
    let usable: Vec<&EvalRecord> =
        db.records.iter().filter(|r| !r.timed_out && r.objective.is_finite()).collect();
    if usable.len() < 8 {
        return Vec::new();
    }
    let dim = space.dim();
    let mut x = Vec::with_capacity(usable.len() * dim);
    let mut y = Vec::with_capacity(usable.len());
    let mut row = vec![0.0f32; dim];
    for r in &usable {
        let idx: Vec<u32> = r.config_key.split(',').filter_map(|s| s.parse().ok()).collect();
        let cfg = Configuration::from_indices(idx);
        space.encode_into(&cfg, &mut row);
        x.extend_from_slice(&row);
        y.push(r.objective as f32);
    }
    let mut rng = Pcg32::seeded(seed ^ 0xfeed);
    let cfg = crate::surrogate::ForestConfig { n_trees: 32, ..Default::default() };
    let forest = crate::surrogate::RandomForest::fit(&x, &y, dim, &cfg, &mut rng);
    let imp = crate::surrogate::feature_importance(&forest, &x, &y);
    let names: Vec<&str> = space.params().iter().map(|p| p.name.as_str()).collect();
    crate::surrogate::ranked(&imp, &names)
        .into_iter()
        .map(|(n, v)| (n.to_string(), v))
        .collect()
}

impl TuneResult {
    /// Human-readable run summary (examples / CLI).
    pub fn summary(&self) -> String {
        let metric = self.setup.metric;
        let mut s = String::new();
        s.push_str(&format!(
            "== {} on {} x{} nodes | metric: {} | strategy evaluations: {} ==\n",
            self.setup.app.name(),
            self.setup.platform.name(),
            self.setup.nodes,
            metric.name(),
            self.evaluations,
        ));
        s.push_str(&format!(
            "space size: {} | scorer: {} | simulated wallclock: {:.0} s\n",
            self.space_size,
            if self.scorer_accelerated { "AOT/XLA" } else { "pure-Rust fallback" },
            self.wallclock_s,
        ));
        s.push_str(&format!(
            "baseline {}: {:.3} {} | best: {:.3} {} | improvement: {:.2}%\n",
            metric.name(),
            self.baseline_objective,
            metric.unit(),
            self.best_objective,
            metric.unit(),
            self.improvement_pct,
        ));
        s.push_str(&format!("best configuration: {}\n", self.best_config_desc));
        s.push_str(&format!("max ytopt overhead: {:.1} s\n", self.db.max_overhead_s()));
        if let Some(es) = &self.ensemble {
            s.push_str(&format!(
                "ensemble: {} workers | {} cycle | batch {} | liar {} | {} cycles | faults {} (retries {}, abandoned {}) | crashes {} | timeouts {} | stragglers cancelled {} | barrier idle {:.0} s | resumed {}\n",
                es.workers,
                es.cycle.name(),
                es.batch,
                es.liar.name(),
                es.batches,
                es.faults,
                es.retries,
                es.failed_evals,
                es.worker_crashes,
                es.timeouts,
                es.stragglers_cancelled,
                es.worker_idle_s,
                es.resumed_evals,
            ));
            if self.wallclock_s > 0.0 && es.serial_equivalent_s > 0.0 {
                s.push_str(&format!(
                    "ensemble wall-clock compression: {:.0} s vs {:.0} s serial-equivalent ({:.2}x)\n",
                    self.wallclock_s,
                    es.serial_equivalent_s,
                    es.serial_equivalent_s / self.wallclock_s,
                ));
            }
        }
        if let Some(fs) = &self.federation {
            s.push_str(&format!(
                "federation: {} shards | exchange every {} | {} elites | {} exchanges | {} foreign observations | exchange cost {:.1} s | per-shard evals {:?}\n",
                fs.shards,
                fs.exchange_every,
                fs.elite_n,
                fs.exchanges,
                fs.elites_absorbed,
                fs.exchange_s,
                fs.per_shard_evals,
            ));
        }
        if !self.param_importance.is_empty() {
            let top: Vec<String> = self
                .param_importance
                .iter()
                .take(4)
                .map(|(n, v)| format!("{n} ({:.0}%)", v * 100.0))
                .collect();
            s.push_str(&format!("most important parameters: {}\n", top.join(", ")));
        }
        s
    }

    /// Figure-style trace: one line per evaluation (wallclock, objective,
    /// best-so-far, overhead) — the series behind Figs 5–16.
    pub fn trace(&self) -> String {
        let mut s = String::from("eval wallclock_s objective best_so_far overhead_s\n");
        for r in &self.db.records {
            s.push_str(&format!(
                "{:4} {:10.1} {:12.4} {:12.4} {:8.1}{}\n",
                r.id,
                r.wallclock_s,
                r.objective,
                r.best_so_far,
                r.overhead_s,
                if r.timed_out { "  TIMEOUT" } else { "" },
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_setup(app: AppKind, platform: PlatformKind, nodes: u64, metric: Metric) -> TuneSetup {
        let mut s = TuneSetup::new(app, platform, nodes, metric);
        s.max_evals = 25;
        s.wallclock_budget_s = 1800.0;
        s.n_init = 6;
        s
    }

    #[test]
    fn tunes_xsbench_single_node_theta() {
        let setup = quick_setup(AppKind::XSBenchHistory, PlatformKind::Theta, 1, Metric::Runtime);
        let r = autotune_with_scorer(&setup, Arc::new(Scorer::fallback())).unwrap();
        assert!((r.baseline.runtime_s - 3.31).abs() < 0.02);
        assert!(r.best_objective < r.baseline_objective * 1.02, "tuning went backwards");
        assert!(r.evaluations > 5);
        assert!(r.db.max_overhead_s() <= 70.0, "overhead {}", r.db.max_overhead_s());
        assert_eq!(r.space_size, 51_840);
    }

    #[test]
    fn respects_wallclock_budget() {
        let mut setup =
            quick_setup(AppKind::XSBenchHistory, PlatformKind::Theta, 1, Metric::Runtime);
        setup.wallclock_budget_s = 200.0;
        setup.max_evals = 1000;
        let r = autotune_with_scorer(&setup, Arc::new(Scorer::fallback())).unwrap();
        // each eval costs ~40+ s: only a handful fit into 200 s
        assert!(r.evaluations <= 8, "{} evals", r.evaluations);
        // the last evaluation may start before the budget expires
        assert!(r.wallclock_s < 200.0 + 120.0);
    }

    #[test]
    fn sw4lite_theta_reproduces_the_91pct_improvement_band() {
        let mut setup = quick_setup(AppKind::Sw4lite, PlatformKind::Theta, 1024, Metric::Runtime);
        setup.max_evals = 30;
        setup.wallclock_budget_s = 1e9; // paper budget constraint off
        let r = autotune_with_scorer(&setup, Arc::new(Scorer::fallback())).unwrap();
        assert!((r.baseline.runtime_s - 171.595).abs() < 2.0);
        // the barrier knob is a coin-flip per sample: 30 evals find it
        assert!(r.improvement_pct > 85.0, "improvement {}", r.improvement_pct);
    }

    #[test]
    fn energy_metric_runs_geopm_pipeline_on_theta() {
        let mut setup = quick_setup(AppKind::Amg, PlatformKind::Theta, 256, Metric::Energy);
        setup.max_evals = 12;
        let r = autotune_with_scorer(&setup, Arc::new(Scorer::fallback())).unwrap();
        assert!(r.baseline.avg_node_energy_j.is_some());
        let rec = &r.db.records[0];
        assert!(rec.command.contains("geopmlaunch"), "{}", rec.command);
        assert!(rec.measured.avg_node_energy_j.unwrap_or(0.0) > 0.0);
    }

    #[test]
    fn energy_metric_rejected_on_summit() {
        let setup = quick_setup(AppKind::Amg, PlatformKind::Summit, 256, Metric::Energy);
        assert!(autotune_with_scorer(&setup, Arc::new(Scorer::fallback())).is_err());
    }

    #[test]
    fn timeout_extension_cuts_long_evaluations() {
        let mut setup = quick_setup(AppKind::Amg, PlatformKind::Theta, 4096, Metric::Runtime);
        setup.eval_timeout_s = Some(60.0); // AMG pathological corner ~1000 s
        setup.max_evals = 40;
        setup.wallclock_budget_s = 1e9;
        let r = autotune_with_scorer(&setup, Arc::new(Scorer::fallback())).unwrap();
        // no recorded wallclock span may include a >60 s application run
        for rec in &r.db.records {
            if rec.timed_out {
                assert!(!rec.measured.runtime_s.is_finite());
            } else {
                assert!(rec.measured.runtime_s <= 60.0);
            }
        }
    }

    #[test]
    fn parallel_evaluations_compress_wallclock() {
        let mk = |parallel| {
            let mut s = quick_setup(AppKind::Swfft, PlatformKind::Theta, 64, Metric::Runtime);
            s.max_evals = 16;
            s.parallel_evals = parallel;
            s.wallclock_budget_s = 1e9;
            autotune_with_scorer(&s, Arc::new(Scorer::fallback())).unwrap()
        };
        let seq = mk(1);
        let par = mk(4);
        assert_eq!(seq.evaluations, par.evaluations);
        // savings are straggler-limited (batch span = max over the batch;
        // low-thread-count samples run ~100 s), so expect a solid but not
        // 4x compression
        assert!(
            par.wallclock_s < seq.wallclock_s * 0.8,
            "parallel {} vs sequential {}",
            par.wallclock_s,
            seq.wallclock_s
        );
    }

    #[test]
    fn warm_start_runs() {
        // small-scale run first
        let mut small = quick_setup(AppKind::Amg, PlatformKind::Summit, 64, Metric::Runtime);
        small.max_evals = 15;
        small.wallclock_budget_s = 1e9;
        let r_small = autotune_with_scorer(&small, Arc::new(Scorer::fallback())).unwrap();
        // transfer to large scale
        let space = paper::build_space(AppKind::Amg, PlatformKind::Summit);
        let prior: Vec<(Configuration, f64)> = r_small
            .db
            .records
            .iter()
            .map(|rec| {
                let idx: Vec<u32> =
                    rec.config_key.split(',').map(|s| s.parse().unwrap()).collect();
                (Configuration::from_indices(idx), rec.objective)
            })
            .collect();
        let _ = space;
        let mut large = quick_setup(AppKind::Amg, PlatformKind::Summit, 4096, Metric::Runtime);
        large.max_evals = 15;
        large.wallclock_budget_s = 1e9;
        large.warm_start = Some(crate::history::rescale(
            &prior,
            r_small.baseline_objective,
            9.0, // approx large-scale baseline
        ));
        let r_large = autotune_with_scorer(&large, Arc::new(Scorer::fallback())).unwrap();
        assert!(r_large.improvement_pct > 0.0);
    }

    #[test]
    fn importance_identifies_the_sw4lite_barrier() {
        let mut s = quick_setup(AppKind::Sw4lite, PlatformKind::Theta, 1024, Metric::Runtime);
        s.max_evals = 30;
        s.wallclock_budget_s = 1e9;
        let r = autotune_with_scorer(&s, Arc::new(Scorer::fallback())).unwrap();
        assert!(!r.param_importance.is_empty());
        // the barrier toggle dominates the Theta landscape
        assert_eq!(r.param_importance[0].0, "mpi_barrier_0", "{:?}", &r.param_importance[..3]);
        assert!(r.param_importance[0].1 > 0.5);
    }

    #[test]
    fn power_cap_trades_runtime_for_power() {
        let mk = |cap: Option<f64>| {
            let mut s = quick_setup(AppKind::Amg, PlatformKind::Theta, 256, Metric::Energy);
            s.max_evals = 8;
            s.power_cap_w = cap;
            autotune_with_scorer(&s, Arc::new(Scorer::fallback())).unwrap()
        };
        let free = mk(None);
        let capped = mk(Some(150.0));
        // capped baseline runs longer but draws less power
        assert!(capped.baseline.runtime_s > free.baseline.runtime_s);
        let p_free = free.baseline.avg_node_energy_j.unwrap() / free.baseline.runtime_s;
        let p_cap = capped.baseline.avg_node_energy_j.unwrap() / capped.baseline.runtime_s;
        assert!(p_cap < p_free, "avg power {p_cap} !< {p_free}");
    }

    #[test]
    fn node_hours_budget_ends_the_run_early() {
        let mut s = quick_setup(AppKind::Swfft, PlatformKind::Theta, 4096, Metric::Runtime);
        s.max_evals = 100;
        s.wallclock_budget_s = 1e9;
        // ~45 s/eval x 4096 nodes ≈ 51 node-hours each; budget 160 ≈ 3 evals
        s.node_hours_budget = Some(160.0);
        let r = autotune_with_scorer(&s, Arc::new(Scorer::fallback())).unwrap();
        assert!(r.evaluations <= 4, "{} evals", r.evaluations);
        assert!(r.evaluations >= 2);
    }

    #[test]
    fn random_and_grid_strategies_run() {
        for kind in [StrategyKind::Random, StrategyKind::Grid, StrategyKind::Mctree] {
            let mut s = quick_setup(AppKind::Swfft, PlatformKind::Summit, 4096, Metric::Runtime);
            s.strategy = kind;
            s.max_evals = 10;
            let r = autotune_with_scorer(&s, Arc::new(Scorer::fallback())).unwrap();
            assert_eq!(r.evaluations, 10);
        }
    }
}
