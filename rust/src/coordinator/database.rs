//! The performance database (paper Fig. 1/4, Step 5): every evaluated
//! configuration with its metrics, timing breakdown, and launch command.

use crate::metrics::{Measured, Metric};
use crate::util::Json;

/// One evaluation's record.
#[derive(Debug, Clone)]
pub struct EvalRecord {
    pub id: usize,
    /// Configuration key (value indices) and human-readable description.
    pub config_key: String,
    pub config_desc: String,
    /// The generated aprun/jsrun (possibly geopmlaunch-wrapped) line.
    pub command: String,
    pub measured: Measured,
    /// The scalar objective minimized in this run.
    pub objective: f64,
    /// Timing breakdown (ytopt definitions; see coordinator::overhead).
    pub compile_s: f64,
    pub processing_s: f64,
    pub overhead_s: f64,
    /// Simulated wall-clock time at which this evaluation finished.
    pub wallclock_s: f64,
    /// Best objective seen up to and including this evaluation.
    pub best_so_far: f64,
    /// Evaluation hit the timeout (extension feature, §VIII).
    pub timed_out: bool,
}

/// Append-only store of evaluations for one autotuning run.
#[derive(Debug, Clone, Default)]
pub struct PerfDatabase {
    pub records: Vec<EvalRecord>,
}

impl PerfDatabase {
    pub fn new() -> Self {
        PerfDatabase { records: Vec::new() }
    }

    pub fn push(&mut self, rec: EvalRecord) {
        self.records.push(rec);
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Best (lowest-objective) record, ignoring timed-out evaluations.
    pub fn best(&self) -> Option<&EvalRecord> {
        self.records
            .iter()
            .filter(|r| !r.timed_out && r.objective.is_finite())
            .min_by(|a, b| a.objective.partial_cmp(&b.objective).unwrap())
    }

    /// Maximum per-evaluation overhead (Table IV row entries).
    pub fn max_overhead_s(&self) -> f64 {
        self.records.iter().map(|r| r.overhead_s).fold(0.0, f64::max)
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "id,objective,runtime_s,energy_j,edp_js,compile_s,processing_s,overhead_s,wallclock_s,best_so_far,timed_out,config\n",
        );
        for r in &self.records {
            s.push_str(&format!(
                "{},{:.6},{:.6},{},{},{:.3},{:.3},{:.3},{:.3},{:.6},{},\"{}\"\n",
                r.id,
                r.objective,
                r.measured.runtime_s,
                r.measured.avg_node_energy_j.map(|e| format!("{e:.3}")).unwrap_or_default(),
                r.measured.edp_js.map(|e| format!("{e:.3}")).unwrap_or_default(),
                r.compile_s,
                r.processing_s,
                r.overhead_s,
                r.wallclock_s,
                r.best_so_far,
                r.timed_out,
                r.config_desc.replace('"', "'"),
            ));
        }
        s
    }

    pub fn to_json(&self, metric: Metric) -> Json {
        Json::obj(vec![
            ("metric", metric.name().into()),
            (
                "records",
                Json::Arr(
                    self.records
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("id", r.id.into()),
                                ("objective", r.objective.into()),
                                ("runtime_s", r.measured.runtime_s.into()),
                                (
                                    "energy_j",
                                    r.measured
                                        .avg_node_energy_j
                                        .map(Json::from)
                                        .unwrap_or(Json::Null),
                                ),
                                ("overhead_s", r.overhead_s.into()),
                                ("wallclock_s", r.wallclock_s.into()),
                                ("best_so_far", r.best_so_far.into()),
                                ("timed_out", r.timed_out.into()),
                                ("config", r.config_desc.as_str().into()),
                                ("command", r.command.as_str().into()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: usize, objective: f64, overhead: f64, timed_out: bool) -> EvalRecord {
        EvalRecord {
            id,
            config_key: format!("k{id}"),
            config_desc: format!("threads={id}"),
            command: "aprun ...".into(),
            measured: Measured::runtime_only(objective),
            objective,
            compile_s: 2.0,
            processing_s: 50.0,
            overhead_s: overhead,
            wallclock_s: id as f64 * 60.0,
            best_so_far: objective,
            timed_out,
        }
    }

    #[test]
    fn best_ignores_timeouts() {
        let mut db = PerfDatabase::new();
        db.push(rec(0, 5.0, 40.0, false));
        db.push(rec(1, 1.0, 45.0, true)); // timed out: excluded
        db.push(rec(2, 3.0, 42.0, false));
        assert_eq!(db.best().unwrap().id, 2);
        assert_eq!(db.max_overhead_s(), 45.0);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut db = PerfDatabase::new();
        db.push(rec(0, 5.0, 40.0, false));
        let csv = db.to_csv();
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.starts_with("id,objective"));
        assert!(csv.contains("threads=0"));
    }

    #[test]
    fn json_roundtrips_through_parser() {
        let mut db = PerfDatabase::new();
        db.push(rec(0, 5.0, 40.0, false));
        db.push(rec(1, 4.0, 41.0, false));
        let j = db.to_json(Metric::Runtime).to_string();
        let v = crate::util::Json::parse(&j).unwrap();
        assert_eq!(v.get("records").and_then(|r| r.as_arr()).map(|a| a.len()), Some(2));
    }
}
