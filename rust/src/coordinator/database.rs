//! The performance database (paper Fig. 1/4, Step 5): every evaluated
//! configuration with its metrics, timing breakdown, and launch command.

use crate::metrics::{Measured, Metric};
use crate::util::Json;

/// One evaluation's record.
#[derive(Debug, Clone)]
pub struct EvalRecord {
    pub id: usize,
    /// Configuration key (value indices) and human-readable description.
    pub config_key: String,
    pub config_desc: String,
    /// The generated aprun/jsrun (possibly geopmlaunch-wrapped) line.
    pub command: String,
    pub measured: Measured,
    /// The scalar objective minimized in this run.
    pub objective: f64,
    /// Timing breakdown (ytopt definitions; see coordinator::overhead).
    pub compile_s: f64,
    pub processing_s: f64,
    pub overhead_s: f64,
    /// Simulated wall-clock time at which this evaluation finished.
    pub wallclock_s: f64,
    /// Best objective seen up to and including this evaluation.
    pub best_so_far: f64,
    /// Evaluation hit the timeout (extension feature, §VIII).
    pub timed_out: bool,
    /// Evaluation was cancelled by the ensemble's straggler policy (the
    /// run exceeded the batch-median multiple; also sets `timed_out`).
    pub cancelled: bool,
}

impl EvalRecord {
    /// Full-fidelity serialization for the ensemble checkpoint (unlike
    /// [`PerfDatabase::to_json`], which is a report view). Non-finite
    /// numbers (timed-out runtimes) round-trip through JSON `null`.
    pub fn to_json_full(&self) -> Json {
        let num = |v: f64| if v.is_finite() { Json::Num(v) } else { Json::Null };
        Json::obj(vec![
            ("id", self.id.into()),
            ("config_key", self.config_key.as_str().into()),
            ("config_desc", self.config_desc.as_str().into()),
            ("command", self.command.as_str().into()),
            ("runtime_s", num(self.measured.runtime_s)),
            ("energy_j", self.measured.avg_node_energy_j.map(Json::from).unwrap_or(Json::Null)),
            ("edp_js", self.measured.edp_js.map(Json::from).unwrap_or(Json::Null)),
            ("objective", num(self.objective)),
            ("compile_s", num(self.compile_s)),
            ("processing_s", num(self.processing_s)),
            ("overhead_s", num(self.overhead_s)),
            ("wallclock_s", num(self.wallclock_s)),
            ("best_so_far", num(self.best_so_far)),
            ("timed_out", self.timed_out.into()),
            ("cancelled", self.cancelled.into()),
        ])
    }

    /// Inverse of [`EvalRecord::to_json_full`].
    pub fn from_json_full(v: &Json) -> anyhow::Result<EvalRecord> {
        let s = |key: &str| -> anyhow::Result<String> {
            v.get(key)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow::anyhow!("record missing string field `{key}`"))
        };
        // absent or null numeric fields read back as +inf (timed out)
        let f = |key: &str| v.get(key).and_then(Json::as_f64).unwrap_or(f64::INFINITY);
        let b = |key: &str| v.get(key).and_then(Json::as_bool).unwrap_or(false);
        let id = v
            .get("id")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow::anyhow!("record missing `id`"))? as usize;
        Ok(EvalRecord {
            id,
            config_key: s("config_key")?,
            config_desc: s("config_desc")?,
            command: s("command")?,
            measured: Measured {
                runtime_s: f("runtime_s"),
                avg_node_energy_j: v.get("energy_j").and_then(Json::as_f64),
                edp_js: v.get("edp_js").and_then(Json::as_f64),
            },
            objective: f("objective"),
            compile_s: f("compile_s"),
            processing_s: f("processing_s"),
            overhead_s: f("overhead_s"),
            wallclock_s: f("wallclock_s"),
            best_so_far: f("best_so_far"),
            timed_out: b("timed_out"),
            cancelled: b("cancelled"),
        })
    }
}

/// Append-only store of evaluations for one autotuning run.
#[derive(Debug, Clone, Default)]
pub struct PerfDatabase {
    pub records: Vec<EvalRecord>,
}

impl PerfDatabase {
    pub fn new() -> Self {
        PerfDatabase { records: Vec::new() }
    }

    pub fn push(&mut self, rec: EvalRecord) {
        self.records.push(rec);
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Best (lowest-objective) record, ignoring timed-out evaluations.
    pub fn best(&self) -> Option<&EvalRecord> {
        self.records
            .iter()
            .filter(|r| !r.timed_out && r.objective.is_finite())
            .min_by(|a, b| a.objective.total_cmp(&b.objective))
    }

    /// Maximum per-evaluation overhead (Table IV row entries).
    pub fn max_overhead_s(&self) -> f64 {
        self.records.iter().map(|r| r.overhead_s).fold(0.0, f64::max)
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "id,objective,runtime_s,energy_j,edp_js,compile_s,processing_s,overhead_s,wallclock_s,best_so_far,timed_out,cancelled,config\n",
        );
        for r in &self.records {
            s.push_str(&format!(
                "{},{:.6},{:.6},{},{},{:.3},{:.3},{:.3},{:.3},{:.6},{},{},\"{}\"\n",
                r.id,
                r.objective,
                r.measured.runtime_s,
                r.measured.avg_node_energy_j.map(|e| format!("{e:.3}")).unwrap_or_default(),
                r.measured.edp_js.map(|e| format!("{e:.3}")).unwrap_or_default(),
                r.compile_s,
                r.processing_s,
                r.overhead_s,
                r.wallclock_s,
                r.best_so_far,
                r.timed_out,
                r.cancelled,
                r.config_desc.replace('"', "'"),
            ));
        }
        s
    }

    pub fn to_json(&self, metric: Metric) -> Json {
        Json::obj(vec![
            ("metric", metric.name().into()),
            (
                "records",
                Json::Arr(
                    self.records
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("id", r.id.into()),
                                ("objective", r.objective.into()),
                                ("runtime_s", r.measured.runtime_s.into()),
                                (
                                    "energy_j",
                                    r.measured
                                        .avg_node_energy_j
                                        .map(Json::from)
                                        .unwrap_or(Json::Null),
                                ),
                                ("overhead_s", r.overhead_s.into()),
                                ("wallclock_s", r.wallclock_s.into()),
                                ("best_so_far", r.best_so_far.into()),
                                ("timed_out", r.timed_out.into()),
                                ("cancelled", r.cancelled.into()),
                                ("config", r.config_desc.as_str().into()),
                                ("command", r.command.as_str().into()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: usize, objective: f64, overhead: f64, timed_out: bool) -> EvalRecord {
        EvalRecord {
            id,
            config_key: format!("k{id}"),
            config_desc: format!("threads={id}"),
            command: "aprun ...".into(),
            measured: Measured::runtime_only(objective),
            objective,
            compile_s: 2.0,
            processing_s: 50.0,
            overhead_s: overhead,
            wallclock_s: id as f64 * 60.0,
            best_so_far: objective,
            timed_out,
            cancelled: false,
        }
    }

    #[test]
    fn best_ignores_timeouts() {
        let mut db = PerfDatabase::new();
        db.push(rec(0, 5.0, 40.0, false));
        db.push(rec(1, 1.0, 45.0, true)); // timed out: excluded
        db.push(rec(2, 3.0, 42.0, false));
        assert_eq!(db.best().unwrap().id, 2);
        assert_eq!(db.max_overhead_s(), 45.0);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut db = PerfDatabase::new();
        db.push(rec(0, 5.0, 40.0, false));
        let csv = db.to_csv();
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.starts_with("id,objective"));
        assert!(csv.contains("threads=0"));
    }

    #[test]
    fn full_record_json_roundtrips_including_infinities() {
        let mut r = rec(3, 7.5, 41.0, true);
        r.measured = Measured::runtime_only(f64::INFINITY); // timed out
        r.cancelled = true;
        let j = r.to_json_full().to_string();
        let v = crate::util::Json::parse(&j).unwrap();
        let back = EvalRecord::from_json_full(&v).unwrap();
        assert_eq!(back.id, 3);
        assert_eq!(back.config_key, r.config_key);
        assert_eq!(back.command, r.command);
        assert!(back.measured.runtime_s.is_infinite());
        assert_eq!(back.objective, 7.5);
        assert!(back.timed_out);
        assert!(back.cancelled);
        // a finite record round-trips exactly
        let r2 = rec(4, 2.25, 40.0, false);
        let back2 =
            EvalRecord::from_json_full(&crate::util::Json::parse(&r2.to_json_full().to_string()).unwrap())
                .unwrap();
        assert_eq!(back2.measured.runtime_s, 2.25);
        assert_eq!(back2.best_so_far, r2.best_so_far);
        assert!(!back2.timed_out);
    }

    #[test]
    fn from_json_full_rejects_garbage() {
        let v = crate::util::Json::parse(r#"{"id": 1}"#).unwrap();
        assert!(EvalRecord::from_json_full(&v).is_err());
        let v = crate::util::Json::parse(r#"{"config_key": "1,2"}"#).unwrap();
        assert!(EvalRecord::from_json_full(&v).is_err());
    }

    #[test]
    fn json_roundtrips_through_parser() {
        let mut db = PerfDatabase::new();
        db.push(rec(0, 5.0, 40.0, false));
        db.push(rec(1, 4.0, 41.0, false));
        let j = db.to_json(Metric::Runtime).to_string();
        let v = crate::util::Json::parse(&j).unwrap();
        assert_eq!(v.get("records").and_then(|r| r.as_arr()).map(|a| a.len()), Some(2));
    }
}
