//! ytopt processing-time / overhead accounting (paper §IV-A definition,
//! Table IV calibration).
//!
//! * **ytopt processing time** = parameter-space search + surrogate fit +
//!   code generation + launch-line generation + compile + application
//!   launch + database record (everything except the application run).
//! * **ytopt overhead** = processing time − compile time.
//!
//! Per-evaluation orchestration cost (Ray task setup, python interpreter
//! + file staging on the login node) is the dominant term the paper
//! observes (tens of seconds even though compiles take ~2 s); the first
//! evaluation additionally pays environment setup (conda; plus the nvhpc
//! module for the offload build). Constants are calibrated so the maxima
//! land on Table IV.

use crate::apps::AppKind;
use crate::platform::PlatformKind;
use crate::util::Pcg32;

/// Mean per-evaluation orchestration seconds (excluding launch/compile).
pub fn orchestration_s(app: AppKind, platform: PlatformKind, nodes: u64) -> f64 {
    use AppKind::*;
    use PlatformKind::*;
    match (app, platform) {
        (XSBenchMixed, Theta) => 44.0,
        (XSBenchHistory | XSBenchEvent, Theta) => 30.0,
        (XSBenchOffload, Theta) => 30.0,
        (Swfft, Theta) => 2.0,
        (Amg, Theta) => 6.0,
        (Sw4lite, Theta) => 16.0,
        // offload orchestration swells at scale (jsrun + GPU plumbing)
        (XSBenchOffload, Summit) => {
            if nodes >= 64 {
                40.0
            } else {
                10.0
            }
        }
        (XSBenchHistory | XSBenchEvent | XSBenchMixed, Summit) => 12.0,
        (Swfft, Summit) => 3.0,
        (Amg, Summit) => 2.0,
        (Sw4lite, Summit) => 5.0,
    }
}

/// Orchestration jitter half-width (seconds).
pub fn orchestration_jitter_s(app: AppKind, platform: PlatformKind) -> f64 {
    match (app, platform) {
        (AppKind::XSBenchMixed, PlatformKind::Theta) => 5.0,
        (AppKind::Swfft, PlatformKind::Theta) => 1.5,
        (AppKind::Amg, PlatformKind::Theta) => 3.0,
        (_, PlatformKind::Theta) => 4.0,
        (AppKind::XSBenchOffload, PlatformKind::Summit) => 3.0,
        (_, PlatformKind::Summit) => 2.0,
    }
}

/// One-time first-evaluation environment setup (conda env; nvhpc module
/// for the at-scale offload runs — paper Fig 5d / Fig 8b).
pub fn first_eval_setup_s(app: AppKind, platform: PlatformKind, nodes: u64) -> f64 {
    match (app, platform) {
        (AppKind::XSBenchOffload, PlatformKind::Summit) => {
            if nodes >= 64 {
                45.0
            } else {
                4.0
            }
        }
        (_, PlatformKind::Summit) => 22.0,
        (_, PlatformKind::Theta) => 8.0,
    }
}

/// One evaluation's orchestration sample.
pub fn sample_orchestration_s(
    app: AppKind,
    platform: PlatformKind,
    nodes: u64,
    rng: &mut Pcg32,
) -> f64 {
    let mean = orchestration_s(app, platform, nodes);
    let jitter = orchestration_jitter_s(app, platform);
    (mean + jitter * (2.0 * rng.f64() - 1.0)).max(0.5)
}

/// Ensemble-manager dispatch cost per evaluation (seconds): bounded-queue
/// hand-off, result collection, pending-point bookkeeping, and the
/// checkpoint append. The fixed part is the manager's per-result work;
/// the shared part (liar imputation + surrogate refit) amortizes across
/// the workers that are fed from one proposal cycle. Far cheaper than the
/// Ray per-task orchestration it replaces (tens of seconds, above).
pub fn ensemble_dispatch_s(workers: usize) -> f64 {
    0.6 + 2.4 / workers.max(1) as f64
}

/// Continuous-manager cost per completion (seconds): amend the pending
/// lie by index, refit/propose exactly one replacement candidate,
/// dispatch it to the freed worker, and append the checkpoint. Cheaper
/// than the generational cycle's per-evaluation share
/// ([`ensemble_dispatch_s`]) because there is no batch assembly or
/// barrier collection bookkeeping — the event loop touches one result
/// at a time.
pub fn continuous_completion_s(workers: usize) -> f64 {
    0.5 + 2.0 / workers.max(1) as f64
}

/// Federation elite-exchange cost per round (seconds), charged to every
/// participating shard: serialize the shard's top-N history entries,
/// all-to-all broadcast among the K managers (each shard sends one
/// message to and receives one from each of the K-1 peers), and absorb
/// the foreign observations into the local surrogate. Linear in the
/// peer count and in the elite width, with a fixed synchronization
/// floor; zero when there is nothing to exchange (K <= 1). Stays well
/// under a single evaluation's orchestration cost at the paper's scales
/// — the federation must never pay more to coordinate than it saves by
/// sharding.
pub fn federation_exchange_s(shards: usize, elites: usize) -> f64 {
    if shards <= 1 {
        return 0.0;
    }
    0.2 + 0.02 * (shards - 1) as f64 * elites.max(1) as f64
}

/// Table IV: expected maximum ytopt overhead (s) per app and system.
pub fn table4_max_overhead_s(app: AppKind, platform: PlatformKind) -> f64 {
    use AppKind::*;
    use PlatformKind::*;
    match (app, platform) {
        (XSBenchMixed, Theta) => 70.0,
        (XSBenchHistory | XSBenchEvent, Theta) => 69.0,
        (XSBenchOffload, Theta) => 69.0,
        (Swfft, Theta) => 30.0,
        (Amg, Theta) => 34.0,
        (Sw4lite, Theta) => 46.0,
        (XSBenchMixed, Summit) => 24.0, // Fig 6b (offload, single node)
        (XSBenchHistory | XSBenchEvent | XSBenchOffload, Summit) => 111.0, // Fig 8b
        (Swfft, Summit) => 50.0,
        (Amg, Summit) => 45.0,
        (Sw4lite, Summit) => 46.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::launch::launch_overhead_s;

    /// The calibrated components must keep the per-evaluation overhead
    /// under the Table IV maxima at the scales the paper ran.
    #[test]
    fn calibration_respects_table4_maxima() {
        let cases: [(AppKind, PlatformKind, u64); 9] = [
            (AppKind::XSBenchMixed, PlatformKind::Theta, 1),
            (AppKind::XSBenchEvent, PlatformKind::Theta, 4096),
            (AppKind::Swfft, PlatformKind::Theta, 4096),
            (AppKind::Amg, PlatformKind::Theta, 4096),
            (AppKind::Sw4lite, PlatformKind::Theta, 1024),
            (AppKind::XSBenchOffload, PlatformKind::Summit, 4096),
            (AppKind::Swfft, PlatformKind::Summit, 4096),
            (AppKind::Amg, PlatformKind::Summit, 4096),
            (AppKind::Sw4lite, PlatformKind::Summit, 1024),
        ];
        for (app, pf, nodes) in cases {
            let worst = orchestration_s(app, pf, nodes)
                + orchestration_jitter_s(app, pf)
                + launch_overhead_s(pf, nodes)
                + first_eval_setup_s(app, pf, nodes)
                + 1.5; // search + codegen + record slack
            let cap = table4_max_overhead_s(app, pf);
            assert!(worst <= cap + 0.5, "{app:?}@{pf:?}/{nodes}: worst {worst:.1} > cap {cap}");
        }
        // Fig 6b: offload on ONE Summit node stays under 24 s
        let worst = orchestration_s(AppKind::XSBenchOffload, PlatformKind::Summit, 1)
            + orchestration_jitter_s(AppKind::XSBenchOffload, PlatformKind::Summit)
            + launch_overhead_s(PlatformKind::Summit, 1)
            + first_eval_setup_s(AppKind::XSBenchOffload, PlatformKind::Summit, 1)
            + 1.5;
        assert!(worst <= 24.5, "single-node offload worst {worst:.1}");
    }

    #[test]
    fn overhead_scales_weakly_with_nodes() {
        // the paper's "low overhead and good scalability" claim: going
        // 1 -> 4096 nodes must not blow up the overhead
        for pf in [PlatformKind::Theta, PlatformKind::Summit] {
            let small = launch_overhead_s(pf, 1);
            let large = launch_overhead_s(pf, 4096);
            assert!(large - small < 15.0, "{pf:?}: {small} -> {large}");
        }
    }

    #[test]
    fn ensemble_dispatch_amortizes_with_workers() {
        let one = ensemble_dispatch_s(1);
        let eight = ensemble_dispatch_s(8);
        assert!(eight < one, "{eight} !< {one}");
        // always well under the serial per-evaluation orchestration costs
        assert!(one <= 3.5 && eight >= 0.6, "one={one} eight={eight}");
        // degenerate input does not divide by zero
        assert!(ensemble_dispatch_s(0).is_finite());
    }

    #[test]
    fn continuous_completion_undercuts_the_generational_dispatch() {
        for workers in [1usize, 2, 4, 8, 64] {
            let cont = continuous_completion_s(workers);
            let gen = ensemble_dispatch_s(workers);
            assert!(cont < gen, "workers={workers}: continuous {cont} !< generational {gen}");
            assert!(cont > 0.0);
        }
        // degenerate input does not divide by zero
        assert!(continuous_completion_s(0).is_finite());
    }

    #[test]
    fn federation_exchange_is_cheap_and_scales_with_policy() {
        // nothing to exchange with one (or zero) managers
        assert_eq!(federation_exchange_s(0, 8), 0.0);
        assert_eq!(federation_exchange_s(1, 8), 0.0);
        // monotone in both shard count and elite width
        assert!(federation_exchange_s(2, 3) > 0.0);
        assert!(federation_exchange_s(8, 3) > federation_exchange_s(2, 3));
        assert!(federation_exchange_s(4, 16) > federation_exchange_s(4, 2));
        // a zero-elite exchange still pays the synchronization floor
        assert!(federation_exchange_s(4, 0) > 0.0);
        // typical policies stay under a second — far below the tens of
        // seconds one evaluation's orchestration costs
        assert!(federation_exchange_s(4, 3) < 1.0);
        assert!(federation_exchange_s(8, 8) < 2.0);
    }

    #[test]
    fn sampling_stays_in_band() {
        let mut rng = Pcg32::seeded(1);
        for _ in 0..200 {
            let s = sample_orchestration_s(AppKind::Amg, PlatformKind::Theta, 4096, &mut rng);
            assert!((2.5..=9.5).contains(&s), "{s}");
        }
    }
}
