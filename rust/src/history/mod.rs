//! Cross-run tuning-history database with transfer-learning warm starts
//! (paper §VIII future work; the Sid-Lakhdar et al. multitask-transfer
//! and Wu et al. ytopt+libEnsemble directions).
//!
//! Every completed autotuning run — serial, ensemble, or federated —
//! can append one durable [`RunRecord`] (space fingerprint, app/scale
//! metadata, the full evaluation history, best-so-far, wall-clock and
//! energy stats) to a [`HistoryStore`] directory. A later run at any
//! scale looks up records with a *compatible space fingerprint*, picks
//! the nearest source scale, extracts the top-K elites, rescales their
//! objectives by the target/source baseline ratio (the ordering
//! structure of the landscape is what transfers), and feeds them to the
//! search through `BayesianOptimizer::warm_start_from_history` — the
//! index-keyed `observe_foreign` world, so warmed observations are
//! recorded in the surrogate but never re-proposed, exactly like
//! federation elites.
//!
//! Durability contract: appends are atomic (write a sibling temp file,
//! rename over the final name — the same discipline as
//! `ensemble::Checkpoint::save`), and a truncated or garbage record is
//! skipped with a warning during the store scan, never aborting it: one
//! corrupt file must not poison every future warm start.

use std::path::{Path, PathBuf};

use crate::coordinator::{TuneResult, TuneSetup};
use crate::runtime::Scorer;
use crate::space::{paper, ConfigSpace, Configuration};
use crate::util::Json;
use anyhow::{Context, Result};

/// Identity of a search space for cross-run compatibility: the space
/// name plus every parameter's name and cardinality. Two runs may
/// exchange observations only when these match — a configuration key is
/// a vector of value *indices*, meaningless under any other layout.
pub fn space_fingerprint(space: &ConfigSpace) -> String {
    let params: Vec<String> = space
        .params()
        .iter()
        .map(|p| format!("{}:{}", p.name, p.domain.cardinality()))
        .collect();
    format!("{}|{}d|{}|{}", space.name(), space.dim(), space.size(), params.join(","))
}

/// One evaluation inside a [`RunRecord`] — the transferable slice of an
/// `EvalRecord` (non-finite numbers round-trip through JSON `null`,
/// reading back as +inf, the same convention the checkpoint uses).
#[derive(Debug, Clone, PartialEq)]
pub struct HistoryEval {
    pub config_key: String,
    pub objective: f64,
    pub runtime_s: f64,
    pub energy_j: Option<f64>,
    pub timed_out: bool,
}

impl HistoryEval {
    fn to_json(&self) -> Json {
        let num = |v: f64| if v.is_finite() { Json::Num(v) } else { Json::Null };
        Json::obj(vec![
            ("config_key", self.config_key.as_str().into()),
            ("objective", num(self.objective)),
            ("runtime_s", num(self.runtime_s)),
            ("energy_j", self.energy_j.map(Json::from).unwrap_or(Json::Null)),
            ("timed_out", self.timed_out.into()),
        ])
    }

    fn from_json(v: &Json) -> Result<HistoryEval> {
        let config_key = v
            .get("config_key")
            .and_then(Json::as_str)
            .context("history eval missing `config_key`")?
            .to_string();
        let f = |key: &str| v.get(key).and_then(Json::as_f64).unwrap_or(f64::INFINITY);
        Ok(HistoryEval {
            config_key,
            objective: f("objective"),
            runtime_s: f("runtime_s"),
            energy_j: v.get("energy_j").and_then(Json::as_f64),
            timed_out: v.get("timed_out").and_then(Json::as_bool).unwrap_or(false),
        })
    }
}

/// One completed tuning run in the cross-run history database.
#[derive(Debug, Clone, PartialEq)]
pub struct RunRecord {
    /// [`space_fingerprint`] of the run's search space (compatibility key).
    pub space_fingerprint: String,
    pub app: String,
    pub platform: String,
    /// The scale this run tuned at (nearest-scale source selection).
    pub nodes: u64,
    pub metric: String,
    pub seed: u64,
    /// Default-configuration objective at this scale (the rescale anchor).
    pub baseline_objective: f64,
    pub best_objective: f64,
    pub best_config_key: String,
    /// Simulated campaign wall-clock.
    pub wallclock_s: f64,
    /// Full evaluation history, in eval-id order.
    pub evals: Vec<HistoryEval>,
}

impl RunRecord {
    /// Capture the transferable view of a finished run.
    pub fn from_result(result: &TuneResult) -> RunRecord {
        let setup = &result.setup;
        let space = paper::build_space(setup.app, setup.platform);
        RunRecord {
            space_fingerprint: space_fingerprint(&space),
            app: setup.app.name().to_string(),
            platform: setup.platform.name().to_string(),
            nodes: setup.nodes,
            metric: setup.metric.name().to_string(),
            seed: setup.seed,
            baseline_objective: result.baseline_objective,
            best_objective: result.best_objective,
            best_config_key: result
                .db
                .best()
                .map(|r| r.config_key.clone())
                .unwrap_or_default(),
            wallclock_s: result.wallclock_s,
            evals: result
                .db
                .records
                .iter()
                .map(|r| HistoryEval {
                    config_key: r.config_key.clone(),
                    objective: r.objective,
                    runtime_s: r.measured.runtime_s,
                    energy_j: r.measured.avg_node_energy_j,
                    timed_out: r.timed_out,
                })
                .collect(),
        }
    }

    pub fn to_json(&self) -> Json {
        let num = |v: f64| if v.is_finite() { Json::Num(v) } else { Json::Null };
        Json::obj(vec![
            ("version", 1u64.into()),
            ("kind", "run-record".into()),
            ("space_fingerprint", self.space_fingerprint.as_str().into()),
            ("app", self.app.as_str().into()),
            ("platform", self.platform.as_str().into()),
            ("nodes", self.nodes.into()),
            ("metric", self.metric.as_str().into()),
            // hex-encoded: JSON numbers are f64 and cannot carry a full
            // u64 seed losslessly (same convention as the checkpoint's
            // persisted RNG words)
            ("seed", format!("{:016x}", self.seed).into()),
            ("baseline_objective", num(self.baseline_objective)),
            ("best_objective", num(self.best_objective)),
            ("best_config_key", self.best_config_key.as_str().into()),
            ("wallclock_s", num(self.wallclock_s)),
            ("evals", Json::Arr(self.evals.iter().map(HistoryEval::to_json).collect())),
        ])
    }

    pub fn parse(text: &str) -> Result<RunRecord> {
        let v = Json::parse(text).context("parsing run record")?;
        anyhow::ensure!(
            v.get("kind").and_then(Json::as_str) == Some("run-record"),
            "not a run record (missing `kind`)"
        );
        let s = |key: &str| -> Result<String> {
            v.get(key)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow::anyhow!("run record missing string field `{key}`"))
        };
        let f = |key: &str| v.get(key).and_then(Json::as_f64).unwrap_or(f64::INFINITY);
        let evals = v
            .get("evals")
            .and_then(Json::as_arr)
            .context("run record missing `evals`")?
            .iter()
            .map(HistoryEval::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(RunRecord {
            space_fingerprint: s("space_fingerprint")?,
            app: s("app")?,
            platform: s("platform")?,
            nodes: v.get("nodes").and_then(Json::as_u64).context("run record missing `nodes`")?,
            metric: s("metric")?,
            seed: v
                .get("seed")
                .and_then(Json::as_str)
                .and_then(|s| u64::from_str_radix(s, 16).ok())
                .unwrap_or(0),
            baseline_objective: f("baseline_objective"),
            best_objective: f("best_objective"),
            best_config_key: s("best_config_key")?,
            wallclock_s: f("wallclock_s"),
            evals,
        })
    }

    /// Content-derived identifier (FNV-1a over the serialized record):
    /// appending the same run twice is idempotent, and no wall-clock or
    /// counter enters the store (determinism across replays).
    pub fn run_id(&self) -> String {
        let text = self.to_json().to_string();
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for b in text.as_bytes() {
            h = (h ^ *b as u64).wrapping_mul(0x100_0000_01b3);
        }
        format!("{h:016x}")
    }
}

/// A directory of [`RunRecord`] files (`run-<content-hash>.json`),
/// appended atomically and scanned leniently.
#[derive(Debug, Clone)]
pub struct HistoryStore {
    dir: PathBuf,
    /// Chaos failpoint plan armed on the append path (tests and the
    /// chaos soak; production opens leave this unset).
    chaos: Option<std::sync::Arc<crate::chaos::FaultPlan>>,
}

impl HistoryStore {
    /// Open (creating if needed) the store directory — the append path.
    /// Sweeps temp files orphaned by writers that crashed mid-append
    /// (the embedded-pid naming spares live writers' temps).
    pub fn open(dir: &Path) -> Result<HistoryStore> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating history store {}", dir.display()))?;
        crate::chaos::fsx::sweep_orphan_tmps(dir);
        Ok(HistoryStore { dir: dir.to_path_buf(), chaos: None })
    }

    /// Arm the append path with a chaos failpoint plan.
    pub fn with_chaos(mut self, plan: std::sync::Arc<crate::chaos::FaultPlan>) -> HistoryStore {
        self.chaos = Some(plan);
        self
    }

    /// Open an existing store without creating anything: the read-only
    /// warm-start path must not mkdir a mistyped `--warm-start-from`
    /// directory as a side effect, and a missing store should say so
    /// instead of reporting itself as empty.
    pub fn open_existing(dir: &Path) -> Result<HistoryStore> {
        anyhow::ensure!(
            dir.is_dir(),
            "history store {} does not exist (check the warm-start path)",
            dir.display()
        );
        Ok(HistoryStore { dir: dir.to_path_buf(), chaos: None })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Append one run record atomically, **safe under concurrent
    /// writers** (a daemon finishes many campaigns at once): write a
    /// writer-unique temp file, rename over the content-hashed final
    /// name, then *audit* the installed file.
    ///
    /// * Same content racing itself is idempotent: both writers rename
    ///   byte-identical files over the same name and both audits pass.
    /// * A content-hash collision (different content, same `run_id`) is
    ///   detected by the audit — never silently clobbered — and retried
    ///   under a salted name (`run-<id>-<n>.json`), so both records
    ///   survive in the store.
    /// * A crash mid-write leaves only a temp file, which the scan
    ///   ignores; the store never holds a half record under a final
    ///   name.
    pub fn append(&self, rec: &RunRecord) -> Result<PathBuf> {
        let text = rec.to_json().to_string();
        let id = rec.run_id();
        // writer-unique temp name: two threads (or processes) appending
        // concurrently must never interleave writes into one temp file
        static TMP_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let tmp = self.dir.join(format!(
            "run-{id}.{}-{}.tmp",
            std::process::id(),
            TMP_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
        ));
        // the temp write is audited *before* install: a short write
        // (torn page, injected ENOSPC) must never reach a final name,
        // and transient faults retry under capped deterministic backoff
        let plan = self.chaos.as_deref();
        let written =
            crate::chaos::with_retries(plan, crate::chaos::Site::HistoryWrite.name(), |_| {
                crate::chaos::fsx::write_file(
                    &tmp,
                    text.as_bytes(),
                    plan,
                    crate::chaos::Site::HistoryWrite,
                )?;
                let back = std::fs::read(&tmp)
                    .with_context(|| format!("auditing run-record temp {}", tmp.display()))?;
                anyhow::ensure!(
                    back == text.as_bytes(),
                    "run-record temp {} is short ({} of {} bytes) — rejected before install",
                    tmp.display(),
                    back.len(),
                    text.len()
                );
                Ok(())
            });
        let outcome = written.and_then(|()| self.install(&tmp, &text, &id));
        // the temp file never outlives the append: `install` only links
        // it under final names, so success and failure both drop it here
        let _ = std::fs::remove_file(&tmp);
        outcome
    }

    /// Install an already-written temp file under its content-hashed
    /// final name via `hard_link` — which *fails* on an existing
    /// destination, so no interleaving of writers can ever clobber an
    /// installed record (a plain rename-over would lose one side of a
    /// same-name race). Occupied names are audited: identical bytes mean
    /// an idempotent re-append (done); different bytes mean a content-
    /// hash collision, retried under a salted `run-<id>-<n>.json` name
    /// so both records survive.
    fn install(&self, tmp: &Path, text: &str, id: &str) -> Result<PathBuf> {
        for attempt in 0..16u32 {
            let name = if attempt == 0 {
                format!("run-{id}.json")
            } else {
                format!("run-{id}-{attempt}.json")
            };
            let path = self.dir.join(&name);
            match std::fs::hard_link(tmp, &path) {
                Ok(()) => {
                    // audit: exclusive creation succeeded, so the link
                    // target is our temp file by construction; verify
                    // anyway so a broken filesystem can never plant a
                    // wrong record silently
                    let installed = std::fs::read_to_string(&path).with_context(|| {
                        format!("auditing installed run record {}", path.display())
                    })?;
                    anyhow::ensure!(
                        installed == text,
                        "history append audit failed: {} does not hold the appended record",
                        path.display()
                    );
                    return Ok(path);
                }
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    match std::fs::read_to_string(&path) {
                        Ok(existing) if existing == text => return Ok(path), // idempotent
                        Ok(_) => {
                            log::warn!(
                                "history store: {} occupied by different content \
                                 (run_id collision); retrying under a salted name",
                                path.display()
                            );
                            continue;
                        }
                        // racing writer mid-settle or unreadable file:
                        // try the next salted name rather than abort
                        Err(_) => continue,
                    }
                }
                Err(e) => {
                    return Err(e)
                        .with_context(|| format!("installing run record {}", path.display()))
                }
            }
        }
        anyhow::bail!(
            "history store {}: could not place run {id} after 16 salted attempts",
            self.dir.display()
        )
    }

    /// Every readable run record, in file-name order (deterministic).
    /// Truncated or garbage files are skipped with a warning — a corrupt
    /// record must not abort the scan.
    pub fn load_all(&self) -> Result<Vec<RunRecord>> {
        let mut names: Vec<PathBuf> = Vec::new();
        for entry in std::fs::read_dir(&self.dir)
            .with_context(|| format!("scanning history store {}", self.dir.display()))?
        {
            let path = entry?.path();
            let is_record = path
                .file_name()
                .and_then(|n| n.to_str())
                .map(|n| n.ends_with(".json"))
                .unwrap_or(false);
            if is_record && path.is_file() {
                names.push(path);
            }
        }
        names.sort();
        let mut out = Vec::with_capacity(names.len());
        for path in names {
            let parsed = std::fs::read_to_string(&path)
                .map_err(anyhow::Error::from)
                .and_then(|text| RunRecord::parse(&text));
            match parsed {
                Ok(rec) => out.push(rec),
                Err(e) => {
                    log::warn!("skipping corrupt history record {}: {e:#}", path.display())
                }
            }
        }
        Ok(out)
    }

    /// Records whose space fingerprint matches `fp` exactly.
    pub fn compatible(&self, fp: &str) -> Result<Vec<RunRecord>> {
        Ok(self.load_all()?.into_iter().filter(|r| r.space_fingerprint == fp).collect())
    }
}

/// The subset of `records` tuned at the scale nearest `target_nodes`
/// (log-ratio distance: 64 -> 4,096 is "closer" to 1,024 than to 1).
pub fn nearest_scale<'a>(records: &[&'a RunRecord], target_nodes: u64) -> Vec<&'a RunRecord> {
    let dist = |nodes: u64| {
        ((nodes.max(1) as f64).ln() - (target_nodes.max(1) as f64).ln()).abs()
    };
    // ties in distance resolve to the smaller node count (the `(dist,
    // nodes)` lexicographic minimum), so the selection is a pure
    // function of the record *set*
    let best = records
        .iter()
        .map(|r| r.nodes)
        .min_by(|&a, &b| dist(a).total_cmp(&dist(b)).then(a.cmp(&b)));
    match best {
        Some(nodes) => records.iter().copied().filter(|r| r.nodes == nodes).collect(),
        None => Vec::new(),
    }
}

/// Top-`k` elite `(configuration, objective)` pairs across `records`:
/// finite, non-timed-out evaluations, deduped by configuration key
/// (keeping each key's best objective), ordered by `(objective, key)`.
/// The ordering is a total function of the record *contents*, so the
/// extraction is stable under record-insertion order.
pub fn top_k_elites(records: &[&RunRecord], k: usize) -> Vec<(Configuration, f64)> {
    let mut best: std::collections::BTreeMap<String, f64> = std::collections::BTreeMap::new();
    for rec in records {
        for e in &rec.evals {
            if e.timed_out || !e.objective.is_finite() {
                continue;
            }
            best.entry(e.config_key.clone())
                .and_modify(|y| *y = y.min(e.objective))
                .or_insert(e.objective);
        }
    }
    let mut pool: Vec<(String, f64)> = best.into_iter().collect();
    pool.sort_by(|a, b| a.1.total_cmp(&b.1).then_with(|| a.0.cmp(&b.0)));
    // parse *before* taking k: an unparseable key from a damaged record
    // must not consume an elite slot (it would silently shrink — or
    // empty — the prior while valid elites sit further down the pool)
    pool.into_iter()
        .filter_map(|(key, y)| {
            crate::ensemble::checkpoint::config_from_key(&key).ok().map(|c| (c, y))
        })
        .take(k)
        .collect()
}

/// Rescale source-scale observations into the target scale's range by
/// the ratio of target/source default-configuration baselines — the
/// generalization of the retired `search::transfer::warm_start` free
/// function. The *ordering structure* of the landscape is what
/// transfers; panics on non-positive baselines (same contract as the
/// deprecated shim that delegates here).
pub fn rescale(
    source_obs: &[(Configuration, f64)],
    source_baseline: f64,
    target_baseline: f64,
) -> Vec<(Configuration, f64)> {
    assert!(
        source_baseline > 0.0 && target_baseline > 0.0,
        "baselines must be positive (source {source_baseline}, target {target_baseline})"
    );
    let ratio = target_baseline / source_baseline;
    source_obs.iter().map(|(c, y)| (c.clone(), y * ratio)).collect()
}

/// Build the warm-start prior from source records: rescale every
/// record's history by its own baseline ratio, then take the stable
/// top-`k` elites over the rescaled pool.
pub fn warm_prior(
    records: &[&RunRecord],
    target_baseline: f64,
    k: usize,
) -> Result<Vec<(Configuration, f64)>> {
    anyhow::ensure!(target_baseline > 0.0, "target baseline must be positive");
    let mut rescaled: Vec<RunRecord> = Vec::with_capacity(records.len());
    for rec in records {
        anyhow::ensure!(
            rec.baseline_objective.is_finite() && rec.baseline_objective > 0.0,
            "source run (seed {}, {} nodes) has a non-positive baseline {}",
            rec.seed,
            rec.nodes,
            rec.baseline_objective
        );
        let ratio = target_baseline / rec.baseline_objective;
        let mut r = (*rec).clone();
        for e in &mut r.evals {
            if e.objective.is_finite() {
                e.objective *= ratio;
            }
        }
        rescaled.push(r);
    }
    let views: Vec<&RunRecord> = rescaled.iter().collect();
    Ok(top_k_elites(&views, k))
}

/// Resolve `setup.warm_start_from` into the concrete foreign warm-start
/// prior, in place. Idempotent: a no-op when no store is configured or
/// the prior is already resolved — so every entry point (the serial
/// coordinator, the ensemble manager, the federation driver) may call
/// it and exactly one resolution happens. The resolved prior is part of
/// the run's checkpoint fingerprint, which is what makes a warm-started
/// run seed-for-seed deterministic *given the same store contents* and
/// refuses resumes against a store that changed underneath it.
///
/// Refusal contract: a configured store with no space-compatible run is
/// an error naming both fingerprints — silently cold-starting would
/// misreport a transfer experiment as a warm one.
pub fn apply_warm_start(setup: &mut TuneSetup, scorer: &Scorer) -> Result<()> {
    if setup.foreign_warm.is_some() {
        return Ok(());
    }
    let Some(dir) = setup.warm_start_from.clone() else {
        return Ok(());
    };
    // range check lives here — not only in the CLI — so config-file and
    // library callers get the same acceptance rules (and K=0 errors
    // clearly instead of resolving an empty prior)
    anyhow::ensure!(
        (1..=64).contains(&setup.warm_start_elites),
        "warm-start-elites must be in 1..=64 when a warm-start store is configured (got {})",
        setup.warm_start_elites
    );
    let space = paper::build_space(setup.app, setup.platform);
    let fp = space_fingerprint(&space);
    let store = HistoryStore::open_existing(&dir)?;
    let all = store.load_all()?;
    anyhow::ensure!(
        !all.is_empty(),
        "warm-start store {} holds no readable run records",
        dir.display()
    );
    // the metric is part of compatibility too: joule objectives must
    // never seed a runtime search (energy and runtime optima differ —
    // that is the point of tuning them separately)
    let metric = setup.metric.name();
    let compat: Vec<&RunRecord> = all
        .iter()
        .filter(|r| r.space_fingerprint == fp && r.metric == metric)
        .collect();
    if compat.is_empty() {
        let mut found: Vec<String> =
            all.iter().map(|r| format!("{} [{}]", r.space_fingerprint, r.metric)).collect();
        found.sort_unstable();
        found.dedup();
        anyhow::bail!(
            "warm-start refused: store {} has no run with a compatible space fingerprint \
             and metric\n  this run's space: `{fp}` [{metric}]\n  store holds:      `{}`",
            dir.display(),
            found.join("`, `")
        );
    }
    let source = nearest_scale(&compat, setup.nodes);
    let source_nodes = source.first().map(|r| r.nodes).unwrap_or(0);
    // drop damaged observations (unparseable or out-of-space keys)
    // *before* elite selection, so they can never consume top-K slots
    // while valid elites sit further down the pool
    let cleaned: Vec<RunRecord> = source
        .iter()
        .map(|rec| {
            let mut r = (**rec).clone();
            r.evals.retain(|e| {
                crate::ensemble::checkpoint::config_from_key(&e.config_key)
                    .map(|c| space.is_valid(&c))
                    .unwrap_or(false)
            });
            r
        })
        .collect();
    let cleaned_views: Vec<&RunRecord> = cleaned.iter().collect();
    // pay for the baseline once: the engines reuse this measurement
    // through the memo instead of re-running it
    let (baseline, target_baseline) = crate::coordinator::measure_baseline(setup, scorer)?;
    setup.baseline_memo = Some((baseline, target_baseline));
    let prior = warm_prior(&cleaned_views, target_baseline, setup.warm_start_elites)?;
    anyhow::ensure!(
        !prior.is_empty(),
        "warm-start store {} has compatible runs but no finite observations to transfer",
        dir.display()
    );
    log::info!(
        "warm start: {} elites from {} source run(s) at {} nodes (target {} nodes, \
         baseline ratio anchored at {target_baseline:.3})",
        prior.len(),
        source.len(),
        source_nodes,
        setup.nodes
    );
    setup.foreign_warm = Some(prior);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::AppKind;
    use crate::platform::PlatformKind;

    fn record(nodes: u64, seed: u64, evals: &[(&str, f64)]) -> RunRecord {
        RunRecord {
            space_fingerprint: "toy|2d|16|a:4,b:4".into(),
            app: "xsbench".into(),
            platform: "Theta".into(),
            nodes,
            metric: "runtime".into(),
            seed,
            baseline_objective: 10.0,
            best_objective: evals
                .iter()
                .map(|(_, y)| *y)
                .fold(f64::INFINITY, f64::min),
            best_config_key: evals
                .iter()
                .min_by(|a, b| a.1.total_cmp(&b.1))
                .map(|(k, _)| k.to_string())
                .unwrap_or_default(),
            wallclock_s: 120.0,
            evals: evals
                .iter()
                .map(|(k, y)| HistoryEval {
                    config_key: k.to_string(),
                    objective: *y,
                    runtime_s: *y,
                    energy_j: None,
                    timed_out: false,
                })
                .collect(),
        }
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("ytopt-hist-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn space_fingerprints_separate_apps_and_platforms() {
        let a = space_fingerprint(&paper::build_space(AppKind::XSBenchHistory, PlatformKind::Theta));
        let b = space_fingerprint(&paper::build_space(AppKind::Amg, PlatformKind::Theta));
        let c = space_fingerprint(&paper::build_space(AppKind::XSBenchHistory, PlatformKind::Summit));
        assert_ne!(a, b);
        assert_ne!(a, c);
        // and are stable across rebuilds
        assert_eq!(
            a,
            space_fingerprint(&paper::build_space(AppKind::XSBenchHistory, PlatformKind::Theta))
        );
    }

    #[test]
    fn append_is_atomic_and_idempotent() {
        let dir = tmpdir("append");
        let store = HistoryStore::open(&dir).unwrap();
        let rec = record(64, 1, &[("0,0", 3.0), ("1,2", 2.0)]);
        let p1 = store.append(&rec).unwrap();
        let p2 = store.append(&rec).unwrap();
        assert_eq!(p1, p2, "same content must land in the same file");
        // no temp litter under the final-name contract
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.path().extension().map(|x| x == "tmp").unwrap_or(false))
            .collect();
        assert!(leftovers.is_empty(), "append left temp files behind");
        let all = store.load_all().unwrap();
        assert_eq!(all, vec![rec]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    fn tmp_count(dir: &Path) -> usize {
        std::fs::read_dir(dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.path().extension().map(|x| x == "tmp").unwrap_or(false))
            .count()
    }

    /// Satellite: injected short writes and ENOSPC on the append path
    /// are caught by the pre-install audit — a partial record never
    /// reaches a final name — and retried away under the deterministic
    /// backoff once the fault clears (the `x4` fire cap).
    #[test]
    fn injected_append_faults_retry_and_never_install_partials() {
        let dir = tmpdir("chaos-append");
        let plan = std::sync::Arc::new(
            crate::chaos::FaultPlan::parse("seed=7;history-write=1x4;base-ms=0;cap-ms=0")
                .unwrap(),
        );
        let store = HistoryStore::open(&dir).unwrap().with_chaos(plan.clone());
        let rec = record(64, 1, &[("0,0", 3.0), ("1,2", 2.0)]);
        let p = store.append(&rec).unwrap();
        assert_eq!(
            plan.fired(crate::chaos::Site::HistoryWrite),
            4,
            "every scheduled fault must fire before the append clears"
        );
        assert_eq!(std::fs::read_to_string(&p).unwrap(), rec.to_json().to_string());
        assert_eq!(store.load_all().unwrap(), vec![rec]);
        assert_eq!(tmp_count(&dir), 0, "faulted appends left temp files behind");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// An unlimited write fault outlasting the retry budget surfaces as
    /// the typed [`crate::chaos::RetryExhausted`] marker (what the
    /// scheduler maps to `Degraded`), installs nothing, litters nothing.
    #[test]
    fn exhausted_append_budget_is_typed_and_installs_nothing() {
        let dir = tmpdir("chaos-append-exhaust");
        let plan = std::sync::Arc::new(
            crate::chaos::FaultPlan::parse("seed=3;history-write=1;retries=2;base-ms=0;cap-ms=0")
                .unwrap(),
        );
        let store = HistoryStore::open(&dir).unwrap().with_chaos(plan);
        let err = store.append(&record(64, 1, &[("0,0", 3.0)])).unwrap_err();
        assert!(crate::chaos::is_retry_exhausted(&err), "{err:#}");
        assert!(store.load_all().unwrap().is_empty(), "no partial record under a final name");
        assert_eq!(tmp_count(&dir), 0, "exhausted append left temp litter");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Satellite: temps orphaned by a writer that crashed mid-append are
    /// swept (with a warning) on the next open; the embedded-pid naming
    /// spares a live writer's in-progress temps.
    #[test]
    fn open_sweeps_dead_writers_temp_files() {
        let dir = tmpdir("orphan-sweep");
        std::fs::create_dir_all(&dir).unwrap();
        // a dead writer's temp (pid 1 is init, never this test) plus an
        // unparseable stray
        // detlint: allow(io-atomic) -- planted orphan fixture, not a real install
        std::fs::write(dir.join("run-abcd.1-0.tmp"), "partial").unwrap();
        // detlint: allow(io-atomic) -- planted orphan fixture, not a real install
        std::fs::write(dir.join("stray.tmp"), "junk").unwrap();
        let store = HistoryStore::open(&dir).unwrap();
        assert_eq!(tmp_count(&dir), 0, "open must sweep orphaned temps");
        assert!(store.load_all().unwrap().is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Satellite: many campaigns finishing at once in one daemon must
    /// not lose, duplicate, or corrupt records. 8 threads × 5 rounds all
    /// appending the same 4 distinct records — maximal same-name racing
    /// on every final file, both same-content (idempotence) and
    /// cross-content (distinct ids) traffic — with the first few temp
    /// writes faulted, so retries interleave with the races too.
    #[test]
    fn concurrent_appends_lose_nothing() {
        let dir = tmpdir("concurrent-append");
        let plan = std::sync::Arc::new(
            crate::chaos::FaultPlan::parse("seed=11;history-write=1x4;base-ms=0;cap-ms=0")
                .unwrap(),
        );
        let store = HistoryStore::open(&dir).unwrap().with_chaos(plan.clone());
        let recs: Vec<RunRecord> = (0..4)
            .map(|i| record(64 << i, i as u64 + 1, &[("0,0", 3.0 + i as f64), ("1,1", 9.0)]))
            .collect();
        // detlint: allow(par-float-accum) -- append stress test; no float reduction, outcome is order-independent by design
        std::thread::scope(|s| {
            for _ in 0..8 {
                let store = store.clone();
                let recs = &recs;
                s.spawn(move || {
                    for _round in 0..5 {
                        for r in recs {
                            store.append(r).unwrap();
                        }
                    }
                });
            }
        });
        let all = store.load_all().unwrap();
        assert_eq!(all.len(), recs.len(), "each distinct record exactly once: {all:?}");
        for r in &recs {
            assert!(all.contains(r), "record for seed {} lost in the race", r.seed);
        }
        assert_eq!(
            plan.fired(crate::chaos::Site::HistoryWrite),
            4,
            "the scheduled write faults must all have fired (and been retried away)"
        );
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.path().extension().map(|x| x == "tmp").unwrap_or(false))
            .collect();
        assert!(leftovers.is_empty(), "concurrent appends left temp files behind");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// A `run_id` collision (different content, same hash — forced here
    /// by planting an imposter under the final name) must never clobber:
    /// the append lands under a salted name and both files survive.
    #[test]
    fn run_id_collision_salts_instead_of_clobbering() {
        let dir = tmpdir("collision");
        let store = HistoryStore::open(&dir).unwrap();
        let rec = record(64, 1, &[("0,0", 3.0)]);
        let id = rec.run_id();
        let imposter = "imposter: not the appended record";
        // detlint: allow(io-atomic) -- planted imposter fixture, not a real install
        std::fs::write(dir.join(format!("run-{id}.json")), imposter).unwrap();
        let p = store.append(&rec).unwrap();
        assert_eq!(
            p.file_name().and_then(|n| n.to_str()),
            Some(format!("run-{id}-1.json").as_str()),
            "collision must fall through to the first salted name"
        );
        assert_eq!(
            std::fs::read_to_string(dir.join(format!("run-{id}.json"))).unwrap(),
            imposter,
            "the occupant must be left untouched"
        );
        // idempotent re-append resolves to the salted file, not a third
        assert_eq!(store.append(&rec).unwrap(), p);
        // the scan returns the real record (the imposter is skipped as corrupt)
        assert_eq!(store.load_all().unwrap(), vec![rec]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupt_records_are_skipped_not_fatal() {
        let dir = tmpdir("corrupt");
        let store = HistoryStore::open(&dir).unwrap();
        store.append(&record(64, 1, &[("0,0", 3.0)])).unwrap();
        store.append(&record(256, 2, &[("1,1", 4.0)])).unwrap();
        // a truncated record and outright garbage, both under final names
        // detlint: allow(io-atomic) -- planted corrupt fixture
        std::fs::write(dir.join("run-truncated.json"), "{\"kind\":\"run-rec").unwrap();
        // detlint: allow(io-atomic) -- planted corrupt fixture
        std::fs::write(dir.join("run-garbage.json"), "not json at all").unwrap();
        // and a foreign-but-valid JSON file (wrong kind)
        // detlint: allow(io-atomic) -- planted corrupt fixture
        std::fs::write(dir.join("run-foreign.json"), "{\"fingerprint\":\"fp\"}").unwrap();
        let all = store.load_all().unwrap();
        assert_eq!(all.len(), 2, "exactly the two good records survive the scan");
        // fingerprint lookup sees the same lenient view
        let compat = store.compatible("toy|2d|16|a:4,b:4").unwrap();
        assert_eq!(compat.len(), 2);
        assert!(store.compatible("other-space").unwrap().is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn open_existing_refuses_missing_dirs_without_creating_them() {
        let dir = tmpdir("open-existing"); // removed, never created
        let err = HistoryStore::open_existing(&dir);
        assert!(err.is_err(), "a missing store must be an error, not an empty store");
        assert!(!dir.exists(), "the read path must not mkdir as a side effect");
        // the append path does create, and open_existing accepts it then
        let store = HistoryStore::open(&dir).unwrap();
        assert_eq!(HistoryStore::open_existing(&dir).unwrap().dir(), store.dir());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn nearest_scale_uses_log_distance() {
        let rs = [record(1, 1, &[]), record(64, 2, &[]), record(4096, 3, &[])];
        let views: Vec<&RunRecord> = rs.iter().collect();
        // 1024 is closer to 4096 than to 64 in log space? ln ratios: 1.39 vs 2.77
        let near = nearest_scale(&views, 1024);
        assert_eq!(near.len(), 1);
        assert_eq!(near[0].nodes, 4096);
        let near = nearest_scale(&views, 2);
        assert_eq!(near[0].nodes, 1);
        // exact match wins outright and collects every run at that scale
        let rs2 = [record(64, 1, &[]), record(64, 2, &[]), record(1, 3, &[])];
        let views2: Vec<&RunRecord> = rs2.iter().collect();
        let near = nearest_scale(&views2, 64);
        assert_eq!(near.len(), 2);
    }

    #[test]
    fn elite_extraction_dedupes_and_orders() {
        let a = record(64, 1, &[("0,0", 5.0), ("1,1", 2.0), ("2,2", 9.0)]);
        let b = record(64, 2, &[("1,1", 3.0), ("3,3", 2.5)]);
        let elites = top_k_elites(&[&a, &b], 3);
        assert_eq!(elites.len(), 3);
        assert_eq!(elites[0].0.key(), "1,1");
        assert_eq!(elites[0].1, 2.0, "dedup keeps the best objective per key");
        assert_eq!(elites[1].0.key(), "3,3");
        assert_eq!(elites[2].0.key(), "0,0");
        // stable under record-insertion order
        let swapped = top_k_elites(&[&b, &a], 3);
        let key = |v: &[(Configuration, f64)]| {
            v.iter().map(|(c, y)| (c.key(), y.to_bits())).collect::<Vec<_>>()
        };
        assert_eq!(key(&elites), key(&swapped));
    }

    #[test]
    fn warm_prior_rescales_per_source_baseline() {
        let mut a = record(64, 1, &[("0,0", 5.0)]);
        a.baseline_objective = 10.0;
        let mut b = record(64, 2, &[("1,1", 1.0)]);
        b.baseline_objective = 2.0;
        // target baseline 20: a's ratio 2.0 (5 -> 10), b's ratio 10.0 (1 -> 10)
        let prior = warm_prior(&[&a, &b], 20.0, 8).unwrap();
        assert_eq!(prior.len(), 2);
        for (_, y) in &prior {
            assert_eq!(*y, 10.0);
        }
        // non-positive source baseline is refused
        let mut bad = record(64, 3, &[("2,2", 1.0)]);
        bad.baseline_objective = 0.0;
        assert!(warm_prior(&[&bad], 20.0, 8).is_err());
    }

    #[test]
    fn rescale_keeps_the_ordering_structure() {
        let obs = vec![
            (Configuration::from_indices(vec![0]), 2.0),
            (Configuration::from_indices(vec![1]), 4.0),
        ];
        let out = rescale(&obs, 2.0, 20.0);
        assert_eq!(out[0].1, 20.0);
        assert_eq!(out[1].1, 40.0);
        assert!(out[0].1 < out[1].1);
    }

    #[test]
    fn run_record_roundtrips_including_infinities() {
        let mut rec = record(4096, 7, &[("0,1", 2.5), ("3,2", 4.25)]);
        rec.evals.push(HistoryEval {
            config_key: "1,1".into(),
            objective: f64::INFINITY,
            runtime_s: f64::INFINITY,
            energy_j: Some(812.5),
            timed_out: true,
        });
        rec.best_objective = 2.5;
        let back = RunRecord::parse(&rec.to_json().to_string()).unwrap();
        assert_eq!(back, rec);
        assert_eq!(back.run_id(), rec.run_id());
    }
}
