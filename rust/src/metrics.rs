//! Tuning objectives: runtime, average node energy, EDP (paper §IV/§VII).

/// The metric the autotuner minimizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Metric {
    /// Application runtime in seconds — the primary performance metric.
    Runtime,
    /// Average node energy in joules (runtime x power tradeoff).
    Energy,
    /// Energy-delay product in joule-seconds (runtime x energy tradeoff).
    Edp,
}

impl Metric {
    pub fn name(&self) -> &'static str {
        match self {
            Metric::Runtime => "runtime",
            Metric::Energy => "energy",
            Metric::Edp => "EDP",
        }
    }

    pub fn unit(&self) -> &'static str {
        match self {
            Metric::Runtime => "s",
            Metric::Energy => "J",
            Metric::Edp => "J*s",
        }
    }

    pub fn parse(s: &str) -> Option<Metric> {
        match s.to_ascii_lowercase().as_str() {
            "runtime" | "perf" | "performance" => Some(Metric::Runtime),
            "energy" => Some(Metric::Energy),
            "edp" => Some(Metric::Edp),
            _ => None,
        }
    }

    /// Whether measuring this metric requires the GEOPM pipeline.
    pub fn needs_power(&self) -> bool {
        !matches!(self, Metric::Runtime)
    }
}

/// One evaluated objective bundle (all three metrics of a run, so the
/// database can report tradeoffs regardless of which one was tuned).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Measured {
    pub runtime_s: f64,
    pub avg_node_energy_j: Option<f64>,
    pub edp_js: Option<f64>,
}

impl Measured {
    pub fn runtime_only(runtime_s: f64) -> Measured {
        Measured { runtime_s, avg_node_energy_j: None, edp_js: None }
    }

    pub fn with_energy(runtime_s: f64, energy_j: f64) -> Measured {
        Measured {
            runtime_s,
            avg_node_energy_j: Some(energy_j),
            edp_js: Some(energy_j * runtime_s),
        }
    }

    /// The scalar the search minimizes for `metric`.
    pub fn objective(&self, metric: Metric) -> f64 {
        match metric {
            Metric::Runtime => self.runtime_s,
            Metric::Energy => self.avg_node_energy_j.unwrap_or(f64::INFINITY),
            Metric::Edp => self.edp_js.unwrap_or(f64::INFINITY),
        }
    }
}

/// Percent improvement of `best` over `baseline` (paper Tables IV/V).
pub fn improvement_pct(baseline: f64, best: f64) -> f64 {
    if baseline <= 0.0 {
        return 0.0;
    }
    100.0 * (baseline - best) / baseline
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_names() {
        assert_eq!(Metric::parse("runtime"), Some(Metric::Runtime));
        assert_eq!(Metric::parse("EDP"), Some(Metric::Edp));
        assert_eq!(Metric::parse("Energy"), Some(Metric::Energy));
        assert_eq!(Metric::parse("x"), None);
        assert!(Metric::Energy.needs_power());
        assert!(!Metric::Runtime.needs_power());
    }

    #[test]
    fn objective_selection() {
        let m = Measured::with_energy(10.0, 2000.0);
        assert_eq!(m.objective(Metric::Runtime), 10.0);
        assert_eq!(m.objective(Metric::Energy), 2000.0);
        assert_eq!(m.objective(Metric::Edp), 20000.0);
        let r = Measured::runtime_only(5.0);
        assert_eq!(r.objective(Metric::Energy), f64::INFINITY);
    }

    #[test]
    fn improvement_matches_paper_arithmetic() {
        // paper: 171.595 -> 14.427 is 91.59%
        let pct = improvement_pct(171.595, 14.427);
        assert!((pct - 91.59).abs() < 0.01, "{pct}");
        // paper: 2494.905 -> 2280.806 is 8.58%
        let pct = improvement_pct(2494.905, 2280.806);
        assert!((pct - 8.58).abs() < 0.01, "{pct}");
    }
}
