//! Declarative CLI argument parser (no clap in the offline crate set).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`, and
//! positional arguments, with generated `--help` text. Used by
//! `rust/src/main.rs` and the examples.

use std::collections::BTreeMap;

/// One declared option.
#[derive(Debug, Clone)]
struct OptSpec {
    name: String,
    takes_value: bool,
    default: Option<String>,
    help: String,
}

/// A declarative command-line specification.
#[derive(Debug, Clone)]
pub struct CliSpec {
    program: String,
    about: String,
    opts: Vec<OptSpec>,
    positionals: Vec<(String, String)>, // (name, help)
}

/// Parsed arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positionals: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    Unknown(String),
    MissingValue(String),
    UnexpectedPositional(String),
    /// Value outside a declared choice set: (option, detail).
    InvalidValue(String, String),
    HelpRequested,
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Unknown(n) => write!(f, "unknown option --{n}"),
            CliError::MissingValue(n) => write!(f, "option --{n} requires a value"),
            CliError::UnexpectedPositional(a) => {
                write!(f, "unexpected positional argument `{a}`")
            }
            CliError::InvalidValue(n, detail) => {
                write!(f, "invalid value for --{n}: {detail}")
            }
            CliError::HelpRequested => write!(f, "help requested"),
        }
    }
}

impl std::error::Error for CliError {}

impl CliSpec {
    pub fn new(program: &str, about: &str) -> Self {
        CliSpec {
            program: program.to_string(),
            about: about.to_string(),
            opts: Vec::new(),
            positionals: Vec::new(),
        }
    }

    /// Declare `--name <value>` with an optional default.
    pub fn opt(mut self, name: &str, default: Option<&str>, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            takes_value: true,
            default: default.map(|s| s.to_string()),
            help: help.to_string(),
        });
        self
    }

    /// Declare a boolean `--name` flag.
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            takes_value: false,
            default: None,
            help: help.to_string(),
        });
        self
    }

    /// Declare a positional argument (in order).
    pub fn positional(mut self, name: &str, help: &str) -> Self {
        self.positionals.push((name.to_string(), help.to_string()));
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {}", self.program, self.about, self.program);
        for (p, _) in &self.positionals {
            s.push_str(&format!(" <{p}>"));
        }
        s.push_str(" [OPTIONS]\n");
        if !self.positionals.is_empty() {
            s.push_str("\nARGS:\n");
            for (p, h) in &self.positionals {
                s.push_str(&format!("  <{p}>  {h}\n"));
            }
        }
        s.push_str("\nOPTIONS:\n");
        for o in &self.opts {
            let head = if o.takes_value {
                format!("--{} <value>", o.name)
            } else {
                format!("--{}", o.name)
            };
            let def = o.default.as_ref().map(|d| format!(" [default: {d}]")).unwrap_or_default();
            s.push_str(&format!("  {head:<26} {}{def}\n", o.help));
        }
        s.push_str("  --help                     print this help\n");
        s
    }

    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        let mut args = Args::default();
        for o in &self.opts {
            if let Some(d) = &o.default {
                args.values.insert(o.name.clone(), d.clone());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(CliError::HelpRequested);
            }
            if let Some(rest) = a.strip_prefix("--") {
                let (name, inline) = match rest.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| CliError::Unknown(name.clone()))?;
                if spec.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i).cloned().ok_or(CliError::MissingValue(name.clone()))?
                        }
                    };
                    args.values.insert(name, v);
                } else {
                    args.flags.push(name);
                }
            } else {
                if args.positionals.len() >= self.positionals.len() {
                    return Err(CliError::UnexpectedPositional(a.clone()));
                }
                args.positionals.push(a.clone());
            }
            i += 1;
        }
        Ok(args)
    }
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn int(&self, name: &str) -> Option<i64> {
        self.get(name)?.parse().ok()
    }

    pub fn float(&self, name: &str) -> Option<f64> {
        self.get(name)?.parse().ok()
    }

    /// Non-negative count option (worker/batch sizes and similar).
    pub fn usize(&self, name: &str) -> Option<usize> {
        self.get(name)?.parse().ok()
    }

    /// Filesystem-path option (checkpoint files, history-store
    /// directories). `None` when absent or empty — an empty `--x=""`
    /// would otherwise silently become the current directory.
    pub fn path(&self, name: &str) -> Option<std::path::PathBuf> {
        self.get(name).filter(|s| !s.is_empty()).map(std::path::PathBuf::from)
    }

    /// Count option constrained to `lo..=hi` (shard counts, exchange
    /// periods). Errors name the option, the offending value, and the
    /// accepted range instead of silently clamping or defaulting.
    pub fn usize_in(&self, name: &str, lo: usize, hi: usize) -> Result<usize, CliError> {
        let v = self.get(name).ok_or_else(|| CliError::MissingValue(name.to_string()))?;
        match v.parse::<usize>() {
            Ok(n) if (lo..=hi).contains(&n) => Ok(n),
            _ => Err(CliError::InvalidValue(
                name.to_string(),
                format!("`{v}` (expected an integer in {lo}..={hi})"),
            )),
        }
    }

    /// Value constrained to a fixed choice set (case-insensitive match;
    /// the raw value is returned so callers keep their own parsing).
    /// Errors name the option and list the accepted values.
    pub fn choice<'a>(&'a self, name: &str, allowed: &[&str]) -> Result<&'a str, CliError> {
        let v = self.get(name).ok_or_else(|| CliError::MissingValue(name.to_string()))?;
        if allowed.iter().any(|a| v.eq_ignore_ascii_case(a)) {
            Ok(v)
        } else {
            Err(CliError::InvalidValue(
                name.to_string(),
                format!("`{v}` (expected one of: {})", allowed.join(" | ")),
            ))
        }
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positionals.get(i).map(String::as_str)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> CliSpec {
        CliSpec::new("ytopt-rs", "autotuner")
            .positional("command", "subcommand")
            .opt("app", Some("xsbench"), "application")
            .opt("nodes", Some("1"), "node count")
            .flag("parallel", "parallel evaluation")
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed_forms() {
        let a = spec().parse(&sv(&["tune", "--app=amg", "--nodes", "4096", "--parallel"])).unwrap();
        assert_eq!(a.positional(0), Some("tune"));
        assert_eq!(a.get("app"), Some("amg"));
        assert_eq!(a.int("nodes"), Some(4096));
        assert_eq!(a.usize("nodes"), Some(4096));
        assert!(a.has_flag("parallel"));
    }

    #[test]
    fn usize_rejects_negatives_and_garbage() {
        let a = spec().parse(&sv(&["tune", "--nodes", "-3"])).unwrap();
        assert_eq!(a.usize("nodes"), None);
        let a = spec().parse(&sv(&["tune", "--nodes", "abc"])).unwrap();
        assert_eq!(a.usize("nodes"), None);
    }

    #[test]
    fn path_rejects_empty_values() {
        let sp = CliSpec::new("t", "test").opt("history-dir", None, "store dir");
        let a = sp.parse(&sv(&["--history-dir", "/tmp/store"])).unwrap();
        assert_eq!(a.path("history-dir"), Some(std::path::PathBuf::from("/tmp/store")));
        let a = sp.parse(&sv(&["--history-dir="])).unwrap();
        assert_eq!(a.path("history-dir"), None, "empty path must not mean cwd");
        let a = sp.parse(&sv(&[])).unwrap();
        assert_eq!(a.path("history-dir"), None);
    }

    #[test]
    fn defaults_fill_in() {
        let a = spec().parse(&sv(&["tune"])).unwrap();
        assert_eq!(a.get("app"), Some("xsbench"));
        assert_eq!(a.int("nodes"), Some(1));
        assert!(!a.has_flag("parallel"));
    }

    #[test]
    fn errors_are_specific() {
        assert!(matches!(spec().parse(&sv(&["--bogus"])), Err(CliError::Unknown(_))));
        assert!(matches!(spec().parse(&sv(&["--app"])), Err(CliError::MissingValue(_))));
        assert!(matches!(
            spec().parse(&sv(&["a", "b"])),
            Err(CliError::UnexpectedPositional(_))
        ));
        assert!(matches!(spec().parse(&sv(&["--help"])), Err(CliError::HelpRequested)));
    }

    #[test]
    fn choice_validates_against_the_allowed_set() {
        let sp = CliSpec::new("t", "test").opt("mode", Some("fast"), "speed mode");
        // declared default satisfies the choice
        let a = sp.parse(&sv(&[])).unwrap();
        assert_eq!(a.choice("mode", &["fast", "slow"]).unwrap(), "fast");
        // matching is case-insensitive but the raw value is returned
        let a = sp.parse(&sv(&["--mode", "SLOW"])).unwrap();
        assert_eq!(a.choice("mode", &["fast", "slow"]).unwrap(), "SLOW");
        // out-of-set values error with the option name and the set
        let a = sp.parse(&sv(&["--mode", "warp"])).unwrap();
        match a.choice("mode", &["fast", "slow"]) {
            Err(CliError::InvalidValue(n, detail)) => {
                assert_eq!(n, "mode");
                assert!(detail.contains("warp") && detail.contains("fast | slow"), "{detail}");
            }
            other => panic!("{other:?}"),
        }
        // undeclared options surface as missing
        assert!(matches!(a.choice("nope", &["x"]), Err(CliError::MissingValue(_))));
    }

    #[test]
    fn usize_in_enforces_the_declared_range() {
        let sp = CliSpec::new("t", "test").opt("shards", Some("0"), "shard count");
        let a = sp.parse(&sv(&[])).unwrap();
        assert_eq!(a.usize_in("shards", 0, 64).unwrap(), 0);
        let a = sp.parse(&sv(&["--shards", "64"])).unwrap();
        assert_eq!(a.usize_in("shards", 0, 64).unwrap(), 64);
        for bad in ["65", "-1", "3.5", "many"] {
            let a = sp.parse(&sv(&["--shards", bad])).unwrap();
            match a.usize_in("shards", 0, 64) {
                Err(CliError::InvalidValue(n, detail)) => {
                    assert_eq!(n, "shards");
                    assert!(detail.contains(bad) && detail.contains("0..=64"), "{detail}");
                }
                other => panic!("`{bad}` accepted: {other:?}"),
            }
        }
        // undeclared options surface as missing
        let a = sp.parse(&sv(&[])).unwrap();
        assert!(matches!(a.usize_in("nope", 0, 1), Err(CliError::MissingValue(_))));
    }

    #[test]
    fn usage_mentions_everything() {
        let u = spec().usage();
        assert!(u.contains("--app"));
        assert!(u.contains("--parallel"));
        assert!(u.contains("<command>"));
        assert!(u.contains("[default: xsbench]"));
    }
}
