//! Small statistics helpers shared by the coordinator, benches, and tests.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance; 0.0 for len < 2.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Linear-interpolated percentile, `q` in [0, 100]. Sorts a copy.
///
/// Total over all inputs: NaNs order after +inf (`f64::total_cmp`), so
/// a NaN in the sample can surface in high percentiles but can never
/// panic the caller — the hot paths feed this from fault-injected
/// runtimes. Finite-only inputs behave exactly as before.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Index of the minimum value (first on ties); None if empty/NaN-only.
pub fn argmin(xs: &[f64]) -> Option<usize> {
    let mut best: Option<(usize, f64)> = None;
    for (i, &x) in xs.iter().enumerate() {
        if x.is_nan() {
            continue;
        }
        match best {
            Some((_, b)) if x >= b => {}
            _ => best = Some((i, x)),
        }
    }
    best.map(|(i, _)| i)
}

/// Streaming mean/variance (Welford).
#[derive(Debug, Clone, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Online quantile over a growing sample, kept sorted for O(log n)
/// lookup of the insertion point. Backs the continuous ensemble
/// manager's straggler policy, where the cutoff must come from the
/// distribution of *all* completed runtimes so far rather than from one
/// batch's handful. Non-finite values are ignored.
#[derive(Debug, Clone, Default)]
pub struct RunningQuantile {
    sorted: Vec<f64>,
}

impl RunningQuantile {
    pub fn new() -> Self {
        RunningQuantile::default()
    }

    pub fn push(&mut self, x: f64) {
        if !x.is_finite() {
            return;
        }
        let i = self.sorted.partition_point(|v| *v < x);
        self.sorted.insert(i, x);
    }

    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }

    /// Linear-interpolated quantile, `q` in [0, 1]; `None` when empty.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        if self.sorted.is_empty() {
            return None;
        }
        let q = q.clamp(0.0, 1.0);
        let pos = q * (self.sorted.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        Some(if lo == hi {
            self.sorted[lo]
        } else {
            self.sorted[lo] + (pos - lo as f64) * (self.sorted[hi] - self.sorted[lo])
        })
    }

    pub fn median(&self) -> Option<f64> {
        self.quantile(0.5)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_variance_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((variance(&xs) - 4.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_tolerates_planted_nan() {
        // regression: a NaN input used to panic the partial_cmp sort.
        // NaNs order last, so low percentiles stay finite and correct
        // while the top of the distribution reports the contamination.
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
        assert!(percentile(&xs, 100.0).is_nan());
        // median transitively: all-but-one finite keeps its meaning
        assert!((median(&[5.0, f64::NAN, 4.0]) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn argmin_handles_ties_and_nan() {
        assert_eq!(argmin(&[3.0, 1.0, 1.0, 2.0]), Some(1));
        assert_eq!(argmin(&[f64::NAN, 2.0]), Some(1));
        assert_eq!(argmin(&[]), None);
    }

    #[test]
    fn running_quantile_matches_batch_percentile() {
        let xs = [4.0, 1.0, 3.0, 2.0, 9.0, 5.0];
        let mut rq = RunningQuantile::new();
        assert!(rq.is_empty());
        assert_eq!(rq.median(), None);
        for &x in &xs {
            rq.push(x);
        }
        rq.push(f64::INFINITY); // ignored
        rq.push(f64::NAN); // ignored
        assert_eq!(rq.len(), 6);
        assert!((rq.median().unwrap() - median(&xs)).abs() < 1e-12);
        assert!((rq.quantile(1.0).unwrap() - 9.0).abs() < 1e-12);
        assert!((rq.quantile(0.0).unwrap() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn running_quantile_small_n_edge_cases() {
        // n = 0: every quantile is None
        let rq = RunningQuantile::new();
        for q in [0.0, 0.5, 1.0] {
            assert_eq!(rq.quantile(q), None);
        }
        // n = 1: every quantile is the lone sample (pos is always 0)
        let mut rq = RunningQuantile::new();
        rq.push(7.5);
        for q in [0.0, 0.25, 0.5, 1.0, -3.0, 42.0] {
            assert_eq!(rq.quantile(q), Some(7.5), "q={q}");
        }
        // n = 2: endpoints are exact, the middle interpolates
        let mut rq = RunningQuantile::new();
        rq.push(10.0);
        rq.push(2.0);
        assert_eq!(rq.quantile(0.0), Some(2.0));
        assert_eq!(rq.quantile(1.0), Some(10.0));
        assert!((rq.median().unwrap() - 6.0).abs() < 1e-12);
        // all-equal samples: every quantile collapses to that value
        let mut rq = RunningQuantile::new();
        for _ in 0..5 {
            rq.push(3.25);
        }
        for q in [0.0, 0.1, 0.5, 0.9, 1.0] {
            assert_eq!(rq.quantile(q), Some(3.25), "q={q}");
        }
    }

    #[test]
    fn prop_running_quantile_agrees_with_batch_and_is_bounded() {
        crate::proptest_lite::for_all(
            "running_quantile_matches_batch",
            200,
            0x5ca1ab1e,
            |rng| {
                let n = rng.index(12); // exercises n = 0, 1, 2 heavily
                let equal = rng.bool(0.25);
                let base = rng.uniform(-50.0, 50.0);
                let xs: Vec<f64> = (0..n)
                    .map(|_| if equal { base } else { rng.uniform(-50.0, 50.0) })
                    .collect();
                let q = rng.f64();
                (xs, q)
            },
            |(xs, q)| {
                let mut rq = RunningQuantile::new();
                for &x in xs {
                    rq.push(x);
                }
                match rq.quantile(*q) {
                    None => xs.is_empty(),
                    Some(v) => {
                        // matches the batch percentile on the same data...
                        let batch = percentile(xs, q * 100.0);
                        (v - batch).abs() < 1e-9
                            // ...and never escapes the sample range
                            && v >= min(xs) - 1e-12
                            && v <= max(xs) + 1e-12
                    }
                }
            },
        );
    }

    #[test]
    fn online_matches_batch() {
        let xs = [1.5, -2.0, 3.25, 0.0, 9.0, -4.5];
        let mut o = OnlineStats::new();
        for &x in &xs {
            o.push(x);
        }
        assert!((o.mean() - mean(&xs)).abs() < 1e-12);
        assert!((o.variance() - variance(&xs)).abs() < 1e-12);
        assert_eq!(o.min(), min(&xs));
        assert_eq!(o.max(), max(&xs));
    }
}
