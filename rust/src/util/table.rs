//! Aligned text tables for bench output (paper tables are regenerated as
//! these).

/// A simple column-aligned table with a header row.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&self.title);
            out.push('\n');
        }
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let pad = width[i] - c.chars().count();
                line.push(' ');
                line.push_str(c);
                line.push_str(&" ".repeat(pad + 1));
                line.push('|');
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &width));
        out.push('\n');
        let mut sep = String::from("|");
        for w in &width {
            sep.push_str(&"-".repeat(w + 2));
            sep.push('|');
        }
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &width));
            out.push('\n');
        }
        out
    }
}

/// Format seconds compactly (e.g. "3.262 s", "65 ms").
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.2} us", s * 1e6)
    } else {
        format!("{:.0} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["a", "long-header"]);
        t.row_strs(&["1", "2"]);
        t.row_strs(&["wide-cell", "3"]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 5); // title, header, sep, 2 rows
        assert!(lines[1..].iter().all(|l| l.len() == lines[1].len()));
    }

    #[test]
    #[should_panic]
    fn rejects_ragged_rows() {
        let mut t = Table::new("", &["a", "b"]);
        t.row_strs(&["only-one"]);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert_eq!(fmt_secs(3.262), "3.262 s");
        assert_eq!(fmt_secs(0.065), "65.00 ms");
        assert!(fmt_secs(3e-6).ends_with("us"));
        assert!(fmt_secs(5e-8).ends_with("ns"));
    }
}
