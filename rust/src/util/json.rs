//! Minimal JSON value, writer, and parser (no serde in the offline set).
//!
//! Used for: reading `artifacts/manifest.json`, exporting the performance
//! database and bench results, and the figure-series dumps the benches
//! emit for EXPERIMENTS.md.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

impl Json {
    pub fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if n.fract() == 0.0 && n.abs() < 1e15 {
                        out.push_str(&format!("{}", *n as i64));
                    } else {
                        out.push_str(&format!("{n}"));
                    }
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => escape(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    escape(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let v = Json::obj(vec![
            ("name", "ytopt".into()),
            ("n", 42u64.into()),
            ("pi", 3.25.into()),
            ("flags", vec![true, false].into()),
            ("nested", Json::obj(vec![("a", Json::Null)])),
        ]);
        let s = v.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parses_manifest_like_doc() {
        let doc = r#"{ "forest_scorer": {"candidates": 1024, "file": "f.hlo.txt",
                       "inputs": ["a", "b"]}, "format": "hlo-text" }"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("format").and_then(Json::as_str), Some("hlo-text"));
        let fs = v.get("forest_scorer").unwrap();
        assert_eq!(fs.get("candidates").and_then(Json::as_u64), Some(1024));
        assert_eq!(fs.get("inputs").and_then(Json::as_arr).map(|a| a.len()), Some(2));
    }

    #[test]
    fn escapes_special_chars() {
        let v = Json::Str("a\"b\\c\nd".to_string());
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""éx""#).unwrap();
        assert_eq!(v.as_str(), Some("éx"));
    }
}
