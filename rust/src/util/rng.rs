//! Deterministic PRNG (PCG32) and sampling helpers.
//!
//! The offline crate set has no `rand`; everything stochastic in the
//! coordinator (space sampling, bootstrap, split selection, simulator
//! noise) flows through this generator so runs are reproducible from a
//! single seed.

/// PCG-XSH-RR 64/32 (Melissa O'Neill's PCG32).
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience constructor with the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    /// Derive an independent child generator (for parallel workers).
    pub fn split(&mut self, stream: u64) -> Pcg32 {
        Pcg32::new(self.next_u64(), stream.wrapping_mul(2654435769).wrapping_add(1))
    }

    /// Snapshot the raw generator state (checkpoint persistence). The
    /// pair round-trips through [`Pcg32::from_state`] so a resumed
    /// session continues the *same* stream mid-trajectory instead of
    /// re-seeding from the start.
    pub fn state(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from a [`Pcg32::state`] snapshot, without
    /// re-running the seeding permutation (which would advance the
    /// stream past the snapshot point).
    pub fn from_state(state: u64, inc: u64) -> Pcg32 {
        Pcg32 { state, inc }
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform integer in `[0, n)` (Lemire's debiased multiply-shift).
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Uniform usize index in `[0, n)`.
    pub fn index(&mut self, n: usize) -> usize {
        self.gen_range(n as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli trial.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (no caching: simpler, deterministic).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with mean/std.
    pub fn gauss(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.index(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn state_snapshot_resumes_the_same_stream() {
        let mut a = Pcg32::seeded(99);
        for _ in 0..17 {
            a.next_u64();
        }
        let (s, i) = a.state();
        let mut b = Pcg32::from_state(s, i);
        for _ in 0..50 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut rng = Pcg32::seeded(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.gen_range(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut rng = Pcg32::seeded(9);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::seeded(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::seeded(13);
        let mut v: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg32::seeded(17);
        let s = rng.sample_indices(20, 10);
        assert_eq!(s.len(), 10);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 10);
        assert!(s.iter().all(|&i| i < 20));
    }

    #[test]
    fn split_streams_independent() {
        let mut root = Pcg32::seeded(5);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }
}
