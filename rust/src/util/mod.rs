//! Foundation utilities built from scratch for the offline crate set:
//! deterministic RNG, statistics, JSON, and text tables.

pub mod json;
pub mod rng;
pub mod stats;
pub mod table;

pub use json::Json;
pub use rng::Pcg32;
pub use table::Table;
