//! The campaign engine behind both front-ends.
//!
//! `ytopt-rs tune` (one-shot CLI) and `ytopt-rs serve` (the daemon) run
//! the *same* continuous-manager state machine: [`drive_continuous`]
//! steps a K=1 [`ContinuousShard`] one applied completion at a time,
//! emitting progress events and honoring a cancel flag between steps.
//! `federation::autotune_continuous` — the function the classic
//! `autotune_with_scorer` dispatch chain lands on — is now a thin
//! delegate over this driver with a never-raised cancel flag and a
//! discarding event sink. That shared core is what makes a daemon
//! campaign's trajectory bit-identical to the solo CLI run with the
//! same seed/policy: there is only one engine to diverge from.
//!
//! [`CampaignHandle`] is the start / poll-events / cancel / join facade
//! over a campaign running on its own thread; the daemon's scheduler
//! holds one per running campaign, and `cmd_tune` drives its one-shot
//! campaign through the identical handle.
//!
//! [`ContinuousShard`]: crate::ensemble::federation::ContinuousShard

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::Result;

use crate::coordinator::{self, TuneResult, TuneSetup};
use crate::ensemble::federation::{ContinuousShard, ShardSpec};
use crate::ensemble::{checkpoint, ManagerCycle};
use crate::metrics::improvement_pct;
use crate::runtime::Scorer;
use crate::space::paper;

/// Progress notification from a running campaign. Protocol-agnostic
/// (no campaign id, no wire types) — the daemon's scheduler tags these
/// with the campaign id and lowers them to `protocol::Event` frames;
/// the CLI front-end renders them as trace lines.
#[derive(Debug, Clone, PartialEq)]
pub enum CampaignEvent {
    /// The campaign thread is up; evaluation budget attached.
    Started { evals_planned: u64 },
    /// `elites` prior observations were absorbed from the history store
    /// before the first proposal.
    WarmStarted { elites: u64 },
    /// A fresh configuration was proposed under global eval id `eval_id`.
    Proposed { eval_id: u64 },
    /// Eval `eval_id` completed and was applied in order.
    EvalCompleted {
        eval_id: u64,
        config_key: String,
        objective: f64,
        runtime_s: f64,
        best_so_far: f64,
        timed_out: bool,
        cancelled: bool,
    },
    /// `eval_id`'s result improved the campaign's best-so-far.
    Improved { eval_id: u64, best_objective: f64, config_desc: String },
    /// `eval_id` was cancelled by the straggler policy.
    StragglerKilled { eval_id: u64 },
}

/// How a campaign ended.
#[derive(Debug)]
pub enum CampaignOutcome {
    /// Budget drained normally.
    Finished(Box<TuneResult>),
    /// The cancel flag was honored between applies: `applied`
    /// completions are in the books, and — when the setup carried a
    /// checkpoint path — on disk via the v3 checkpoint written with
    /// every apply, ready for a later resume.
    Interrupted { applied: usize, checkpointed: bool },
    /// A retry budget was exhausted at an I/O boundary
    /// ([`crate::chaos::RetryExhausted`] in the error chain): the
    /// applied prefix stands, the campaign is terminal, and — crucially
    /// — the driver returns `Ok`, so a daemon hosting many campaigns
    /// degrades exactly one of them instead of dying.
    Degraded { applied: usize, message: String },
}

/// Does this setup run on the stepped continuous engine? (The dispatch
/// conditions `autotune_with_scorer` uses to land on
/// `autotune_continuous`, restated.)
pub fn steppable(setup: &TuneSetup) -> bool {
    setup.federation_shards == 0
        && setup.ensemble_workers >= 2
        && setup.manager_cycle == ManagerCycle::Continuous
}

/// Step one unsharded continuous-manager campaign to completion (or
/// cancellation), emitting a [`CampaignEvent`] stream through `sink`.
///
/// The shard is stepped one *applied completion* at a time
/// (`run_for(1)` repeated is pinned elsewhere to evolve state
/// identically to `run_for(MAX)`), with the cancel flag sampled between
/// steps — so a cancel never tears mid-apply and the applied prefix is
/// always a valid checkpointed trajectory.
pub fn drive_continuous(
    setup: &TuneSetup,
    scorer: Arc<Scorer>,
    cancel: &AtomicBool,
    sink: &mut dyn FnMut(CampaignEvent),
) -> Result<CampaignOutcome> {
    let space = Arc::new(paper::build_space(setup.app, setup.platform));
    let (baseline, baseline_objective) = coordinator::measure_baseline(setup, &scorer)?;
    let lens = ShardSpec { seed: setup.seed, shards: 1, shard: 0 };
    let mut shard = ContinuousShard::new(
        setup,
        lens,
        space.clone(),
        scorer.clone(),
        baseline_objective,
        checkpoint::fingerprint(setup),
        setup.checkpoint_path.clone(),
    )?;

    let mut best = f64::INFINITY;
    let mut interrupted = false;
    loop {
        if cancel.load(Ordering::SeqCst) {
            interrupted = true;
            break;
        }
        let proposed_before = shard.proposed();
        let applied_before = shard.applied();
        let n = match shard.run_for(1) {
            Ok(n) => n,
            Err(e) if crate::chaos::is_retry_exhausted(&e) => {
                let applied = shard.applied();
                log::warn!(
                    "campaign degraded after {applied} applied completions: {e:#}"
                );
                shard.finish(); // shuts the worker pool down
                return Ok(CampaignOutcome::Degraded { applied, message: format!("{e:#}") });
            }
            Err(e) => return Err(e),
        };
        for id in proposed_before..shard.proposed() {
            sink(CampaignEvent::Proposed { eval_id: id as u64 });
        }
        for r in &shard.records()[applied_before..] {
            sink(CampaignEvent::EvalCompleted {
                eval_id: r.id as u64,
                config_key: r.config_key.clone(),
                objective: r.objective,
                runtime_s: r.measured.runtime_s,
                best_so_far: r.best_so_far,
                timed_out: r.timed_out,
                cancelled: r.cancelled,
            });
            if r.cancelled {
                sink(CampaignEvent::StragglerKilled { eval_id: r.id as u64 });
            }
            if r.best_so_far.is_finite() && r.best_so_far < best {
                best = r.best_so_far;
                sink(CampaignEvent::Improved {
                    eval_id: r.id as u64,
                    best_objective: r.best_so_far,
                    config_desc: r.config_desc.clone(),
                });
            }
        }
        if n == 0 {
            break;
        }
    }

    if interrupted {
        let applied = shard.applied();
        // the v3 checkpoint is written with every apply; an applied
        // prefix plus a configured path means it is on disk already
        let checkpointed = setup.checkpoint_path.is_some() && applied > 0;
        shard.finish(); // shuts the worker pool down
        return Ok(CampaignOutcome::Interrupted { applied, checkpointed });
    }

    let run = shard.finish();
    let param_importance = coordinator::importance_from_db(&space, &run.db, setup.seed);
    Ok(CampaignOutcome::Finished(Box::new(TuneResult {
        setup: setup.clone(),
        space_size: space.size(),
        baseline,
        baseline_objective,
        best_objective: run.best,
        best_config_desc: run.best_desc,
        improvement_pct: improvement_pct(baseline_objective, run.best),
        wallclock_s: run.wallclock,
        evaluations: run.db.len(),
        scorer_accelerated: scorer.is_accelerated(),
        param_importance,
        db: run.db,
        ensemble: Some(run.stats),
        federation: None,
    })))
}

/// A campaign running on its own thread: start / poll events / cancel /
/// join. Both front-ends hold one of these per campaign.
pub struct CampaignHandle {
    events: Receiver<CampaignEvent>,
    cancel: Arc<AtomicBool>,
    /// The setup's observability sink, if one was attached (`--stats` /
    /// daemon campaigns) — held here so front-ends can snapshot live
    /// state without reaching into the campaign thread.
    obs: Option<Arc<crate::obs::ObsSink>>,
    thread: Option<JoinHandle<Result<CampaignOutcome>>>,
}

impl CampaignHandle {
    /// Launch `setup` on a fresh thread. The thread resolves the
    /// history-database warm start first (exactly as the classic
    /// dispatch does, so the resolved prior lands in the checkpoint
    /// fingerprint), emits `Started`/`WarmStarted`, then either steps
    /// the continuous engine (cancellable, event-streaming) or — for
    /// setups outside it (serial, generational, federated) — falls back
    /// to the blocking `autotune_with_scorer` dispatch, which appends
    /// history itself.
    pub fn start(setup: TuneSetup, scorer: Arc<Scorer>) -> CampaignHandle {
        let (tx, rx): (Sender<CampaignEvent>, Receiver<CampaignEvent>) =
            std::sync::mpsc::channel();
        let cancel = Arc::new(AtomicBool::new(false));
        let flag = cancel.clone();
        let obs = setup.obs.clone();
        let thread = std::thread::Builder::new()
            .name("campaign".into())
            .spawn(move || -> Result<CampaignOutcome> {
                let mut setup = setup;
                setup.parallel_evals = setup.parallel_evals.max(1);
                crate::history::apply_warm_start(&mut setup, scorer.as_ref())?;
                // sends are best-effort: a front-end that dropped its
                // receiver still deserves a completed campaign
                let _ = tx.send(CampaignEvent::Started {
                    evals_planned: setup.max_evals as u64,
                });
                if let Some(prior) = &setup.foreign_warm {
                    let _ = tx.send(CampaignEvent::WarmStarted {
                        elites: prior.len() as u64,
                    });
                }
                if steppable(&setup) {
                    let mut sink = |ev: CampaignEvent| {
                        let _ = tx.send(ev);
                    };
                    let outcome = drive_continuous(&setup, scorer, &flag, &mut sink)?;
                    // the classic dispatch appends completed runs to the
                    // history store; the stepped path owns that duty here
                    // (interrupted campaigns are NOT completed runs)
                    if let CampaignOutcome::Finished(result) = &outcome {
                        if let (Some(dir), None) = (&setup.history_dir, setup.kill_after_evals) {
                            let appended = crate::history::HistoryStore::open(dir)
                                .map(|store| match &setup.chaos {
                                    Some(plan) => store.with_chaos(plan.clone()),
                                    None => store,
                                })
                                .and_then(|store| {
                                    store.append(&crate::history::RunRecord::from_result(result))
                                });
                            match appended {
                                Ok(path) => {
                                    log::info!("tuning history appended to {}", path.display())
                                }
                                Err(e) => log::warn!(
                                    "tuning history NOT recorded to {}: {e:#} (the run result \
                                     is unaffected)",
                                    dir.display()
                                ),
                            }
                        }
                    }
                    Ok(outcome)
                } else {
                    let result = coordinator::autotune_with_scorer(&setup, scorer)?;
                    Ok(CampaignOutcome::Finished(Box::new(result)))
                }
            })
            .expect("spawn campaign thread");
        CampaignHandle { events: rx, cancel, obs, thread: Some(thread) }
    }

    /// The campaign's observability sink, when the setup carried one.
    /// Reading it (snapshot/tail) never perturbs the running trajectory.
    pub fn obs_sink(&self) -> Option<Arc<crate::obs::ObsSink>> {
        self.obs.clone()
    }

    /// Drain any events emitted since the last poll (non-blocking).
    pub fn poll_events(&self) -> Vec<CampaignEvent> {
        let mut out = Vec::new();
        loop {
            match self.events.try_recv() {
                Ok(ev) => out.push(ev),
                Err(TryRecvError::Empty | TryRecvError::Disconnected) => break,
            }
        }
        out
    }

    /// Block up to `timeout` for the next event. `None` once the
    /// campaign thread is done and the channel drained.
    pub fn recv_event(&self, timeout: std::time::Duration) -> Option<CampaignEvent> {
        self.events.recv_timeout(timeout).ok()
    }

    /// Has the campaign thread exited? (Events may still be queued.)
    pub fn is_done(&self) -> bool {
        self.thread.as_ref().map(|t| t.is_finished()).unwrap_or(true)
    }

    /// Request cancellation; honored between applied completions.
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::SeqCst);
    }

    /// The shared cancel flag (the daemon's SIGTERM hook raises many of
    /// these at once).
    pub fn cancel_flag(&self) -> Arc<AtomicBool> {
        self.cancel.clone()
    }

    /// Wait for the campaign thread and take its outcome. Idempotent
    /// callers beware: the outcome moves out; a second join errors.
    pub fn join(&mut self) -> Result<CampaignOutcome> {
        let t = self
            .thread
            .take()
            .ok_or_else(|| anyhow::anyhow!("campaign already joined"))?;
        match t.join() {
            Ok(res) => res,
            Err(_) => anyhow::bail!("campaign thread panicked"),
        }
    }
}
