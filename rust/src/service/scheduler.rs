//! Multi-campaign scheduler: N campaigns on a bounded shared substrate.
//!
//! Admission is FIFO with a concurrency cap (`max_active`): each running
//! campaign is a [`CampaignHandle`] — its own [`ContinuousShard`] state
//! machine with its own worker pool, RNG stream, and surrogate — so a
//! campaign's trajectory depends only on its own seed/policy, never on
//! what else is co-scheduled (pinned by `tests/service_e2e.rs` against
//! solo CLI runs). Fairness is therefore wholly an admission property:
//! the cap bounds the substrate, the queue order is submission order,
//! and nothing a running campaign does can perturb a neighbour's search.
//!
//! The scheduler owns the daemon's **shared history store**: every
//! completed campaign appends its run record, and every submitted
//! campaign (unless it opts out) is probed against the store *at
//! admission time* — if compatible-fingerprint elites exist, the warm
//! start is resolved eagerly under the admission lock, so the prior a
//! campaign absorbs is pinned the moment it is accepted, not whenever a
//! worker thread happens to start it.
//!
//! [`ContinuousShard`]: crate::ensemble::federation::ContinuousShard

use std::path::PathBuf;
use std::sync::atomic::AtomicBool;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::Result;

use crate::coordinator::TuneSetup;
use crate::runtime::Scorer;

use super::engine::{CampaignEvent, CampaignHandle, CampaignOutcome};
use super::protocol::{CampaignSpec, CampaignStatusInfo, CampaignSummary, Event};

/// Daemon-side service policy (the `[service]` config section).
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Campaigns running concurrently; further submissions queue.
    pub max_active: usize,
    /// Shared cross-run history store: completed campaigns append here,
    /// new compatible campaigns warm-start from here.
    pub history_dir: Option<PathBuf>,
    /// Directory for per-campaign v3 checkpoints (`campaign-<id>.json`);
    /// what makes a graceful shutdown resumable.
    pub checkpoint_dir: Option<PathBuf>,
    /// Elites to absorb when a warm start resolves.
    pub warm_start_elites: usize,
}

impl Default for ServiceConfig {
    fn default() -> ServiceConfig {
        ServiceConfig {
            max_active: 4,
            history_dir: None,
            checkpoint_dir: None,
            warm_start_elites: 8,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    Queued,
    Running,
    Done,
    Cancelled,
    Interrupted,
    /// Terminal: an I/O retry budget was exhausted; the applied prefix
    /// stands and the daemon stays up.
    Degraded,
    Failed,
}

impl Phase {
    fn name(self) -> &'static str {
        match self {
            Phase::Queued => "queued",
            Phase::Running => "running",
            Phase::Done => "done",
            Phase::Cancelled => "cancelled",
            Phase::Interrupted => "interrupted",
            Phase::Degraded => "degraded",
            Phase::Failed => "failed",
        }
    }

    fn is_terminal(self) -> bool {
        matches!(
            self,
            Phase::Done
                | Phase::Cancelled
                | Phase::Interrupted
                | Phase::Degraded
                | Phase::Failed
        )
    }
}

/// One campaign's scheduler-side record. The full event log is kept for
/// the campaign's lifetime so a watcher can attach at any point (or
/// re-attach after a dropped connection) and replay from any index.
struct Campaign {
    id: u64,
    spec: CampaignSpec,
    /// `Some` while waiting to run; taken at dispatch.
    setup: Option<TuneSetup>,
    phase: Phase,
    events: Vec<Event>,
    evaluations: u64,
    best_objective: f64,
    /// Raised to stop the running campaign (user cancel or shutdown).
    cancel: Option<Arc<AtomicBool>>,
    /// True when the stop came from daemon shutdown, not a user cancel —
    /// decides whether the terminal event is `Interrupted` or
    /// `Cancelled`.
    interrupt_requested: bool,
    /// Checkpoint path handed to the setup (reported in `Interrupted`).
    checkpointed_to: Option<PathBuf>,
    /// Live observability sink, cloned into the setup before dispatch.
    /// Always present for daemon campaigns: the engine records into it
    /// write-only, so `stats` queries can read counters and tail the
    /// event ring at any point in the lifecycle without perturbing the
    /// trajectory.
    obs: Arc<crate::obs::ObsSink>,
}

/// One atomic read of a campaign's event log: the tail from the caller's
/// cursor plus — decided under the *same* lock acquisition — whether
/// that tail reaches the end of a terminal campaign's log. Splitting
/// those two reads across lock acquisitions loses terminal events
/// appended in between (the watch replay→live handoff bug).
pub struct WatchChunk {
    pub events: Vec<Event>,
    /// The campaign is terminal and `events` ends at the log's end: the
    /// watcher now has everything it will ever get.
    pub complete: bool,
}

struct SchedState {
    campaigns: Vec<Campaign>,
    next_id: u64,
    running: usize,
    shutting_down: bool,
}

impl SchedState {
    fn campaign_mut(&mut self, id: u64) -> Option<&mut Campaign> {
        self.campaigns.iter_mut().find(|c| c.id == id)
    }

    fn campaign(&self, id: u64) -> Option<&Campaign> {
        self.campaigns.iter().find(|c| c.id == id)
    }
}

/// The daemon's campaign scheduler. All methods take `&Arc<Self>`
/// because dispatch spawns pump threads holding a scheduler reference.
pub struct Scheduler {
    scorer: Arc<Scorer>,
    cfg: ServiceConfig,
    state: Mutex<SchedState>,
    /// Notified on every event append and phase change (watchers block
    /// here; `shutdown` waits here for the running count to drain).
    wake: Condvar,
}

impl Scheduler {
    pub fn new(scorer: Arc<Scorer>, cfg: ServiceConfig) -> Arc<Scheduler> {
        Arc::new(Scheduler {
            scorer,
            cfg,
            state: Mutex::new(SchedState {
                campaigns: Vec::new(),
                next_id: 1,
                running: 0,
                shutting_down: false,
            }),
            wake: Condvar::new(),
        })
    }

    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }

    /// Admit a campaign: validate the spec, resolve the shared-history
    /// warm start (eagerly, under the admission lock — see module docs),
    /// assign an id, queue, and dispatch if a slot is free.
    pub fn submit(self: &Arc<Self>, spec: CampaignSpec) -> Result<u64> {
        let mut setup = spec.to_setup()?;
        if let Some(dir) = &self.cfg.history_dir {
            setup.history_dir = Some(dir.clone());
        }

        let mut st = self.state.lock().unwrap();
        anyhow::ensure!(!st.shutting_down, "daemon is shutting down; submissions refused");
        let id = st.next_id;
        st.next_id += 1;

        if let Some(dir) = &self.cfg.checkpoint_dir {
            setup.checkpoint_path = Some(dir.join(format!("campaign-{id}.json")));
        }

        // eager warm-start resolution: `apply_warm_start` refuses when
        // the store holds nothing compatible — that refusal is this
        // campaign's cold start, not an error (first campaigns into an
        // empty store, or a different app/platform/metric)
        if spec.warm_start && self.cfg.history_dir.is_some() {
            let mut warm = setup.clone();
            warm.warm_start_from = self.cfg.history_dir.clone();
            warm.warm_start_elites = self.cfg.warm_start_elites;
            match crate::history::apply_warm_start(&mut warm, self.scorer.as_ref()) {
                Ok(()) => setup = warm,
                Err(e) => log::info!("campaign {id}: cold start ({e:#})"),
            }
        }

        // every daemon campaign carries a sink; recording is write-only
        // from the engine, so this cannot alter the trajectory (pinned
        // by the stats on/off bit-identity e2e)
        let obs = Arc::new(crate::obs::ObsSink::default());
        setup.obs = Some(obs.clone());

        st.campaigns.push(Campaign {
            id,
            spec,
            setup: Some(setup),
            phase: Phase::Queued,
            events: Vec::new(),
            evaluations: 0,
            best_objective: f64::INFINITY,
            cancel: None,
            interrupt_requested: false,
            checkpointed_to: None,
            obs,
        });
        self.dispatch_locked(&mut st);
        drop(st);
        self.wake.notify_all();
        Ok(id)
    }

    /// Start queued campaigns while slots are free. Caller holds the lock.
    fn dispatch_locked(self: &Arc<Self>, st: &mut SchedState) {
        while st.running < self.cfg.max_active.max(1) {
            let Some(c) =
                st.campaigns.iter_mut().find(|c| c.phase == Phase::Queued && c.setup.is_some())
            else {
                break;
            };
            let id = c.id;
            let setup = c.setup.take().expect("queued campaign has a setup");
            c.checkpointed_to = setup.checkpoint_path.clone();
            c.phase = Phase::Running;
            let handle = CampaignHandle::start(setup, self.scorer.clone());
            c.cancel = Some(handle.cancel_flag());
            // a stop requested while this campaign was still queued
            // (cancel-then-dispatch race) applies immediately
            if c.interrupt_requested {
                handle.cancel();
            }
            st.running += 1;
            let sched = self.clone();
            std::thread::Builder::new()
                .name(format!("campaign-{id}-pump"))
                .spawn(move || sched.pump(id, handle))
                .expect("spawn campaign pump thread");
        }
    }

    /// Per-running-campaign event pump: forward engine events into the
    /// campaign's log, then translate the join outcome into the terminal
    /// event and free the slot.
    fn pump(self: Arc<Self>, id: u64, mut handle: CampaignHandle) {
        loop {
            match handle.recv_event(Duration::from_millis(100)) {
                Some(ev) => self.push_event(id, ev),
                None => {
                    if handle.is_done() {
                        for ev in handle.poll_events() {
                            self.push_event(id, ev);
                        }
                        break;
                    }
                }
            }
        }
        let outcome = handle.join();
        let mut st = self.state.lock().unwrap();
        if let Some(c) = st.campaign_mut(id) {
            let (phase, terminal) = match outcome {
                Ok(CampaignOutcome::Finished(result)) => {
                    let summary = CampaignSummary {
                        evaluations: result.evaluations as u64,
                        baseline_objective: result.baseline_objective,
                        best_objective: result.best_objective,
                        best_config_desc: result.best_config_desc.clone(),
                        improvement_pct: result.improvement_pct,
                        wallclock_s: result.wallclock_s,
                    };
                    (Phase::Done, Event::Done { campaign: id, summary })
                }
                Ok(CampaignOutcome::Interrupted { applied, checkpointed }) => {
                    if c.interrupt_requested {
                        (
                            Phase::Interrupted,
                            Event::Interrupted { campaign: id, applied: applied as u64, checkpointed },
                        )
                    } else {
                        (Phase::Cancelled, Event::Cancelled { campaign: id, applied: applied as u64 })
                    }
                }
                Ok(CampaignOutcome::Degraded { applied, message }) => (
                    Phase::Degraded,
                    Event::Degraded { campaign: id, applied: applied as u64, message },
                ),
                // the non-steppable engines (serial, generational,
                // federated) surface an exhausted retry budget as a
                // plain error; the typed marker in the chain still maps
                // it to Degraded, not Failed
                Err(e) if crate::chaos::is_retry_exhausted(&e) => (
                    Phase::Degraded,
                    Event::Degraded {
                        campaign: id,
                        applied: c.evaluations,
                        message: format!("{e:#}"),
                    },
                ),
                Err(e) => (Phase::Failed, Event::Failed { campaign: id, message: format!("{e:#}") }),
            };
            c.phase = phase;
            c.events.push(terminal);
        }
        st.running = st.running.saturating_sub(1);
        if !st.shutting_down {
            self.dispatch_locked(&mut st);
        }
        drop(st);
        self.wake.notify_all();
    }

    /// Append one engine event to a campaign's log (tagging it with the
    /// campaign id) and update the live counters.
    fn push_event(&self, id: u64, ev: CampaignEvent) {
        let mut st = self.state.lock().unwrap();
        if let Some(c) = st.campaign_mut(id) {
            let wire = match ev {
                CampaignEvent::Started { evals_planned } => {
                    Event::Started { campaign: id, evals_planned }
                }
                CampaignEvent::WarmStarted { elites } => Event::WarmStarted { campaign: id, elites },
                CampaignEvent::Proposed { eval_id } => Event::Proposed { campaign: id, eval_id },
                CampaignEvent::EvalCompleted {
                    eval_id,
                    config_key,
                    objective,
                    runtime_s,
                    best_so_far,
                    timed_out,
                    cancelled,
                } => {
                    c.evaluations += 1;
                    Event::EvalCompleted {
                        campaign: id,
                        eval_id,
                        config_key,
                        objective,
                        runtime_s,
                        best_so_far,
                        timed_out,
                        cancelled,
                    }
                }
                CampaignEvent::Improved { eval_id, best_objective, config_desc } => {
                    c.best_objective = best_objective;
                    Event::Improved { campaign: id, eval_id, best_objective, config_desc }
                }
                CampaignEvent::StragglerKilled { eval_id } => {
                    Event::StragglerKilled { campaign: id, eval_id }
                }
            };
            c.events.push(wire);
        }
        drop(st);
        self.wake.notify_all();
    }

    /// Events `from..` for `campaign`, blocking up to `timeout` while the
    /// log has nothing new **and** the campaign is not terminal. The
    /// returned chunk's `complete` flag is decided under the same lock
    /// acquisition that read the tail, so "you have everything" can never
    /// race a terminal event appended moments later — a watcher loops on
    /// this until `complete` and is guaranteed the full log, attached at
    /// any point in the campaign's lifecycle.
    pub fn wait_events(&self, campaign: u64, from: usize, timeout: Duration) -> Result<WatchChunk> {
        // real-time blocking wait only: what a watcher sees depends on
        // when it asks, but the event log itself is append-only and
        // deterministic
        let deadline = std::time::Instant::now() + timeout; // detlint: allow(wall-clock) -- condvar deadline, not trajectory state
        let mut st = self.state.lock().unwrap();
        loop {
            let Some(c) = st.campaign(campaign) else {
                anyhow::bail!("no such campaign: {campaign}");
            };
            let terminal = c.phase.is_terminal();
            if c.events.len() > from {
                return Ok(WatchChunk { events: c.events[from..].to_vec(), complete: terminal });
            }
            if terminal {
                return Ok(WatchChunk { events: Vec::new(), complete: true });
            }
            let now = std::time::Instant::now(); // detlint: allow(wall-clock) -- condvar deadline, not trajectory state
            if now >= deadline {
                return Ok(WatchChunk { events: Vec::new(), complete: false });
            }
            let (guard, _) = self.wake.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }

    /// One campaign's live observability state: the counter snapshot,
    /// the event-ring tail from `from`, and the cursor for the next
    /// poll. Read-only — the sink is recorded into by the engine and
    /// never read back, so polling this perturbs nothing.
    pub fn stats(
        &self,
        campaign: u64,
        from: u64,
    ) -> Result<(crate::obs::StatsSnapshot, Vec<crate::obs::RingEvent>, u64)> {
        let obs = {
            let st = self.state.lock().unwrap();
            let Some(c) = st.campaign(campaign) else {
                anyhow::bail!("no such campaign: {campaign}");
            };
            c.obs.clone()
        };
        let snapshot = obs.snapshot();
        let (events, next) = obs.tail(from);
        Ok((snapshot, events, next))
    }

    /// Is this campaign terminal (done, cancelled, interrupted, failed)?
    pub fn is_terminal(&self, campaign: u64) -> Result<bool> {
        let st = self.state.lock().unwrap();
        let Some(c) = st.campaign(campaign) else {
            anyhow::bail!("no such campaign: {campaign}");
        };
        Ok(c.phase.is_terminal())
    }

    /// Request cancellation. A queued campaign goes terminal at once; a
    /// running one stops at its next applied completion. Idempotent on
    /// terminal campaigns.
    pub fn cancel(&self, campaign: u64) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        let Some(c) = st.campaign_mut(campaign) else {
            anyhow::bail!("no such campaign: {campaign}");
        };
        match c.phase {
            Phase::Queued => {
                c.phase = Phase::Cancelled;
                c.events.push(Event::Cancelled { campaign, applied: 0 });
            }
            Phase::Running => {
                if let Some(flag) = &c.cancel {
                    flag.store(true, std::sync::atomic::Ordering::SeqCst);
                }
            }
            _ => {}
        }
        drop(st);
        self.wake.notify_all();
        Ok(())
    }

    /// Graceful-stop entry (shutdown request or SIGTERM): refuse new
    /// submissions, mark every live campaign interrupted, raise every
    /// running campaign's cancel flag. Running campaigns checkpoint at
    /// their next apply boundary and their watchers get a terminal
    /// `Interrupted` event from the pump.
    pub fn interrupt_all(&self) {
        let mut st = self.state.lock().unwrap();
        st.shutting_down = true;
        for c in st.campaigns.iter_mut() {
            match c.phase {
                Phase::Queued => {
                    c.interrupt_requested = true;
                    c.phase = Phase::Interrupted;
                    c.events.push(Event::Interrupted {
                        campaign: c.id,
                        applied: 0,
                        checkpointed: false,
                    });
                }
                Phase::Running => {
                    c.interrupt_requested = true;
                    if let Some(flag) = &c.cancel {
                        flag.store(true, std::sync::atomic::Ordering::SeqCst);
                    }
                }
                _ => {}
            }
        }
        drop(st);
        self.wake.notify_all();
    }

    /// [`Scheduler::interrupt_all`], then block until every running
    /// campaign has gone terminal (pumps push the terminal events before
    /// freeing their slot, so returning here means every watcher can
    /// drain a complete log).
    pub fn shutdown(&self) {
        self.interrupt_all();
        let mut st = self.state.lock().unwrap();
        while st.running > 0 {
            let (guard, _) =
                self.wake.wait_timeout(st, Duration::from_millis(200)).unwrap();
            st = guard;
        }
    }

    /// One status row per campaign, submission order.
    pub fn status(&self) -> Vec<CampaignStatusInfo> {
        let st = self.state.lock().unwrap();
        st.campaigns
            .iter()
            .map(|c| CampaignStatusInfo {
                id: c.id,
                state: c.phase.name().to_string(),
                app: c.spec.app.clone(),
                seed: c.spec.seed,
                evaluations: c.evaluations,
                best_objective: c.best_objective,
            })
            .collect()
    }
}
