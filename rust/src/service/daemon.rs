//! `ytopt-serve`: the TCP front-end over [`Scheduler`].
//!
//! One accept loop (non-blocking listener polled alongside the stop
//! flag), one thread per connection. A connection speaks the framed
//! protocol: requests are answered in order; a `Watch` request starts a
//! dedicated streaming thread over the connection's frame-atomic shared
//! writer, so the request path keeps answering submit/status/cancel/
//! stats while events flow — a slow or stalled watcher costs only its
//! own stream (writes carry a stall timeout), never the request path
//! and never daemon shutdown. Framing junk poisons the stream, so a
//! decode error drops the connection — the protocol cannot
//! resynchronize mid-garbage.
//!
//! Graceful shutdown (satellite 2): a `Shutdown` request or SIGTERM
//! stops the accept loop, refuses new submissions, and interrupts every
//! live campaign through [`Scheduler::interrupt_all`] — running
//! campaigns stop at their next apply boundary with their v3 checkpoint
//! already on disk, and every watcher receives a terminal
//! [`Event::Interrupted`](super::protocol::Event::Interrupted) frame
//! instead of a dropped socket.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use super::protocol::{encode_frame, Decoder, Message, Request, Response};
use super::scheduler::{Scheduler, ServiceConfig};
use crate::runtime::Scorer;

/// The `[service]` config section plus the listen address.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// TCP listen address; port 0 binds an ephemeral port (the loopback
    /// e2e harness uses this).
    pub listen: String,
    pub service: ServiceConfig,
    /// Socket failpoints (`sock-read` / `sock-write` sites): `None` in
    /// production. The plan is shared by every connection thread, so
    /// occurrence counters span the daemon, not one peer.
    pub chaos: Option<Arc<crate::chaos::FaultPlan>>,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            listen: "127.0.0.1:7459".into(),
            service: ServiceConfig::default(),
            chaos: None,
        }
    }
}

/// Raised by the SIGTERM handler; polled by every daemon's accept loop.
static TERM_REQUESTED: AtomicBool = AtomicBool::new(false);

/// Install the SIGTERM hook (idempotent). Signal-handler discipline: the
/// handler only stores to an atomic; the accept loop does the actual
/// shutdown work at poll granularity. No `libc` crate in the offline
/// set — std already links the platform libc, so the raw `signal(2)`
/// symbol resolves.
#[cfg(unix)]
pub fn install_sigterm_hook() {
    extern "C" fn on_term(_signum: i32) {
        TERM_REQUESTED.store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGTERM, on_term as usize);
    }
}

#[cfg(not(unix))]
pub fn install_sigterm_hook() {}

/// True once SIGTERM has been delivered (test hooks may set it too).
pub fn sigterm_requested() -> bool {
    TERM_REQUESTED.load(Ordering::SeqCst)
}

/// Raised by the SIGUSR1 handler; consumed by the solo CLI's event loop
/// to dump a live stats snapshot (`ytopt-rs tune --stats`).
static USR1_REQUESTED: AtomicBool = AtomicBool::new(false);

/// Install the SIGUSR1 hook (idempotent); same raw-`signal(2)`
/// discipline as [`install_sigterm_hook`] — the handler only stores to
/// an atomic, the event loop does the dump at poll granularity.
#[cfg(unix)]
pub fn install_sigusr1_hook() {
    extern "C" fn on_usr1(_signum: i32) {
        USR1_REQUESTED.store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    const SIGUSR1: i32 = 10;
    unsafe {
        signal(SIGUSR1, on_usr1 as usize);
    }
}

#[cfg(not(unix))]
pub fn install_sigusr1_hook() {}

/// True if SIGUSR1 arrived since the last call (consumes the flag, so
/// each delivery triggers exactly one dump).
pub fn take_sigusr1() -> bool {
    USR1_REQUESTED.swap(false, Ordering::SeqCst)
}

/// A running daemon: listener + scheduler + connection threads.
pub struct Daemon {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    scheduler: Arc<Scheduler>,
    accept_thread: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl Daemon {
    /// Bind and start serving. Returns once the listener is live (the
    /// bound address — with the resolved ephemeral port — is available
    /// immediately via [`Daemon::addr`]).
    pub fn start(cfg: ServeConfig, scorer: Arc<Scorer>) -> Result<Daemon> {
        let listener = TcpListener::bind(&cfg.listen)
            .with_context(|| format!("binding service listener on {}", cfg.listen))?;
        listener.set_nonblocking(true).context("nonblocking listener")?;
        let addr = listener.local_addr().context("resolving bound address")?;
        let scheduler = Scheduler::new(scorer, cfg.service);
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));

        let accept_stop = stop.clone();
        let accept_sched = scheduler.clone();
        let accept_conns = conns.clone();
        let accept_chaos = cfg.chaos.clone();
        let accept_thread = std::thread::Builder::new()
            .name("ytopt-serve-accept".into())
            .spawn(move || loop {
                if sigterm_requested() && !accept_stop.swap(true, Ordering::SeqCst) {
                    log::info!("SIGTERM: interrupting live campaigns, refusing new work");
                    accept_sched.interrupt_all();
                }
                if accept_stop.load(Ordering::SeqCst) {
                    break;
                }
                match listener.accept() {
                    Ok((stream, peer)) => {
                        log::debug!("service connection from {peer}");
                        let sched = accept_sched.clone();
                        let stop = accept_stop.clone();
                        let chaos = accept_chaos.clone();
                        match std::thread::Builder::new()
                            .name("ytopt-serve-conn".into())
                            .spawn(move || serve_connection(stream, sched, stop, chaos))
                        {
                            Ok(handle) => accept_conns
                                .lock()
                                .unwrap_or_else(std::sync::PoisonError::into_inner)
                                .push(handle),
                            Err(e) => {
                                // refuse this connection (its stream drops
                                // here) rather than panic the accept loop
                                // and take every campaign down with it
                                log::warn!("could not spawn a connection thread: {e}");
                            }
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(25));
                    }
                    Err(e) => {
                        log::warn!("service accept failed: {e}");
                        std::thread::sleep(Duration::from_millis(25));
                    }
                }
            })
            .context("spawning the service accept thread")?;

        Ok(Daemon { addr, stop, scheduler, accept_thread: Some(accept_thread), conns })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn scheduler(&self) -> Arc<Scheduler> {
        self.scheduler.clone()
    }

    /// Has a stop (Shutdown request, SIGTERM, or [`Daemon::request_stop`])
    /// been initiated?
    pub fn stop_requested(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    /// Initiate a graceful stop without blocking: accept loop winds
    /// down, live campaigns are interrupted.
    pub fn request_stop(&self) {
        if !self.stop.swap(true, Ordering::SeqCst) {
            self.scheduler.interrupt_all();
        }
    }

    /// Graceful stop, run to completion: every campaign terminal (and
    /// checkpointed, when configured), every connection drained, every
    /// thread joined.
    pub fn shutdown(mut self) {
        self.request_stop();
        self.scheduler.shutdown();
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        // a connection thread that panicked poisons this lock; drain the
        // survivors anyway instead of double-panicking the shutdown
        let handles: Vec<JoinHandle<()>> = std::mem::take(
            &mut *self.conns.lock().unwrap_or_else(std::sync::PoisonError::into_inner),
        );
        for h in handles {
            let _ = h.join();
        }
    }
}

/// Writes from the request loop and any live watch threads interleave
/// on one socket; the mutex keeps each frame atomic on the wire.
type SharedWriter = Arc<Mutex<TcpStream>>;

/// A peer that stops draining its socket is disconnected once a frame
/// write has been stuck this long, instead of pinning a daemon thread
/// (and daemon shutdown, which joins them all) in `write_all` forever.
const WRITE_STALL_TIMEOUT: Duration = Duration::from_secs(5);

/// Serve one connection until the peer hangs up, framing breaks, or the
/// daemon stops. Watch streams run on their own threads and are joined
/// on the way out — by then their campaigns are terminal (shutdown
/// interrupts them) or their writes have failed/stalled out.
///
/// Under an armed `chaos` plan the `sock-read` site fires after each
/// successful read (reset → drop this connection; stall → park the
/// request path) and the `sock-write` site fires inside [`write_msg`]
/// (torn frame, reset, stall). Every fault costs only this peer — the
/// accept loop, scheduler, and sibling connections never see it.
fn serve_connection(
    mut stream: TcpStream,
    sched: Arc<Scheduler>,
    stop: Arc<AtomicBool>,
    chaos: Option<Arc<crate::chaos::FaultPlan>>,
) {
    if stream.set_read_timeout(Some(Duration::from_millis(250))).is_err() {
        return;
    }
    if stream.set_write_timeout(Some(WRITE_STALL_TIMEOUT)).is_err() {
        return;
    }
    let writer: SharedWriter = match stream.try_clone() {
        Ok(w) => Arc::new(Mutex::new(w)),
        Err(_) => return,
    };
    let mut watchers: Vec<JoinHandle<()>> = Vec::new();
    let mut dec = Decoder::new();
    let mut buf = [0u8; 4096];
    'serve: loop {
        match stream.read(&mut buf) {
            Ok(0) => break, // peer closed
            Ok(n) => {
                if let Some(plan) = chaos.as_deref() {
                    match plan.fire(crate::chaos::Site::SockRead) {
                        Some(crate::chaos::Fault::SockReset) => {
                            log::warn!("chaos: dropping the connection after a read");
                            break;
                        }
                        Some(crate::chaos::Fault::SockStall { ms }) => {
                            log::warn!("chaos: stalling the request path for {ms}ms");
                            std::thread::sleep(Duration::from_millis(ms));
                        }
                        Some(_) | None => {}
                    }
                }
                let msgs = match dec.push(&buf[..n]) {
                    Ok(m) => m,
                    Err(e) => {
                        log::warn!("dropping connection on framing error: {e}");
                        let _ = write_msg(
                            &writer,
                            &Message::Response(Response::Error { message: e.to_string() }),
                            chaos.as_deref(),
                        );
                        break;
                    }
                };
                for msg in msgs {
                    if !handle_message(&writer, &sched, &stop, &mut watchers, &chaos, msg) {
                        break 'serve;
                    }
                }
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                // idle: once the daemon is stopping, stop reading new
                // requests; live watch threads drain below (shutdown
                // interrupts their campaigns, which pushes the terminal
                // events they are waiting on)
                if stop.load(Ordering::SeqCst) {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    for w in watchers {
        let _ = w.join();
    }
}

/// Dispatch one request; returns false when the connection should close.
fn handle_message(
    writer: &SharedWriter,
    sched: &Arc<Scheduler>,
    stop: &Arc<AtomicBool>,
    watchers: &mut Vec<JoinHandle<()>>,
    chaos: &Option<Arc<crate::chaos::FaultPlan>>,
    msg: Message,
) -> bool {
    let plan = chaos.as_deref();
    let req = match msg {
        Message::Request(r) => r,
        _ => {
            let _ = write_msg(
                writer,
                &Message::Response(Response::Error {
                    message: "clients send request frames".into(),
                }),
                plan,
            );
            return false;
        }
    };
    match req {
        Request::Ping => write_msg(writer, &Message::Response(Response::Pong), plan),
        Request::Submit { spec } => {
            let resp = match sched.submit(spec) {
                Ok(campaign) => Response::Accepted { campaign },
                Err(e) => Response::Error { message: format!("{e:#}") },
            };
            write_msg(writer, &Message::Response(resp), plan)
        }
        Request::Status => write_msg(
            writer,
            &Message::Response(Response::Status { campaigns: sched.status() }),
            plan,
        ),
        Request::Cancel { campaign } => {
            let resp = match sched.cancel(campaign) {
                Ok(()) => Response::Cancelling { campaign },
                Err(e) => Response::Error { message: format!("{e:#}") },
            };
            write_msg(writer, &Message::Response(resp), plan)
        }
        Request::Stats { campaign, from } => {
            let resp = match sched.stats(campaign, from) {
                Ok((snapshot, events, next)) => {
                    Response::StatsReply { campaign, snapshot, events, next }
                }
                Err(e) => Response::Error { message: format!("{e:#}") },
            };
            write_msg(writer, &Message::Response(resp), plan)
        }
        Request::Shutdown => {
            let ok = write_msg(writer, &Message::Response(Response::ShuttingDown), plan);
            if !stop.swap(true, Ordering::SeqCst) {
                log::info!("shutdown requested over the wire");
                sched.interrupt_all();
            }
            ok
        }
        Request::Watch { campaign, from } => {
            // streaming runs on its own thread over the frame-atomic
            // shared writer, so this connection keeps answering
            // submit/status/cancel/stats while events flow — the old
            // inline loop parked the request path here until the
            // campaign went terminal
            let watch_sched = sched.clone();
            let watch_writer = writer.clone();
            let watch_chaos = chaos.clone();
            match std::thread::Builder::new()
                .name("ytopt-serve-watch".into())
                .spawn(move || {
                    stream_watch(&watch_writer, &watch_sched, campaign, from, watch_chaos)
                }) {
                Ok(handle) => {
                    watchers.push(handle);
                    true
                }
                Err(e) => write_msg(
                    writer,
                    &Message::Response(Response::Error {
                        message: format!("could not start a watch stream: {e}"),
                    }),
                    plan,
                ),
            }
        }
    }
}

/// Stream one watch to its conclusion: replay from `from`, then follow
/// live until the terminal event. [`WatchChunk::complete`] is decided by
/// the scheduler under the same lock acquisition that reads the tail,
/// so the replay→live handoff can never drop a terminal event appended
/// between polls — a watcher attached at any point gets the full
/// remainder of the log, exactly once.
///
/// [`WatchChunk::complete`]: super::scheduler::WatchChunk
fn stream_watch(
    writer: &SharedWriter,
    sched: &Arc<Scheduler>,
    campaign: u64,
    from: u64,
    chaos: Option<Arc<crate::chaos::FaultPlan>>,
) {
    let plan = chaos.as_deref();
    let mut idx = from as usize;
    loop {
        let chunk = match sched.wait_events(campaign, idx, Duration::from_secs(1)) {
            Ok(chunk) => chunk,
            Err(e) => {
                let _ = write_msg(
                    writer,
                    &Message::Response(Response::Error { message: format!("{e:#}") }),
                    plan,
                );
                return;
            }
        };
        idx += chunk.events.len();
        for ev in chunk.events {
            if !write_msg(writer, &Message::Event(ev), plan) {
                return; // peer gone, or a write stalled past the timeout
            }
        }
        if chunk.complete {
            return;
        }
    }
}

/// Write one frame atomically on the shared socket. Under an armed plan
/// the `sock-write` site can tear the frame (a strict prefix reaches
/// the wire, then the socket is shut down — the client's decoder sees
/// EOF mid-frame), reset the connection before any bytes move, or stall
/// the write. Torn/reset report failure so the caller winds the
/// connection (or just its watch stream) down, exactly as it would for
/// a genuinely broken peer.
fn write_msg(writer: &SharedWriter, msg: &Message, chaos: Option<&crate::chaos::FaultPlan>) -> bool {
    let frame = encode_frame(msg);
    if let Some(plan) = chaos {
        match plan.fire(crate::chaos::Site::SockWrite) {
            Some(crate::chaos::Fault::SockTorn { frac }) => {
                let keep =
                    (((frame.len() as f64) * frac) as usize).min(frame.len().saturating_sub(1));
                log::warn!("chaos: tearing a frame at {keep} of {} bytes", frame.len());
                let mut stream =
                    writer.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
                let _ = stream.write_all(&frame[..keep]).and_then(|_| stream.flush());
                let _ = stream.shutdown(std::net::Shutdown::Both);
                return false;
            }
            Some(crate::chaos::Fault::SockReset) => {
                log::warn!("chaos: resetting the connection before a frame write");
                let stream = writer.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
                let _ = stream.shutdown(std::net::Shutdown::Both);
                return false;
            }
            Some(crate::chaos::Fault::SockStall { ms }) => {
                log::warn!("chaos: stalling a frame write for {ms}ms");
                std::thread::sleep(Duration::from_millis(ms));
            }
            Some(_) | None => {}
        }
    }
    let mut stream = writer.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    stream.write_all(&frame).and_then(|_| stream.flush()).is_ok()
}
