//! Loopback client for the tuning daemon: a blocking [`TcpStream`]
//! wrapped in the frame [`Decoder`]. Used by the CLI `submit`/`watch`/
//! `status`/`cancel`/`stats`/`top` subcommands,
//! `examples/service_tuning.rs`, and the `tests/service_e2e.rs` harness.

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{Context, Result};

use super::protocol::{
    encode_frame, CampaignSpec, CampaignStatusInfo, Decoder, Event, Message, Request, Response,
};

pub struct Client {
    stream: TcpStream,
    dec: Decoder,
    /// Frames decoded past the one a caller asked for (a watch stream
    /// can arrive in bursts bigger than one read).
    queue: VecDeque<Message>,
}

impl Client {
    /// Connect to a daemon. The generous read timeout is the stall
    /// detector: campaigns emit events continuously while running, so
    /// two silent minutes means the daemon is gone.
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to tuning daemon at {addr}"))?;
        stream
            .set_read_timeout(Some(Duration::from_secs(120)))
            .context("setting client read timeout")?;
        Ok(Client { stream, dec: Decoder::new(), queue: VecDeque::new() })
    }

    fn send(&mut self, req: Request) -> Result<()> {
        self.stream
            .write_all(&encode_frame(&Message::Request(req)))
            .and_then(|_| self.stream.flush())
            .context("writing request frame")
    }

    /// Next message off the wire (or the local queue).
    fn next_message(&mut self) -> Result<Message> {
        if let Some(m) = self.queue.pop_front() {
            return Ok(m);
        }
        let mut buf = [0u8; 4096];
        loop {
            let n = self.stream.read(&mut buf).context("reading from daemon")?;
            anyhow::ensure!(n > 0, "daemon closed the connection");
            let msgs = self.dec.push(&buf[..n]).context("decoding daemon frames")?;
            self.queue.extend(msgs);
            if let Some(m) = self.queue.pop_front() {
                return Ok(m);
            }
        }
    }

    /// Send a request and take the daemon's (single) response,
    /// surfacing `Error` responses as errors.
    fn request(&mut self, req: Request) -> Result<Response> {
        self.send(req)?;
        match self.next_message()? {
            Message::Response(Response::Error { message }) => {
                anyhow::bail!("daemon refused: {message}")
            }
            Message::Response(r) => Ok(r),
            other => anyhow::bail!("expected a response frame, got {other:?}"),
        }
    }

    pub fn ping(&mut self) -> Result<()> {
        match self.request(Request::Ping)? {
            Response::Pong => Ok(()),
            other => anyhow::bail!("expected pong, got {other:?}"),
        }
    }

    /// Submit a campaign; returns the assigned campaign id.
    pub fn submit(&mut self, spec: CampaignSpec) -> Result<u64> {
        match self.request(Request::Submit { spec })? {
            Response::Accepted { campaign } => Ok(campaign),
            other => anyhow::bail!("expected acceptance, got {other:?}"),
        }
    }

    pub fn status(&mut self) -> Result<Vec<CampaignStatusInfo>> {
        match self.request(Request::Status)? {
            Response::Status { campaigns } => Ok(campaigns),
            other => anyhow::bail!("expected a status listing, got {other:?}"),
        }
    }

    pub fn cancel(&mut self, campaign: u64) -> Result<()> {
        match self.request(Request::Cancel { campaign })? {
            Response::Cancelling { .. } => Ok(()),
            other => anyhow::bail!("expected a cancel acknowledgement, got {other:?}"),
        }
    }

    /// Query a campaign's live observability state: the counter
    /// snapshot, the event-ring tail from logical clock `from`, and the
    /// cursor to pass on the next poll. Read-only on the daemon side —
    /// safe to poll a running campaign at any rate (`ytopt-rs top` does
    /// exactly that).
    pub fn stats(
        &mut self,
        campaign: u64,
        from: u64,
    ) -> Result<(crate::obs::StatsSnapshot, Vec<crate::obs::RingEvent>, u64)> {
        match self.request(Request::Stats { campaign, from })? {
            Response::StatsReply { snapshot, events, next, .. } => Ok((snapshot, events, next)),
            other => anyhow::bail!("expected a stats reply, got {other:?}"),
        }
    }

    /// Request graceful daemon shutdown (acknowledged before the daemon
    /// begins interrupting campaigns).
    pub fn shutdown(&mut self) -> Result<()> {
        match self.request(Request::Shutdown)? {
            Response::ShuttingDown => Ok(()),
            other => anyhow::bail!("expected a shutdown acknowledgement, got {other:?}"),
        }
    }

    /// Stream `campaign`'s events from index `from`, invoking `on_event`
    /// for each, until the terminal event arrives — which is returned.
    pub fn watch(
        &mut self,
        campaign: u64,
        from: u64,
        on_event: &mut dyn FnMut(&Event),
    ) -> Result<Event> {
        self.send(Request::Watch { campaign, from })?;
        loop {
            match self.next_message()? {
                Message::Event(ev) => {
                    on_event(&ev);
                    if ev.is_terminal() {
                        return Ok(ev);
                    }
                }
                Message::Response(Response::Error { message }) => {
                    anyhow::bail!("daemon refused watch: {message}")
                }
                other => anyhow::bail!("expected an event frame, got {other:?}"),
            }
        }
    }
}
