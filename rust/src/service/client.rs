//! Loopback client for the tuning daemon: a blocking [`TcpStream`]
//! wrapped in the frame [`Decoder`]. Used by the CLI `submit`/`watch`/
//! `status`/`cancel`/`stats`/`top` subcommands,
//! `examples/service_tuning.rs`, and the `tests/service_e2e.rs` harness.

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{Context, Result};

use super::protocol::{
    encode_frame, CampaignSpec, CampaignStatusInfo, Decoder, Event, Message, Request, Response,
};

/// The daemon answered with a protocol-level refusal (`Response::Error`).
/// The connection itself is healthy, so reconnect-and-retry cannot help;
/// [`ResilientClient`] surfaces these immediately instead of burning its
/// reconnect budget on them.
#[derive(Debug)]
pub struct Refused(pub String);

impl std::fmt::Display for Refused {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "daemon refused: {}", self.0)
    }
}

impl std::error::Error for Refused {}

/// Is this a daemon refusal (anywhere in the chain) rather than a
/// transport failure?
pub fn is_refusal(e: &anyhow::Error) -> bool {
    e.chain().any(|c| c.is::<Refused>())
}

pub struct Client {
    stream: TcpStream,
    dec: Decoder,
    /// Frames decoded past the one a caller asked for (a watch stream
    /// can arrive in bursts bigger than one read).
    queue: VecDeque<Message>,
}

impl Client {
    /// Connect to a daemon. The generous read timeout is the stall
    /// detector: campaigns emit events continuously while running, so
    /// two silent minutes means the daemon is gone.
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to tuning daemon at {addr}"))?;
        stream
            .set_read_timeout(Some(Duration::from_secs(120)))
            .context("setting client read timeout")?;
        Ok(Client { stream, dec: Decoder::new(), queue: VecDeque::new() })
    }

    fn send(&mut self, req: Request) -> Result<()> {
        self.stream
            .write_all(&encode_frame(&Message::Request(req)))
            .and_then(|_| self.stream.flush())
            .context("writing request frame")
    }

    /// Next message off the wire (or the local queue).
    fn next_message(&mut self) -> Result<Message> {
        if let Some(m) = self.queue.pop_front() {
            return Ok(m);
        }
        let mut buf = [0u8; 4096];
        loop {
            let n = self.stream.read(&mut buf).context("reading from daemon")?;
            anyhow::ensure!(n > 0, "daemon closed the connection");
            let msgs = self.dec.push(&buf[..n]).context("decoding daemon frames")?;
            self.queue.extend(msgs);
            if let Some(m) = self.queue.pop_front() {
                return Ok(m);
            }
        }
    }

    /// Send a request and take the daemon's (single) response,
    /// surfacing `Error` responses as errors.
    fn request(&mut self, req: Request) -> Result<Response> {
        self.send(req)?;
        match self.next_message()? {
            Message::Response(Response::Error { message }) => {
                Err(anyhow::Error::new(Refused(message)))
            }
            Message::Response(r) => Ok(r),
            other => anyhow::bail!("expected a response frame, got {other:?}"),
        }
    }

    pub fn ping(&mut self) -> Result<()> {
        match self.request(Request::Ping)? {
            Response::Pong => Ok(()),
            other => anyhow::bail!("expected pong, got {other:?}"),
        }
    }

    /// Submit a campaign; returns the assigned campaign id.
    pub fn submit(&mut self, spec: CampaignSpec) -> Result<u64> {
        match self.request(Request::Submit { spec })? {
            Response::Accepted { campaign } => Ok(campaign),
            other => anyhow::bail!("expected acceptance, got {other:?}"),
        }
    }

    pub fn status(&mut self) -> Result<Vec<CampaignStatusInfo>> {
        match self.request(Request::Status)? {
            Response::Status { campaigns } => Ok(campaigns),
            other => anyhow::bail!("expected a status listing, got {other:?}"),
        }
    }

    pub fn cancel(&mut self, campaign: u64) -> Result<()> {
        match self.request(Request::Cancel { campaign })? {
            Response::Cancelling { .. } => Ok(()),
            other => anyhow::bail!("expected a cancel acknowledgement, got {other:?}"),
        }
    }

    /// Query a campaign's live observability state: the counter
    /// snapshot, the event-ring tail from logical clock `from`, and the
    /// cursor to pass on the next poll. Read-only on the daemon side —
    /// safe to poll a running campaign at any rate (`ytopt-rs top` does
    /// exactly that).
    pub fn stats(
        &mut self,
        campaign: u64,
        from: u64,
    ) -> Result<(crate::obs::StatsSnapshot, Vec<crate::obs::RingEvent>, u64)> {
        match self.request(Request::Stats { campaign, from })? {
            Response::StatsReply { snapshot, events, next, .. } => Ok((snapshot, events, next)),
            other => anyhow::bail!("expected a stats reply, got {other:?}"),
        }
    }

    /// Request graceful daemon shutdown (acknowledged before the daemon
    /// begins interrupting campaigns).
    pub fn shutdown(&mut self) -> Result<()> {
        match self.request(Request::Shutdown)? {
            Response::ShuttingDown => Ok(()),
            other => anyhow::bail!("expected a shutdown acknowledgement, got {other:?}"),
        }
    }

    /// Stream `campaign`'s events from index `from`, invoking `on_event`
    /// for each, until the terminal event arrives — which is returned.
    pub fn watch(
        &mut self,
        campaign: u64,
        from: u64,
        on_event: &mut dyn FnMut(&Event),
    ) -> Result<Event> {
        self.send(Request::Watch { campaign, from })?;
        loop {
            match self.next_message()? {
                Message::Event(ev) => {
                    on_event(&ev);
                    if ev.is_terminal() {
                        return Ok(ev);
                    }
                }
                Message::Response(Response::Error { message }) => {
                    return Err(anyhow::Error::new(Refused(message)))
                        .context("daemon refused watch")
                }
                other => anyhow::bail!("expected an event frame, got {other:?}"),
            }
        }
    }
}

/// A client that survives connection loss: every operation redials on
/// failure with capped deterministic backoff ([`crate::chaos::Backoff`]),
/// and the stream cursors are absolute — the daemon's per-campaign event
/// log index for `watch`, the ring logical clock for `stats` — so a
/// retry on a fresh connection resumes exactly where the dead one
/// stopped. No event is double-printed and none is lost.
///
/// Daemon refusals ([`Refused`]) are NOT retried: the connection that
/// carried them is healthy, so redialing cannot change the answer.
pub struct ResilientClient {
    addr: String,
    client: Option<Client>,
    backoff: crate::chaos::Backoff,
    max_attempts: u32,
}

impl ResilientClient {
    /// Defaults: 8 reconnect attempts, 50ms doubling to a 2s cap, with
    /// seed-0 deterministic jitter.
    pub fn new(addr: &str) -> ResilientClient {
        ResilientClient {
            addr: addr.to_string(),
            client: None,
            backoff: crate::chaos::Backoff::new(50, 2_000, 0),
            max_attempts: 8,
        }
    }

    /// Override the reconnect policy (tests tighten it so chaotic soak
    /// runs fail fast instead of sleeping through the budget).
    pub fn with_policy(
        mut self,
        max_attempts: u32,
        backoff: crate::chaos::Backoff,
    ) -> ResilientClient {
        self.max_attempts = max_attempts;
        self.backoff = backoff;
        self
    }

    fn connected(&mut self) -> Result<&mut Client> {
        if self.client.is_none() {
            self.client = Some(Client::connect(&self.addr)?);
        }
        Ok(self.client.as_mut().expect("just connected"))
    }

    /// Run one operation, redialing between attempts. The connection is
    /// dropped after every failure, so a half-decoded frame can never
    /// leak into the retry.
    fn with_retry<T>(
        &mut self,
        label: &str,
        mut op: impl FnMut(&mut Client) -> Result<T>,
    ) -> Result<T> {
        let mut attempt: u32 = 0;
        loop {
            match self.connected().and_then(|c| op(c)) {
                Ok(v) => return Ok(v),
                Err(e) => {
                    self.client = None;
                    if is_refusal(&e) || attempt >= self.max_attempts {
                        return Err(e.context(format!(
                            "{label} gave up after {} attempt(s)",
                            attempt + 1
                        )));
                    }
                    log::warn!("{label} failed ({e:#}); redialing {}", self.addr);
                    self.backoff.sleep(attempt);
                    attempt += 1;
                }
            }
        }
    }

    /// Submission is NOT idempotent — once the request frame may have
    /// reached the daemon, a retry could queue the campaign twice. Only
    /// the dial retries; a failure after that surfaces to the caller.
    pub fn submit(&mut self, spec: CampaignSpec) -> Result<u64> {
        let mut attempt: u32 = 0;
        loop {
            match self.connected() {
                Ok(_) => break,
                Err(e) => {
                    if attempt >= self.max_attempts {
                        return Err(e.context("submit could not reach the daemon"));
                    }
                    log::warn!("dial for submit failed ({e:#}); redialing {}", self.addr);
                    self.backoff.sleep(attempt);
                    attempt += 1;
                }
            }
        }
        let out = self.client.as_mut().expect("just connected").submit(spec);
        if out.is_err() {
            self.client = None;
        }
        out
    }

    pub fn status(&mut self) -> Result<Vec<CampaignStatusInfo>> {
        self.with_retry("status poll", |c| c.status())
    }

    pub fn stats(
        &mut self,
        campaign: u64,
        from: u64,
    ) -> Result<(crate::obs::StatsSnapshot, Vec<crate::obs::RingEvent>, u64)> {
        self.with_retry("stats poll", |c| c.stats(campaign, from))
    }

    /// Stream a campaign's events from index `from` until the terminal
    /// event, surviving connection loss: when the stream breaks
    /// mid-flight the watch reattaches at the next unseen index, and
    /// delivered progress resets the reconnect budget.
    pub fn watch(
        &mut self,
        campaign: u64,
        from: u64,
        on_event: &mut dyn FnMut(&Event),
    ) -> Result<Event> {
        let mut next = from;
        let mut attempt: u32 = 0;
        loop {
            let before = next;
            let run = self.connected().and_then(|client| {
                client.watch(campaign, next, &mut |ev| {
                    next += 1;
                    on_event(ev);
                })
            });
            match run {
                Ok(terminal) => return Ok(terminal),
                Err(e) => {
                    self.client = None;
                    if next > before {
                        attempt = 0; // progress resets the reconnect budget
                    }
                    if is_refusal(&e) || attempt >= self.max_attempts {
                        return Err(e.context(format!(
                            "watch of campaign {campaign} gave up at event index {next}"
                        )));
                    }
                    log::warn!(
                        "watch stream broke at event index {next} ({e:#}); \
                         reattaching from there"
                    );
                    self.backoff.sleep(attempt);
                    attempt += 1;
                }
            }
        }
    }
}
