//! Tuning-as-a-service: a multi-campaign daemon over the shared
//! federation substrate (ISSUE 6; the ytopt+libEnsemble persistent-
//! manager direction from PAPERS.md, arXiv:2402.09222).
//!
//! The paper runs one batch job per tuning campaign. This subsystem
//! turns the engine into a long-lived service:
//!
//! * [`protocol`] — the framed wire protocol (pure codec, versioned
//!   `YT` frames, request/response/event families).
//! * [`engine`] — the shared campaign engine: [`engine::drive_continuous`]
//!   steps one continuous-manager campaign at a time with cancel +
//!   event hooks, and [`engine::CampaignHandle`] is the
//!   start/poll/cancel/join facade both front-ends use. The classic
//!   `coordinator::autotune` dispatch lands on the *same* function —
//!   daemon and CLI cannot diverge.
//! * [`scheduler`] — FIFO admission onto a bounded set of concurrent
//!   campaigns, per-campaign event logs, and the shared history store
//!   that warm-starts each compatible campaign from its predecessors'
//!   elites.
//! * [`daemon`] — the TCP listener (`ytopt-rs serve`), with graceful
//!   SIGTERM/shutdown semantics: checkpoint, terminal `Interrupted`
//!   events, no dropped sockets.
//! * [`client`] — the loopback client the CLI subcommands, the example,
//!   and the e2e tests use.

pub mod client;
pub mod daemon;
pub mod engine;
pub mod protocol;
pub mod scheduler;

pub use client::{Client, ResilientClient};
pub use daemon::{Daemon, ServeConfig};
pub use engine::{CampaignEvent, CampaignHandle, CampaignOutcome};
pub use protocol::{CampaignSpec, Decoder, Event, Message, Request, Response};
pub use scheduler::{Scheduler, ServiceConfig};
