//! The tuning service's wire protocol: versioned, length-prefixed frames
//! carrying JSON payloads, with a **pure codec** — [`encode_frame`] /
//! [`decode_frame`] work on byte slices, no I/O in sight, so every
//! protocol invariant is property-testable (`tests/service_protocol.rs`).
//!
//! Frame layout (network byte order):
//!
//! ```text
//! offset 0..2   magic  b"YT"
//!        2      protocol version (PROTOCOL_VERSION)
//!        3      frame kind: 1 = request, 2 = response, 3 = event
//!        4..8   payload length, u32 big-endian (<= MAX_FRAME_BYTES)
//!        8..    payload: one UTF-8 JSON object with a "type" tag
//! ```
//!
//! The codec is incremental: [`decode_frame`] returns `Ok(None)` while a
//! frame is still incomplete (partial reads reassemble for free through
//! [`Decoder`]), and rejects bad magic, foreign versions, and oversized
//! lengths *before* buffering a payload — a junk-spewing peer can never
//! make the daemon allocate unbounded memory or panic.
//!
//! Numbers follow the repo's JSON conventions: non-finite `f64` writes
//! as `null` and reads back as `+inf`; full-width `u64` seeds travel as
//! hex strings (JSON numbers are f64 and would truncate them).

use crate::coordinator::TuneSetup;
use crate::util::Json;
use std::fmt;

/// Protocol revision spoken by this build. A daemon refuses frames from
/// any other revision (the version byte sits before the length, so the
/// refusal happens before any payload is trusted).
pub const PROTOCOL_VERSION: u8 = 1;

/// Frame header length: magic(2) + version(1) + kind(1) + len(4).
pub const FRAME_HEADER_BYTES: usize = 8;

/// Upper bound on one frame's payload. Status listings and event frames
/// are small; this exists so a corrupt or hostile length field cannot
/// drive an allocation.
pub const MAX_FRAME_BYTES: usize = 1 << 20;

const MAGIC: [u8; 2] = *b"YT";
const KIND_REQUEST: u8 = 1;
const KIND_RESPONSE: u8 = 2;
const KIND_EVENT: u8 = 3;

/// Codec failure. Every variant is a protocol-level rejection — the
/// decoder never panics on hostile input (pinned by property test).
#[derive(Debug, Clone, PartialEq)]
pub enum ProtocolError {
    /// First bytes are not the `b"YT"` magic.
    BadMagic([u8; 2]),
    /// Version byte differs from [`PROTOCOL_VERSION`].
    BadVersion(u8),
    /// Unknown frame-kind byte.
    BadKind(u8),
    /// Declared payload length exceeds [`MAX_FRAME_BYTES`].
    Oversized(usize),
    /// Payload failed to parse as the declared message shape.
    Malformed(String),
}

impl fmt::Display for ProtocolError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ProtocolError::BadMagic(m) => write!(f, "bad frame magic {m:02x?} (expected \"YT\")"),
            ProtocolError::BadVersion(v) => {
                write!(f, "protocol version {v} (this build speaks {PROTOCOL_VERSION})")
            }
            ProtocolError::BadKind(k) => write!(f, "unknown frame kind {k}"),
            ProtocolError::Oversized(n) => {
                write!(f, "frame payload of {n} bytes exceeds the {MAX_FRAME_BYTES}-byte cap")
            }
            ProtocolError::Malformed(m) => write!(f, "malformed frame payload: {m}"),
        }
    }
}

impl std::error::Error for ProtocolError {}

// ---------------------------------------------------------------------------
// campaign request / status / summary payloads

/// A client's campaign request: the search policy subset of
/// [`TuneSetup`] that the daemon accepts over the wire. Everything the
/// daemon itself owns (history store, checkpoint placement) is absent by
/// design — clients describe *what* to tune, the service decides *where*
/// state lives.
#[derive(Debug, Clone, PartialEq)]
pub struct CampaignSpec {
    pub app: String,
    pub platform: String,
    pub nodes: u64,
    pub metric: String,
    // detlint: allow(fingerprint-coverage) -- capacity knob: resuming with a larger budget continues the same campaign
    pub max_evals: usize,
    // detlint: allow(fingerprint-coverage) -- capacity knob: resuming with a larger budget continues the same campaign
    pub wallclock_budget_s: f64,
    pub seed: u64,
    pub strategy: String,
    pub surrogate: String,
    pub kappa: f64,
    pub n_init: usize,
    /// Ensemble worker threads for this campaign (the service runs every
    /// campaign on the continuous manager engine, so 2..=64).
    pub workers: usize,
    /// In-flight proposals (0 = worker count).
    pub batch: usize,
    pub liar: String,
    pub fault_rate: f64,
    pub max_retries: usize,
    pub straggler_factor: Option<f64>,
    pub eval_timeout_s: Option<f64>,
    /// Opt out of the daemon's automatic shared-history warm start.
    pub warm_start: bool,
    /// Chaos failpoint spec (`FaultPlan::parse` grammar), `None` in
    /// production. Excluded from run identity exactly like the obs sink:
    /// injected faults are retried away or end the campaign `Degraded` —
    /// they never change what a completed record means.
    // detlint: allow(fingerprint-coverage) -- fault schedule, not run identity; recovery is pinned trajectory-neutral by chaos_soak
    pub chaos: Option<String>,
}

impl Default for CampaignSpec {
    fn default() -> CampaignSpec {
        CampaignSpec {
            app: "xsbench".into(),
            platform: "theta".into(),
            nodes: 1,
            metric: "runtime".into(),
            max_evals: 16,
            wallclock_budget_s: 1800.0,
            seed: 42,
            strategy: "bo".into(),
            surrogate: "rf".into(),
            kappa: crate::acquisition::DEFAULT_KAPPA,
            n_init: 8,
            workers: 4,
            batch: 0,
            liar: "cl-min".into(),
            fault_rate: 0.0,
            max_retries: 2,
            straggler_factor: None,
            eval_timeout_s: None,
            warm_start: true,
            chaos: None,
        }
    }
}

fn num_or_null(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

fn opt_num(v: Option<f64>) -> Json {
    v.map(Json::Num).unwrap_or(Json::Null)
}

fn get_f(v: &Json, key: &str, default: f64) -> f64 {
    v.get(key).and_then(Json::as_f64).unwrap_or(default)
}

fn get_u(v: &Json, key: &str, default: u64) -> u64 {
    v.get(key).and_then(Json::as_u64).unwrap_or(default)
}

fn get_s(v: &Json, key: &str, default: &str) -> String {
    v.get(key).and_then(Json::as_str).unwrap_or(default).to_string()
}

fn get_b(v: &Json, key: &str, default: bool) -> bool {
    v.get(key).and_then(Json::as_bool).unwrap_or(default)
}

/// `f64` objective off the wire: JSON `null` (non-finite on encode)
/// reads back as `+inf`, the same convention checkpoints use.
fn get_obj(v: &Json, key: &str) -> f64 {
    v.get(key).and_then(Json::as_f64).unwrap_or(f64::INFINITY)
}

fn seed_to_json(seed: u64) -> Json {
    Json::Str(format!("{seed:016x}"))
}

fn seed_from_json(v: &Json, key: &str, default: u64) -> u64 {
    v.get(key)
        .and_then(Json::as_str)
        .and_then(|s| u64::from_str_radix(s, 16).ok())
        .unwrap_or(default)
}

impl CampaignSpec {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("app", self.app.as_str().into()),
            ("platform", self.platform.as_str().into()),
            ("nodes", self.nodes.into()),
            ("metric", self.metric.as_str().into()),
            ("max_evals", (self.max_evals as u64).into()),
            ("wallclock_budget_s", num_or_null(self.wallclock_budget_s)),
            ("seed", seed_to_json(self.seed)),
            ("strategy", self.strategy.as_str().into()),
            ("surrogate", self.surrogate.as_str().into()),
            ("kappa", num_or_null(self.kappa)),
            ("n_init", (self.n_init as u64).into()),
            ("workers", (self.workers as u64).into()),
            ("batch", (self.batch as u64).into()),
            ("liar", self.liar.as_str().into()),
            ("fault_rate", num_or_null(self.fault_rate)),
            ("max_retries", (self.max_retries as u64).into()),
            ("straggler_factor", opt_num(self.straggler_factor)),
            ("eval_timeout_s", opt_num(self.eval_timeout_s)),
            ("warm_start", self.warm_start.into()),
            (
                "chaos",
                self.chaos.as_deref().map(|s| Json::Str(s.to_string())).unwrap_or(Json::Null),
            ),
        ])
    }

    /// Lenient field-wise parse: absent fields take the defaults, so a
    /// newer client talking to this daemon degrades gracefully instead
    /// of being refused outright (the version byte still gates frame
    /// *layout* changes).
    pub fn from_json(v: &Json) -> CampaignSpec {
        let d = CampaignSpec::default();
        CampaignSpec {
            app: get_s(v, "app", &d.app),
            platform: get_s(v, "platform", &d.platform),
            nodes: get_u(v, "nodes", d.nodes),
            metric: get_s(v, "metric", &d.metric),
            max_evals: get_u(v, "max_evals", d.max_evals as u64) as usize,
            wallclock_budget_s: get_f(v, "wallclock_budget_s", d.wallclock_budget_s),
            seed: seed_from_json(v, "seed", d.seed),
            strategy: get_s(v, "strategy", &d.strategy),
            surrogate: get_s(v, "surrogate", &d.surrogate),
            kappa: get_f(v, "kappa", d.kappa),
            n_init: get_u(v, "n_init", d.n_init as u64) as usize,
            workers: get_u(v, "workers", d.workers as u64) as usize,
            batch: get_u(v, "batch", d.batch as u64) as usize,
            liar: get_s(v, "liar", &d.liar),
            fault_rate: get_f(v, "fault_rate", d.fault_rate),
            max_retries: get_u(v, "max_retries", d.max_retries as u64) as usize,
            straggler_factor: v.get("straggler_factor").and_then(Json::as_f64),
            eval_timeout_s: v.get("eval_timeout_s").and_then(Json::as_f64),
            warm_start: get_b(v, "warm_start", d.warm_start),
            chaos: v.get("chaos").and_then(Json::as_str).map(str::to_string),
        }
    }

    /// Validate and lower into a [`TuneSetup`] the service engine can
    /// run. The service runs every campaign on the continuous manager
    /// engine — the same engine `ytopt-rs tune` uses at `workers >= 2` —
    /// which is what makes a daemon campaign's trajectory bit-identical
    /// to the solo CLI run with the same spec.
    pub fn to_setup(&self) -> anyhow::Result<TuneSetup> {
        use crate::apps::AppKind;
        use crate::ensemble::LiarStrategy;
        use crate::metrics::Metric;
        use crate::platform::PlatformKind;
        use crate::search::{StrategyKind, SurrogateKind};

        let app = AppKind::parse(&self.app)
            .ok_or_else(|| anyhow::anyhow!("unknown app `{}`", self.app))?;
        let platform = match self.platform.to_ascii_lowercase().as_str() {
            "theta" => PlatformKind::Theta,
            "summit" => PlatformKind::Summit,
            other => anyhow::bail!("unknown platform `{other}`"),
        };
        let metric = Metric::parse(&self.metric)
            .ok_or_else(|| anyhow::anyhow!("unknown metric `{}`", self.metric))?;
        anyhow::ensure!(self.nodes >= 1, "nodes must be >= 1 (got {})", self.nodes);
        anyhow::ensure!(
            (1..=100_000).contains(&self.max_evals),
            "max_evals must be in 1..=100000 (got {})",
            self.max_evals
        );
        anyhow::ensure!(
            (2..=64).contains(&self.workers),
            "service campaigns need 2..=64 ensemble workers (got {}); the continuous \
             manager engine is the only campaign engine the daemon runs",
            self.workers
        );
        anyhow::ensure!(
            self.wallclock_budget_s > 0.0,
            "wallclock budget must be positive (got {})",
            self.wallclock_budget_s
        );
        anyhow::ensure!(self.kappa.is_finite(), "kappa must be finite");
        let mut setup = TuneSetup::new(app, platform, self.nodes, metric);
        setup.max_evals = self.max_evals;
        setup.wallclock_budget_s = self.wallclock_budget_s;
        setup.seed = self.seed;
        setup.strategy = StrategyKind::parse(&self.strategy)
            .ok_or_else(|| anyhow::anyhow!("unknown strategy `{}`", self.strategy))?;
        setup.surrogate = SurrogateKind::parse(&self.surrogate)
            .ok_or_else(|| anyhow::anyhow!("unknown surrogate `{}`", self.surrogate))?;
        setup.kappa = self.kappa;
        setup.n_init = self.n_init;
        setup.ensemble_workers = self.workers;
        setup.ensemble_batch = self.batch;
        setup.liar = LiarStrategy::parse(&self.liar)
            .ok_or_else(|| anyhow::anyhow!("unknown liar strategy `{}`", self.liar))?;
        setup.fault_rate = self.fault_rate.clamp(0.0, 1.0);
        setup.max_retries = self.max_retries;
        setup.straggler_factor = self.straggler_factor;
        setup.eval_timeout_s = self.eval_timeout_s;
        if let Some(spec) = &self.chaos {
            let plan = crate::chaos::FaultPlan::parse(spec)
                .map_err(|e| anyhow::anyhow!("invalid chaos spec `{spec}`: {e:#}"))?;
            setup.chaos = Some(std::sync::Arc::new(plan));
        }
        Ok(setup)
    }

    /// Capture a `TuneSetup`'s wire-transferable policy (the CLI
    /// `submit` front-end builds its setup with the `tune` flags, then
    /// ships this). Fails on setups the service does not run.
    pub fn from_setup(setup: &TuneSetup) -> anyhow::Result<CampaignSpec> {
        use crate::search::{StrategyKind, SurrogateKind};
        anyhow::ensure!(
            setup.federation_shards == 0,
            "federated campaigns are not submittable over the service protocol"
        );
        anyhow::ensure!(
            setup.manager_cycle == crate::ensemble::ManagerCycle::Continuous,
            "service campaigns run the continuous manager cycle"
        );
        let strategy = match setup.strategy {
            StrategyKind::Bo => "bo",
            StrategyKind::Random => "random",
            StrategyKind::Grid => "grid",
            StrategyKind::Mctree => "mctree",
        };
        let surrogate = match setup.surrogate {
            SurrogateKind::RandomForest => "rf",
            SurrogateKind::ExtraTrees => "et",
            SurrogateKind::Gbrt => "gbrt",
        };
        // canonical lowercase tokens: every enum's `parse` accepts the
        // lowercased `name`, but `name` itself is display-cased
        Ok(CampaignSpec {
            app: setup.app.name().to_ascii_lowercase(),
            platform: setup.platform.name().to_ascii_lowercase(),
            nodes: setup.nodes,
            metric: setup.metric.name().to_ascii_lowercase(),
            max_evals: setup.max_evals,
            wallclock_budget_s: setup.wallclock_budget_s,
            seed: setup.seed,
            strategy: strategy.into(),
            surrogate: surrogate.into(),
            kappa: setup.kappa,
            n_init: setup.n_init,
            workers: setup.ensemble_workers.max(2),
            batch: setup.ensemble_batch,
            liar: setup.liar.name().to_string(),
            fault_rate: setup.fault_rate,
            max_retries: setup.max_retries,
            straggler_factor: setup.straggler_factor,
            eval_timeout_s: setup.eval_timeout_s,
            warm_start: true,
            chaos: setup.chaos.as_ref().map(|p| p.spec()),
        })
    }
}

/// One campaign's terminal report, carried by [`Event::Done`].
#[derive(Debug, Clone, PartialEq)]
pub struct CampaignSummary {
    pub evaluations: u64,
    pub baseline_objective: f64,
    pub best_objective: f64,
    pub best_config_desc: String,
    pub improvement_pct: f64,
    pub wallclock_s: f64,
}

impl CampaignSummary {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("evaluations", self.evaluations.into()),
            ("baseline_objective", num_or_null(self.baseline_objective)),
            ("best_objective", num_or_null(self.best_objective)),
            ("best_config_desc", self.best_config_desc.as_str().into()),
            ("improvement_pct", num_or_null(self.improvement_pct)),
            ("wallclock_s", num_or_null(self.wallclock_s)),
        ])
    }

    fn from_json(v: &Json) -> CampaignSummary {
        CampaignSummary {
            evaluations: get_u(v, "evaluations", 0),
            baseline_objective: get_obj(v, "baseline_objective"),
            best_objective: get_obj(v, "best_objective"),
            best_config_desc: get_s(v, "best_config_desc", ""),
            improvement_pct: get_f(v, "improvement_pct", 0.0),
            wallclock_s: get_f(v, "wallclock_s", 0.0),
        }
    }
}

/// One row of a [`Response::Status`] listing.
#[derive(Debug, Clone, PartialEq)]
pub struct CampaignStatusInfo {
    pub id: u64,
    /// `queued | running | done | cancelled | interrupted | degraded | failed`.
    pub state: String,
    pub app: String,
    pub seed: u64,
    pub evaluations: u64,
    pub best_objective: f64,
}

impl CampaignStatusInfo {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", self.id.into()),
            ("state", self.state.as_str().into()),
            ("app", self.app.as_str().into()),
            ("seed", seed_to_json(self.seed)),
            ("evaluations", self.evaluations.into()),
            ("best_objective", num_or_null(self.best_objective)),
        ])
    }

    fn from_json(v: &Json) -> CampaignStatusInfo {
        CampaignStatusInfo {
            id: get_u(v, "id", 0),
            state: get_s(v, "state", "unknown"),
            app: get_s(v, "app", ""),
            seed: seed_from_json(v, "seed", 0),
            evaluations: get_u(v, "evaluations", 0),
            best_objective: get_obj(v, "best_objective"),
        }
    }
}

// ---------------------------------------------------------------------------
// the three frame families

/// Client → daemon.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Ping,
    /// Submit a campaign; answered with [`Response::Accepted`].
    Submit { spec: CampaignSpec },
    /// Stream `campaign`'s events starting at index `from`; the daemon
    /// writes [`Event`] frames until a terminal event has been sent.
    Watch { campaign: u64, from: u64 },
    Status,
    Cancel { campaign: u64 },
    /// Query `campaign`'s live observability state: a counter snapshot
    /// plus the event-ring tail from logical clock `from`. Answered
    /// with [`Response::StatsReply`]. Read-only — stats queries never
    /// perturb a running trajectory (the sink is write-only for the
    /// engine), so `ytopt-rs stats` and `ytopt-rs top` can poll any
    /// live campaign freely.
    Stats { campaign: u64, from: u64 },
    /// Graceful daemon shutdown: running campaigns checkpoint and every
    /// watcher receives a terminal [`Event::Interrupted`].
    Shutdown,
}

/// Daemon → client, one per request (watch additionally streams events).
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Pong,
    Accepted { campaign: u64 },
    Status { campaigns: Vec<CampaignStatusInfo> },
    Cancelling { campaign: u64 },
    /// One campaign's observability state: the counter snapshot, the
    /// event-ring tail from the requested cursor, and the cursor to
    /// pass on the next poll (`next`).
    StatsReply {
        campaign: u64,
        snapshot: crate::obs::StatsSnapshot,
        events: Vec<crate::obs::RingEvent>,
        next: u64,
    },
    ShuttingDown,
    Error { message: String },
}

/// Daemon → client, streamed to watchers. `Done`, `Cancelled`,
/// `Interrupted`, `Degraded`, and `Failed` are terminal: nothing
/// follows them.
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    Started { campaign: u64, evals_planned: u64 },
    /// The campaign absorbed `elites` prior observations from the
    /// daemon's shared history store before its first proposal.
    WarmStarted { campaign: u64, elites: u64 },
    Proposed { campaign: u64, eval_id: u64 },
    EvalCompleted {
        campaign: u64,
        eval_id: u64,
        config_key: String,
        objective: f64,
        runtime_s: f64,
        best_so_far: f64,
        timed_out: bool,
        cancelled: bool,
    },
    Improved { campaign: u64, eval_id: u64, best_objective: f64, config_desc: String },
    StragglerKilled { campaign: u64, eval_id: u64 },
    Done { campaign: u64, summary: CampaignSummary },
    Cancelled { campaign: u64, applied: u64 },
    /// Daemon shutdown overtook the campaign: the applied prefix is
    /// checkpointed (when the daemon runs with a checkpoint dir) and the
    /// campaign can resume in a later daemon life.
    Interrupted { campaign: u64, applied: u64, checkpointed: bool },
    /// Terminal: an I/O retry budget was exhausted mid-campaign
    /// (`chaos::RetryExhausted` in the engine's error chain). The
    /// applied prefix stands; the daemon and its other campaigns are
    /// unaffected.
    Degraded { campaign: u64, applied: u64, message: String },
    Failed { campaign: u64, message: String },
}

impl Event {
    /// Terminal events end a watch stream.
    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            Event::Done { .. }
                | Event::Cancelled { .. }
                | Event::Interrupted { .. }
                | Event::Degraded { .. }
                | Event::Failed { .. }
        )
    }

    /// The campaign this event belongs to.
    pub fn campaign(&self) -> u64 {
        match self {
            Event::Started { campaign, .. }
            | Event::WarmStarted { campaign, .. }
            | Event::Proposed { campaign, .. }
            | Event::EvalCompleted { campaign, .. }
            | Event::Improved { campaign, .. }
            | Event::StragglerKilled { campaign, .. }
            | Event::Done { campaign, .. }
            | Event::Cancelled { campaign, .. }
            | Event::Interrupted { campaign, .. }
            | Event::Degraded { campaign, .. }
            | Event::Failed { campaign, .. } => *campaign,
        }
    }
}

/// Any frame payload.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    Request(Request),
    Response(Response),
    Event(Event),
}

// ---------------------------------------------------------------------------
// payload (de)serialization

fn tagged(t: &str, mut fields: Vec<(&str, Json)>) -> Json {
    let mut all = vec![("type", Json::Str(t.to_string()))];
    all.append(&mut fields);
    Json::obj(all)
}

impl Request {
    fn to_json(&self) -> Json {
        match self {
            Request::Ping => tagged("ping", vec![]),
            Request::Submit { spec } => tagged("submit", vec![("spec", spec.to_json())]),
            Request::Watch { campaign, from } => tagged(
                "watch",
                vec![("campaign", (*campaign).into()), ("from", (*from).into())],
            ),
            Request::Status => tagged("status", vec![]),
            Request::Cancel { campaign } => {
                tagged("cancel", vec![("campaign", (*campaign).into())])
            }
            Request::Stats { campaign, from } => tagged(
                "stats",
                vec![("campaign", (*campaign).into()), ("from", (*from).into())],
            ),
            Request::Shutdown => tagged("shutdown", vec![]),
        }
    }

    fn from_json(v: &Json) -> Result<Request, ProtocolError> {
        let t = v.get("type").and_then(Json::as_str).unwrap_or("");
        match t {
            "ping" => Ok(Request::Ping),
            "submit" => {
                let spec = v
                    .get("spec")
                    .ok_or_else(|| ProtocolError::Malformed("submit missing `spec`".into()))?;
                Ok(Request::Submit { spec: CampaignSpec::from_json(spec) })
            }
            "watch" => Ok(Request::Watch {
                campaign: get_u(v, "campaign", 0),
                from: get_u(v, "from", 0),
            }),
            "status" => Ok(Request::Status),
            "cancel" => Ok(Request::Cancel { campaign: get_u(v, "campaign", 0) }),
            "stats" => Ok(Request::Stats {
                campaign: get_u(v, "campaign", 0),
                from: get_u(v, "from", 0),
            }),
            "shutdown" => Ok(Request::Shutdown),
            other => Err(ProtocolError::Malformed(format!("unknown request type `{other}`"))),
        }
    }
}

impl Response {
    fn to_json(&self) -> Json {
        match self {
            Response::Pong => tagged("pong", vec![]),
            Response::Accepted { campaign } => {
                tagged("accepted", vec![("campaign", (*campaign).into())])
            }
            Response::Status { campaigns } => tagged(
                "status",
                vec![(
                    "campaigns",
                    Json::Arr(campaigns.iter().map(CampaignStatusInfo::to_json).collect()),
                )],
            ),
            Response::Cancelling { campaign } => {
                tagged("cancelling", vec![("campaign", (*campaign).into())])
            }
            Response::StatsReply { campaign, snapshot, events, next } => tagged(
                "stats_reply",
                vec![
                    ("campaign", (*campaign).into()),
                    ("snapshot", snapshot.to_json()),
                    ("events", Json::Arr(events.iter().map(crate::obs::RingEvent::to_json).collect())),
                    ("next", (*next).into()),
                ],
            ),
            Response::ShuttingDown => tagged("shutting_down", vec![]),
            Response::Error { message } => {
                tagged("error", vec![("message", message.as_str().into())])
            }
        }
    }

    fn from_json(v: &Json) -> Result<Response, ProtocolError> {
        let t = v.get("type").and_then(Json::as_str).unwrap_or("");
        match t {
            "pong" => Ok(Response::Pong),
            "accepted" => Ok(Response::Accepted { campaign: get_u(v, "campaign", 0) }),
            "status" => {
                let campaigns = v
                    .get("campaigns")
                    .and_then(Json::as_arr)
                    .map(|a| a.iter().map(CampaignStatusInfo::from_json).collect())
                    .unwrap_or_default();
                Ok(Response::Status { campaigns })
            }
            "cancelling" => Ok(Response::Cancelling { campaign: get_u(v, "campaign", 0) }),
            "stats_reply" => {
                let snapshot = v
                    .get("snapshot")
                    .map(crate::obs::StatsSnapshot::from_json)
                    .unwrap_or_default();
                let events = v
                    .get("events")
                    .and_then(Json::as_arr)
                    .map(|a| a.iter().filter_map(crate::obs::RingEvent::from_json).collect())
                    .unwrap_or_default();
                Ok(Response::StatsReply {
                    campaign: get_u(v, "campaign", 0),
                    snapshot,
                    events,
                    next: get_u(v, "next", 0),
                })
            }
            "shutting_down" => Ok(Response::ShuttingDown),
            "error" => Ok(Response::Error { message: get_s(v, "message", "") }),
            other => Err(ProtocolError::Malformed(format!("unknown response type `{other}`"))),
        }
    }
}

impl Event {
    fn to_json(&self) -> Json {
        let c = |campaign: u64| ("campaign", campaign.into());
        match self {
            Event::Started { campaign, evals_planned } => tagged(
                "started",
                vec![c(*campaign), ("evals_planned", (*evals_planned).into())],
            ),
            Event::WarmStarted { campaign, elites } => {
                tagged("warm_started", vec![c(*campaign), ("elites", (*elites).into())])
            }
            Event::Proposed { campaign, eval_id } => {
                tagged("proposed", vec![c(*campaign), ("eval_id", (*eval_id).into())])
            }
            Event::EvalCompleted {
                campaign,
                eval_id,
                config_key,
                objective,
                runtime_s,
                best_so_far,
                timed_out,
                cancelled,
            } => tagged(
                "eval_completed",
                vec![
                    c(*campaign),
                    ("eval_id", (*eval_id).into()),
                    ("config_key", config_key.as_str().into()),
                    ("objective", num_or_null(*objective)),
                    ("runtime_s", num_or_null(*runtime_s)),
                    ("best_so_far", num_or_null(*best_so_far)),
                    ("timed_out", (*timed_out).into()),
                    ("cancelled", (*cancelled).into()),
                ],
            ),
            Event::Improved { campaign, eval_id, best_objective, config_desc } => tagged(
                "improved",
                vec![
                    c(*campaign),
                    ("eval_id", (*eval_id).into()),
                    ("best_objective", num_or_null(*best_objective)),
                    ("config_desc", config_desc.as_str().into()),
                ],
            ),
            Event::StragglerKilled { campaign, eval_id } => {
                tagged("straggler_killed", vec![c(*campaign), ("eval_id", (*eval_id).into())])
            }
            Event::Done { campaign, summary } => {
                tagged("done", vec![c(*campaign), ("summary", summary.to_json())])
            }
            Event::Cancelled { campaign, applied } => {
                tagged("cancelled", vec![c(*campaign), ("applied", (*applied).into())])
            }
            Event::Interrupted { campaign, applied, checkpointed } => tagged(
                "interrupted",
                vec![
                    c(*campaign),
                    ("applied", (*applied).into()),
                    ("checkpointed", (*checkpointed).into()),
                ],
            ),
            Event::Degraded { campaign, applied, message } => tagged(
                "degraded",
                vec![
                    c(*campaign),
                    ("applied", (*applied).into()),
                    ("message", message.as_str().into()),
                ],
            ),
            Event::Failed { campaign, message } => {
                tagged("failed", vec![c(*campaign), ("message", message.as_str().into())])
            }
        }
    }

    fn from_json(v: &Json) -> Result<Event, ProtocolError> {
        let t = v.get("type").and_then(Json::as_str).unwrap_or("");
        let campaign = get_u(v, "campaign", 0);
        match t {
            "started" => {
                Ok(Event::Started { campaign, evals_planned: get_u(v, "evals_planned", 0) })
            }
            "warm_started" => Ok(Event::WarmStarted { campaign, elites: get_u(v, "elites", 0) }),
            "proposed" => Ok(Event::Proposed { campaign, eval_id: get_u(v, "eval_id", 0) }),
            "eval_completed" => Ok(Event::EvalCompleted {
                campaign,
                eval_id: get_u(v, "eval_id", 0),
                config_key: get_s(v, "config_key", ""),
                objective: get_obj(v, "objective"),
                runtime_s: get_obj(v, "runtime_s"),
                best_so_far: get_obj(v, "best_so_far"),
                timed_out: get_b(v, "timed_out", false),
                cancelled: get_b(v, "cancelled", false),
            }),
            "improved" => Ok(Event::Improved {
                campaign,
                eval_id: get_u(v, "eval_id", 0),
                best_objective: get_obj(v, "best_objective"),
                config_desc: get_s(v, "config_desc", ""),
            }),
            "straggler_killed" => {
                Ok(Event::StragglerKilled { campaign, eval_id: get_u(v, "eval_id", 0) })
            }
            "done" => {
                let summary = v
                    .get("summary")
                    .map(CampaignSummary::from_json)
                    .ok_or_else(|| ProtocolError::Malformed("done missing `summary`".into()))?;
                Ok(Event::Done { campaign, summary })
            }
            "cancelled" => Ok(Event::Cancelled { campaign, applied: get_u(v, "applied", 0) }),
            "interrupted" => Ok(Event::Interrupted {
                campaign,
                applied: get_u(v, "applied", 0),
                checkpointed: get_b(v, "checkpointed", false),
            }),
            "degraded" => Ok(Event::Degraded {
                campaign,
                applied: get_u(v, "applied", 0),
                message: get_s(v, "message", ""),
            }),
            "failed" => Ok(Event::Failed { campaign, message: get_s(v, "message", "") }),
            other => Err(ProtocolError::Malformed(format!("unknown event type `{other}`"))),
        }
    }
}

// ---------------------------------------------------------------------------
// the pure codec

/// Serialize one message into a complete frame.
pub fn encode_frame(msg: &Message) -> Vec<u8> {
    let (kind, payload) = match msg {
        Message::Request(r) => (KIND_REQUEST, r.to_json()),
        Message::Response(r) => (KIND_RESPONSE, r.to_json()),
        Message::Event(e) => (KIND_EVENT, e.to_json()),
    };
    let body = payload.to_string().into_bytes();
    debug_assert!(body.len() <= MAX_FRAME_BYTES, "outgoing frame exceeds the payload cap");
    let mut out = Vec::with_capacity(FRAME_HEADER_BYTES + body.len());
    out.extend_from_slice(&MAGIC);
    out.push(PROTOCOL_VERSION);
    out.push(kind);
    out.extend_from_slice(&(body.len() as u32).to_be_bytes());
    out.extend_from_slice(&body);
    out
}

/// Decode the first complete frame at the head of `buf`.
///
/// * `Ok(Some((message, consumed)))` — one frame decoded; the caller
///   should drop `consumed` bytes and call again.
/// * `Ok(None)` — the head is a *valid prefix* of a frame; read more.
/// * `Err(_)` — the head can never become a valid frame (bad magic,
///   foreign version, oversized length, malformed payload). The
///   connection should be dropped. Never panics, for any input.
pub fn decode_frame(buf: &[u8]) -> Result<Option<(Message, usize)>, ProtocolError> {
    // validate header bytes as they arrive, so junk is rejected at the
    // earliest byte that can prove it junk
    if !buf.is_empty() && buf[0] != MAGIC[0] {
        return Err(ProtocolError::BadMagic([buf[0], *buf.get(1).unwrap_or(&0)]));
    }
    if buf.len() >= 2 && buf[1] != MAGIC[1] {
        return Err(ProtocolError::BadMagic([buf[0], buf[1]]));
    }
    if buf.len() >= 3 && buf[2] != PROTOCOL_VERSION {
        return Err(ProtocolError::BadVersion(buf[2]));
    }
    if buf.len() >= 4 && !matches!(buf[3], KIND_REQUEST | KIND_RESPONSE | KIND_EVENT) {
        return Err(ProtocolError::BadKind(buf[3]));
    }
    if buf.len() < FRAME_HEADER_BYTES {
        return Ok(None);
    }
    let len = u32::from_be_bytes([buf[4], buf[5], buf[6], buf[7]]) as usize;
    if len > MAX_FRAME_BYTES {
        return Err(ProtocolError::Oversized(len));
    }
    if buf.len() < FRAME_HEADER_BYTES + len {
        return Ok(None);
    }
    let body = &buf[FRAME_HEADER_BYTES..FRAME_HEADER_BYTES + len];
    let text = std::str::from_utf8(body)
        .map_err(|e| ProtocolError::Malformed(format!("payload is not UTF-8: {e}")))?;
    let v = Json::parse(text).map_err(|e| ProtocolError::Malformed(e.to_string()))?;
    let msg = match buf[3] {
        KIND_REQUEST => Message::Request(Request::from_json(&v)?),
        KIND_RESPONSE => Message::Response(Response::from_json(&v)?),
        _ => Message::Event(Event::from_json(&v)?),
    };
    Ok(Some((msg, FRAME_HEADER_BYTES + len)))
}

/// Incremental frame reassembler over [`decode_frame`]: push whatever
/// byte chunks the transport hands you, collect whole messages.
#[derive(Debug, Default)]
pub struct Decoder {
    buf: Vec<u8>,
}

impl Decoder {
    pub fn new() -> Decoder {
        Decoder { buf: Vec::new() }
    }

    /// Bytes currently buffered (a partial frame, possibly empty).
    pub fn pending(&self) -> usize {
        self.buf.len()
    }

    /// Append `bytes` and drain every complete frame at the head. On
    /// error the stream is poisoned — the caller should drop the
    /// connection (framing cannot resynchronize after junk).
    pub fn push(&mut self, bytes: &[u8]) -> Result<Vec<Message>, ProtocolError> {
        self.buf.extend_from_slice(bytes);
        let mut out = Vec::new();
        let mut consumed = 0usize;
        loop {
            match decode_frame(&self.buf[consumed..]) {
                Ok(Some((msg, used))) => {
                    out.push(msg);
                    consumed += used;
                }
                Ok(None) => break,
                Err(e) => {
                    self.buf.clear();
                    return Err(e);
                }
            }
        }
        if consumed > 0 {
            self.buf.drain(..consumed);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_messages() -> Vec<Message> {
        vec![
            Message::Request(Request::Ping),
            Message::Request(Request::Submit { spec: CampaignSpec::default() }),
            Message::Request(Request::Watch { campaign: 3, from: 17 }),
            Message::Request(Request::Stats { campaign: 3, from: 42 }),
            Message::Response(Response::Accepted { campaign: 9 }),
            Message::Response(Response::StatsReply {
                campaign: 3,
                snapshot: {
                    let sink = crate::obs::ObsSink::new(8);
                    sink.record(crate::obs::ObsEvent::Proposed {
                        eval_id: 1,
                        shard: 0,
                        search_us: 250,
                    });
                    sink.record(crate::obs::ObsEvent::Completed {
                        eval_id: 1,
                        shard: 0,
                        objective: 12.75,
                        best_so_far: 12.75,
                        sim_wallclock_s: 30.0,
                    });
                    sink.snapshot()
                },
                events: vec![crate::obs::RingEvent {
                    seq: 41,
                    ev: crate::obs::ObsEvent::StragglerKilled { eval_id: 7, shard: 1 },
                }],
                next: 42,
            }),
            Message::Response(Response::Error { message: "no such campaign".into() }),
            Message::Event(Event::EvalCompleted {
                campaign: 2,
                eval_id: 11,
                config_key: "1,4,0,2".into(),
                objective: 12.75,
                runtime_s: f64::INFINITY, // travels as null, reads as +inf
                best_so_far: 12.75,
                timed_out: true,
                cancelled: false,
            }),
            Message::Event(Event::Degraded {
                campaign: 4,
                applied: 7,
                message: "retry budget exhausted at `ckpt-write` after 6 attempts".into(),
            }),
            Message::Event(Event::Done {
                campaign: 2,
                summary: CampaignSummary {
                    evaluations: 16,
                    baseline_objective: 20.0,
                    best_objective: 12.75,
                    best_config_desc: "OMP_NUM_THREADS=64".into(),
                    improvement_pct: 36.25,
                    wallclock_s: 480.5,
                },
            }),
        ]
    }

    #[test]
    fn frames_roundtrip() {
        for msg in sample_messages() {
            let bytes = encode_frame(&msg);
            let (back, used) = decode_frame(&bytes).unwrap().unwrap();
            assert_eq!(used, bytes.len());
            assert_eq!(back, msg);
        }
    }

    #[test]
    fn decoder_reassembles_byte_at_a_time() {
        let msgs = sample_messages();
        let mut wire = Vec::new();
        for m in &msgs {
            wire.extend_from_slice(&encode_frame(m));
        }
        let mut dec = Decoder::new();
        let mut got = Vec::new();
        for b in wire {
            got.extend(dec.push(&[b]).unwrap());
        }
        assert_eq!(got, msgs);
        assert_eq!(dec.pending(), 0);
    }

    #[test]
    fn junk_and_oversized_frames_are_rejected() {
        assert!(matches!(decode_frame(b"xx"), Err(ProtocolError::BadMagic(_))));
        assert!(matches!(decode_frame(b"Yx"), Err(ProtocolError::BadMagic(_))));
        assert!(matches!(decode_frame(&[b'Y', b'T', 99]), Err(ProtocolError::BadVersion(99))));
        assert!(matches!(
            decode_frame(&[b'Y', b'T', PROTOCOL_VERSION, 7]),
            Err(ProtocolError::BadKind(7))
        ));
        let mut oversized = vec![b'Y', b'T', PROTOCOL_VERSION, 1];
        oversized.extend_from_slice(&(MAX_FRAME_BYTES as u32 + 1).to_be_bytes());
        assert!(matches!(decode_frame(&oversized), Err(ProtocolError::Oversized(_))));
        // a valid prefix is not an error
        let frame = encode_frame(&Message::Request(Request::Ping));
        for cut in 0..frame.len() {
            assert_eq!(decode_frame(&frame[..cut]).unwrap(), None, "cut at {cut}");
        }
    }

    #[test]
    fn spec_lowers_to_a_runnable_setup_and_back() {
        let spec = CampaignSpec { seed: u64::MAX - 5, workers: 3, ..CampaignSpec::default() };
        let setup = spec.to_setup().unwrap();
        assert_eq!(setup.seed, u64::MAX - 5);
        assert_eq!(setup.ensemble_workers, 3);
        // from_setup emits canonical tokens ("xsbench-history", not
        // "xsbench"); lowering again must land on the identical setup
        let back = CampaignSpec::from_setup(&setup).unwrap();
        let setup2 = back.to_setup().unwrap();
        assert_eq!(setup2.app, setup.app);
        assert_eq!(setup2.platform, setup.platform);
        assert_eq!(setup2.metric, setup.metric);
        assert_eq!(setup2.seed, setup.seed);
        assert_eq!(setup2.ensemble_workers, setup.ensemble_workers);
        assert_eq!(setup2.liar, setup.liar);
        // wire roundtrip preserves the full-width seed
        let wire = CampaignSpec::from_json(&Json::parse(&spec.to_json().to_string()).unwrap());
        assert_eq!(wire, spec);
    }

    #[test]
    fn spec_validation_rejects_unrunnable_campaigns() {
        let bad = |f: &dyn Fn(&mut CampaignSpec)| {
            let mut s = CampaignSpec::default();
            f(&mut s);
            s.to_setup().is_err()
        };
        assert!(bad(&|s| s.app = "no-such-app".into()));
        assert!(bad(&|s| s.platform = "frontier".into()));
        assert!(bad(&|s| s.metric = "latency".into()));
        assert!(bad(&|s| s.workers = 1), "serial campaigns are not the service engine");
        assert!(bad(&|s| s.workers = 65));
        assert!(bad(&|s| s.max_evals = 0));
        assert!(bad(&|s| s.strategy = "annealing".into()));
        assert!(bad(&|s| s.liar = "truth".into()));
    }
}
