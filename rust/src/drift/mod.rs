//! Continuous-controller primitives: drift detection and authority
//! limits for online re-tuning (ROADMAP "continuous controller").
//!
//! The paper's campaigns tune, deploy the best configuration, and stop.
//! At large scale the substrate under a deployed configuration *moves* —
//! input phases shift, thermal envelopes change, co-scheduled jobs
//! contend — and a tuner that never re-opens its eyes keeps serving a
//! stale optimum. The continuous controller keeps the tuning loop alive
//! after convergence, but a controller that adjusts a production
//! application must be *governed*:
//!
//! * [`CusumDetector`] — two-sided CUSUM over standardized
//!   predicted-vs-observed residuals. The surrogate is the controller's
//!   world model; when reality walks away from it in a sustained
//!   direction, the cumulative sum crosses its threshold and the
//!   controller discards the stale window instead of averaging the old
//!   world into the new one.
//! * [`AuthorityLimiter`] — bounded per-update actuation: one apply may
//!   move at most one parameter by at most `max_delta` ordinal steps
//!   from the currently deployed configuration. A surrogate reset (or a
//!   quarantined batch of garbage observations) can therefore never
//!   slam a production app across the space in one step.
//! * [`quarantine`] — data-quality gate in front of the surrogate:
//!   non-finite, non-positive, or wildly out-of-band objectives are
//!   recorded in the history but never trusted as model evidence.
//!
//! Everything here is pure arithmetic on values the caller already
//! holds — no clock, no RNG, no I/O — so controller trajectories remain
//! a pure function of `(setup, seed)` like every other core path.

use crate::space::{ConfigSpace, Configuration};

/// CUSUM slack (the "allowance" k): residuals within half a standard
/// deviation of the model are treated as noise, not evidence of drift.
pub const CUSUM_SLACK: f64 = 0.5;

/// Objectives at or beyond this multiple of the baseline objective are
/// quarantined as out-of-band (a faulted node or a mis-measured run,
/// not a configuration this bad).
pub const QUARANTINE_BAND: f64 = 3.0;

/// Two-sided CUSUM detector over standardized residuals.
///
/// Feed it `z = (observed - predicted) / scale` per completion;
/// [`CusumDetector::observe`] returns `true` when the accumulated
/// one-sided sum (either direction) crosses the threshold, and resets
/// both sums so detection re-arms for the next drift. State is exposed
/// for checkpointing so a resumed controller re-arms mid-accumulation
/// exactly where the killed one stood.
#[derive(Debug, Clone, PartialEq)]
pub struct CusumDetector {
    threshold: f64,
    pos: f64,
    neg: f64,
}

impl CusumDetector {
    pub fn new(threshold: f64) -> CusumDetector {
        CusumDetector { threshold: threshold.max(0.0), pos: 0.0, neg: 0.0 }
    }

    /// Accumulate one standardized residual; `true` means drift fired
    /// (and the detector has reset itself). Non-finite residuals are
    /// ignored — the quarantine gate upstream owns those.
    pub fn observe(&mut self, z: f64) -> bool {
        if !z.is_finite() {
            return false;
        }
        self.pos = (self.pos + z - CUSUM_SLACK).max(0.0);
        self.neg = (self.neg - z - CUSUM_SLACK).max(0.0);
        if self.pos > self.threshold || self.neg > self.threshold {
            self.pos = 0.0;
            self.neg = 0.0;
            return true;
        }
        false
    }

    /// Accumulator state `(pos, neg)` for checkpointing.
    pub fn state(&self) -> (f64, f64) {
        (self.pos, self.neg)
    }

    /// Restore checkpointed accumulator state.
    pub fn restore(&mut self, pos: f64, neg: f64) {
        self.pos = pos.max(0.0);
        self.neg = neg.max(0.0);
    }
}

/// Bounded per-update actuation authority.
///
/// Given the currently *deployed* configuration and the strategy's
/// *proposed* one, [`AuthorityLimiter::limit`] returns the largest move
/// the controller is allowed to actually apply: at most one parameter
/// changes, by at most `max_delta` index steps, chosen as the axis where
/// the proposal disagrees most (ties broken by lowest parameter index,
/// so the choice is deterministic). If the limited move lands on an
/// invalid configuration (constraint coupling), the deployed
/// configuration is returned unchanged — a no-op is always safe.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AuthorityLimiter {
    max_delta: usize,
}

impl AuthorityLimiter {
    pub fn new(max_delta: usize) -> AuthorityLimiter {
        AuthorityLimiter { max_delta: max_delta.max(1) }
    }

    pub fn max_delta(&self) -> usize {
        self.max_delta
    }

    /// Largest permitted step from `deployed` toward `proposed`.
    pub fn limit(
        &self,
        space: &ConfigSpace,
        deployed: &Configuration,
        proposed: &Configuration,
    ) -> Configuration {
        let cur = deployed.indices();
        let want = proposed.indices();
        debug_assert_eq!(cur.len(), want.len());
        // axis with the largest disagreement; ties -> lowest index
        let mut axis: Option<(usize, u32)> = None;
        for (j, (&a, &b)) in cur.iter().zip(want.iter()).enumerate() {
            let d = a.abs_diff(b);
            if d > 0 && axis.map_or(true, |(_, best)| d > best) {
                axis = Some((j, d));
            }
        }
        let Some((j, d)) = axis else {
            return deployed.clone();
        };
        let step = (self.max_delta as u32).min(d);
        let mut idx = cur.to_vec();
        idx[j] = if want[j] > cur[j] { cur[j] + step } else { cur[j] - step };
        let limited = Configuration::from_indices(idx);
        if space.is_valid(&limited) {
            limited
        } else {
            deployed.clone()
        }
    }

    /// Number of index steps (summed over axes) between two
    /// configurations — what the authority-limit acceptance test
    /// asserts never exceeds `max_delta` across a whole event log.
    pub fn step_distance(a: &Configuration, b: &Configuration) -> usize {
        a.indices().iter().zip(b.indices().iter()).map(|(&x, &y)| x.abs_diff(y) as usize).sum()
    }
}

/// Data-quality gate: `true` means the observation must not enter the
/// surrogate as evidence (it is still recorded in the history database).
/// Quarantined: non-finite, non-positive (objectives here are runtimes /
/// energies — zero or negative means a broken measurement), or at least
/// [`QUARANTINE_BAND`]× the baseline objective.
pub fn quarantine(objective: f64, baseline_objective: f64) -> bool {
    if !objective.is_finite() || objective <= 0.0 {
        return true;
    }
    baseline_objective.is_finite()
        && baseline_objective > 0.0
        && objective >= QUARANTINE_BAND * baseline_objective
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::{Param, ParamDomain};

    fn toy_space() -> ConfigSpace {
        let mut s = ConfigSpace::new("toy");
        s.add(Param::new("a", ParamDomain::ordinal(&[0, 1, 2, 3, 4, 5, 6, 7])));
        s.add(Param::new("b", ParamDomain::ordinal(&[0, 1, 2, 3])));
        s.add(Param::new("c", ParamDomain::Toggle));
        s
    }

    #[test]
    fn cusum_ignores_noise_and_fires_on_sustained_shift() {
        let mut d = CusumDetector::new(8.0);
        // zero-mean alternating noise never accumulates past the slack
        for i in 0..200 {
            let z = if i % 2 == 0 { 0.4 } else { -0.4 };
            assert!(!d.observe(z), "noise fired at step {i}");
        }
        // a sustained +2 sigma shift fires after ~threshold/(2-k) steps
        let mut fired_at = None;
        for i in 0..32 {
            if d.observe(2.0) {
                fired_at = Some(i);
                break;
            }
        }
        let at = fired_at.expect("sustained shift must fire");
        assert!((4..=8).contains(&at), "fired at {at}");
        // detector re-armed after firing
        assert_eq!(d.state(), (0.0, 0.0));
    }

    #[test]
    fn cusum_is_two_sided_and_skips_non_finite() {
        let mut d = CusumDetector::new(4.0);
        assert!(!d.observe(f64::NAN));
        assert!(!d.observe(f64::INFINITY));
        assert_eq!(d.state(), (0.0, 0.0));
        let mut fired = false;
        for _ in 0..16 {
            fired |= d.observe(-1.5);
        }
        assert!(fired, "downward drift must fire too");
    }

    #[test]
    fn cusum_state_roundtrips() {
        let mut a = CusumDetector::new(8.0);
        a.observe(1.2);
        a.observe(0.9);
        let (p, n) = a.state();
        let mut b = CusumDetector::new(8.0);
        b.restore(p, n);
        assert_eq!(a, b);
        // identical future trajectories
        for z in [1.0, -0.3, 2.1] {
            assert_eq!(a.observe(z), b.observe(z));
        }
        assert_eq!(a.state(), b.state());
    }

    #[test]
    fn limiter_moves_one_axis_at_most_max_delta() {
        let sp = toy_space();
        let lim = AuthorityLimiter::new(1);
        let cur = Configuration::from_indices(vec![2, 1, 0]);
        let want = Configuration::from_indices(vec![7, 3, 1]);
        let step = lim.limit(&sp, &cur, &want);
        // axis 0 has the largest disagreement (5); moved exactly 1 step
        assert_eq!(step.indices(), &[3, 1, 0]);
        assert_eq!(AuthorityLimiter::step_distance(&cur, &step), 1);
        // already-agreeing proposal is a no-op
        assert_eq!(lim.limit(&sp, &cur, &cur), cur);
    }

    #[test]
    fn limiter_steps_downward_and_breaks_ties_low() {
        let sp = toy_space();
        let lim = AuthorityLimiter::new(2);
        let cur = Configuration::from_indices(vec![5, 3, 1]);
        let want = Configuration::from_indices(vec![2, 0, 1]);
        let step = lim.limit(&sp, &cur, &want);
        // axes 0 and 1 both disagree by 3; tie -> axis 0, downward, 2 steps
        assert_eq!(step.indices(), &[3, 3, 1]);
        assert!(AuthorityLimiter::step_distance(&cur, &step) <= 2);
    }

    #[test]
    fn limiter_never_leaves_the_valid_region() {
        let mut sp = toy_space();
        sp.constrain("a-even-when-c", |sp, c| {
            sp.int_value(c, "c") == 0 || sp.int_value(c, "a") % 2 == 0
        });
        let lim = AuthorityLimiter::new(1);
        let cur = Configuration::from_indices(vec![2, 0, 1]);
        let want = Configuration::from_indices(vec![3, 0, 1]); // odd `a` with c=1: invalid
        assert_eq!(lim.limit(&sp, &cur, &want), cur, "invalid step must be a no-op");
    }

    #[test]
    fn quarantine_rejects_garbage_and_passes_plausible_objectives() {
        assert!(quarantine(f64::NAN, 100.0));
        assert!(quarantine(f64::INFINITY, 100.0));
        assert!(quarantine(0.0, 100.0));
        assert!(quarantine(-3.0, 100.0));
        assert!(quarantine(300.0, 100.0), "3x baseline is out of band");
        assert!(!quarantine(299.0, 100.0));
        assert!(!quarantine(40.0, 100.0));
        // no baseline yet: only the finite/positive gate applies
        assert!(!quarantine(1e9, f64::NAN));
        assert!(quarantine(f64::NAN, f64::NAN));
    }
}
